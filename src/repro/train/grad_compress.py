"""Int8 error-feedback gradient compression for the pure-DP (pod) axis.

Distributed-optimization trick for multi-pod scale: the inter-pod gradient
all-reduce crosses the slowest links (DCN/optical), so its volume dominates.
We compress to int8 with error feedback (1-bit-Adam / EF-SGD lineage):

    q  = quantize(g + e)          # int8, per-leaf max-abs scale
    ĝ  = allreduce_int8(q)        # reduce-scatter + all-gather in int8
    e' = (g + e) - dequant(q)     # residual carried to the next step

The int8 exchange is two ``all_to_all``/``all_gather`` rounds on one quarter
of the fp32 volume.  Exact when every pod sees identical data (q identical);
otherwise standard EF convergence applies.  Exposed as a standalone operator
(HPTMT array-operator, usable on any mesh axis) and unit-tested on a host
mesh; the trainer enables it on meshes with a ``pod`` axis.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_allreduce_mean(x: jnp.ndarray, err: jnp.ndarray, axis: str,
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Inside shard_map: int8 mean-allreduce of ``x`` with error feedback.

    Returns (averaged value, new error state). x/err are the local shard's
    full gradient leaf (replicated shape across the axis).
    """
    from repro.core.array_ops import axis_size
    n = axis_size(axis)
    xe = x.astype(jnp.float32) + err
    # pad flat length to a multiple of the axis size
    flat = xe.reshape(-1)
    pad = (-flat.shape[0]) % n
    flat_p = jnp.pad(flat, (0, pad))

    q, scale = _quantize(flat_p)
    # stage 1: reduce-scatter in int8 — each member sums one chunk
    chunks = q.reshape(n, -1)
    mine = jax.lax.all_to_all(chunks, axis, split_axis=0, concat_axis=0,
                              tiled=False)                      # (n, chunk)
    scales = jax.lax.all_gather(scale, axis)                    # (n,)
    part = jnp.sum(mine.astype(jnp.float32) * scales[:, None], axis=0) / n

    # stage 2: all-gather the reduced chunk in int8
    q2, scale2 = _quantize(part)
    full_q = jax.lax.all_gather(q2, axis, axis=0, tiled=True)
    scale2_all = jax.lax.all_gather(scale2, axis)               # (n,)
    per_chunk = full_q.reshape(n, -1).astype(jnp.float32) \
        * scale2_all[:, None]
    result = per_chunk.reshape(-1)[:flat.shape[0]].reshape(x.shape)

    # error feedback on the local quantization
    dq_local = (q.astype(jnp.float32) * scale)[:flat.shape[0]].reshape(x.shape)
    new_err = xe - dq_local
    return result.astype(x.dtype), new_err


def init_error_state(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def tree_ef_allreduce(grads, err_state, axis: str):
    """Apply ef_allreduce_mean leaf-wise (inside shard_map over ``axis``)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    out = [ef_allreduce_mean(g, e, axis) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
