"""Training loop: HPTMT composition of table-operator data pipeline and
tensor-operator train steps, with workflow-level fault tolerance.

The loop body is intentionally thin — operators do the work. Fault handling
follows the paper (§VII-F): the trainer snapshots through
``CheckpointManager`` and restarts resume from the last snapshot (exercised
in tests by killing and re-running the loop); per-step timings feed the
straggler monitor.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ModelConfig
from repro.train.train_step import (TrainConfig, TrainState, init_train_state,
                                    make_train_step)
from repro.workflow.engine import StragglerMonitor, Stopwatch


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None


def train_loop(cfg: ModelConfig, tcfg: TrainConfig, loop: LoopConfig,
               batches: Iterator[Dict[str, Any]], rng=None,
               state: Optional[TrainState] = None,
               log_fn: Callable[[str], None] = print) -> TrainState:
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    ckpt = (CheckpointManager(loop.checkpoint_dir, async_save=True)
            if loop.checkpoint_dir else None)

    start_step = 0
    if state is None:
        state = init_train_state(rng, cfg)
        if ckpt is not None and ckpt.latest_step() is not None:
            start_step = ckpt.latest_step()
            state = ckpt.restore(state)
            log_fn(f"[trainer] resumed from checkpoint step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    monitor = StragglerMonitor()
    history = []
    for step in range(start_step, loop.total_steps):
        batch = next(batches)
        with Stopwatch() as sw:
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
        slow = monitor.record(sw.seconds)
        history.append(float(metrics["loss"]))
        if step % loop.log_every == 0 or step == loop.total_steps - 1:
            log_fn(f"[trainer] step {step:5d} "
                   f"loss={float(metrics['loss']):.4f} "
                   f"acc={float(metrics['accuracy']):.3f} "
                   f"lr={float(metrics['lr']):.2e} "
                   f"gnorm={float(metrics['grad_norm']):.2f} "
                   f"dt={sw.seconds * 1e3:.0f}ms"
                   + (" [straggler]" if slow else ""))
        if ckpt is not None and (step + 1) % loop.checkpoint_every == 0:
            ckpt.save(step + 1, state)
    if ckpt is not None:
        ckpt.save(loop.total_steps, state)
        ckpt.wait()
    train_loop.last_history = history  # introspection for tests/examples
    return state
