"""AdamW with fp32 master weights, global-norm clipping, LR schedules.

Optimizer state (m, v) inherits each parameter's sharding (FSDP×TP), so the
ZeRO-style memory split is automatic under pjit.  Built from scratch (no
optax dependency) as required by the implement-everything mandate.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    mu: Any
    nu: Any
    count: jnp.ndarray


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    return OptState(mu=zeros,
                    nu=jax.tree.map(jnp.zeros_like, zeros),
                    count=jnp.zeros((), jnp.int32))


def lr_schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup → cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = cfg.learning_rate * step / max(cfg.warmup_steps, 1)
    progress = jnp.clip((step - cfg.warmup_steps)
                        / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * progress))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.learning_rate * cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def _is_matrix(p) -> bool:
    return p.ndim >= 2


def adamw_update(cfg: OptimizerConfig, params, grads, state: OptState,
                 ) -> Tuple[Any, OptState, Dict[str, jnp.ndarray]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    count = state.count + 1
    lr = lr_schedule(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _is_matrix(p):
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(new_m, new_v, count), metrics
