"""Train-step factory: loss, grad accumulation, sharded pjit step."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.sharding import partition
from repro.train.optimizer import (OptimizerConfig, OptState, adamw_update,
                                   init_opt_state)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = OptimizerConfig()
    micro_batches: int = 1
    moe_aux_coef: float = 0.01
    router_z_coef: float = 1e-3
    grad_compress: bool = False   # int8 EF on the pod axis (pure-DP meshes)


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def init_train_state(rng, cfg: ModelConfig) -> TrainState:
    params = T.init_lm(rng, cfg)
    return TrainState(params=params, opt=init_opt_state(params))


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Masked next-token CE. labels == -1 are ignored. Returns (loss, acc).

    Sharding note: the gold logit is extracted with a one-hot contraction
    (not take_along_axis) and accuracy compares gold against the row max —
    both are plain reductions over the vocab dim, so they partition cleanly
    when logits are vocab-sharded (a vocab gather/argmax would force the
    SPMD partitioner to replicate the full logits tensor).
    """
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    v = logits.shape[-1]
    onehot = jax.nn.one_hot(safe, v, dtype=logits.dtype)
    gold = jnp.sum(logits * onehot, axis=-1)
    logz = jax.nn.logsumexp(logits, axis=-1)
    nll = (logz - gold) * mask
    denom = jnp.maximum(jnp.sum(mask), 1)
    loss = jnp.sum(nll) / denom
    row_max = jnp.max(logits, axis=-1)
    acc = jnp.sum((gold >= row_max) * mask) / denom
    return loss, acc


_KEEP_F32 = ("router", "a_log", "dt_bias", "b_gates", "scale", "b")


def cast_params_for_compute(params, dtype):
    """bf16-cast params *before* the FSDP all-gather (ZeRO trick).

    Weights are consumed in bf16 anyway; casting the fp32 masters first
    halves every per-layer parameter all-gather.  Precision-critical leaves
    (router logits, SSM decay/bias, norm scales) stay fp32.
    """

    def leaf(path, p):
        name = ""
        for part in path[::-1]:
            if isinstance(part, jax.tree_util.DictKey):
                name = str(part.key)
                break
        if name in _KEEP_F32 or p.ndim < 2:
            return p
        return p.astype(dtype)

    return jax.tree_util.tree_map_with_path(leaf, params)


def loss_fn(params, cfg: ModelConfig, tcfg: TrainConfig, batch,
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    # NOTE (§Perf C5): bf16-casting params here (before the FSDP gather)
    # was measured to leave the collective term unchanged (activation
    # psums dominate at this batch) while costing +0.7 GiB/dev for the
    # bf16 copy — refuted and reverted; `cast_params_for_compute` is kept
    # for smaller-batch regimes where parameter gathers dominate.
    logits, _, aux = T.apply_lm(
        params, cfg, batch["tokens"], mode="train",
        frontend_embeds=batch.get("frontend"))
    labels = batch["labels"]
    if cfg.frontend == "vision":
        # image prefix positions carry no LM loss
        pad = jnp.full(labels.shape[:1] + (cfg.frontend_seq,), -1,
                       labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    ce, acc = cross_entropy(logits, labels)
    total = (ce + tcfg.moe_aux_coef * aux["moe_aux_loss"]
             + tcfg.router_z_coef * aux["router_z_loss"])
    metrics = {"loss": ce, "accuracy": acc, **aux}
    return total, metrics


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns train_step(state, batch) → (state, metrics) (jit-compatible)."""

    def train_step(state: TrainState, batch):
        m = tcfg.micro_batches
        if m == 1:
            grads, metrics = jax.grad(
                lambda p: loss_fn(p, cfg, tcfg, batch), has_aux=True)(
                    state.params)
        else:
            # gradient accumulation over micro-batches via lax.scan: ONE
            # fwd/bwd loop pair in the HLO, so the per-group residual stack
            # is allocated once and reused across micro-steps (a Python
            # loop leaves every micro-step's stack allocated separately —
            # CPU XLA does not share while-carry buffers across loops).
            def micro(b):
                return jax.grad(
                    lambda p: loss_fn(p, cfg, tcfg, b), has_aux=True)(
                        state.params)

            def split(x):
                return x.reshape((m, x.shape[0] // m) + x.shape[1:])

            micro_batches = {k: split(v) for k, v in batch.items()}

            def body(acc, mb):
                g, met = micro(mb)
                acc_g, acc_m = acc
                acc_g = jax.tree.map(lambda a, b: a + b, acc_g, g)
                acc_m = jax.tree.map(lambda a, b: a + b, acc_m, met)
                return (acc_g, acc_m), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            zero_m = {k: jnp.zeros((), jnp.float32) for k in
                      ("loss", "accuracy", "moe_aux_loss", "router_z_loss",
                       "moe_dropped_frac")}
            (grads, metrics), _ = jax.lax.scan(
                body, (zero_g, zero_m), micro_batches)
            grads = jax.tree.map(lambda g: g / m, grads)
            metrics = jax.tree.map(lambda x: x / m, metrics)

        params, opt, opt_metrics = adamw_update(
            tcfg.optimizer, state.params, grads, state.opt)
        metrics.update(opt_metrics)
        return TrainState(params, opt), metrics

    return train_step


def make_sharded_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh: Mesh,
                            state_template: TrainState, rules=None):
    """pjit the train step with FSDP×TP shardings derived from the rules."""
    pspecs = partition.param_specs(state_template.params, cfg, mesh, rules)

    def ns(spec):
        return NamedSharding(mesh, spec)

    pshard = jax.tree.map(ns, pspecs,
                          is_leaf=lambda x: isinstance(x, P))
    opt_shard = OptState(mu=pshard, nu=pshard, count=ns(P()))
    state_shard = TrainState(params=pshard, opt=opt_shard)
    bspec = partition.batch_spec(mesh, rules)
    b_axes = bspec[0] if len(bspec) else None
    batch_shard = {"tokens": ns(P(b_axes)), "labels": ns(P(b_axes))}
    if cfg.frontend is not None or cfg.is_encoder_decoder:
        batch_shard["frontend"] = ns(P(b_axes))

    step = make_train_step(cfg, tcfg)
    return jax.jit(
        step,
        in_shardings=(state_shard, batch_shard),
        out_shardings=(state_shard, None),
        donate_argnums=(0,)), state_shard, batch_shard
