"""Parameter partitioning: param-tree paths → PartitionSpecs.

Strategy (DESIGN.md §5): FSDP (ZeRO-3) over the ``data`` axis × tensor
parallelism over ``model`` — heads/ff/vocab/experts on ``model``, the
d_model ("fsdp") dimension on ``data``.  Rules are *shape-validated*: if a
dimension is not divisible by its mapped mesh axes the axis is dropped
(e.g. kv_heads=8 on a 16-way model axis ⇒ replicated KV projections;
mixtral's 8 experts ⇒ expert-internal TP fallback instead of EP).

Everything under ``decoder``/``encoder`` is stacked with a leading
layer-group dimension (scan-over-layers), which is never sharded.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.sharding import axes as axes_mod

# rules keyed by (context, leaf name): logical axes per dim (unstacked shape)
_ATTN_RULES = {
    "wq": ("fsdp", "heads"), "wk": ("fsdp", "kv_heads"),
    "wv": ("fsdp", "kv_heads"), "wo": ("heads", "fsdp"),
    "wdq": ("fsdp", None), "wuq": (None, "heads"),
    "wdkv": ("fsdp", None), "wukv": (None, "heads"),
}
_MAMBA_RULES = {
    "in_proj": ("fsdp", "ssm_inner"), "conv_w": (None, "ssm_inner"),
    "conv_b": ("ssm_inner",), "x_proj": ("ssm_inner", None),
    "dt_proj": (None, "ssm_inner"), "dt_bias": ("ssm_inner",),
    "a_log": ("ssm_inner", None), "d_skip": ("ssm_inner",),
    "out_proj": ("ssm_inner", "fsdp"),
}
_XLSTM_RULES = {
    "w_up": ("fsdp", "ssm_inner"), "wq": (None, "ssm_inner"),
    "wk": (None, "ssm_inner"), "wv": (None, "ssm_inner"),
    "w_gates": (None, None), "b_gates": (None,),
    "w_down": ("ssm_inner", "fsdp"),
    "w_x": ("fsdp", None), "w_h": (None, None), "b": (None,),
}
_DENSE_FFN_RULES = {
    "w_gate": ("fsdp", "ff"), "w_in": ("fsdp", "ff"), "w_out": ("ff", "fsdp"),
}
_MOE_RULES = {
    "router": ("fsdp", None),
    "w_gate": ("expert", "fsdp", None), "w_in": ("expert", "fsdp", None),
    "w_out": ("expert", None, "fsdp"),
}
_MOE_TP_RULES = {  # fallback when E doesn't divide the model axis
    "router": ("fsdp", None),
    "w_gate": (None, "fsdp", "ff"), "w_in": (None, "fsdp", "ff"),
    "w_out": (None, "ff", "fsdp"),
}


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            names.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            names.append(p.name)
    return tuple(names)


def _axis_size(mesh: Mesh, logical: Optional[str], rules) -> int:
    if logical is None:
        return 1
    mapped = rules.get(logical)
    if mapped is None:
        return 1
    mapped = (mapped,) if isinstance(mapped, str) else mapped
    return math.prod(mesh.shape.get(a, 1) for a in mapped)


def _logical_for(names: Tuple[str, ...], shape, cfg: ModelConfig,
                 mesh: Mesh, rules) -> Tuple[Optional[str], ...]:
    name = names[-1]
    stacked = any(n in ("decoder", "encoder") for n in names)
    eff_ndim = len(shape) - (1 if stacked else 0)

    if name == "embed":
        logical = (None, "embed_d")
    elif name == "lm_head":
        logical = ("fsdp", "vocab")
    elif name == "scale":
        logical = (None,) * eff_ndim
    elif "mixer" in names or "cross" in names:
        # pick family by layer kind from the path
        kind = "attn"
        for n in names:
            if n.startswith("layer_"):
                i = int(n.split("_")[1])
                kind = cfg.block_pattern[i % cfg.group_size]
        if "cross" in names:
            kind = "attn"
        table = {"attn": _ATTN_RULES, "mamba": _MAMBA_RULES,
                 "mlstm": _XLSTM_RULES, "slstm": _XLSTM_RULES}[kind]
        logical = table.get(name, (None,) * eff_ndim)
    elif "shared" in names:
        logical = _DENSE_FFN_RULES.get(name, (None,) * eff_ndim)
    elif "ffn" in names:
        if eff_ndim == 3 or name == "router":
            e_pad = shape[-3] if eff_ndim == 3 else 0
            model_size = _axis_size(mesh, "expert", axes_mod.DEFAULT_RULES)
            ep_ok = e_pad > 0 and e_pad % max(model_size, 1) == 0
            table = _MOE_RULES if ep_ok or name == "router" else _MOE_TP_RULES
            logical = table.get(name, (None,) * eff_ndim)
        else:
            logical = _DENSE_FFN_RULES.get(name, (None,) * eff_ndim)
    else:
        logical = (None,) * eff_ndim

    if len(logical) != eff_ndim:
        logical = (None,) * eff_ndim
    if stacked:
        logical = (None,) + logical
    return logical


def param_spec(names: Tuple[str, ...], shape, cfg: ModelConfig,
               mesh: Mesh, rules=None) -> P:
    rules = rules or axes_mod.DEFAULT_RULES
    logical = _logical_for(names, shape, cfg, mesh, rules)
    # shape-validate: drop axes that do not divide the dimension
    parts = []
    used = set()
    for dim, lg in zip(shape, logical):
        mapped = rules.get(lg) if lg else None
        if mapped is None:
            parts.append(None)
            continue
        cand = (mapped,) if isinstance(mapped, str) else tuple(mapped)
        cand = tuple(a for a in cand if a in mesh.axis_names
                     and a not in used)
        size = math.prod(mesh.shape[a] for a in cand) if cand else 1
        if not cand or dim % size != 0:
            parts.append(None)
            continue
        used.update(cand)
        parts.append(cand[0] if len(cand) == 1 else cand)
    return P(*parts)


def param_shardings(params, cfg: ModelConfig, mesh: Mesh, rules=None):
    """Pytree of NamedShardings matching ``params``."""

    def leaf(path, x):
        spec = param_spec(_path_names(path), x.shape, cfg, mesh, rules)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf, params)


def param_specs(params, cfg: ModelConfig, mesh: Mesh, rules=None):
    """Pytree of PartitionSpecs matching ``params``."""

    def leaf(path, x):
        return param_spec(_path_names(path), x.shape, cfg, mesh, rules)

    return jax.tree_util.tree_map_with_path(leaf, params)


def batch_spec(mesh: Mesh, rules=None) -> P:
    rules = rules or axes_mod.DEFAULT_RULES
    mapped = rules.get("batch")
    mapped = (mapped,) if isinstance(mapped, str) else tuple(mapped or ())
    axes = tuple(a for a in mapped if a in mesh.axis_names)
    if not axes:
        return P()
    return P(axes if len(axes) > 1 else axes[0])


def cache_shardings(cache, cfg: ModelConfig, mesh: Mesh, rules=None):
    """KV/state cache shardings: batch over DP axes, heads/L over model.

    For archs whose KV-head count doesn't divide the model axis, the cache
    *length* dimension is model-sharded instead (sequence-sharded KV).
    """
    rules = rules or axes_mod.DEFAULT_RULES
    bspec = batch_spec(mesh, rules)
    b_axes = bspec[0] if len(bspec) else None
    model_ok = "model" in mesh.axis_names
    msize = mesh.shape.get("model", 1)

    def leaf(path, x):
        names = _path_names(path)
        name = names[-1]
        # stacked leading group dim
        if name in ("pos", "cursor"):
            return NamedSharding(mesh, P())
        if name in ("k", "v", "k_s", "v_s"):   # (G, B, Hkv, L, Dh|1)
            hk = x.shape[2]
            if model_ok and hk % msize == 0:
                spec = P(None, b_axes, "model", None, None)
            elif model_ok and x.shape[3] % msize == 0:
                spec = P(None, b_axes, None, "model", None)
            else:
                spec = P(None, b_axes)
            return NamedSharding(mesh, spec)
        if name == "c_kv":              # (G, B, L, r)
            spec = P(None, b_axes, "model" if model_ok and
                     x.shape[2] % msize == 0 else None, None)
            return NamedSharding(mesh, spec)
        if name == "k_rope":            # (G, B, 1, L, rd)
            spec = P(None, b_axes, None, "model" if model_ok and
                     x.shape[3] % msize == 0 else None, None)
            return NamedSharding(mesh, spec)
        if name in ("ssm", "conv"):     # (G, B, ...) mamba states
            # shard d_inner over model
            din_axis = 2 if name == "ssm" else 3
            shape = x.shape
            spec_list = [None, b_axes] + [None] * (len(shape) - 2)
            if model_ok and len(shape) > din_axis \
                    and shape[din_axis] % msize == 0:
                spec_list[din_axis] = "model"
            return NamedSharding(mesh, P(*spec_list))
        if name == "enc_out":           # (B, F, D)
            return NamedSharding(mesh, P(b_axes, None, None))
        # xlstm states (G, B, ...)
        return NamedSharding(mesh, P(None, b_axes))

    return jax.tree_util.tree_map_with_path(leaf, cache)
