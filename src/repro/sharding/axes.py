"""Logical-axis sharding: rules mapping logical tensor axes → mesh axes.

Model code annotates activations with *logical* axes (``batch``, ``seq``,
``heads``, ``ff`` …); the launcher binds a mesh + rule set, and
:func:`constrain` lowers the annotation to ``with_sharding_constraint``.
Unbound (test / single-device) execution makes ``constrain`` a no-op — the
same model code runs everywhere (HPTMT principle (c)/(d)).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: F401

MeshAxes = Union[None, str, Tuple[str, ...]]

# default logical→mesh rules for the production mesh (pod, data, model)
DEFAULT_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),     # DP over pods × data axis
    "seq": None,
    "embed": None,
    "heads": "model",             # TP: attention heads
    "kv_heads": "model",
    "q_lora": None,
    "kv_lora": None,
    "ff": "model",                # TP: FFN hidden
    "vocab": "model",             # TP: vocab / logits
    "embed_d": "model",           # embedding table: shard d_model, NOT vocab
                                  # (vocab-sharded gather forces involuntary
                                  # replication in the SPMD partitioner)
    "expert": "model",            # EP: routed experts
    "moe_ff": None,               # expert-internal hidden (TP fallback: model)
    "fsdp": "data",               # parameter sharding (ZeRO-3 style)
    "ssm_inner": "model",
    "kv_seq": "model",            # sequence-sharded KV (decode)
    "state": None,
}


class _Binding(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Dict[str, MeshAxes] = dict(DEFAULT_RULES)


_BINDING = _Binding()


@contextlib.contextmanager
def logical_binding(mesh: Optional[Mesh], rules: Optional[Dict] = None):
    """Bind mesh + rules for ``constrain``/``spec_for`` inside the block."""
    old = (_BINDING.mesh, _BINDING.rules)
    _BINDING.mesh = mesh
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    _BINDING.rules = merged
    try:
        yield
    finally:
        _BINDING.mesh, _BINDING.rules = old


def current_mesh() -> Optional[Mesh]:
    return _BINDING.mesh


def spec_for(logical_axes: Sequence[Optional[str]]) -> P:
    """Translate logical axis names to a PartitionSpec under current rules."""
    rules = _BINDING.rules
    mesh = _BINDING.mesh
    used = set()
    parts = []
    for ax in logical_axes:
        mapped = rules.get(ax) if ax is not None else None
        if mapped is None:
            parts.append(None)
            continue
        axes = (mapped,) if isinstance(mapped, str) else tuple(mapped)
        if mesh is not None:
            axes = tuple(a for a in axes if a in mesh.axis_names)
        axes = tuple(a for a in axes if a not in used)
        used.update(axes)
        if not axes:
            parts.append(None)
        elif len(axes) == 1:
            parts.append(axes[0])
        else:
            parts.append(axes)
    return P(*parts)


def constrain(x, *logical_axes: Optional[str]):
    """with_sharding_constraint by logical axes; no-op when unbound."""
    mesh = _BINDING.mesh
    if mesh is None:
        return x
    spec = spec_for(logical_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def embed_lookup(embed, tokens):
    """Embedding gather that sidesteps the SPMD partitioner.

    With the table sharded (vocab replicated, d_model over ``model``) and
    token ids sharded over the DP axes, the gather is *local* per device —
    but the auto-partitioner mis-handles gather-from-sharded-operand (it
    either replicates the output or emits invalid dynamic-slices).  A
    ``shard_map`` pins the obvious strategy: every shard gathers its own
    d-slice for its own batch rows; backward is the matching local
    scatter-add.  Unbound contexts use the plain gather.
    """
    mesh = _BINDING.mesh
    if mesh is None:
        return embed[tokens]
    rules = _BINDING.rules
    d_axis = rules.get("embed_d")
    if isinstance(d_axis, tuple):
        d_axis = d_axis[0] if d_axis else None
    if d_axis is not None and d_axis not in mesh.axis_names:
        d_axis = None
    if d_axis is not None and embed.shape[1] % mesh.shape[d_axis]:
        d_axis = None
    b_spec = spec_for(["batch"])[0]

    def local(e, t):
        return e[t]

    from repro.core.context import compat_shard_map
    fn = compat_shard_map(
        local, mesh=mesh,
        in_specs=(P(None, d_axis), P(b_spec, None)),
        out_specs=P(b_spec, None, d_axis))
    return fn(embed, tokens)


def divisible(n: int, axis: MeshAxes) -> bool:
    """Can dimension ``n`` be sharded over the mapped mesh axes?"""
    mesh = _BINDING.mesh
    if mesh is None or axis is None:
        return True
    axes = (axis,) if isinstance(axis, str) else axis
    size = 1
    for a in axes:
        size *= mesh.shape.get(a, 1)
    return n % size == 0
