"""Physical planner: lower an optimized logical plan to ONE traced program.

``PhysicalPlan`` walks the logical tree bottom-up and builds a single
closure over the eager ``table_ops`` engines — the whole pipeline then
traces (and jits) as one program, which is what makes cross-operator
layout reasoning sound: the planner tracks the TRUE layout of every
intermediate in a :class:`Layout` value and *sets the partitioning stamp
explicitly before each operator call*, so per-op elision decisions are
taken here, with whole-pipeline knowledge, not by the operators' local
metadata checks (DESIGN.md §11).

Layout-driven strategies (the elision-proof catalog):

  join      a side whose TRUE layout is hash on exactly the join keys
            skips its shuffle (the eager §4 rule, applied transitively)
  groupby   ANY layout (hash or range) whose key SET equals the group
            keys proves equal key-combos co-located → grouping is purely
            local.  Placement survives: the output keeps the input's
            layout, which the per-call metadata stamp cannot express.
  orderby   input range-placed on the same keys/directions but locally
            unordered (e.g. a groupby ran on it) needs only a per-shard
            ``local_sort`` — zero AllToAll; an exact ordered match is a
            no-op
  window    input co-located on the partition keys (hash or range, any
            key order) ⇒ no partition straddles a shard ⇒ a local sort
            by ``partition_by + order_by`` replaces the range exchange.
            ``lead`` aggs are excluded: their truncation accounting
            reads downstream shards and can over-report on co-located
            layouts; they take the full exchange.
  groupby→orderby (rule "choose-range-layout"): the groupby exchanges by
            RANGE instead of hash; grouping elides by co-location and
            the orderby finishes with a local sort — one AllToAll where
            the eager chain pays two.

Identity contract: hash placement co-locates by the 32-bit *bit-pattern*
identity of ``hash_columns`` (``-0.0 != +0.0``; NaNs equal iff their
bits are), which is exactly the grouping/join identity — and the window
partition identity except for heterogeneous NaN bit patterns, which are
out of contract for hash layouts exactly as they are for the eager
hash join (DESIGN.md §8).

``inputs()`` (scan I/O) is lazy — ``explain()`` builds a full physical
plan, with per-scan pushdown detail, without reading a single data page.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro import telemetry
from repro.core import table_ops
from repro.core.table import (DistTable, partitioning_ascending,
                              partitioning_keys, partitioning_kind,
                              range_partitioning)

from .logical import LogicalNode

_FLIP = {"inner": "inner", "left": "right", "right": "left",
         "outer": "outer"}


@dataclasses.dataclass(frozen=True)
class Layout:
    """TRUE physical layout of an intermediate (vs. the metadata stamp).

    ``kind="hash"``: rows placed by ``hash(keys) % n`` (genuine, ordered
    tuple).  ``kind="range"``: shards hold disjoint contiguous key
    ranges; ``ordered=True`` adds that rows are ALSO locally sorted, so
    the table is globally sorted (the full ``("range", ...)`` stamp).
    ``ordered=False`` keeps only the placement half — co-location
    evidence no metadata stamp can carry.
    """
    kind: str = "none"  # none | hash | range
    keys: Tuple[str, ...] = ()
    ascending: Tuple[bool, ...] = ()
    ordered: bool = False

    def describe(self) -> str:
        if self.kind == "none":
            return "none"
        d = f"{self.kind}({','.join(self.keys)})"
        if self.kind == "range":
            d += "+sorted" if self.ordered else "+placed"
        return d


def _from_stamp(part) -> Layout:
    kind = partitioning_kind(part)
    if kind == "hash":
        return Layout("hash", tuple(partitioning_keys(part)))
    if kind == "range":
        return Layout("range", tuple(partitioning_keys(part)),
                      tuple(partitioning_ascending(part)), True)
    return Layout()


def _to_stamp(layout: Layout, n: int):
    """The honest metadata stamp for a layout (coloc-only → None)."""
    if layout.kind == "hash":
        return (layout.keys, n)
    if layout.kind == "range" and layout.ordered:
        return range_partitioning(layout.keys, layout.ascending, n)
    return None


def _coloc(layout: Layout, keys) -> bool:
    """Equal key-combos provably on one shard (any key order)."""
    return (layout.kind in ("hash", "range") and len(keys) > 0
            and set(layout.keys) == set(keys))


def _hash_exact(layout: Layout, keys) -> bool:
    return layout.kind == "hash" and layout.keys == tuple(keys)


def _restamp(dt: DistTable, part) -> DistTable:
    return DistTable(dt.columns, dt.counts, part)


@dataclasses.dataclass(frozen=True)
class PlanStep:
    """One physical operator: strategy + predicted AllToAll count.

    ``stage`` marks an exchange boundary — a step whose strategy moves
    rows between shards (pre-clamp, so single-shard runs keep the same
    stage structure).  Stage steps are where ``collect(policy=...)``
    commits lineage checkpoints (DESIGN.md §13.2).

    ``est_rows`` / ``est_bytes`` are the planner's deterministic
    predictions (manifest cardinality estimate + the packed-lane
    live-bytes model, DESIGN.md §14) that the op-by-op instrumentation
    audits against observed ``rows_out`` / ``peak_rss_delta_kb``.
    """
    index: int
    op: str
    strategy: str
    a2a: int
    detail: str = ""
    stage: bool = False
    est_rows: Optional[float] = None
    est_bytes: Optional[int] = None


class PhysicalPlan:
    """Lowered pipeline: ``fn(*inputs)`` runs everything in one trace.

    ``fn`` returns ``(DistTable, {step_label: overflow_scalar})`` and is
    jit/`make_jaxpr`-able; ``inputs()`` materializes leaf tables (scan
    I/O happens here, and only here).  ``steps`` carries the per-operator
    strategy and predicted collective count that ``explain()`` renders
    and the plan-contract tests assert against the traced jaxpr.
    """

    def __init__(self, root: LogicalNode, ctx):
        self.ctx = ctx
        self.root = root
        self.steps: List[PlanStep] = []
        self._input_specs: List[Tuple[str, object]] = []
        self._materialized: Optional[Tuple[DistTable, ...]] = None
        self.scan_overflow = 0
        # resilience hook: when set (collect(policy=...)), stage-boundary
        # steps route through it — restore a committed snapshot (skipping
        # the whole subtree) or run + commit.  None (the default) keeps
        # the executed program byte-identical to the hookless one.
        self.stage_hook = None
        self._est_cache: Dict[int, float] = {}
        run, layout = self._lower(root)
        self.out_layout = layout
        self._run = run

    # -- public surface ----------------------------------------------------
    @property
    def predicted_collectives(self) -> int:
        return sum(s.a2a for s in self.steps)

    def inputs(self) -> Tuple[DistTable, ...]:
        if self._materialized is None:
            tables, overflow = [], 0
            for kind, obj in self._input_specs:
                if kind == "table":
                    tables.append(obj)
                else:  # scan
                    dt, ov = obj.to_dist_table()
                    overflow += int(ov)
                    tables.append(dt)
            self.scan_overflow = overflow
            self._materialized = tuple(tables)
        return self._materialized

    def fn(self, *tables) -> Tuple[DistTable, Dict[str, jnp.ndarray]]:
        out, ovs = self._run(tables)
        out = _restamp(out, _to_stamp(self.out_layout, self.ctx.n_shards))
        return out, dict(ovs)

    # -- lowering ----------------------------------------------------------
    def _step(self, op: str, strategy: str, a2a: int,
              detail: str = "") -> PlanStep:
        stage = a2a > 0  # exchange boundary — judged before the clamp so
        # a 1-shard run checkpoints at the same stages as a 4-shard one
        if self.ctx.n_shards == 1:
            a2a = 0  # single shard: every exchange is local
        s = PlanStep(len(self.steps), op, strategy, a2a, detail, stage)
        self.steps.append(s)
        return s

    def _lower(self, node: LogicalNode) -> Tuple[Callable, Layout]:
        run, layout = getattr(self, f"_lower_{node.kind}")(node)
        # every _lower_* appends its own step LAST, so steps[-1] here is
        # the node just lowered (children were appended before it)
        step = self._annotate(self.steps[-1], node)
        run = self._instrument(run, step, layout)
        return self._resilient(run, step, layout), layout

    def _annotate(self, step: PlanStep, node: LogicalNode) -> PlanStep:
        """Stamp the step with its predicted cardinality and live bytes
        (manifests + schema widths only — deterministic, no data read).
        Safe to replace in-place: run closures capture only the index."""
        from repro.telemetry import memory as M

        from .rules import estimated_rows

        est = estimated_rows(node, self._est_cache)
        rows_in = sum(estimated_rows(i, self._est_cache)
                      for i in node.inputs)
        cols_in = max((len(i.schema) for i in node.inputs), default=0)
        est_bytes = M.step_live_bytes(
            step.op, rows_in=rows_in, rows_out=est, cols_in=cols_in,
            cols_out=len(node.schema), exchanges=step.a2a,
            n_shards=self.ctx.n_shards)
        step = dataclasses.replace(step, est_rows=est, est_bytes=est_bytes)
        self.steps[step.index] = step
        return step

    def _resilient(self, run: Callable, step: PlanStep,
                   layout: Layout) -> Callable:
        """Per-node fault-injection + stage-checkpoint wrapper.

        Always fires the ``plan.step.<idx>`` chaos site (a cheap no-op
        unless a fault is armed).  With a ``stage_hook`` installed and
        the step at an exchange boundary, the hook decides: restore a
        committed snapshot — the child closures never run, so a resumed
        trace contains only the suffix — or run and commit.
        """
        from repro.resilience import faults

        def wrapped(tables):
            faults.fire(f"plan.step.{step.index}")
            hook = self.stage_hook
            if hook is None or not step.stage:
                return run(tables)
            return hook(step, layout, lambda: run(tables))

        return wrapped

    def _instrument(self, run: Callable, step: PlanStep,
                    layout: Layout) -> Callable:
        """Per-node telemetry wrapper.

        Inert unless a collector is active AND the plan runs op-by-op
        (``collect(jit=False)``): inside a jit trace the host clock lies,
        so the wrapper passes straight through and the traced program is
        byte-identical to the uninstrumented one.  When live, each node
        becomes a ``plan.<index>.<op>`` span (children nested inside) and
        its measured time/rows land in ``Collector.plan_steps`` for
        ``explain(analyze=True)`` to join against the predicted steps.
        """
        label = f"plan.{step.index}.{step.op}"

        def wrapped(tables):
            from repro.telemetry import memory as M

            rec = telemetry.current()
            if rec is None or telemetry.tracing():
                return run(tables)
            with M.RssWatermark() as wm:
                with rec.span(label, op=step.op, strategy=step.strategy,
                              a2a=step.a2a, layout=layout.describe(),
                              est_rows=step.est_rows,
                              est_bytes=step.est_bytes) as sp:
                    out, ovs = run(tables)
                    sp.block(out)
                    rows = telemetry.record._rows_of(out)
                    if rows is not None:
                        sp.attrs["rows_out"] = rows
            sp.attrs["peak_rss_delta_kb"] = wm.delta_kb
            rec.observe_step(step.index, time_us=sp.dur_us, rows_out=rows,
                             peak_rss_delta_kb=wm.delta_kb)
            return out, ovs

        return wrapped

    def _lower_source(self, node: LogicalNode):
        dt: DistTable = node.payload["table"]
        idx = len(self._input_specs)
        self._input_specs.append(("table", dt))
        layout = _from_stamp(dt.partitioning)
        self._step("source", node.payload["name"], 0,
                   f"layout={layout.describe()}")
        return (lambda tables: (tables[idx], [])), layout

    def _lower_scan(self, node: LogicalNode):
        from repro.io.scan import ScanSource

        p = node.payload
        src = ScanSource(p["dataset"], ctx=self.ctx, columns=p["columns"],
                         predicate=p["predicate"], capacity=p["capacity"],
                         bucket_factor=p["bucket_factor"],
                         allow_narrowing=p["allow_narrowing"],
                         on_error=p.get("on_error", "raise"))
        idx = len(self._input_specs)
        self._input_specs.append(("scan", src))
        layout = _from_stamp(src.partitioning)
        st = src.stats
        kept = st.row_groups_total - st.row_groups_skipped
        self._step(
            "scan", "pushdown", 0,
            f"cols {len(src.read_columns)}/{st.columns_total}, "
            f"fragments {kept}/{st.row_groups_total}, "
            f"rows<={src.planned_rows}, layout={layout.describe()}")
        return (lambda tables: (tables[idx], [])), layout

    def _lower_filter(self, node: LogicalNode):
        crun, clay = self._lower(node.inputs[0])
        pred = node.payload["predicate"]
        if callable(pred):
            mask_fn, desc = pred, "callable"
        else:
            def mask_fn(cols, _ps=pred):
                m = _ps[0].mask(cols)
                for q in _ps[1:]:
                    m = m & q.mask(cols)
                return m
            desc = " AND ".join(f"{q.column}{q.op}{q.value!r}"
                                for q in pred)
        step = self._step("filter", "local", 0, desc)
        n = self.ctx.n_shards

        def run(tables, _step=step):
            t, ovs = crun(tables)
            out = table_ops.select(_restamp(t, _to_stamp(clay, n)),
                                   mask_fn, ctx=self.ctx)
            return out, ovs

        # filtering keeps placement AND local order (stable compaction)
        return run, clay

    def _lower_project(self, node: LogicalNode):
        crun, clay = self._lower(node.inputs[0])
        cols = node.payload["columns"]
        keeps = clay.kind != "none" and set(clay.keys) <= set(cols)
        out_layout = clay if keeps else Layout()
        self._step("project", "local", 0, ",".join(cols))
        n = self.ctx.n_shards

        def run(tables):
            t, ovs = crun(tables)
            out = table_ops.project(_restamp(t, _to_stamp(clay, n)),
                                    cols, ctx=self.ctx)
            return out, ovs

        return run, out_layout

    def _lower_join(self, node: LogicalNode):
        lrun, llay = self._lower(node.inputs[0])
        rrun, rlay = self._lower(node.inputs[1])
        p = node.payload
        keys, how, swap = p["keys"], p["how"], p["swap"]
        mm, method, kw = p["max_matches"], p["method"], dict(p["kw"])
        out_capacity = kw.pop("out_capacity", None)
        elide_l = _hash_exact(llay, keys)
        elide_r = _hash_exact(rlay, keys)
        a2a = int(not elide_l) + int(not elide_r)
        n = self.ctx.n_shards
        lsch, rsch = node.inputs[0].schema, node.inputs[1].schema
        dups = [c for c in lsch if c in rsch and c not in keys]
        rename = {}
        if swap:
            rename = {c: f"{c}_r" for c in dups}
            rename.update({f"{c}_r": c for c in dups})
        parts = [w for w, e in (("left", elide_l), ("right", elide_r))
                 if e]
        strategy = ("elide-" + "+".join(parts)) if parts else "shuffle"
        if swap:
            strategy += ",swap"
        step = self._step("join", strategy, a2a,
                          f"keys={','.join(keys)} how={how}")

        def run(tables, _label=f"{step.index}.join"):
            lt, lov = lrun(tables)
            rt, rov = rrun(tables)
            lt = _restamp(lt, (keys, n) if elide_l else _to_stamp(llay, n))
            rt = _restamp(rt, (keys, n) if elide_r else _to_stamp(rlay, n))
            # keep the output capacity of the ORIGINAL orientation so a
            # swapped join is shape-identical to the eager call
            cap = out_capacity if out_capacity is not None else \
                max(lt.capacity, 1) * mm + (
                    max(rt.capacity, 1) if how in ("right", "outer")
                    else 0)
            if swap:
                out, ov = table_ops.join(
                    rt, lt, keys, ctx=self.ctx, how=_FLIP[how],
                    max_matches=mm, method=method, out_capacity=cap, **kw)
                out = DistTable(
                    {rename.get(c, c): v for c, v in out.columns.items()},
                    out.counts, out.partitioning)
            else:
                out, ov = table_ops.join(
                    lt, rt, keys, ctx=self.ctx, how=how, max_matches=mm,
                    method=method, out_capacity=cap, **kw)
            return out, lov + rov + [(_label, ov)]

        return run, Layout("hash", tuple(keys))

    def _lower_groupby(self, node: LogicalNode):
        crun, clay = self._lower(node.inputs[0])
        p = node.payload
        keys, aggs, kw = p["keys"], p["aggs"], dict(p["kw"])
        n = self.ctx.n_shards
        if _coloc(clay, keys):
            strategy, a2a = "elide(co-located)", 0
            # grouping keeps rows on their shard: placement survives,
            # local order does not
            out_layout = dataclasses.replace(clay, ordered=False) \
                if clay.kind == "range" else clay
            pre, stamp_in = None, (tuple(keys), n)
        elif p["layout"] == "range":
            strategy, a2a = "range-exchange", 1
            asc = tuple(p["layout_ascending"])
            out_layout = Layout("range", tuple(keys), asc, False)

            def pre(t, _asc=asc):
                return table_ops.orderby(t, keys, ctx=self.ctx,
                                         ascending=_asc)
            stamp_in = (tuple(keys), n)  # range-placed ⇒ co-located
        else:
            strategy, a2a = "hash-exchange", 1
            out_layout = Layout("hash", tuple(keys))
            pre, stamp_in = None, None
        step = self._step("groupby", strategy, a2a,
                          f"keys={','.join(keys)}")

        def run(tables, _label=f"{step.index}.groupby"):
            t, ovs = crun(tables)
            t = _restamp(t, _to_stamp(clay, n))
            if pre is not None:
                t, ov0 = pre(t)
                ovs = ovs + [(f"{step.index}.groupby.exchange", ov0)]
            if stamp_in is not None:
                t = _restamp(t, stamp_in)
            out, ov = table_ops.groupby_aggregate(t, keys, aggs,
                                                  ctx=self.ctx, **kw)
            return out, ovs + [(_label, ov)]

        return run, out_layout

    def _lower_orderby(self, node: LogicalNode):
        crun, clay = self._lower(node.inputs[0])
        keys = tuple(node.payload["by"])
        asc = tuple(node.payload["ascending"])
        n = self.ctx.n_shards
        target = Layout("range", keys, asc, True)
        part = range_partitioning(keys, asc, n)
        if clay == target:
            strategy, a2a = "elide(sorted)", 0
        elif clay.kind == "range" and clay.keys == keys \
                and clay.ascending == asc:
            strategy, a2a = "local-sort", 0
        else:
            strategy, a2a = "range-exchange", 1
        step = self._step("orderby", strategy, a2a,
                          f"by={','.join(keys)}")

        def run(tables, _label=f"{step.index}.orderby"):
            t, ovs = crun(tables)
            if strategy == "elide(sorted)":
                return _restamp(t, part), ovs
            if strategy == "local-sort":
                out, ov = table_ops.local_sort(
                    _restamp(t, None), keys, ctx=self.ctx, ascending=asc,
                    partitioning=part)
            else:
                out, ov = table_ops.orderby(
                    _restamp(t, _to_stamp(clay, n)), keys, ctx=self.ctx,
                    ascending=asc)
            return out, ovs + [(_label, ov)]

        return run, target

    def _lower_window(self, node: LogicalNode):
        from repro.window import normalize_aggs

        crun, clay = self._lower(node.inputs[0])
        p = node.payload
        pkeys = tuple(p["partition_by"])
        okeys, asc_o = tuple(p["order_by"]), tuple(p["ascending"])
        aggs, rows = p["aggs"], p["rows"]
        keys = pkeys + okeys
        asc = (True,) * len(pkeys) + asc_o
        n = self.ctx.n_shards
        part = range_partitioning(keys, asc, n)
        norm = normalize_aggs(aggs, node.inputs[0].schema, rows)
        has_lead = any(op == "lead" for _, _, op, _ in norm)
        target = Layout("range", keys, asc, True)
        if clay == target:
            strategy, a2a = "elide(sorted)", 0
            out_layout = target
        elif _coloc(clay, pkeys) and not has_lead:
            strategy, a2a = "local-sort(co-located)", 0
            if clay.kind == "range" and clay.keys == pkeys \
                    and clay.ascending == (True,) * len(pkeys):
                # shards hold ascending contiguous pkey ranges AND rows
                # are now locally (pkeys, okeys)-sorted → globally sorted
                out_layout = target
            elif clay.kind == "range":
                out_layout = dataclasses.replace(clay, ordered=False)
            else:
                out_layout = clay
        else:
            strategy, a2a = "range-exchange", 1
            out_layout = target
        step = self._step(
            "window", strategy, a2a,
            f"partition={','.join(pkeys)} order={','.join(okeys)}")

        def run(tables, _label=f"{step.index}.window"):
            t, ovs = crun(tables)
            if strategy == "elide(sorted)":
                t = _restamp(t, part)
            elif strategy == "local-sort(co-located)":
                # no partition straddles a shard, so a per-shard sort
                # establishes the full (pkeys, okeys) order; the range
                # stamp below is a RELABEL consumed only by the window's
                # need_sort check (halo/carry chains never link: equal
                # partition keys cannot sit on two shards)
                t, _ = table_ops.local_sort(_restamp(t, None), keys,
                                            ctx=self.ctx, ascending=asc,
                                            partitioning=part)
            else:
                t = _restamp(t, _to_stamp(clay, n))
            out, ov = table_ops.window_aggregate(
                t, pkeys, okeys, aggs, ctx=self.ctx, rows=rows,
                ascending=asc_o)
            return out, ovs + [(_label, ov)]

        return run, out_layout

    def _lower_topk(self, node: LogicalNode):
        crun, clay = self._lower(node.inputs[0])
        p = node.payload
        keys, asc, k = tuple(p["by"]), tuple(p["ascending"]), p["k"]
        n = self.ctx.n_shards
        self._step("topk", "tree-reduce", 0, f"by={','.join(keys)} k={k}")

        def run(tables):
            t, ovs = crun(tables)
            out = table_ops.topk(_restamp(t, _to_stamp(clay, n)), keys, k,
                                 ctx=self.ctx, ascending=asc)
            return out, ovs

        return run, Layout("range", keys, asc, True)

    def _lower_repartition(self, node: LogicalNode):
        p = node.payload
        if p["mode"] == "range":
            # identical semantics to orderby (DataFrame.repartition
            # delegates to sort_values)
            return self._lower_orderby(LogicalNode(
                "orderby", node.inputs,
                {"by": p["keys"], "ascending": p["ascending"]},
                node.schema))
        crun, clay = self._lower(node.inputs[0])
        keys = tuple(p["keys"])
        n = self.ctx.n_shards
        if _hash_exact(clay, keys):
            strategy, a2a = "elide(placed)", 0
        else:
            strategy, a2a = "hash-exchange", 1
        step = self._step("repartition", strategy, a2a,
                          f"keys={','.join(keys)}")

        def run(tables, _label=f"{step.index}.repartition"):
            t, ovs = crun(tables)
            if strategy == "elide(placed)":
                return _restamp(t, (keys, n)), ovs
            out, ov = table_ops.shuffle(_restamp(t, _to_stamp(clay, n)),
                                        keys, ctx=self.ctx)
            return out, ovs + [(_label, ov)]

        return run, Layout("hash", keys)
