"""LazyFrame: the deferred twin of :class:`repro.dataframe.DataFrame`.

``DataFrame.lazy()`` (or :meth:`LazyFrame.read_parquet`) starts an
expression graph; chained operators only build :mod:`plan.logical`
nodes.  ``.collect()`` optimizes the graph (``plan.rules``), lowers it
to one traced program (``plan.physical``) and runs it; ``.explain()``
renders logical → optimized → physical without reading any data.  The
eager DataFrame stays the parity oracle: ``lazy().collect()`` is
bit-exact against the same eager chain, it just moves less data
(DESIGN.md §11).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

from repro.core.report import OverflowError, OverflowReport

from . import logical as L
from .explain import plan_annotations, render_explain
from .physical import PhysicalPlan
from .rules import optimize


class PlanAuditError(RuntimeError):
    """The three collective layers disagree: the planner's predicted
    AllToAll count, the traced jaxpr count, and the compiled-HLO count
    must all be equal (the plan contract, DESIGN.md §11/§12).  Raised by
    ``collect(telemetry=..., strict=True)`` when they are not."""


class LazyFrame:
    """A logical plan + context; every operator returns a new LazyFrame."""

    def __init__(self, node: L.LogicalNode, ctx,
                 report: Optional[OverflowReport] = None):
        self._node = node
        self._ctx = ctx
        self._report = report if report is not None else OverflowReport()

    # -- construction ------------------------------------------------------
    @classmethod
    def read_parquet(cls, path: str, ctx, *,
                     columns: Optional[Sequence[str]] = None,
                     predicate=None, capacity: Optional[int] = None,
                     bucket_factor: float = 1.0,
                     allow_narrowing: bool = False,
                     on_error: str = "raise") -> "LazyFrame":
        """Lazy dataset scan (Parquet or ``.hpt``): only metadata is read
        here; pushed-down predicates/projections land in the physical
        scan at ``collect()`` time.  ``on_error="quarantine"`` skips
        corrupt fragments at scan time instead of raising (recorded in
        scan stats + the dataset's quarantine sidecar)."""
        return cls(L.scan(path, columns=columns, predicate=predicate,
                          capacity=capacity, bucket_factor=bucket_factor,
                          allow_narrowing=allow_narrowing,
                          on_error=on_error), ctx)

    read_dataset = read_parquet  # format-neutral alias

    # -- metadata ----------------------------------------------------------
    @property
    def columns(self) -> Tuple[str, ...]:
        return self._node.schema

    @property
    def logical_plan(self) -> L.LogicalNode:
        return self._node

    def _chain(self, node: L.LogicalNode, *others: "LazyFrame"
               ) -> "LazyFrame":
        rep = OverflowReport().merge(self._report)
        for o in others:
            rep.merge(o._report)
        return LazyFrame(node, self._ctx, rep)

    # -- operators (all deferred) ------------------------------------------
    def filter(self, predicate) -> "LazyFrame":
        """Row filter: ``pred()`` tuples / ``(col, op, value)`` triples
        (visible to the rewriter: pushed through joins and into scans) or
        a callable ``cols -> mask`` (opaque, never pushed)."""
        return self._chain(L.filter_(self._node, predicate))

    select = filter  # eager-API name (callable predicate form)

    def project(self, columns) -> "LazyFrame":
        return self._chain(L.project(self._node, columns))

    def join(self, other: "LazyFrame", on, how: str = "inner", *,
             method: str = "auto", max_matches: int = 1,
             reorder: bool = False, **kw) -> "LazyFrame":
        """Deferred equi-join (same semantics as the eager ``join``).

        ``reorder=True`` lets the optimizer swap the inputs so the
        smaller estimated side becomes the hash build side (rule
        ``reorder-join-inputs``).  Off by default: ``table_ops.join``
        caps fan-out per LEFT row, so swapping changes which side
        ``max_matches`` caps and overflow accounting could diverge from
        the eager oracle — opt in only when the cap cannot bind (e.g.
        ``max_matches`` exceeds any true key fan-out on either side).
        """
        if not isinstance(other, LazyFrame):
            raise TypeError(f"join expects a LazyFrame (got "
                            f"{type(other).__name__}); call .lazy() first")
        return self._chain(
            L.join(self._node, other._node, on, how=how,
                   max_matches=max_matches, method=method,
                   reorder=reorder, **kw), other)

    def groupby(self, keys, aggs, **kw) -> "LazyFrame":
        return self._chain(L.groupby(self._node, keys, aggs, **kw))

    def repartition(self, keys, mode: str = "hash",
                    ascending=True) -> "LazyFrame":
        return self._chain(L.repartition(self._node, keys, mode=mode,
                                         ascending=ascending))

    def sort_values(self, by, ascending=True) -> "LazyFrame":
        return self._chain(L.orderby(self._node, by, ascending=ascending))

    def window(self, partition_by, order_by, ascending=True) -> "LazyWindow":
        return LazyWindow(self, partition_by, order_by, ascending)

    def rank(self, partition_by, order_by, ascending=True) -> "LazyFrame":
        return self._chain(L.window(
            self._node, partition_by, order_by,
            [(None, "rank"), (None, "row_number")], ascending=ascending))

    def topk(self, by, k: int, largest: bool = True,
             ascending=None) -> "LazyFrame":
        if ascending is None:
            ascending = not largest
        return self._chain(L.topk(self._node, by, k, ascending=ascending))

    # -- execution ---------------------------------------------------------
    def physical_plan(self) -> PhysicalPlan:
        """Optimize + lower without running (no data I/O): the traced
        ``plan.fn`` / ``plan.inputs()`` pair the contract tests jaxpr."""
        root, _ = optimize(self._node)
        return PhysicalPlan(root, self._ctx)

    def collect(self, *, strict: bool = True, jit: bool = True,
                telemetry=None, policy=None, qerror_threshold=None,
                ledger=None):
        """Optimize, lower, run; returns an eager :class:`DataFrame`.

        One program executes the whole pipeline (``jit=True`` compiles
        it; ``jit=False`` runs the same trace op-by-op).  Overflow from
        any step lands in the result's ``overflow_report`` under
        ``plan.<step>`` labels and raises unless ``strict=False`` — the
        same §2 contract as the eager operators.

        ``telemetry`` accepts a :class:`repro.telemetry.Collector`: the
        run then records spans (per physical node when ``jit=False`` —
        inside one jitted program the host clock cannot attribute time
        to nodes), publishes the plan-vs-observed collective audit
        (predicted == traced jaxpr == compiled HLO; a mismatch raises
        :class:`PlanAuditError` under ``strict=True``), and files the
        predicted facts of every step (strategy, ``est_rows``,
        ``est_bytes``) next to its measured ones.  Per-step q-errors
        (DESIGN.md §14.1) are always recorded when observations exist;
        ``qerror_threshold`` (a float) additionally ENFORCES them under
        ``strict=True``: any step whose estimate misses observed rows by
        more than the threshold raises :class:`~repro.telemetry.
        cardinality.CardinalityAuditError`.

        ``ledger`` names a JSONL file: the run appends one record keyed
        by its plan fingerprint (wall time, metrics, q-errors, memory
        watermark — DESIGN.md §14.3) for ``scripts/perf_report.py`` to
        chart cross-run deltas.

        ``policy`` accepts a :class:`repro.resilience.FaultPolicy` and
        switches on fault-tolerant execution (DESIGN.md §13): scan reads
        and the whole-plan run retry with backoff, and — when the policy
        carries a ``checkpoint_dir`` — every exchange-boundary stage
        commits a CRC-checked snapshot keyed by the plan's fingerprint,
        so a crashed/killed collect resumes from the last committed
        stage and re-runs only the suffix, bit-exact.  The resilient
        path runs op-by-op (stage commits need concrete arrays), so
        ``jit`` is ignored; without a policy this path adds nothing —
        no stage I/O, no extra tracing.
        """
        import time

        import jax

        from repro.dataframe.frame import DataFrame

        root, _ = optimize(self._node)
        plan = PhysicalPlan(root, self._ctx)
        fingerprint = None
        if policy is not None or ledger is not None:
            from repro.resilience import stages as S

            fingerprint = S.plan_fingerprint(root, self._ctx)
        t0 = time.perf_counter()
        if policy is not None:
            out, ovs = self._collect_resilient(plan, policy, telemetry,
                                               fingerprint)
        elif telemetry is not None:
            out, ovs = self._collect_audited(plan, telemetry, jit=jit,
                                             strict=strict)
        else:
            inputs = plan.inputs()
            fn = jax.jit(plan.fn) if jit else plan.fn
            out, ovs = fn(*inputs)
        wall_s = time.perf_counter() - t0
        report = OverflowReport().merge(self._report)
        report.add("plan.scan.capacity", plan.scan_overflow)
        for label, v in sorted(ovs.items()):
            report.add(f"plan.{label}", int(v))
        if telemetry is not None:
            from repro.telemetry import cardinality as C

            telemetry.record_overflow(report)
            C.record_qerrors(telemetry)
        if ledger is not None:
            from repro.telemetry import ledger as Led

            Led.append(ledger, Led.collect_record(
                telemetry, fingerprint=fingerprint, wall_s=wall_s))
        if strict and not report.is_exact():
            detail = ", ".join(f"{k}={v}" for k, v in report)
            raise OverflowError(
                f"planned pipeline overflowed static capacity ({detail}) "
                f"— re-run with larger capacities, or collect(strict=False)")
        if telemetry is not None and strict and qerror_threshold is not None:
            C.audit_cardinality(telemetry, qerror_threshold)
        return DataFrame(out, self._ctx, report)

    def refine(self, rec) -> "LazyFrame":
        """Re-optimize join order from OBSERVED cardinalities (opt-in).

        ``rec`` is the collector of a prior ``collect(telemetry=rec,
        jit=False)`` of THIS pipeline: physical steps are appended in
        the same post-order the optimized logical tree walks, so step
        ``i``'s observed ``rows_out`` belongs to post-order node ``i``.
        Every inner join that opted into reordering (``reorder=True``)
        has its swap decision re-taken from the observed input rows —
        under the same rename-safety guard as the estimate-based rule —
        and PINNED (``reorder=False``), so the estimate rule cannot undo
        the observed decision on the next ``collect()``.  Joins without
        observations (jitted collect, different pipeline) are left
        untouched.  Parity holds by the same argument as the rewrite
        rule: a swap only changes which side hashes first.
        """
        root, _ = optimize(self._node)
        obs = {}
        for i, node in enumerate(L.walk(root)):
            rows = rec.plan_steps.get(i, {}).get("rows_out")
            if rows is not None:
                obs[id(node)] = int(rows)

        def rebuild(node):
            kids = tuple(rebuild(i) for i in node.inputs)
            out = node if kids == node.inputs else node.with_inputs(*kids)
            if node.kind != "join" or node.payload["how"] != "inner" \
                    or not node.payload["reorder"]:
                return out
            lo = obs.get(id(node.inputs[0]))
            ro = obs.get(id(node.inputs[1]))
            if lo is None or ro is None:
                return out
            swap = lo < ro
            if swap:
                keys = node.payload["keys"]
                left, right = node.inputs
                dups = [c for c in left.schema
                        if c in right.schema and c not in keys]
                names = set(left.schema) | set(right.schema)
                if any(f"{c}_r" in names for c in dups):
                    return out  # rename would collide: keep as-is
            return out.with_payload(swap=swap, reorder=False)

        return LazyFrame(rebuild(root), self._ctx,
                         OverflowReport().merge(self._report))

    def _collect_resilient(self, plan: PhysicalPlan, policy, rec,
                           fingerprint: str):
        """Run ``plan`` under ``policy``: scan retries, stage
        checkpoints at exchange boundaries, whole-plan retry, and
        resume-from-last-committed-stage on restart (DESIGN.md §13.2).

        Runs op-by-op (un-jitted): commits need concrete arrays, and a
        restored stage replaces its whole subtree — the re-executed
        program is exactly the plan suffix after the last commit.
        """
        import contextlib
        import shutil
        import tempfile

        from repro import telemetry as T
        from repro.resilience import stages as S

        for kind, obj in plan._input_specs:
            if kind == "scan":  # route transient-read retries to scans
                obj.policy = policy

        tmp_root = None
        ckpt_root = policy.checkpoint_dir
        if ckpt_root is None:
            # stages still give in-process retry memoization; without a
            # durable dir they simply cannot survive a process death
            tmp_root = tempfile.mkdtemp(prefix="hptmt-stages-")
            ckpt_root = tmp_root
        ckpt = S.StageCheckpointer(ckpt_root, fingerprint)
        committed = set(ckpt.committed_stages())
        resumed_from = max(committed) if committed else None
        plan.stage_hook = S.stage_hook(ckpt, policy=policy, ctx=self._ctx,
                                       committed=committed, record=rec)
        active = T.using(rec) if rec is not None else \
            contextlib.nullcontext()
        try:
            with active:
                if rec is not None:
                    for s in plan.steps:
                        rec.observe_step(s.index, op=s.op,
                                         strategy=s.strategy,
                                         predicted_a2a=s.a2a,
                                         est_rows=s.est_rows,
                                         est_bytes=s.est_bytes)
                    if resumed_from is not None:
                        rec.metrics.gauge("recovery.resumed_from_stage",
                                          resumed_from)
                with T.span("recovery.collect", fingerprint=fingerprint,
                            resumed_from=(-1 if resumed_from is None
                                          else resumed_from),
                            stages=sum(s.stage for s in plan.steps)) as sp:
                    out, ovs = policy.run(
                        lambda: plan.fn(*plan.inputs()),
                        site="plan.collect")
                    sp.block(out)
        finally:
            plan.stage_hook = None
        if not policy.keep_checkpoints:
            ckpt.remove()
        if tmp_root is not None:
            shutil.rmtree(tmp_root, ignore_errors=True)
        return out, ovs

    def _collect_audited(self, plan: PhysicalPlan, rec, *, jit: bool,
                         strict: bool):
        """Run ``plan`` under collector ``rec``: root span + per-step
        predicted facts + the three-layer collective audit."""
        import jax

        from repro import telemetry as T

        for s in plan.steps:
            rec.observe_step(s.index, op=s.op, strategy=s.strategy,
                             predicted_a2a=s.a2a, est_rows=s.est_rows,
                             est_bytes=s.est_bytes)
        with T.using(rec):
            with rec.span("plan.collect", steps=len(plan.steps), jit=jit,
                          predicted_a2a=plan.predicted_collectives) as sp:
                inputs = plan.inputs()
                fn = jax.jit(plan.fn) if jit else plan.fn
                out, ovs = fn(*inputs)
                sp.block(out)
        audit = T.program_audit(plan.fn, *inputs,
                                n_shards=self._ctx.n_shards,
                                predicted_a2a=plan.predicted_collectives)
        rec.record_audit(audit)
        rec.metrics.gauge("plan.predicted_a2a", audit["predicted_a2a"])
        rec.metrics.gauge("plan.traced_a2a", audit["traced_a2a"])
        rec.metrics.gauge("plan.observed_a2a", audit["observed_a2a"])
        rec.metrics.gauge("plan.observed_bytes",
                          audit["observed_total_bytes"])
        # map the k-th traced exchange to the k-th exchanging step (steps
        # are appended children-first, i.e. in execution order) — skipped
        # if the counts disagree, never guessed
        payloads = [e["bytes"] for e in audit["exchanges"]]
        if len(payloads) == sum(s.a2a for s in plan.steps):
            it = iter(payloads)
            for s in plan.steps:
                if s.a2a:
                    rec.observe_step(s.index, a2a_bytes=sum(
                        next(it) for _ in range(s.a2a)))
        if strict and not audit["consistent"]:
            raise PlanAuditError(
                f"collective audit mismatch: planner predicted "
                f"{audit['predicted_a2a']} all_to_all, jaxpr traced "
                f"{audit['traced_a2a']}, compiled HLO observed "
                f"{audit['observed_a2a']} — the plan contract is broken")
        return out, ovs

    def explain(self, *, optimized: bool = True,
                analyze: bool = False) -> str:
        """Stable text rendering: logical plan → fired rewrite rules →
        optimized plan → physical steps with predicted collective counts.
        Builds the physical plan but reads no data.

        ``analyze=True`` EXECUTES the pipeline op-by-op under a private
        collector and annotates every physical step with its measured
        self-time, output rows, and exchange payload bytes, plus the
        predicted/traced/observed audit line (the runtime form of
        EXPLAIN ANALYZE).
        """
        if analyze and not optimized:
            raise ValueError("explain(analyze=True) runs the optimized "
                             "plan; optimized=False is not analyzable")
        root, fired = optimize(self._node)
        plan = PhysicalPlan(root if optimized else self._node, self._ctx)
        if not analyze:
            return render_explain(self._node, root, fired, plan)
        from repro import telemetry as T

        rec = T.Collector("explain-analyze")
        self.collect(telemetry=rec, jit=False, strict=False)
        audit = rec.audits[-1] if rec.audits else None
        return render_explain(self._node, root, fired, plan,
                              annotations=plan_annotations(rec),
                              audit=audit)


class LazyWindow:
    """Deferred ``(partition_by, order_by)`` spec; ``.agg()`` defers too."""

    def __init__(self, lf: LazyFrame, partition_by, order_by, ascending):
        self._lf = lf
        self._partition_by = partition_by
        self._order_by = order_by
        self._ascending = ascending

    def agg(self, aggs, rows: Optional[int] = None) -> LazyFrame:
        return self._lf._chain(L.window(
            self._lf._node, self._partition_by, self._order_by, aggs,
            rows=rows, ascending=self._ascending))
