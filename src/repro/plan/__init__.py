"""Lazy query planner: whole-pipeline exchange optimization (DESIGN.md §11).

``DataFrame.lazy()`` / ``LazyFrame.read_parquet`` build a logical
expression graph (``plan.logical``); a rule-based rewriter
(``plan.rules``) pushes predicates/projections into the scan, reorders
join inputs from manifest cardinality estimates and picks hash-vs-range
layouts globally; the physical planner (``plan.physical``) lowers the
whole pipeline into ONE traced program over the eager ``table_ops``
engines, eliding exchanges across operator chains via true-layout
tracking.  ``.explain()`` renders all three stages with predicted
collective counts; the eager DataFrame remains the bit-exact parity
oracle and the plan-contract tests jaxpr-assert planned pipelines never
emit more AllToAll collectives than their eager equivalents.
"""
from . import logical
from .explain import render_explain
from .frame import LazyFrame, LazyWindow
from .physical import Layout, PhysicalPlan, PlanStep
from .rules import RULES, estimated_rows, optimize

__all__ = ["LazyFrame", "LazyWindow", "Layout", "PhysicalPlan",
           "PlanStep", "RULES", "estimated_rows", "logical", "optimize",
           "render_explain"]
