"""Logical plan: a lazy expression graph over the eager operator set.

Each :class:`LogicalNode` is one operator application (DESIGN.md §11);
the graph is an immutable tree built bottom-up by the constructor
functions here.  Builders validate eagerly — unknown columns, bad agg
specs and malformed key lists fail at graph-construction time with the
same error style as the eager operators, long before anything traces —
and compute the node's output ``schema`` (the sorted column-name tuple
that ``DistTable.column_names`` would report), so the rewriter
(``plan.rules``) and the physical planner (``plan.physical``) reason
about column sets without touching data.

Node kinds and payloads:

  source       table (DistTable), name
  scan         dataset (Dataset), columns, predicate, capacity,
               bucket_factor, allow_narrowing
  filter       predicate — a tuple of ColumnPredicate (AND), or a
               callable ``cols -> bool mask`` (opaque to the rewriter)
  project      columns
  join         keys, how, method, max_matches, swap, reorder, kw
  groupby      keys, aggs, layout ("hash" | "range"), layout_ascending, kw
  orderby      by, ascending
  window       partition_by, order_by, ascending, aggs, rows
  topk         by, k, ascending
  repartition  keys, mode ("hash" | "range"), ascending
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple, Union

from repro.core.table import DistTable
from repro.core.table_ops import _JOIN_HOWS, _SEGMENT_OPS, _normalize_order
from repro.io.scan import ColumnPredicate, _normalize_predicate


@dataclasses.dataclass(frozen=True, eq=False)
class LogicalNode:
    """One operator application; identity equality (nodes are unique)."""
    kind: str
    inputs: Tuple["LogicalNode", ...]
    payload: Dict
    schema: Tuple[str, ...]  # sorted output column names

    def with_payload(self, **updates) -> "LogicalNode":
        """Copy with payload entries replaced (schema unchanged)."""
        return LogicalNode(self.kind, self.inputs, {**self.payload,
                                                    **updates}, self.schema)

    def with_inputs(self, *inputs) -> "LogicalNode":
        return LogicalNode(self.kind, tuple(inputs), self.payload,
                           self.schema)


Predicate = Union[Tuple[ColumnPredicate, ...], Callable]


def _check_columns(cols, schema, what: str) -> None:
    missing = [c for c in cols if c not in schema]
    if missing:
        raise ValueError(f"{what} names unknown column(s) {missing}; "
                         f"input has {list(schema)}")


# -- leaves -----------------------------------------------------------------
def source(table: DistTable, name: str = "table") -> LogicalNode:
    return LogicalNode("source", (), {"table": table, "name": name},
                       tuple(sorted(table.column_names)))


def scan(dataset, *, columns=None, predicate=None, capacity=None,
         bucket_factor: float = 1.0, allow_narrowing: bool = False,
         on_error: str = "raise") -> LogicalNode:
    """Lazy dataset scan; column/predicate pushdown lands here.

    ``on_error="quarantine"`` opts the physical scan into skipping
    corrupt fragments (recorded in stats + sidecar) instead of raising.
    """
    from repro.io.dataset import open_dataset

    if isinstance(dataset, str):
        dataset = open_dataset(dataset)
    if on_error not in ("raise", "quarantine"):
        raise ValueError(f"scan on_error={on_error!r}; expected 'raise' "
                         f"or 'quarantine'")
    names = dataset.schema.names
    out = tuple(columns) if columns is not None else tuple(names)
    _check_columns(out, names, "scan columns=")
    preds = _normalize_predicate(predicate)
    _check_columns([p.column for p in preds], names, "scan predicate=")
    return LogicalNode("scan", (), {
        "dataset": dataset, "columns": out, "predicate": preds,
        "capacity": capacity, "bucket_factor": bucket_factor,
        "allow_narrowing": allow_narrowing, "on_error": on_error},
        tuple(sorted(out)))


# -- row / column ops -------------------------------------------------------
def filter_(child: LogicalNode, predicate) -> LogicalNode:
    if callable(predicate):
        preds: Predicate = predicate
    else:
        preds = _normalize_predicate(predicate)
        if not preds:
            raise ValueError("filter needs a predicate")
        _check_columns([p.column for p in preds], child.schema,
                       "filter predicate=")
    return LogicalNode("filter", (child,), {"predicate": preds},
                       child.schema)


def project(child: LogicalNode, columns) -> LogicalNode:
    cols = (columns,) if isinstance(columns, str) else tuple(columns)
    if not cols:
        raise ValueError("project needs at least one column")
    _check_columns(cols, child.schema, "project columns=")
    return LogicalNode("project", (child,), {"columns": cols},
                       tuple(sorted(dict.fromkeys(cols))))


# -- relational ops ---------------------------------------------------------
def join_schema(left_schema, right_schema, keys) -> Tuple[str, ...]:
    """Output columns of ``table_ops.join``: keys + left non-keys +
    right non-keys (``_r``-suffixed on name clash) + ``_matched``."""
    out = list(keys)
    out += [c for c in left_schema if c not in keys]
    for c in right_schema:
        if c in keys:
            continue
        out.append(f"{c}_r" if c in left_schema else c)
    out.append("_matched")
    return tuple(sorted(dict.fromkeys(out)))


def join(left: LogicalNode, right: LogicalNode, keys, *,
         how: str = "inner", max_matches: int = 1, method: str = "auto",
         reorder: bool = False, **kw) -> LogicalNode:
    """``reorder=True`` opts this join into the ``reorder-join-inputs``
    rewrite (the caller promises ``max_matches`` cannot bind — see
    ``plan.rules``); ``swap`` is the rewriter's decision output."""
    keys = tuple(keys)
    if how not in _JOIN_HOWS:
        raise ValueError(f"unknown join type how={how!r}; "
                         f"expected one of {_JOIN_HOWS}")
    _check_columns(keys, left.schema, "join keys= (left)")
    _check_columns(keys, right.schema, "join keys= (right)")
    return LogicalNode(
        "join", (left, right),
        {"keys": keys, "how": how, "max_matches": max_matches,
         "method": method, "swap": False, "reorder": bool(reorder),
         "kw": dict(kw)},
        join_schema(left.schema, right.schema, keys))


def groupby(child: LogicalNode, keys, aggs, **kw) -> LogicalNode:
    keys = tuple(keys)
    aggs = tuple((c, op) for c, op in aggs)
    _check_columns(keys, child.schema, "groupby keys=")
    for c, op in aggs:
        if op not in _SEGMENT_OPS:
            raise ValueError(f"unknown aggregate {op!r}")
        if c not in child.schema:
            raise ValueError(f"aggregate column {c!r} not in input "
                             f"{list(child.schema)}")
    labels = [f"{c}_{op}" for c, op in aggs]
    return LogicalNode(
        "groupby", (child,),
        {"keys": keys, "aggs": aggs, "layout": "hash",
         "layout_ascending": None, "kw": dict(kw)},
        tuple(sorted(dict.fromkeys(list(keys) + labels))))


def orderby(child: LogicalNode, by, ascending=True) -> LogicalNode:
    keys, asc = _normalize_order(by, ascending, child.schema, "by")
    return LogicalNode("orderby", (child,),
                       {"by": keys, "ascending": asc}, child.schema)


def window(child: LogicalNode, partition_by, order_by, aggs, *,
           rows: Optional[int] = None, ascending=True) -> LogicalNode:
    from repro.window import normalize_aggs

    pkeys = (partition_by,) if isinstance(partition_by, str) \
        else tuple(partition_by)
    _check_columns(pkeys, child.schema, "window partition_by=")
    okeys, asc_o = _normalize_order(order_by, ascending, child.schema,
                                    "order_by")
    norm = normalize_aggs(aggs, child.schema, rows)
    labels = [lbl for lbl, _, _, _ in norm]
    return LogicalNode(
        "window", (child,),
        {"partition_by": pkeys, "order_by": okeys, "ascending": asc_o,
         "aggs": tuple(tuple(a) for a in aggs), "rows": rows},
        tuple(sorted(list(child.schema) + labels)))


def topk(child: LogicalNode, by, k: int, ascending=True) -> LogicalNode:
    keys, asc = _normalize_order(by, ascending, child.schema, "by")
    if not isinstance(k, int) or k < 1:
        raise ValueError(f"topk k={k!r} must be a positive int")
    return LogicalNode("topk", (child,),
                       {"by": keys, "k": k, "ascending": asc}, child.schema)


def repartition(child: LogicalNode, keys, *, mode: str = "hash",
                ascending=True) -> LogicalNode:
    if mode not in ("hash", "range"):
        raise ValueError(f"repartition mode={mode!r}; "
                         f"expected 'hash' or 'range'")
    keys, asc = _normalize_order(keys, ascending, child.schema, "keys")
    return LogicalNode("repartition", (child,),
                       {"keys": keys, "mode": mode, "ascending": asc},
                       child.schema)


def walk(node: LogicalNode):
    """Post-order traversal (inputs before node)."""
    for inp in node.inputs:
        yield from walk(inp)
    yield node
