"""Stable text rendering of logical / optimized / physical plans.

The output is deterministic for a given (plan, context): node payloads
render through explicit per-kind formatters (never ``repr`` of objects
with memory addresses — callables render as ``<fn>``, datasets by their
fragment/column counts), so tests can assert exact substrings and two
renders of the same plan compare equal (DESIGN.md §11).
"""
from __future__ import annotations

from typing import Dict, List, Optional

from .logical import LogicalNode


def _fmt_preds(preds) -> str:
    if callable(preds):
        return "<fn>"
    return " AND ".join(f"{p.column}{p.op}{p.value!r}" for p in preds)


def _fmt_asc(keys, asc) -> str:
    return ",".join(k if a else f"{k}:desc" for k, a in zip(keys, asc))


def _describe(node: LogicalNode) -> str:
    p = node.payload
    k = node.kind
    if k == "source":
        return f"source[{p['name']}: {','.join(node.schema)}]"
    if k == "scan":
        ds = p["dataset"]
        s = f"scan[{len(ds.fragments)} fragments, cols={','.join(p['columns'])}"
        if p["predicate"]:
            s += f", predicate={_fmt_preds(p['predicate'])}"
        return s + "]"
    if k == "filter":
        return f"filter[{_fmt_preds(p['predicate'])}]"
    if k == "project":
        return f"project[{','.join(p['columns'])}]"
    if k == "join":
        s = f"join[{p['how']} on={','.join(p['keys'])}"
        if p["swap"]:
            s += ", swapped"
        return s + "]"
    if k == "groupby":
        aggs = ",".join(f"{c}_{op}" for c, op in p["aggs"])
        s = f"groupby[keys={','.join(p['keys'])} aggs={aggs}"
        if p["layout"] != "hash":
            s += f", layout={p['layout']}"
        return s + "]"
    if k == "orderby":
        return f"orderby[{_fmt_asc(p['by'], p['ascending'])}]"
    if k == "window":
        aggs = ",".join(f"{c}:{op}" if c else op
                        for c, op, *_ in p["aggs"])
        rows = p["rows"] if p["rows"] is not None else "cumulative"
        return (f"window[partition={','.join(p['partition_by'])} "
                f"order={_fmt_asc(p['order_by'], p['ascending'])} "
                f"aggs={aggs} rows={rows}]")
    if k == "topk":
        return f"topk[{_fmt_asc(p['by'], p['ascending'])} k={p['k']}]"
    if k == "repartition":
        return f"repartition[{p['mode']} keys={','.join(p['keys'])}]"
    return k  # pragma: no cover — exhaustive over node kinds


def render_tree(root: LogicalNode) -> str:
    lines: List[str] = []

    def walk(node: LogicalNode, depth: int) -> None:
        lines.append("  " * depth + _describe(node))
        for inp in node.inputs:
            walk(inp, depth + 1)

    walk(root, 0)
    return "\n".join(lines)


def plan_annotations(rec) -> Dict[int, Dict]:
    """Join a collector's measured facts back onto physical step indices.

    ``Collector.plan_steps`` carries what the instrumented plan observed
    (inclusive ``time_us``, ``rows_out``, ``a2a_bytes``); the span tree
    additionally yields each node's SELF time — its inclusive duration
    minus its direct ``plan.*`` children, so a parent is not charged for
    work its inputs did.
    """
    ann: Dict[int, Dict] = {i: dict(f) for i, f in rec.plan_steps.items()}
    for sp in rec.all_spans():
        parts = sp.name.split(".")
        if len(parts) < 3 or parts[0] != "plan":
            continue
        try:
            idx = int(parts[1])
        except ValueError:
            continue
        child_us = sum(c.dur_us for c in sp.children
                       if c.name.startswith("plan.")
                       and c.name != "plan.collect")
        ann.setdefault(idx, {})["self_us"] = sp.dur_us - child_us
        # SELF peak-rss growth: a monotone watermark charges a child's
        # rise to every enclosing step, so subtract direct plan children
        own = sp.attrs.get("peak_rss_delta_kb")
        if own is not None:
            child_kb = sum(c.attrs.get("peak_rss_delta_kb", 0.0)
                           for c in sp.children
                           if c.name.startswith("plan.")
                           and c.name != "plan.collect")
            ann[idx]["self_rss_kb"] = max(0.0, own - child_kb)
    return ann


def _fmt_est(v) -> str:
    """Deterministic short form of a row estimate (manifests only)."""
    if v is None:
        return "?"
    return f"{round(float(v), 1):g}"


def _fmt_annotation(a: Dict) -> str:
    bits = []
    if "self_us" in a:
        bits.append(f"time={a['self_us'] / 1e3:.3f}ms")
    if a.get("rows_out") is not None:
        bits.append(f"rows={a['rows_out']}")
    if "qerr" in a:
        bits.append(f"qerr={a['qerr']:.2f}")
    if "a2a_bytes" in a:
        bits.append(f"bytes={a['a2a_bytes']}")
    if a.get("self_rss_kb"):
        bits.append(f"rss=+{a['self_rss_kb']:.0f}KB")
    return "  [" + " ".join(bits) + "]" if bits else ""


def _memory_footer(plan, annotations: Dict[int, Dict]) -> Optional[str]:
    """Peak-memory attribution: predicted live bytes vs the observed
    watermark growth, naming the step that grew the peak most."""
    est_total = sum(s.est_bytes or 0 for s in plan.steps)
    deltas = {i: a.get("self_rss_kb", 0.0)
              for i, a in annotations.items()
              if a.get("self_rss_kb") is not None}
    if not deltas and not est_total:
        return None
    total_kb = sum(deltas.values())
    line = (f"  memory: est_live={est_total / 1024:.0f}KB "
            f"peak_rss_delta={total_kb:.0f}KB")
    if deltas and max(deltas.values()) > 0:
        top = max(deltas, key=deltas.get)
        op = next((s.op for s in plan.steps if s.index == top), "?")
        line += f" (top: {top}.{op} +{deltas[top]:.0f}KB)"
    return line


def render_physical(plan, annotations: Optional[Dict[int, Dict]] = None,
                    audit: Optional[Dict] = None) -> str:
    lines = []
    for s in plan.steps:
        det = f"  -- {s.detail}" if s.detail else ""
        line = (f"  {s.index:2d}. {s.op:<12} {s.strategy:<24} "
                f"all_to_all={s.a2a} est_rows={_fmt_est(s.est_rows)}{det}")
        if annotations is not None and s.index in annotations:
            line += _fmt_annotation(annotations[s.index])
        lines.append(line)
    lines.append(f"  predicted collectives: {plan.predicted_collectives} "
                 f"all_to_all on {plan.ctx.n_shards} shards "
                 f"(output layout: {plan.out_layout.describe()})")
    if annotations is not None:
        footer = _memory_footer(plan, annotations)
        if footer is not None:
            lines.append(footer)
    if audit is not None:
        a2a_bytes = audit["observed_bytes_by_kind"].get("all-to-all", 0)
        lines.append(
            f"  audit: predicted={audit.get('predicted_a2a', '?')} "
            f"traced={audit['traced_a2a']} "
            f"observed={audit['observed_a2a']} all_to_all "
            f"({a2a_bytes} bytes in compiled HLO)")
    return "\n".join(lines)


def render_explain(logical_root: LogicalNode, optimized_root: LogicalNode,
                   fired, plan,
                   annotations: Optional[Dict[int, Dict]] = None,
                   audit: Optional[Dict] = None) -> str:
    parts = ["== logical plan ==", render_tree(logical_root),
             "== rewrites =="]
    parts.append("  " + (", ".join(fired) if fired else "(none fired)"))
    parts += ["== optimized plan ==", render_tree(optimized_root),
              "== physical plan ==",
              render_physical(plan, annotations, audit)]
    return "\n".join(parts)
