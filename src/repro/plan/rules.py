"""Rule-based logical-plan rewriter (DESIGN.md §11).

``optimize(root)`` returns ``(new_root, fired)`` where ``fired`` is the
ordered tuple of rule names that changed the tree.  Every rule preserves
the result the eager pipeline would compute (the parity oracle); rules
only move work earlier, drop provably dead work, or change *layout*
decisions the physical planner exploits:

  push-filter-through-project   filter commutes with a projection that
                                keeps its columns
  push-filter-through-join      inner joins only: per-side structured
                                predicates move below the join (a filter
                                below a left/right/outer join would also
                                drop the zero-filled unmatched rows —
                                never pushed)
  push-filter-into-scan         structured predicates land in the scan's
                                predicate pushdown (fragment pruning +
                                residual filter)
  push-projection-into-scan     scans read only columns some consumer
                                needs (predicate columns are added back
                                by ``ScanSource.read_columns``)
  drop-redundant-exchange       a user ``repartition`` whose layout is
                                immediately destroyed by a re-exchanging
                                consumer is dead work (never fired before
                                ``topk``: its tie selection and its
                                ``k <= capacity`` validation are
                                placement-sensitive)
  reorder-join-inputs           inner joins put the smaller estimated
                                side on the right — the hash build side
                                (manifest min/max cardinality estimates).
                                Opt-in per join (``join(..., reorder=
                                True)``): ``table_ops.join`` caps fan-out
                                per LEFT row, so swapping sides changes
                                which side ``max_matches`` caps and
                                overflow accounting could diverge from
                                the eager oracle unless the caller knows
                                the cap cannot bind
  choose-range-layout           groupby feeding an orderby on the same
                                keys exchanges by RANGE once instead of
                                hash + range twice

Structured predicates are tuples of :class:`ColumnPredicate`; callable
filters are opaque — they block predicate pushdown and force scans below
them to keep every column a consumer might touch.
"""
from __future__ import annotations

from typing import List, Optional, Set, Tuple

from repro.io.scan import ColumnPredicate

from . import logical as L
from .logical import LogicalNode

__all__ = ["optimize", "estimated_rows", "RULES"]

RULES = (
    "push-filter-through-project",
    "push-filter-through-join",
    "push-filter-into-scan",
    "push-projection-into-scan",
    "drop-redundant-exchange",
    "reorder-join-inputs",
    "choose-range-layout",
)

# crude per-op selectivity priors for cardinality estimates; exact
# numbers matter less than their ORDER (== is tighter than a range,
# which is tighter than !=)
_SELECTIVITY = {"==": 0.1, "<": 0.4, "<=": 0.4, ">": 0.4, ">=": 0.4,
                "!=": 0.9}


def _structured(pred) -> bool:
    return not callable(pred)


# ===========================================================================
# cardinality estimation (manifest min/max stats)
# ===========================================================================
def _pred_selectivity(p: ColumnPredicate, dataset) -> float:
    """Fraction of rows estimated to survive ``p``, refined by the
    dataset's global min/max when available (uniformity assumption)."""
    if dataset is not None:
        bounds = dataset.stat_bounds(p.column)
        if bounds is not None and p.op in ("<", "<=", ">", ">="):
            lo, hi = bounds
            span = float(hi) - float(lo)
            if span > 0:
                frac = (float(p.value) - float(lo)) / span
                frac = min(1.0, max(0.0, frac))
                return frac if p.op in ("<", "<=") else 1.0 - frac
            # degenerate single-value fragment range
            return 1.0 if ColumnPredicate(p.column, p.op, p.value
                                          ).maybe_satisfied(bounds) else 0.0
    return _SELECTIVITY[p.op]


def _key_width(node: LogicalNode, column: str) -> Optional[float]:
    """Distinct-value bound for ``column`` from the manifest stats of
    scans below ``node``: an integer-valued global ``(min, max)`` range
    admits at most ``max - min + 1`` distinct values.  ``None`` when no
    scan below carries integral bounds for the column (sources have no
    manifests — estimates never read data)."""
    best = None
    for sub in L.walk(node):
        if sub.kind != "scan":
            continue
        bounds = sub.payload["dataset"].stat_bounds(column)
        if bounds is None:
            continue
        lo, hi = float(bounds[0]), float(bounds[1])
        if lo != int(lo) or hi != int(hi) or hi < lo:
            continue
        width = hi - lo + 1.0
        best = width if best is None else min(best, width)
    return best


def _distinct_combos(node: LogicalNode) -> Optional[float]:
    """Upper bound on distinct key-combos a groupby can emit, from the
    per-key manifest ranges (``None`` when any key is unbounded)."""
    combos = 1.0
    for key in node.payload["keys"]:
        width = _key_width(node.inputs[0], key)
        if width is None:
            return None
        combos *= width
    return combos


def estimated_rows(node: LogicalNode, cache: Optional[dict] = None) -> float:
    """Upper-ish row estimate from manifest stats and selectivity priors.

    Orders join inputs (rule ``reorder-join-inputs``) and is stamped on
    every :class:`~repro.plan.physical.PlanStep` as ``est_rows`` for the
    cardinality audit (DESIGN.md §14.1) — deterministic, manifests only,
    no data is ever read.  ``cache`` (id-keyed) amortizes the recursion
    when the physical planner estimates every node of one tree."""
    if cache is not None and id(node) in cache:
        return cache[id(node)]
    est = _estimated_rows(node, cache)
    if cache is not None:
        cache[id(node)] = est
    return est


def _estimated_rows(node: LogicalNode, cache: Optional[dict]) -> float:
    if node.kind == "source":
        return float(int(node.payload["table"].num_rows()))
    if node.kind == "scan":
        from repro.io.scan import ScanSource  # noqa: F401 (doc pointer)
        ds = node.payload["dataset"]
        kept = 0.0
        for frag in ds.fragments:
            if all(p.maybe_satisfied(frag.stats.get(p.column))
                   for p in node.payload["predicate"]):
                kept += frag.rows
        for p in node.payload["predicate"]:
            kept *= _pred_selectivity(p, ds)
        return kept
    if node.kind == "filter":
        est = estimated_rows(node.inputs[0], cache)
        pred = node.payload["predicate"]
        if _structured(pred):
            for p in pred:
                est *= _pred_selectivity(p, None)
            return est
        return est * 0.5
    if node.kind == "join":
        return max(estimated_rows(node.inputs[0], cache),
                   estimated_rows(node.inputs[1], cache))
    if node.kind == "groupby":
        est = estimated_rows(node.inputs[0], cache)
        combos = _distinct_combos(node)
        return est if combos is None else min(est, combos)
    if node.kind == "topk":
        return float(node.payload["k"])
    return estimated_rows(node.inputs[0], cache)


# ===========================================================================
# local rewrite rules (applied bottom-up to fixpoint)
# ===========================================================================
def _push_filter(node: LogicalNode, fired: List[str]) -> LogicalNode:
    """Rewrite one Filter node downward where legal."""
    child = node.inputs[0]
    pred = node.payload["predicate"]
    if not _structured(pred):
        return node

    if child.kind == "project":
        # predicate columns ⊆ projected columns (validated at build), so
        # the filter commutes with the projection
        fired.append("push-filter-through-project")
        return L.project(L.filter_(child.inputs[0], pred),
                         child.payload["columns"])

    if child.kind == "filter" and _structured(child.payload["predicate"]):
        # fuse ANDed structured filters so join/scan pushes see all preds
        return L.filter_(child.inputs[0],
                         child.payload["predicate"] + pred)

    if child.kind == "join" and child.payload["how"] == "inner":
        left, right = child.inputs
        keys = child.payload["keys"]
        to_l, to_r, residual = [], [], []
        for p in pred:
            c = p.column
            # generated (_matched) and _r-suffixed names refer to THIS
            # join's output, not to either input — never pushed; so does
            # any name the join's dup-suffixing would shadow
            generated = (c == "_matched" or (
                c.endswith("_r") and c[:-2] in left.schema
                and c[:-2] in right.schema and c[:-2] not in keys))
            if generated:
                residual.append(p)
            elif c in keys:
                # key values are identical on both sides of a matched
                # inner pair — push into BOTH builds
                to_l.append(p)
                to_r.append(p)
            elif c in left.schema:
                to_l.append(p)
            elif c in right.schema:
                to_r.append(p)
            else:
                residual.append(p)
        if not to_l and not to_r:
            return node
        fired.append("push-filter-through-join")
        if to_l:
            left = L.filter_(left, tuple(to_l))
        if to_r:
            right = L.filter_(right, tuple(to_r))
        new_join = LogicalNode(child.kind, (left, right), child.payload,
                               child.schema)
        return L.filter_(new_join, tuple(residual)) if residual else new_join

    if child.kind == "scan":
        schema = child.payload["dataset"].schema
        push = [p for p in pred if not schema[p.column].trailing]
        if not push:
            return node
        fired.append("push-filter-into-scan")
        new_scan = L.scan(
            child.payload["dataset"], columns=child.payload["columns"],
            predicate=child.payload["predicate"] + tuple(push),
            capacity=child.payload["capacity"],
            bucket_factor=child.payload["bucket_factor"],
            allow_narrowing=child.payload["allow_narrowing"],
            on_error=child.payload["on_error"])
        rest = tuple(p for p in pred if p not in push)
        return L.filter_(new_scan, rest) if rest else new_scan

    return node


def _serves(rep: LogicalNode, consumer: LogicalNode, side: int) -> bool:
    """Could ``rep``'s layout elide any exchange of ``consumer``?"""
    keys = rep.payload["keys"]
    mode = rep.payload["mode"]
    k = consumer.kind
    if k == "join":
        return mode == "hash" and keys == consumer.payload["keys"]
    if k == "groupby":
        return set(keys) == set(consumer.payload["keys"])
    if k == "orderby":
        return (mode == "range" and keys == consumer.payload["by"]
                and rep.payload["ascending"]
                == consumer.payload["ascending"])
    if k == "window":
        pk = consumer.payload["partition_by"]
        full = tuple(pk) + tuple(consumer.payload["order_by"])
        return (set(keys) == set(pk)
                or (mode == "range" and keys == full))
    if k == "repartition":
        return False  # immediately re-exchanged by the consumer
    # anything else (incl. topk: tie selection is per-shard and
    # ``k <= capacity`` validation is per-shard too, so placement — and
    # the rebalanced capacity a repartition brings — is observable):
    # layout flows through, keep it
    return True


def _drop_dead_repartition(node: LogicalNode,
                           fired: List[str]) -> LogicalNode:
    """Drop a repartition child whose layout this node destroys unused."""
    if node.kind not in ("join", "groupby", "orderby", "window",
                         "repartition"):
        return node
    new_inputs, changed = [], False
    for i, inp in enumerate(node.inputs):
        if inp.kind == "repartition" and not _serves(inp, node, i):
            fired.append("drop-redundant-exchange")
            new_inputs.append(inp.inputs[0])
            changed = True
        else:
            new_inputs.append(inp)
    return node.with_inputs(*new_inputs) if changed else node


def _rewrite_up(node: LogicalNode, fired: List[str]) -> LogicalNode:
    """Bottom-up pass; re-applies locally until the node stops changing."""
    node = node.with_inputs(*[_rewrite_up(i, fired) for i in node.inputs])
    for _ in range(16):  # fixpoint bound (a push can expose another)
        new = node
        if new.kind == "filter":
            new = _push_filter(new, fired)
        new = _drop_dead_repartition(new, fired)
        if new is node:
            return node
        node = new.with_inputs(*[_rewrite_up(i, fired)
                                 for i in new.inputs])
    return node


# ===========================================================================
# whole-tree passes
# ===========================================================================
def _push_projection(node: LogicalNode, req: Set[str],
                     fired: List[str]) -> LogicalNode:
    """Top-down required-column analysis; narrows scan reads."""
    if node.kind == "scan":
        cur = node.payload["columns"]
        keep = tuple(c for c in cur if c in req)
        if keep and set(keep) != set(cur):
            fired.append("push-projection-into-scan")
            return L.scan(node.payload["dataset"], columns=keep,
                          predicate=node.payload["predicate"],
                          capacity=node.payload["capacity"],
                          bucket_factor=node.payload["bucket_factor"],
                          allow_narrowing=node.payload["allow_narrowing"],
                          on_error=node.payload["on_error"])
        return node
    if node.kind == "source":
        return node

    k, p = node.kind, node.payload
    if k == "filter":
        pred = p["predicate"]
        if _structured(pred):
            child_req = req | {q.column for q in pred}
        else:  # opaque callable: every input column may be touched
            child_req = set(node.inputs[0].schema)
        reqs = [child_req]
    elif k == "project":
        reqs = [set(p["columns"])]
    elif k == "join":
        keys = set(p["keys"])
        lsch, rsch = node.inputs[0].schema, node.inputs[1].schema
        lreq, rreq = set(keys), set(keys)
        for c in req:
            if c == "_matched":
                continue
            if c in lsch and c not in keys:
                lreq.add(c)
            # join-generated dup suffix requires right's base column —
            # but join_schema never suffixes KEYS, so "k_r" with k a
            # join key can only be a literal input column (same guard
            # as _push_filter's `generated` test): fall through to the
            # plain rsch handling so the literal column stays required
            if c.endswith("_r") and c[:-2] in rsch and c[:-2] in lsch \
                    and c[:-2] not in keys:
                rreq.add(c[:-2])
            elif c in rsch and c not in lsch and c not in keys:
                rreq.add(c)
        reqs = [lreq, rreq]
    elif k == "groupby":
        reqs = [set(p["keys"]) | {c for c, _ in p["aggs"]}]
    elif k == "orderby" or k == "topk":
        reqs = [req | set(p["by"])]
    elif k == "window":
        child = set(c for c in req if c in node.inputs[0].schema)
        from repro.window import normalize_aggs
        norm = normalize_aggs(p["aggs"], node.inputs[0].schema, p["rows"])
        reqs = [child | set(p["partition_by"]) | set(p["order_by"])
                | {c for _, c, _, _ in norm if c is not None}]
    elif k == "repartition":
        reqs = [req | set(p["keys"])]
    else:  # pragma: no cover — exhaustive over node kinds
        reqs = [set(i.schema) for i in node.inputs]
    return node.with_inputs(*[_push_projection(i, r, fired)
                              for i, r in zip(node.inputs, reqs)])


def _reorder_joins(node: LogicalNode, fired: List[str]) -> LogicalNode:
    node = node.with_inputs(*[_reorder_joins(i, fired)
                              for i in node.inputs])
    # opt-in only: the local kernels cap fan-out per PROBE (left) row,
    # so a swap silently moves the max_matches cap to the other side —
    # a 1:N join whose fan-out exceeds the cap on the swapped-to-left
    # side would overflow where the eager oracle is exact (or vice
    # versa).  ``reorder=True`` is the caller's promise the cap cannot
    # bind either way.
    if node.kind != "join" or node.payload["how"] != "inner" \
            or node.payload["swap"] or not node.payload["reorder"]:
        return node
    left, right = node.inputs
    if not (estimated_rows(left) < estimated_rows(right)):
        return node
    # renaming safety: after the swap every duplicate non-key column c
    # swaps names with c_r — refuse when a literal "c_r" column already
    # exists on either side (the rename would collide)
    keys = node.payload["keys"]
    dups = [c for c in left.schema
            if c in right.schema and c not in keys]
    names = set(left.schema) | set(right.schema)
    if any(f"{c}_r" in names for c in dups):
        return node
    fired.append("reorder-join-inputs")
    return node.with_payload(swap=True)


def _choose_layouts(node: LogicalNode, fired: List[str]) -> LogicalNode:
    node = node.with_inputs(*[_choose_layouts(i, fired)
                              for i in node.inputs])
    if node.kind == "orderby" and node.inputs[0].kind == "groupby":
        gb = node.inputs[0]
        if tuple(node.payload["by"]) == tuple(gb.payload["keys"]) \
                and gb.payload["layout"] == "hash":
            fired.append("choose-range-layout")
            gb = gb.with_payload(layout="range",
                                 layout_ascending=node.payload["ascending"])
            return node.with_inputs(gb)
    return node


def optimize(root: LogicalNode) -> Tuple[LogicalNode, Tuple[str, ...]]:
    """Run every rewrite pass; returns ``(optimized_root, fired_rules)``."""
    fired: List[str] = []
    root = _rewrite_up(root, fired)
    root = _push_projection(root, set(root.schema), fired)
    root = _reorder_joins(root, fired)
    root = _choose_layouts(root, fired)
    return root, tuple(dict.fromkeys(fired))
