"""Arrow-compatible schema model ↔ the packed ``ColSpec`` lane format.

A :class:`Schema` is the static type of a table: an ordered set of
:class:`Field`\\ s (name, numpy dtype, trailing dims).  It maps
*bidirectionally* onto the ``ColSpec`` uint32-lane layout that the packed
exchange uses (``core/exchange.py`` §3.1): fields are laid out in
sorted-name order and each field occupies ``lanes`` uint32 lanes per row —
1 lane per element for ≤4-byte types (sub-4-byte types widen), 2 lanes per
element for 8-byte types, trailing dims flatten to extra lanes.  The same
schema also maps onto an Arrow schema (``pyarrow`` optional): trailing
dims become nested ``fixed_size_list`` types.

Validity contract (DESIGN.md §2/§5): a stored table is *fixed capacity +
``num_rows``* — every row in ``[0, num_rows)`` is valid and there is no
per-value null bitmap.  Arrow inputs containing nulls are rejected eagerly
with the offending column names (never silently zero-filled).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.exchange import ColSpec
from .compat import require_pyarrow

#: numpy dtypes representable in the packed uint32-lane format.
SUPPORTED_DTYPES: Tuple[str, ...] = (
    "bool", "int8", "int16", "int32", "int64",
    "uint8", "uint16", "uint32", "uint64",
    "float16", "float32", "float64",
)


def _canon_dtype(dtype) -> str:
    try:
        name = np.dtype(dtype).name
    except TypeError:
        name = str(dtype)  # e.g. the unparseable 'str32' of a '<U' dtype
    if name not in SUPPORTED_DTYPES:
        raise TypeError(
            f"dtype {name!r} is not storable: the packed lane format "
            f"supports {SUPPORTED_DTYPES} (dictionary-encode strings into "
            f"fixed-width integer ids first, per core/table.py)")
    return name


@dataclasses.dataclass(frozen=True)
class Field:
    """One column: name, canonical numpy dtype name, trailing dims."""
    name: str
    dtype: str
    trailing: Tuple[int, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "dtype", _canon_dtype(self.dtype))
        object.__setattr__(self, "trailing", tuple(int(t) for t in self.trailing))

    @property
    def np_dtype(self) -> np.dtype:
        return np.dtype(self.dtype)

    @property
    def elements(self) -> int:
        """Flattened trailing elements per row."""
        return math.prod(self.trailing) if self.trailing else 1

    @property
    def lanes(self) -> int:
        """uint32 lanes per row in the packed format (§3.1)."""
        per = 2 if self.np_dtype.itemsize == 8 else 1
        return per * self.elements


class Schema:
    """Ordered field set; order is the packed layout's sorted-name order."""

    def __init__(self, fields: Sequence[Field]):
        fields = sorted(fields, key=lambda f: f.name)
        names = [f.name for f in fields]
        if len(set(names)) != len(names):
            dup = sorted({n for n in names if names.count(n) > 1})
            raise ValueError(f"duplicate field names: {dup}")
        if not fields:
            raise ValueError("Schema needs at least one field")
        self.fields: Tuple[Field, ...] = tuple(fields)
        self._by_name: Dict[str, Field] = {f.name: f for f in fields}

    # -- basics ----------------------------------------------------------
    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(f.name for f in self.fields)

    @property
    def row_width(self) -> int:
        """Total uint32 lanes per packed row."""
        return sum(f.lanes for f in self.fields)

    def __getitem__(self, name: str) -> Field:
        return self._by_name[name]

    def __contains__(self, name: str) -> bool:
        return name in self._by_name

    def __iter__(self):
        return iter(self.fields)

    def __eq__(self, other) -> bool:
        return isinstance(other, Schema) and self.fields == other.fields

    def __repr__(self) -> str:
        inner = ", ".join(
            f"{f.name}:{f.dtype}{list(f.trailing) if f.trailing else ''}"
            for f in self.fields)
        return f"Schema({inner})"

    def subset(self, names: Sequence[str]) -> "Schema":
        missing = [n for n in names if n not in self._by_name]
        if missing:
            raise KeyError(
                f"columns {missing} not in schema {list(self.names)}")
        return Schema([self._by_name[n] for n in names])

    # -- columns ↔ schema -------------------------------------------------
    @classmethod
    def from_columns(cls, cols: Dict[str, "np.ndarray"]) -> "Schema":
        """Infer the schema of a column dict (numpy or jax arrays)."""
        return cls([Field(k, np.dtype(v.dtype).name, tuple(v.shape[1:]))
                    for k, v in cols.items()])

    def validate_columns(self, cols: Dict[str, np.ndarray]) -> None:
        got = Schema.from_columns(cols)
        if got != self:
            raise ValueError(f"columns {got} do not match schema {self}")

    # -- ColSpec mapping (core/exchange.py §3.1) ---------------------------
    def to_colspecs(self) -> Tuple[ColSpec, ...]:
        """The exact packed layout ``pack_columns`` produces for this schema."""
        specs: List[ColSpec] = []
        start = 0
        for f in self.fields:  # already sorted by name == pack order
            specs.append(ColSpec(f.name, f.np_dtype, f.trailing, start,
                                 f.lanes))
            start += f.lanes
        return tuple(specs)

    @classmethod
    def from_colspecs(cls, specs: Sequence[ColSpec]) -> "Schema":
        sc = cls([Field(s.name, np.dtype(s.dtype).name, tuple(s.trailing))
                  for s in specs])
        # round-trip integrity: the lane math here must agree with the
        # packer that produced the specs
        for ours, theirs in zip(sc.to_colspecs(), sorted(specs,
                                                         key=lambda s: s.start)):
            if (ours.start, ours.lanes) != (theirs.start, theirs.lanes):
                raise ValueError(
                    f"ColSpec layout mismatch for {ours.name!r}: schema "
                    f"computes (start={ours.start}, lanes={ours.lanes}), "
                    f"packer recorded (start={theirs.start}, "
                    f"lanes={theirs.lanes})")
        return sc

    # -- JSON (manifest / .hpt header) -------------------------------------
    def to_json(self) -> List[dict]:
        return [{"name": f.name, "dtype": f.dtype,
                 "trailing": list(f.trailing)} for f in self.fields]

    @classmethod
    def from_json(cls, data: Sequence[dict]) -> "Schema":
        return cls([Field(d["name"], d["dtype"], tuple(d.get("trailing", ())))
                    for d in data])

    # -- Arrow mapping ------------------------------------------------------
    def to_arrow(self):
        pa = require_pyarrow("Schema.to_arrow")
        return pa.schema([(f.name, _arrow_type(pa, f)) for f in self.fields])

    @classmethod
    def from_arrow(cls, arrow_schema) -> "Schema":
        require_pyarrow("Schema.from_arrow")
        return cls([_field_from_arrow(f) for f in arrow_schema])


def _arrow_type(pa, field: Field):
    t = pa.from_numpy_dtype(field.np_dtype)
    for dim in reversed(field.trailing):
        t = pa.list_(t, dim)
    return t


def _field_from_arrow(af) -> Field:
    import pyarrow as pa

    t, trailing = af.type, []
    while pa.types.is_fixed_size_list(t):
        trailing.append(t.list_size)
        t = t.value_type
    try:
        dtype = t.to_pandas_dtype()
    except NotImplementedError as e:
        raise TypeError(
            f"arrow column {af.name!r} has unsupported type {af.type} "
            f"(dictionary-encode strings into integer ids first)") from e
    return Field(af.name, np.dtype(dtype).name, tuple(trailing))
