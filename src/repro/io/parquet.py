"""Parquet shard files: write with row groups, read with pushdown.

One Parquet file holds one shard's valid rows, split into row groups of
``rows_per_group`` (the pushdown granularity).  The reader works from
file *metadata only* until actual row groups are selected:

  * :func:`parquet_fragments` lists per-row-group ``(rows, min/max stats)``
    without touching data pages — what the scan planner prunes against;
  * :func:`read_row_groups` materializes only the selected row groups and
    only the projected columns (projection pushdown is Parquet-native:
    unprojected column chunks are never decoded or read).

All functions require pyarrow (`pip install .[io]`); the native ``.hpt``
path (``native.py``) is the dependency-free equivalent.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .arrow import from_arrow, to_arrow
from .compat import require_pyarrow
from .schema import Schema


def write_parquet(path: str, cols: Dict[str, np.ndarray],
                  num_rows: Optional[int] = None,
                  rows_per_group: Optional[int] = None) -> None:
    """Write valid rows as one Parquet file with min/max statistics."""
    require_pyarrow("write_parquet")
    import pyarrow.parquet as pq

    table = to_arrow(cols, num_rows)
    kw = {}
    if rows_per_group is not None:
        kw["row_group_size"] = int(rows_per_group)
    pq.write_table(table, path, write_statistics=True, **kw)


def parquet_schema(path: str) -> Schema:
    require_pyarrow("parquet_schema")
    import pyarrow.parquet as pq

    return Schema.from_arrow(pq.ParquetFile(path).schema_arrow)


def parquet_fragments(path: str) -> List[Tuple[int, int, Dict[str, Optional[Tuple]]]]:
    """Per-row-group metadata: ``(row_group_index, rows, {col: (min,max)})``.

    Stats cover only top-level primitive columns (nested fixed_size_list
    leaves are skipped); a column without usable min/max maps to ``None``
    so the planner cannot prune on it — conservative, never wrong.
    """
    require_pyarrow("parquet_fragments")
    import pyarrow.parquet as pq

    md = pq.ParquetFile(path).metadata
    out = []
    for g in range(md.num_row_groups):
        rg = md.row_group(g)
        stats: Dict[str, Optional[Tuple]] = {}
        for c in range(rg.num_columns):
            col = rg.column(c)
            name = col.path_in_schema
            if "." in name:  # nested leaf — not a scannable scalar column
                continue
            s = col.statistics
            if s is not None and s.has_min_max:
                stats[name] = (s.min, s.max)
            else:
                stats[name] = None
        out.append((g, rg.num_rows, stats))
    return out


def read_row_groups(path: str, row_groups: Sequence[int],
                    columns: Optional[Sequence[str]] = None,
                    ) -> Tuple[Dict[str, np.ndarray], int]:
    """Materialize selected row groups / projected columns → numpy."""
    require_pyarrow("read_row_groups")
    import pyarrow.parquet as pq

    pf = pq.ParquetFile(path)
    table = pf.read_row_groups(list(row_groups),
                               columns=list(columns) if columns else None)
    return from_arrow(table)
