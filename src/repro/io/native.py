"""Native ``.hpt`` columnar container — pure numpy, zero dependencies.

Layout (DESIGN.md §5.2)::

    bytes [0, 4)     magic  b"HPT1"
    bytes [4, 8)     uint32 little-endian header length H
    bytes [8, 8+H)   JSON header:
        {"num_rows": int,
         "schema":  [{"name", "dtype", "trailing"}, ...],
         "stats":   {col: {"min": x, "max": x} | null, ...},
         "offsets": {col: [start, nbytes], ...}}
    bytes [8+H, …)   data region: per-column raw little-endian C-order
                     buffers of exactly ``num_rows`` valid rows

Only valid rows are written — the fixed-capacity padding of the in-memory
representation never touches disk; capacity is re-planned at scan time
from the recorded row counts.  ``stats`` holds per-column min/max over the
valid rows of 1-D numeric/bool columns (``null`` when the column has NaNs
or trailing dims), feeding predicate pushdown: a reader may skip the whole
file when the stats prove no row can satisfy the predicate.

Round trips are bit-exact for every supported dtype — including ``-0.0``,
``inf`` and ``nan`` payloads — because buffers are raw ``tobytes()`` dumps.
"""
from __future__ import annotations

import json
import os
import struct
import zlib
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from .schema import Schema

MAGIC = b"HPT1"

Stats = Optional[Tuple[float, float]]


class CorruptFragmentError(ValueError):
    """A data fragment failed structural validation (truncation, CRC or
    byte-count mismatch, schema drift, undecodable pages).

    The base of the storage layer's corruption family — a ``ValueError``
    subclass, so the shared :class:`~repro.resilience.FaultPolicy`
    classifies it FATAL: corruption is deterministic, a retry re-reads
    the same bad bytes.  The scan layer either surfaces it naming file +
    fragment (``on_error="raise"``) or skips and records the fragment
    (``on_error="quarantine"``).
    """


class HptIntegrityError(CorruptFragmentError):
    """A ``.hpt`` file is truncated or corrupted.

    Raised instead of decoding garbage when the container fails its
    structural checks (magic, header length, buffer extents) or a column
    buffer's recorded CRC32 does not match the bytes on disk.  The message
    names the file and the failing check; the usual causes are an
    interrupted copy or a torn spill run — delete the file and regenerate
    it (spill runs are recomputed from their source on retry).
    """


def column_stats(arr: np.ndarray) -> Stats:
    """Min/max of a 1-D numeric/bool column, or None when unusable.

    NaNs poison ordering comparisons, so any NaN disables the stats for
    the column (pushdown then cannot prune on it — conservative, never
    wrong).
    """
    if arr.ndim != 1 or arr.size == 0:
        return None
    if arr.dtype.kind == "f" and bool(np.isnan(arr).any()):
        return None
    if arr.dtype.kind == "b":
        return bool(arr.min()), bool(arr.max())
    if arr.dtype.kind == "f":
        return float(arr.min()), float(arr.max())
    return int(arr.min()), int(arr.max())


def write_hpt(path: str, cols: Dict[str, np.ndarray],
              num_rows: Optional[int] = None) -> dict:
    """Write valid rows of a column dict; returns the header written."""
    cols = {k: np.asarray(v) for k, v in cols.items()}
    schema = Schema.from_columns(cols)
    lengths = {k: v.shape[0] for k, v in cols.items()}
    if len(set(lengths.values())) > 1:
        raise ValueError(f"ragged column lengths: {sorted(lengths.items())}")
    n = next(iter(lengths.values()))
    if num_rows is None:
        num_rows = n
    if num_rows > n:
        raise ValueError(f"num_rows {num_rows} exceeds column length {n}")

    offsets, stats, crcs, bufs, pos = {}, {}, {}, [], 0
    for name in schema.names:
        valid = np.ascontiguousarray(cols[name][:num_rows])
        buf = valid.tobytes()
        offsets[name] = [pos, len(buf)]
        crcs[name] = zlib.crc32(buf) & 0xFFFFFFFF
        stats[name] = None
        s = column_stats(valid)
        if s is not None:
            stats[name] = {"min": s[0], "max": s[1]}
        bufs.append(buf)
        pos += len(buf)

    header = {"num_rows": int(num_rows), "schema": schema.to_json(),
              "stats": stats, "offsets": offsets, "crc32": crcs}
    hjson = json.dumps(header).encode()
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(hjson)))
        f.write(hjson)
        for buf in bufs:
            f.write(buf)
    os.replace(tmp, path)  # readers never observe a half-written file
    return header


def read_hpt_header(path: str) -> dict:
    """Header only — the metadata a scan plans from, no data bytes read."""
    with open(path, "rb") as f:
        magic = f.read(4)
        if magic != MAGIC:
            raise HptIntegrityError(
                f"{path}: not an .hpt file or truncated before the magic "
                f"(read {magic!r}, want {MAGIC!r})")
        raw_len = f.read(4)
        if len(raw_len) < 4:
            raise HptIntegrityError(
                f"{path}: truncated inside the header-length field")
        (hlen,) = struct.unpack("<I", raw_len)
        hjson = f.read(hlen)
        if len(hjson) < hlen:
            raise HptIntegrityError(
                f"{path}: truncated inside the JSON header (have "
                f"{len(hjson)} of {hlen} bytes)")
        try:
            return json.loads(hjson.decode())
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise HptIntegrityError(
                f"{path}: corrupted JSON header ({e})") from e


def read_hpt(path: str, columns: Optional[Sequence[str]] = None,
             ) -> Tuple[Dict[str, np.ndarray], int]:
    """Read (a projection of) an ``.hpt`` file → (columns, num_rows).

    Projection pushdown is physical: unprojected columns are never read
    from disk — the reader seeks straight to each requested buffer.
    """
    header = read_hpt_header(path)
    schema = Schema.from_json(header["schema"])
    n = header["num_rows"]
    names = list(columns) if columns is not None else list(schema.names)
    missing = [c for c in names if c not in schema]
    if missing:
        raise KeyError(f"{path}: columns {missing} not in schema "
                       f"{list(schema.names)}")
    crcs = header.get("crc32", {})  # absent in pre-checksum files
    with open(path, "rb") as f:
        f.seek(4)
        (hlen,) = struct.unpack("<I", f.read(4))
        data_start = 8 + hlen
        out: Dict[str, np.ndarray] = {}
        for name in names:
            field = schema[name]
            start, nbytes = header["offsets"][name]
            # eager consistency check BEFORE any byte is read: the header
            # row count must agree with the recorded buffer extent, else
            # the reshape below would surface a raw numpy error
            trail = 1
            for d in field.trailing:
                trail *= int(d)
            expected = int(n) * trail * field.np_dtype.itemsize
            if nbytes != expected:
                raise CorruptFragmentError(
                    f"{path}: column {name!r} is inconsistent — the "
                    f"header claims {n} rows ({expected} bytes of "
                    f"{field.np_dtype}{field.trailing or ''}) but records "
                    f"a {nbytes}-byte buffer; the header or data region "
                    f"was corrupted — regenerate the file")
            f.seek(data_start + start)
            raw = f.read(nbytes)
            if len(raw) < nbytes:
                raise HptIntegrityError(
                    f"{path}: column {name!r} truncated (have {len(raw)} "
                    f"of {nbytes} bytes) — the file was cut short while "
                    f"being written or copied")
            if name in crcs and (zlib.crc32(raw) & 0xFFFFFFFF) != crcs[name]:
                raise HptIntegrityError(
                    f"{path}: column {name!r} failed its CRC32 check — "
                    f"the data bytes do not match what the writer "
                    f"recorded; regenerate the file")
            arr = np.frombuffer(raw, dtype=field.np_dtype)
            out[name] = arr.reshape((n,) + field.trailing).copy()
    return out, n
