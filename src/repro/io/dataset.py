"""Sharded on-disk datasets and the partitioning manifest (DESIGN.md §5.3).

A dataset is a directory of shard files plus ``_hptmt_manifest.json``::

    root/
      _hptmt_manifest.json
      part-00000-000.hpt        (or .parquet)
      part-00001-000.hpt
      ...

The manifest records the schema, every file's row count and **which shard
wrote it**, and — when the dataset was written with ``partition_by=keys``
— the hash-partitioning evidence ``{"keys": [...], "n_shards": p}``.  That
is exactly the ``DistTable.partitioning`` contract of DESIGN.md §4: a scan
that places file ``i``'s rows back on shard ``i`` of a ``p``-shard context
may re-attach the metadata, and a following ``join``/``groupby`` on the
partition keys elides its shuffle (zero left-side AllToAll, asserted on
the traced jaxpr in ``tests/test_io.py``).

Fragments are the pushdown granularity: one per Parquet row group, one
per native ``.hpt`` file.  Both carry per-column min/max stats.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.table import DistTable, Partitioning, partitioning_kind
from .compat import has_pyarrow, require_pyarrow
from .native import read_hpt_header, write_hpt
from .schema import Schema

MANIFEST_NAME = "_hptmt_manifest.json"
FORMATS = ("hpt", "parquet")


@dataclasses.dataclass(frozen=True)
class Fragment:
    """One prunable unit: an ``.hpt`` file or one Parquet row group."""
    path: str
    format: str
    row_group: Optional[int]  # None for hpt (file == fragment)
    rows: int
    stats: Dict[str, Optional[Tuple]]
    file_index: int
    shard: Optional[int]  # writer shard recorded in the manifest


@dataclasses.dataclass(frozen=True)
class Dataset:
    """Planned view of an on-disk dataset: metadata only, no data read."""
    root: str
    format: str
    schema: Schema
    fragments: Tuple[Fragment, ...]
    partitioning: Partitioning
    n_files: int

    @property
    def num_rows(self) -> int:
        return sum(f.rows for f in self.fragments)

    def stat_bounds(self, column: str) -> Optional[Tuple]:
        """Global ``(min, max)`` for ``column`` across all fragments.

        ``None`` when any fragment lacks stats for the column — callers
        (the query planner's cardinality estimator) must treat that as
        "unknown", the same conservatism as fragment pruning.
        """
        lo = hi = None
        for f in self.fragments:
            s = f.stats.get(column)
            if s is None:
                return None
            lo = s[0] if lo is None else min(lo, s[0])
            hi = s[1] if hi is None else max(hi, s[1])
        return None if lo is None else (lo, hi)


def _default_format(fmt: Optional[str]) -> str:
    if fmt in FORMATS:
        return fmt
    if fmt in (None, "auto"):
        return "parquet" if has_pyarrow() else "hpt"
    raise ValueError(f"unknown dataset format {fmt!r}; expected {FORMATS}")


# ===========================================================================
# writing
# ===========================================================================
def write_dataset(root: str,
                  shards: Sequence[Tuple[Dict[str, np.ndarray], int]],
                  *, format: Optional[str] = None,
                  partitioning: Partitioning = None,
                  rows_per_group: Optional[int] = None) -> str:
    """Write per-shard ``(columns, num_rows)`` arrays as a dataset.

    ``rows_per_group`` bounds the pushdown granularity: Parquet splits each
    shard file into row groups of that size; the native format writes one
    ``.hpt`` file per group (a fragment is a whole file there).
    ``partitioning`` is recorded verbatim in the manifest — callers assert
    it truthfully (see :func:`write_dist_table`).
    """
    fmt = _default_format(format)
    os.makedirs(root, exist_ok=True)
    files: List[dict] = []
    schema: Optional[Schema] = None
    for shard_id, (cols, n) in enumerate(shards):
        cols = {k: np.asarray(v)[:n] for k, v in cols.items()}
        s = Schema.from_columns(cols)
        if schema is None:
            schema = s
        elif s != schema:
            raise ValueError(f"shard {shard_id} schema {s} != shard 0 "
                             f"schema {schema}")
        if fmt == "parquet":
            from .parquet import write_parquet

            name = f"part-{shard_id:05d}-000.parquet"
            write_parquet(os.path.join(root, name), cols, n,
                          rows_per_group=rows_per_group)
            files.append({"path": name, "rows": int(n), "shard": shard_id})
        else:
            per = int(rows_per_group) if rows_per_group else max(int(n), 1)
            starts = range(0, max(int(n), 1), per) if n else [0]
            for g, start in enumerate(starts):
                stop = min(start + per, int(n))
                name = f"part-{shard_id:05d}-{g:03d}.hpt"
                write_hpt(os.path.join(root, name),
                          {k: v[start:stop] for k, v in cols.items()},
                          stop - start)
                files.append({"path": name, "rows": int(stop - start),
                              "shard": shard_id})
    if schema is None:
        raise ValueError("write_dataset needs at least one shard")
    # the manifest's {"keys", "n_shards"} schema records HASH evidence
    # only (scan re-entry feeds the §4 elision sites); a range layout
    # (orderby output) is not representable on disk yet — normalize it to
    # None here so EVERY caller is covered (dropping is always safe, §4)
    if partitioning is not None and partitioning_kind(partitioning) != "hash":
        partitioning = None
    manifest = {
        "version": 1,
        "format": fmt,
        "schema": schema.to_json(),
        "partitioning": (None if partitioning is None else
                         {"keys": list(partitioning[0]),
                          "n_shards": int(partitioning[1])}),
        "files": files,
    }
    tmp = os.path.join(root, MANIFEST_NAME + ".tmp")
    with open(tmp, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, os.path.join(root, MANIFEST_NAME))
    return root


def write_dist_table(dt: DistTable, root: str, *, ctx,
                     format: Optional[str] = None,
                     partition_by: Optional[Sequence[str]] = None,
                     rows_per_group: Optional[int] = None):
    """Write a :class:`DistTable` as a dataset; returns the overflow count.

    With ``partition_by`` the rows are hash-shuffled first (a no-op when
    ``dt.partitioning`` already proves the layout, DESIGN.md §4) and the
    manifest records the ``(keys, n_shards)`` evidence, so a later scan on
    a matching context re-enters the partitioned world without moving a
    row.
    """
    from repro.core import table_ops

    overflow = 0
    if partition_by is not None:
        dt, ov = table_ops.shuffle(dt, list(partition_by), ctx=ctx)
        overflow = int(ov)
    shards = []
    for i in range(dt.n_shards):
        t = dt.shard_table(i)
        shards.append((t.to_numpy(), int(t.num_rows)))
    write_dataset(root, shards, format=format,
                  partitioning=dt.partitioning,
                  rows_per_group=rows_per_group)
    return overflow


# ===========================================================================
# opening
# ===========================================================================
def open_dataset(path: str) -> Dataset:
    """Open a dataset directory (manifest) or a single shard file.

    Metadata-only: reads the manifest plus per-file headers / Parquet
    footers; no data pages are touched until a scan materializes.
    """
    if os.path.isdir(path):
        return _open_dir(path)
    if path.endswith(".hpt"):
        return _from_files(os.path.dirname(path) or ".", "hpt",
                           [{"path": os.path.basename(path), "shard": None}],
                           partitioning=None)
    if path.endswith(".parquet"):
        return _from_files(os.path.dirname(path) or ".", "parquet",
                          [{"path": os.path.basename(path), "shard": None}],
                          partitioning=None)
    raise ValueError(f"{path}: not a dataset directory, .hpt, or .parquet")


def _open_dir(root: str) -> Dataset:
    mpath = os.path.join(root, MANIFEST_NAME)
    if os.path.exists(mpath):
        with open(mpath) as f:
            m = json.load(f)
        part = m.get("partitioning")
        partitioning = (tuple(part["keys"]), int(part["n_shards"])) \
            if part else None
        return _from_files(root, m["format"], m["files"], partitioning,
                           schema=Schema.from_json(m["schema"]))
    # manifest-less directory: glob shard files, no partitioning evidence
    for fmt, pattern in (("parquet", "*.parquet"), ("hpt", "*.hpt")):
        found = sorted(glob.glob(os.path.join(root, pattern)))
        if found:
            return _from_files(
                root, fmt,
                [{"path": os.path.basename(p), "shard": None} for p in found],
                partitioning=None)
    raise FileNotFoundError(f"{root}: no {MANIFEST_NAME}, *.parquet or "
                            f"*.hpt files")


def _from_files(root: str, fmt: str, files: Sequence[dict],
                partitioning: Partitioning,
                schema: Optional[Schema] = None) -> Dataset:
    if fmt == "parquet":
        require_pyarrow(f"opening parquet dataset {root}")
    fragments: List[Fragment] = []
    for idx, entry in enumerate(files):
        fpath = os.path.join(root, entry["path"])
        shard = entry.get("shard")
        if fmt == "hpt":
            header = read_hpt_header(fpath)
            fschema = Schema.from_json(header["schema"])
            stats = {k: (None if v is None else (v["min"], v["max"]))
                     for k, v in header.get("stats", {}).items()}
            fragments.append(Fragment(fpath, fmt, None, header["num_rows"],
                                      stats, idx, shard))
        else:
            from .parquet import parquet_fragments, parquet_schema

            fschema = parquet_schema(fpath)
            for g, rows, stats in parquet_fragments(fpath):
                fragments.append(Fragment(fpath, fmt, g, rows, stats, idx,
                                          shard))
        if schema is None:
            schema = fschema
        elif fschema != schema:
            raise ValueError(f"{fpath}: schema {fschema} != dataset "
                             f"schema {schema}")
    if schema is None:
        raise FileNotFoundError(f"{root}: dataset has no files")
    return Dataset(root=root, format=fmt, schema=schema,
                   fragments=tuple(fragments), partitioning=partitioning,
                   n_files=len(files))
