"""Optional pyarrow dependency gate.

pyarrow is an *optional* extra (``pip install .[io]``): the native ``.hpt``
path and every scan feature must work without it, and tier-1 collection
must never hard-fail on its absence (mirrors the hypothesis shim in
``tests/conftest.py``).

``HPTMT_DISABLE_PYARROW=1`` force-disables pyarrow even when installed —
this is how the "pyarrow absent" CI leg and local tests exercise the
fallback paths on machines that do have the package.
"""
from __future__ import annotations

import os

_DISABLE_ENV = "HPTMT_DISABLE_PYARROW"


def get_pyarrow():
    """The ``pyarrow`` module, or ``None`` when absent/disabled."""
    if os.environ.get(_DISABLE_ENV):
        return None
    try:
        import pyarrow
        return pyarrow
    except ImportError:
        return None


def has_pyarrow() -> bool:
    return get_pyarrow() is not None


def require_pyarrow(what: str):
    """Return pyarrow or raise an actionable error naming the feature."""
    pa = get_pyarrow()
    if pa is None:
        raise RuntimeError(
            f"{what} requires pyarrow, which is "
            + ("disabled via $" + _DISABLE_ENV
               if os.environ.get(_DISABLE_ENV) else "not installed")
            + " — `pip install hptmt-repro[io]` (or plain `pip install "
            "pyarrow`), or use the native .hpt format which has no "
            "dependency (repro.io.native / format='hpt')")
    return pa
