"""Columnar storage & ingest subsystem (HPTMT §VI interoperability).

The paper names Apache Arrow and Parquet as the keystone of
language-agnostic, high-performance interop; this package maps them onto
the repo's static-shape Table/DistTable world (DESIGN.md §5):

  schema.py    Arrow-compatible schema model ↔ the packed ``ColSpec``
               uint32-lane format of ``core/exchange.py`` §3.1
  native.py    pure-numpy ``.hpt`` container (header + raw column
               buffers) — works and is CI-tested with pyarrow absent
  arrow.py     zero-copy ``from_arrow``/``to_arrow`` (optional pyarrow)
  parquet.py   per-shard Parquet files with row-group min/max stats
  dataset.py   sharded on-disk datasets + the partitioning manifest
  scan.py      pushdown-aware ``ScanSource`` (projection + predicate,
               row-group skipping, per-shard capacity planning)
"""
from .compat import has_pyarrow, require_pyarrow
from .schema import Field, Schema
from .native import (CorruptFragmentError, HptIntegrityError, read_hpt,
                     read_hpt_header, write_hpt)
from .arrow import from_arrow, to_arrow
from .dataset import Dataset, Fragment, open_dataset, write_dataset, write_dist_table
from .scan import ColumnPredicate, ScanSource, ScanStats, pred, read_dataset

__all__ = [
    "has_pyarrow", "require_pyarrow", "Field", "Schema",
    "CorruptFragmentError", "HptIntegrityError", "read_hpt",
    "read_hpt_header", "write_hpt",
    "from_arrow", "to_arrow",
    "Dataset", "Fragment", "open_dataset", "write_dataset",
    "write_dist_table",
    "ColumnPredicate", "ScanSource", "ScanStats", "pred", "read_dataset",
]
