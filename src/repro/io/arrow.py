"""Zero-copy Arrow interop (optional pyarrow; HPTMT §VI).

``from_arrow`` / ``to_arrow`` convert between a pyarrow Table and the
column-dict + ``num_rows`` representation the rest of the stack uses.
Fixed-width numeric columns cross the boundary without copying the data
buffers (Arrow and numpy agree on the raw layout); bool (bit-packed in
Arrow, byte-per-value in numpy) is the one materializing conversion.

Validity contract (DESIGN.md §5.1): the in-memory format is fixed
capacity + ``num_rows`` with **no null bitmap** — Arrow inputs containing
nulls are rejected eagerly with the offending column names, never
silently zero-filled.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from .compat import require_pyarrow
from .schema import Schema


def check_no_nulls(arrow_table) -> None:
    """Reject nulls eagerly — the fixed-capacity + num_rows contract has
    no per-value validity bitmap to carry them."""
    bad = [(f.name, arrow_table.column(f.name).null_count)
           for f in arrow_table.schema
           if arrow_table.column(f.name).null_count]
    if bad:
        raise ValueError(
            f"columns with nulls cannot be ingested: "
            f"{[f'{n} ({c} nulls)' for n, c in bad]} — the storage "
            f"contract is fixed capacity + num_rows with no validity "
            f"bitmap (DESIGN.md §5); drop or fill the nulls first")


def from_arrow(arrow_table, columns: Optional[Sequence[str]] = None,
               ) -> Tuple[Dict[str, np.ndarray], int]:
    """pyarrow Table → (column dict, num_rows); zero-copy where possible."""
    pa = require_pyarrow("from_arrow")
    if columns is not None:
        arrow_table = arrow_table.select(list(columns))
    schema = Schema.from_arrow(arrow_table.schema)  # validates dtypes
    check_no_nulls(arrow_table)
    n = arrow_table.num_rows
    out: Dict[str, np.ndarray] = {}
    for field in schema:
        col = arrow_table.column(field.name)
        chunked = col.combine_chunks() if col.num_chunks != 1 else col.chunk(0)
        arr = chunked
        for _ in field.trailing:  # unwrap nested fixed_size_list levels
            arr = arr.flatten()
        if pa.types.is_boolean(arr.type):
            flat = arr.to_numpy(zero_copy_only=False)
        else:
            flat = arr.to_numpy(zero_copy_only=True)
        out[field.name] = flat.reshape((n,) + field.trailing)
    return out, n


def to_arrow(cols: Dict[str, np.ndarray], num_rows: Optional[int] = None):
    """(column dict, num_rows) → pyarrow Table over the valid rows.

    Numeric buffers are wrapped, not copied; only the valid-row prefix is
    exposed so padding never leaks into Arrow land.
    """
    pa = require_pyarrow("to_arrow")
    cols = {k: np.asarray(v) for k, v in cols.items()}
    schema = Schema.from_columns(cols)
    n = num_rows if num_rows is not None else \
        next(iter(cols.values())).shape[0]
    arrays = []
    for field in schema:
        valid = np.ascontiguousarray(cols[field.name][:n])
        arr = pa.array(valid.reshape(-1))
        for dim in reversed(field.trailing):
            arr = pa.FixedSizeListArray.from_arrays(arr, dim)
        arrays.append(arr)
    return pa.Table.from_arrays(arrays, names=list(schema.names))
