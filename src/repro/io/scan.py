"""Pushdown-aware sharded scan operator (DESIGN.md §5.4).

``ScanSource`` turns an on-disk :class:`~repro.io.dataset.Dataset` into a
:class:`DistTable` (eager) or a stream of chunk tables (out-of-core,
via ``TSet``), planning everything from metadata before touching a data
page:

  * **Projection pushdown** — only projected columns (plus columns the
    predicate needs) are read; unprojected columns are never materialized
    (Parquet skips their column chunks, ``.hpt`` seeks past their
    buffers).
  * **Predicate pushdown** — fragments (Parquet row groups / ``.hpt``
    files) whose min/max stats prove no row can match are skipped whole;
    surviving fragments get an exact residual row filter after load.
    Stats-based pruning is conservative: missing stats never prune.
  * **Capacity planning** — per-shard static capacity is computed from
    the row counts of the fragments assigned to each shard; an explicit
    smaller ``capacity`` engages the §2 overflow contract (excess rows
    are counted and dropped in original row order, never corrupted).
  * **Partitioned re-entry** — when the manifest's hash-partitioning
    evidence matches the context (same ordered keys, same shard count,
    every key column projected), fragments are placed back on the shard
    that wrote them and the result carries ``DistTable.partitioning``:
    a following join/groupby on those keys elides its shuffle
    (DESIGN.md §4).

Hardened reads (DESIGN.md §13.5): every fragment run passes through the
``scan.read`` chaos-injection site and, with a
:class:`~repro.resilience.FaultPolicy`, transient ``OSError``-family
failures are retried with backoff.  Corruption — truncation, CRC or
byte-count mismatch, schema drift, undecodable Parquet pages — is
*never* retried: it surfaces as a typed
:class:`~repro.io.native.CorruptFragmentError` naming the file and
fragment, or, under ``on_error="quarantine"``, the bad fragment is
skipped whole, counted in :class:`ScanStats`, and recorded in a
``_hptmt_quarantine.json`` sidecar next to the dataset.

Planning and I/O run on the host in numpy; rows enter jax (and the
fixed-capacity static-shape world) only at table assembly.
"""
from __future__ import annotations

import dataclasses
import json
import math
import operator as _op
import os
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro import telemetry
from repro.core.table import DistTable, Partitioning, Table
from repro.resilience import faults
from .dataset import Dataset, Fragment, open_dataset
from .native import CorruptFragmentError

_OPS = {"<": _op.lt, "<=": _op.le, ">": _op.gt, ">=": _op.ge,
        "==": _op.eq, "!=": _op.ne}


@dataclasses.dataclass(frozen=True)
class ColumnPredicate:
    """One comparison ``column <op> value``; a list of these is an AND."""
    column: str
    op: str
    value: Union[int, float, bool]

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"unknown predicate op {self.op!r}; "
                             f"expected one of {sorted(_OPS)}")

    def maybe_satisfied(self, stats: Optional[Tuple]) -> bool:
        """Can ANY row of a fragment with these min/max stats match?

        ``None`` stats (absent, NaN-poisoned, or non-scalar column) never
        prune — conservative.
        """
        if stats is None:
            return True
        mn, mx = stats
        v = self.value
        if self.op == "<":
            return mn < v
        if self.op == "<=":
            return mn <= v
        if self.op == ">":
            return mx > v
        if self.op == ">=":
            return mx >= v
        if self.op == "==":
            return mn <= v <= mx
        return not (mn == v == mx)  # "!="

    def mask(self, cols: Dict[str, np.ndarray]) -> np.ndarray:
        """Exact residual row filter on loaded host columns."""
        return _OPS[self.op](cols[self.column], self.value)


def pred(column: str, op: str, value) -> ColumnPredicate:
    """Shorthand: ``pred("day", "<", 7)``."""
    return ColumnPredicate(column, op, value)


def _normalize_predicate(predicate) -> Tuple[ColumnPredicate, ...]:
    if predicate is None:
        return ()
    if isinstance(predicate, ColumnPredicate):
        return (predicate,)
    if isinstance(predicate, tuple) and len(predicate) == 3 \
            and isinstance(predicate[0], str):
        return (ColumnPredicate(*predicate),)
    return tuple(p if isinstance(p, ColumnPredicate)
                 else ColumnPredicate(*p) for p in predicate)


@dataclasses.dataclass
class ScanStats:
    """Observable pushdown accounting (asserted by tests/benchmarks)."""
    files_total: int = 0
    row_groups_total: int = 0
    row_groups_skipped: int = 0
    columns_total: int = 0
    columns_read: int = 0
    rows_on_disk: int = 0      # dataset total per metadata
    rows_scanned: int = 0      # materialized from surviving fragments
    rows_selected: int = 0     # after the residual predicate
    rows_overflowed: int = 0   # dropped by the §2 capacity contract
    fragments_quarantined: int = 0  # corrupt fragments skipped (opt-in)
    rows_quarantined: int = 0       # metadata rows of those fragments

    def as_report(self):
        """This scan's overflow as an :class:`~repro.core.report.OverflowReport`
        under the ``"scan.capacity"`` label — mergeable into a
        DataFrame/TSet lineage report (DESIGN.md §10)."""
        from repro.core.report import OverflowReport

        return OverflowReport().add("scan.capacity", self.rows_overflowed)


class ScanSource:
    """Plan + execute a sharded, pushdown-aware scan of a dataset."""

    def __init__(self, dataset: Union[Dataset, str], *, ctx,
                 columns: Optional[Sequence[str]] = None,
                 predicate=None, capacity: Optional[int] = None,
                 bucket_factor: float = 1.0,
                 allow_narrowing: bool = False,
                 on_error: str = "raise", policy=None):
        if on_error not in ("raise", "quarantine"):
            raise ValueError(f"on_error={on_error!r}; expected 'raise' "
                             f"or 'quarantine'")
        if isinstance(dataset, str):
            dataset = open_dataset(dataset)
        self.dataset = dataset
        self.ctx = ctx
        self.predicate = _normalize_predicate(predicate)
        self.allow_narrowing = allow_narrowing
        self.on_error = on_error
        self.policy = policy  # optional FaultPolicy: retry transient reads
        self.quarantined: List[Dict] = []
        schema = dataset.schema
        self.out_columns: Tuple[str, ...] = (
            tuple(columns) if columns is not None else schema.names)
        missing = [c for c in self.out_columns if c not in schema]
        if missing:
            raise KeyError(f"projected columns {missing} not in dataset "
                           f"schema {list(schema.names)}")
        for p in self.predicate:
            if p.column not in schema:
                raise KeyError(f"predicate column {p.column!r} not in "
                               f"dataset schema {list(schema.names)}")
            if schema[p.column].trailing:
                raise ValueError(f"predicate column {p.column!r} has "
                                 f"trailing dims {schema[p.column].trailing}"
                                 f" — predicates apply to scalar columns")
        # read set = projection ∪ predicate columns (pred-only columns are
        # dropped after filtering, never returned)
        self.read_columns: Tuple[str, ...] = tuple(dict.fromkeys(
            list(self.out_columns) + [p.column for p in self.predicate]))
        self.stats = ScanStats(
            files_total=dataset.n_files,
            row_groups_total=len(dataset.fragments),
            columns_total=len(schema.names),
            rows_on_disk=dataset.num_rows)
        self._plan(capacity, bucket_factor)

    # -- planning (metadata only) ------------------------------------------
    def _plan(self, capacity: Optional[int], bucket_factor: float) -> None:
        p = self.ctx.n_shards
        # "!=" on a float column must never prune: NaN rows satisfy it,
        # but writers may compute min/max ignoring NaNs (Parquet does), so
        # min == max == v does NOT prove every row equals v.  All other
        # ops are NaN-safe (a NaN row can never satisfy them).  The
        # residual filter still applies "!=" exactly.
        prunable = [pr for pr in self.predicate
                    if not (pr.op == "!="
                            and self.dataset.schema[pr.column].np_dtype.kind
                            == "f")]
        with telemetry.span("io.scan.prune",
                            fragments=len(self.dataset.fragments)) as sp:
            kept: List[Fragment] = []
            for frag in self.dataset.fragments:
                if all(pr.maybe_satisfied(frag.stats.get(pr.column))
                       for pr in prunable):
                    kept.append(frag)
            self.stats.row_groups_skipped = (
                len(self.dataset.fragments) - len(kept))
            sp.attrs["pruned"] = self.stats.row_groups_skipped
        self.stats.columns_read = len(self.read_columns) if kept else 0

        # partitioned re-entry: manifest evidence + matching context +
        # every hash-key column surviving the projection (same rule as
        # table_ops.project, DESIGN.md §4)
        dpart = self.dataset.partitioning
        self._partitioning: Partitioning = None
        use_manifest_placement = (
            dpart is not None and dpart[1] == p
            and all(f.shard is not None and 0 <= f.shard < p
                    for f in self.dataset.fragments))
        if use_manifest_placement and set(dpart[0]) <= set(self.out_columns):
            self._partitioning = dpart

        self._by_shard: List[List[Fragment]] = [[] for _ in range(p)]
        for i, frag in enumerate(kept):
            shard = frag.shard if use_manifest_placement else i % p
            self._by_shard[shard].append(frag)

        # bucket_factor over-allocates like DataFrame.from_dict: head-room
        # for a *later* shuffle's hash skew (a 100%-occupancy table gives
        # downstream exchanges zero slack and overflows on skewed keys)
        planned = max([sum(f.rows for f in fr) for fr in self._by_shard]
                      + [1])
        self.shard_capacity = int(capacity) if capacity is not None \
            else math.ceil(planned * bucket_factor)

    @property
    def partitioning(self) -> Partitioning:
        return self._partitioning

    @property
    def planned_rows(self) -> int:
        """Rows in fragments that survived pruning (metadata only; an
        upper bound on materialized rows — the residual filter can only
        shrink it).  Feeds the query planner's cardinality estimates."""
        return sum(f.rows for fr in self._by_shard for f in fr)

    # -- materialization ----------------------------------------------------
    def _reset_io_stats(self) -> None:
        """I/O counters are per-materialization, not cumulative — calling
        ``to_dist_table`` and then ``chunks`` must not double-count."""
        self.stats.rows_scanned = 0
        self.stats.rows_selected = 0
        self.stats.rows_overflowed = 0
        self.stats.fragments_quarantined = 0
        self.stats.rows_quarantined = 0
        self.quarantined = []

    def _validate_run(self, frags: Sequence[Fragment],
                      cols: Dict[str, np.ndarray]) -> None:
        """Schema-drift check: a fragment whose on-disk dtypes disagree
        with the dataset schema corrupts downstream identity contracts
        (hash layouts, bit-exact parity) — typed error, never a silent
        cast."""
        schema = self.dataset.schema
        for name in self.read_columns:
            want = schema[name].np_dtype
            if cols[name].dtype != want:
                raise CorruptFragmentError(
                    f"{frags[0].path}: column {name!r} drifted to dtype "
                    f"{cols[name].dtype} (dataset schema says {want}) — "
                    f"the fragment was rewritten with a different schema")

    def _read_fragments(self, frags: Sequence[Fragment]
                        ) -> Tuple[Dict[str, np.ndarray], int]:
        """One physical read (+ validation), retried under the policy
        for transient failures; the ``scan.read`` injection site fires
        inside the retry loop so injected one-shot faults recover."""
        def read():
            faults.fire("scan.read", path=frags[0].path)
            if frags[0].format == "hpt":
                from .native import read_hpt

                cols, n = read_hpt(frags[0].path, self.read_columns)
            else:
                from .parquet import read_row_groups

                cols, n = read_row_groups(frags[0].path,
                                          [f.row_group for f in frags],
                                          self.read_columns)
            self._validate_run(frags, cols)
            return cols, n

        if self.policy is not None:
            return self.policy.run(read, site="scan.read")
        return read()

    def _quarantine(self, frags: Sequence[Fragment],
                    err: Exception) -> None:
        """Record a corrupt run and skip it whole (opt-in data loss with
        a full audit trail: stats counters, telemetry, sidecar)."""
        rows = sum(f.rows for f in frags)
        self.stats.fragments_quarantined += len(frags)
        self.stats.rows_quarantined += rows
        self.quarantined.append({
            "path": frags[0].path,
            "fragments": [f.file_index if f.row_group is None
                          else f.row_group for f in frags],
            "rows": int(rows), "error": str(err)})

    def _write_quarantine_manifest(self) -> None:
        """Sidecar audit record next to the dataset (atomic, best-effort:
        an unwritable dataset dir must not fail the scan itself)."""
        path = os.path.join(self.dataset.root, "_hptmt_quarantine.json")
        try:
            tmp = path + ".tmp"
            with open(tmp, "w") as f:
                json.dump({"quarantined": self.quarantined}, f, indent=1)
            os.replace(tmp, path)
        except OSError:
            pass

    def _load_run(self, frags: Sequence[Fragment]
                  ) -> Tuple[Dict[str, np.ndarray], int]:
        """Load consecutive fragments of ONE file in a single read.

        Parquet row groups of the same shard file batch into one
        ``read_row_groups`` call — one file open / footer parse per run,
        not per fragment.  Corruption surfaces as a typed
        :class:`CorruptFragmentError` naming file + fragments, or the
        run is quarantined when the scan opted in.
        """
        with telemetry.span("io.scan.read", path=frags[0].path,
                            fragments=len(frags)) as sp:
            try:
                cols, n = self._read_fragments(frags)
            except (ValueError, KeyError) as e:
                # the corruption family: CorruptFragmentError subclasses
                # (hpt integrity / byte counts / schema drift), pyarrow's
                # ArrowInvalid (a ValueError), missing-column KeyErrors
                err = e if isinstance(e, CorruptFragmentError) else \
                    CorruptFragmentError(
                        f"{frags[0].path}: fragment(s) "
                        f"{[f.row_group for f in frags]} failed to decode "
                        f"({type(e).__name__}: {e})")
                if self.on_error != "quarantine":
                    raise err from e
                self._quarantine(frags, err)
                sp.attrs["quarantined"] = len(frags)
                schema = self.dataset.schema
                cols = {c: np.zeros((0,) + schema[c].trailing,
                                    schema[c].np_dtype)
                        for c in self.read_columns}
                n = 0
            self.stats.rows_scanned += n
            sp.attrs["rows_scanned"] = n
            if self.predicate:
                keep = np.ones(n, bool)
                for pr in self.predicate:
                    keep &= pr.mask(cols)
                cols = {k: v[keep] for k, v in cols.items()}
                n = int(keep.sum())
            self.stats.rows_selected += n
            sp.attrs["rows_selected"] = n
        return {k: cols[k] for k in self.out_columns}, n

    def _load_fragments(self, frags: Sequence[Fragment]
                        ) -> List[Tuple[Dict[str, np.ndarray], int]]:
        runs: List[List[Fragment]] = []
        for f in frags:
            if (runs and f.format == "parquet"
                    and runs[-1][-1].path == f.path):
                runs[-1].append(f)
            else:
                runs.append([f])
        return [self._load_run(r) for r in runs]

    def _empty_shard(self) -> Tuple[Dict[str, np.ndarray], int]:
        schema = self.dataset.schema
        return {c: np.zeros((0,) + schema[c].trailing, schema[c].np_dtype)
                for c in self.out_columns}, 0

    def _shard_table(self, frags: Sequence[Fragment],
                     capacity: int) -> Tuple[Table, int]:
        """Concatenate a shard's fragments (original row order), truncate
        at ``capacity`` per the §2 count-and-drop contract."""
        parts = self._load_fragments(frags) if frags else []
        if not parts:
            cols, n = self._empty_shard()
        else:
            n = sum(pn for _, pn in parts)
            cols = {c: np.concatenate([pc[c] for pc, _ in parts], axis=0)
                    for c in self.out_columns}
        overflow = max(0, n - capacity)
        if overflow:
            cols = {k: v[:capacity] for k, v in cols.items()}
            n = capacity
            self.stats.rows_overflowed += overflow
        jcols = {k: _to_jax_column(k, v, self.allow_narrowing)
                 for k, v in cols.items()}
        return Table.from_arrays(jcols, num_rows=n, capacity=capacity), \
            overflow

    def to_dist_table(self) -> Tuple[DistTable, int]:
        """Materialize the whole scan → ``(DistTable, overflow)``."""
        self._reset_io_stats()
        overflow = 0
        tables = []
        with telemetry.span("io.scan.materialize",
                            shards=self.ctx.n_shards) as sp:
            for frags in self._by_shard:
                t, ov = self._shard_table(frags, self.shard_capacity)
                tables.append(t)
                overflow += ov
            dt = DistTable.from_shard_tables(tables, self.ctx,
                                             partitioning=self._partitioning)
            sp.block(dt)
            sp.attrs["rows"] = self.stats.rows_selected
            sp.attrs["overflow"] = overflow
        if self.quarantined:
            self._write_quarantine_manifest()
        rec = telemetry.current()
        if rec is not None:
            rec.record_scan(self.stats)
            telemetry.publish_pressure(rec, "scan")
        return dt, overflow

    def chunks(self):
        """Chunked form: lazily yield one DistTable per fragment *round*.

        Round ``r`` holds every shard's ``r``-th surviving fragment (or an
        empty block), sized to that round's largest fragment.  The
        generator loads one round at a time, so iterating and processing
        chunk-by-chunk keeps the I/O working set at one fragment round
        (paper Fig 5); a consumer that collects all chunks (``TSet``
        sources, barrier operators) bounds per-*operator* state by the
        chunk size but holds the chunk list itself.  Chunks inherit the
        partitioned-re-entry metadata, so a downstream combiner barrier
        can elide its merge shuffle.
        """
        self._reset_io_stats()
        rounds = max((len(fr) for fr in self._by_shard), default=0)
        for r in range(rounds):
            frags = [fr[r] if r < len(fr) else None
                     for fr in self._by_shard]
            cap = max((f.rows for f in frags if f is not None), default=1)
            cap = max(cap, 1)
            tables = []
            for f in frags:
                if f is None:
                    cols, n = self._empty_shard()
                    jcols = {k: _to_jax_column(k, v, self.allow_narrowing)
                             for k, v in cols.items()}
                    tables.append(Table.from_arrays(jcols, num_rows=0,
                                                    capacity=cap))
                else:
                    t, _ = self._shard_table([f], cap)
                    tables.append(t)
            yield DistTable.from_shard_tables(
                tables, self.ctx, partitioning=self._partitioning)

    def to_tset(self):
        """The TSet bridge for out-of-core dataflow pipelines."""
        from repro.core.dataflow import TSet

        return TSet.from_scan(self)


def read_dataset(path: str, *, ctx, columns: Optional[Sequence[str]] = None,
                 predicate=None, capacity: Optional[int] = None,
                 bucket_factor: float = 1.0, allow_narrowing: bool = False,
                 on_error: str = "raise", policy=None,
                 ) -> Tuple[DistTable, int, ScanStats]:
    """One-call scan: ``(DistTable, overflow, stats)``."""
    src = ScanSource(path, ctx=ctx, columns=columns, predicate=predicate,
                     capacity=capacity, bucket_factor=bucket_factor,
                     allow_narrowing=allow_narrowing, on_error=on_error,
                     policy=policy)
    dt, overflow = src.to_dist_table()
    return dt, overflow, src.stats


# ---------------------------------------------------------------------------
# host → jax dtype boundary
# ---------------------------------------------------------------------------
_NARROW = {"int64": np.int32, "uint64": np.uint32, "float64": np.float32}


def _to_jax_column(name: str, arr: np.ndarray, allow_narrowing: bool):
    """Move a host column into jax, refusing silent 64→32-bit data loss.

    With jax x64 disabled (the default), ``jnp.asarray`` would silently
    narrow 64-bit columns.  We narrow explicitly and — unless
    ``allow_narrowing`` — verify the round trip is lossless, raising an
    eager, named error otherwise (the storage layer never corrupts
    silently, DESIGN.md §2/§5).
    """
    import jax
    import jax.numpy as jnp

    if arr.dtype.name in _NARROW and not jax.config.jax_enable_x64:
        cast = arr.astype(_NARROW[arr.dtype.name])
        if not allow_narrowing:
            back = cast.astype(arr.dtype)
            lossless = (np.array_equal(back, arr, equal_nan=True)
                        if arr.dtype.kind == "f"
                        else np.array_equal(back, arr))
            if not lossless:
                raise ValueError(
                    f"column {name!r} ({arr.dtype}) does not fit "
                    f"{np.dtype(_NARROW[arr.dtype.name]).name} and jax x64 "
                    f"is disabled — enable jax_enable_x64, cast the data, "
                    f"or pass allow_narrowing=True to accept the loss")
        arr = cast
    return jnp.asarray(arr)
