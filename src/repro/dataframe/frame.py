"""Cylon-style eager DataFrame API over the HPTMT table operators.

Global-view programming (paper §V-B): the user manipulates one logical
DataFrame; operators run SPMD over the context's mesh.  ``to_numpy()`` /
``to_jax()`` are the zero-ceremony bridges to array-operator code
(paper Figs 13/17 interop).
"""
from __future__ import annotations

import math
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import DistTable, HPTMTContext, Table, table_ops
from repro.core.report import OverflowError, OverflowReport


def _publish_report(report: OverflowReport) -> OverflowReport:
    """Mirror a lineage report into the active telemetry collector (a
    no-op when telemetry is off).  Gauge semantics make re-publishing a
    cumulative lineage idempotent — overflow shows up in the metrics
    dump under the same dotted labels the report itself uses."""
    from repro import telemetry

    rec = telemetry.current()
    if rec is not None:
        rec.record_overflow(report)
    return report


def _spill_mode(spill: object) -> object:
    """Validate the ``spill=`` tri-state eagerly, naming the bad value."""
    if spill not in (False, True, "auto"):
        raise ValueError(
            f"spill={spill!r}: expected False (in-memory, overflow "
            f"raises), 'auto' (spill when the budget or an overflow "
            f"demands it), or True (force the out-of-core path)")
    return spill


class DataFrame:
    """``spill=`` on join/groupby/window/agg-style operators selects the
    out-of-core path (DESIGN.md §10): ``False`` keeps the all-in-memory
    behavior (overflow raises), ``"auto"`` pre-checks the input against
    ``budget_rows`` and — when the in-memory attempt still overflows —
    retries once through the spill engine, and ``True`` forces spill.
    Every operator's overflow lands in :attr:`overflow_report`, the one
    exactness certificate for the whole lineage.
    """

    def __init__(self, table: DistTable, ctx: HPTMTContext,
                 report: Optional[OverflowReport] = None):
        self._t = table
        self._ctx = ctx
        self._report = report if report is not None else OverflowReport()

    @property
    def overflow_report(self) -> OverflowReport:
        """Unified overflow accounting across this frame's lineage."""
        return self._report

    # -- construction ----------------------------------------------------
    @classmethod
    def from_dict(cls, data: Dict[str, np.ndarray], ctx: HPTMTContext,
                  capacity: Optional[int] = None,
                  bucket_factor: float = 1.0) -> "DataFrame":
        """Build a DataFrame, block-partitioned over the context's shards.

        ``bucket_factor`` over-allocates each shard's capacity beyond
        ``capacity`` (or the exact ``ceil(rows / n_shards)`` default) so
        that a *later* shuffle (join, groupby, sort) has head-room for
        hash skew — without it, a shard receiving more than its exact
        share overflows at the operator and raises.  A
        ``capacity``/``bucket_factor`` too small to hold the input rows
        themselves is rejected eagerly here, at the API layer, instead of
        silently truncating inside ``DistTable.from_local``.
        """
        lengths = {k: np.shape(v)[0] if np.ndim(v) else 0
                   for k, v in data.items()}
        if len(set(lengths.values())) > 1:
            common = max(set(lengths.values()),
                         key=lambda n: sum(v == n for v in lengths.values()))
            ragged = sorted(f"{k} has {n} rows" for k, n in lengths.items()
                            if n != common)
            raise ValueError(
                f"ragged column lengths: {ragged} vs {common} rows in the "
                f"other column(s) — every column must have the same length")
        cols = {k: jnp.asarray(v) for k, v in data.items()}
        t = Table.from_arrays(cols)
        per = math.ceil(
            (capacity or -(-t.capacity // ctx.n_shards)) * bucket_factor)
        if per * ctx.n_shards < t.capacity:
            raise ValueError(
                f"per-shard capacity {per} x {ctx.n_shards} shards cannot "
                f"hold {t.capacity} rows — raise capacity or bucket_factor")
        return cls(DistTable.from_local(t, ctx, capacity=per), ctx)

    # -- storage & Arrow interop (repro.io, DESIGN.md §5) -----------------
    @classmethod
    def read_parquet(cls, path: str, ctx: HPTMTContext, *,
                     columns: Optional[Sequence[str]] = None,
                     predicate=None, capacity: Optional[int] = None,
                     bucket_factor: float = 1.0,
                     allow_narrowing: bool = False,
                     strict: bool = True) -> "DataFrame":
        """Scan an on-disk dataset (Parquet or native ``.hpt`` — format
        auto-detected) with projection + predicate pushdown.

        A dataset written with ``partition_by`` re-enters with its
        ``partitioning`` metadata attached when the context matches, so a
        following ``join``/``groupby`` on the partition keys moves no data
        (DESIGN.md §5.3).

        ``strict=False`` records a capacity overflow under
        ``"scan.capacity"`` in the frame's :attr:`overflow_report`
        instead of raising — the caller owns the exactness decision.
        """
        from repro.io import read_dataset

        dt, overflow, _ = read_dataset(
            path, ctx=ctx, columns=columns, predicate=predicate,
            capacity=capacity, bucket_factor=bucket_factor,
            allow_narrowing=allow_narrowing)
        if strict:
            cls._check(overflow, "scan")
        return cls(dt, ctx, _publish_report(
            OverflowReport().add("scan.capacity", overflow)))

    read_dataset = read_parquet  # format-neutral alias

    def to_parquet(self, path: str, *,
                   partition_by: Optional[Sequence[str]] = None,
                   rows_per_group: Optional[int] = None,
                   format: Optional[str] = "parquet") -> "DataFrame":
        """Write as a sharded Parquet dataset (``format="hpt"`` for the
        dependency-free native container; ``None``/"auto" picks parquet
        when pyarrow is available).

        ``partition_by`` hash-shuffles rows first (elided when already
        partitioned) and records the layout in the dataset manifest, so a
        later :meth:`read_parquet` on a matching context restores the
        shuffle-elision evidence.
        """
        from repro.io import write_dist_table

        overflow = write_dist_table(self._t, path, ctx=self._ctx,
                                    format=format, partition_by=partition_by,
                                    rows_per_group=rows_per_group)
        self._check(overflow, "to_parquet")
        return self

    def to_hpt(self, path: str, *,
               partition_by: Optional[Sequence[str]] = None,
               rows_per_group: Optional[int] = None) -> "DataFrame":
        return self.to_parquet(path, partition_by=partition_by,
                               rows_per_group=rows_per_group, format="hpt")

    @classmethod
    def from_arrow(cls, arrow_table, ctx: HPTMTContext,
                   capacity: Optional[int] = None,
                   bucket_factor: float = 1.0) -> "DataFrame":
        """Ingest a pyarrow Table (zero-copy columns, nulls rejected
        eagerly — repro.io.arrow)."""
        from repro.io import from_arrow as _from_arrow

        cols, _ = _from_arrow(arrow_table)
        return cls.from_dict(cols, ctx, capacity=capacity,
                             bucket_factor=bucket_factor)

    def to_arrow(self):
        """Materialize valid rows as a pyarrow Table (paper §VI interop)."""
        from repro.io import to_arrow as _to_arrow

        return _to_arrow(self.to_numpy())

    # -- metadata ------------------------------------------------------------
    @property
    def columns(self) -> Tuple[str, ...]:
        return self._t.column_names

    def __len__(self) -> int:
        return int(self._t.num_rows())

    @property
    def table(self) -> DistTable:
        return self._t

    @property
    def partitioning(self):
        """The layout evidence tuple: ``(hash_keys, n_shards)`` after a
        hash exchange, ``("range", keys, ascending, n_shards)`` after an
        orderby/range repartition, else None.

        Operators on matching keys skip their shuffle entirely — hash
        layouts feed join/groupby/set ops (DESIGN.md §4), range layouts
        feed window/rank/quantile/orderby (DESIGN.md §9).
        """
        return self._t.partitioning

    @property
    def partitioning_kind(self):
        """``"hash"``, ``"range"``, or ``None`` — the layout kind."""
        from repro.core import partitioning_kind

        return partitioning_kind(self._t.partitioning)

    # -- relational operators (eager) ------------------------------------------
    def select(self, predicate: Callable) -> "DataFrame":
        return self._child(table_ops.select(self._t, predicate,
                                            ctx=self._ctx))

    def project(self, cols: Sequence[str]) -> "DataFrame":
        return self._child(table_ops.project(self._t, cols, ctx=self._ctx))

    def join(self, other: "DataFrame", on: Sequence[str], how: str = "inner",
             *, method: str = "auto", max_matches: int = 1,
             spill: object = False, budget_rows: Optional[int] = None,
             spill_workdir: Optional[str] = None, **kw) -> "DataFrame":
        """Equi-join on ``on``; ``how`` is inner/left/right/outer.

        ``method`` picks the local join kernel — ``"hash"`` (sort-free
        build/probe, the ``"auto"`` choice), or ``"sort"`` (sort-merge
        oracle) — and ``max_matches`` bounds the fan-out per left row;
        matches beyond it count as overflow and raise here (DESIGN.md §8).
        Unknown values are rejected eagerly, before any tracing, by
        ``table_ops.join`` with a ValueError naming the offending kwarg.

        ``spill="auto"`` spills to disk when either input exceeds
        ``n_shards * budget_rows`` rows (or, lacking a budget, when the
        in-memory attempt overflows); ``spill=True`` forces the
        out-of-core path (DESIGN.md §10).  Extra keyword arguments apply
        to the in-memory path only.
        """
        from repro.spill import should_spill, spill_join

        _spill_mode(spill)
        budget = budget_rows or max(self._t.capacity, other._t.capacity)
        ns = self._ctx.n_shards

        def _spilled() -> "DataFrame":
            return self._from_spill(
                spill_join(self._t, other._t, on, ctx=self._ctx,
                           budget_rows=budget, how=how, method=method,
                           max_matches=max_matches,
                           max_probes=kw.get("max_probes"),
                           workdir=spill_workdir), other)

        if spill is True or (spill == "auto" and budget_rows is not None and
                             (should_spill(len(self), ns, budget_rows) or
                              should_spill(len(other), ns, budget_rows))):
            return _spilled()
        out, ov = table_ops.join(self._t, other._t, on, ctx=self._ctx,
                                 how=how, method=method,
                                 max_matches=max_matches, **kw)
        if int(ov) != 0 and spill == "auto":
            return _spilled()
        self._check(ov, "join")
        return self._child(out, other)

    def groupby(self, keys: Sequence[str],
                aggs: Sequence[Tuple[str, str]], *,
                spill: object = False, budget_rows: Optional[int] = None,
                spill_workdir: Optional[str] = None, **kw) -> "DataFrame":
        """Hash-aggregate ``aggs`` per distinct ``keys`` combination.

        ``spill="auto"``/``spill=True``/``budget_rows`` select the
        out-of-core path exactly as in :meth:`join` (DESIGN.md §10).
        """
        from repro.spill import should_spill, spill_groupby

        _spill_mode(spill)
        budget = budget_rows or self._t.capacity

        def _spilled() -> "DataFrame":
            return self._from_spill(
                spill_groupby(self._t, keys, aggs, ctx=self._ctx,
                              budget_rows=budget, workdir=spill_workdir))

        if spill is True or (spill == "auto" and budget_rows is not None and
                             should_spill(len(self), self._ctx.n_shards,
                                          budget_rows)):
            return _spilled()
        out, ov = table_ops.groupby_aggregate(self._t, keys, aggs,
                                              ctx=self._ctx, **kw)
        if int(ov) != 0 and spill == "auto":
            return _spilled()
        self._check(ov, "groupby")
        return self._child(out)

    def repartition(self, keys: Sequence[str], mode: str = "hash",
                    ascending=True, **kw) -> "DataFrame":
        """Re-distribute rows: ``mode="hash"`` co-locates equal ``keys`` on
        a shard (Fig 2); ``mode="range"`` globally sorts by ``keys`` via
        the sample-sort exchange (DESIGN.md §9) — contiguous key ranges
        per shard, locally sorted.

        Either way the result records its layout (see
        :attr:`partitioning` / :attr:`partitioning_kind`), so chained
        operators on the same keys elide their shuffles.  A no-op when
        the layout already holds.  Unknown modes and key columns are
        rejected eagerly with a ValueError naming the offending kwarg.
        """
        if mode not in ("hash", "range"):
            raise ValueError(f"unknown repartition mode={mode!r}; "
                             f"expected 'hash' or 'range'")
        keys = (keys,) if isinstance(keys, str) else tuple(keys)
        missing = [k for k in keys if k not in self.columns]
        if missing:
            raise ValueError(f"keys= names unknown column(s) {missing}; "
                             f"table has {sorted(self.columns)}")
        if mode == "range":
            return self.sort_values(list(keys), ascending=ascending, **kw)
        out, ov = table_ops.shuffle(self._t, keys, ctx=self._ctx, **kw)
        self._check(ov, "shuffle")
        return self._child(out)

    def sort_values(self, by, ascending=True, **kw) -> "DataFrame":
        """Globally sort by one or more columns (multi-key sample sort;
        per-key ``ascending``, NaNs always last — DESIGN.md §9)."""
        out, ov = table_ops.orderby(self._t, by, ctx=self._ctx,
                                    ascending=ascending, **kw)
        self._check(ov, "orderby")
        return self._child(out)

    def window(self, partition_by, order_by, ascending=True) -> "Window":
        """SQL-style window builder: ``df.window(["g"], ["t"]).agg([...],
        rows=32)`` — see :meth:`Window.agg`."""
        return Window(self, partition_by, order_by, ascending)

    def rank(self, partition_by, order_by, ascending=True,
             **kw) -> "DataFrame":
        """Add ``rank`` and ``row_number`` columns per partition/order."""
        out, ov = table_ops.rank(self._t, partition_by, order_by,
                                 ctx=self._ctx, ascending=ascending, **kw)
        self._check(ov, "rank")
        return self._child(out)

    def topk(self, by, k: int, largest: bool = True, **kw) -> "DataFrame":
        """The global top-``k`` rows by ``by`` — per-shard candidates
        tree-reduced over ppermute rounds, no global sort (DESIGN.md §9)."""
        return self._child(table_ops.topk(self._t, by, k, ctx=self._ctx,
                                          largest=largest, **kw))

    def quantile(self, column: str, qs, method: str = "auto", **kw):
        """Quantiles of ``column`` (numpy ``nanquantile`` semantics).

        Scalar ``qs`` returns a float; a sequence returns a numpy array.
        ``method="exact"`` is free of extra exchanges on a range-sorted
        input; ``"approx"`` is the splitter-sample sketch (DESIGN.md §9).
        """
        out = table_ops.quantile(self._t, column, qs, ctx=self._ctx,
                                 method=method, **kw)
        arr = np.asarray(out)
        scalar = np.isscalar(qs) and not isinstance(qs, (str, bytes))
        return float(arr[0]) if scalar else arr

    def union(self, other: "DataFrame", **kw) -> "DataFrame":
        out, ov = table_ops.union(self._t, other._t, ctx=self._ctx, **kw)
        self._check(ov, "union")
        return self._child(out, other)

    def difference(self, other: "DataFrame", **kw) -> "DataFrame":
        out, ov = table_ops.difference(self._t, other._t, ctx=self._ctx, **kw)
        self._check(ov, "difference")
        return self._child(out, other)

    def intersect(self, other: "DataFrame", **kw) -> "DataFrame":
        out, ov = table_ops.intersect(self._t, other._t, ctx=self._ctx, **kw)
        self._check(ov, "intersect")
        return self._child(out, other)

    def agg(self, column: str, op: str):
        return float(table_ops.aggregate(self._t, column, op, ctx=self._ctx))

    # -- lazy planning (repro.plan, DESIGN.md §11) ---------------------------
    def lazy(self, name: str = "table"):
        """Start a lazy expression graph rooted at this frame's table.

        Chained operators on the returned :class:`~repro.plan.LazyFrame`
        only build a logical plan; ``.collect()`` optimizes it
        (predicate/projection pushdown, chained exchange elision, join
        reordering, global layout choice) and runs the whole pipeline as
        ONE traced program — bit-exact vs the eager chain, never more
        collectives.  ``.explain()`` shows the plan without running it.
        """
        from repro.plan import LazyFrame
        from repro.plan.logical import source

        return LazyFrame(source(self._t, name), self._ctx, self._report)

    # -- interop bridges ----------------------------------------------------
    def to_numpy(self) -> Dict[str, np.ndarray]:
        return self._t.to_numpy()

    def to_jax(self, columns: Optional[Sequence[str]] = None) -> jnp.ndarray:
        """Stack numeric columns into a dense (rows, cols) matrix."""
        data = self.to_numpy()
        cols = columns or sorted(data)
        return jnp.stack([jnp.asarray(data[c], jnp.float32) for c in cols],
                         axis=1)

    # -- spill / overflow plumbing ------------------------------------------
    def _child(self, out: DistTable, *others: "DataFrame") -> "DataFrame":
        """Wrap an operator result, carrying the lineage's overflow report."""
        rep = OverflowReport().merge(self._report)
        for o in others:
            rep.merge(o._report)
        return DataFrame(out, self._ctx, _publish_report(rep))

    def _from_spill(self, res, *others: "DataFrame") -> "DataFrame":
        """Materialize a spilled operator's chunk stream into a DataFrame.

        The spill store is closed (scratch dir removed) before returning;
        any residual loss in the spill report — e.g. join fan-out beyond
        ``max_matches``, which is a semantic cap, not a memory one —
        still raises, exactly as the in-memory path would.
        """
        from repro.core.dataflow import _concat_chunks

        with res:
            chunks = list(res.chunks()) or [res.empty_chunk()]
            res.report.assert_exact()
            rep = OverflowReport().merge(self._report)
            for o in others:
                rep.merge(o._report)
            rep.merge(res.report)
            out = _concat_chunks(chunks, self._ctx)
        return DataFrame(out, self._ctx, _publish_report(rep))

    @staticmethod
    def _check(overflow, op: str) -> None:
        if int(overflow) != 0:
            raise OverflowError(
                f"{op}: {int(overflow)} rows overflowed static capacity — "
                "re-run with a larger out_capacity/bucket_factor, or pass "
                "spill='auto' to recover out-of-core")


class Window:
    """Bound ``(partition_by, order_by)`` spec, built by
    :meth:`DataFrame.window`; ``.agg(...)`` evaluates window functions."""

    def __init__(self, df: DataFrame, partition_by, order_by, ascending):
        self._df = df
        self._partition_by = partition_by
        self._order_by = order_by
        self._ascending = ascending

    def agg(self, aggs, rows: Optional[int] = None, *,
            spill: object = False, budget_rows: Optional[int] = None,
            spill_workdir: Optional[str] = None, **kw) -> DataFrame:
        """Evaluate window aggregates; returns the DataFrame plus one
        column per agg (rows never move or drop).

        ``aggs`` entries: ``(col, op)`` with op in
        sum/mean/count/min/max (over a trailing window of ``rows`` rows,
        or cumulative when ``rows=None``), ``(col, "lag"/"lead",
        offset)``, and ``(None, "row_number"/"rank")``.  Already-sorted
        inputs (``sort_values`` on ``partition_by + order_by``) evaluate
        with zero additional data movement (DESIGN.md §9); unknown ops,
        columns, offsets and label collisions raise eagerly with the
        offending entry named.

        ``spill="auto"``/``spill=True``/``budget_rows`` select the
        out-of-core path (DESIGN.md §10): window partitions spill whole
        to disk and re-enter pre-sorted, so no window is ever truncated
        by the cross-shard halo.
        """
        from repro.spill import should_spill, spill_window

        df = self._df
        _spill_mode(spill)
        budget = budget_rows or df._t.capacity

        def _spilled() -> DataFrame:
            return df._from_spill(spill_window(
                df._t, self._partition_by, self._order_by, aggs,
                ctx=df._ctx, budget_rows=budget, rows=rows,
                ascending=self._ascending, workdir=spill_workdir))

        if spill is True or (spill == "auto" and budget_rows is not None and
                             should_spill(len(df), df._ctx.n_shards,
                                          budget_rows)):
            return _spilled()
        out, ov = table_ops.window_aggregate(
            df._t, self._partition_by, self._order_by, aggs,
            ctx=df._ctx, rows=rows, ascending=self._ascending, **kw)
        if int(ov) != 0 and spill == "auto":
            return _spilled()
        DataFrame._check(ov, "window")
        return df._child(out)
