"""Run-history ledger: one JSONL record per collect/bench run
(DESIGN.md §14.3).

Every record is keyed by the run's **plan fingerprint** — the same
deterministic identity stage checkpoints use (``resilience.stages.
plan_fingerprint``: canonical logical tree + shard count) — so runs of
the same pipeline over the same data land under one key across
processes, machines and days, and ``scripts/perf_report.py`` can chart
per-fingerprint deltas and flag regressions (>30% wall time, >2x
q-error drift) instead of comparing apples to oranges.  Bench cases use
the synthetic key ``bench:<case>`` (their identity is the case name).

Record schema (one JSON object per line, append-only)::

    {"fingerprint": "...", "kind": "collect" | "bench",
     "ts": <unix seconds>, "wall_s": <float>,
     "max_qerror": <float | null>, "qerrors": {"<step>": q, ...},
     "peak_rss_mb": <float | null>, "steps": <n | null>,
     "predicted_a2a": <n | null>, "observed_a2a": <n | null>,
     "audit_consistent": <bool | null>,
     "counters": {...}, "gauges": {...},       # metrics snapshot
     "derived": "..."}                          # bench flavor text

Appends are line-atomic (single ``write`` of one line, O_APPEND), so
concurrent benchers interleave whole records, never tear one.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List, Optional


def append(path: str, record: Dict[str, Any]) -> None:
    """Append one record as a single JSONL line (parent dirs created)."""
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    line = json.dumps(record, sort_keys=True, default=repr)
    with open(path, "a") as f:
        f.write(line + "\n")


def read(path: str) -> List[Dict[str, Any]]:
    """All records in file order; a torn/garbage trailing line (crash
    mid-append on a non-atomic filesystem) is skipped, not fatal."""
    out: List[Dict[str, Any]] = []
    if not os.path.exists(path):
        return out
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                out.append(json.loads(line))
            except json.JSONDecodeError:
                continue
    return out


def collect_record(rec, *, fingerprint: str, wall_s: float,
                   kind: str = "collect",
                   extra: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Build the ledger record for one ``collect()`` run.

    ``rec`` is the run's :class:`~repro.telemetry.record.Collector`, or
    ``None`` for an un-instrumented collect (then only identity + wall
    time are recorded — still enough for the time-regression screen).
    """
    from .memory import peak_rss_kb

    out: Dict[str, Any] = {
        "fingerprint": fingerprint, "kind": kind,
        "ts": round(time.time(), 3), "wall_s": round(float(wall_s), 6),
        "max_qerror": None, "qerrors": {}, "peak_rss_mb": None,
        "steps": None, "predicted_a2a": None, "observed_a2a": None,
        "audit_consistent": None, "counters": {}, "gauges": {},
    }
    peak = peak_rss_kb()
    if peak is not None:
        out["peak_rss_mb"] = round(peak / 1024.0, 1)
    if rec is not None:
        out["counters"] = dict(sorted(rec.metrics.counters.items()))
        out["gauges"] = dict(sorted(rec.metrics.gauges.items()))
        out["steps"] = len(rec.plan_steps) or None
        qs = {str(i): round(f["qerr"], 3)
              for i, f in rec.plan_steps.items() if "qerr" in f}
        out["qerrors"] = qs
        if qs:
            out["max_qerror"] = max(qs.values())
        if rec.audits:
            a = rec.audits[-1]
            out["predicted_a2a"] = a.get("predicted_a2a")
            out["observed_a2a"] = a.get("observed_a2a")
            out["audit_consistent"] = a.get("consistent")
    if extra:
        out.update(extra)
    return out


def bench_record(name: str, us_per_call: float, derived: str = "",
                 peak_rss_mb: Optional[float] = None,
                 telemetry: Optional[Dict[str, Any]] = None
                 ) -> Dict[str, Any]:
    """Ledger record for one benchmark case (key ``bench:<name>``)."""
    out: Dict[str, Any] = {
        "fingerprint": f"bench:{name}", "kind": "bench",
        "ts": round(time.time(), 3),
        "wall_s": round(us_per_call * 1e-6, 6),
        "max_qerror": None, "qerrors": {}, "derived": derived,
        "peak_rss_mb": peak_rss_mb,
    }
    if telemetry:
        out["observed_a2a"] = sum(
            telemetry.get("collectives", {}).values()) or None
    return out
