"""Span/metrics recorder — the runtime half of the telemetry layer.

One module-level active :class:`Collector` (or ``None``, the default).
Every instrumentation site in the repo follows the same two-gate rule:

  * **off-by-default** — when no collector is active, the site is one
    global ``None`` check (:func:`span` returns the shared no-op span);
    nothing allocates, nothing times, nothing blocks.
  * **host-clock honesty** — spans never materialize inside a jax trace
    (:func:`tracing` gates every open).  A span that wraps device work
    calls ``block_until_ready`` on its outputs before stamping its
    duration, so jit's async dispatch cannot make an operator look free.

Spans form a tree (``Collector._stack``); metrics are flat counters and
gauges under dotted names, matching the :class:`~repro.core.report.
OverflowReport` label convention (DESIGN.md §12).
"""
from __future__ import annotations

import contextlib
import functools
import time
from typing import Any, Dict, List, Optional


def tracing() -> bool:
    """True while jax is tracing — spans must not materialize then."""
    try:
        import jax.core

        return not jax.core.trace_state_clean()
    except Exception:  # unknown jax internals: assume unsafe, skip spans
        return True


class Span:
    """One timed region: name + attrs + children, µs since trace start."""

    __slots__ = ("name", "attrs", "t0_us", "dur_us", "children")

    def __init__(self, name: str, attrs: Dict[str, Any]):
        self.name = name
        self.attrs = attrs
        self.t0_us = 0.0
        self.dur_us = 0.0
        self.children: List["Span"] = []

    def block(self, value) -> None:
        """Wait for ``value`` (any pytree of jax arrays) before the span
        closes — the async-dispatch honesty rule."""
        import jax

        jax.block_until_ready(value)

    def walk(self):
        yield self
        for c in self.children:
            yield from c.walk()

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, {self.dur_us:.0f}us, "
                f"{len(self.children)} children)")


class _NullSpan:
    """Shared no-op span: every method is free, attrs go nowhere."""

    __slots__ = ("attrs",)
    name = "null"
    t0_us = dur_us = 0.0
    children: List[Span] = []

    def __init__(self):
        self.attrs: Dict[str, Any] = {}

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        self.attrs = {}

    def block(self, value) -> None:
        pass


_NULL = _NullSpan()


class Metrics:
    """Flat dotted-name registry: counters accumulate, gauges overwrite."""

    def __init__(self):
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}

    def count(self, name: str, value: float = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + value

    def gauge(self, name: str, value: float) -> None:
        self.gauges[name] = value

    def merge(self, other: "Metrics") -> "Metrics":
        for k, v in other.counters.items():
            self.count(k, v)
        self.gauges.update(other.gauges)
        return self

    def as_dict(self) -> Dict[str, Dict[str, float]]:
        return {"counters": dict(sorted(self.counters.items())),
                "gauges": dict(sorted(self.gauges.items()))}


class _SpanCtx:
    """Context manager that opens/closes one span on a collector."""

    __slots__ = ("_rec", "_span", "_pending")

    def __init__(self, rec: "Collector", sp: Span):
        self._rec = rec
        self._span = sp

    def __enter__(self) -> Span:
        sp = self._span
        sp.t0_us = (time.perf_counter() - self._rec.epoch) * 1e6
        self._rec._stack.append(sp)
        return sp

    def __exit__(self, *exc) -> None:
        sp = self._rec._stack.pop()
        sp.dur_us = (time.perf_counter() - self._rec.epoch) * 1e6 - sp.t0_us


class Collector:
    """One trace session: a span tree + metrics + plan/exchange audits."""

    def __init__(self, name: str = "trace"):
        self.name = name
        self.epoch = time.perf_counter()
        self.spans: List[Span] = []
        self.metrics = Metrics()
        self.audits: List[Dict[str, Any]] = []
        self.plan_steps: Dict[int, Dict[str, Any]] = {}
        self._stack: List[Span] = []

    def span(self, name: str, **attrs):
        """Open a child span of the innermost open span (no-op while jax
        is tracing: host clocks lie there)."""
        if tracing():
            return _NULL
        sp = Span(name, attrs)
        parent = self._stack[-1] if self._stack else None
        (parent.children if parent is not None else self.spans).append(sp)
        return _SpanCtx(self, sp)

    def all_spans(self):
        for root in self.spans:
            yield from root.walk()

    # -- runtime-fact bridges (dynamic metrics source) ---------------------
    def record_overflow(self, report) -> None:
        """Expose an :class:`OverflowReport` lineage under its own dotted
        labels.  Gauges, not counters: lineage reports are cumulative, so
        the latest value IS the lineage total (re-recording a child's
        report never double-counts)."""
        for k, v in report.to_metrics().items():
            self.metrics.gauge(k, v)

    def record_scan(self, stats) -> None:
        """Absorb a :class:`~repro.io.scan.ScanStats` into ``scan.*``."""
        for k, v in vars(stats).items():
            self.metrics.count(f"scan.{k}", v)

    def record_audit(self, audit: Dict[str, Any]) -> None:
        self.audits.append(audit)

    def observe_step(self, index: int, **facts) -> None:
        """Per-physical-node runtime facts (plan.physical instrumentation);
        keyed by step index so ``explain(analyze=True)`` can join them."""
        self.plan_steps.setdefault(index, {}).update(facts)


# ---------------------------------------------------------------------------
# module-level state: the off-by-default switch
# ---------------------------------------------------------------------------
_ACTIVE: Optional[Collector] = None


def current() -> Optional[Collector]:
    """The active collector, or ``None`` (telemetry off — the default)."""
    return _ACTIVE


@contextlib.contextmanager
def trace(name: str = "trace"):
    """Activate a fresh :class:`Collector` for the ``with`` body.

    Nested traces stack: the innermost collector receives the spans; the
    outer one resumes when the inner block exits.
    """
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, Collector(name)
    try:
        yield _ACTIVE
    finally:
        _ACTIVE = prev


@contextlib.contextmanager
def using(rec: Collector):
    """Activate an EXISTING collector for the ``with`` body (the
    ``collect(telemetry=rec)`` path: the caller owns the collector and
    may activate it across several pipelines)."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, rec
    try:
        yield rec
    finally:
        _ACTIVE = prev


def span(name: str, **attrs):
    """Open a span on the active collector — the shared no-op when
    telemetry is off or jax is tracing."""
    rec = _ACTIVE
    if rec is None:
        return _NULL
    return rec.span(name, **attrs)


def traced(name: Optional[str] = None, **attrs):
    """Decorator form: run the function under a span, blocking on its
    result so device work is charged to the span that launched it."""

    def wrap(fn):
        label = name if name is not None else fn.__qualname__

        @functools.wraps(fn)
        def inner(*args, **kwargs):
            rec = _ACTIVE
            if rec is None:
                return fn(*args, **kwargs)
            with rec.span(label, **attrs) as sp:
                out = fn(*args, **kwargs)
                sp.block(out)
            return out

        return inner

    return wrap


def _rows_of(value) -> Optional[int]:
    """Row count of the first table-like element of a value, if any."""
    items = value if isinstance(value, (tuple, list)) else (value,)
    for v in items:
        if hasattr(v, "num_rows"):
            try:
                n = v.num_rows
                return int(n() if callable(n) else n)
            except Exception:
                return None
    return None


def operator_call(name: str, fn, args, kwargs):
    """Span-wrapped operator invocation (the ``@operator`` hook).

    Only runs when a collector is active; skips entirely under tracing so
    operators called inside a jit region stay unperturbed.  Closes with
    ``block_until_ready`` on the outputs and records rows in/out both as
    span attrs and as ``<name>.rows_*`` counters.
    """
    rec = _ACTIVE
    if rec is None or tracing():
        return fn(*args, **kwargs)
    with rec.span(name) as sp:
        out = fn(*args, **kwargs)
        sp.block(out)
        rows_in = _rows_of(args)
        rows_out = _rows_of(out)
        if rows_in is not None:
            sp.attrs["rows_in"] = rows_in
            rec.metrics.count(f"{name}.rows_in", rows_in)
        if rows_out is not None:
            sp.attrs["rows_out"] = rows_out
            rec.metrics.count(f"{name}.rows_out", rows_out)
        rec.metrics.count(f"{name}.calls", 1)
    return out
