"""Exporters: Chrome ``trace_event`` JSON and a flat metrics dump.

``export_chrome_trace`` writes the span tree in the Trace Event Format,
loadable by Perfetto / ``chrome://tracing``: complete ``"ph": "X"``
events for spans, ``"ph": "C"`` counter tracks for every gauge, and
``"ph": "M"`` process/thread-name metadata so spans group into one lane
per subsystem phase (``plan.*``, ``spill.*``, ``recovery.*``, ...)
instead of a single flat track.  ``metrics_snapshot`` flattens a
collector — metrics, plan audits, per-step observations — into one
JSON-serializable dict that ``benchmarks/run.py`` attaches to bench
records, so a perf number ships with the collective counts and bytes
that explain it.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List

#: span-name prefixes → one Perfetto lane each (tid 1..n; unknown
#: prefixes share tid 0, the "main" lane)
PHASE_LANES = ("plan", "io", "scan", "spill", "recovery", "workflow",
               "table", "exchange", "bench")


def _jsonable(v):
    try:
        json.dumps(v)
        return v
    except (TypeError, ValueError):
        return repr(v)


def _lane(name: str) -> int:
    prefix = name.split(".", 1)[0]
    try:
        return PHASE_LANES.index(prefix) + 1
    except ValueError:
        return 0


def chrome_trace_events(collector) -> List[Dict[str, Any]]:
    """Span tree + gauges as Trace Event Format events.

    Spans are complete ``X`` events placed on a per-phase lane (tid);
    ``M`` metadata events name the process (the collector) and each used
    lane; every gauge becomes one ``C`` counter sample stamped at the
    trace end so Perfetto renders it as a counter track.
    """
    events: List[Dict[str, Any]] = []
    used_lanes = {0}
    end_ts = 0.0

    def emit(span):
        nonlocal end_ts
        tid = _lane(span.name)
        used_lanes.add(tid)
        end_ts = max(end_ts, span.t0_us + span.dur_us)
        events.append({
            "name": span.name, "ph": "X", "cat": "repro",
            "ts": round(span.t0_us, 3), "dur": round(span.dur_us, 3),
            "pid": 0, "tid": tid,
            "args": {k: _jsonable(v) for k, v in span.attrs.items()},
        })
        for c in span.children:
            emit(c)

    for root in collector.spans:
        emit(root)

    meta: List[Dict[str, Any]] = [{
        "name": "process_name", "ph": "M", "pid": 0, "tid": 0,
        "args": {"name": collector.name}}]
    for tid in sorted(used_lanes):
        lane = "main" if tid == 0 else PHASE_LANES[tid - 1]
        meta.append({"name": "thread_name", "ph": "M", "pid": 0,
                     "tid": tid, "args": {"name": lane}})
        meta.append({"name": "thread_sort_index", "ph": "M", "pid": 0,
                     "tid": tid, "args": {"sort_index": tid}})

    counters = [{
        "name": gname, "ph": "C", "cat": "repro", "pid": 0, "tid": 0,
        "ts": round(end_ts, 3), "args": {"value": _jsonable(v)}}
        for gname, v in sorted(collector.metrics.gauges.items())]
    return meta + events + counters


def export_chrome_trace(collector, path: str) -> str:
    """Write the trace to ``path`` (Perfetto-loadable); returns ``path``."""
    doc = {"traceEvents": chrome_trace_events(collector),
           "displayTimeUnit": "ms",
           "otherData": {"collector": collector.name}}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return path


def metrics_snapshot(collector) -> Dict[str, Any]:
    """Flat JSON-ready view: metrics + audits + per-plan-step facts."""
    return {
        "collector": collector.name,
        "metrics": collector.metrics.as_dict(),
        "audits": [dict(a) for a in collector.audits],
        "plan_steps": {str(i): dict(v)
                       for i, v in sorted(collector.plan_steps.items())},
        "n_spans": sum(1 for _ in collector.all_spans()),
    }


def export_metrics(collector, path: str) -> str:
    with open(path, "w") as f:
        json.dump(metrics_snapshot(collector), f, indent=1, sort_keys=True)
        f.write("\n")
    return path
