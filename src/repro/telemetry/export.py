"""Exporters: Chrome ``trace_event`` JSON and a flat metrics dump.

``export_chrome_trace`` writes the span tree in the Trace Event Format
(complete ``"ph": "X"`` events), loadable by Perfetto / ``chrome://
tracing``.  ``metrics_snapshot`` flattens a collector — metrics, plan
audits, per-step observations — into one JSON-serializable dict that
``benchmarks/run.py`` attaches to bench records, so a perf number ships
with the collective counts and bytes that explain it.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List


def _jsonable(v):
    try:
        json.dumps(v)
        return v
    except (TypeError, ValueError):
        return repr(v)


def chrome_trace_events(collector) -> List[Dict[str, Any]]:
    """The collector's span tree as Trace Event Format complete events."""
    events = []

    def emit(span, depth):
        events.append({
            "name": span.name, "ph": "X", "cat": "repro",
            "ts": round(span.t0_us, 3), "dur": round(span.dur_us, 3),
            "pid": 0, "tid": 0,
            "args": {k: _jsonable(v) for k, v in span.attrs.items()},
        })
        for c in span.children:
            emit(c, depth + 1)

    for root in collector.spans:
        emit(root, 0)
    return events


def export_chrome_trace(collector, path: str) -> str:
    """Write the trace to ``path`` (Perfetto-loadable); returns ``path``."""
    doc = {"traceEvents": chrome_trace_events(collector),
           "displayTimeUnit": "ms",
           "otherData": {"collector": collector.name}}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return path


def metrics_snapshot(collector) -> Dict[str, Any]:
    """Flat JSON-ready view: metrics + audits + per-plan-step facts."""
    return {
        "collector": collector.name,
        "metrics": collector.metrics.as_dict(),
        "audits": [dict(a) for a in collector.audits],
        "plan_steps": {str(i): dict(v)
                       for i, v in sorted(collector.plan_steps.items())},
        "n_spans": sum(1 for _ in collector.all_spans()),
    }


def export_metrics(collector, path: str) -> str:
    with open(path, "w") as f:
        json.dump(metrics_snapshot(collector), f, indent=1, sort_keys=True)
        f.write("\n")
    return path
