"""Unified runtime telemetry: spans, metrics, and collective audits.

The observability layer for the whole operator stack (DESIGN.md §12):

  * :func:`trace` / :func:`span` / :func:`traced` — a hierarchical span
    recorder with host-clock honesty (``block_until_ready`` at span
    close).  Off by default; when no collector is active every
    instrumentation site in the repo is a single ``None`` check.
  * :class:`Collector` ``.metrics`` — counters/gauges fed by runtime
    facts (rows in/out, overflow labels, spill bytes, scan pruning) and
    by static program audits (:mod:`.audit`: jaxpr + compiled HLO
    collective counts and payload bytes).
  * :func:`export_chrome_trace` / :func:`metrics_snapshot` — Perfetto
    trace JSON and the flat dump ``benchmarks/run.py`` attaches to
    bench records.

Typical session::

    from repro import telemetry

    with telemetry.trace() as rec:
        df = lazy_pipeline.collect(telemetry=rec)
    telemetry.export_chrome_trace(rec, "pipeline_trace.json")
"""
from .audit import (JAXPR_PRIMITIVES, compiled_collectives, hlo_collectives,
                    jaxpr_collectives, jaxpr_exchanges, program_audit,
                    top_collectives, trace_collectives)
from .cardinality import (DEFAULT_QERROR_THRESHOLD, CardinalityAuditError,
                          audit_cardinality, q_error, record_qerrors,
                          step_qerrors)
from .export import (chrome_trace_events, export_chrome_trace,
                     export_metrics, metrics_snapshot)
from .ledger import (append as ledger_append, bench_record, collect_record,
                     read as ledger_read)
from .memory import (RssWatermark, peak_rss_kb, publish_pressure,
                     reset_peak_rss, rss_kb, step_live_bytes)
from .record import (Collector, Metrics, Span, current, operator_call, span,
                     trace, traced, tracing, using)

__all__ = [
    "Collector", "Metrics", "Span", "current", "operator_call", "span",
    "trace", "traced", "tracing", "using",
    "JAXPR_PRIMITIVES", "compiled_collectives", "hlo_collectives",
    "jaxpr_collectives", "jaxpr_exchanges", "program_audit",
    "top_collectives", "trace_collectives",
    "chrome_trace_events", "export_chrome_trace", "export_metrics",
    "metrics_snapshot",
    "DEFAULT_QERROR_THRESHOLD", "CardinalityAuditError", "audit_cardinality",
    "q_error", "record_qerrors", "step_qerrors",
    "RssWatermark", "peak_rss_kb", "publish_pressure", "reset_peak_rss",
    "rss_kb", "step_live_bytes",
    "ledger_append", "ledger_read", "bench_record", "collect_record",
]
