"""Static collective audits: what a traced/compiled program WILL do.

The second metrics source (DESIGN.md §12): instead of timing, these
functions read collectives out of program artifacts at two levels —

  * **jaxpr** (:func:`jaxpr_collectives`, :func:`jaxpr_exchanges`):
    counts and payload bytes of ``all_to_all`` / ``all_gather`` /
    ``ppermute`` / ``sort`` equations, walked recursively through
    ``shard_map``/``pjit`` sub-jaxprs in program order.  This is the
    "traced" layer — the exact program jax will hand to XLA.
  * **compiled HLO** (:func:`hlo_collectives`, :func:`top_collectives`):
    the post-optimization executable, parsed with the roofline HLO
    collective parser.  This is the "observed" layer — what actually
    runs, after XLA has had every chance to fuse, split or elide.

The lazy planner's plan-vs-observed audit compares its own prediction
against BOTH (``LazyFrame.collect(telemetry=...)``); the perf CLI and
the benchmark harness reuse the same parsers for their reports.
"""
from __future__ import annotations

import collections
import re
from typing import Any, Dict, List, Optional, Tuple

#: jaxpr primitives worth counting — the exchange (all_to_all), the
#: splitter/broadcast collectives, and the sort the paper's operators
#: are built from.
JAXPR_PRIMITIVES = ("all_to_all", "all_gather", "ppermute", "psum", "sort")


def _iter_eqns(jaxpr):
    """Every equation of a (Closed)Jaxpr, recursing into sub-jaxprs
    carried in params (pjit/shard_map/scan/cond), in program order."""
    inner = getattr(jaxpr, "jaxpr", jaxpr)  # ClosedJaxpr -> Jaxpr
    for eqn in inner.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v):
                yield from _iter_eqns(sub)


def _sub_jaxprs(value):
    if hasattr(value, "eqns") or hasattr(value, "jaxpr"):
        yield value
    elif isinstance(value, (tuple, list)):
        for v in value:
            if hasattr(v, "eqns") or hasattr(v, "jaxpr"):
                yield v


def jaxpr_collectives(closed_jaxpr) -> Dict[str, int]:
    """Counts of :data:`JAXPR_PRIMITIVES` in a traced program."""
    counts = {name: 0 for name in JAXPR_PRIMITIVES}
    for eqn in _iter_eqns(closed_jaxpr):
        name = eqn.primitive.name
        if name in counts:
            counts[name] += 1
    return counts


def _eqn_bytes(eqn) -> int:
    total = 0
    for var in eqn.invars:
        aval = getattr(var, "aval", None)
        if aval is not None and hasattr(aval, "size"):
            total += int(aval.size) * aval.dtype.itemsize
    return total


def jaxpr_exchanges(closed_jaxpr, n_shards: int = 1) -> List[Dict[str, Any]]:
    """Program-order ``all_to_all`` payloads.

    Bytes are GLOBAL: inside ``shard_map`` an equation sees the
    per-shard operand, so the per-shard payload is scaled by
    ``n_shards`` — the total volume the exchange moves across the mesh.
    """
    out = []
    for eqn in _iter_eqns(closed_jaxpr):
        if eqn.primitive.name == "all_to_all":
            out.append({"primitive": "all_to_all",
                        "bytes": _eqn_bytes(eqn) * n_shards})
    return out


def trace_collectives(fn, *args, n_shards: int = 1) -> Dict[str, Any]:
    """Trace ``fn`` (no execution) → jaxpr counts + exchange payloads."""
    import jax

    closed = jax.make_jaxpr(fn)(*args)
    return {"counts": jaxpr_collectives(closed),
            "exchanges": jaxpr_exchanges(closed, n_shards)}


# ---------------------------------------------------------------------------
# compiled-HLO layer (generalized from the launch/perf.py CLI)
# ---------------------------------------------------------------------------
def hlo_collectives(hlo_text: str):
    """Counts/bytes/ring-cost of every collective in compiled HLO text
    (a :class:`~repro.launch.roofline.CollectiveStats`)."""
    from repro.launch.roofline import parse_collectives

    return parse_collectives(hlo_text)


def top_collectives(hlo_text: str, k: int = 12
                    ) -> List[Tuple[int, str, str]]:
    """The ``k`` largest collectives by total bytes, aggregated by
    (kind, shape) — the perf CLI's contributor table."""
    from repro.launch.roofline import _shape_bytes

    rows = []
    for line in hlo_text.splitlines():
        m = re.match(
            r"\s*%?\S+ = (.+?)\s+(all-gather|all-reduce|reduce-scatter"
            r"|all-to-all|collective-permute)(?:-start)?\(", line)
        if not m:
            continue
        b = _shape_bytes(m.group(1))
        if b:
            rows.append((b, m.group(2), m.group(1)[:70]))
    agg = collections.Counter()
    for b, kind, shape in rows:
        agg[(kind, shape)] += b
    return sorted(((b, kind, shape) for (kind, shape), b in agg.items()),
                  reverse=True)[:k]


def compiled_collectives(fn, *args) -> Dict[str, Any]:
    """Compile ``fn`` (no execution) → observed HLO collective stats."""
    import jax

    compiled = jax.jit(fn).lower(*args).compile()
    stats = hlo_collectives(compiled.as_text())
    return {"counts": dict(stats.counts),
            "bytes_by_kind": dict(stats.bytes_by_kind),
            "total_bytes": stats.total_bytes,
            "ring_cost_s": stats.cost_s}


def program_audit(fn, *args, n_shards: int = 1,
                  predicted_a2a: Optional[int] = None) -> Dict[str, Any]:
    """Full two-layer audit of one program: traced jaxpr + compiled HLO.

    ``traced_a2a`` counts ``all_to_all`` equations; ``observed_a2a``
    counts ``all-to-all`` ops in the optimized executable.  When the
    caller supplies its planner prediction, ``consistent`` states
    whether all three layers agree — the runtime form of the
    plan-contract CI assertion.
    """
    traced = trace_collectives(fn, *args, n_shards=n_shards)
    observed = compiled_collectives(fn, *args)
    audit: Dict[str, Any] = {
        "n_shards": n_shards,
        "traced": traced["counts"],
        "traced_a2a": traced["counts"]["all_to_all"],
        "exchanges": traced["exchanges"],
        "observed": observed["counts"],
        "observed_a2a": observed["counts"].get("all-to-all", 0),
        "observed_bytes_by_kind": observed["bytes_by_kind"],
        "observed_total_bytes": observed["total_bytes"],
    }
    if predicted_a2a is not None:
        audit["predicted_a2a"] = predicted_a2a
        audit["consistent"] = (predicted_a2a == audit["traced_a2a"]
                               == audit["observed_a2a"])
    return audit
