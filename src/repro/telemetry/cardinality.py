"""Cardinality audit: planner estimates vs observed rows (DESIGN.md §14.1).

The physical planner stamps every :class:`~repro.plan.physical.PlanStep`
with its ``estimated_rows`` prediction; the op-by-op instrumentation
(``collect(telemetry=rec, jit=False)``) records each step's observed
``rows_out``.  This module closes the loop with the standard **q-error**

    q(est, obs) = max(est / obs, obs / est)        (both floored at 1 row)

— 1.0 is a perfect estimate, and the metric is symmetric: a 10x over-
and a 10x under-estimate are equally wrong, which is what makes it the
right gate for join-order decisions (they only need the *ratio* right).

``record_qerrors`` files a ``qerr`` fact per audited step plus the
``cardinality.max_qerror`` gauge; ``audit_cardinality`` raises
:class:`CardinalityAuditError` when any step's q-error exceeds the
caller's threshold (``collect(..., strict=True, qerror_threshold=...)``)
so a planner whose estimates drift out of contract fails loudly instead
of silently reordering joins from fiction.
"""
from __future__ import annotations

from typing import Dict, Optional

#: the contract threshold CI asserts on the representative chain — a
#: generous bound (estimates guide ORDER, not admission), but one real
#: estimator regressions blow straight past
DEFAULT_QERROR_THRESHOLD = 4.0


class CardinalityAuditError(RuntimeError):
    """A plan step's cardinality estimate missed the observed row count
    by more than the configured q-error threshold."""


def q_error(est: float, obs: float) -> float:
    """Symmetric multiplicative estimation error, both sides ≥ 1 row
    (an empty-vs-empty prediction is exact, not a 0/0)."""
    e = max(float(est), 1.0)
    o = max(float(obs), 1.0)
    return max(e / o, o / e)


def step_qerrors(rec) -> Dict[int, float]:
    """Per-step q-errors for every plan step carrying BOTH an estimate
    and an observation (jitted collects observe no per-step rows — then
    the audit is vacuous, by design)."""
    out: Dict[int, float] = {}
    for idx, facts in rec.plan_steps.items():
        est, obs = facts.get("est_rows"), facts.get("rows_out")
        if est is None or obs is None:
            continue
        out[idx] = q_error(est, obs)
    return out


def record_qerrors(rec) -> Dict[int, float]:
    """Compute q-errors, file each as a ``qerr`` step fact, and publish
    the ``cardinality.max_qerror`` / ``cardinality.steps_audited``
    gauges; returns the per-step map."""
    qs = step_qerrors(rec)
    for idx, q in qs.items():
        rec.observe_step(idx, qerr=round(q, 3))
    rec.metrics.gauge("cardinality.steps_audited", len(qs))
    if qs:
        rec.metrics.gauge("cardinality.max_qerror",
                          round(max(qs.values()), 3))
    return qs


def audit_cardinality(rec, threshold: Optional[float] = None) -> Dict[int, float]:
    """Enforce the q-error contract: raise :class:`CardinalityAuditError`
    when any audited step exceeds ``threshold`` (default
    :data:`DEFAULT_QERROR_THRESHOLD`)."""
    limit = DEFAULT_QERROR_THRESHOLD if threshold is None else float(threshold)
    qs = step_qerrors(rec)
    bad = {i: q for i, q in qs.items() if q > limit}
    if bad:
        detail = ", ".join(
            f"step {i} ({rec.plan_steps[i].get('op', '?')}): "
            f"est={rec.plan_steps[i].get('est_rows'):.0f} "
            f"obs={rec.plan_steps[i].get('rows_out')} q={q:.2f}"
            for i, q in sorted(bad.items()))
        raise CardinalityAuditError(
            f"cardinality audit failed (q-error threshold {limit:g}): "
            f"{detail} — the planner's estimates are out of contract; "
            f"refine() with the observed rows or fix the estimator")
    return qs
