"""Per-step memory accounting: RSS watermarks + an analytic live-bytes
model (DESIGN.md §14.2).

Two complementary views of a plan step's memory, both off-by-default:

  * **Observed** — the kernel's peak-RSS watermark (``VmHWM``) sampled
    before/after a step.  The watermark is monotone, so the delta is
    "how much this step pushed the process peak up": zero for a step
    that ran inside already-allocated headroom, positive exactly when
    the step set a new high-water mark.  Attribution, not accounting —
    deltas over a run sum to the run's total peak growth.
  * **Predicted** — :func:`step_live_bytes`, a deterministic analytic
    model over the packed-lane layout: every table row costs
    ``LANE_BYTES`` per column plus ``HASH_LANES`` carried hash lanes;
    an exchange stages a packed send + recv copy of its input; ordered
    operators add per-shard halo/carry buffers; spilled runs add their
    on-disk bytes (they transit host memory).  The model reads only
    static plan facts (estimated rows, schema widths), so ``explain()``
    can print it without running anything.

Both land on the same ``plan.<idx>.<op>`` spans / ``Collector.
plan_steps`` facts the cardinality audit uses, so ``explain
(analyze=True)`` joins predicted ``est_bytes`` against observed
``peak_rss_delta_kb`` per node.
"""
from __future__ import annotations

from typing import Optional

#: bytes per packed lane (everything tables move is 32-bit lanes)
LANE_BYTES = 4
#: (h1, h2) hash lanes carried alongside every row through exchanges
HASH_LANES = 2


# ---------------------------------------------------------------------------
# observed: /proc watermark sampling (same source as benchmarks/run.py)
# ---------------------------------------------------------------------------
def _status_kb(field: str) -> Optional[float]:
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith(field + ":"):
                    return float(line.split()[1])
    except OSError:
        pass
    return None


def rss_kb() -> Optional[float]:
    """Current resident set size in KB (``None`` off-Linux)."""
    return _status_kb("VmRSS")


def peak_rss_kb() -> Optional[float]:
    """Process peak RSS in KB — ``VmHWM`` with a rusage fallback."""
    kb = _status_kb("VmHWM")
    if kb is not None:
        return kb
    try:
        import resource

        return float(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss)
    except Exception:
        return None


def reset_peak_rss() -> None:
    """Reset the kernel watermark (Linux ``clear_refs``; no-op elsewhere,
    where VmHWM stays a lifetime high-water mark and deltas only ever
    under-report — never over-report — per-region growth)."""
    try:
        with open("/proc/self/clear_refs", "w") as f:
            f.write("5")
    except OSError:
        pass


class RssWatermark:
    """Sample the peak-RSS watermark around a region.

    ``delta_kb`` after exit is the region's contribution to the process
    peak (0.0 when the region fit in existing headroom, or when the
    platform has no watermark to read).
    """

    __slots__ = ("before_kb", "delta_kb")

    def __enter__(self) -> "RssWatermark":
        self.before_kb = peak_rss_kb()
        self.delta_kb = 0.0
        return self

    def __exit__(self, *exc) -> None:
        after = peak_rss_kb()
        if self.before_kb is not None and after is not None:
            self.delta_kb = max(0.0, after - self.before_kb)


def publish_pressure(rec, prefix: str) -> None:
    """Publish current/peak RSS gauges under ``<prefix>.pressure.*`` —
    the memory-pressure evidence spill decisions and scans leave behind
    (a no-op for unreadable platforms)."""
    cur, peak = rss_kb(), peak_rss_kb()
    if cur is not None:
        rec.metrics.gauge(f"{prefix}.pressure.rss_mb",
                          round(cur / 1024.0, 1))
    if peak is not None:
        rec.metrics.gauge(f"{prefix}.pressure.peak_rss_mb",
                          round(peak / 1024.0, 1))


# ---------------------------------------------------------------------------
# predicted: the analytic live-bytes model
# ---------------------------------------------------------------------------
def row_bytes(n_cols: int) -> int:
    """Bytes one resident row costs in the packed-lane layout."""
    return LANE_BYTES * (int(n_cols) + HASH_LANES)


def step_live_bytes(op: str, *, rows_in: float = 0.0, rows_out: float = 0.0,
                    cols_in: int = 0, cols_out: int = 0, exchanges: int = 0,
                    n_shards: int = 1, spill_bytes: float = 0.0) -> int:
    """Deterministic live-bytes estimate for one physical plan step.

    input + output residency, plus per-exchange packed send/recv staging
    (each AllToAll materializes one packed copy of its input on each
    side), plus per-shard halo + carry rows for the ordered operators,
    plus any spill run bytes (on-disk runs transit host buffers).
    """
    base = rows_in * row_bytes(cols_in) + rows_out * row_bytes(cols_out)
    staged = 2.0 * exchanges * rows_in * row_bytes(cols_in)
    halo = 0.0
    if op in ("window", "orderby", "topk"):
        halo = 2.0 * max(1, n_shards) * row_bytes(cols_in)
    return int(base + staged + halo + spill_bytes)
