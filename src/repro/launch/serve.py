"""Production serving launcher: batched prefill/decode over a mesh.

Usage:
    python -m repro.launch.serve --arch phi3-mini-3.8b --reduced \
        --batch 4 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    from repro.configs import get_config, reduced_config
    from repro.models import transformer as T
    from repro.serve.engine import Engine, ServeConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)

    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params,
                    ServeConfig(max_len=args.prompt_len + args.gen + 8,
                                temperature=args.temperature))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(1, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    fe = None
    if cfg.frontend is not None or cfg.is_encoder_decoder:
        fe = jnp.asarray(0.02 * rng.normal(
            size=(args.batch, cfg.frontend_seq, cfg.d_model)), jnp.float32)
    t0 = time.perf_counter()
    out = engine.generate(prompts, n_tokens=args.gen, frontend_embeds=fe)
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({out.size / dt:.0f} tok/s)")
    print("serve launcher done")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
