"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs / (chips × 197 TF bf16)
    memory     = HLO_bytes / (chips × 819 GB/s HBM)
    collective = Σ per-op collective cost, ICI-hop-weighted, / 50 GB/s/link

cost_analysis() supplies FLOPs/bytes; collective bytes are parsed from the
compiled HLO text (all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute operand sizes).  Per-chip collective cost applies the
standard ring factors: all-gather/reduce-scatter move (n-1)/n of the shard
bytes per link, all-reduce 2(n-1)/n, all-to-all (n-1)/n of the local bytes.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link (per direction)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(?:\([^)]*\)|(\w+)\[[^\]]*\]|[\w\[\],\s]*?)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string like 'bf16[16,128]{1,0}'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, int]
    cost_s: float          # per-chip link-seconds (ring model)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str, replica_groups_size: Optional[int] = None
                      ) -> CollectiveStats:
    """Sum output-shape bytes of every collective op in the HLO."""
    counts: Dict[str, int] = {}
    bytes_by: Dict[str, int] = {}
    cost = 0.0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(
            r".*?=\s*(.+?)\s+(all-gather|all-reduce|reduce-scatter"
            r"|all-to-all|collective-permute)(?:-start)?\(", line)
        if not m or line.startswith("//"):
            continue
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        if b == 0:
            continue
        counts[kind] = counts.get(kind, 0) + 1
        bytes_by[kind] = bytes_by.get(kind, 0) + b
        # group size from replica_groups
        g = replica_groups_size
        gm = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
        if gm:
            g = len(gm.group(1).split(","))
        gm2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
        if gm2:
            g = int(gm2.group(2))
        if g is None or g <= 1:
            g = 2
        frac = (g - 1) / g
        if kind == "all-gather":
            # output is the gathered buffer; each link moves (g-1)/g of it
            cost += b * frac / ICI_BW
        elif kind == "reduce-scatter":
            # b is the scattered output shard; ring moves (g-1)·b per chip
            cost += b * (g - 1) / ICI_BW
        elif kind == "all-reduce":
            cost += 2 * b * frac / ICI_BW
        elif kind == "all-to-all":
            cost += b * frac / ICI_BW
        elif kind == "collective-permute":
            cost += b / ICI_BW
    return CollectiveStats(counts, bytes_by, cost)


@dataclasses.dataclass
class Roofline:
    flops: float          # per-device (cost_analysis of the SPMD module)
    hbm_bytes: float      # per-device
    collectives: CollectiveStats
    n_chips: int
    model_flops: float = 0.0   # global analytic 6·N·D / 2·N·tok

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collectives.cost_s

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_frac(self) -> float:
        if self.model_flops and self.flops:
            return self.model_flops / (self.flops * self.n_chips)
        return float("nan")

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline step time."""
        if not self.model_flops or not self.step_s:
            return float("nan")
        return self.model_flops / (self.step_s * self.n_chips * PEAK_FLOPS)

    def summary(self) -> Dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "hlo_flops": self.flops,
            "hlo_bytes": self.hbm_bytes,
            "collective_bytes": self.collectives.total_bytes,
            "collective_counts": self.collectives.counts,
            "model_flops": self.model_flops,
            "useful_flops_frac": self.useful_flops_frac,
            "roofline_step_s": self.step_s,
            "mfu_at_roofline": self.mfu,
        }


def model_flops_for(cfg, cell) -> float:
    """MODEL_FLOPS: 6·N·D for training, 2·N_active per generated/processed
    token for inference (standard convention)."""
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * cell.global_batch


def tpu_adjusted_terms(cfg, cell, n_chips: int, measured: "Roofline",
                       model_axis: int = 16) -> Dict[str, float]:
    """TPU-target estimates for the memory/collective terms.

    The measured terms come from XLA:CPU, which (a) promotes bf16 dots to
    f32 (collectives carry 2× the bytes) and (b) barely fuses elementwise
    chains (per-opcode attribution shows `convert`+`add`+`multiply`
    dominate measured bytes).  On the TPU target:

      * collective ≈ measured / 2 (bf16 payloads);
      * memory = analytic first-principles traffic — parameters (bf16 read
        for fwd + remat + bwd, f32 grad/optimizer streams), activations
        (~22 bf16 tensor passes per layer per token, ×3 for fwd/remat/bwd),
        flash-kernel attention (Q+O once, K+V streamed once per 128-row
        query block — the Pallas kernel's exact HBM pattern), logits, and
        for decode the KV-cache read+write.

    Compute is trusted as measured (dot FLOPs count exactly).
    """
    dp = max(n_chips // model_axis, 1)
    p_dev = cfg.param_count() / n_chips
    d, l = cfg.d_model, cfg.n_layers
    if cell.kind == "train":
        tok_dev = cell.global_batch * cell.seq_len / dp
        param_traffic = p_dev * (3 * 2 + 2 * 4 + 16 + 8)
        act = l * tok_dev * d * 2 * 22 * 3 / model_axis  # TP-sharded hidden
        passes = 3
    elif cell.kind == "prefill":
        tok_dev = cell.global_batch * cell.seq_len / dp
        param_traffic = p_dev * 2
        act = l * tok_dev * d * 2 * 22 / model_axis
        passes = 1
    else:  # decode
        tok_dev = cell.global_batch / max(dp, 1)
        param_traffic = p_dev * 2
        # KV cache read + write per token
        kv = 2 * cfg.n_kv_heads * cfg.head_dim * \
            cfg.decode_cache_len(cell.seq_len)
        act = tok_dev * (l * kv * 2 * 2 / model_axis
                         + l * d * 2 * 22 / model_axis)
        passes = 1

    # flash attention: K+V streamed once per 128-row query block
    attn = 0.0
    n_attn = sum(1 for k in cfg.block_pattern if k == "attn") * cfg.n_groups
    if n_attn and cell.kind != "decode":
        s_loc = cell.seq_len
        b_loc = cell.global_batch / dp
        kv_bytes = 2 * cfg.n_kv_heads * cfg.head_dim * min(
            cell.seq_len, cfg.window or cell.seq_len) * 2
        n_qblk = -(-s_loc // 128)
        attn = n_attn * b_loc * (n_qblk * kv_bytes / model_axis
                                 + 2 * s_loc * cfg.n_heads * cfg.head_dim
                                 * 2 / model_axis) * passes
    logits_tok = 1 if cell.kind != "train" else \
        cell.global_batch * cell.seq_len / dp
    logits = logits_tok * cfg.vocab_size / model_axis * 4 * (3 if
             cell.kind == "train" else 1)

    mem_bytes = param_traffic + act + attn + logits
    return {
        "memory_s_tpu": mem_bytes / HBM_BW,
        "collective_s_tpu": measured.collective_s / 2,
        "step_s_tpu": max(measured.compute_s, mem_bytes / HBM_BW,
                          measured.collective_s / 2),
        "mfu_tpu": (measured.model_flops
                    / (max(measured.compute_s, mem_bytes / HBM_BW,
                           measured.collective_s / 2)
                       * n_chips * PEAK_FLOPS)
                    if measured.model_flops else float("nan")),
    }


def analyze(compiled, n_chips: int, cfg=None, cell=None,
            hlo_text: Optional[str] = None) -> Roofline:
    cost = compiled.cost_analysis()
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    colls = parse_collectives(text)
    mf = model_flops_for(cfg, cell) if cfg is not None else 0.0
    return Roofline(flops=flops, hbm_bytes=byts, collectives=colls,
                    n_chips=n_chips, model_flops=mf)
