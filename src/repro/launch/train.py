"""Production training launcher.

Assembles: mesh → logical-rule binding → FSDP×TP sharded train step →
HPTMT data pipeline → checkpointed loop.  On a real pod this is the entry
point per host process (`jax.distributed.initialize` + the same code); on
this container it runs with whatever host devices exist.

Usage:
    python -m repro.launch.train --arch smollm-360m --steps 20 \
        --mesh 1x1 --batch 8 --seq 128 [--ckpt DIR]
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--mesh", default="1x1",
                    help="DATAxMODEL (e.g. 16x16) or PODxDATAxMODEL")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true",
                    help="family-preserving reduced config (CPU demo)")
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args(argv)

    from repro.configs import get_config, reduced_config
    from repro.core import HPTMTContext
    from repro.core.context import make_mesh
    from repro.data.pipeline import CorpusConfig, make_training_data
    from repro.sharding import axes as am
    from repro.train.optimizer import OptimizerConfig
    from repro.train.train_step import (TrainConfig, init_train_state,
                                        make_sharded_train_step)
    from repro.train.trainer import LoopConfig, train_loop

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)

    dims = [int(d) for d in args.mesh.split("x")]
    names = (("pod", "data", "model") if len(dims) == 3
             else ("data", "model"))[:len(dims)]
    mesh = make_mesh(dims, names) if np.prod(dims) > 1 else None

    tcfg = TrainConfig(
        optimizer=OptimizerConfig(warmup_steps=max(args.steps // 20, 1),
                                  total_steps=args.steps),
        micro_batches=args.micro)
    loop = LoopConfig(total_steps=args.steps, log_every=5,
                      checkpoint_every=max(args.steps // 2, 5),
                      checkpoint_dir=args.ckpt)

    ctx = HPTMTContext(mesh=mesh) if mesh is not None else HPTMTContext()
    data = make_training_data(cfg, ctx, batch=args.batch, seq_len=args.seq,
                              ccfg=CorpusConfig(vocab_size=cfg.vocab_size))

    if mesh is None:
        state = train_loop(cfg, tcfg, loop, data)
    else:
        with am.logical_binding(mesh):
            template = init_train_state(jax.random.PRNGKey(0), cfg)
            step, sspec, _ = make_sharded_train_step(cfg, tcfg, mesh,
                                                     template)
            state = template
            import time
            for i in range(args.steps):
                batch = next(data)
                t0 = time.perf_counter()
                state, metrics = step(state, batch)
                jax.block_until_ready(metrics["loss"])
                if i % 5 == 0:
                    print(f"step {i} loss={float(metrics['loss']):.4f} "
                          f"dt={(time.perf_counter()-t0)*1e3:.0f}ms")
    print("train launcher done")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
