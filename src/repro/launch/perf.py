"""Perf-iteration tool: lower one cell (with config/rule overrides), report
the three roofline terms and the largest collective/memory contributors.

    python -m repro.launch.perf --arch smollm-360m --shape prefill_32k \
        [--set key=value ...] [--rule axis=meshaxis ...] [--top 10]

Each hypothesis→change→measure cycle in EXPERIMENTS.md §Perf is one
invocation of this tool.

Importing this module is side-effect free: the 512-host-device XLA flag
the CLI needs is set under ``__main__`` only (before jax initializes),
never at import time — ``import repro.launch.perf`` from a test or a
library must not reconfigure the process's device topology.
"""
import argparse
import dataclasses
import sys

from repro.telemetry.audit import top_collectives as _top_collectives


def measure(arch, shape_name, set_overrides=None, rule_overrides=None,
            top=10, show_mem=False, micro=None):
    import jax
    from repro.configs import SHAPES, get_config
    from repro.launch import roofline as rl
    from repro.launch.cells import lower_cell, roofline_config, \
        slstm_flops_correction
    from repro.launch.dryrun import _extrapolated_roofline
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    if set_overrides:
        cfg = dataclasses.replace(cfg, **set_overrides)
    cell = SHAPES[shape_name]
    mesh = make_production_mesh()

    # full compile for memory analysis
    lc = lower_cell(arch, cell, mesh, rule_overrides, cfg=cfg,
                    micro_batches=micro)
    co = lc.lowered.compile()
    mem = co.memory_analysis()
    peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes)
    print(f"peak/dev: {peak/2**30:.2f} GiB  (args {mem.argument_size_in_bytes/2**30:.2f} "
          f"out {mem.output_size_in_bytes/2**30:.2f} temp {mem.temp_size_in_bytes/2**30:.2f} "
          f"alias {mem.alias_size_in_bytes/2**30:.2f})")

    # extrapolated roofline on the modified config
    def lower_with_cfg(a, c, m, r, cfg=None, micro_batches=None):
        return lower_cell(a, c, m, r, cfg=cfg, micro_batches=micro_batches)

    meas = {}
    for k in (1, 2):
        rcfg = roofline_config(cfg, k)
        lck = lower_cell(arch, cell, mesh, rule_overrides, cfg=rcfg,
                         micro_batches=1)
        cok = lck.lowered.compile()
        ca = cok.cost_analysis()
        text = cok.as_text()
        meas[k] = (float(ca.get("flops", 0)),
                   float(ca.get("bytes accessed", 0)),
                   rl.parse_collectives(text), text)

    g = cfg.n_groups

    def extr(a1, a2):
        return max((2 * a1 - a2) + g * (a2 - a1), max(a1, a2))

    dp = mesh.devices.size // mesh.shape.get("model", 1)
    flops = extr(meas[1][0], meas[2][0]) + slstm_flops_correction(cfg, cell,
                                                                  dp)
    byts = extr(meas[1][1], meas[2][1])
    coll = extr(meas[1][2].cost_s, meas[2][2].cost_s)
    coll_b = extr(meas[1][2].total_bytes, meas[2][2].total_bytes)
    mf = rl.model_flops_for(cfg, cell)
    compute_s = flops / rl.PEAK_FLOPS
    memory_s = byts / rl.HBM_BW
    step = max(compute_s, memory_s, coll)
    print(f"compute {compute_s:.3f}s | memory {memory_s:.3f}s | "
          f"collective {coll:.3f}s  → step {step:.3f}s  "
          f"mfu {mf/(step*256*rl.PEAK_FLOPS)*100:.1f}%  "
          f"useful_frac {mf/(flops*256):.2f}  coll {coll_b/1e9:.0f}GB")

    print("top collectives (k=2 variant, per-layer-group ×%d):" % g)
    for b, kind, shape in _top_collectives(meas[2][3], top):
        print(f"  {b/2**30:8.3f} GiB  {kind:20s} {shape}")
    return {"peak": peak, "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": coll, "step_s": step,
            "mfu": mf / (step * 256 * rl.PEAK_FLOPS)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (python literal)")
    ap.add_argument("--rule", action="append", default=[],
                    help="logical rule override axis=meshaxis|none")
    ap.add_argument("--micro", type=int, default=None)
    ap.add_argument("--top", type=int, default=10)
    args = ap.parse_args(argv)

    import ast
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            overrides[k] = ast.literal_eval(v)
        except (ValueError, SyntaxError):
            overrides[k] = v
    rules = {}
    for kv in args.rule:
        k, v = kv.split("=", 1)
        rules[k] = None if v.lower() == "none" else (
            tuple(v.split("+")) if "+" in v else v)
    measure(args.arch, args.shape, overrides or None, rules or None,
            args.top, micro=args.micro)
    return 0


if __name__ == "__main__":
    # the CLI wants a 512-device host platform; set it HERE (jax has not
    # initialized yet — measure() imports it lazily), not at import time
    import os

    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=512")
    sys.exit(main())
