import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# NOTE: the two lines above MUST run before any other import (including
# `from repro...`) — JAX locks the device count on first initialization.

"""Multi-pod dry-run: lower + compile every (architecture × shape) cell on
the production meshes and record memory/cost/collective analysis.

Usage:
    python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
    python -m repro.launch.dryrun --all [--multipod-only|--singlepod-only]
    python -m repro.launch.dryrun --all --out results/dryrun.json

Success criterion (deliverable e): ``.lower().compile()`` succeeds for the
16×16 mesh AND the 2×16×16 multi-pod mesh for every runnable cell; the
single-pod pass also emits the §Roofline terms.
"""
import argparse
import json
import sys
import time
import traceback


def _extrapolated_roofline(arch, cell, mesh, rule_overrides, cfg, n_chips):
    """HLO-accurate roofline via depth-1/depth-2 unrolled compiles."""
    from repro.launch import roofline as rl
    from repro.launch.cells import (lower_cell, roofline_config,
                                    slstm_flops_correction)

    meas = {}
    for k in (1, 2):
        rcfg = roofline_config(cfg, k)
        # micro_batches=1: the micro-accumulation scan is a while loop too,
        # and cost_analysis counts its body once — keep the measurement
        # variants loop-free.
        lc = lower_cell(arch, cell, mesh, rule_overrides, cfg=rcfg,
                        micro_batches=1)
        co = lc.lowered.compile()
        ca = co.cost_analysis()
        colls = rl.parse_collectives(co.as_text())
        meas[k] = (float(ca.get("flops", 0.0)),
                   float(ca.get("bytes accessed", 0.0)), colls)

    g = cfg.n_groups

    def extr(a1, a2):
        return max((2 * a1 - a2) + g * (a2 - a1), max(a1, a2))

    dp = n_chips // mesh.shape.get("model", 1)
    flops = extr(meas[1][0], meas[2][0]) \
        + slstm_flops_correction(cfg, cell, dp)
    byts = extr(meas[1][1], meas[2][1])
    c1, c2 = meas[1][2], meas[2][2]
    kinds = set(c1.counts) | set(c2.counts)
    counts = {kk: int(extr(c1.counts.get(kk, 0), c2.counts.get(kk, 0)))
              for kk in kinds}
    byk = {kk: int(extr(c1.bytes_by_kind.get(kk, 0),
                        c2.bytes_by_kind.get(kk, 0))) for kk in kinds}
    cost = extr(c1.cost_s, c2.cost_s)
    colls = rl.CollectiveStats(counts, byk, cost)
    return rl.Roofline(flops=flops, hbm_bytes=byts, collectives=colls,
                       n_chips=n_chips,
                       model_flops=rl.model_flops_for(cfg, cell))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             rule_overrides=None, with_roofline: bool = None) -> dict:
    import jax

    from repro.configs import SHAPES, cell_is_runnable, get_config
    from repro.launch import roofline as rl
    from repro.launch.cells import lower_cell
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    cell = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, cell)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    lc = lower_cell(arch, cell, mesh, rule_overrides)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lc.lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    out = {
        "arch": arch, "shape": shape_name, "kind": cell.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "alias_bytes_per_device": mem.alias_size_in_bytes,
            "peak_bytes_per_device": (
                mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes),
        },
    }
    # raw per-device cost analysis of the scanned module (diagnostic —
    # loop bodies counted once; see roofline_config docstring)
    raw = rl.analyze(compiled, n_chips, cfg, cell)
    out["roofline_raw_scanned"] = {
        "hlo_flops": raw.flops, "hlo_bytes": raw.hbm_bytes,
        "collective_bytes": raw.collectives.total_bytes}
    if with_roofline is None:
        with_roofline = not multi_pod
    if with_roofline:
        roof = _extrapolated_roofline(arch, cell, mesh, rule_overrides, cfg,
                                      n_chips)
        out["roofline"] = roof.summary()
        out["roofline"]["tpu_adjusted"] = rl.tpu_adjusted_terms(
            cfg, cell, n_chips, roof, mesh.shape.get("model", 1))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod-only", action="store_true")
    ap.add_argument("--singlepod-only", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    from repro.configs import SHAPES, list_archs

    cells = []
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True]
    if args.multipod_only:
        meshes = [True]
    if args.singlepod_only:
        meshes = [False]

    results = []
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}"
                try:
                    r = run_cell(arch, shape, mp)
                except Exception as e:  # noqa: BLE001 — report, keep going
                    failures += 1
                    r = {"arch": arch, "shape": shape,
                         "mesh": "2x16x16" if mp else "16x16",
                         "status": "error", "error": repr(e),
                         "traceback": traceback.format_exc(limit=12)}
                results.append(r)
                status = r["status"]
                extra = ""
                if status == "ok":
                    peak = r["memory"]["peak_bytes_per_device"] / 2**30
                    extra = f" peak={peak:.2f}GiB/dev"
                    roof = r.get("roofline")
                    if roof:
                        extra += (f" bottleneck={roof['bottleneck']} "
                                  f"compute={roof['compute_s']*1e3:.1f}ms "
                                  f"mem={roof['memory_s']*1e3:.1f}ms "
                                  f"coll={roof['collective_s']*1e3:.1f}ms "
                                  f"mfu={roof['mfu_at_roofline']*100:.0f}%")
                elif status == "skipped":
                    extra = f" ({r['reason'][:60]}…)"
                else:
                    extra = f" {r['error'][:120]}"
                print(f"[dryrun] {tag}: {status}{extra}", flush=True)
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1)
    print(f"[dryrun] done: {len(results)} cells, {failures} failures",
          flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
