"""Production mesh construction (multi-pod dry-run target).

A FUNCTION, not a module constant — importing this module never touches JAX
device state (the dry-run sets ``xla_force_host_platform_device_count``
before any JAX initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
