"""(architecture × shape) cell definitions: step functions, input specs,
shardings — shared by the dry-run, roofline, and benchmark harnesses.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, no device allocation); full configs are only ever lowered, never
materialized, on this container.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ShapeCell, get_config
from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.sharding import axes as axes_mod
from repro.sharding import partition
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import (TrainConfig, TrainState,
                                    init_train_state, make_train_step)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def roofline_config(cfg: ModelConfig, k: int) -> ModelConfig:
    """Depth-k, fully-unrolled variant for HLO cost extrapolation.

    ``compiled.cost_analysis()`` counts loop bodies ONCE (not × trip count),
    so the full-depth scanned model under-reports FLOPs/bytes by ~n_groups.
    We compile k=1 and k=2 group variants with every scan unrolled/disabled
    (layer-group scan unrolled; SSM/mLSTM/attention seq-chunk loops widened
    to one chunk) and extrapolate linearly:
        cost(G) = (2·c1 − c2) + G·(c2 − c1).
    Lowering only — no buffers are ever allocated at these shapes.
    """
    updates = dict(n_layers=k * cfg.group_size, scan_unroll=True,
                   scan_chunk=2**30, mlstm_chunk=2**30, attn_q_chunk=2**30)
    if cfg.is_encoder_decoder:
        updates["n_encoder_layers"] = k
    return dataclasses.replace(cfg, **updates)


def slstm_flops_correction(cfg: ModelConfig, cell: ShapeCell,
                           dp_shards: int) -> float:
    """Per-device FLOPs missing from sLSTM's sequential time scan.

    The recurrent matmul (B_loc, D)·(D, 4D) runs once per timestep but is
    counted once total; add the remaining (S−1) steps analytically
    (×3 for train: fwd + two bwd matmuls)."""
    n_slstm = sum(1 for kk in cfg.block_pattern if kk == "slstm") \
        * cfg.n_groups
    if n_slstm == 0 or cell.seq_len <= 1 or cell.kind == "decode":
        return 0.0
    b_loc = max(cell.global_batch // dp_shards, 1)
    per_step = 2.0 * b_loc * cfg.d_model * 4 * cfg.d_model
    mult = 3.0 if cell.kind == "train" else 1.0
    return n_slstm * per_step * (cell.seq_len - 1) * mult


def cell_rules(cfg: ModelConfig, cell: ShapeCell,
               overrides: Optional[Dict] = None) -> Dict:
    rules = dict(axes_mod.DEFAULT_RULES)
    if cell.global_batch == 1:
        rules["batch"] = None          # long-context decode: nothing to DP
    if overrides:
        rules.update(overrides)
    return rules


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    """ShapeDtypeStructs for every model input of this cell."""
    b, s = cell.global_batch, cell.seq_len
    if cell.kind in ("train", "prefill"):
        text = s - (cfg.frontend_seq if cfg.frontend == "vision" else 0)
        out = {"tokens": _sds((b, text), jnp.int32),
               "labels": _sds((b, text), jnp.int32)}
        if cfg.frontend is not None or cfg.is_encoder_decoder:
            out["frontend"] = _sds((b, cfg.frontend_seq, cfg.d_model),
                                   jnp.float32)
        if cell.kind == "prefill":
            out.pop("labels")
        return out
    # decode: one new token against a cache of length s
    return {"token": _sds((b, 1), jnp.int32),
            "pos": _sds((1,), jnp.int32)}


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------
def _train_cfg(cfg: ModelConfig, cell: Optional[ShapeCell] = None,
               micro_batches: Optional[int] = None,
               dp_shards: int = 16) -> TrainConfig:
    if micro_batches is None:
        # bound per-group activation carries: pick micro-batches from the
        # estimated per-device residual-stack bytes (n_groups × B_loc × S ×
        # d_model × bf16 ≲ 2 GiB), not the param count — small-d models at
        # big batches need accumulation just as much as the 67B ones
        micro_batches = 1
        if cell is not None and cell.kind == "train":
            b_loc = max(cell.global_batch // dp_shards, 1)
            stack = (cfg.n_groups * b_loc * cell.seq_len * cfg.d_model * 2
                     * (3 if set(cfg.block_pattern) & {"mlstm", "slstm",
                                                       "mamba"} else 1))
            # sLSTM's sequential time scan saves 4 f32 carries per step
            n_slstm = cfg.block_pattern.count("slstm") * cfg.n_groups
            stack += n_slstm * b_loc * cell.seq_len * cfg.d_model * 16
            # Mamba chunk scans save (B, chunk, d_inner, N) f32 per chunk
            n_mamba = cfg.block_pattern.count("mamba") * cfg.n_groups
            if n_mamba:
                stack += (b_loc * cell.seq_len * cfg.ssm_expand
                          * cfg.d_model * cfg.ssm_state_dim * 4) // 16
            micro_batches = 1
            while stack / micro_batches > 1.5e9 and micro_batches < 16:
                micro_batches *= 2
            # floor from param scale (activation estimate is approximate)
            params = cfg.param_count()
            micro_batches = max(micro_batches,
                                16 if params > 2e10 else
                                (8 if params > 2e9 else 1))
            # each micro-batch must still split across all DP shards
            micro_batches = min(micro_batches,
                                max(cell.global_batch // dp_shards, 1))
            while cell.global_batch % (micro_batches * dp_shards):
                micro_batches //= 2
            micro_batches = max(micro_batches, 1)
    return TrainConfig(optimizer=OptimizerConfig(),
                       micro_batches=micro_batches)


def make_train_fn(cfg: ModelConfig, cell: Optional[ShapeCell] = None):
    return make_train_step(cfg, _train_cfg(cfg, cell))


def make_prefill_fn(cfg: ModelConfig, cache_len: int):
    def prefill_step(params, batch):
        logits, cache, _ = T.apply_lm(
            params, cfg, batch["tokens"], mode="prefill",
            frontend_embeds=batch.get("frontend"), cache_len=cache_len,
            last_logit_only=True)
        return logits[:, -1], cache

    return prefill_step


def make_decode_fn(cfg: ModelConfig):
    def serve_step(params, cache, token, pos):
        logits, new_cache, _ = T.apply_lm(
            params, cfg, token, mode="decode", cache=cache, positions=pos)
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token, new_cache

    return serve_step


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class LoweredCell:
    arch: str
    shape: str
    kind: str
    lowered: Any
    abstract_args: Tuple


def _state_shapes(cfg: ModelConfig) -> TrainState:
    rng = jax.random.PRNGKey(0)
    return jax.eval_shape(functools.partial(init_train_state, cfg=cfg), rng)


def _params_shapes(cfg: ModelConfig):
    rng = jax.random.PRNGKey(0)
    return jax.eval_shape(functools.partial(T.init_lm, cfg=cfg), rng)


def _cache_shapes(cfg: ModelConfig, batch: int, cache_len: int):
    fn = functools.partial(T.init_cache, cfg, batch, cache_len,
                           jnp.dtype(cfg.dtype))
    shapes = jax.eval_shape(fn)
    if cfg.is_encoder_decoder:
        shapes = dict(groups=shapes) if not isinstance(shapes, dict) else \
            {"groups": shapes}
        shapes["enc_out"] = _sds(
            (batch, cfg.frontend_seq, cfg.d_model), jnp.dtype(cfg.dtype))
    else:
        shapes = {"groups": shapes}
    return shapes


def lower_cell(arch: str, cell: ShapeCell, mesh: Mesh,
               rule_overrides: Optional[Dict] = None,
               cfg: Optional[ModelConfig] = None,
               micro_batches: Optional[int] = None) -> LoweredCell:
    cfg = cfg or get_config(arch)
    rules = cell_rules(cfg, cell, rule_overrides)
    specs = input_specs(cfg, cell)

    with axes_mod.logical_binding(mesh, rules):
        bspec = partition.batch_spec(mesh, rules)
        b_axes = bspec[0] if len(bspec) else None

        if cell.kind == "train":
            state = _state_shapes(cfg)
            pspecs = partition.param_specs(state.params, cfg, mesh, rules)
            state_sh = TrainState(
                params=jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs),
                opt=type(state.opt)(
                    mu=jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs),
                    nu=jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs),
                    count=NamedSharding(mesh, P())))
            batch_sh = {k: NamedSharding(mesh, P(b_axes))
                        for k in specs}
            dp = mesh.devices.size // mesh.shape.get("model", 1)
            fn = make_train_step(
                cfg, _train_cfg(cfg, cell, micro_batches, dp_shards=dp))
            lowered = jax.jit(
                fn, in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            ).lower(state, specs)
            return LoweredCell(arch, cell.name, "train", lowered,
                               (state, specs))

        params = _params_shapes(cfg)
        pspecs = partition.param_specs(params, cfg, mesh, rules)
        params_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)

        if cell.kind == "prefill":
            fn = make_prefill_fn(cfg, cache_len=cell.seq_len)
            batch_sh = {k: NamedSharding(mesh, P(b_axes)) for k in specs}
            cache_shapes = jax.eval_shape(
                lambda p, b: fn(p, b)[1], params, specs)
            cache_sh = partition.cache_shardings(cache_shapes, cfg, mesh,
                                                 rules)
            lowered = jax.jit(
                fn, in_shardings=(params_sh, batch_sh),
                out_shardings=(NamedSharding(mesh, P(b_axes)), cache_sh),
            ).lower(params, specs)
            return LoweredCell(arch, cell.name, "prefill", lowered,
                               (params, specs))

        # decode — no remat (nothing to rematerialize for a 1-token step;
        # the checkpoint wrapper only adds buffer copies); absorbed MLA
        # scores in latent space instead of re-expanding K/V per token
        # (measured 7× on minicpm3 decode_32k — EXPERIMENTS §Perf)
        cfg = dataclasses.replace(cfg, remat=False, mla_absorb=True)
        cache = _cache_shapes(cfg, cell.global_batch, cell.seq_len)
        cache_sh = partition.cache_shardings(cache, cfg, mesh, rules)
        fn = make_decode_fn(cfg)
        tok_sh = NamedSharding(mesh, P(b_axes))
        pos_sh = NamedSharding(mesh, P())
        lowered = jax.jit(
            fn,
            in_shardings=(params_sh, cache_sh, tok_sh, pos_sh),
            out_shardings=(tok_sh, cache_sh),
            donate_argnums=(1,),
        ).lower(params, cache, specs["token"], specs["pos"])
        return LoweredCell(arch, cell.name, "decode", lowered,
                           (params, cache, specs["token"], specs["pos"]))
