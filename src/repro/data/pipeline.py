"""Data pipeline: HPTMT table operators feeding tensor training.

This is the paper's flagship composition (Fig 14): *dataflow table
operators* pre-process a corpus, then hand off to *array/tensor operators*
for the numeric algorithm.  The synthetic corpus is a pair of tables —
documents (doc_id, quality, n_tokens) and token rows (doc_id, position,
token) — and the pipeline is

    select(quality ≥ θ) → join(tokens ⋈ docs) → orderby/shuffle
        → to_numpy() → fixed-length (tokens, labels) batches,

exactly the table→tensor bridge of paper Figs 13/17.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import DistTable, HPTMTContext, Table, TSet, table_ops


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    n_docs: int = 64
    mean_doc_len: int = 96
    vocab_size: int = 128
    quality_threshold: float = 0.3
    seed: int = 0


def synthetic_corpus(ccfg: CorpusConfig, ctx: HPTMTContext
                     ) -> Dict[str, DistTable]:
    """Two-table corpus: docs metadata + flat token rows."""
    rng = np.random.default_rng(ccfg.seed)
    lens = np.clip(rng.poisson(ccfg.mean_doc_len, ccfg.n_docs), 8, None)
    quality = rng.uniform(size=ccfg.n_docs).astype(np.float32)
    docs = Table.from_arrays({
        "doc_id": jnp.arange(ccfg.n_docs, dtype=jnp.int32),
        "quality": jnp.asarray(quality),
        "n_tokens": jnp.asarray(lens.astype(np.int32)),
    })
    total = int(lens.sum())
    doc_ids = np.repeat(np.arange(ccfg.n_docs), lens).astype(np.int32)
    positions = np.concatenate([np.arange(l) for l in lens]).astype(np.int32)
    # token stream with mild structure so small models can learn it
    toks = ((doc_ids * 31 + positions * 7) % (ccfg.vocab_size - 2) + 1
            ).astype(np.int32)
    tokens = Table.from_arrays({
        "doc_id": jnp.asarray(doc_ids),
        "position": jnp.asarray(positions),
        "token": jnp.asarray(toks),
    })
    p = ctx.n_shards
    return {
        "docs": DistTable.from_local(docs, ctx,
                                     capacity=-(-ccfg.n_docs // p)),
        "tokens": DistTable.from_local(tokens, ctx, capacity=-(-total // p)),
    }


def preprocess(corpus: Dict[str, DistTable], ccfg: CorpusConfig,
               ctx: HPTMTContext) -> np.ndarray:
    """Dataflow pipeline → flat curated token stream (host array)."""
    docs = TSet.from_table(corpus["docs"], ctx)
    tokens = TSet.from_table(corpus["tokens"], ctx,
                             chunk_rows=max(corpus["tokens"].capacity // 4, 8))
    good = docs.select(lambda c: c["quality"] >= ccfg.quality_threshold) \
               .project(["doc_id", "quality"])
    curated = tokens.join(good, keys=["doc_id"],
                          out_capacity=corpus["tokens"].capacity)
    result = curated.collect()
    # global order by (doc, position) → deterministic stream
    ordered, _ = table_ops.orderby(result, "doc_id", ctx=ctx)
    arrs = ordered.to_numpy()
    order = np.lexsort((arrs["position"], arrs["doc_id"]))
    return arrs["token"][order]


def batch_iterator(stream: np.ndarray, batch: int, seq_len: int,
                   seed: int = 0) -> Iterator[Dict[str, jnp.ndarray]]:
    """Infinite (tokens, labels) batches from a curated token stream."""
    rng = np.random.default_rng(seed)
    n = len(stream) - (seq_len + 1)
    if n <= 0:
        reps = (seq_len + 2) // max(len(stream), 1) + 1
        stream = np.tile(stream, reps)
        n = len(stream) - (seq_len + 1)
    while True:
        starts = rng.integers(0, n, size=batch)
        toks = np.stack([stream[s:s + seq_len] for s in starts])
        labels = np.stack([stream[s + 1:s + seq_len + 1] for s in starts])
        yield {"tokens": jnp.asarray(toks, jnp.int32),
               "labels": jnp.asarray(labels, jnp.int32)}


def make_training_data(cfg: ModelConfig, ctx: HPTMTContext, batch: int,
                       seq_len: int, ccfg: Optional[CorpusConfig] = None,
                       ) -> Iterator[Dict[str, jnp.ndarray]]:
    ccfg = ccfg or CorpusConfig(vocab_size=cfg.vocab_size)
    corpus = synthetic_corpus(ccfg, ctx)
    stream = preprocess(corpus, ccfg, ctx)
    base = batch_iterator(stream, batch, seq_len, seed=ccfg.seed)
    if cfg.frontend is None and not cfg.is_encoder_decoder:
        return base

    def with_frontend():
        rng = np.random.default_rng(ccfg.seed + 1)
        for b in base:
            fe = rng.normal(size=(batch, cfg.frontend_seq, cfg.d_model)
                            ).astype(np.float32) * 0.02
            yield {**b, "frontend": jnp.asarray(fe)}

    return with_frontend()
