"""Data pipeline: HPTMT table operators feeding tensor training.

This is the paper's flagship composition (Fig 14): *dataflow table
operators* pre-process a corpus, then hand off to *array/tensor operators*
for the numeric algorithm.  The synthetic corpus is a pair of tables —
documents (doc_id, quality, n_tokens) and token rows (doc_id, position,
token) — and the pipeline is

    select(quality ≥ θ) → join(tokens ⋈ docs) → orderby/shuffle
        → to_numpy() → fixed-length (tokens, labels) batches,

exactly the table→tensor bridge of paper Figs 13/17.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import DistTable, HPTMTContext, Table, TSet, table_ops


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    n_docs: int = 64
    mean_doc_len: int = 96
    vocab_size: int = 128
    quality_threshold: float = 0.3
    seed: int = 0


def synthetic_corpus_arrays(ccfg: CorpusConfig
                            ) -> Dict[str, Dict[str, np.ndarray]]:
    """Pure-numpy corpus generation: ``{"docs": cols, "tokens": cols}``.

    Shared by the in-memory path (:func:`synthetic_corpus`) and the
    on-disk dataset writer (``scripts/make_dataset.py``), so the scan
    ingest benchmark and the training pipeline read identical data.
    """
    rng = np.random.default_rng(ccfg.seed)
    lens = np.clip(rng.poisson(ccfg.mean_doc_len, ccfg.n_docs), 8, None)
    quality = rng.uniform(size=ccfg.n_docs).astype(np.float32)
    doc_ids = np.repeat(np.arange(ccfg.n_docs), lens).astype(np.int32)
    positions = np.concatenate([np.arange(l) for l in lens]).astype(np.int32)
    # token stream with mild structure so small models can learn it
    toks = ((doc_ids * 31 + positions * 7) % (ccfg.vocab_size - 2) + 1
            ).astype(np.int32)
    return {
        "docs": {"doc_id": np.arange(ccfg.n_docs, dtype=np.int32),
                 "quality": quality,
                 "n_tokens": lens.astype(np.int32)},
        "tokens": {"doc_id": doc_ids, "position": positions, "token": toks},
    }


def synthetic_corpus(ccfg: CorpusConfig, ctx: HPTMTContext
                     ) -> Dict[str, DistTable]:
    """Two-table corpus: docs metadata + flat token rows."""
    arrays = synthetic_corpus_arrays(ccfg)
    docs = Table.from_arrays(
        {k: jnp.asarray(v) for k, v in arrays["docs"].items()})
    tokens = Table.from_arrays(
        {k: jnp.asarray(v) for k, v in arrays["tokens"].items()})
    total = arrays["tokens"]["doc_id"].shape[0]
    p = ctx.n_shards
    return {
        "docs": DistTable.from_local(docs, ctx,
                                     capacity=-(-ccfg.n_docs // p)),
        "tokens": DistTable.from_local(tokens, ctx, capacity=-(-total // p)),
    }


def disk_corpus(root: str, ctx: HPTMTContext,
                quality_threshold: Optional[float] = None,
                ) -> Dict[str, DistTable]:
    """Scan a corpus written as on-disk datasets (``root/docs``,
    ``root/tokens``) back into distributed tables — the realistic ingest
    path (paper §VI: Parquet/Arrow interop feeding the table operators).

    Predicate pushdown happens at the storage layer: with a
    ``quality_threshold`` the docs scan skips whole fragments whose
    quality max falls below it, before any rows materialize.
    """
    import os

    from repro.io import pred, read_dataset

    doc_pred = (pred("quality", ">=", float(quality_threshold))
                if quality_threshold is not None else None)
    docs, ov_d, _ = read_dataset(os.path.join(root, "docs"), ctx=ctx,
                                 predicate=doc_pred)
    tokens, ov_t, _ = read_dataset(os.path.join(root, "tokens"), ctx=ctx)
    if ov_d or ov_t:
        raise RuntimeError(f"corpus scan overflowed ({int(ov_d + ov_t)} "
                           f"rows) — raise the scan capacity")
    return {"docs": docs, "tokens": tokens}


def preprocess(corpus: Dict[str, DistTable], ccfg: CorpusConfig,
               ctx: HPTMTContext) -> np.ndarray:
    """Dataflow pipeline → flat curated token stream (host array)."""
    docs = TSet.from_table(corpus["docs"], ctx)
    tokens = TSet.from_table(corpus["tokens"], ctx,
                             chunk_rows=max(corpus["tokens"].capacity // 4, 8))
    good = docs.select(lambda c: c["quality"] >= ccfg.quality_threshold) \
               .project(["doc_id", "quality"])
    curated = tokens.join(good, keys=["doc_id"],
                          out_capacity=corpus["tokens"].capacity)
    result = curated.collect()
    # global order by (doc, position) → deterministic stream
    ordered, _ = table_ops.orderby(result, "doc_id", ctx=ctx)
    arrs = ordered.to_numpy()
    order = np.lexsort((arrs["position"], arrs["doc_id"]))
    return arrs["token"][order]


def batch_iterator(stream: np.ndarray, batch: int, seq_len: int,
                   seed: int = 0) -> Iterator[Dict[str, jnp.ndarray]]:
    """Infinite (tokens, labels) batches from a curated token stream."""
    rng = np.random.default_rng(seed)
    n = len(stream) - (seq_len + 1)
    if n <= 0:
        reps = (seq_len + 2) // max(len(stream), 1) + 1
        stream = np.tile(stream, reps)
        n = len(stream) - (seq_len + 1)
    while True:
        starts = rng.integers(0, n, size=batch)
        toks = np.stack([stream[s:s + seq_len] for s in starts])
        labels = np.stack([stream[s + 1:s + seq_len + 1] for s in starts])
        yield {"tokens": jnp.asarray(toks, jnp.int32),
               "labels": jnp.asarray(labels, jnp.int32)}


def make_training_data(cfg: ModelConfig, ctx: HPTMTContext, batch: int,
                       seq_len: int, ccfg: Optional[CorpusConfig] = None,
                       data_root: Optional[str] = None,
                       ) -> Iterator[Dict[str, jnp.ndarray]]:
    """Batches from the synthetic corpus, or — with ``data_root`` — from
    an on-disk dataset corpus (``scripts/make_dataset.py``) via the
    storage scan ingest path."""
    ccfg = ccfg or CorpusConfig(vocab_size=cfg.vocab_size)
    corpus = (disk_corpus(data_root, ctx) if data_root is not None
              else synthetic_corpus(ccfg, ctx))
    stream = preprocess(corpus, ccfg, ctx)
    base = batch_iterator(stream, batch, seq_len, seed=ccfg.seed)
    if cfg.frontend is None and not cfg.is_encoder_decoder:
        return base

    def with_frontend():
        rng = np.random.default_rng(ccfg.seed + 1)
        for b in base:
            fe = rng.normal(size=(batch, cfg.frontend_seq, cfg.d_model)
                            ).astype(np.float32) * 0.02
            yield {**b, "frontend": jnp.asarray(fe)}

    return with_frontend()
