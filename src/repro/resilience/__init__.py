"""Pipeline fault tolerance (paper §VII-F, DESIGN.md §13).

The paper's prescription — "we can always handle the faults outside of
the operator code" — as a subsystem with three coupled pieces:

  faults.py    unified chaos-injection registry: site-addressable,
               seeded deterministic schedules, env-drivable; subsumes
               the legacy ``HPTMT_SPILL_FAULT`` knob
  policy.py    :class:`FaultPolicy` — the shared retry/backoff contract
               (typed retryable-vs-fatal split, deterministic jitter)
               consumed by scan, spill, stage commits and the workflow
               engine
  stages.py    lineage stage checkpoints: CRC-checked ``.hpt`` stage
               snapshots at exchange boundaries, keyed by a plan
               fingerprint; ``collect(policy=...)`` resumes from the
               last committed stage and re-runs only the suffix

Recovery events publish through :mod:`repro.telemetry` as
``fault.injected.*`` / ``retry.<site>`` counters, the
``recovery.resumed_from_stage`` gauge, and ``recovery.*`` spans.
"""
from .faults import (FAULTS_ENV, KINDS, FatalInjectedFault, InjectedFault,
                     arm, arm_schedule, clear, fire, fires, reset)
from .policy import FaultPolicy, RetryBudgetExceeded
from .stages import StageCheckpointer, plan_fingerprint, stage_hook

__all__ = [
    "FAULTS_ENV", "KINDS", "FatalInjectedFault", "InjectedFault",
    "arm", "arm_schedule", "clear", "fire", "fires", "reset",
    "FaultPolicy", "RetryBudgetExceeded",
    "StageCheckpointer", "plan_fingerprint", "stage_hook",
]
