"""Lineage stage checkpoints for planned pipelines (DESIGN.md §13.2).

``LazyFrame.collect(policy=FaultPolicy(checkpoint_dir=...))`` commits a
CRC-checked ``.hpt`` snapshot of every **stage boundary** — a physical
plan step that performs an exchange (``PlanStep.stage``) — as it
completes.  Snapshots are keyed by a deterministic **plan fingerprint**
(a canonical hash of the optimized logical tree + shard count), so a
restarted process recovers exactly the pipeline it crashed out of and
nothing else: recovery walks the planner's lineage, finds the last
committed stage, loads it from disk, and re-runs only the suffix —
bit-exact, because a snapshot stores the *full* static-shape buffers
(padding included) plus counts, partitioning, and the accumulated
overflow lineage.

Commit protocol (crash-safe at every point): write ``data.hpt`` +
``meta.json`` into ``stage_<i>.tmp/``, fire the ``checkpoint.commit``
injection site, then ``os.rename`` to ``stage_<i>/`` — the same
tmp-then-rename discipline as ``io.native`` / ``checkpoint.manager``.
A reader only ever sees fully-committed stages; stale ``*.tmp`` dirs
from a crash are swept on open.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import zlib
from typing import List, Optional, Tuple

import numpy as np

from repro import telemetry
from repro.core.table import DistTable
from repro.io.native import read_hpt, write_hpt

from . import faults


# ---------------------------------------------------------------------------
# plan fingerprint
# ---------------------------------------------------------------------------
def _canon_value(key: str, v) -> str:
    if key == "table":  # source DistTable: schema + counts + data identity
        cols = {k: np.asarray(v.columns[k]) for k in v.column_names}
        crc = 0
        for name in sorted(cols):
            crc = zlib.crc32(cols[name].tobytes(), crc)
            crc = zlib.crc32(f"{name}:{cols[name].dtype}".encode(), crc)
        return (f"table(cols={list(sorted(cols))},"
                f"counts={np.asarray(v.counts).tolist()},"
                f"part={v.partitioning!r},crc={crc:08x})")
    if key == "dataset":
        frags = sorted((f.path, int(f.rows), f.shard)
                       for f in v.fragments)
        return f"dataset({frags!r},schema={list(v.schema.names)!r})"
    if callable(v):
        return f"fn({getattr(v, '__module__', '?')}." \
               f"{getattr(v, '__qualname__', repr(v))})"
    if isinstance(v, (tuple, list)):
        return repr([_canon_value("", x) for x in v])
    if isinstance(v, dict):
        return repr(sorted((k, _canon_value("", x)) for k, x in v.items()))
    return repr(v)


def _canon_node(node) -> str:
    payload = ";".join(f"{k}={_canon_value(k, v)}"
                       for k, v in sorted(node.payload.items()))
    kids = ",".join(_canon_node(i) for i in node.inputs)
    return f"{node.kind}[{payload}]({kids})"


def plan_fingerprint(root, ctx) -> str:
    """Deterministic identity of (optimized logical plan, mesh size):
    equal across processes for the same pipeline over the same data, so
    a restart resumes its own stages and never someone else's."""
    text = f"shards={ctx.n_shards}|{_canon_node(root)}"
    return hashlib.sha256(text.encode()).hexdigest()[:24]


# ---------------------------------------------------------------------------
# partitioning (de)serialization — the three metadata forms of core.table
# ---------------------------------------------------------------------------
def _part_to_json(part):
    if part is None:
        return None
    if part[0] == "range":
        return {"kind": "range", "keys": list(part[1]),
                "ascending": [bool(a) for a in part[2]], "n": int(part[3])}
    return {"kind": "hash", "keys": list(part[0]), "n": int(part[1])}


def _part_from_json(d):
    if d is None:
        return None
    if d["kind"] == "range":
        return ("range", tuple(d["keys"]),
                tuple(bool(a) for a in d["ascending"]), int(d["n"]))
    return (tuple(d["keys"]), int(d["n"]))


# ---------------------------------------------------------------------------
# stage checkpoint store
# ---------------------------------------------------------------------------
class StageCheckpointer:
    """One pipeline's stage snapshots: ``<root>/<fingerprint>/stage_<i>/``."""

    def __init__(self, root_dir: str, fingerprint: str):
        self.dir = os.path.join(root_dir, fingerprint)
        os.makedirs(self.dir, exist_ok=True)
        for name in os.listdir(self.dir):  # sweep torn commits
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)

    def _stage_dir(self, index: int) -> str:
        return os.path.join(self.dir, f"stage_{index}")

    def committed_stages(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("stage_") and not name.endswith(".tmp") \
                    and os.path.exists(os.path.join(self.dir, name,
                                                    "meta.json")):
                out.append(int(name[len("stage_"):]))
        return sorted(out)

    def commit(self, index: int, dt: DistTable,
               ovs: List[Tuple[str, object]], *, op: str = "") -> str:
        """Atomically snapshot one completed stage (full buffers +
        counts + partitioning + overflow lineage so far)."""
        final = self._stage_dir(index)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        cols = {k: np.asarray(v) for k, v in dt.columns.items()}
        rows = next(iter(cols.values())).shape[0] if cols else 0
        write_hpt(os.path.join(tmp, "data.hpt"), cols, rows)
        meta = {"stage": int(index), "op": op,
                "n_shards": int(dt.n_shards),
                "capacity": int(dt.capacity),
                "counts": np.asarray(dt.counts).tolist(),
                "partitioning": _part_to_json(dt.partitioning),
                "ovs": [[label, int(v)] for label, v in ovs]}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        faults.fire("checkpoint.commit", path=final)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # commit point: all-or-nothing
        return final

    def restore(self, index: int, ctx=None
                ) -> Tuple[DistTable, List[Tuple[str, int]]]:
        """Load a committed stage back into a :class:`DistTable` (CRC
        checked by the ``.hpt`` reader) + its overflow lineage."""
        import jax.numpy as jnp

        d = self._stage_dir(index)
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        cols, _ = read_hpt(os.path.join(d, "data.hpt"))
        dt = DistTable({k: jnp.asarray(v) for k, v in cols.items()},
                       jnp.asarray(meta["counts"], jnp.int32),
                       _part_from_json(meta["partitioning"]))
        if ctx is not None and getattr(ctx, "mesh", None) is not None \
                and not telemetry.tracing():
            dt = dt.with_sharding(ctx)
        return dt, [(label, int(v)) for label, v in meta["ovs"]]

    def remove(self) -> None:
        shutil.rmtree(self.dir, ignore_errors=True)


def stage_hook(ckpt: StageCheckpointer, *, policy=None, ctx=None,
               committed: Optional[set] = None, record=None):
    """Build the per-stage hook ``PhysicalPlan`` consults at run time.

    For a stage already committed on disk the hook returns the restored
    snapshot WITHOUT running the step's closure — the whole subtree
    below it is skipped, which is what makes a resumed run's traced
    program a strict suffix (the jaxpr-asserted recovery contract).
    Otherwise it runs the step and commits the result (never while jax
    is tracing: commits are host I/O on concrete arrays).
    """
    have = set(ckpt.committed_stages()) if committed is None else committed

    def hook(step, layout, thunk):
        if step.index in have:
            with telemetry.span("recovery.restore", stage=step.index,
                                op=step.op):
                out = ckpt.restore(step.index, ctx)
            if record is not None:
                record.metrics.count("recovery.stages_restored")
            return out
        out, ovs = thunk()
        if not telemetry.tracing():
            with telemetry.span("recovery.commit", stage=step.index,
                                op=step.op):
                if policy is not None:
                    policy.run(
                        lambda: ckpt.commit(step.index, out, ovs,
                                            op=step.op),
                        site="checkpoint.commit")
                else:
                    ckpt.commit(step.index, out, ovs, op=step.op)
            have.add(step.index)
            if record is not None:
                record.metrics.count("recovery.stages_committed")
        return out, ovs

    return hook
