"""Unified chaos-injection registry (DESIGN.md §13.3).

One module-level registry of *armed* faults, addressed by **site** — a
dotted name for an injection point the runtime passes through::

    scan.read            per fragment-run read in ``io.scan``
    spill.write          per run-file write in ``spill.store``
    plan.step.<idx>      entry of physical plan step ``<idx>``
    checkpoint.commit    just before a stage checkpoint's atomic rename

Every site calls :func:`fire` with its name; when nothing is armed the
call is a cheap no-op (two env lookups, no allocation), so production
paths carry no chaos overhead.  An armed fault counts down ``nth``
occurrences at its site, raises (or kills the process) on the ``nth``,
then **disarms** — so a retry under the same environment succeeds, which
is exactly the contract the retry/backoff layer is tested against.

Arming is programmatic (:func:`arm`, :func:`arm_schedule` for seeded
deterministic schedules) or via environment::

    HPTMT_FAULTS="scan.read:io_error:2;checkpoint.commit:crash:1"

The legacy ``HPTMT_SPILL_FAULT="<point>:<n>"`` knob is kept as a
back-compat alias for site ``spill.write`` (``point`` one of
``disk_full`` / ``partial_write``) with identical semantics.

Fault kinds:

  io_error       raise :class:`InjectedFault` (``EIO``) — retryable
  disk_full      raise :class:`InjectedFault` (``ENOSPC``) — retryable
  partial_write  tear a half-written ``<path>.tmp`` then raise ``EIO``
  fatal          raise :class:`FatalInjectedFault` (a ``ValueError``) —
                 the typed-fatal family, must fail fast, never retry
  crash          ``SIGKILL`` the current process (kill-and-resume tests)

Fires are counted per site (:func:`fires`) and published to an active
telemetry collector as ``fault.injected.<site>`` counters.
"""
from __future__ import annotations

import dataclasses
import errno
import os
import signal
from typing import Dict, List, Optional, Sequence, Tuple

FAULTS_ENV = "HPTMT_FAULTS"
SPILL_FAULT_ENV = "HPTMT_SPILL_FAULT"
SPILL_FAULT_POINTS = ("disk_full", "partial_write")
KINDS = ("io_error", "disk_full", "partial_write", "fatal", "crash")


class InjectedFault(OSError):
    """A chaos-injected *transient* failure (an ``OSError``): the
    retryable family — a retry after the injector disarms succeeds."""


class FatalInjectedFault(ValueError):
    """A chaos-injected *fatal* failure (a ``ValueError``): the typed
    non-retryable family — policies must fail fast, never retry."""


@dataclasses.dataclass
class _Arm:
    site: str
    kind: str
    remaining: int
    fired: bool = False


# programmatic arms + env-derived arms are tracked separately so an env
# change mid-run re-arms the env set without clobbering test-armed faults
_prog_arms: List[_Arm] = []
_env_arms: List[_Arm] = []
_env_cache: Dict[str, Optional[str]] = {"faults": None, "spill": None}
_counts: Dict[str, int] = {}


def _parse_env_faults(spec: str) -> List[_Arm]:
    arms = []
    for part in spec.split(";"):
        part = part.strip()
        if not part:
            continue
        bits = part.split(":")
        if len(bits) < 2:
            raise ValueError(
                f"{FAULTS_ENV}={spec!r}: entry {part!r} is not "
                f"'<site>:<kind>[:<nth>]'")
        site, kind = bits[0], bits[1]
        if kind not in KINDS:
            raise ValueError(f"{FAULTS_ENV}={spec!r}: unknown fault kind "
                             f"{kind!r}; expected one of {KINDS}")
        nth = int(bits[2]) if len(bits) > 2 and bits[2] else 1
        arms.append(_Arm(site, kind, nth))
    return arms


def _parse_env_spill(spec: str) -> List[_Arm]:
    point, _, count = spec.partition(":")
    if point not in SPILL_FAULT_POINTS:
        raise ValueError(
            f"{SPILL_FAULT_ENV}={spec!r}: unknown fault point {point!r}; "
            f"expected one of {SPILL_FAULT_POINTS}")
    return [_Arm("spill.write", point, int(count) if count else 1)]


def _sync_env() -> None:
    """Re-arm from the environment iff it changed since the last look —
    keeps the one-shot "fired" memory stable under an unchanged env."""
    faults = os.environ.get(FAULTS_ENV)
    spill = os.environ.get(SPILL_FAULT_ENV)
    if faults == _env_cache["faults"] and spill == _env_cache["spill"]:
        return
    _env_cache["faults"] = faults
    _env_cache["spill"] = spill
    _env_arms.clear()
    if faults:
        _env_arms.extend(_parse_env_faults(faults))
    if spill:
        _env_arms.extend(_parse_env_spill(spill))


def arm(site: str, kind: str, nth: int = 1) -> None:
    """Arm one fault: the ``nth`` future :func:`fire` at ``site`` raises
    ``kind``; the arm then disarms (one-shot)."""
    if kind not in KINDS:
        raise ValueError(f"unknown fault kind {kind!r}; "
                         f"expected one of {KINDS}")
    if nth < 1:
        raise ValueError(f"nth={nth} must be >= 1")
    _prog_arms.append(_Arm(site, kind, nth))


def arm_schedule(seed: int, sites: Sequence[str], *,
                 kinds: Sequence[str] = ("io_error",), n_faults: int = 1,
                 max_nth: int = 3) -> List[Tuple[str, str, int]]:
    """Arm a seeded deterministic schedule of ``n_faults`` faults drawn
    over ``sites`` × ``kinds``; returns the armed ``(site, kind, nth)``
    tuples so a harness can log / bound-check what it injected."""
    import numpy as np

    rng = np.random.default_rng(seed)
    armed = []
    for _ in range(n_faults):
        site = sites[int(rng.integers(len(sites)))]
        kind = kinds[int(rng.integers(len(kinds)))]
        nth = int(rng.integers(1, max_nth + 1))
        arm(site, kind, nth)
        armed.append((site, kind, nth))
    return armed


def clear() -> None:
    """Disarm everything and zero the fire counters (env stays cached:
    an unchanged env does not re-arm)."""
    _prog_arms.clear()
    _env_arms.clear()
    _counts.clear()


def reset() -> None:
    """Full reset: disarm, zero counters, and re-arm from the current
    environment on the next :func:`fire` (test fixtures call this)."""
    clear()
    _env_cache["faults"] = None
    _env_cache["spill"] = None


def fires(site: Optional[str] = None) -> int:
    """How many faults have fired (at ``site``, or in total)."""
    if site is not None:
        return _counts.get(site, 0)
    return sum(_counts.values())


def _trigger(a: _Arm, path: Optional[str]) -> None:
    _counts[a.site] = _counts.get(a.site, 0) + 1
    from repro import telemetry

    rec = telemetry.current()
    if rec is not None:
        rec.metrics.count(f"fault.injected.{a.site}")
    where = path or a.site
    if a.kind == "crash":
        os.kill(os.getpid(), signal.SIGKILL)
    if a.kind == "fatal":
        raise FatalInjectedFault(
            f"injected fatal fault at {a.site} ({where})")
    if a.kind == "disk_full":
        raise InjectedFault(errno.ENOSPC, "injected disk-full", where)
    if a.kind == "partial_write":
        if path is not None:  # tear a half-written tmp, then die mid-write
            with open(path + ".tmp", "wb") as f:
                f.write(b"HPT1\x00")
        raise InjectedFault(errno.EIO, "injected partial write", where)
    raise InjectedFault(errno.EIO, "injected io error", where)


def fire(site: str, path: Optional[str] = None) -> None:
    """Injection point: no-op unless a matching fault is armed.

    Every IO/exec layer calls this with its site name; ``path`` (when
    the site writes a file) lets ``partial_write`` tear ``<path>.tmp``
    exactly like a mid-write crash would.
    """
    _sync_env()
    if not _prog_arms and not _env_arms:
        return
    for a in _prog_arms + _env_arms:
        if a.fired or a.site != site:
            continue
        a.remaining -= 1
        if a.remaining > 0:
            return
        a.fired = True  # disarm: the retry under the same env succeeds
        _trigger(a, path)
        return
