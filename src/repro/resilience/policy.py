"""Shared retry/backoff policy — the one retry loop every layer uses.

:class:`FaultPolicy` carries the whole fault-handling contract of a run
(DESIGN.md §13.4): how many times to retry, how long to back off
(exponential with *deterministic* jitter — reproducible schedules, no
wall-clock randomness), which exception types are retryable vs fatal,
and where stage checkpoints go.  It is consumed by

  * ``LazyFrame.collect(policy=...)`` — stage checkpoints + whole-plan
    retry (``plan.collect`` site),
  * ``io.scan.ScanSource`` — per-fragment-run read retries,
  * ``spill.SpillStore`` — run-write retries,
  * stage-checkpoint commits (``checkpoint.commit`` site),
  * ``workflow.WorkflowEngine`` — task retries with backoff.

Retry taxonomy: the **fatal** tuple (``ValueError``/``TypeError``/...)
fails fast — those are programming or corruption errors where a retry
re-runs the same deterministic failure (``HptIntegrityError`` and
``CorruptFragmentError`` are ``ValueError`` subclasses precisely so
corruption is never retried).  Everything else is presumed transient
(``OSError``, ``RuntimeError``) unless an explicit ``retryable`` tuple
narrows it.  Exhausted budgets raise :class:`RetryBudgetExceeded`,
itself classified fatal so nested policies never multiply retries.
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from typing import Callable, Optional, Tuple


class RetryBudgetExceeded(RuntimeError):
    """A site failed on every attempt the policy allowed.  ``__cause__``
    carries the last underlying error.  Classified fatal by every
    :class:`FaultPolicy`, so an outer retry loop fails fast instead of
    multiplying the inner budget."""


_DEFAULT_FATAL = (ValueError, TypeError, KeyError, AttributeError,
                  NotImplementedError, AssertionError, RetryBudgetExceeded)


@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """Immutable fault-handling contract; share one per run.

    ``max_retries`` bounds RETRIES — a site gets ``max_retries + 1``
    attempts.  Backoff before retry ``k`` (0-based) is
    ``min(backoff_base * backoff_factor**k, backoff_max)`` scaled by a
    deterministic per-``(site, attempt)`` jitter in ``[1, 1+jitter]``.

    ``checkpoint_dir`` enables lineage stage checkpoints under
    ``collect(policy=...)``; ``keep_checkpoints=False`` removes them
    after a successful collect (a crash leaves them for resume).
    """
    max_retries: int = 3
    backoff_base: float = 0.01
    backoff_factor: float = 2.0
    backoff_max: float = 1.0
    jitter: float = 0.1
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: bool = False
    retryable: Optional[Tuple[type, ...]] = None
    fatal: Tuple[type, ...] = _DEFAULT_FATAL

    def is_retryable(self, exc: BaseException) -> bool:
        """Fatal types fail fast; otherwise retryable (or only the
        explicit ``retryable`` tuple when one is given)."""
        if isinstance(exc, self.fatal):
            return False
        if self.retryable is not None:
            return isinstance(exc, self.retryable)
        return True

    def delay(self, attempt: int, site: str = "") -> float:
        """Backoff before retry ``attempt`` (deterministic: same site +
        attempt → same delay, across processes and reruns)."""
        d = min(self.backoff_base * self.backoff_factor ** attempt,
                self.backoff_max)
        frac = (zlib.crc32(f"{site}:{attempt}".encode()) % 1000) / 999.0
        return d * (1.0 + self.jitter * frac)

    def run(self, fn: Callable, *, site: str,
            sleep: Callable[[float], None] = time.sleep):
        """Invoke ``fn()`` under this policy's retry loop.

        Publishes a ``retry.<site>`` counter per retry on the active
        telemetry collector; raises the original exception for fatal
        failures and :class:`RetryBudgetExceeded` on exhaustion.
        """
        from repro import telemetry

        last: Optional[BaseException] = None
        for attempt in range(self.max_retries + 1):
            try:
                return fn()
            except Exception as e:  # noqa: BLE001 — classified below
                if not self.is_retryable(e):
                    raise
                last = e
                if attempt < self.max_retries:
                    rec = telemetry.current()
                    if rec is not None:
                        rec.metrics.count(f"retry.{site}")
                    sleep(self.delay(attempt, site))
        raise RetryBudgetExceeded(
            f"site {site!r}: all {self.max_retries + 1} attempts failed; "
            f"last error: {type(last).__name__}: {last}") from last
