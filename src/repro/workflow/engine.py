"""Workflow orchestration — the paper's separation-of-concerns layer (§VII-D/E).

The parallel program (operators) does the computing; the *workflow engine*
owns scheduling, retries, and fault tolerance (§VII-F: "we can always handle
the faults outside of the operator code").  Tasks form a DAG; completed
tasks are journaled so a crashed run resumes from the last barrier instead
of recomputing — the same contract a Pegasus/Kubeflow deployment gives the
multi-pod trainer, scaled down to one process for this container.

Retries route through the shared :class:`~repro.resilience.FaultPolicy`
(DESIGN.md §13.4): transient failures back off exponentially with
deterministic jitter; typed-fatal exceptions (``ValueError``/
``TypeError``/...) fail fast instead of burning the budget on a
deterministic bug.  The journal records a content hash per completed
task (its name + dependency edges), so resuming against a *changed* DAG
is detected and refused instead of silently skipping different work.

Also hosts the straggler monitor: per-step wall-time dispersion tracking
that a production launcher would use to evict/replace slow hosts.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro import telemetry
from repro.resilience.policy import FaultPolicy, RetryBudgetExceeded


@dataclasses.dataclass
class Task:
    name: str
    fn: Callable[..., Any]
    deps: Sequence[str] = ()
    retries: int = 2
    policy: Optional[FaultPolicy] = None  # overrides retries/backoff
    # results of deps are passed as kwargs keyed by dep name


class WorkflowError(RuntimeError):
    pass


def _task_hash(name: str, deps: Sequence[str]) -> str:
    """Journal identity of a task: its name + dependency edges.

    Deliberately NOT the function body — a restarted process rebuilds
    the DAG with fresh closures (different bytecode addresses, same
    work), and those must still match their journal entries.
    """
    text = json.dumps([name, sorted(deps)])
    return hashlib.sha256(text.encode()).hexdigest()[:16]


class WorkflowEngine:
    def __init__(self, journal_path: Optional[str] = None,
                 policy: Optional[FaultPolicy] = None):
        self.tasks: Dict[str, Task] = {}
        self.journal_path = journal_path
        self.policy = policy  # engine-wide default retry policy
        self._done: Dict[str, Any] = {}
        if journal_path and os.path.exists(journal_path):
            with open(journal_path) as f:
                self._done = json.load(f)

    def add(self, task: Task) -> "WorkflowEngine":
        if task.name in self.tasks:
            raise ValueError(f"duplicate task {task.name}")
        self.tasks[task.name] = task
        return self

    def _journal(self):
        if self.journal_path:
            tmp = self.journal_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(self._done, f)
            os.replace(tmp, self.journal_path)

    def run(self, context: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
        """Execute the DAG; returns {task: result}. Resumes past journaled
        tasks (their results must be re-derivable from ``context`` or
        checkpoints — the HPTMT contract: state lives in checkpoints, not
        in the workflow engine)."""
        results: Dict[str, Any] = dict(context or {})
        order = self._topo_order()
        rec = telemetry.current()
        for name in order:
            task = self.tasks[name]
            digest = _task_hash(name, task.deps)
            done = self._done.get(name)
            if done:
                # Dict entries carry a content hash; a mismatch means the
                # journal describes a *different* DAG (renamed deps, edited
                # edges) and silently skipping would corrupt the resume.
                # Legacy `true` entries predate hashing and skip as before.
                if isinstance(done, dict) and done.get("hash") != digest:
                    raise WorkflowError(
                        f"stale journal: task {name} was journaled with a "
                        f"different definition (hash {done.get('hash')!r} != "
                        f"{digest!r}); delete {self.journal_path} to rerun")
                if rec is not None:
                    rec.metrics.count("workflow.replayed")
                continue
            kwargs = {d: results.get(d) for d in task.deps}
            pol = task.policy or self.policy or FaultPolicy(
                max_retries=task.retries, backoff_base=0.005,
                backoff_max=0.1)
            attempts = [0]

            def call(_task=task, _kwargs=kwargs, _attempts=attempts):
                _attempts[0] += 1
                return _task.fn(**_kwargs)

            try:
                with telemetry.span(f"workflow.{name}",
                                    deps=list(task.deps)) as sp:
                    results[name] = pol.run(call, site=f"workflow.{name}")
                    sp.attrs["attempts"] = attempts[0]
            except RetryBudgetExceeded as e:
                raise WorkflowError(
                    f"task {name} failed after {pol.max_retries + 1} attempts"
                ) from e
            except Exception as e:  # typed-fatal: don't mask the bug class
                raise WorkflowError(
                    f"task {name} raised non-retryable "
                    f"{type(e).__name__}: {e}") from e
            finally:
                if rec is not None and attempts[0] > 1:
                    rec.metrics.count("workflow.retries", attempts[0] - 1)
            if rec is not None:
                rec.metrics.count("workflow.tasks_run")
            self._done[name] = {"hash": digest}
            self._journal()
        return results

    def _topo_order(self) -> List[str]:
        seen: Dict[str, int] = {}
        order: List[str] = []

        def visit(n: str):
            state = seen.get(n, 0)
            if state == 1:
                raise WorkflowError(f"cycle at task {n}")
            if state == 2:
                return
            seen[n] = 1
            for d in self.tasks[n].deps:
                if d not in self.tasks:
                    raise WorkflowError(f"task {n} depends on unknown {d}")
                visit(d)
            seen[n] = 2
            order.append(n)

        for n in self.tasks:
            visit(n)
        return order


class StragglerMonitor:
    """Flags steps (or peers) whose wall time exceeds k× the running median.

    On a real pod this drives re-scheduling / hot-spare swap; here it feeds
    trainer logs and is unit-tested against synthetic timings.
    """

    def __init__(self, window: int = 50, threshold: float = 2.0):
        self.window = window
        self.threshold = threshold
        self.samples: List[float] = []
        self.flagged: List[int] = []
        self._i = 0

    def record(self, seconds: float) -> bool:
        self.samples.append(seconds)
        if len(self.samples) > self.window:
            self.samples.pop(0)
        slow = False
        if len(self.samples) >= 5:
            srt = sorted(self.samples)
            median = srt[len(srt) // 2]
            slow = seconds > self.threshold * median
        if slow:
            self.flagged.append(self._i)
        self._i += 1
        return slow


class Stopwatch:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0
