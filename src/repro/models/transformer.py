"""Model composition: layer blocks → scanned stacks → full LMs.

Supports the ten assigned architectures through ``ModelConfig``:
decoder-only dense/GQA/SWA/MLA, MoE FFNs, hybrid Mamba+attention groups
(Jamba), xLSTM stacks, encoder–decoder with stub audio frontend (Whisper),
and VLM token streams with stub patch embeddings (InternVL2).

Layer stacking uses ``lax.scan`` over *groups* (one group = one repetition
of ``cfg.block_pattern``) with per-group ``jax.checkpoint`` — the HLO holds
one group body regardless of depth (95-layer DeepSeek compiles as fast as
12-layer xLSTM), and remat keeps activation memory to one group.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.axes import constrain, embed_lookup

from . import moe as moe_mod
from . import ssm as ssm_mod
from . import xlstm as xlstm_mod
from .layers import (Params, _dense_init, gqa_attention, init_attention,
                     init_mla, init_mlp, init_rmsnorm, mla_attention, mlp,
                     rms_norm)

_ZERO_METRICS = ("moe_aux_loss", "router_z_loss", "moe_dropped_frac")


def _layer_has_moe(cfg: ModelConfig, i: int, kind: str) -> bool:
    if not cfg.is_moe or cfg.d_ff == 0 or kind in ("mlstm", "slstm"):
        return False
    return i % cfg.moe_every == cfg.moe_every - 1


def _layer_has_ffn(cfg: ModelConfig, kind: str) -> bool:
    return cfg.d_ff > 0 and kind not in ("mlstm", "slstm")


# ---------------------------------------------------------------------------
# single layer
# ---------------------------------------------------------------------------
def init_layer(rng, cfg: ModelConfig, kind: str, i: int,
               cross: bool = False) -> Params:
    k_mix, k_ffn, k_cross = jax.random.split(rng, 3)
    p: Params = {}
    if kind == "attn":
        p["mixer"] = (init_mla(k_mix, cfg) if cfg.attention == "mla"
                      else init_attention(k_mix, cfg))
    elif kind == "mamba":
        p["mixer"] = ssm_mod.init_mamba(k_mix, cfg)
    elif kind == "mlstm":
        p["mixer"] = xlstm_mod.init_mlstm(k_mix, cfg)
    elif kind == "slstm":
        p["mixer"] = xlstm_mod.init_slstm(k_mix, cfg)
    else:
        raise ValueError(kind)
    if cross:
        p["cross"] = init_attention(k_cross, cfg)
    if _layer_has_moe(cfg, i, kind):
        p["ffn"] = moe_mod.init_moe(k_ffn, cfg)
    elif _layer_has_ffn(cfg, kind):
        p["ffn"] = init_mlp(k_ffn, cfg)
    return p


def apply_layer(p: Params, cfg: ModelConfig, x, kind: str, i: int, *,
                mode: str, cache, positions, enc_out=None, causal=True,
                cache_len: int = 0):
    metrics = {k: jnp.zeros((), jnp.float32) for k in _ZERO_METRICS}
    mix_cache = cache["mixer"] if cache is not None else None
    if kind == "attn":
        fn = mla_attention if cfg.attention == "mla" else gqa_attention
        dx, new_mix = fn(p["mixer"], cfg, x, positions=positions, mode=mode,
                         cache=mix_cache, cache_len=cache_len,
                         **({} if cfg.attention == "mla"
                            else {"causal": causal}))
    elif kind == "mamba":
        dx, new_mix = ssm_mod.mamba_mixer(p["mixer"], cfg, x, mode=mode,
                                          cache=mix_cache)
    elif kind == "mlstm":
        dx, new_mix = xlstm_mod.mlstm_mixer(p["mixer"], cfg, x, mode=mode,
                                            cache=mix_cache)
    else:  # slstm
        dx, new_mix = xlstm_mod.slstm_mixer(p["mixer"], cfg, x, mode=mode,
                                            cache=mix_cache)
    x = x + dx

    if "cross" in p:
        cdx, _ = gqa_attention(p["cross"], cfg, x, positions=positions,
                               mode="train", kv_source=enc_out, causal=False)
        x = x + cdx

    if "ffn" in p:
        if _layer_has_moe(cfg, i, kind):
            dff, m = moe_mod.moe_ffn(p["ffn"], cfg, x)
            for k, v in m.items():
                metrics[k] = metrics[k] + v
        else:
            dff = mlp(p["ffn"], cfg, x)
        x = x + dff
    new_cache = {"mixer": new_mix} if new_mix is not None else None
    return x, new_cache, metrics


# ---------------------------------------------------------------------------
# scanned stack of groups
# ---------------------------------------------------------------------------
def init_stack(rng, cfg: ModelConfig, cross: bool = False) -> Params:
    def one_group(key):
        ks = jax.random.split(key, cfg.group_size)
        return {f"layer_{i}": init_layer(ks[i], cfg, kind, i, cross)
                for i, kind in enumerate(cfg.block_pattern)}

    keys = jax.random.split(rng, cfg.n_groups)
    return jax.vmap(one_group)(keys)


def init_group_cache(cfg: ModelConfig, batch: int, cache_len: int,
                     dtype) -> Params:
    """Zero decode cache for one group (stacked by caller)."""
    out = {}
    for i, kind in enumerate(cfg.block_pattern):
        if kind == "attn":
            if cfg.attention == "mla":
                mix = {
                    "c_kv": jnp.zeros((batch, cache_len, cfg.kv_lora_rank),
                                      dtype),
                    "k_rope": jnp.zeros((batch, 1, cache_len,
                                         cfg.qk_rope_dim), dtype),
                    "pos": jnp.full((cache_len,), -1, jnp.int32),
                    "cursor": jnp.zeros((), jnp.int32)}
            else:
                l = cfg.decode_cache_len(cache_len)
                hk, dh = cfg.n_kv_heads, cfg.head_dim
                kv_dt = jnp.int8 if cfg.kv_quant else dtype
                mix = {"k": jnp.zeros((batch, hk, l, dh), kv_dt),
                       "v": jnp.zeros((batch, hk, l, dh), kv_dt),
                       "pos": jnp.full((l,), -1, jnp.int32),
                       "cursor": jnp.zeros((), jnp.int32)}
                if cfg.kv_quant:
                    mix["k_s"] = jnp.full((batch, hk, l, 1), 1e-8,
                                          jnp.float32)
                    mix["v_s"] = jnp.full((batch, hk, l, 1), 1e-8,
                                          jnp.float32)
        elif kind == "mamba":
            mix = ssm_mod.init_mamba_cache(cfg, batch, dtype)
        elif kind == "mlstm":
            mix = xlstm_mod.init_mlstm_cache(cfg, batch)
        else:
            mix = xlstm_mod.init_slstm_cache(cfg, batch)
        out[f"layer_{i}"] = {"mixer": mix}
    return out


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> Params:
    one = init_group_cache(cfg, batch, cache_len, dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_groups,) + a.shape), one)


@jax.custom_vjp
def _grad_transparent_barrier(x):
    return jax.lax.optimization_barrier(x)


def _gtb_fwd(x):
    return jax.lax.optimization_barrier(x), None


def _gtb_bwd(_, g):
    return (g,)


# optimization_barrier has no differentiation rule on older jax; keep the
# barrier in the forward pass and pass cotangents straight through.
_grad_transparent_barrier.defvjp(_gtb_fwd, _gtb_bwd)


def apply_stack(stacked: Params, cfg: ModelConfig, x, *, mode: str,
                caches=None, positions=None, enc_out=None, causal=True,
                cache_len: int = 0):
    def body(carry, inp):
        x, aux = carry
        # barrier: stops XLA hoisting the bf16→f32 norm upcast out of the
        # (rematerialized) body — without it the scan's saved per-group
        # residual stack is materialized in f32, doubling activation memory.
        x = _grad_transparent_barrier(x)
        gp = inp[0] if isinstance(inp, tuple) else inp
        gc = inp[1] if isinstance(inp, tuple) else None
        new_caches = {}
        for i, kind in enumerate(cfg.block_pattern):
            lc = gc[f"layer_{i}"] if gc is not None else None
            x, nc, m = apply_layer(
                gp[f"layer_{i}"], cfg, x, kind, i, mode=mode, cache=lc,
                positions=positions, enc_out=enc_out, causal=causal,
                cache_len=cache_len)
            for k, v in m.items():
                aux[k] = aux[k] + v
            if nc is not None:
                new_caches[f"layer_{i}"] = nc
        ys = new_caches if new_caches else None
        return (x, aux), ys

    if cfg.remat:
        body = jax.checkpoint(body)

    aux0 = {k: jnp.zeros((), jnp.float32) for k in _ZERO_METRICS}
    xs = stacked if caches is None else (stacked, caches)
    (x, aux), new_caches = jax.lax.scan(
        body, (x, aux0), xs, unroll=True if cfg.scan_unroll else 1)
    return x, new_caches, aux


# ---------------------------------------------------------------------------
# full language model
# ---------------------------------------------------------------------------
def init_lm(rng, cfg: ModelConfig) -> Params:
    ks = jax.random.split(rng, 5)
    p: Params = {
        "embed": _dense_init(ks[0], (cfg.vocab_size, cfg.d_model),
                             fan_in=cfg.d_model),
        "final_norm": init_rmsnorm(cfg.d_model),
        "decoder": init_stack(ks[1], cfg, cross=cfg.is_encoder_decoder),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = _dense_init(ks[2], (cfg.d_model, cfg.vocab_size))
    if cfg.is_encoder_decoder:
        enc_cfg = encoder_config(cfg)
        p["encoder"] = init_stack(ks[3], enc_cfg)
        p["enc_norm"] = init_rmsnorm(cfg.d_model)
    return p


def encoder_config(cfg: ModelConfig):
    import dataclasses
    return dataclasses.replace(
        cfg, n_layers=cfg.n_encoder_layers, block_pattern=("attn",),
        n_experts=0, window=None)


def _encode(params: Params, cfg: ModelConfig, frontend_embeds: jnp.ndarray):
    enc_cfg = encoder_config(cfg)
    f = frontend_embeds.shape[1]
    pos = jnp.arange(f, dtype=jnp.int32)
    h, _, _ = apply_stack(params["encoder"], enc_cfg, frontend_embeds,
                          mode="train", positions=pos, causal=False)
    return rms_norm(params["enc_norm"], h, cfg.norm_eps)


def apply_lm(params: Params, cfg: ModelConfig, tokens: jnp.ndarray, *,
             mode: str = "train", cache: Optional[Params] = None,
             positions: Optional[jnp.ndarray] = None,
             frontend_embeds: Optional[jnp.ndarray] = None,
             cache_len: int = 0, last_logit_only: bool = False,
             ) -> Tuple[jnp.ndarray, Optional[Params], Dict[str, Any]]:
    """tokens (B, S) → logits (B, S, V).

    ``frontend_embeds``: audio frames (enc-dec) or image patches (VLM,
    prepended to the token stream).  ``positions`` default to
    ``arange(S)`` (train/prefill) and must be given for decode.
    ``last_logit_only``: serving prefill needs logits for the final
    position only — skipping the (B,S,V) head matmul + its TP reduction is
    a large collective/memory win (EXPERIMENTS.md §Perf).
    """
    dtype = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    x = embed_lookup(params["embed"], tokens).astype(dtype)
    x = constrain(x, "batch", "seq", "embed")

    enc_out = None
    if cfg.is_encoder_decoder:
        if mode == "decode":
            enc_out = cache["enc_out"]
        else:
            enc_out = _encode(params, cfg, frontend_embeds.astype(dtype))
    elif cfg.frontend == "vision" and mode != "decode":
        # VLM: image patch embeddings prefix the token stream
        x = jnp.concatenate([frontend_embeds.astype(dtype), x], axis=1)
        s = x.shape[1]

    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)

    groups_cache = cache["groups"] if cache is not None else None
    x, new_groups, aux = apply_stack(
        params["decoder"], cfg, x, mode=mode, caches=groups_cache,
        positions=positions, enc_out=enc_out, cache_len=cache_len)

    if last_logit_only:
        x = x[:, -1:]
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(dtype)
    logits = x @ head
    logits = constrain(logits, "batch", "seq", "vocab")

    new_cache = None
    if mode in ("prefill", "decode") and new_groups is not None:
        new_cache = {"groups": new_groups}
        if cfg.is_encoder_decoder:
            new_cache["enc_out"] = enc_out
    return logits.astype(jnp.float32), new_cache, aux
