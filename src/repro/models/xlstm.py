"""xLSTM mixers: chunkwise-parallel mLSTM and recurrent sLSTM.

mLSTM (matrix-memory LSTM) is attention-free but trainable in parallel: the
sequence is split into chunks; within a chunk the stabilized closed form is
two MXU matmuls (q·kᵀ weighted by gate-decay matrix, then ·v), and an outer
``lax.scan`` carries the (C, n, m) state across chunks — O(S) total compute,
O(1) decode state, which is why xlstm runs the ``long_500k`` cell.

sLSTM keeps the scalar-memory recurrence with exponential gating and a
recurrent gate path, so it stays a true ``lax.scan`` over time (the paper's
sequential component).

Both follow the stabilized gating of Beck et al., arXiv:2405.04517.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.axes import constrain

from .layers import Params, _dense_init, init_rmsnorm, rms_norm


def _dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    din = cfg.ssm_expand * cfg.d_model
    nh = cfg.n_heads
    return din, nh, din // nh


# ===========================================================================
# mLSTM
# ===========================================================================
def init_mlstm(rng, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    din, nh, dh = _dims(cfg)
    ks = jax.random.split(rng, 7)
    return {
        "norm": init_rmsnorm(d),
        "w_up": _dense_init(ks[0], (d, 2 * din)),
        "wq": _dense_init(ks[1], (din, din)),
        "wk": _dense_init(ks[2], (din, din)),
        "wv": _dense_init(ks[3], (din, din)),
        "w_gates": _dense_init(ks[4], (din, 2 * nh)),
        "b_gates": jnp.concatenate([
            jnp.zeros((nh,), jnp.float32),          # input gate bias
            jnp.linspace(3.0, 6.0, nh)]),           # forget gate bias (open)
        "out_norm": init_rmsnorm(din),
        "w_down": _dense_init(ks[5], (din, d), fan_in=din),
    }


def _mlstm_chunk(q, k, v, i_log, f_log, state):
    """One chunk of stabilized mLSTM. q,k,v (B,H,L,D); gates (B,H,L)."""
    bsz, nh, l, dh = q.shape
    c0, n0, m0 = state                      # (B,H,D,D), (B,H,D), (B,H)
    b_cum = jnp.cumsum(f_log, axis=-1)      # inclusive Σ log f
    # intra-chunk log weights: w[t,s] = b_t - b_s + i_s  (s <= t)
    w_log = (b_cum[..., :, None] - b_cum[..., None, :]
             + i_log[..., None, :])
    tri = jnp.tril(jnp.ones((l, l), bool))
    w_log = jnp.where(tri, w_log, -jnp.inf)
    # stabilizer per target step
    m_intra = jnp.max(w_log, axis=-1)                        # (B,H,L)
    m_inter = b_cum + m0[..., None]
    m_t = jnp.maximum(m_intra, m_inter)
    d_mat = jnp.exp(w_log - m_t[..., None])                  # (B,H,L,L)
    inter_w = jnp.exp(m_inter - m_t)                         # (B,H,L)

    scale = dh ** -0.5
    qk = jnp.einsum("bhld,bhsd->bhls", q, k) * scale
    num = (jnp.einsum("bhls,bhsd->bhld", qk * d_mat, v)
           + inter_w[..., None] * jnp.einsum("bhld,bhde->bhle", q * scale, c0))
    den = (jnp.sum(qk * d_mat, axis=-1)
           + inter_w * jnp.einsum("bhld,bhd->bhl", q * scale, n0))
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

    # state for next chunk
    b_tot = b_cum[..., -1]                                    # (B,H)
    m_state_intra = jnp.max(b_tot[..., None] - b_cum + i_log, axis=-1)
    m_next = jnp.maximum(b_tot + m0, m_state_intra)
    kv_w = jnp.exp(b_tot[..., None] - b_cum + i_log - m_next[..., None])
    c_next = (jnp.exp(b_tot + m0 - m_next)[..., None, None] * c0
              + jnp.einsum("bhs,bhsd,bhse->bhde", kv_w, k, v))
    n_next = (jnp.exp(b_tot + m0 - m_next)[..., None] * n0
              + jnp.einsum("bhs,bhsd->bhd", kv_w, k))
    return h, (c_next, n_next, m_next)


def mlstm_mixer(params: Params, cfg: ModelConfig, x: jnp.ndarray, *,
                mode: str = "train", cache: Optional[Params] = None,
                ) -> Tuple[jnp.ndarray, Optional[Params]]:
    b, s, d = x.shape
    din, nh, dh = _dims(cfg)
    dt = x.dtype
    xn = rms_norm(params["norm"], x, cfg.norm_eps)
    up = xn @ params["w_up"].astype(dt)
    a, z = up[..., :din], up[..., din:]

    def heads(t):
        return t.reshape(b, -1, nh, dh).transpose(0, 2, 1, 3)

    q = heads(a @ params["wq"].astype(dt)).astype(jnp.float32)
    k = heads(a @ params["wk"].astype(dt)).astype(jnp.float32)
    v = heads(a @ params["wv"].astype(dt)).astype(jnp.float32)
    gates = (a.astype(jnp.float32) @ params["w_gates"]
             + params["b_gates"])                              # (B,S,2H)
    i_log = gates[..., :nh].transpose(0, 2, 1)                 # (B,H,S)
    f_log = jax.nn.log_sigmoid(gates[..., nh:]).transpose(0, 2, 1)

    if mode == "decode":
        c0, n0, m0 = cache["c"], cache["n"], cache["m"]
        i1, f1 = i_log[..., 0], f_log[..., 0]
        m_t = jnp.maximum(f1 + m0, i1)
        ip = jnp.exp(i1 - m_t)
        fp = jnp.exp(f1 + m0 - m_t)
        c1 = fp[..., None, None] * c0 + ip[..., None, None] * (
            k[:, :, 0, :, None] * v[:, :, 0, None, :])
        n1 = fp[..., None] * n0 + ip[..., None] * k[:, :, 0]
        qs = q[:, :, 0] * dh ** -0.5
        num = jnp.einsum("bhd,bhde->bhe", qs, c1)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qs, n1)),
                          jnp.exp(-m_t))
        h = (num / den[..., None])[:, :, None]                 # (B,H,1,D)
        new_cache = {"c": c1, "n": n1, "m": m_t}
    else:
        chunk = min(cfg.mlstm_chunk, s)
        n_chunks = -(-s // chunk)
        pad = n_chunks * chunk - s
        if pad:
            q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
            k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
            i_log = jnp.pad(i_log, ((0, 0), (0, 0), (0, pad)),
                            constant_values=-1e30)
            f_log = jnp.pad(f_log, ((0, 0), (0, 0), (0, pad)))

        def step(state, inp):
            qc, kc, vc, ic, fc = inp
            h, new_state = _mlstm_chunk(qc, kc, vc, ic, fc, state)
            return new_state, h

        def to_chunks(t):
            tail = t.shape[3:] if t.ndim == 4 else ()
            t = t.reshape(t.shape[:2] + (n_chunks, chunk) + tail)
            return jnp.moveaxis(t, 2, 0)

        state0 = (jnp.zeros((b, nh, dh, dh), jnp.float32),
                  jnp.zeros((b, nh, dh), jnp.float32),
                  jnp.full((b, nh), -1e30, jnp.float32))
        if n_chunks == 1:
            state, h = step(state0, (q, k, v, i_log, f_log))
            h = h[:, :, :s]
        else:
            state, hs = jax.lax.scan(
                step, state0,
                (to_chunks(q), to_chunks(k), to_chunks(v),
                 to_chunks(i_log), to_chunks(f_log)))
            h = jnp.moveaxis(hs, 0, 2).reshape(b, nh, n_chunks * chunk, dh)
            h = h[:, :, :s]
        new_cache = ({"c": state[0], "n": state[1], "m": state[2]}
                     if mode == "prefill" else None)

    h = h.transpose(0, 2, 1, 3).reshape(b, -1, din).astype(dt)
    h = rms_norm(params["out_norm"], h, cfg.norm_eps)
    y = (h * jax.nn.silu(z)) @ params["w_down"].astype(dt)
    return constrain(y, "batch", "seq", "embed"), new_cache


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> Params:
    _, nh, dh = _dims(cfg)
    return {"c": jnp.zeros((batch, nh, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, nh, dh), jnp.float32),
            "m": jnp.full((batch, nh), -1e30, jnp.float32)}


# ===========================================================================
# sLSTM
# ===========================================================================
def init_slstm(rng, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    ks = jax.random.split(rng, 3)
    return {
        "norm": init_rmsnorm(d),
        "w_x": _dense_init(ks[0], (d, 4 * d)),      # i, f, z, o from input
        "w_h": _dense_init(ks[1], (d, 4 * d)),      # recurrent path
        "b": jnp.concatenate([jnp.zeros((d,)), jnp.full((d,), 3.0),
                              jnp.zeros((2 * d,))]).astype(jnp.float32),
        "out_norm": init_rmsnorm(d),
        "w_down": _dense_init(ks[2], (d, d)),
    }


def _slstm_step(params, carry, xw):
    """carry: (h, c, n, m) each (B,D); xw: W_x·x_t (B,4D)."""
    h, c, n, m = carry
    d = h.shape[-1]
    pre = xw + h @ params["w_h"] + params["b"]
    i_log = pre[..., :d]
    f_log = jax.nn.log_sigmoid(pre[..., d:2 * d])
    z = jnp.tanh(pre[..., 2 * d:3 * d])
    o = jax.nn.sigmoid(pre[..., 3 * d:])
    m_new = jnp.maximum(f_log + m, i_log)
    ip = jnp.exp(i_log - m_new)
    fp = jnp.exp(f_log + m - m_new)
    c_new = fp * c + ip * z
    n_new = fp * n + ip
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (h_new, c_new, n_new, m_new)


def slstm_mixer(params: Params, cfg: ModelConfig, x: jnp.ndarray, *,
                mode: str = "train", cache: Optional[Params] = None,
                ) -> Tuple[jnp.ndarray, Optional[Params]]:
    b, s, d = x.shape
    dt = x.dtype
    xn = rms_norm(params["norm"], x, cfg.norm_eps)
    xw = (xn @ params["w_x"].astype(dt)).astype(jnp.float32)  # (B,S,4D)

    if cache is not None and mode == "decode":
        carry = (cache["h"], cache["c"], cache["n"], cache["m"])
    else:
        carry = tuple(jnp.zeros((b, d), jnp.float32) for _ in range(3)) + (
            jnp.full((b, d), -1e30, jnp.float32),)
        carry = (carry[0], carry[1], carry[2], carry[3])

    def step(cr, xt):
        new = _slstm_step(params, cr, xt)
        return new, new[0]

    carry, hs = jax.lax.scan(step, carry, xw.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).astype(dt)                           # (B,S,D)
    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"h": carry[0], "c": carry[1], "n": carry[2],
                     "m": carry[3]}
    h = rms_norm(params["out_norm"], h, cfg.norm_eps)
    y = h @ params["w_down"].astype(dt)
    return constrain(y, "batch", "seq", "embed"), new_cache


def init_slstm_cache(cfg: ModelConfig, batch: int) -> Params:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": jnp.full((batch, d), -1e30,
                                                  jnp.float32)}
