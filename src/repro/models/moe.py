"""Mixture-of-Experts FFN with HPTMT-shuffle token dispatch.

Routing tokens to experts is exactly the paper's shuffle operator (Fig 2)
applied to tensors: hash/top-k chooses a destination *partition* (expert),
rows are packed into capacity-bounded buckets, exchanged, processed, and
combined.  The TPU-native realization is sort-based packing (argsort by
expert id — the same group-by-destination step that
``core.exchange.exchange_rows`` performs with a counting scatter) into a
static ``(groups, E, capacity, d)`` buffer, with expert placement expressed
through sharding constraints:

  * experts sharded over the ``model`` axis (EP) when ``E %% model == 0``
    (jamba-16e, qwen2-64e-padded); the combine contraction over the sharded
    expert axis makes GSPMD insert the reduce collective;
  * otherwise expert-internal TP (ff dim over ``model``; mixtral E=8 < 16).

Overflowing tokens beyond per-group capacity are *dropped* (their combine
weight is zero) and counted — the same overflow contract as the table
shuffle; the trainer monitors the dropped fraction.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.axes import constrain

from .layers import Params, _dense_init, init_rmsnorm, rms_norm


def padded_experts(cfg: ModelConfig, model_axis: int = 16) -> int:
    """Pad expert count so EP divides the model axis (dead experts)."""
    e = cfg.n_experts
    if e % model_axis == 0 or model_axis % e == 0:
        return e
    return -(-e // model_axis) * model_axis


def init_moe(rng, cfg: ModelConfig) -> Params:
    d, f = cfg.d_model, cfg.expert_d_ff
    e = padded_experts(cfg)
    ks = jax.random.split(rng, 5)
    p = {
        "norm": init_rmsnorm(d),
        "router": _dense_init(ks[0], (d, e)),
        "w_gate": _dense_init(ks[1], (e, d, f)),
        "w_in": _dense_init(ks[2], (e, d, f)),
        "w_out": _dense_init(ks[3], (e, f, d), fan_in=f),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * f
        ks2 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": _dense_init(ks2[0], (d, fs)),
            "w_in": _dense_init(ks2[1], (d, fs)),
            "w_out": _dense_init(ks2[2], (fs, d), fan_in=fs),
        }
    return p


def _capacity(tokens_per_group: int, k: int, e: int, factor: float) -> int:
    return max(4, math.ceil(tokens_per_group * k / e * factor))


def moe_ffn(params: Params, cfg: ModelConfig, x: jnp.ndarray,
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Dispatch to the explicit-EP shard_map path when the mesh supports it
    (E divisible by the model axis), else the einsum/auto-SPMD path.

    The auto-SPMD path lets the partitioner handle the expert scatter — and
    it emulates the shuffle with full dense all-reduces of the token buffers
    (measured: 10 GiB f32 + 4 GiB u32 AR per layer group on qwen2-moe),
    which is exactly the operator-mismatch anti-pattern the paper calls out
    (§IV: AllReduce-via-GroupBy).  The shard_map path expresses the shuffle
    directly: local pack → local expert compute on the device's expert
    slice → ONE psum combine.  See EXPERIMENTS.md §Perf.
    """
    from repro.sharding import axes as axes_mod
    mesh = axes_mod.current_mesh()
    if mesh is not None and "model" in mesh.axis_names:
        e = params["router"].shape[1]
        if e % mesh.shape["model"] == 0:
            return _moe_ffn_ep_shardmap(params, cfg, x, mesh)
    return _moe_ffn_einsum(params, cfg, x)


def _routing(params: Params, cfg: ModelConfig, xn: jnp.ndarray):
    """Router logits → (top-k gates/ids, aux metrics). fp32 throughout."""
    e = params["router"].shape[1]
    k = cfg.experts_per_token
    logits = (xn.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    if e > cfg.n_experts:
        pad_mask = jnp.arange(e) >= cfg.n_experts
        logits = jnp.where(pad_mask, -1e30, logits)
    gates = jax.nn.softmax(logits, axis=-1)
    top_g, top_i = jax.lax.top_k(gates, k)
    top_g = top_g / jnp.maximum(jnp.sum(top_g, -1, keepdims=True), 1e-9)
    me = jnp.mean(gates.reshape(-1, e), axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_i.reshape(-1, k), e), axis=1), axis=0) / k
    aux = jnp.sum(me * ce) * cfg.n_experts
    router_z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return top_g, top_i, aux, router_z


def _pack(xg, ig, gg, e: int, cap: int, dt):
    """Sort-by-destination bucket pack (the HPTMT shuffle's local step).

    xg (g, tg, d); ig/gg (g, tg, k) → (buf (g, e, cap, d), slot, tok_idx,
    g_tok, ok)."""
    g, tg, d = xg.shape
    k = ig.shape[-1]
    flat_e = ig.reshape(g, tg * k)
    flat_g = gg.reshape(g, tg * k).astype(dt)
    order = jnp.argsort(flat_e, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    first = jax.vmap(lambda a: jnp.searchsorted(a, a, side="left"))(sorted_e)
    rank = jnp.arange(tg * k, dtype=jnp.int32)[None] - first.astype(jnp.int32)
    ok = rank < cap
    slot = jnp.where(ok, sorted_e * cap + rank, e * cap)
    tok_idx = order // k
    x_tok = jnp.take_along_axis(xg, tok_idx[..., None], axis=1)
    g_tok = jnp.take_along_axis(flat_g, order, axis=1)

    def scatter_rows(xt, st):
        return jnp.zeros((e * cap, d), dt).at[st].set(xt, mode="drop")

    buf = jax.vmap(scatter_rows)(x_tok, slot).reshape(g, e, cap, d)
    return buf, slot, tok_idx, g_tok, ok


def _moe_ffn_ep_shardmap(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                         mesh) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Expert-parallel MoE as an explicit HPTMT shuffle (shard_map).

    Activations are batch-sharded over the DP axes and replicated over
    ``model``; experts are sharded over ``model``.  Each device packs
    buckets for *its* expert slice locally (zero dispatch communication —
    the shuffle's exchange is subsumed by the existing replication), runs
    its experts, and contributes a partial output; ONE bf16 psum over
    ``model`` combines.  Shared experts run as plain TP inside the same
    region and join the same psum.
    """
    from jax.sharding import PartitionSpec as P
    from repro.sharding import axes as axes_mod

    b, s, d = x.shape
    dt = x.dtype
    e = params["router"].shape[1]
    k = cfg.experts_per_token
    msize = mesh.shape["model"]
    e_loc = e // msize
    bspec = axes_mod.spec_for(["batch"])[0]
    dp_axes = tuple(a for a in mesh.axis_names if a != "model")

    f = cfg.expert_d_ff
    fs = cfg.n_shared_experts * f
    has_shared = "shared" in params
    shared_ok = has_shared and fs % msize == 0

    in_specs = (
        P(bspec, None, None),                 # x
        P(None),                              # norm scale
        P(None, None),                        # router
        P("model", None, None),               # w_gate
        P("model", None, None),               # w_in
        P("model", None, None),               # w_out
    )
    shared_args = ()
    if has_shared:
        sspec = "model" if shared_ok else None
        in_specs += (P(None, sspec), P(None, sspec), P(sspec, None))
        shared_args = (params["shared"]["w_gate"], params["shared"]["w_in"],
                       params["shared"]["w_out"])

    def local(xl, scale, router, wg, wi, wo, *shared):
        xn = rms_norm({"scale": scale}, xl, cfg.norm_eps)
        top_g, top_i, aux, router_z = _routing(
            {"router": router}, cfg, xn)

        if s >= 64:
            g, tg = xl.shape[0], s
            xg, ig, gg = xn, top_i, top_g
        else:
            g, tg = 1, xl.shape[0] * s
            xg = xn.reshape(1, -1, d)
            ig, gg = top_i.reshape(1, -1, k), top_g.reshape(1, -1, k)
        cap = _capacity(tg, k, e, cfg.capacity_factor)
        buf, slot, tok_idx, g_tok, ok = _pack(xg, ig, gg, e, cap, dt)
        dropped = 1.0 - jnp.mean(ok.astype(jnp.float32))

        # my expert slice
        m_idx = jax.lax.axis_index("model")
        start = m_idx * e_loc * cap
        buf_flat = buf.reshape(g, e * cap, d)
        mine = jax.lax.dynamic_slice_in_dim(buf_flat, start, e_loc * cap,
                                            axis=1)
        mine = mine.reshape(g, e_loc, cap, d)
        wg_ = wg.astype(dt)
        wi_ = wi.astype(dt)
        wo_ = wo.astype(dt)
        hidden = jax.nn.silu(jnp.einsum("gecd,edf->gecf", mine, wg_)) \
            * jnp.einsum("gecd,edf->gecf", mine, wi_)
        out = jnp.einsum("gecf,efd->gecd", hidden, wo_)

        # scatter my experts' rows back into the full slot space (local)
        out_flat = jnp.zeros((g, e * cap, d), dt)
        out_flat = jax.lax.dynamic_update_slice_in_dim(
            out_flat, out.reshape(g, e_loc * cap, d), start, axis=1)
        safe = jnp.minimum(slot, e * cap - 1)
        y_tok = jnp.take_along_axis(out_flat, safe[..., None], axis=1)
        y_tok = jnp.where(ok[..., None], y_tok, 0.0) * g_tok[..., None]

        def combine_rows(yt, ti):
            return jnp.zeros((tg, d), dt).at[ti].add(yt)

        y = jax.vmap(combine_rows)(y_tok, tok_idx).reshape(xl.shape)

        if shared:
            swg, swi, swo = (w.astype(dt) for w in shared)
            hsh = jax.nn.silu(xn @ swg) * (xn @ swi)
            y_sh = hsh @ swo
            if shared_ok:
                y = y + y_sh           # partial: joins the model psum
            else:
                y = y + y_sh / msize   # replicated weights: avoid double-add
        # ONE combine for routed (+shared) partials — the shuffle's reduce
        y = jax.lax.psum(y, "model")

        # aux metrics: identical across model; mean across DP shards
        metrics = (aux, router_z, dropped)
        if dp_axes:
            metrics = tuple(
                jax.lax.pmean(v, dp_axes) for v in metrics)
        return y, metrics[0], metrics[1], metrics[2]

    from repro.core.context import compat_shard_map
    fn = compat_shard_map(
        local, mesh=mesh,
        in_specs=in_specs,
        out_specs=(P(bspec, None, None), P(), P(), P()),
        check_vma=False)
    args = (x, params["norm"]["scale"], params["router"],
            params["w_gate"], params["w_in"], params["w_out"]) + shared_args
    y, aux, router_z, dropped = fn(*args)
    return y, {"moe_aux_loss": aux, "router_z_loss": router_z,
               "moe_dropped_frac": dropped}


def _moe_ffn_einsum(params: Params, cfg: ModelConfig, x: jnp.ndarray,
                    ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x (B, S, D) → (y, metrics{aux_loss, router_z, dropped_frac})."""
    b, s, d = x.shape
    dt = x.dtype
    e = params["router"].shape[1]
    k = cfg.experts_per_token

    xn = rms_norm(params["norm"], x, cfg.norm_eps)

    # --- routing (fp32) ------------------------------------------------------
    logits = (xn.astype(jnp.float32) @ params["router"]).astype(jnp.float32)
    # mask padded (dead) experts out of routing
    if e > cfg.n_experts:
        pad_mask = jnp.arange(e) >= cfg.n_experts
        logits = jnp.where(pad_mask, -1e30, logits)
    gates = jax.nn.softmax(logits, axis=-1)                    # (B,S,E)
    top_g, top_i = jax.lax.top_k(gates, k)                     # (B,S,k)
    top_g = top_g / jnp.maximum(jnp.sum(top_g, -1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch) + router z-loss
    me = jnp.mean(gates.reshape(-1, e), axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_i.reshape(-1, k), e), axis=1), axis=0) / k
    aux = jnp.sum(me * ce) * (cfg.n_experts ** 1)
    router_z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))

    # --- group & pack (HPTMT shuffle: sort by destination, bucket) -----------
    # groups: per-batch-row when sequences are long, whole batch when decoding
    if s >= 64:
        g, tg = b, s
        xg = xn
        ig, gg = top_i, top_g
    else:
        g, tg = 1, b * s
        xg = xn.reshape(1, b * s, d)
        ig, gg = top_i.reshape(1, -1, k), top_g.reshape(1, -1, k)

    cap = _capacity(tg, k, e, cfg.capacity_factor)
    flat_e = ig.reshape(g, tg * k)
    flat_g = gg.reshape(g, tg * k).astype(dt)
    order = jnp.argsort(flat_e, axis=1, stable=True)           # (g, tg*k)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    first = jax.vmap(lambda a: jnp.searchsorted(a, a, side="left"))(sorted_e)
    rank = jnp.arange(tg * k, dtype=jnp.int32)[None] - first.astype(jnp.int32)
    ok = rank < cap
    slot = jnp.where(ok, sorted_e * cap + rank, e * cap)
    tok_idx = order // k                                       # source token
    dropped = 1.0 - jnp.mean(ok.astype(jnp.float32))

    x_tok = jnp.take_along_axis(xg, tok_idx[..., None], axis=1)  # (g,tg*k,d)
    g_tok = jnp.take_along_axis(flat_g, order, axis=1)

    def scatter_rows(xt, st):
        return jnp.zeros((e * cap, d), dt).at[st].set(xt, mode="drop")

    buf = jax.vmap(scatter_rows)(x_tok, slot)                  # (g, e*cap, d)
    buf = buf.reshape(g, e, cap, d)
    buf = constrain(buf, "batch", "expert", None, "embed")

    # --- expert compute (einsum over stacked expert weights) -----------------
    wg = params["w_gate"].astype(dt)
    wi = params["w_in"].astype(dt)
    wo = params["w_out"].astype(dt)
    hidden = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, wg)) \
        * jnp.einsum("gecd,edf->gecf", buf, wi)
    hidden = constrain(hidden, "batch", "expert", None, "moe_ff")
    out = jnp.einsum("gecf,efd->gecd", hidden, wo)
    out = constrain(out, "batch", "expert", None, "embed")

    # --- combine (inverse shuffle: gather + weighted scatter-add) ------------
    out_flat = out.reshape(g, e * cap, d)
    safe = jnp.minimum(slot, e * cap - 1)
    y_tok = jnp.take_along_axis(out_flat, safe[..., None], axis=1)
    y_tok = jnp.where(ok[..., None], y_tok, 0.0) * g_tok[..., None]

    def combine_rows(yt, ti):
        return jnp.zeros((tg, d), dt).at[ti].add(yt)

    y = jax.vmap(combine_rows)(y_tok, tok_idx).reshape(b, s, d)

    if cfg.n_shared_experts:
        sp = params["shared"]
        gsh = jax.nn.silu(xn @ sp["w_gate"].astype(dt))
        ush = xn @ sp["w_in"].astype(dt)
        y = y + (gsh * ush) @ sp["w_out"].astype(dt)

    y = constrain(y, "batch", "seq", "embed")
    metrics = {"moe_aux_loss": aux, "router_z_loss": router_z,
               "moe_dropped_frac": dropped}
    return y, metrics
