"""Mamba selective-state-space mixer (Jamba's SSM blocks).

Training/prefill uses a *chunked associative scan*: an outer ``lax.scan``
over sequence chunks carries the SSM state, and within each chunk the linear
recurrence ``h_t = a_t · h_{t-1} + b_t`` runs as ``lax.associative_scan`` —
this bounds the materialized (B, chunk, d_inner, N) discretization tensors
to one chunk (the TPU VMEM/HBM-friendly adaptation; a full-sequence scan at
500k tokens would materialize terabytes).

Decode carries ``(conv_state, ssm_state)`` — O(1) per token, which is what
makes the hybrid archs runnable at the ``long_500k`` cell.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.axes import constrain

from .layers import Params, _dense_init, init_rmsnorm, rms_norm


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    dt_rank = cfg.dt_rank or -(-cfg.d_model // 16)
    return d_inner, dt_rank, cfg.ssm_state_dim, cfg.ssm_conv_width


def init_mamba(rng, cfg: ModelConfig) -> Params:
    d = cfg.d_model
    din, dtr, n, w = _dims(cfg)
    ks = jax.random.split(rng, 6)
    return {
        "norm": init_rmsnorm(d),
        "in_proj": _dense_init(ks[0], (d, 2 * din)),
        "conv_w": _dense_init(ks[1], (w, din), fan_in=w),
        "conv_b": jnp.zeros((din,), jnp.float32),
        "x_proj": _dense_init(ks[2], (din, dtr + 2 * n)),
        "dt_proj": _dense_init(ks[3], (dtr, din)),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(ks[4], (din,)) * 0.099 + 0.001,
                     1e-4, None))),
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (din, n))),
        "d_skip": jnp.ones((din,), jnp.float32),
        "out_proj": _dense_init(ks[5], (din, d), fan_in=din),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv along seq. x (B,S,C); w (W,C). Returns (y, state)."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                   # (B, S+W-1, C)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(width))
    new_state = xp[:, -(width - 1):] if width > 1 else pad
    return y + b.astype(x.dtype), new_state


def _scan_chunk(a: jnp.ndarray, bx: jnp.ndarray, h0: jnp.ndarray):
    """h_t = a_t * h_{t-1} + bx_t over axis 1. a,bx (B,L,D,N); h0 (B,D,N)."""
    # fold h0 into the first step
    bx = bx.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_c, h = jax.lax.associative_scan(combine, (a, bx), axis=1)
    return h, h[:, -1]


def mamba_mixer(params: Params, cfg: ModelConfig, x: jnp.ndarray, *,
                mode: str = "train", cache: Optional[Params] = None,
                ) -> Tuple[jnp.ndarray, Optional[Params]]:
    """Pre-norm Mamba block. Returns (residual_delta, new_cache)."""
    b, s, d = x.shape
    din, dtr, n, w = _dims(cfg)
    dt_ = x.dtype
    xn = rms_norm(params["norm"], x, cfg.norm_eps)

    xz = xn @ params["in_proj"].astype(dt_)
    xs, z = xz[..., :din], xz[..., din:]
    xs = constrain(xs, "batch", "seq", "ssm_inner")

    conv_state = cache["conv"] if cache is not None else None
    xs, new_conv = _causal_conv(xs, params["conv_w"], params["conv_b"],
                                conv_state if mode == "decode" else None)
    xs = jax.nn.silu(xs)

    dbc = xs @ params["x_proj"].astype(dt_)
    dt_raw, bm, cm = (dbc[..., :dtr], dbc[..., dtr:dtr + n],
                      dbc[..., dtr + n:])
    dt_full = jax.nn.softplus(
        (dt_raw @ params["dt_proj"].astype(dt_)).astype(jnp.float32)
        + params["dt_bias"])                                   # (B,S,Din)
    a = -jnp.exp(params["a_log"])                              # (Din,N)

    xs_f = xs.astype(jnp.float32)
    bm_f = bm.astype(jnp.float32)
    cm_f = cm.astype(jnp.float32)

    if mode == "decode":
        # O(1) recurrent update
        h0 = cache["ssm"]                                       # (B,Din,N)
        da = jnp.exp(dt_full[:, 0, :, None] * a)                # (B,Din,N)
        dbx = (dt_full[:, 0, :, None] * bm_f[:, 0, None, :]
               * xs_f[:, 0, :, None])
        h = da * h0 + dbx
        y = jnp.einsum("bdn,bn->bd", h, cm_f[:, 0])[:, None]    # (B,1,Din)
        new_cache = {"conv": new_conv, "ssm": h}
    else:
        chunk = min(cfg.scan_chunk, s)
        n_chunks = -(-s // chunk)
        pad = n_chunks * chunk - s
        if pad:
            dt_full = jnp.pad(dt_full, ((0, 0), (0, pad), (0, 0)))
            bm_f = jnp.pad(bm_f, ((0, 0), (0, pad), (0, 0)))
            cm_f = jnp.pad(cm_f, ((0, 0), (0, pad), (0, 0)))
            xs_f = jnp.pad(xs_f, ((0, 0), (0, pad), (0, 0)))

        def step(h0, inp):
            dt_c, b_c, c_c, x_c = inp                           # (B,L,·)
            da = jnp.exp(dt_c[..., None] * a)                   # (B,L,Din,N)
            dbx = dt_c[..., None] * b_c[:, :, None, :] * x_c[..., None]
            hs, h_last = _scan_chunk(da, dbx, h0)
            y_c = jnp.einsum("bldn,bln->bld", hs, c_c)
            return h_last, y_c

        def to_chunks(t):
            return t.reshape(b, n_chunks, chunk, *t.shape[2:]).swapaxes(0, 1)

        h_init = jnp.zeros((b, din, n), jnp.float32)
        if n_chunks == 1:
            h_last, y = step(h_init, (dt_full, bm_f, cm_f, xs_f))
            y = y[:, :s]
        else:
            h_last, ys = jax.lax.scan(
                step, h_init,
                (to_chunks(dt_full), to_chunks(bm_f), to_chunks(cm_f),
                 to_chunks(xs_f)))
            y = ys.swapaxes(0, 1).reshape(b, n_chunks * chunk, din)[:, :s]
        new_cache = ({"conv": new_conv, "ssm": h_last}
                     if mode == "prefill" else None)

    y = (y + xs_f[:, :s] * params["d_skip"]).astype(dt_)
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(dt_)
    return constrain(out, "batch", "seq", "embed"), new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> Params:
    din, _, n, w = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, w - 1, din), dtype),
        "ssm": jnp.zeros((batch, din, n), jnp.float32),
    }
