"""Core tensor-operator layers: norms, RoPE, attention (GQA/SWA/MLA), MLP.

Pure-JAX modules in init/apply style: ``init_*`` builds a param pytree,
the apply function is a plain function of (params, x).  Activation sharding
is annotated with logical axes (``repro.sharding.axes``); parameter sharding
is derived from param-path rules (``repro.sharding.partition``).

Attention dispatch: the XLA einsum path (below) is what the dry-run lowers
and what trains on CPU; on TPU the Pallas flash kernel
(``repro.kernels.flash_attention``) is used for the same semantics.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.sharding.axes import constrain

Params = Dict[str, jnp.ndarray]


def _dense_init(rng, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in or shape[0]
    return (jax.random.normal(rng, shape, dtype) / math.sqrt(fan_in))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def init_rmsnorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm with f32 *accumulation* (not a full-tensor f32 upcast).

    Upcasting ``x`` first makes the layer-scan's saved residual stack a
    target for XLA's convert-mover, which then carries the whole activation
    stack in f32 (2× memory).  Reducing with ``dtype=f32`` keeps the sums
    exact while every full-size tensor stays bf16 — the same contract a
    fused TPU norm kernel provides.
    """
    dt = x.dtype
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True, dtype=jnp.float32)
    inv = jax.lax.rsqrt(var + eps)
    return (x * inv.astype(dt)) * params["scale"].astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x: jnp.ndarray, positions: jnp.ndarray,
         theta: float = 10_000.0) -> jnp.ndarray:
    """x (..., S, D) with D even; positions (..., S) absolute indices."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                              axis=-1)
    return rotated.astype(x.dtype)


# ---------------------------------------------------------------------------
# masked attention core (XLA path; same semantics as kernels/flash_attention)
# ---------------------------------------------------------------------------
def _mask_for_chunk(q_pos: jnp.ndarray, kv_pos: jnp.ndarray, causal: bool,
                    window: Optional[int]) -> jnp.ndarray:
    """(cq, L) visibility from absolute positions (kv_pos == -1 → empty)."""
    qp = q_pos[:, None]
    kp = kv_pos[None, :]
    allow = kp >= 0
    if causal:
        allow = allow & (kp <= qp)
    if window is not None:
        allow = allow & ((qp - kp) < window)
    return allow


def attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
           q_pos: jnp.ndarray, kv_pos: jnp.ndarray, causal: bool = True,
           window: Optional[int] = None, sm_scale: Optional[float] = None,
           q_chunk: int = 256) -> jnp.ndarray:
    """Masked softmax attention, streamed over query chunks.

    q (B,Hq,S,D); k,v (B,Hkv,L,Dv); q_pos (S,), kv_pos (L,) absolute
    positions (-1 = empty cache slot).  Two TPU/SPMD adaptations vs the
    textbook einsum (DESIGN.md §2):

      * KV heads are repeated up to Hq *before* the contraction so the head
        dimension keeps a single sharded axis (a (b,hkv,g,s,l) reshape splits
        64 heads into 8×8, and neither factor divides a 16-way model axis);
        the Pallas kernel does GQA natively without the repeat.
      * queries stream in chunks through a rematerialized ``lax.map`` so no
        full S×L score matrix ever materializes (the XLA analogue of the
        flash kernel's VMEM tiling — scores exist one (cq, L) tile at a
        time, recomputed in the backward pass).
    """
    b, hq, s, d = q.shape
    hkv, l = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    scale = sm_scale if sm_scale is not None else d ** -0.5
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def one_chunk(args):
        qc, qp = args                                  # (B,H,cq,D), (cq,)
        scores = jnp.einsum("bhsd,bhld->bhsl", qc.astype(jnp.float32),
                            kf) * scale
        allow = _mask_for_chunk(qp, kv_pos, causal, window)
        scores = jnp.where(allow[None, None], scores, -1e30)
        m = jnp.max(scores, axis=-1, keepdims=True)
        p = jnp.exp(scores - jax.lax.stop_gradient(m))
        p = jnp.where(allow[None, None], p, 0.0)
        denom = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bhsl,bhld->bhsd", p, vf) / jnp.maximum(denom, 1e-30)
        return o.astype(q.dtype)

    if s <= q_chunk:
        return one_chunk((q, q_pos))

    n_chunks = -(-s // q_chunk)
    pad = n_chunks * q_chunk - s
    qp_pad = jnp.pad(q_pos, (0, pad), constant_values=-1)
    q_pad = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    q_chunks = jnp.moveaxis(
        q_pad.reshape(b, hq, n_chunks, q_chunk, d), 2, 0)
    qp_chunks = qp_pad.reshape(n_chunks, q_chunk)
    out = jax.lax.map(jax.checkpoint(one_chunk), (q_chunks, qp_chunks))
    out = jnp.moveaxis(out, 0, 2).reshape(b, hq, n_chunks * q_chunk, dv)
    return out[:, :, :s]


def _use_flash_kernel(cfg: ModelConfig) -> bool:
    """Pallas flash kernel for self-attention: on TPU by default, opt-in
    elsewhere (interpret mode; tests force it)."""
    if cfg.use_flash is not None:
        return cfg.use_flash
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# GQA attention block (supports SWA + self/cross + KV cache)
# ---------------------------------------------------------------------------
def init_attention(rng, cfg: ModelConfig, cross: bool = False) -> Params:
    d, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 4)
    return {
        "wq": _dense_init(ks[0], (d, h * dh)),
        "wk": _dense_init(ks[1], (d, hk * dh)),
        "wv": _dense_init(ks[2], (d, hk * dh)),
        "wo": _dense_init(ks[3], (h * dh, d), fan_in=h * dh),
        "norm": init_rmsnorm(d),
    }


def gqa_attention(params: Params, cfg: ModelConfig, x: jnp.ndarray, *,
                  positions: jnp.ndarray, mode: str = "train",
                  cache: Optional[Params] = None,
                  kv_source: Optional[jnp.ndarray] = None,
                  causal: bool = True, cache_len: int = 0,
                  ) -> Tuple[jnp.ndarray, Optional[Params]]:
    """Pre-norm GQA attention. Returns (residual_delta, new_cache)."""
    b, s, d = x.shape
    h, hk, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype
    xn = rms_norm(params["norm"], x, cfg.norm_eps)

    q = (xn @ params["wq"].astype(dt)).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    q = constrain(q, "batch", "heads", "seq", None)
    kv_in = rms_norm(params["norm"], kv_source, cfg.norm_eps) \
        if kv_source is not None else xn
    k = (kv_in @ params["wk"].astype(dt)).reshape(
        b, kv_in.shape[1], hk, dh).transpose(0, 2, 1, 3)
    v = (kv_in @ params["wv"].astype(dt)).reshape(
        b, kv_in.shape[1], hk, dh).transpose(0, 2, 1, 3)

    is_cross = kv_source is not None
    if not is_cross:
        q = rope(q, positions[None, None, :], cfg.rope_theta)
        k = rope(k, positions[None, None, :], cfg.rope_theta)

    new_cache = None
    if mode == "decode" and not is_cross:
        # append to ring/linear cache and attend over it
        cpos = cache["pos"]
        slot = cache["cursor"]  # scalar int32 write index
        if cfg.kv_quant:
            kq, ks = kv_quantize(k)
            vq, vs = kv_quantize(v)
            ckq = jax.lax.dynamic_update_slice_in_dim(cache["k"], kq, slot,
                                                      axis=2)
            cks = jax.lax.dynamic_update_slice_in_dim(cache["k_s"], ks,
                                                      slot, axis=2)
            cvq = jax.lax.dynamic_update_slice_in_dim(cache["v"], vq, slot,
                                                      axis=2)
            cvs = jax.lax.dynamic_update_slice_in_dim(cache["v_s"], vs,
                                                      slot, axis=2)
            ck = kv_dequantize(ckq, cks, dt)
            cv = kv_dequantize(cvq, cvs, dt)
            stored = {"k": ckq, "k_s": cks, "v": cvq, "v_s": cvs}
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot,
                                                     axis=2)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot,
                                                     axis=2)
            stored = {"k": ck, "v": cv}
        cpos = jax.lax.dynamic_update_slice_in_dim(
            cpos, positions.astype(jnp.int32), slot, axis=0)
        cache_len = ck.shape[2]
        cursor = (slot + s) % cache_len if cfg.window else slot + s
        new_cache = {**stored, "pos": cpos,
                     "cursor": jnp.asarray(cursor, jnp.int32)}
        o = attend(q, ck, cv, q_pos=positions, kv_pos=cpos, causal=causal,
                   window=cfg.window, q_chunk=cfg.attn_q_chunk)
    else:
        if is_cross:
            kv_pos = jnp.arange(k.shape[2], dtype=jnp.int32)
            o = attend(q, k, v, q_pos=positions, kv_pos=kv_pos,
                       causal=False, q_chunk=cfg.attn_q_chunk)
        elif _use_flash_kernel(cfg) and (mode != "train" or cfg.use_flash):
            # Pallas flash kernel (TPU target): native GQA, VMEM-tiled —
            # no KV-head repeat, no score-tile HBM traffic.  Default for
            # inference modes; training keeps the rematerialized XLA path
            # until the backward kernel lands (the fwd kernel has no vjp).
            from repro.kernels.flash_attention import ops as flash_ops
            o = flash_ops.flash_attention(
                q, k, v, causal=causal, window=cfg.window,
                force="pallas" if cfg.use_flash else None)
        else:
            o = attend(q, k, v, q_pos=positions, kv_pos=positions,
                       causal=causal, window=cfg.window,
                       q_chunk=cfg.attn_q_chunk)
        if mode == "prefill" and not is_cross:
            new_cache = _build_prefill_cache(
                cfg, k, v, positions, cache_len or k.shape[2])

    o = constrain(o, "batch", "heads", "seq", None)
    y = o.transpose(0, 2, 1, 3).reshape(b, s, h * dh) @ params["wo"].astype(dt)
    return constrain(y, "batch", "seq", "embed"), new_cache


# ---------------------------------------------------------------------------
# int8 KV quantization (beyond-paper: halves resident cache + its HBM reads)
# ---------------------------------------------------------------------------
def kv_quantize(x: jnp.ndarray):
    """(B,H,L,D) → (int8 values, f32 per-vector scales (B,H,L,1))."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def kv_dequantize(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def _build_prefill_cache(cfg: ModelConfig, k, v, positions,
                         cache_len: int) -> Params:
    """Size a decode cache of ``cache_len`` slots from prefill K/V.

    Sliding-window archs keep a ring of the last ``window`` entries; others
    right-pad to the full decode length.  ``pos`` tracks the absolute
    position per slot (-1 = empty) so decode masking is position-exact.
    """
    b, hk, s, dh = k.shape
    if cfg.window is not None and cache_len <= cfg.window:
        w = cache_len
        if s >= w:
            # last w entries, placed at slot = pos % w (ring order)
            src = (s - w) + jnp.mod(jnp.arange(w) - s, w)
            ck, cv = k[:, :, src], v[:, :, src]
            cpos = positions[src].astype(jnp.int32)
        else:
            pad = w - s
            ck = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
            cv = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
            cpos = jnp.pad(positions.astype(jnp.int32), (0, pad),
                           constant_values=-1)
        cursor = s % w
    else:
        pad = cache_len - s
        ck = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        cv = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        cpos = jnp.pad(positions.astype(jnp.int32), (0, pad),
                       constant_values=-1)
        cursor = s
    out = {"pos": cpos, "cursor": jnp.asarray(cursor, jnp.int32)}
    if cfg.kv_quant:
        out["k"], out["k_s"] = kv_quantize(ck)
        out["v"], out["v_s"] = kv_quantize(cv)
    else:
        out["k"], out["v"] = ck, cv
    return out


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (MiniCPM3 / DeepSeek-V2 style)
# ---------------------------------------------------------------------------
def init_mla(rng, cfg: ModelConfig) -> Params:
    d, h = cfg.d_model, cfg.n_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim
    ks = jax.random.split(rng, 6)
    return {
        "wdq": _dense_init(ks[0], (d, cfg.q_lora_rank)),
        "wuq": _dense_init(ks[1], (cfg.q_lora_rank, h * qd)),
        "wdkv": _dense_init(ks[2], (d, cfg.kv_lora_rank + cfg.qk_rope_dim)),
        "wukv": _dense_init(ks[3], (cfg.kv_lora_rank,
                                    h * (cfg.qk_nope_dim + cfg.v_head_dim))),
        "wo": _dense_init(ks[4], (h * cfg.v_head_dim, d),
                          fan_in=h * cfg.v_head_dim),
        "norm": init_rmsnorm(d),
        "q_norm": init_rmsnorm(cfg.q_lora_rank),
        "kv_norm": init_rmsnorm(cfg.kv_lora_rank),
    }


def mla_attention(params: Params, cfg: ModelConfig, x: jnp.ndarray, *,
                  positions: jnp.ndarray, mode: str = "train",
                  cache: Optional[Params] = None, cache_len: int = 0,
                  ) -> Tuple[jnp.ndarray, Optional[Params]]:
    """Latent attention: KV compressed to ``kv_lora_rank`` + shared RoPE key.

    Cache stores only the latent ``c_kv`` and rope key — the paper-exact
    memory win.  Baseline decode re-expands K/V from the latent each step;
    ``cfg.mla_absorb`` switches to the absorbed formulation (beyond-paper
    optimization recorded in EXPERIMENTS §Perf).
    """
    b, s, d = x.shape
    h = cfg.n_heads
    nope, rdim, vdim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    dt = x.dtype
    xn = rms_norm(params["norm"], x, cfg.norm_eps)

    cq = rms_norm(params["q_norm"], xn @ params["wdq"].astype(dt), cfg.norm_eps)
    q = (cq @ params["wuq"].astype(dt)).reshape(b, s, h, nope + rdim)
    q = q.transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = rope(q_rope, positions[None, None, :], cfg.rope_theta)

    dkv = xn @ params["wdkv"].astype(dt)            # (B,S,kv_lora + rdim)
    c_kv = rms_norm(params["kv_norm"], dkv[..., :cfg.kv_lora_rank],
                    cfg.norm_eps)
    k_rope = rope(dkv[..., None, cfg.kv_lora_rank:].transpose(0, 2, 1, 3),
                  positions[None, None, :], cfg.rope_theta)  # (B,1,S,rdim)

    new_cache = None
    if mode == "decode":
        cc, cr, cpos = cache["c_kv"], cache["k_rope"], cache["pos"]
        slot = cache["cursor"]
        cc = jax.lax.dynamic_update_slice_in_dim(cc, c_kv, slot, axis=1)
        cr = jax.lax.dynamic_update_slice_in_dim(cr, k_rope, slot, axis=2)
        cpos = jax.lax.dynamic_update_slice_in_dim(
            cpos, positions.astype(jnp.int32), slot, axis=0)
        new_cache = {"c_kv": cc, "k_rope": cr, "pos": cpos,
                     "cursor": jnp.asarray(slot + s, jnp.int32)}
        c_kv_full, k_rope_full, kpos = cc, cr, cpos
    else:
        c_kv_full, k_rope_full = c_kv, k_rope
        kpos = positions
        if mode == "prefill":
            clen = cache_len or s
            pad = clen - s
            new_cache = {
                "c_kv": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
                "k_rope": jnp.pad(k_rope, ((0, 0), (0, 0), (0, pad), (0, 0))),
                "pos": jnp.pad(positions.astype(jnp.int32), (0, pad),
                               constant_values=-1),
                "cursor": jnp.asarray(s, jnp.int32)}

    scale = (nope + rdim) ** -0.5
    if cfg.mla_absorb and mode == "decode":
        # absorbed: score in latent space — never re-expand K
        wukv = params["wukv"].astype(dt).reshape(cfg.kv_lora_rank, h,
                                                 nope + vdim)
        wuk = wukv[..., :nope]                      # (r, h, nope)
        q_lat = jnp.einsum("bhsn,rhn->bhsr", q_nope, wuk)
        s_nope = jnp.einsum("bhsr,blr->bhsl", q_lat, c_kv_full)
        s_rope = jnp.einsum("bhsr,blr->bhsl", q_rope, k_rope_full[:, 0])
        scores = (s_nope + s_rope).astype(jnp.float32) * scale
        allow = _mask_for_chunk(positions, kpos, True, None)
        scores = jnp.where(allow[None, None], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1)
        wuv = wukv[..., nope:]                      # (r, h, vdim)
        o_lat = jnp.einsum("bhsl,blr->bhsr", p.astype(dt), c_kv_full)
        o = jnp.einsum("bhsr,rhv->bhsv", o_lat, wuv)
    else:
        # baseline: expand K/V from latent (paper-faithful reference path)
        kv = (c_kv_full @ params["wukv"].astype(dt)).reshape(
            b, -1, h, nope + vdim).transpose(0, 2, 1, 3)
        k_nope, v = kv[..., :nope], kv[..., nope:]
        k_r = jnp.broadcast_to(k_rope_full, (b, h) + k_rope_full.shape[2:])
        k = jnp.concatenate([k_nope, k_r], axis=-1)
        qc = jnp.concatenate([q_nope, q_rope], axis=-1)
        o = attend(qc, k, v, q_pos=positions, kv_pos=kpos, causal=True,
                   sm_scale=scale, q_chunk=cfg.attn_q_chunk)

    y = o.transpose(0, 2, 1, 3).reshape(b, s, h * vdim) @ params["wo"].astype(dt)
    return constrain(y, "batch", "seq", "embed"), new_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def init_mlp(rng, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(rng, 3)
    return {
        "w_gate": _dense_init(ks[0], (d, f)),
        "w_in": _dense_init(ks[1], (d, f)),
        "w_out": _dense_init(ks[2], (f, d), fan_in=f),
        "norm": init_rmsnorm(d),
    }


def mlp(params: Params, cfg: ModelConfig, x: jnp.ndarray,
        skip_norm: bool = False) -> jnp.ndarray:
    dt = x.dtype
    xn = x if skip_norm else rms_norm(params["norm"], x, cfg.norm_eps)
    g = jax.nn.silu(xn @ params["w_gate"].astype(dt))
    u = xn @ params["w_in"].astype(dt)
    h = constrain(g * u, "batch", "seq", "ff")
    y = h @ params["w_out"].astype(dt)
    return constrain(y, "batch", "seq", "embed")
