"""Segment machinery for ordered analytics (DESIGN.md §9).

A table sorted by ``(partition, order)`` keys turns window PARTITIONs into
contiguous SEGMENTS — runs of rows whose partition-key lanes are equal.  No
hash table, no re-grouping sort: boundaries are one adjacent-row lane
compare, and every windowed operator (rolling aggregates, cumulatives,
lag/lead, row_number, rank) consumes the same two arrays:

  * ``new_seg (n,) bool`` — row starts a new segment;
  * ``seg_start (n,) int32`` — index of the row's segment start (a running
    ``cummax`` over flagged indices — no reset needed because segments are
    contiguous).

**Partition identity is the ordering identity** (the `sort_key_lanes`
transform): all NaN bit patterns collapse to one lane value, so NaN keys
form ONE partition (they are one contiguous block of the sort, where the
bitwise §8 identity would split equal-sort-position NaNs into
non-contiguous groups); ``-0.0`` and ``+0.0`` order apart and are two
partitions.  Deterministic, documented, and consistent with what the sort
itself can guarantee.

Cross-shard state (a range-partitioned table may split one partition across
a shard boundary — equal FULL keys never straddle, but equal partition keys
with different order keys can):

  * :func:`tail_halo` / leading rows — the last ``h`` valid rows of the
    previous shard, moved with one ``ppermute`` so bounded-lookback ops
    (rolling windows, lag) read across the boundary;
  * :func:`chain_carries` — per-shard boundary summaries pooled with one
    small AllGather, then chained so unbounded-lookback ops (cumulatives,
    row_number, rank) add the exact contribution of every preceding shard
    of the same partition.  The chain walks shards right-to-left and stays
    alive through shards that are entirely one partition (and through
    empty shards, which sample-sort splitter duplication can produce).

Neither mechanism is an AllToAll: the orderby→window elision contract
("zero additional AllToAll") is preserved on a real mesh.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.array_ops import spmd_ppermute
# one op table for the whole ordered stack: the carry chain must combine
# exactly like the scans it extends (kernels/window_scan/ref.py)
from repro.kernels.window_scan.ref import _IDENTITY, _combine

Cols = Dict[str, jnp.ndarray]


def boundary_flags(lanes: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """``new_seg`` flags from ``(n, L)`` key lanes (L may be 0 = one global
    partition).  Invalid rows are each their own segment, so padding can
    never join — or bridge — a real partition."""
    n = valid.shape[0]
    first = jnp.zeros((n,), bool).at[0].set(True)
    if lanes.shape[1]:
        diff = jnp.concatenate(
            [jnp.ones((1,), bool),
             jnp.any(lanes[1:] != lanes[:-1], axis=1)])
    else:
        diff = first
    prev_invalid = jnp.concatenate([jnp.ones((1,), bool), ~valid[:-1]])
    return first | diff | prev_invalid | ~valid


def flag_starts(flags: jnp.ndarray) -> jnp.ndarray:
    """``seg_start[i]`` = index of the nearest flagged row at or before i."""
    idx = jnp.arange(flags.shape[0], dtype=jnp.int32)
    return jax.lax.cummax(jnp.where(flags, idx, 0))


def tail_halo(arrays: Cols, count: jnp.ndarray, h: int, n_shards: int,
              axis: Optional[str]) -> Tuple[Cols, jnp.ndarray]:
    """Last ``h`` valid rows of each shard, delivered to the NEXT shard.

    Returns ``(received arrays (h, ...), received valid (h,))`` — the rows
    globally immediately preceding this shard's row 0, oldest first, with
    missing positions (short predecessor, or shard 0's absent predecessor —
    ppermute delivers zeros there) marked invalid.
    """
    j = jnp.arange(h, dtype=jnp.int32)
    src = count - h + j
    ok = src >= 0
    taken = {}
    for name, v in arrays.items():
        g = v[jnp.clip(src, 0, v.shape[0] - 1)]
        taken[name] = jnp.where(ok.reshape((-1,) + (1,) * (g.ndim - 1)), g,
                                jnp.zeros_like(g))
    if axis is None or n_shards == 1:
        return {k: jnp.zeros_like(v) for k, v in taken.items()}, \
            jnp.zeros((h,), bool)
    perm = [(s, s + 1) for s in range(n_shards - 1)]
    recv = {k: spmd_ppermute(v, axis, perm) for k, v in taken.items()}
    return recv, spmd_ppermute(ok, axis, perm)


def head_halo(arrays: Cols, count: jnp.ndarray, k: int, n_shards: int,
              axis: Optional[str]) -> Tuple[Cols, jnp.ndarray]:
    """First ``k`` valid rows of each shard, delivered to the PREVIOUS
    shard — the forward (lead) counterpart of :func:`tail_halo`."""
    j = jnp.arange(k, dtype=jnp.int32)
    ok = j < count
    taken = {}
    for name, v in arrays.items():
        g = v[jnp.clip(j, 0, v.shape[0] - 1)]
        taken[name] = jnp.where(ok.reshape((-1,) + (1,) * (g.ndim - 1)), g,
                                jnp.zeros_like(g))
    if axis is None or n_shards == 1:
        return {k2: jnp.zeros_like(v) for k2, v in taken.items()}, \
            jnp.zeros((k,), bool)
    perm = [(s + 1, s) for s in range(n_shards - 1)]
    recv = {k2: spmd_ppermute(v, axis, perm) for k2, v in taken.items()}
    return recv, spmd_ppermute(ok, axis, perm)


def chain_carries(head_keys: jnp.ndarray, tail_keys: jnp.ndarray,
                  tail_vals: jnp.ndarray, whole: jnp.ndarray,
                  nonempty: jnp.ndarray, op: str = "sum") -> jnp.ndarray:
    """Cross-shard prefix carry for each shard's HEAD segment.

    All inputs are AllGathered per-shard summaries, leading dim =
    ``n_shards``: first/last valid row's partition-key lanes, the reduction
    of each shard's TAIL segment over the carried lanes, whether the whole
    shard is one segment, and whether it holds any row.  Returns the
    ``(n_shards, ...)`` carries: ``carry[s]`` = reduction over every row of
    ``s``'s head partition on shards ``< s`` (the op identity when the
    partition starts at shard ``s``).

    The double loop is static (``n_shards²`` scalar-ish ops at trace time)
    and runs identically on every shard — each picks its own row via
    ``axis_index``.  Empty shards are transparent: the chain walks through
    them, since splitter duplication can park an empty shard mid-partition.
    """
    p = head_keys.shape[0]
    ident = jnp.full(tail_vals.shape[1:], _IDENTITY[op], tail_vals.dtype)
    outs = []
    for s in range(p):
        carry = ident
        alive = jnp.asarray(True)
        for r in range(s - 1, -1, -1):
            keymatch = jnp.all(tail_keys[r] == head_keys[s]) \
                if head_keys.shape[1] else jnp.asarray(True)
            link = alive & nonempty[r] & keymatch
            carry = jnp.where(link, _combine(op, tail_vals[r], carry),
                              carry)
            alive = alive & (~nonempty[r] | (link & whole[r]))
        outs.append(carry)
    return jnp.stack(outs)
