"""The windowed-aggregation engine (DESIGN.md §9).

Evaluates every window lane of one ``window_aggregate`` call over a table
already sorted by ``(partition_by, order_by)`` — the range layout the §9
exchange establishes and ``DistTable.partitioning`` records.  One pass,
organized around the segment machinery (``segments.py``):

  * **rolling** sum/mean/count/min/max (``rows=w``): all sum-combining
    lanes ride ONE fused ``windowed_scan`` (mean = sum lane / derived
    count; count itself is pure index arithmetic off ``seg_start``),
    min/max scan per column — the ``kernels/window_scan`` surface;
  * **cumulative** aggregates (``rows=None``): the same lanes through
    ``segmented_cumulative`` plus the cross-shard carry chain;
  * **lag / lead / row_number / rank**: gathers and index arithmetic off
    the same segment boundaries — no scan, no sort, no kernel.

Cross-shard correctness rides a bounded ``ppermute`` halo (rolling / lag /
lead) and one summary AllGather carry chain (cumulative / row_number /
rank); neither is an AllToAll, so a ``window`` on a range-partitioned input
adds ZERO AllToAll and ZERO sort primitives to the trace (jaxpr-asserted).

Overflow (§2 contract): a window is *truncated* when it needs rows from
beyond what the halo can prove — the predecessor shard held fewer same-
partition rows than the lookback (or, for lead, the successor's head ran
out while the partition could not be proven to end).  Truncated windows
are counted and returned, never silently wrong-valued: zero overflow is
the exactness certificate.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import exchange
from repro.core.array_ops import spmd_allgather
from repro.core.table_ops import _bcast as _mask_rows
from repro.kernels.window_scan import ops as wops

from .segments import (boundary_flags, chain_carries, flag_starts,
                       head_halo, tail_halo)

Cols = Dict[str, jnp.ndarray]

#: op → (needs a value column, takes an offset param)
WINDOW_OPS = {
    "sum": (True, False), "mean": (True, False), "count": (False, False),
    "min": (True, False), "max": (True, False),
    "lag": (True, True), "lead": (True, True),
    "row_number": (False, False), "rank": (False, False),
}


def normalize_aggs(aggs, columns: Sequence[str], rows: Optional[int]
                   ) -> List[Tuple[str, Optional[str], str, int]]:
    """Validate window specs eagerly; returns ``(label, col, op, param)``.

    Accepts ``(col, op)`` and ``(col, op, offset)`` entries; ``col`` is
    ``None`` for row_number/rank.  Errors name the offending entry before
    anything traces (the join-validation style).
    """
    out = []
    seen = set(columns)
    if rows is not None and (not isinstance(rows, int) or rows < 1):
        raise ValueError(f"rows={rows!r} must be a positive int or None "
                         f"(cumulative)")
    if not aggs:
        raise ValueError("window aggregation needs at least one agg")
    for entry in aggs:
        if len(entry) == 2:
            col, op = entry
            param = 1
        elif len(entry) == 3:
            col, op, param = entry
        else:
            raise ValueError(f"window agg {entry!r} must be (col, op) or "
                             f"(col, op, offset)")
        if op not in WINDOW_OPS:
            raise ValueError(f"unknown window op {op!r} in {entry!r}; "
                             f"expected one of {tuple(WINDOW_OPS)}")
        needs_col, takes_param = WINDOW_OPS[op]
        if needs_col or (op == "count" and col is not None):
            if col not in columns:
                raise ValueError(f"window agg {entry!r} names unknown "
                                 f"column {col!r}")
        elif col is not None:
            raise ValueError(f"window op {op!r} takes no column; use "
                             f"(None, {op!r})")
        if takes_param:
            if not isinstance(param, int) or param < 1:
                raise ValueError(f"window agg {entry!r}: offset must be a "
                                 f"positive int, got {param!r}")
        elif len(entry) == 3:
            raise ValueError(f"window op {op!r} takes no offset "
                             f"({entry!r})")
        if op in ("row_number", "rank") or (op == "count" and col is None):
            label = op
        elif takes_param and param != 1:
            label = f"{col}_{op}{param}"
        else:
            label = f"{col}_{op}"
        if label in seen:
            raise ValueError(f"window output column {label!r} collides "
                             f"with an existing column or another agg")
        seen.add(label)
        out.append((label, col, op, param))
    return out


def eval_window(cols: Cols, count: jnp.ndarray, *, pkeys, okeys, ascending,
                aggs, rows: Optional[int], n_shards: int,
                axis: Optional[str]) -> Tuple[Cols, jnp.ndarray]:
    """Evaluate normalized window ``aggs`` over sorted local columns.

    Returns ``(new columns, overflow)``; input columns are untouched (a
    window never moves or drops rows, it only adds lanes).
    """
    cap = next(iter(cols.values())).shape[0]
    idx = jnp.arange(cap, dtype=jnp.int32)
    mask = idx < count
    lanes = exchange.order_lanes(cols, tuple(pkeys) + tuple(okeys),
                                 ascending)
    plane = lanes[:, :len(pkeys)]
    new_seg = boundary_flags(plane, mask)
    seg_start = flag_starts(new_seg)
    distributed = axis is not None and n_shards > 1

    def gather(x):  # per-shard summary → (n_shards, ...) pool
        return spmd_allgather(x[None], axis, tiled=False)[:, 0]

    # ---- lane plan --------------------------------------------------------
    sum_cols = list(dict.fromkeys(
        c for _, c, op, _ in aggs if op in ("sum", "mean")))
    mm_items = list(dict.fromkeys(
        (c, op) for _, c, op, _ in aggs if op in ("min", "max")))
    lags = [(lb, c, k) for lb, c, op, k in aggs if op == "lag"]
    leads = [(lb, c, k) for lb, c, op, k in aggs if op == "lead"]
    need_rank = any(op == "rank" for _, _, op, _ in aggs)
    need_rn = any(op == "row_number" for _, _, op, _ in aggs)
    rolling = rows is not None
    run_start = flag_starts(boundary_flags(lanes, mask)) if need_rank \
        else None

    # f32 scan lanes: sum columns first, then one lane per min/max column
    scan_parts = [cols[c].astype(jnp.float32)[:, None] for c in sum_cols]
    scan_parts += [cols[c].astype(jnp.float32)[:, None] for c, _ in mm_items]
    scan_stack = (jnp.concatenate(scan_parts, axis=1) if scan_parts
                  else jnp.zeros((cap, 0), jnp.float32))
    n_sum = len(sum_cols)

    # ---- cross-shard carry chain (unbounded lookback) ---------------------
    carry_cnt = jnp.zeros((), jnp.int32)
    carry_run = jnp.zeros((), jnp.int32)
    if distributed:
        nonempty = count > 0
        last = jnp.clip(count - 1, 0, cap - 1)
        head_k, tail_k = gather(plane[0]), gather(plane[last])
        whole = gather(nonempty & (seg_start[last] == 0))
        ne = gather(nonempty)
        me = jax.lax.axis_index(axis)
        carry_cnt = chain_carries(
            head_k, tail_k,
            gather(jnp.where(nonempty, last - seg_start[last] + 1, 0)),
            whole, ne)[me]
        if need_rank:
            carry_run = chain_carries(
                gather(lanes[0]), gather(lanes[last]),
                gather(jnp.where(nonempty, last - run_start[last] + 1, 0)),
                gather(nonempty & (run_start[last] == 0)), ne)[me]

    out: Cols = {}
    overflow = jnp.zeros((), jnp.int32)

    # ---- backward halo: rolling scans AND lag share one ppermute ----------
    h_roll = rows - 1 if rolling else 0
    h = min(max(h_roll, max((k for _, _, k in lags), default=0)), cap)
    halo_arrays = {"lanes": plane}
    if rolling and scan_stack.shape[1]:
        halo_arrays["vals"] = scan_stack
    for _, c, _ in lags:
        halo_arrays.setdefault(f"lag:{c}", cols[c])
    if h > 0:
        halo, halo_ok = tail_halo(halo_arrays, count, h, n_shards, axis)
    else:
        halo = {k2: v[:0] for k2, v in halo_arrays.items()}
        halo_ok = jnp.zeros((0,), bool)
    ext_valid = jnp.concatenate([halo_ok, mask])
    ext_plane = jnp.concatenate([halo["lanes"], plane])
    ext_seg = flag_starts(boundary_flags(ext_plane, ext_valid))
    if distributed and h > 0:
        # truncation: lookback the halo could not prove (§2) — the
        # predecessor held fewer same-partition rows than the deepest
        # bounded lookback while the carry chain proves more exist
        need = jnp.maximum(h - idx, 0)
        carry_seg = jnp.where(seg_start == 0, carry_cnt, 0)
        avail = jnp.maximum(h - ext_seg[h:], 0)
        overflow += jnp.sum(
            mask & (jnp.minimum(need, carry_seg) > avail), dtype=jnp.int32)
    for lb, c, k in lags:
        src_arr = jnp.concatenate([halo[f"lag:{c}"], cols[c]])
        src = h + idx - k
        ok = mask & (src >= ext_seg[h + idx])
        out[lb] = _mask_rows(ok, src_arr[jnp.clip(src, 0, h + cap - 1)])

    # ---- rolling path: blocked windowed scan over the halo-extended rows --
    sums, mm_out = None, {}
    if rolling:
        ext_idx = jnp.arange(h + cap, dtype=jnp.int32)
        a_ext = jnp.maximum(ext_idx - (rows - 1), ext_seg)
        cnt_win = (ext_idx - a_ext + 1)[h:]
        if scan_stack.shape[1]:
            ext_vals = jnp.concatenate([halo["vals"], scan_stack]) \
                if h > 0 else scan_stack
            if n_sum:
                sums = wops.windowed_scan(ext_vals[:, :n_sum], ext_seg,
                                          rows, "sum")[h:]
            for i, (c, op) in enumerate(mm_items):
                mm_out[(c, op)] = wops.windowed_scan(
                    ext_vals[:, n_sum + i], ext_seg, rows, op)[h:]
    else:
        # ---- cumulative path: local scans + exact carry chain -------------
        if scan_stack.shape[1]:
            if n_sum:
                sums = wops.segmented_cumulative(scan_stack[:, :n_sum],
                                                 seg_start, "sum")
            for i, (c, op) in enumerate(mm_items):
                mm_out[(c, op)] = wops.segmented_cumulative(
                    scan_stack[:, n_sum + i:n_sum + i + 1], seg_start, op
                )[:, 0]
            if distributed:
                in_first = seg_start == 0
                if n_sum:
                    tail_tot = jnp.where(
                        nonempty, sums[last], jnp.zeros((n_sum,),
                                                        jnp.float32))
                    cv = chain_carries(head_k, tail_k, gather(tail_tot),
                                       whole, ne)[me]
                    sums = jnp.where(in_first[:, None], sums + cv[None, :],
                                     sums)
                for (c, op), v in list(mm_out.items()):
                    cv = chain_carries(
                        head_k, tail_k,
                        gather(jnp.where(nonempty, v[last], 0.0)),
                        whole, ne, op=op)[me]
                    comb = jnp.minimum if op == "min" else jnp.maximum
                    mm_out[(c, op)] = jnp.where(in_first, comb(v, cv), v)
        cnt_win = idx - seg_start + 1 + jnp.where(seg_start == 0,
                                                  carry_cnt, 0)

    # ---- leads: forward halo, dynamic gather across the boundary ----------
    if leads:
        kmax = min(max(k for _, _, k in leads), cap)
        lead_arrays = {"lanes": plane}
        for _, c, _ in leads:
            lead_arrays.setdefault(f"lead:{c}", cols[c])
        fhalo, fok = head_halo(lead_arrays, count, kmax, n_shards, axis)
        # same-partition prefix of the forward halo, per local row: the
        # chain breaks at the first invalid or different-key halo row
        if len(pkeys):
            eq = jnp.all(fhalo["lanes"][None, :, :] == plane[:, None, :],
                         axis=2) & fok[None, :]
        else:
            eq = jnp.broadcast_to(fok[None, :], (cap, kmax))
        avail_f = jnp.sum(jnp.cumprod(eq.astype(jnp.int32), axis=1),
                          axis=1).astype(jnp.int32)
        ended = (avail_f < kmax) & fok[jnp.clip(avail_f, 0, kmax - 1)]
        for lb, c, k in leads:
            src = idx + k
            local_ok = mask & (src < count) & \
                (seg_start[jnp.clip(src, 0, cap - 1)] == seg_start)
            hj = src - count
            halo_ok = mask & (hj >= 0) & (hj < avail_f)
            hv = fhalo[f"lead:{c}"][jnp.clip(hj, 0, kmax - 1)]
            lv = cols[c][jnp.clip(src, 0, cap - 1)]
            out[lb] = jnp.where(local_ok, lv, _mask_rows(halo_ok, hv))
        if distributed:
            # truncation is only possible for rows whose partition reaches
            # the local end (the shard's LAST segment) while some LATER
            # shard still holds rows — otherwise the table provably ends
            # and every lead is exact, no matter what the (absent or
            # empty-successor) halo says
            in_tail_seg = seg_start == seg_start[last]
            later_ne = jnp.any(
                jnp.where(jnp.arange(n_shards) > me, ne, False))
            need_f = jnp.maximum(idx + kmax - (count - 1), 0)
            overflow += jnp.sum(
                mask & in_tail_seg & (need_f > avail_f) & ~ended,
                dtype=jnp.int32) * later_ne.astype(jnp.int32)

    # ---- ranking lanes ----------------------------------------------------
    if need_rn:
        out["row_number"] = jnp.where(
            mask, idx - seg_start + 1
            + jnp.where(seg_start == 0, carry_cnt, 0), 0)
    if need_rank:
        out["rank"] = jnp.where(
            mask, run_start - seg_start + 1
            + jnp.where(seg_start == 0, carry_cnt, 0)
            - jnp.where(run_start == 0, carry_run, 0), 0)

    # ---- assemble value-agg labels ----------------------------------------
    cnt_f = jnp.maximum(cnt_win.astype(jnp.float32), 1.0)
    for lb, c, op, _ in aggs:
        if op == "count":
            out[lb] = jnp.where(mask, cnt_win, 0)
        elif op == "sum":
            out[lb] = _mask_rows(mask, sums[:, sum_cols.index(c)])
        elif op == "mean":
            out[lb] = _mask_rows(mask, sums[:, sum_cols.index(c)] / cnt_f)
        elif op in ("min", "max"):
            out[lb] = _mask_rows(mask, mm_out[(c, op)])
    return out, overflow
