"""Ordered-analytics subsystem: windowed aggregation over range layouts.

The ordered twin of the hash stack (DESIGN.md §9): ``segments`` turns the
sorted layout into partition boundaries and cross-shard halo/carry state,
``engine`` evaluates rolling/cumulative aggregates, lag/lead, row_number
and rank in one pass over the ``kernels/window_scan`` surface.  Operators
are surfaced in ``core.table_ops`` (``window_aggregate``/``rank``) and the
DataFrame/TSet layers.
"""
from .engine import WINDOW_OPS, eval_window, normalize_aggs
from .segments import boundary_flags, chain_carries, flag_starts

__all__ = ["WINDOW_OPS", "eval_window", "normalize_aggs", "boundary_flags",
           "chain_carries", "flag_starts"]
