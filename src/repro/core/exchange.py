"""Fused single-collective row-exchange engine (shuffle hot path, Fig 2).

Every distributed table operator (join, groupby, set ops, orderby) reduces
to the shuffle primitive — re-distributing rows so related keys land on the
same shard (paper §IV-B-1).  This module is the one implementation of that
primitive, replacing the seed's per-column exchange with three optimisations
(DESIGN.md §3):

  1. **Packed exchange** — every column is bit-cast to ``uint32`` lanes and
     packed into a single ``(n_shards * bucket, row_width)`` buffer, so each
     shuffle issues exactly **one** AllToAll regardless of column count.  The
     per-destination send counts travel in a metadata row fused into the same
     buffer — a shuffle is ONE collective, not ``n_cols + 1``.
  2. **Sort-free bucketing** — destination slots come from a counting-sort
     scatter (per-destination prefix ranks + the histogram that the Pallas
     ``hash_partition`` kernel already produces), not from ``argsort``.
     Compaction (``compact_rows``) is likewise a cumsum scatter.  The shuffle
     path is O(n) and contains zero ``sort`` primitives.
  3. **Hash carrying** — the row hashes ``(h1, h2)`` computed for destination
     assignment are threaded through the exchange as hidden columns
     (:data:`H1_NAME` / :data:`H2_NAME`), so join / set-op kernels never
     rehash rows after a shuffle — the carried pair directly seeds the
     hash-join / set-op slot tables (``h1`` = probe start, ``h2|1`` =
     stride; DESIGN.md §3.3/§8), with :func:`key_compare_u32` providing
     the matching bitwise verification lanes.

The static-shape overflow contract is unchanged from the seed: rows beyond a
destination bucket (send side) or beyond ``out_capacity`` (receive side) are
*counted and dropped*, never silently corrupted; callers surface the count so
the workflow layer can retry with larger capacities (paper §VII-F).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .array_ops import spmd_allgather, spmd_alltoall

Cols = Dict[str, jnp.ndarray]

#: Reserved hidden-column names for carried row hashes.  Operator impls pop
#: these after a shuffle instead of recomputing ``hash_columns``.
H1_NAME = "_h1"
H2_NAME = "_h2"
#: Reserved hidden-column name for carried order lanes: the spill engine
#: (``repro.spill``) persists :func:`order_lanes` in its on-disk runs so
#: re-ingested partitions re-sort on the host without recomputing the
#: directional transform (DESIGN.md §10).
LANES_NAME = "_lanes"


# ===========================================================================
# bit-exact uint32 packing
# ===========================================================================
@dataclasses.dataclass(frozen=True)
class ColSpec:
    """Static layout of one column inside the packed row (DESIGN.md §3.1)."""
    name: str
    dtype: np.dtype
    trailing: Tuple[int, ...]
    start: int
    lanes: int


def _col_to_u32(col: jnp.ndarray) -> jnp.ndarray:
    """Bit-exact reversible view of a column as ``(cap, lanes)`` uint32."""
    cap = col.shape[0]
    x = col.reshape(cap, -1) if col.ndim > 1 else col.reshape(cap, 1)
    size = jnp.dtype(x.dtype).itemsize
    if x.dtype == jnp.bool_:
        u = x.astype(jnp.uint32)
    elif size == 4:
        u = jax.lax.bitcast_convert_type(x, jnp.uint32)
    elif size == 8:
        u = jax.lax.bitcast_convert_type(x, jnp.uint32)  # (cap, L, 2)
    elif size == 2:
        u = jax.lax.bitcast_convert_type(x, jnp.uint16).astype(jnp.uint32)
    elif size == 1:
        u = jax.lax.bitcast_convert_type(x, jnp.uint8).astype(jnp.uint32)
    else:
        raise TypeError(f"unsupported column dtype {col.dtype}")
    return u.reshape(cap, -1)


def _u32_to_col(u: jnp.ndarray, dtype, trailing: Tuple[int, ...]) -> jnp.ndarray:
    """Inverse of :func:`_col_to_u32`."""
    cap = u.shape[0]
    dt = jnp.dtype(dtype)
    if dt == jnp.bool_:
        x = u.astype(jnp.bool_)
    elif dt.itemsize == 4:
        x = jax.lax.bitcast_convert_type(u, dtype)
    elif dt.itemsize == 8:
        x = jax.lax.bitcast_convert_type(u.reshape(cap, -1, 2), dtype)
    elif dt.itemsize == 2:
        x = jax.lax.bitcast_convert_type(u.astype(jnp.uint16), dtype)
    else:
        x = jax.lax.bitcast_convert_type(u.astype(jnp.uint8), dtype)
    return x.reshape((cap,) + tuple(trailing))


def pack_columns(cols: Cols) -> Tuple[jnp.ndarray, Tuple[ColSpec, ...]]:
    """Pack all columns into one ``(cap, row_width)`` uint32 buffer."""
    parts, specs, start = [], [], 0
    for name in sorted(cols):
        u = _col_to_u32(cols[name])
        specs.append(ColSpec(name, cols[name].dtype,
                             tuple(cols[name].shape[1:]), start, u.shape[1]))
        start += u.shape[1]
        parts.append(u)
    return jnp.concatenate(parts, axis=1), tuple(specs)


def unpack_columns(buf: jnp.ndarray, specs: Sequence[ColSpec]) -> Cols:
    """Recover original dtypes/shapes from a packed uint32 buffer."""
    return {s.name: _u32_to_col(buf[:, s.start:s.start + s.lanes],
                                s.dtype, s.trailing) for s in specs}


# ===========================================================================
# sort-free primitives
# ===========================================================================
def dest_ranks(dest: jnp.ndarray, n_parts: int,
               chunk: int = 16) -> jnp.ndarray:
    """Stable within-destination rank of each row (counting sort, no argsort).

    ``rank[i]`` = number of earlier rows with the same destination.  Rows with
    ``dest >= n_parts`` (invalid) get an arbitrary rank — callers mask them.

    Destinations are processed in chunks of ``chunk`` so the one-hot prefix
    buffer stays O(n * chunk) regardless of shard count (a full
    ``(n, n_parts)`` cumsum would be a memory blowup at pod-scale meshes).
    """
    n = dest.shape[0]
    rank = jnp.zeros((n,), jnp.int32)
    for c0 in range(0, n_parts, chunk):
        parts = jnp.arange(c0, min(c0 + chunk, n_parts), dtype=dest.dtype)
        onehot = dest[:, None] == parts[None, :]
        prefix = jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1
        idx = jnp.clip(dest.astype(jnp.int32) - c0, 0, parts.shape[0] - 1)
        picked = jnp.take_along_axis(prefix, idx[:, None], axis=1)[:, 0]
        in_chunk = (dest >= c0) & (dest < c0 + parts.shape[0])
        rank = jnp.where(in_chunk, picked, rank)
    return rank


def compact_rows(cols: Cols, keep: jnp.ndarray,
                 out_capacity: int) -> Tuple[Cols, jnp.ndarray, jnp.ndarray]:
    """Move kept rows to the front (stable) via cumsum scatter; no sort.

    Returns ``(columns, new_count, n_truncated)`` — rows past ``out_capacity``
    are dropped and counted, matching the seed overflow contract.  Padding
    rows are zero-filled (operators never read them).
    """
    total = jnp.sum(keep, dtype=jnp.int32)
    pos = jnp.cumsum(keep.astype(jnp.int32)) - 1
    slot = jnp.where(keep, pos, out_capacity)  # out-of-bounds ⇒ dropped
    out = {}
    for k, v in cols.items():
        buf = jnp.zeros((out_capacity,) + v.shape[1:], v.dtype)
        out[k] = buf.at[slot].set(v, mode="drop")
    new_count = jnp.minimum(total, out_capacity).astype(jnp.int32)
    return out, new_count, total - new_count


# ===========================================================================
# the packed single-collective exchange
# ===========================================================================
def exchange_rows(cols: Cols, dest: jnp.ndarray, n_shards: int, bucket: int,
                  axis: Optional[str], hist: Optional[jnp.ndarray] = None):
    """Bucket rows by destination shard and exchange them in ONE AllToAll.

    ``dest`` must be ``>= n_shards`` for invalid rows; ``hist`` is the
    per-destination valid-row histogram (recomputed by scatter-add when not
    supplied, e.g. by the fused ``hash_partition`` kernel).

    Frame layout (DESIGN.md §3.2): per destination, ``bucket`` packed data
    rows followed by one metadata row whose lane 0 holds the send count —
    so counts ride the same collective as the data.

    Returns ``(received_cols, received_valid_mask, n_overflowed_send)``.
    """
    if hist is None:
        hist = jnp.zeros(n_shards + 1, jnp.int32).at[
            jnp.clip(dest, 0, n_shards)].add(1)[:n_shards]
    packed, specs = pack_columns(cols)
    width = packed.shape[1]

    rank = dest_ranks(dest, n_shards)
    ok = (dest < n_shards) & (rank < bucket)
    slot = jnp.where(ok, dest * bucket + rank, n_shards * bucket)
    buf = jnp.zeros((n_shards * bucket, width), jnp.uint32
                    ).at[slot].set(packed, mode="drop")

    sent = jnp.minimum(hist, bucket)
    overflow = jnp.sum(hist - sent)

    if axis is not None:
        meta = jnp.zeros((n_shards, 1, width), jnp.uint32
                         ).at[:, 0, 0].set(sent.astype(jnp.uint32))
        framed = jnp.concatenate(
            [buf.reshape(n_shards, bucket, width), meta], axis=1)
        recv = spmd_alltoall(framed.reshape(-1, width), axis)
        recv = recv.reshape(n_shards, bucket + 1, width)
        recv_cnt = recv[:, bucket, 0].astype(jnp.int32)
        buf = recv[:, :bucket].reshape(n_shards * bucket, width)
    else:
        recv_cnt = sent

    pos = jnp.arange(n_shards * bucket, dtype=jnp.int32)
    valid = (pos % bucket) < recv_cnt[pos // bucket]
    return unpack_columns(buf, specs), valid, overflow


def hash_shuffle(cols: Cols, count: jnp.ndarray, key_names: Sequence[str],
                 n_shards: int, bucket: int, out_capacity: int,
                 axis: Optional[str], *, carry_hashes: bool = False):
    """Hash-partition + packed exchange + compaction in one call.

    Destination assignment and the send histogram come from the fused
    ``hash_partition`` dispatcher (Pallas on TPU, jnp elsewhere).  With
    ``carry_hashes`` the row hashes travel as hidden :data:`H1_NAME` /
    :data:`H2_NAME` columns so downstream kernels skip rehashing; pop them
    with :func:`take_hashes`.

    A completed call establishes the ``(key_names, n_shards)`` hash layout
    that operators record as ``DistTable.partitioning`` — the evidence the
    shuffle-elision machinery trusts (DESIGN.md §4).  Any exchange on other
    keys or a different shard count invalidates it.

    Returns ``(columns, new_count, overflow)``.
    """
    from repro.kernels.hash_partition import ops as hpops  # lazy: no cycle

    capacity = next(iter(cols.values())).shape[0]
    mask = jnp.arange(capacity, dtype=jnp.int32) < count
    key_cols = [cols[k] for k in key_names]
    if carry_hashes:
        check_no_reserved(cols)
        dest, hist, h1, h2 = hpops.hash_partition(
            key_cols, n_shards, mask, return_hashes=True)
        cols = dict(cols)
        cols[H1_NAME], cols[H2_NAME] = h1, h2
    else:
        dest, hist = hpops.hash_partition(key_cols, n_shards, mask)
    bufs, valid, ov_send = exchange_rows(cols, dest, n_shards, bucket, axis,
                                         hist=hist)
    out, new_count, ov_recv = compact_rows(bufs, valid, out_capacity)
    return out, new_count, ov_send + ov_recv


# ===========================================================================
# sample-sort range partitioning (DESIGN.md §9)
# ===========================================================================
def sort_key_lanes(col: jnp.ndarray, ascending: bool = True) -> jnp.ndarray:
    """Monotone ``(n, lanes)`` uint32 view of a key column for ordering.

    Unsigned lexicographic comparison of the lanes reproduces the column's
    value order exactly — the ordered twin of the §3.1 bit-packing:

      * floats narrow to f32 and map through the standard total-order
        transform (sign bit set for non-negatives, full complement for
        negatives), so ``-inf < -0.0 < +0.0 < +inf``;
      * signed integers flip their sign bit; unsigned/bool widen as-is;
      * ``ascending=False`` complements the lane, reversing the order.

    **NaN-last contract:** every NaN bit pattern is forced to the maximum
    lane value AFTER the direction flip, so NaNs form one deterministic
    block at the END of the order in BOTH directions.  (The old negation
    trick — ``sort by -x`` — flipped NaNs to the front under descending
    because complementing a NaN's transform does not commute with the
    override; this function is the fix, property-tested both ways.)

    64-bit key dtypes are rejected: with jax x64 disabled they cannot
    round-trip anyway — narrow the column first (``io.schema`` rules).
    """
    if jnp.dtype(col.dtype).itemsize == 8:
        raise TypeError(
            f"orderby/range-partition key dtype {col.dtype} is 64-bit; "
            f"narrow the column to a 32-bit type first")
    if col.ndim > 1:
        raise TypeError("orderby/range-partition keys must be 1-D columns")
    if jnp.issubdtype(col.dtype, jnp.floating):
        f = col.astype(jnp.float32)
        b = jax.lax.bitcast_convert_type(f, jnp.uint32)
        m = jnp.where(b >> 31 != 0, ~b, b | jnp.uint32(0x80000000))
        nan = jnp.isnan(f)
    elif col.dtype == jnp.bool_:
        m = col.astype(jnp.uint32)
        nan = None
    elif jnp.issubdtype(col.dtype, jnp.unsignedinteger):
        m = col.astype(jnp.uint32)
        nan = None
    else:  # signed integers
        m = jax.lax.bitcast_convert_type(
            col.astype(jnp.int32), jnp.uint32) ^ jnp.uint32(0x80000000)
        nan = None
    if not ascending:
        m = ~m
    if nan is not None:
        m = jnp.where(nan, jnp.uint32(0xFFFFFFFF), m)
    return m[:, None]


def order_lanes(cols: Cols, key_names: Sequence[str],
                ascending: Sequence[bool]) -> jnp.ndarray:
    """Concatenated directional lanes for multi-key ordering.

    Row ``i`` sorts before row ``j`` iff ``lanes[i]`` is lexicographically
    below ``lanes[j]`` (unsigned, lane 0 most significant) — so one uint32
    matrix carries the whole multi-key, per-key-direction, NaN-last order.
    """
    return jnp.concatenate(
        [sort_key_lanes(cols[k], asc)
         for k, asc in zip(key_names, ascending)], axis=1)


def lex_order(lanes: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Stable sort permutation for directional lanes; invalid rows last."""
    keys = tuple(lanes[:, lane] for lane in range(lanes.shape[1] - 1, -1, -1))
    return jnp.lexsort(keys + (~mask,))


def _lex_leq(splitters: jnp.ndarray, lanes: jnp.ndarray) -> jnp.ndarray:
    """``(S, n)`` bool: splitter ``s`` <= row lexicographically."""
    L = lanes.shape[1]
    res = jnp.ones((splitters.shape[0], lanes.shape[0]), bool)
    for lane in range(L - 1, -1, -1):
        sp = splitters[:, lane][:, None]
        rw = lanes[:, lane][None, :]
        res = (sp < rw) | ((sp == rw) & res)
    return res


def range_splitters(lanes: jnp.ndarray, mask: jnp.ndarray, n_shards: int,
                    n_samples: int, axis: Optional[str]) -> jnp.ndarray:
    """Per-shard regular sampling + AllGather → ``n_shards - 1`` splitters.

    Each shard samples ``n_samples`` valid rows at a regular stride (an
    even-spaced picture of its local distribution), all shards pool the
    samples with one AllGather, sort them lexicographically, and read the
    splitters at even positions.  Skew bound: with ``s`` samples per shard
    a destination receives at most ``~(1 + p/s)`` times its fair share of
    DISTINCT key positions (standard sample-sort bound) — duplicates of
    one key all land on one shard by the side="right" rule below, so heavy
    duplicate keys concentrate instead of splitting (DESIGN.md §9).
    """
    count = jnp.sum(mask, dtype=jnp.int32)
    stride = jnp.maximum(count // n_samples, 1)
    sidx = jnp.minimum(jnp.arange(n_samples, dtype=jnp.int32) * stride,
                       jnp.maximum(count - 1, 0))
    sample = jnp.where((sidx < count)[:, None], lanes[sidx],
                       jnp.uint32(0xFFFFFFFF))
    if axis is not None:
        sample = spmd_allgather(sample, axis)
    order = lex_order(sample, jnp.ones((sample.shape[0],), bool))
    sample = sample[order]
    total = sample.shape[0]
    spos = (jnp.arange(1, n_shards, dtype=jnp.int32) * total) // n_shards
    return sample[spos]


def range_shuffle(cols: Cols, count: jnp.ndarray, key_names: Sequence[str],
                  ascending: Sequence[bool], n_shards: int, bucket: int,
                  out_capacity: int, axis: Optional[str], *,
                  n_samples: int = 64, sort_local: bool = True):
    """Sample-sort range partitioning + packed exchange (+ local sort).

    The ordered twin of :func:`hash_shuffle`: destinations come from a
    lexicographic ``searchsorted`` against sampled splitters instead of a
    hash, and the rows ride the SAME single packed AllToAll
    (:func:`exchange_rows`).  Destination rule is side="right" — a row goes
    to ``#{splitters <= row}`` — so rows with equal full keys always share
    a shard (range metadata's boundary guarantee).  With ``sort_local``
    the received rows are lexsorted, completing the sample sort: the
    result is globally ordered by ``(key_names, ascending)`` with NaNs
    last.  A completed call establishes the layout that operators record
    as ``("range", keys, ascending, n_shards)`` partitioning metadata
    (DESIGN.md §9).

    Returns ``(columns, new_count, overflow)``.
    """
    capacity = next(iter(cols.values())).shape[0]
    mask = jnp.arange(capacity, dtype=jnp.int32) < count
    lanes = order_lanes(cols, key_names, ascending)

    if n_shards > 1:
        splitters = range_splitters(lanes, mask, n_shards, n_samples, axis)
        dest = jnp.sum(_lex_leq(splitters, lanes), axis=0, dtype=jnp.int32)
        dest = jnp.where(mask, dest, n_shards)
        bufs, valid, ov_send = exchange_rows(cols, dest, n_shards, bucket,
                                             axis)
        out, new_count, ov_recv = compact_rows(bufs, valid, out_capacity)
        overflow = ov_send + ov_recv
    else:
        out, new_count, overflow = compact_rows(cols, mask, out_capacity)
    if sort_local:
        m = jnp.arange(out_capacity, dtype=jnp.int32) < new_count
        order = lex_order(order_lanes(out, key_names, ascending), m)
        out = {k: v[order] for k, v in out.items()}
    return out, new_count, overflow


def key_compare_u32(cols: Cols, key_names: Sequence[str]) -> jnp.ndarray:
    """Bitwise key-comparison lanes, consistent with the hash identity.

    Builds the ``(N, L)`` uint32 matrix the hash-join / set-op kernels
    verify candidates against: float keys narrow to float32 and compare by
    bit pattern — exactly the identity ``hash_columns`` uses, so NaN keys
    with equal bits are equal and ``-0.0 != +0.0`` (DESIGN.md §8) — while
    integer/bool keys compare by their packed two's-complement lanes
    (identical to value equality).  The lane packing reuses the §3.1
    exchange layout (:func:`_col_to_u32`), so 64-bit integer keys keep both
    halves.  Comparing rows ``i`` and ``j`` is then
    ``jnp.all(m[i] == m[j])`` — two uint32 lane compares per key column,
    never a trip through the original dtypes.
    """
    parts = []
    for name in key_names:
        col = cols[name]
        if jnp.issubdtype(col.dtype, jnp.floating):
            col = jax.lax.bitcast_convert_type(
                col.astype(jnp.float32), jnp.uint32)
        parts.append(_col_to_u32(col))
    return jnp.concatenate(parts, axis=1)


def check_no_reserved(names: Sequence[str]) -> None:
    """Reject user tables that use the reserved hidden-column names."""
    clash = {H1_NAME, H2_NAME, LANES_NAME} & set(names)
    if clash:
        raise ValueError(
            f"column names {sorted(clash)} are reserved for carried row "
            f"hashes / order lanes (core/exchange.py); rename the column(s)")


def take_hashes(cols: Cols, key_names: Sequence[str]
                ) -> Tuple[Cols, jnp.ndarray, jnp.ndarray]:
    """Pop carried ``(h1, h2)`` from a shuffled table, or compute them.

    After a :func:`hash_shuffle` with ``carry_hashes=True`` this is a free
    dictionary pop; on the unshuffled (single-shard) path it falls back to
    ``hash_columns`` — same values either way.
    """
    from .table import hash_columns  # lazy: table does not import exchange

    cols = dict(cols)
    if H1_NAME in cols:
        return cols, cols.pop(H1_NAME), cols.pop(H2_NAME)
    h1, h2 = hash_columns([cols[k] for k in key_names])
    return cols, h1, h2


def strip_hidden(cols: Cols) -> Cols:
    """Drop carried-hash columns before handing a table back to the user."""
    return {k: v for k, v in cols.items()
            if k not in (H1_NAME, H2_NAME, LANES_NAME)}


# ===========================================================================
# seed reference implementation (oracle for parity tests)
# ===========================================================================
def exchange_rows_reference(cols: Cols, dest: jnp.ndarray, n_shards: int,
                            bucket: int, axis: Optional[str]):
    """The seed per-column argsort exchange, kept verbatim as a test oracle.

    Issues one AllToAll per column plus a count side-channel; bucketing via
    stable ``argsort``.  Bit-for-bit equal *valid rows* to
    :func:`exchange_rows` (padding differs: the reference leaves residual row
    data in padding slots, the packed engine zero-fills).
    """
    capacity = dest.shape[0]
    order = jnp.argsort(dest, stable=True)
    sdest = dest[order]
    first = jnp.searchsorted(sdest, sdest, side="left")
    rank = jnp.arange(capacity, dtype=jnp.int32) - first.astype(jnp.int32)
    ok = (sdest < n_shards) & (rank < bucket)
    slot = jnp.where(ok, sdest * bucket + rank, n_shards * bucket)

    send_cnt = jnp.zeros(n_shards + 1, jnp.int32).at[
        jnp.clip(dest, 0, n_shards)].add(1)[:n_shards]
    sent = jnp.minimum(send_cnt, bucket)
    overflow = jnp.sum(send_cnt - sent)

    bufs: Cols = {}
    for name, col in cols.items():
        buf = jnp.zeros((n_shards * bucket,) + col.shape[1:], col.dtype)
        bufs[name] = buf.at[slot].set(col[order], mode="drop")

    if axis is not None:
        recv_cnt = spmd_alltoall(sent, axis)
        bufs = {k: spmd_alltoall(v, axis) for k, v in bufs.items()}
    else:
        recv_cnt = sent

    pos = jnp.arange(n_shards * bucket, dtype=jnp.int32)
    valid = (pos % bucket) < recv_cnt[pos // bucket]
    return bufs, valid, overflow
