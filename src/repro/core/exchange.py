"""Fused single-collective row-exchange engine (shuffle hot path, Fig 2).

Every distributed table operator (join, groupby, set ops, orderby) reduces
to the shuffle primitive — re-distributing rows so related keys land on the
same shard (paper §IV-B-1).  This module is the one implementation of that
primitive, replacing the seed's per-column exchange with three optimisations
(DESIGN.md §3):

  1. **Packed exchange** — every column is bit-cast to ``uint32`` lanes and
     packed into a single ``(n_shards * bucket, row_width)`` buffer, so each
     shuffle issues exactly **one** AllToAll regardless of column count.  The
     per-destination send counts travel in a metadata row fused into the same
     buffer — a shuffle is ONE collective, not ``n_cols + 1``.
  2. **Sort-free bucketing** — destination slots come from a counting-sort
     scatter (per-destination prefix ranks + the histogram that the Pallas
     ``hash_partition`` kernel already produces), not from ``argsort``.
     Compaction (``compact_rows``) is likewise a cumsum scatter.  The shuffle
     path is O(n) and contains zero ``sort`` primitives.
  3. **Hash carrying** — the row hashes ``(h1, h2)`` computed for destination
     assignment are threaded through the exchange as hidden columns
     (:data:`H1_NAME` / :data:`H2_NAME`), so join / set-op kernels never
     rehash rows after a shuffle — the carried pair directly seeds the
     hash-join / set-op slot tables (``h1`` = probe start, ``h2|1`` =
     stride; DESIGN.md §3.3/§8), with :func:`key_compare_u32` providing
     the matching bitwise verification lanes.

The static-shape overflow contract is unchanged from the seed: rows beyond a
destination bucket (send side) or beyond ``out_capacity`` (receive side) are
*counted and dropped*, never silently corrupted; callers surface the count so
the workflow layer can retry with larger capacities (paper §VII-F).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .array_ops import spmd_alltoall

Cols = Dict[str, jnp.ndarray]

#: Reserved hidden-column names for carried row hashes.  Operator impls pop
#: these after a shuffle instead of recomputing ``hash_columns``.
H1_NAME = "_h1"
H2_NAME = "_h2"


# ===========================================================================
# bit-exact uint32 packing
# ===========================================================================
@dataclasses.dataclass(frozen=True)
class ColSpec:
    """Static layout of one column inside the packed row (DESIGN.md §3.1)."""
    name: str
    dtype: np.dtype
    trailing: Tuple[int, ...]
    start: int
    lanes: int


def _col_to_u32(col: jnp.ndarray) -> jnp.ndarray:
    """Bit-exact reversible view of a column as ``(cap, lanes)`` uint32."""
    cap = col.shape[0]
    x = col.reshape(cap, -1) if col.ndim > 1 else col.reshape(cap, 1)
    size = jnp.dtype(x.dtype).itemsize
    if x.dtype == jnp.bool_:
        u = x.astype(jnp.uint32)
    elif size == 4:
        u = jax.lax.bitcast_convert_type(x, jnp.uint32)
    elif size == 8:
        u = jax.lax.bitcast_convert_type(x, jnp.uint32)  # (cap, L, 2)
    elif size == 2:
        u = jax.lax.bitcast_convert_type(x, jnp.uint16).astype(jnp.uint32)
    elif size == 1:
        u = jax.lax.bitcast_convert_type(x, jnp.uint8).astype(jnp.uint32)
    else:
        raise TypeError(f"unsupported column dtype {col.dtype}")
    return u.reshape(cap, -1)


def _u32_to_col(u: jnp.ndarray, dtype, trailing: Tuple[int, ...]) -> jnp.ndarray:
    """Inverse of :func:`_col_to_u32`."""
    cap = u.shape[0]
    dt = jnp.dtype(dtype)
    if dt == jnp.bool_:
        x = u.astype(jnp.bool_)
    elif dt.itemsize == 4:
        x = jax.lax.bitcast_convert_type(u, dtype)
    elif dt.itemsize == 8:
        x = jax.lax.bitcast_convert_type(u.reshape(cap, -1, 2), dtype)
    elif dt.itemsize == 2:
        x = jax.lax.bitcast_convert_type(u.astype(jnp.uint16), dtype)
    else:
        x = jax.lax.bitcast_convert_type(u.astype(jnp.uint8), dtype)
    return x.reshape((cap,) + tuple(trailing))


def pack_columns(cols: Cols) -> Tuple[jnp.ndarray, Tuple[ColSpec, ...]]:
    """Pack all columns into one ``(cap, row_width)`` uint32 buffer."""
    parts, specs, start = [], [], 0
    for name in sorted(cols):
        u = _col_to_u32(cols[name])
        specs.append(ColSpec(name, cols[name].dtype,
                             tuple(cols[name].shape[1:]), start, u.shape[1]))
        start += u.shape[1]
        parts.append(u)
    return jnp.concatenate(parts, axis=1), tuple(specs)


def unpack_columns(buf: jnp.ndarray, specs: Sequence[ColSpec]) -> Cols:
    """Recover original dtypes/shapes from a packed uint32 buffer."""
    return {s.name: _u32_to_col(buf[:, s.start:s.start + s.lanes],
                                s.dtype, s.trailing) for s in specs}


# ===========================================================================
# sort-free primitives
# ===========================================================================
def dest_ranks(dest: jnp.ndarray, n_parts: int,
               chunk: int = 16) -> jnp.ndarray:
    """Stable within-destination rank of each row (counting sort, no argsort).

    ``rank[i]`` = number of earlier rows with the same destination.  Rows with
    ``dest >= n_parts`` (invalid) get an arbitrary rank — callers mask them.

    Destinations are processed in chunks of ``chunk`` so the one-hot prefix
    buffer stays O(n * chunk) regardless of shard count (a full
    ``(n, n_parts)`` cumsum would be a memory blowup at pod-scale meshes).
    """
    n = dest.shape[0]
    rank = jnp.zeros((n,), jnp.int32)
    for c0 in range(0, n_parts, chunk):
        parts = jnp.arange(c0, min(c0 + chunk, n_parts), dtype=dest.dtype)
        onehot = dest[:, None] == parts[None, :]
        prefix = jnp.cumsum(onehot.astype(jnp.int32), axis=0) - 1
        idx = jnp.clip(dest.astype(jnp.int32) - c0, 0, parts.shape[0] - 1)
        picked = jnp.take_along_axis(prefix, idx[:, None], axis=1)[:, 0]
        in_chunk = (dest >= c0) & (dest < c0 + parts.shape[0])
        rank = jnp.where(in_chunk, picked, rank)
    return rank


def compact_rows(cols: Cols, keep: jnp.ndarray,
                 out_capacity: int) -> Tuple[Cols, jnp.ndarray, jnp.ndarray]:
    """Move kept rows to the front (stable) via cumsum scatter; no sort.

    Returns ``(columns, new_count, n_truncated)`` — rows past ``out_capacity``
    are dropped and counted, matching the seed overflow contract.  Padding
    rows are zero-filled (operators never read them).
    """
    total = jnp.sum(keep, dtype=jnp.int32)
    pos = jnp.cumsum(keep.astype(jnp.int32)) - 1
    slot = jnp.where(keep, pos, out_capacity)  # out-of-bounds ⇒ dropped
    out = {}
    for k, v in cols.items():
        buf = jnp.zeros((out_capacity,) + v.shape[1:], v.dtype)
        out[k] = buf.at[slot].set(v, mode="drop")
    new_count = jnp.minimum(total, out_capacity).astype(jnp.int32)
    return out, new_count, total - new_count


# ===========================================================================
# the packed single-collective exchange
# ===========================================================================
def exchange_rows(cols: Cols, dest: jnp.ndarray, n_shards: int, bucket: int,
                  axis: Optional[str], hist: Optional[jnp.ndarray] = None):
    """Bucket rows by destination shard and exchange them in ONE AllToAll.

    ``dest`` must be ``>= n_shards`` for invalid rows; ``hist`` is the
    per-destination valid-row histogram (recomputed by scatter-add when not
    supplied, e.g. by the fused ``hash_partition`` kernel).

    Frame layout (DESIGN.md §3.2): per destination, ``bucket`` packed data
    rows followed by one metadata row whose lane 0 holds the send count —
    so counts ride the same collective as the data.

    Returns ``(received_cols, received_valid_mask, n_overflowed_send)``.
    """
    if hist is None:
        hist = jnp.zeros(n_shards + 1, jnp.int32).at[
            jnp.clip(dest, 0, n_shards)].add(1)[:n_shards]
    packed, specs = pack_columns(cols)
    width = packed.shape[1]

    rank = dest_ranks(dest, n_shards)
    ok = (dest < n_shards) & (rank < bucket)
    slot = jnp.where(ok, dest * bucket + rank, n_shards * bucket)
    buf = jnp.zeros((n_shards * bucket, width), jnp.uint32
                    ).at[slot].set(packed, mode="drop")

    sent = jnp.minimum(hist, bucket)
    overflow = jnp.sum(hist - sent)

    if axis is not None:
        meta = jnp.zeros((n_shards, 1, width), jnp.uint32
                         ).at[:, 0, 0].set(sent.astype(jnp.uint32))
        framed = jnp.concatenate(
            [buf.reshape(n_shards, bucket, width), meta], axis=1)
        recv = spmd_alltoall(framed.reshape(-1, width), axis)
        recv = recv.reshape(n_shards, bucket + 1, width)
        recv_cnt = recv[:, bucket, 0].astype(jnp.int32)
        buf = recv[:, :bucket].reshape(n_shards * bucket, width)
    else:
        recv_cnt = sent

    pos = jnp.arange(n_shards * bucket, dtype=jnp.int32)
    valid = (pos % bucket) < recv_cnt[pos // bucket]
    return unpack_columns(buf, specs), valid, overflow


def hash_shuffle(cols: Cols, count: jnp.ndarray, key_names: Sequence[str],
                 n_shards: int, bucket: int, out_capacity: int,
                 axis: Optional[str], *, carry_hashes: bool = False):
    """Hash-partition + packed exchange + compaction in one call.

    Destination assignment and the send histogram come from the fused
    ``hash_partition`` dispatcher (Pallas on TPU, jnp elsewhere).  With
    ``carry_hashes`` the row hashes travel as hidden :data:`H1_NAME` /
    :data:`H2_NAME` columns so downstream kernels skip rehashing; pop them
    with :func:`take_hashes`.

    A completed call establishes the ``(key_names, n_shards)`` hash layout
    that operators record as ``DistTable.partitioning`` — the evidence the
    shuffle-elision machinery trusts (DESIGN.md §4).  Any exchange on other
    keys or a different shard count invalidates it.

    Returns ``(columns, new_count, overflow)``.
    """
    from repro.kernels.hash_partition import ops as hpops  # lazy: no cycle

    capacity = next(iter(cols.values())).shape[0]
    mask = jnp.arange(capacity, dtype=jnp.int32) < count
    key_cols = [cols[k] for k in key_names]
    if carry_hashes:
        check_no_reserved(cols)
        dest, hist, h1, h2 = hpops.hash_partition(
            key_cols, n_shards, mask, return_hashes=True)
        cols = dict(cols)
        cols[H1_NAME], cols[H2_NAME] = h1, h2
    else:
        dest, hist = hpops.hash_partition(key_cols, n_shards, mask)
    bufs, valid, ov_send = exchange_rows(cols, dest, n_shards, bucket, axis,
                                         hist=hist)
    out, new_count, ov_recv = compact_rows(bufs, valid, out_capacity)
    return out, new_count, ov_send + ov_recv


def key_compare_u32(cols: Cols, key_names: Sequence[str]) -> jnp.ndarray:
    """Bitwise key-comparison lanes, consistent with the hash identity.

    Builds the ``(N, L)`` uint32 matrix the hash-join / set-op kernels
    verify candidates against: float keys narrow to float32 and compare by
    bit pattern — exactly the identity ``hash_columns`` uses, so NaN keys
    with equal bits are equal and ``-0.0 != +0.0`` (DESIGN.md §8) — while
    integer/bool keys compare by their packed two's-complement lanes
    (identical to value equality).  The lane packing reuses the §3.1
    exchange layout (:func:`_col_to_u32`), so 64-bit integer keys keep both
    halves.  Comparing rows ``i`` and ``j`` is then
    ``jnp.all(m[i] == m[j])`` — two uint32 lane compares per key column,
    never a trip through the original dtypes.
    """
    parts = []
    for name in key_names:
        col = cols[name]
        if jnp.issubdtype(col.dtype, jnp.floating):
            col = jax.lax.bitcast_convert_type(
                col.astype(jnp.float32), jnp.uint32)
        parts.append(_col_to_u32(col))
    return jnp.concatenate(parts, axis=1)


def check_no_reserved(names: Sequence[str]) -> None:
    """Reject user tables that use the reserved carried-hash column names."""
    clash = {H1_NAME, H2_NAME} & set(names)
    if clash:
        raise ValueError(
            f"column names {sorted(clash)} are reserved for carried row "
            f"hashes (core/exchange.py); rename the column(s)")


def take_hashes(cols: Cols, key_names: Sequence[str]
                ) -> Tuple[Cols, jnp.ndarray, jnp.ndarray]:
    """Pop carried ``(h1, h2)`` from a shuffled table, or compute them.

    After a :func:`hash_shuffle` with ``carry_hashes=True`` this is a free
    dictionary pop; on the unshuffled (single-shard) path it falls back to
    ``hash_columns`` — same values either way.
    """
    from .table import hash_columns  # lazy: table does not import exchange

    cols = dict(cols)
    if H1_NAME in cols:
        return cols, cols.pop(H1_NAME), cols.pop(H2_NAME)
    h1, h2 = hash_columns([cols[k] for k in key_names])
    return cols, h1, h2


def strip_hidden(cols: Cols) -> Cols:
    """Drop carried-hash columns before handing a table back to the user."""
    return {k: v for k, v in cols.items()
            if k not in (H1_NAME, H2_NAME)}


# ===========================================================================
# seed reference implementation (oracle for parity tests)
# ===========================================================================
def exchange_rows_reference(cols: Cols, dest: jnp.ndarray, n_shards: int,
                            bucket: int, axis: Optional[str]):
    """The seed per-column argsort exchange, kept verbatim as a test oracle.

    Issues one AllToAll per column plus a count side-channel; bucketing via
    stable ``argsort``.  Bit-for-bit equal *valid rows* to
    :func:`exchange_rows` (padding differs: the reference leaves residual row
    data in padding slots, the packed engine zero-fills).
    """
    capacity = dest.shape[0]
    order = jnp.argsort(dest, stable=True)
    sdest = dest[order]
    first = jnp.searchsorted(sdest, sdest, side="left")
    rank = jnp.arange(capacity, dtype=jnp.int32) - first.astype(jnp.int32)
    ok = (sdest < n_shards) & (rank < bucket)
    slot = jnp.where(ok, sdest * bucket + rank, n_shards * bucket)

    send_cnt = jnp.zeros(n_shards + 1, jnp.int32).at[
        jnp.clip(dest, 0, n_shards)].add(1)[:n_shards]
    sent = jnp.minimum(send_cnt, bucket)
    overflow = jnp.sum(send_cnt - sent)

    bufs: Cols = {}
    for name, col in cols.items():
        buf = jnp.zeros((n_shards * bucket,) + col.shape[1:], col.dtype)
        bufs[name] = buf.at[slot].set(col[order], mode="drop")

    if axis is not None:
        recv_cnt = spmd_alltoall(sent, axis)
        bufs = {k: spmd_alltoall(v, axis) for k, v in bufs.items()}
    else:
        recv_cnt = sent

    pos = jnp.arange(n_shards * bucket, dtype=jnp.int32)
    valid = (pos % bucket) < recv_cnt[pos // bucket]
    return bufs, valid, overflow
