"""Operator taxonomy and registry (paper §V, §VII).

HPTMT classifies operators along three axes:

  * **data abstraction** — ARRAY (vectors/matrices/tensors), TABLE
    (heterogeneous columns), TENSOR (model compute);
  * **style** — EAGER (whole-input → whole-output, in-memory, Cylon-like) or
    DATAFLOW (piecewise streaming, external-memory capable, Twister2-like);
  * **execution** — SPMD (same program on every shard, loosely synchronous)
    or MPMD (producer/consumer stages; realized on TPU as pipelined SPMD).

The registry makes the operator inventory introspectable — the paper argues
the *completeness* of the operator set is what makes the architecture viable
(§II), so tests assert that every operator of Tables I/II/III is registered.
"""
from __future__ import annotations

import dataclasses
import enum
import functools
from typing import Callable, Dict, List

from repro.telemetry import record as _telemetry


class Abstraction(enum.Enum):
    ARRAY = "array"
    TABLE = "table"
    TENSOR = "tensor"


class Style(enum.Enum):
    EAGER = "eager"
    DATAFLOW = "dataflow"


class Execution(enum.Enum):
    SPMD = "spmd"
    MPMD = "mpmd"


@dataclasses.dataclass(frozen=True)
class OperatorInfo:
    name: str
    abstraction: Abstraction
    style: Style
    execution: Execution
    distributed: bool
    doc: str
    fn: Callable


_REGISTRY: Dict[str, OperatorInfo] = {}


def operator(name: str, abstraction: Abstraction, *,
             style: Style = Style.EAGER,
             execution: Execution = Execution.SPMD,
             distributed: bool = True):
    """Decorator registering an HPTMT operator.

    Registered functions must take an ``HPTMTContext`` (keyword ``ctx``) so
    they remain independent of any global parallel runtime (principle (c)).
    """

    def wrap(fn: Callable) -> Callable:
        info = OperatorInfo(
            name=name, abstraction=abstraction, style=style,
            execution=execution, distributed=distributed,
            doc=(fn.__doc__ or "").strip().split("\n")[0], fn=fn)
        if name in _REGISTRY:
            raise ValueError(f"operator {name!r} registered twice")
        _REGISTRY[name] = info

        @functools.wraps(fn)
        def inner(*args, **kwargs):
            # telemetry hook: ONE global check when off (the overhead
            # contract); under an active collector every registered
            # operator call becomes a span with rows in/out recorded
            if _telemetry._ACTIVE is None:
                return fn(*args, **kwargs)
            return _telemetry.operator_call(name, fn, args, kwargs)

        inner.op_info = info  # type: ignore[attr-defined]
        return inner

    return wrap


def get_operator(name: str) -> OperatorInfo:
    return _REGISTRY[name]


def list_operators(abstraction: Abstraction | None = None) -> List[OperatorInfo]:
    ops = list(_REGISTRY.values())
    if abstraction is not None:
        ops = [o for o in ops if o.abstraction is abstraction]
    return sorted(ops, key=lambda o: o.name)
