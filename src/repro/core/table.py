"""Columnar Table abstraction (paper §IV).

An Arrow-style struct-of-arrays table, adapted to XLA's static-shape world
(DESIGN.md §2 item 1):

  * every column is a fixed-dtype array of length ``capacity`` (static);
  * rows ``[0, num_rows)`` are valid and compacted to the front; rows beyond
    are padding (their contents are ignored by all operators);
  * heterogeneous dtypes across columns, homogeneous within a column — the
    paper's definition of a table;
  * variable-width data (strings) are dictionary-encoded into fixed-width
    integer id columns (the standard static-shape encoding).

``Table`` is a single-shard (local) table; :class:`DistTable` is the
row-partitioned distributed form (paper §IV-B: "most of the time, data
processing systems work on tables distributed with row-based partitioning").
Both are pytrees, so tables flow through ``jax.jit`` / ``shard_map`` like any
tensor — this is what lets table operators and tensor operators compose in a
single compiled program (the HPTMT thesis).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .context import HPTMTContext

Columns = Dict[str, jnp.ndarray]

# ---------------------------------------------------------------------------
# hashing (order must match kernels/hash_partition)
# ---------------------------------------------------------------------------
_H1_INIT = np.uint32(0x9E3779B9)
_H2_INIT = np.uint32(0x85EBCA6B)
_MUL1 = np.uint32(0xCC9E2D51)
_MUL2 = np.uint32(0x1B873593)


def _as_u32(col: jnp.ndarray) -> jnp.ndarray:
    """Bit-stable 32-bit view of a column for hashing."""
    if col.dtype == jnp.bool_:
        return col.astype(jnp.uint32)
    if jnp.issubdtype(col.dtype, jnp.floating):
        col = col.astype(jnp.float32)
        return jax.lax.bitcast_convert_type(col, jnp.uint32)
    return col.astype(jnp.uint32)


def _mix(h: jnp.ndarray, k: jnp.ndarray, mul: np.uint32) -> jnp.ndarray:
    k = (k * mul)
    k = (k << 15) | (k >> 17)
    h = h ^ k
    h = (h << 13) | (h >> 19)
    return h * np.uint32(5) + np.uint32(0xE6546B64)


def hash_columns(cols: Sequence[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Two independent 32-bit hashes per row (≈64-bit identity)."""
    n = cols[0].shape[0]
    h1 = jnp.full((n,), _H1_INIT, dtype=jnp.uint32)
    h2 = jnp.full((n,), _H2_INIT, dtype=jnp.uint32)
    for c in cols:
        k = _as_u32(c)
        h1 = _mix(h1, k, _MUL1)
        h2 = _mix(h2, k ^ np.uint32(0xDEADBEEF), _MUL2)
    # final avalanche
    h1 = h1 ^ (h1 >> 16)
    h2 = h2 ^ (h2 >> 16)
    return h1, h2


# ---------------------------------------------------------------------------
# local Table
# ---------------------------------------------------------------------------
@jax.tree_util.register_pytree_node_class
class Table:
    """A local columnar table with static capacity and dynamic row count."""

    def __init__(self, columns: Columns, num_rows: jnp.ndarray):
        if not columns:
            raise ValueError("Table needs at least one column")
        caps = {v.shape[0] for v in columns.values()}
        if len(caps) != 1:
            raise ValueError(f"column capacities differ: {caps}")
        self.columns = dict(columns)
        self.num_rows = jnp.asarray(num_rows, dtype=jnp.int32)

    # -- construction ----------------------------------------------------
    @classmethod
    def from_arrays(cls, columns: Columns, num_rows=None,
                    capacity: Optional[int] = None) -> "Table":
        cols = {k: jnp.asarray(v) for k, v in columns.items()}
        n = next(iter(cols.values())).shape[0]
        if num_rows is None:
            num_rows = n
        if capacity is not None and capacity != n:
            if capacity < n:
                raise ValueError("capacity smaller than provided rows")
            cols = {k: _pad_axis0(v, capacity) for k, v in cols.items()}
        return cls(cols, jnp.asarray(num_rows, jnp.int32))

    # -- pytree ------------------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        children = tuple(self.columns[k] for k in names) + (self.num_rows,)
        return children, names

    @classmethod
    def tree_unflatten(cls, names, children):
        cols = dict(zip(names, children[:-1]))
        obj = object.__new__(cls)
        obj.columns = cols
        obj.num_rows = children[-1]
        return obj

    # -- properties --------------------------------------------------------
    @property
    def capacity(self) -> int:
        return next(iter(self.columns.values())).shape[0]

    @property
    def column_names(self) -> Tuple[str, ...]:
        return tuple(sorted(self.columns))

    def row_mask(self) -> jnp.ndarray:
        return jnp.arange(self.capacity, dtype=jnp.int32) < self.num_rows

    def key_arrays(self, keys: Sequence[str]) -> Tuple[jnp.ndarray, ...]:
        return tuple(self.columns[k] for k in keys)

    # -- basic local transforms ---------------------------------------------
    def take(self, idx: jnp.ndarray, num_rows) -> "Table":
        cols = {k: v[idx] for k, v in self.columns.items()}
        return Table(cols, num_rows)

    def compact(self, keep_mask: jnp.ndarray) -> "Table":
        """Keep rows where ``keep_mask`` (within valid range); re-compact.

        Sort-free: cumsum-scatter compaction (DESIGN.md §3), stable in row
        order; dropped slots are zero-filled padding.
        """
        from .exchange import compact_rows  # no import cycle: exchange
        # has no top-level dependency on table
        keep = keep_mask & self.row_mask()
        cols, n, _ = compact_rows(self.columns, keep, self.capacity)
        return Table(cols, n)

    def with_capacity(self, capacity: int) -> "Table":
        cols = {k: _pad_axis0(v[:capacity] if capacity < v.shape[0] else v,
                              capacity)
                for k, v in self.columns.items()}
        return Table(cols, jnp.minimum(self.num_rows, capacity))

    def head_np(self, n: int = 10) -> Dict[str, np.ndarray]:
        k = int(self.num_rows)
        return {name: np.asarray(col[:min(n, k)])
                for name, col in self.columns.items()}

    def to_numpy(self) -> Dict[str, np.ndarray]:
        """Materialize valid rows on host (paper Fig 17 interop bridge)."""
        k = int(self.num_rows)
        return {name: np.asarray(col[:k]) for name, col in self.columns.items()}


def _pad_axis0(x: jnp.ndarray, capacity: int) -> jnp.ndarray:
    if x.shape[0] == capacity:
        return x
    pad = [(0, capacity - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad)


# ---------------------------------------------------------------------------
# distributed Table
# ---------------------------------------------------------------------------
#: Partitioning metadata (DESIGN.md §4/§9) — static pytree aux data, one of:
#:
#:   * ``(hash_keys, n_shards)`` — rows hash-co-located: the ordered key
#:     columns whose murmur hash assigned each row to its shard, and the
#:     shard count the hash was taken modulo;
#:   * ``("range", keys, ascending, n_shards)`` — rows globally ordered by
#:     ``keys`` with per-key ``ascending`` directions (NaN-last): shard
#:     ``s`` holds the ``s``-th contiguous run of the global sort, each
#:     shard is locally sorted, and rows with equal full keys never
#:     straddle a shard boundary (the sample-sort splitter rule);
#:   * ``None`` — layout unknown.
#:
#: The hash form stays a 2-tuple for backward compatibility; the range form
#: is distinguished by its leading ``"range"`` marker (tuple equality can
#: never confuse the two).  Use the helpers below instead of destructuring.
Partitioning = Optional[tuple]

RANGE_MARKER = "range"


def range_partitioning(keys: Sequence[str], ascending: Sequence[bool],
                       n_shards: int) -> tuple:
    """Ordered-layout metadata produced by orderby / range repartition."""
    return (RANGE_MARKER, tuple(keys), tuple(bool(a) for a in ascending),
            int(n_shards))


def partitioning_kind(part: Partitioning) -> Optional[str]:
    """``"hash"`` / ``"range"`` / ``None`` for a metadata tuple."""
    if part is None:
        return None
    return RANGE_MARKER if part[0] == RANGE_MARKER else "hash"


def partitioning_keys(part: Partitioning) -> Tuple[str, ...]:
    """The ordered key columns the layout evidence depends on (() if None)."""
    if part is None:
        return ()
    return part[1] if part[0] == RANGE_MARKER else part[0]


def partitioning_ascending(part: Partitioning) -> Tuple[bool, ...]:
    """Per-key sort directions of a range layout (() for hash/None)."""
    if part is None or part[0] != RANGE_MARKER:
        return ()
    return part[2]


@jax.tree_util.register_pytree_node_class
class DistTable:
    """Row-partitioned table: ``n_shards`` blocks of ``capacity`` rows each.

    ``columns[k]`` has global shape ``(n_shards * capacity, ...)`` and is
    sharded over the context's data axis; ``counts`` has shape
    ``(n_shards,)`` giving each shard's valid-row count.  Inside a
    ``shard_map`` region each shard sees a local ``(capacity, ...)`` block —
    i.e. a plain :class:`Table`.

    ``partitioning`` records how rows were assigned to shards (DESIGN.md §4):
    ``(hash_keys, n_shards)`` after a hash exchange on ``hash_keys``, else
    ``None``.  It is static pytree aux data (part of the trace signature,
    not a traced value), so operators can skip a shuffle at Python level
    when equal keys are already co-located.  Constructors that cannot prove
    a layout (``from_local``, concatenation) leave it ``None``.
    """

    def __init__(self, columns: Columns, counts: jnp.ndarray,
                 partitioning: Partitioning = None):
        self.columns = dict(columns)
        self.counts = jnp.asarray(counts, jnp.int32)
        self.partitioning = partitioning

    # -- pytree ------------------------------------------------------------
    def tree_flatten(self):
        names = tuple(sorted(self.columns))
        children = tuple(self.columns[k] for k in names) + (self.counts,)
        return children, (names, self.partitioning)

    @classmethod
    def tree_unflatten(cls, aux, children):
        names, partitioning = aux
        obj = object.__new__(cls)
        obj.columns = dict(zip(names, children[:-1]))
        obj.counts = children[-1]
        obj.partitioning = partitioning
        return obj

    # -- properties ----------------------------------------------------------
    @property
    def n_shards(self) -> int:
        return self.counts.shape[0]

    @property
    def capacity(self) -> int:
        return next(iter(self.columns.values())).shape[0] // self.n_shards

    @property
    def column_names(self) -> Tuple[str, ...]:
        return tuple(sorted(self.columns))

    def num_rows(self) -> jnp.ndarray:
        return jnp.sum(self.counts)

    # -- construction ----------------------------------------------------------
    @classmethod
    def from_local(cls, table: Table, ctx: HPTMTContext,
                   capacity: Optional[int] = None) -> "DistTable":
        """Block-partition a local table's valid rows across shards."""
        p = ctx.n_shards
        n = table.num_rows
        per = (n + p - 1) // p  # rows per shard (last may be short)
        cap = capacity or -(-table.capacity // p)
        # row r goes to shard r // per at slot r % per
        idx = jnp.arange(p * cap, dtype=jnp.int32)
        shard, slot = idx // cap, idx % cap
        src = shard * per + slot
        valid = (slot < per) & (src < n)
        src = jnp.where(valid, src, 0)
        cols = {k: jnp.where(
            valid.reshape((-1,) + (1,) * (v.ndim - 1)), v[src],
            jnp.zeros_like(v[src])) for k, v in table.columns.items()}
        counts = jnp.clip(n - jnp.arange(p, dtype=jnp.int32) * per, 0, per)
        counts = jnp.minimum(counts, cap).astype(jnp.int32)
        dt = cls(cols, counts)
        if ctx.mesh is not None:
            dt = dt.with_sharding(ctx)
        return dt

    @classmethod
    def from_shard_tables(cls, tables: Sequence[Table], ctx: HPTMTContext,
                          partitioning: Partitioning = None) -> "DistTable":
        """Assemble per-shard local tables into a DistTable.

        The inverse of :meth:`shard_table`: ``tables[i]`` becomes shard
        ``i``'s block (padded to the common capacity).  Used by the storage
        scan to place on-disk shard files back onto their shards —
        ``partitioning`` is attached verbatim, so callers assert the layout
        evidence truthfully (DESIGN.md §4/§5).
        """
        if len(tables) != ctx.n_shards:
            raise ValueError(f"{len(tables)} shard tables for a "
                             f"{ctx.n_shards}-shard context")
        names = tables[0].column_names
        for i, t in enumerate(tables[1:], 1):
            if t.column_names != names:
                raise ValueError(f"shard {i} columns {t.column_names} != "
                                 f"shard 0 columns {names}")
        cap = max(t.capacity for t in tables)
        cols = {k: jnp.concatenate([_pad_axis0(t.columns[k], cap)
                                    for t in tables], axis=0)
                for k in names}
        counts = jnp.stack([jnp.minimum(t.num_rows, cap) for t in tables])
        dt = cls(cols, counts, partitioning)
        if ctx.mesh is not None:
            dt = dt.with_sharding(ctx)
        return dt

    def with_sharding(self, ctx: HPTMTContext) -> "DistTable":
        if ctx.mesh is None:
            return self
        cols = {k: jax.device_put(v, ctx.row_sharding(v.ndim))
                for k, v in self.columns.items()}
        counts = jax.device_put(self.counts, ctx.row_sharding(1))
        return DistTable(cols, counts, self.partitioning)

    # -- conversion ----------------------------------------------------------
    def shard_table(self, i: int) -> Table:
        c = self.capacity
        cols = {k: v[i * c:(i + 1) * c] for k, v in self.columns.items()}
        return Table(cols, self.counts[i])

    def to_local(self) -> Table:
        """Gather all shards into one compacted local table."""
        tables = [self.shard_table(i) for i in range(self.n_shards)]
        total_cap = self.capacity * self.n_shards
        out_cols = {}
        # concatenate valid prefixes
        for name in self.column_names:
            pieces = [np.asarray(t.columns[name][:int(t.num_rows)])
                      for t in tables]
            arr = np.concatenate(pieces, axis=0) if pieces else np.zeros((0,))
            out_cols[name] = arr
        n = sum(int(t.num_rows) for t in tables)
        return Table.from_arrays(
            {k: jnp.asarray(v) for k, v in out_cols.items()},
            num_rows=n, capacity=total_cap)

    def to_numpy(self) -> Dict[str, np.ndarray]:
        return self.to_local().to_numpy()
