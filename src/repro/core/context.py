"""HPTMT execution context.

The paper's principle (c) — *independence of the parallel execution
environment* — requires operators that never reach for global runtime state.
Every operator in this framework takes an :class:`HPTMTContext` describing the
device mesh and the named axes it may use.  The same operator code runs on

  * a single device (``mesh=None``) — "excellent performance even in
    non-parallel environments" (paper §II),
  * a host-local test mesh (``xla_force_host_platform_device_count``),
  * a production pod / multi-pod TPU mesh,

without modification — principle (d), *same operator on different hardware*.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def compat_shard_map(fn, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` across jax versions (experimental module pre-0.5)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def make_mesh(shape: Sequence[int], names: Sequence[str], devices=None) -> Mesh:
    """Create a mesh with ``Auto`` axis types (shard_map-compatible)."""
    if devices is None:
        devices = jax.devices()
    n = math.prod(shape)
    if n > len(devices):
        raise ValueError(f"mesh shape {tuple(shape)} needs {n} devices, have {len(devices)}")
    dev_array = np.asarray(devices[:n]).reshape(tuple(shape))
    return Mesh(dev_array, tuple(names))


@dataclasses.dataclass(frozen=True)
class HPTMTContext:
    """Binding of HPTMT logical axes onto a concrete mesh.

    Attributes:
      mesh: the device mesh, or ``None`` for single-device execution.
      data_axis: mesh axis over which table rows / batch entries are
        partitioned (the paper's row-decomposition, §II).
      model_axis: mesh axis for tensor (model) parallelism / expert
        parallelism, if present.
      pod_axis: outer axis spanning pods (multi-pod DP), if present.
    """

    mesh: Optional[Mesh] = None
    data_axis: str = "data"
    model_axis: Optional[str] = None
    pod_axis: Optional[str] = None

    # ---- introspection -------------------------------------------------
    @property
    def is_distributed(self) -> bool:
        return self.mesh is not None and self.n_shards > 1

    @property
    def n_shards(self) -> int:
        """Number of row-partitions (size of the data axis)."""
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.data_axis]

    @property
    def model_size(self) -> int:
        if self.mesh is None or self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def n_pods(self) -> int:
        if self.mesh is None or self.pod_axis is None:
            return 1
        return self.mesh.shape[self.pod_axis]

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        """All data-parallel axes, outermost first."""
        axes: Tuple[str, ...] = ()
        if self.pod_axis is not None:
            axes += (self.pod_axis,)
        axes += (self.data_axis,)
        return axes

    # ---- sharding helpers ----------------------------------------------
    def row_sharding(self, ndim: int = 1) -> Optional[NamedSharding]:
        """Sharding that row-partitions a leading axis over the data axis."""
        if self.mesh is None:
            return None
        spec = P(self.data_axis, *([None] * (ndim - 1)))
        return NamedSharding(self.mesh, spec)

    def replicated(self) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, P())

    def shard_map(self, fn, in_specs, out_specs, check_vma: bool = False):
        """shard_map over this context's mesh (identity when single-device)."""
        if self.mesh is None:
            raise ValueError("shard_map requires a mesh-backed context")
        return compat_shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                                out_specs=out_specs, check_vma=check_vma)


def local_context() -> HPTMTContext:
    """Single-device context: operators degrade to local execution."""
    return HPTMTContext(mesh=None)


def host_test_context(n_shards: int = 1, model: int = 1) -> HPTMTContext:
    """Context over host devices, for tests (requires enough devices)."""
    if n_shards * model == 1:
        return local_context()
    if model > 1:
        mesh = make_mesh((n_shards, model), ("data", "model"))
        return HPTMTContext(mesh=mesh, model_axis="model")
    mesh = make_mesh((n_shards,), ("data",))
    return HPTMTContext(mesh=mesh)
