"""Array (vector/matrix/tensor) distributed operators — paper Table I.

These are the MPI-heritage collectives, re-hosted on JAX named-axis
collectives inside ``shard_map``.  Two API levels:

  * **global-view** functions (``allreduce``, ``allgather``, …): take a
    row-sharded global array + an ``HPTMTContext`` and wrap ``shard_map``
    themselves.  These are the paper's *eager array operators* — they work on
    any mesh (principle (c)) and degrade to local ops on a single device
    (principle (d)).
  * **in-spmd** functions (``spmd_*``): usable *inside* an existing
    ``shard_map`` region (model code, table kernels); thin shims over
    ``jax.lax`` so every layer of the stack speaks the same operator
    vocabulary.

Global-view calling conventions (each shard owns one leading-dim block):

  ===============  =======================  ==============================
  operator         input (global)           output (global)
  ===============  =======================  ==============================
  allreduce        (S, *rest) row-sharded   (*rest) replicated
  allgather        (N, *rest) row-sharded   (N, *rest) replicated
  alltoall         (N, *rest) row-sharded   (N, *rest) row-sharded
  reduce_scatter   (N, *rest) replicated    (N/S… row-sharded blocks)
  broadcast        (S, *rest) row-sharded   (*rest) replicated (root block)
  gather           (N, *rest) row-sharded   (S, N, *rest); zeros off-root
  scatter          (N, *rest) replicated    (N, *rest) row-sharded
  reduce           (S, *rest) row-sharded   (S, *rest); zeros off-root
  ===============  =======================  ==============================

TPU adaptation (DESIGN.md §2): XLA SPMD has no rooted collectives, so
Broadcast/Gather/Reduce/Scatter are expressed with masking + unrooted
collectives — which is how they lower on TPU interconnects anyway.
"""
from __future__ import annotations

from typing import Sequence, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .context import HPTMTContext
from .operator import Abstraction, operator

AxisName = Union[str, Sequence[str]]

_REDUCERS = {
    "sum": jax.lax.psum,
    "max": jax.lax.pmax,
    "min": jax.lax.pmin,
}


def axis_size(axis: AxisName):
    """Size of a named mesh axis inside SPMD code (jax-version compatible)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)  # folds to a constant


# ---------------------------------------------------------------------------
# in-SPMD collectives (usable inside shard_map)
# ---------------------------------------------------------------------------
def spmd_allreduce(x, axis: AxisName, op: str = "sum"):
    if op == "mean":
        size = jax.lax.psum(jnp.ones((), jnp.float32), axis)
        return jax.lax.psum(x, axis) / size.astype(x.dtype)
    if op == "prod":
        # no pprod primitive; all_gather + local prod (small payloads only).
        g = jax.lax.all_gather(x, axis)
        return jnp.prod(g, axis=0)
    return _REDUCERS[op](x, axis)


def spmd_allgather(x, axis: AxisName, *, tiled: bool = True, gather_axis: int = 0):
    return jax.lax.all_gather(x, axis, axis=gather_axis, tiled=tiled)


def spmd_alltoall(x, axis: AxisName, *, split_axis: int = 0, concat_axis: int = 0):
    return jax.lax.all_to_all(x, axis, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=True)


def spmd_reduce_scatter(x, axis: AxisName, *, scatter_axis: int = 0, op: str = "sum"):
    if op != "sum":
        raise NotImplementedError("reduce_scatter supports sum only")
    return jax.lax.psum_scatter(x, axis, scatter_dimension=scatter_axis, tiled=True)


def spmd_broadcast(x, axis: str, root: int = 0):
    """Rooted broadcast = mask + allreduce (TPU-idiomatic)."""
    idx = jax.lax.axis_index(axis)
    masked = jnp.where(idx == root, x, jnp.zeros_like(x))
    return jax.lax.psum(masked, axis)


def spmd_reduce(x, axis: str, root: int = 0, op: str = "sum"):
    """Rooted reduce: full value on ``root``, zeros elsewhere."""
    full = spmd_allreduce(x, axis, op=op)
    idx = jax.lax.axis_index(axis)
    return jnp.where(idx == root, full, jnp.zeros_like(full))


def spmd_gather(x, axis: str, root: int = 0):
    """Rooted gather: concatenated value on ``root``, zeros elsewhere."""
    g = jax.lax.all_gather(x, axis, axis=0, tiled=True)
    idx = jax.lax.axis_index(axis)
    return jnp.where(idx == root, g, jnp.zeros_like(g))


def spmd_scatter(x, axis: str, root: int = 0):
    """Rooted scatter: root's buffer split into blocks across the axis."""
    n = axis_size(axis)
    full = spmd_broadcast(x, axis, root=root)
    idx = jax.lax.axis_index(axis)
    piece = x.shape[0] // n
    return jax.lax.dynamic_slice_in_dim(full, idx * piece, piece, axis=0)


def spmd_ppermute(x, axis: str, perm):
    return jax.lax.ppermute(x, axis, perm=perm)


# ---------------------------------------------------------------------------
# global-view eager operators (paper Table I)
# ---------------------------------------------------------------------------
def _row_spec(ctx: HPTMTContext, ndim: int) -> P:
    return P(ctx.data_axis, *([None] * (ndim - 1)))


def _rep_spec(ndim: int) -> P:
    return P(*([None] * ndim))


@operator("array.allreduce", Abstraction.ARRAY)
def allreduce(x, *, ctx: HPTMTContext, op: str = "sum"):
    """AllReduce: combine one block per shard with SUM/MIN/MAX/MEAN/PROD."""
    if not ctx.is_distributed:
        red = {"sum": jnp.sum, "max": jnp.max, "min": jnp.min,
               "mean": jnp.mean, "prod": jnp.prod}[op]
        return red(x, axis=0)
    fn = ctx.shard_map(
        lambda v: spmd_allreduce(v[0], ctx.data_axis, op=op),
        in_specs=_row_spec(ctx, x.ndim), out_specs=_rep_spec(x.ndim - 1))
    return fn(x)


@operator("array.allgather", Abstraction.ARRAY)
def allgather(x, *, ctx: HPTMTContext):
    """AllGather: every shard receives the concatenation of all shards."""
    if not ctx.is_distributed:
        return x
    fn = ctx.shard_map(
        lambda v: spmd_allgather(v, ctx.data_axis),
        in_specs=_row_spec(ctx, x.ndim), out_specs=_rep_spec(x.ndim))
    return fn(x)


@operator("array.alltoall", Abstraction.ARRAY)
def alltoall(x, *, ctx: HPTMTContext):
    """AllToAll: transpose the (shard, block) layout of a row-sharded array."""
    if not ctx.is_distributed:
        return x
    fn = ctx.shard_map(
        lambda v: spmd_alltoall(v, ctx.data_axis),
        in_specs=_row_spec(ctx, x.ndim), out_specs=_row_spec(ctx, x.ndim))
    return fn(x)


@operator("array.reduce_scatter", Abstraction.ARRAY)
def reduce_scatter(x, *, ctx: HPTMTContext):
    """ReduceScatter: sum shard contributions, scatter result row-blocks."""
    if not ctx.is_distributed:
        return x
    fn = ctx.shard_map(
        lambda v: spmd_reduce_scatter(v, ctx.data_axis),
        in_specs=_rep_spec(x.ndim), out_specs=_row_spec(ctx, x.ndim))
    return fn(x)


@operator("array.broadcast", Abstraction.ARRAY)
def broadcast(x, *, ctx: HPTMTContext, root: int = 0):
    """Broadcast: shard ``root``'s block to every shard (replicated)."""
    if not ctx.is_distributed:
        return x[root]
    fn = ctx.shard_map(
        lambda v: spmd_broadcast(v[0], ctx.data_axis, root=root),
        in_specs=_row_spec(ctx, x.ndim), out_specs=_rep_spec(x.ndim - 1))
    return fn(x)


@operator("array.gather", Abstraction.ARRAY)
def gather(x, *, ctx: HPTMTContext, root: int = 0):
    """Gather: concatenation of all shards on ``root`` (zeros elsewhere)."""
    if not ctx.is_distributed:
        return x[None]
    fn = ctx.shard_map(
        lambda v: spmd_gather(v, ctx.data_axis, root=root)[None],
        in_specs=_row_spec(ctx, x.ndim), out_specs=_row_spec(ctx, x.ndim + 1))
    return fn(x)


@operator("array.scatter", Abstraction.ARRAY)
def scatter(x, *, ctx: HPTMTContext, root: int = 0):
    """Scatter: split ``root``'s (replicated) buffer into one block/shard."""
    if not ctx.is_distributed:
        return x
    fn = ctx.shard_map(
        lambda v: spmd_scatter(v, ctx.data_axis, root=root),
        in_specs=_rep_spec(x.ndim), out_specs=_row_spec(ctx, x.ndim))
    return fn(x)


@operator("array.reduce", Abstraction.ARRAY)
def reduce(x, *, ctx: HPTMTContext, root: int = 0, op: str = "sum"):
    """Reduce: combined value in ``root``'s block, zeros elsewhere."""
    if not ctx.is_distributed:
        red = {"sum": jnp.sum, "max": jnp.max, "min": jnp.min,
               "mean": jnp.mean}[op]
        return red(x, axis=0, keepdims=True)
    fn = ctx.shard_map(
        lambda v: spmd_reduce(v[0], ctx.data_axis, root=root, op=op)[None],
        in_specs=_row_spec(ctx, x.ndim), out_specs=_row_spec(ctx, x.ndim))
    return fn(x)
