"""Distributed table operators — paper Tables II/III and the shuffle (Fig 2).

Every distributed operator is one ``shard_map`` region: local columnar
kernels + the bucket-exchange **shuffle** primitive built on the array
AllToAll operator (paper: "Shuffle is similar to the array AllToAll
operation … what makes these two operations different are the data structure
[and] how we select which values are scattered" §IV-B-1).

Static-shape adaptation (DESIGN.md §2 item 1): shuffles move fixed-capacity
buckets; overflow (rows that exceed bucket or output capacity) is *counted
and returned* so the caller — per the paper's §VII-F prescription, the
workflow layer — can react (retry with a larger capacity), instead of
silently corrupting data.

The data movement itself lives in ``core/exchange.py`` (DESIGN.md §3): all
columns are bit-packed into one uint32 buffer so each shuffle issues exactly
ONE AllToAll (counts ride a fused metadata row), bucketing/compaction are
counting-sort scatters (zero ``argsort`` on the shuffle path), and the row
hashes computed for partitioning are carried through the exchange so join /
set-op kernels never rehash post-shuffle.

Operators implemented here (→ paper table):
  select, project                          — Table II (local)
  union, difference, cartesian             — Table II (distributed)
  intersect, join, orderby, aggregate,
  groupby(+aggregate)                      — Table III (distributed)
  shuffle                                  — Fig 2 primitive
"""
from __future__ import annotations

import functools
import math
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .array_ops import spmd_allgather, spmd_allreduce
from .context import HPTMTContext
from .exchange import (check_no_reserved, compact_rows, exchange_rows,
                       hash_shuffle, take_hashes)
from .operator import Abstraction, Style, operator
from .table import DistTable, Table

Cols = Dict[str, jnp.ndarray]


# ===========================================================================
# shard_map plumbing
# ===========================================================================
def _run_sharded(ctx: HPTMTContext, impl: Callable, args, out_specs):
    """Run ``impl(*local_args, axis=...)`` over the context's data axis.

    Single-device contexts run the same impl with ``axis=None`` (collectives
    become identities) — principle (d), same operator everywhere.
    """
    if not ctx.is_distributed:
        return impl(*args, axis=None)
    fn = ctx.shard_map(
        functools.partial(impl, axis=ctx.data_axis),
        in_specs=P(ctx.data_axis), out_specs=out_specs)
    return fn(*args)


def _local_parts(dt_cols: Cols, counts: jnp.ndarray) -> Tuple[Cols, jnp.ndarray]:
    """Inside shard_map: per-shard column blocks + scalar count."""
    return dt_cols, counts[0]


def _mask_for(count: jnp.ndarray, capacity: int) -> jnp.ndarray:
    return jnp.arange(capacity, dtype=jnp.int32) < count


def _compact_cols(cols: Cols, keep: jnp.ndarray,
                  out_capacity: int) -> Tuple[Cols, jnp.ndarray, jnp.ndarray]:
    """Move kept rows to the front; truncate to ``out_capacity``.

    Returns (columns, new_count, n_truncated).  Sort-free: delegates to the
    exchange engine's cumsum-scatter compaction (DESIGN.md §3).
    """
    return compact_rows(cols, keep, out_capacity)


def _sort_cols(cols: Cols, sort_keys: Sequence[jnp.ndarray],
               mask: jnp.ndarray) -> Tuple[Cols, jnp.ndarray]:
    """Sort valid rows by lexicographic keys; invalid rows go last."""
    order = jnp.lexsort(tuple(sort_keys[::-1]) + (~mask,))
    return {k: v[order] for k, v in cols.items()}, order


# ===========================================================================
# the shuffle primitive (Fig 2)
# ===========================================================================
def _bucket_capacity(capacity: int, n_shards: int, factor: float) -> int:
    if n_shards == 1:
        return capacity
    return max(1, min(capacity, math.ceil(capacity * factor / n_shards)))


def _shuffle_impl(cols: Cols, counts: jnp.ndarray, *, key_names, n_shards,
                  bucket, out_capacity, axis, dest_fn=None):
    cols, count = _local_parts(cols, counts)
    if dest_fn is None:
        out, new_count, overflow = hash_shuffle(
            cols, count, key_names, n_shards, bucket, out_capacity, axis)
    else:
        capacity = next(iter(cols.values())).shape[0]
        mask = _mask_for(count, capacity)
        dest = jnp.where(mask, dest_fn(cols, mask), n_shards)
        bufs, valid, ov_send = exchange_rows(cols, dest, n_shards, bucket,
                                             axis)
        out, new_count, ov_recv = compact_rows(bufs, valid, out_capacity)
        overflow = ov_send + ov_recv
    if axis is not None:
        overflow = spmd_allreduce(overflow, axis)
    return out, new_count[None], overflow


@operator("table.shuffle", Abstraction.TABLE)
def shuffle(dt: DistTable, keys: Sequence[str], *, ctx: HPTMTContext,
            out_capacity: Optional[int] = None, bucket_factor: float = 2.0,
            ) -> Tuple[DistTable, jnp.ndarray]:
    """Re-distribute rows so equal keys land on the same shard (Fig 2)."""
    n = ctx.n_shards
    bucket = _bucket_capacity(dt.capacity, n, bucket_factor)
    out_cap = out_capacity or dt.capacity
    impl = functools.partial(
        _shuffle_impl, key_names=tuple(keys), n_shards=n, bucket=bucket,
        out_capacity=out_cap, )
    cols, counts, overflow = _run_sharded(
        ctx, impl, (dt.columns, dt.counts),
        out_specs=(P(ctx.data_axis), P(ctx.data_axis), P()))
    return DistTable(cols, counts), overflow


# ===========================================================================
# local operators (Table II: Select / Project)
# ===========================================================================
@operator("table.select", Abstraction.TABLE, distributed=False)
def select(dt: DistTable, predicate: Callable[[Cols], jnp.ndarray], *,
           ctx: HPTMTContext) -> DistTable:
    """Filter rows by a per-row predicate over the columns (Table II)."""

    def impl(cols, counts, *, axis):
        cols, count = _local_parts(cols, counts)
        cap = next(iter(cols.values())).shape[0]
        keep = predicate(cols) & _mask_for(count, cap)
        out, n, _ = _compact_cols(cols, keep, cap)
        return out, n[None]

    cols, counts = _run_sharded(
        ctx, impl, (dt.columns, dt.counts),
        out_specs=(P(ctx.data_axis), P(ctx.data_axis)))
    return DistTable(cols, counts)


@operator("table.project", Abstraction.TABLE, distributed=False)
def project(dt: DistTable, columns: Sequence[str], *,
            ctx: HPTMTContext) -> DistTable:
    """Keep only the named columns (Table II). Purely local."""
    return DistTable({k: dt.columns[k] for k in columns}, dt.counts)


# ===========================================================================
# OrderBy (Table III) — distributed sample sort
# ===========================================================================
def _orderby_impl(cols: Cols, counts: jnp.ndarray, *, key, ascending,
                  n_shards, bucket, out_capacity, n_samples, axis):
    local_cols, count = _local_parts(cols, counts)
    capacity = next(iter(local_cols.values())).shape[0]
    mask = _mask_for(count, capacity)
    kcol = local_cols[key]
    skey = kcol if ascending else _negate(kcol)

    # --- sample splitters -------------------------------------------------
    stride = jnp.maximum(count // n_samples, 1)
    sidx = jnp.minimum(jnp.arange(n_samples, dtype=jnp.int32) * stride,
                       jnp.maximum(count - 1, 0))
    sample = jnp.where(sidx < count, skey[sidx], _max_value(skey.dtype))
    if axis is not None:
        sample = spmd_allgather(sample, axis)
    sample = jnp.sort(sample)
    total = sample.shape[0]
    spos = (jnp.arange(1, n_shards, dtype=jnp.int32) * total) // n_shards
    splitters = sample[spos]

    dest = jnp.searchsorted(splitters, skey, side="right").astype(jnp.int32)
    dest = jnp.where(mask, dest, n_shards)
    bufs, valid, ov_send = exchange_rows(local_cols, dest, n_shards,
                                         bucket, axis)
    out, new_count, ov_recv = compact_rows(bufs, valid, out_capacity)
    # local sort
    okey = out[key] if ascending else _negate(out[key])
    m = _mask_for(new_count, out_capacity)
    out, _ = _sort_cols(out, [okey], m)
    overflow = ov_send + ov_recv
    if axis is not None:
        overflow = spmd_allreduce(overflow, axis)
    return out, new_count[None], overflow


def _negate(col: jnp.ndarray) -> jnp.ndarray:
    if jnp.issubdtype(col.dtype, jnp.unsignedinteger):
        return jnp.iinfo(col.dtype).max - col
    return -col


def _max_value(dtype) -> jnp.ndarray:
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


@operator("table.orderby", Abstraction.TABLE)
def orderby(dt: DistTable, key: str, *, ctx: HPTMTContext,
            ascending: bool = True, out_capacity: Optional[int] = None,
            bucket_factor: float = 2.0, n_samples: int = 64,
            ) -> Tuple[DistTable, jnp.ndarray]:
    """Globally sort rows by ``key`` via sample sort (Table III OrderBy)."""
    n = ctx.n_shards
    bucket = _bucket_capacity(dt.capacity, n, bucket_factor)
    impl = functools.partial(
        _orderby_impl, key=key, ascending=ascending, n_shards=n,
        bucket=bucket, out_capacity=out_capacity or dt.capacity,
        n_samples=min(n_samples, dt.capacity))
    cols, counts, overflow = _run_sharded(
        ctx, impl, (dt.columns, dt.counts),
        out_specs=(P(ctx.data_axis), P(ctx.data_axis), P()))
    return DistTable(cols, counts), overflow


# ===========================================================================
# Join (Table III) — shuffle + local sort-merge
# ===========================================================================
def _local_sorted_join(lcols: Cols, ln, rcols: Cols, rn, *, keys, how,
                       max_matches, window, out_capacity):
    # hashes carried through the shuffle (or computed here on the
    # single-shard path — same values either way)
    lcols, lh1, lh2 = take_hashes(lcols, keys)
    rcols, rh1, rh2 = take_hashes(rcols, keys)
    lcap = next(iter(lcols.values())).shape[0]
    rcap = next(iter(rcols.values())).shape[0]
    lmask, rmask = _mask_for(ln, lcap), _mask_for(rn, rcap)

    # invalid rows get MAX hash so the sorted array is truly sorted
    # (binary search requires global sortedness, including the tail).
    # Single-key stable sort: equal-h1 candidates are probed through the
    # bounded window below, so no secondary sort key is needed, and only the
    # probe-side arrays ride the sort gather — non-key output columns are
    # gathered once through ``rorder`` at emit time.
    rh1 = jnp.where(rmask, rh1, jnp.uint32(0xFFFFFFFF))
    rorder = jnp.argsort(rh1, stable=True)
    rh1s, rh2s = rh1[rorder], rh2[rorder]
    rvalid_s = rmask[rorder]
    rkey_s = {k: rcols[k][rorder] for k in keys}

    lo = jnp.searchsorted(rh1s, lh1, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(rh1s, lh1, side="right").astype(jnp.int32)
    cnt = hi - lo

    def keys_equal(cand):
        eq = lh2 == rh2s[cand]
        for k in keys:
            eq &= lcols[k] == rkey_s[k][cand]
        return eq

    rows = jnp.arange(lcap, dtype=jnp.int32)
    if max_matches == 1:
        # scatter-free fast path: first match wins
        ridx = jnp.full((lcap,), -1, jnp.int32)
        found = jnp.zeros((lcap,), bool)
        for j in range(window):
            cand = jnp.clip(lo + j, 0, rcap - 1)
            ok = (j < cnt) & lmask & rvalid_s[cand] & keys_equal(cand)
            ok &= ~found
            ridx = jnp.where(ok, cand, ridx)
            found |= ok
        right_idx = ridx[:, None]
        matched = found.astype(jnp.int32)
    else:
        matched = jnp.zeros((lcap,), jnp.int32)
        right_idx = jnp.full((lcap, max_matches), -1, jnp.int32)
        for j in range(window):
            cand = jnp.clip(lo + j, 0, rcap - 1)
            ok = (j < cnt) & lmask & rvalid_s[cand] & keys_equal(cand)
            ok &= matched < max_matches
            slot = jnp.clip(matched, 0, max_matches - 1)
            cur = right_idx[rows, slot]
            right_idx = right_idx.at[rows, slot].set(jnp.where(ok, cand, cur))
            matched = matched + ok.astype(jnp.int32)

    # expand to (lcap * max_matches) candidate output rows
    li = jnp.repeat(rows, max_matches)
    ri = right_idx.reshape(-1)
    has_match = ri >= 0
    if how == "inner":
        keep = has_match
    elif how == "left":
        first = (jnp.arange(lcap * max_matches) % max_matches) == 0
        keep = has_match | (first & lmask[li] & (matched[li] == 0))
    else:
        raise ValueError(f"unsupported join type {how!r}")

    ri_safe = jnp.clip(ri, 0, rcap - 1)
    rsrc = rorder[ri_safe]  # compose sort + probe gathers for output cols
    out: Cols = {}
    for k, v in lcols.items():
        out[k] = v[li]
    for k, v in rcols.items():
        if k in keys:
            continue
        name = k if k not in lcols else f"{k}_r"
        gathered = v[rsrc]
        out[name] = jnp.where(
            has_match.reshape((-1,) + (1,) * (gathered.ndim - 1)),
            gathered, jnp.zeros_like(gathered))
    out["_matched"] = has_match
    return _compact_cols(out, keep, out_capacity)


def _join_impl(lc, lcnt, rc, rcnt, *, keys, how, max_matches, window,
               n_shards, lbucket, rbucket, mid_cap_l, mid_cap_r,
               out_capacity, axis):
    lcols, ln = _local_parts(lc, lcnt)
    rcols, rn = _local_parts(rc, rcnt)
    ov = jnp.zeros((), jnp.int32)
    if n_shards > 1:
        # co-locate equal keys; carry (h1, h2) so the local join never
        # rehashes the shuffled rows
        lcols, ln, ov_l = hash_shuffle(lcols, ln, keys, n_shards, lbucket,
                                       mid_cap_l, axis, carry_hashes=True)
        rcols, rn, ov_r = hash_shuffle(rcols, rn, keys, n_shards, rbucket,
                                       mid_cap_r, axis, carry_hashes=True)
        ov = ov + ov_l + ov_r
    out, cnt, ov_o = _local_sorted_join(
        lcols, ln, rcols, rn, keys=keys, how=how, max_matches=max_matches,
        window=window, out_capacity=out_capacity)
    overflow = ov + ov_o
    if axis is not None:
        overflow = spmd_allreduce(overflow, axis)
    return out, cnt[None], overflow


@operator("table.join", Abstraction.TABLE)
def join(left: DistTable, right: DistTable, keys: Sequence[str], *,
         ctx: HPTMTContext, how: str = "inner", max_matches: int = 1,
         window: int = 4, out_capacity: Optional[int] = None,
         bucket_factor: float = 2.0) -> Tuple[DistTable, jnp.ndarray]:
    """Distributed equi-join: shuffle-by-key + local sort-merge (Table III).

    ``max_matches`` bounds the join fan-out per left row (static shapes);
    rows beyond it are counted in the returned overflow.
    """
    check_no_reserved(left.column_names)
    check_no_reserved(right.column_names)
    n = ctx.n_shards
    mid_l = max(left.capacity, 1)
    mid_r = max(right.capacity, 1)
    impl = functools.partial(
        _join_impl, keys=tuple(keys), how=how, max_matches=max_matches,
        window=window, n_shards=n,
        lbucket=_bucket_capacity(left.capacity, n, bucket_factor),
        rbucket=_bucket_capacity(right.capacity, n, bucket_factor),
        mid_cap_l=mid_l, mid_cap_r=mid_r,
        out_capacity=out_capacity or mid_l * max_matches)
    cols, counts, overflow = _run_sharded(
        ctx, impl, (left.columns, left.counts, right.columns, right.counts),
        out_specs=(P(ctx.data_axis), P(ctx.data_axis), P()))
    return DistTable(cols, counts), overflow


# ===========================================================================
# GroupBy + Aggregate (Table III)
# ===========================================================================
_SEGMENT_OPS = ("sum", "mean", "min", "max", "count")


def _local_groupby(cols: Cols, count, *, keys, aggs, out_capacity):
    from repro.kernels.segment_reduce import ops as segops

    cap = next(iter(cols.values())).shape[0]
    mask = _mask_for(count, cap)
    key_arrays = [cols[k] for k in keys]
    sorted_cols, order = _sort_cols(cols, key_arrays, mask)
    smask = mask[order]

    new_seg = jnp.ones((cap,), bool)
    for k in keys:
        col = sorted_cols[k]
        same = col[1:] == col[:-1]
        new_seg = new_seg & jnp.concatenate([jnp.ones((1,), bool), ~same])
    new_seg = new_seg & smask
    seg_id = jnp.cumsum(new_seg.astype(jnp.int32)) - 1
    n_seg = jnp.maximum(jnp.max(jnp.where(smask, seg_id, -1)) + 1, 0)
    seg_id = jnp.where(smask, seg_id, cap)  # sentinel bucket for invalid

    out: Cols = {}
    # first row of each segment via counting scatter (segment ids of the
    # boundary rows are unique), no argsort
    first_idx = jnp.zeros((cap,), jnp.int32).at[
        jnp.where(new_seg, seg_id, cap)].set(
        jnp.arange(cap, dtype=jnp.int32), mode="drop")
    for k in keys:
        out[k] = sorted_cols[k][first_idx][:out_capacity]
    ones = jnp.ones((cap,), jnp.float32)
    seg_count = segops.segment_reduce(ones, seg_id, cap + 1, op="sum")[:cap]
    for col_name, agg in aggs:
        vals = sorted_cols[col_name].astype(jnp.float32)
        label = f"{col_name}_{agg}"
        if agg == "count":
            out[label] = seg_count[:out_capacity]
            continue
        red = "sum" if agg == "mean" else agg
        r = segops.segment_reduce(vals, seg_id, cap + 1, op=red)[:cap]
        if agg == "mean":
            r = r / jnp.maximum(seg_count, 1.0)
        out[label] = r[:out_capacity]
    # zero-fill rows beyond n_seg
    m = _mask_for(jnp.minimum(n_seg, out_capacity), out_capacity)
    out = {k: jnp.where(m.reshape((-1,) + (1,) * (v.ndim - 1)), v,
                        jnp.zeros_like(v)) for k, v in out.items()}
    return out, jnp.minimum(n_seg, out_capacity).astype(jnp.int32)


def _groupby_impl(cols, counts, *, keys, aggs, n_shards, bucket,
                  mid_capacity, out_capacity, axis):
    local_cols, count = _local_parts(cols, counts)
    ov = jnp.zeros((), jnp.int32)
    if n_shards > 1:
        local_cols, count, ov = hash_shuffle(
            local_cols, count, keys, n_shards, bucket, mid_capacity, axis)
    out, n_seg = _local_groupby(local_cols, count, keys=keys, aggs=aggs,
                                out_capacity=out_capacity)
    if axis is not None:
        ov = spmd_allreduce(ov, axis)
    return out, n_seg[None], ov


@operator("table.groupby", Abstraction.TABLE)
def groupby_aggregate(dt: DistTable, keys: Sequence[str],
                      aggs: Sequence[Tuple[str, str]], *, ctx: HPTMTContext,
                      out_capacity: Optional[int] = None,
                      bucket_factor: float = 2.0,
                      ) -> Tuple[DistTable, jnp.ndarray]:
    """GroupBy + aggregate (Table III): shuffle-by-key + segment reduce.

    ``aggs`` is a list of ``(column, op)`` with op in sum/mean/min/max/count.
    """
    for _, a in aggs:
        if a not in _SEGMENT_OPS:
            raise ValueError(f"unknown aggregate {a!r}")
    n = ctx.n_shards
    impl = functools.partial(
        _groupby_impl, keys=tuple(keys), aggs=tuple(aggs), n_shards=n,
        bucket=_bucket_capacity(dt.capacity, n, bucket_factor),
        mid_capacity=dt.capacity, out_capacity=out_capacity or dt.capacity)
    cols, counts, overflow = _run_sharded(
        ctx, impl, (dt.columns, dt.counts),
        out_specs=(P(ctx.data_axis), P(ctx.data_axis), P()))
    return DistTable(cols, counts), overflow


@operator("table.aggregate", Abstraction.TABLE)
def aggregate(dt: DistTable, column: str, op: str, *, ctx: HPTMTContext):
    """Global scalar aggregate of one column (Table III Aggregate)."""

    def impl(cols, counts, *, axis):
        local_cols, count = _local_parts(cols, counts)
        cap = next(iter(local_cols.values())).shape[0]
        mask = _mask_for(count, cap)
        col = local_cols[column].astype(jnp.float32)
        if op == "sum":
            v = jnp.sum(jnp.where(mask, col, 0.0))
        elif op == "count":
            v = jnp.sum(mask.astype(jnp.float32))
        elif op == "mean":
            v = jnp.sum(jnp.where(mask, col, 0.0))
        elif op == "min":
            v = jnp.min(jnp.where(mask, col, jnp.inf))
        elif op == "max":
            v = jnp.max(jnp.where(mask, col, -jnp.inf))
        else:
            raise ValueError(f"unknown aggregate {op!r}")
        if axis is not None:
            red = {"sum": "sum", "count": "sum", "mean": "sum",
                   "min": "min", "max": "max"}[op]
            v = spmd_allreduce(v, axis, op=red)
            if op == "mean":
                n = spmd_allreduce(jnp.sum(mask.astype(jnp.float32)), axis)
                v = v / jnp.maximum(n, 1.0)
        elif op == "mean":
            v = v / jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
        return v

    return _run_sharded(ctx, impl, (dt.columns, dt.counts), out_specs=P())


# ===========================================================================
# set operators: Union / Difference / Intersect / Cartesian (Table II/III)
# ===========================================================================
def _dedup_sorted(cols: Cols, h1, h2, mask):
    """Keep the first row of every (h1, h2, full-row) duplicate group."""
    sorted_cols, order = _sort_cols(cols, [h1, h2], mask)
    sh1, sh2, sm = h1[order], h2[order], mask[order]
    same_hash = jnp.concatenate([
        jnp.zeros((1,), bool), (sh1[1:] == sh1[:-1]) & (sh2[1:] == sh2[:-1])])
    same_row = same_hash
    for k, v in sorted_cols.items():
        eq = jnp.concatenate([jnp.zeros((1,), bool), v[1:] == v[:-1]])
        same_row = same_row & eq
    keep = sm & ~same_row
    return sorted_cols, keep


def _membership(a_cols: Cols, amask, ah1, ah2, b_cols: Cols, bmask, bh1, bh2,
                names, window=8):
    """For each row of A: does an equal row exist in B? (hash + verify).

    Row hashes are passed in — carried through the shuffle or computed once
    by the caller — so membership itself never rehashes.
    """
    bh1 = jnp.where(bmask, bh1, jnp.uint32(0xFFFFFFFF))
    # single-key stable sort (see _local_sorted_join): the bounded window
    # probes equal-h1 groups, no secondary key needed
    border = jnp.argsort(bh1, stable=True)
    bh1s, bh2s, bvs = bh1[border], bh2[border], bmask[border]
    bsorted = {k: b_cols[k][border] for k in names}
    bcap = bh1s.shape[0]
    lo = jnp.searchsorted(bh1s, ah1, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(bh1s, ah1, side="right").astype(jnp.int32)
    found = jnp.zeros(ah1.shape, bool)
    for j in range(window):
        cand = jnp.clip(lo + j, 0, bcap - 1)
        ok = (j < hi - lo) & bvs[cand] & (ah2 == bh2s[cand])
        for k in names:
            ok &= a_cols[k] == bsorted[k][cand]
        found |= ok
    return found & amask


def _setop_impl(ac, acnt, bc, bcnt, *, kind, names, n_shards, abucket,
                bbucket, mid_a, mid_b, out_capacity, axis):
    acols, an = _local_parts(ac, acnt)
    bcols, bn = _local_parts(bc, bcnt)
    ov = jnp.zeros((), jnp.int32)

    if n_shards > 1:
        acols, an, o = hash_shuffle(acols, an, names, n_shards, abucket,
                                    mid_a, axis, carry_hashes=True)
        ov += o
        bcols, bn, o = hash_shuffle(bcols, bn, names, n_shards, bbucket,
                                    mid_b, axis, carry_hashes=True)
        ov += o
    # hashes: popped from the shuffle carry, or computed once here
    acols, ah1, ah2 = take_hashes(acols, names)
    bcols, bh1, bh2 = take_hashes(bcols, names)

    acap = next(iter(acols.values())).shape[0]
    bcap = next(iter(bcols.values())).shape[0]
    amask, bmask = _mask_for(an, acap), _mask_for(bn, bcap)

    if kind == "union":
        # concat then dedup (hashes concatenate alongside the rows)
        cat = {k: jnp.concatenate([acols[k], bcols[k]]) for k in acols}
        cmask = jnp.concatenate([amask, bmask])
        h1 = jnp.concatenate([ah1, bh1])
        h2 = jnp.concatenate([ah2, bh2])
        sorted_cols, keep = _dedup_sorted(cat, h1, h2, cmask)
        out, cnt, o = _compact_cols(sorted_cols, keep, out_capacity)
    elif kind == "difference":
        found = _membership(acols, amask, ah1, ah2, bcols, bmask, bh1, bh2,
                            names)
        out, cnt, o = _compact_cols(acols, amask & ~found, out_capacity)
    elif kind == "intersect":
        found = _membership(acols, amask, ah1, ah2, bcols, bmask, bh1, bh2,
                            names)
        kept = amask & found
        sorted_cols, keep = _dedup_sorted(acols, ah1, ah2, kept)
        out, cnt, o = _compact_cols(sorted_cols, keep, out_capacity)
    else:
        raise ValueError(kind)
    ov = ov + o
    if axis is not None:
        ov = spmd_allreduce(ov, axis)
    return out, cnt[None], ov


def _make_setop(kind: str, opname: str, doc: str):
    @operator(opname, Abstraction.TABLE)
    def op(a: DistTable, b: DistTable, *, ctx: HPTMTContext,
           out_capacity: Optional[int] = None, bucket_factor: float = 2.0,
           ) -> Tuple[DistTable, jnp.ndarray]:
        names = tuple(sorted(set(a.column_names) & set(b.column_names)))
        if names != a.column_names or names != b.column_names:
            raise ValueError("set operators require identical schemas")
        check_no_reserved(names)
        n = ctx.n_shards
        default_out = (a.capacity + b.capacity if kind == "union"
                       else a.capacity)
        impl = functools.partial(
            _setop_impl, kind=kind, names=names, n_shards=n,
            abucket=_bucket_capacity(a.capacity, n, bucket_factor),
            bbucket=_bucket_capacity(b.capacity, n, bucket_factor),
            mid_a=a.capacity, mid_b=b.capacity,
            out_capacity=out_capacity or default_out)
        cols, counts, overflow = _run_sharded(
            ctx, impl, (a.columns, a.counts, b.columns, b.counts),
            out_specs=(P(ctx.data_axis), P(ctx.data_axis), P()))
        return DistTable(cols, counts), overflow

    op.__doc__ = doc
    op.__name__ = kind
    return op


union = _make_setop("union", "table.union",
                    "Distributed Union with duplicate removal (Table II).")
difference = _make_setop(
    "difference", "table.difference",
    "Rows of A with no equal row in B (Table II Difference).")
intersect = _make_setop(
    "intersect", "table.intersect",
    "Deduplicated rows of A that also appear in B (Table III Intersect).")


@operator("table.cartesian", Abstraction.TABLE)
def cartesian(a: DistTable, b: DistTable, *, ctx: HPTMTContext,
              out_capacity: Optional[int] = None) -> DistTable:
    """Cartesian product (Table II): AllGather right, local cross join."""
    n = ctx.n_shards

    def impl(ac, acnt, bc, bcnt, *, axis):
        acols, an = _local_parts(ac, acnt)
        bcols, bn = _local_parts(bc, bcnt)
        acap = next(iter(acols.values())).shape[0]
        bcap = next(iter(bcols.values())).shape[0]
        if axis is not None:
            bcols = {k: spmd_allgather(v, axis) for k, v in bcols.items()}
            bns = spmd_allgather(bn[None], axis)
        else:
            bns = bn[None]
        bg = bcols[next(iter(bcols))].shape[0]
        # validity of gathered right rows
        pos = jnp.arange(bg, dtype=jnp.int32)
        bvalid = (pos % bcap) < bns[pos // bcap]
        li = jnp.repeat(jnp.arange(acap, dtype=jnp.int32), bg)
        ri = jnp.tile(jnp.arange(bg, dtype=jnp.int32), acap)
        keep = _mask_for(an, acap)[li] & bvalid[ri]
        out = {f"a_{k}": v[li] for k, v in acols.items()}
        out.update({f"b_{k}": v[ri] for k, v in bcols.items()})
        cols, cnt, _ = _compact_cols(out, keep, out_capacity or acap * bg)
        return cols, cnt[None]

    cols, counts = _run_sharded(
        ctx, impl, (a.columns, a.counts, b.columns, b.counts),
        out_specs=(P(ctx.data_axis), P(ctx.data_axis)))
    return DistTable(cols, counts)
