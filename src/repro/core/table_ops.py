"""Distributed table operators — paper Tables II/III and the shuffle (Fig 2).

Every distributed operator is one ``shard_map`` region: local columnar
kernels + the bucket-exchange **shuffle** primitive built on the array
AllToAll operator (paper: "Shuffle is similar to the array AllToAll
operation … what makes these two operations different are the data structure
[and] how we select which values are scattered" §IV-B-1).

Static-shape adaptation (DESIGN.md §2 item 1): shuffles move fixed-capacity
buckets; overflow (rows that exceed bucket or output capacity) is *counted
and returned* so the caller — per the paper's §VII-F prescription, the
workflow layer — can react (retry with a larger capacity), instead of
silently corrupting data.

The data movement itself lives in ``core/exchange.py`` (DESIGN.md §3): all
columns are bit-packed into one uint32 buffer so each shuffle issues exactly
ONE AllToAll (counts ride a fused metadata row), bucketing/compaction are
counting-sort scatters (zero ``argsort`` on the shuffle path), and the row
hashes computed for partitioning are carried through the exchange so join /
set-op kernels never rehash post-shuffle.

Operators implemented here (→ paper table):
  select, project                          — Table II (local)
  union, difference, cartesian             — Table II (distributed)
  intersect, join, orderby, aggregate,
  groupby(+aggregate)                      — Table III (distributed)
  shuffle                                  — Fig 2 primitive
"""
from __future__ import annotations

import functools
import math
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .array_ops import spmd_allgather, spmd_allreduce, spmd_ppermute
from .context import HPTMTContext
from .exchange import (check_no_reserved, compact_rows, exchange_rows,
                       hash_shuffle, key_compare_u32, lex_order,
                       order_lanes, range_shuffle, take_hashes)
from .operator import Abstraction, Style, operator
from .table import (DistTable, Table, _pad_axis0, partitioning_ascending,
                    partitioning_keys, partitioning_kind,
                    range_partitioning)

Cols = Dict[str, jnp.ndarray]


# ===========================================================================
# shard_map plumbing
# ===========================================================================
def _run_sharded(ctx: HPTMTContext, impl: Callable, args, out_specs):
    """Run ``impl(*local_args, axis=...)`` over the context's data axis.

    Single-device contexts run the same impl with ``axis=None`` (collectives
    become identities) — principle (d), same operator everywhere.
    """
    if not ctx.is_distributed:
        return impl(*args, axis=None)
    fn = ctx.shard_map(
        functools.partial(impl, axis=ctx.data_axis),
        in_specs=P(ctx.data_axis), out_specs=out_specs)
    return fn(*args)


def _local_parts(dt_cols: Cols, counts: jnp.ndarray) -> Tuple[Cols, jnp.ndarray]:
    """Inside shard_map: per-shard column blocks + scalar count."""
    return dt_cols, counts[0]


def _mask_for(count: jnp.ndarray, capacity: int) -> jnp.ndarray:
    return jnp.arange(capacity, dtype=jnp.int32) < count


def _compact_cols(cols: Cols, keep: jnp.ndarray,
                  out_capacity: int) -> Tuple[Cols, jnp.ndarray, jnp.ndarray]:
    """Move kept rows to the front; truncate to ``out_capacity``.

    Returns (columns, new_count, n_truncated).  Sort-free: delegates to the
    exchange engine's cumsum-scatter compaction (DESIGN.md §3).
    """
    return compact_rows(cols, keep, out_capacity)


def _sort_cols(cols: Cols, sort_keys: Sequence[jnp.ndarray],
               mask: jnp.ndarray) -> Tuple[Cols, jnp.ndarray]:
    """Sort valid rows by lexicographic keys; invalid rows go last."""
    order = jnp.lexsort(tuple(sort_keys[::-1]) + (~mask,))
    return {k: v[order] for k, v in cols.items()}, order


# ===========================================================================
# the shuffle primitive (Fig 2)
# ===========================================================================
def _bucket_capacity(capacity: int, n_shards: int, factor: float) -> int:
    if n_shards == 1:
        return capacity
    return max(1, min(capacity, math.ceil(capacity * factor / n_shards)))


def _partitioned_on(dt: DistTable, keys: Sequence[str],
                    ctx: HPTMTContext) -> bool:
    """True when ``dt``'s rows are already hash-co-located on ``keys``.

    Metadata is trusted only on an exact ``(ordered keys, n_shards)`` match —
    the murmur chain is order-sensitive, so ("a","b") and ("b","a") describe
    different layouts (DESIGN.md §4).
    """
    return (ctx.n_shards > 1
            and dt.partitioning == (tuple(keys), ctx.n_shards))


def _shuffle_impl(cols: Cols, counts: jnp.ndarray, *, key_names, n_shards,
                  bucket, out_capacity, axis, dest_fn=None):
    cols, count = _local_parts(cols, counts)
    if dest_fn is None:
        out, new_count, overflow = hash_shuffle(
            cols, count, key_names, n_shards, bucket, out_capacity, axis)
    else:
        capacity = next(iter(cols.values())).shape[0]
        mask = _mask_for(count, capacity)
        dest = jnp.where(mask, dest_fn(cols, mask), n_shards)
        bufs, valid, ov_send = exchange_rows(cols, dest, n_shards, bucket,
                                             axis)
        out, new_count, ov_recv = compact_rows(bufs, valid, out_capacity)
        overflow = ov_send + ov_recv
    if axis is not None:
        overflow = spmd_allreduce(overflow, axis)
    return out, new_count[None], overflow


@operator("table.shuffle", Abstraction.TABLE)
def shuffle(dt: DistTable, keys: Sequence[str], *, ctx: HPTMTContext,
            out_capacity: Optional[int] = None, bucket_factor: float = 2.0,
            ) -> Tuple[DistTable, jnp.ndarray]:
    """Re-distribute rows so equal keys land on the same shard (Fig 2).

    A no-op (elided at trace level, DESIGN.md §4) when ``dt.partitioning``
    already records a hash exchange on exactly these keys — unless the call
    also asks for a resize (``out_capacity`` differing from the input
    capacity), which must run regardless of layout so the output shape and
    overflow accounting never depend on input provenance.  The output
    carries ``(keys, n_shards)`` partitioning metadata so downstream
    join/groupby/set ops on the same keys skip their own shuffle.
    """
    n = ctx.n_shards
    if _partitioned_on(dt, keys, ctx) and (out_capacity is None
                                           or out_capacity == dt.capacity):
        return dt, jnp.zeros((), jnp.int32)
    bucket = _bucket_capacity(dt.capacity, n, bucket_factor)
    out_cap = out_capacity or dt.capacity
    impl = functools.partial(
        _shuffle_impl, key_names=tuple(keys), n_shards=n, bucket=bucket,
        out_capacity=out_cap, )
    cols, counts, overflow = _run_sharded(
        ctx, impl, (dt.columns, dt.counts),
        out_specs=(P(ctx.data_axis), P(ctx.data_axis), P()))
    return DistTable(cols, counts, (tuple(keys), n)), overflow


# ===========================================================================
# local operators (Table II: Select / Project)
# ===========================================================================
@operator("table.select", Abstraction.TABLE, distributed=False)
def select(dt: DistTable, predicate: Callable[[Cols], jnp.ndarray], *,
           ctx: HPTMTContext) -> DistTable:
    """Filter rows by a per-row predicate over the columns (Table II)."""

    def impl(cols, counts, *, axis):
        cols, count = _local_parts(cols, counts)
        cap = next(iter(cols.values())).shape[0]
        keep = predicate(cols) & _mask_for(count, cap)
        out, n, _ = _compact_cols(cols, keep, cap)
        return out, n[None]

    cols, counts = _run_sharded(
        ctx, impl, (dt.columns, dt.counts),
        out_specs=(P(ctx.data_axis), P(ctx.data_axis)))
    # rows never change shards: the partitioning layout survives filtering
    return DistTable(cols, counts, dt.partitioning)


@operator("table.project", Abstraction.TABLE, distributed=False)
def project(dt: DistTable, columns: Sequence[str], *,
            ctx: HPTMTContext) -> DistTable:
    """Keep only the named columns (Table II). Purely local.

    Partitioning metadata — hash AND range alike — survives only while
    every key column is still present (DESIGN.md §4/§9): a projection
    that drops a key loses the evidence of how rows were placed/ordered.
    """
    part = dt.partitioning
    if part is not None and not set(partitioning_keys(part)) <= set(columns):
        part = None
    return DistTable({k: dt.columns[k] for k in columns}, dt.counts, part)


# ===========================================================================
# OrderBy (Table III) — multi-key distributed sample sort (DESIGN.md §9)
# ===========================================================================
def _normalize_order(by, ascending, column_names, kwarg: str):
    """Validate sort keys/directions eagerly; returns ``(keys, ascending)``.

    ``by`` is a column name or a sequence of them; ``ascending`` a bool or
    a per-key sequence.  Errors name the offending kwarg and value before
    anything traces (the join-validation style).
    """
    keys = (by,) if isinstance(by, str) else tuple(by)
    if not keys:
        raise ValueError(f"{kwarg}= needs at least one key column")
    missing = [k for k in keys if k not in column_names]
    if missing:
        raise ValueError(f"{kwarg}= names unknown column(s) {missing}; "
                         f"table has {sorted(column_names)}")
    if isinstance(ascending, bool):
        asc = (ascending,) * len(keys)
    else:
        asc = tuple(bool(a) for a in ascending)
        if len(asc) != len(keys):
            raise ValueError(
                f"ascending= has {len(asc)} entries for {len(keys)} "
                f"{kwarg}= keys — provide one bool, or one per key")
    return keys, asc


def _orderby_impl(cols: Cols, counts: jnp.ndarray, *, keys, ascending,
                  n_shards, bucket, out_capacity, n_samples, axis):
    local_cols, count = _local_parts(cols, counts)
    out, new_count, overflow = range_shuffle(
        local_cols, count, keys, ascending, n_shards, bucket, out_capacity,
        axis, n_samples=n_samples)
    if axis is not None:
        overflow = spmd_allreduce(overflow, axis)
    return out, new_count[None], overflow


@operator("table.orderby", Abstraction.TABLE)
def orderby(dt: DistTable, by, *, ctx: HPTMTContext,
            ascending=True, out_capacity: Optional[int] = None,
            bucket_factor: float = 2.0, n_samples: int = 64,
            ) -> Tuple[DistTable, jnp.ndarray]:
    """Globally sort rows via multi-key sample sort (Table III OrderBy).

    ``by`` is one column name or a sequence; ``ascending`` one bool or one
    per key.  NaN keys sort LAST in BOTH directions (the monotone-lane
    transform of DESIGN.md §9 — the old float negation flipped NaNs to the
    front under ``ascending=False``).  Destination shards come from
    sampled splitters and the rows ride the same single packed AllToAll as
    a hash shuffle; rows with equal full keys never straddle a shard
    boundary.

    The output records ``("range", keys, ascending, n_shards)``
    partitioning metadata — the ordered counterpart of the §4 hash
    evidence: ``window`` / ``rank`` / ``quantile`` / another ``orderby``
    on the same keys then trace with ZERO additional AllToAll.  A call on
    an input already carrying exactly this layout is a traced no-op
    (unless it also resizes, mirroring ``shuffle``).
    """
    keys, asc = _normalize_order(by, ascending, dt.column_names, "by")
    n = ctx.n_shards
    part = range_partitioning(keys, asc, n)
    if dt.partitioning == part and (out_capacity is None
                                    or out_capacity == dt.capacity):
        return dt, jnp.zeros((), jnp.int32)
    impl = functools.partial(
        _orderby_impl, keys=keys, ascending=asc, n_shards=n,
        bucket=_bucket_capacity(dt.capacity, n, bucket_factor),
        out_capacity=out_capacity or dt.capacity,
        n_samples=min(n_samples, dt.capacity))
    cols, counts, overflow = _run_sharded(
        ctx, impl, (dt.columns, dt.counts),
        out_specs=(P(ctx.data_axis), P(ctx.data_axis), P()))
    return DistTable(cols, counts, part), overflow


def _local_sort_impl(cols: Cols, counts: jnp.ndarray, *, keys, ascending,
                     axis):
    local_cols, count = _local_parts(cols, counts)
    capacity = next(iter(local_cols.values())).shape[0]
    mask = _mask_for(count, capacity)
    order = lex_order(order_lanes(local_cols, keys, ascending), mask)
    return {k: v[order] for k, v in local_cols.items()}, count[None]


@operator("table.local_sort", Abstraction.TABLE)
def local_sort(dt: DistTable, by, *, ctx: HPTMTContext, ascending=True,
               partitioning: object = "auto"
               ) -> Tuple[DistTable, jnp.ndarray]:
    """Sort rows *within each shard* — a planner primitive, ZERO AllToAll.

    Rows never cross shards, so this is NOT a global sort on its own: the
    query planner (``repro.plan``) emits it when placement metadata already
    proves the cross-shard half of an ordering (e.g. shards hold disjoint
    contiguous key ranges after a range exchange upstream, so a local sort
    completes a global ``orderby``), or when only per-shard order matters
    (window evaluation over hash-co-located partitions).

    ``partitioning`` stamps the output metadata: ``"auto"`` keeps a hash
    layout (rows did not move) and drops anything else; an explicit value
    is trusted verbatim — callers must pass a layout they can prove.
    Same NaN-last key semantics as ``orderby`` (DESIGN.md §9).
    """
    keys, asc = _normalize_order(by, ascending, dt.column_names, "by")
    if partitioning == "auto":
        part = dt.partitioning if partitioning_kind(dt.partitioning) \
            == "hash" else None
    else:
        part = partitioning
    impl = functools.partial(_local_sort_impl, keys=keys, ascending=asc)
    cols, counts = _run_sharded(
        ctx, impl, (dt.columns, dt.counts),
        out_specs=(P(ctx.data_axis), P(ctx.data_axis)))
    return DistTable(cols, counts, part), jnp.zeros((), jnp.int32)


# ===========================================================================
# Windowed aggregation / rank / top-k / quantile (DESIGN.md §9)
# ===========================================================================
def _window_impl(cols: Cols, counts: jnp.ndarray, *, pkeys, okeys,
                 ascending, aggs, rows, n_shards, bucket, out_capacity,
                 n_samples, need_sort, axis):
    from repro.window import eval_window  # lazy: window imports core

    local_cols, count = _local_parts(cols, counts)
    ov = jnp.zeros((), jnp.int32)
    if need_sort:
        local_cols, count, ov = range_shuffle(
            local_cols, count, tuple(pkeys) + tuple(okeys), ascending,
            n_shards, bucket, out_capacity, axis, n_samples=n_samples)
    new_cols, o = eval_window(local_cols, count, pkeys=pkeys, okeys=okeys,
                              ascending=ascending, aggs=aggs, rows=rows,
                              n_shards=n_shards, axis=axis)
    overflow = ov + o
    if axis is not None:
        overflow = spmd_allreduce(overflow, axis)
    out = dict(local_cols)
    out.update(new_cols)
    return out, count[None], overflow


@operator("table.window", Abstraction.TABLE)
def window_aggregate(dt: DistTable, partition_by, order_by, aggs, *,
                     ctx: HPTMTContext, rows: Optional[int] = None,
                     ascending=True, bucket_factor: float = 2.0,
                     n_samples: int = 64) -> Tuple[DistTable, jnp.ndarray]:
    """SQL-style window functions over ``(PARTITION BY, ORDER BY)`` groups.

    ``aggs`` entries are ``(column, op)`` or ``(column, op, offset)`` with
    op in sum/mean/count/min/max (windowed by ``rows``: a trailing
    row-count window, ``None`` = cumulative/expanding), lag/lead (offset
    gathers, zero-filled outside the partition), and ``(None,
    "row_number")`` / ``(None, "rank")``.  Output = input columns plus one
    labeled column per agg (``{col}_{op}``, ``row_number``, ``rank``);
    rows never move or drop.  A window wider than its partition clips to
    the partition (SQL ROWS BETWEEN semantics); partition identity is the
    ordering identity (all-NaN keys form ONE partition, ±0.0 two).

    The input must be ordered by ``partition_by + order_by``: when its
    metadata already records exactly that range layout the sort is elided
    and the whole operator adds ZERO AllToAll and ZERO sort primitives to
    the trace (halo/carry state moves on ppermute/AllGather, DESIGN.md
    §9); otherwise one sample-sort exchange runs first — so an
    ``orderby -> window`` chain on the same keys costs exactly the
    orderby's single AllToAll.

    Overflow counts *truncated windows*: bounded-lookback lanes (rolling,
    lag/lead) that needed rows beyond what the cross-shard halo could
    prove.  Zero overflow certifies exact results (§2).
    """
    from repro.window import normalize_aggs

    pkeys = tuple(partition_by) if not isinstance(partition_by, str) \
        else (partition_by,)
    missing = [k for k in pkeys if k not in dt.column_names]
    if missing:
        raise ValueError(f"partition_by= names unknown column(s) "
                         f"{missing}; table has {sorted(dt.column_names)}")
    okeys, asc_o = _normalize_order(order_by, ascending, dt.column_names,
                                    "order_by")
    norm = normalize_aggs(aggs, dt.column_names, rows)
    n = ctx.n_shards
    max_off = max((p for _, _, op, p in norm if op in ("lag", "lead")),
                  default=0)
    lookback = max(rows - 1 if rows is not None else 0, max_off)
    if n > 1 and lookback > dt.capacity:
        raise ValueError(
            f"window lookback {lookback} (rows=/lag/lead offsets) exceeds "
            f"the per-shard capacity {dt.capacity}; raise the capacity or "
            f"repartition over fewer shards")
    keys = pkeys + okeys
    asc = (True,) * len(pkeys) + asc_o
    part = range_partitioning(keys, asc, n)
    impl = functools.partial(
        _window_impl, pkeys=pkeys, okeys=okeys, ascending=asc, aggs=norm,
        rows=rows, n_shards=n,
        bucket=_bucket_capacity(dt.capacity, n, bucket_factor),
        out_capacity=dt.capacity, n_samples=min(n_samples, dt.capacity),
        need_sort=dt.partitioning != part)
    cols, counts, overflow = _run_sharded(
        ctx, impl, (dt.columns, dt.counts),
        out_specs=(P(ctx.data_axis), P(ctx.data_axis), P()))
    return DistTable(cols, counts, part), overflow


def rank(dt: DistTable, partition_by, order_by, *, ctx: HPTMTContext,
         ascending=True, **kw) -> Tuple[DistTable, jnp.ndarray]:
    """Convenience: add SQL ``rank`` (+``row_number``) window columns."""
    return window_aggregate(
        dt, partition_by, order_by,
        [(None, "rank"), (None, "row_number")], ctx=ctx,
        ascending=ascending, **kw)


def _topk_impl(cols: Cols, counts: jnp.ndarray, *, keys, ascending, k,
               n_shards, axis):
    local_cols, count = _local_parts(cols, counts)
    capacity = next(iter(local_cols.values())).shape[0]
    mask = _mask_for(count, capacity)
    order = lex_order(order_lanes(local_cols, keys, ascending), mask)
    take = order[:k]
    cand = {name: v[take] for name, v in local_cols.items()}
    ccnt = jnp.minimum(count, k)

    # tree-reduce: log2(p) ppermute rounds, each merging two k-candidate
    # sets with a 2k-row local sort — no global sort, no AllToAll
    rounds = max(n_shards - 1, 0).bit_length()
    for t in range(rounds):
        stepsz = 1 << t
        perm = [(s + stepsz, s) for s in range(0, n_shards - stepsz,
                                               2 * stepsz)]
        recv = {name: spmd_ppermute(v, axis, perm)
                for name, v in cand.items()}
        rcnt = spmd_ppermute(ccnt, axis, perm)
        merged = {name: jnp.concatenate([v, recv[name]])
                  for name, v in cand.items()}
        mvalid = jnp.concatenate([jnp.arange(k) < ccnt,
                                  jnp.arange(k) < rcnt])
        morder = lex_order(order_lanes(merged, keys, ascending), mvalid)
        take = morder[:k]
        cand = {name: v[take] for name, v in merged.items()}
        ccnt = jnp.minimum(ccnt + rcnt, k)

    if axis is not None and n_shards > 1:
        mine = jax.lax.axis_index(axis) == 0
        keep = mine & (jnp.arange(k) < ccnt)
        cand = {name: _bcast(keep, v) for name, v in cand.items()}
        ccnt = jnp.where(mine, ccnt, 0)
    return cand, ccnt[None]


@operator("table.topk", Abstraction.TABLE)
def topk(dt: DistTable, by, k: int, *, ctx: HPTMTContext,
         largest: bool = True, ascending=None) -> DistTable:
    """The first ``k`` rows of the global sort order, WITHOUT a global
    sort: per-shard top-k candidates tree-reduce over ``log2(p)``
    ppermute rounds of 2k-row merges (DESIGN.md §9) — zero AllToAll, and
    local sorts touch at most ``max(capacity, 2k)`` rows.

    ``largest=True`` (default) means descending by ``by``; pass
    ``ascending=`` per-key directions to override.  The result lands on
    shard 0, globally sorted — it carries the corresponding range
    metadata, so a following window/quantile on the same keys elides.
    """
    if not isinstance(k, int) or k < 1:
        raise ValueError(f"k={k!r} must be a positive int")
    if ctx.n_shards > 1 and k > dt.capacity:
        # a shard can only surface `capacity` candidates, so a bigger k
        # would silently return fewer rows than asked — reject eagerly
        raise ValueError(
            f"k={k} exceeds the per-shard capacity {dt.capacity}; raise "
            f"the capacity or use orderby for a full sort")
    if ascending is None:
        ascending = not largest
    keys, asc = _normalize_order(by, ascending, dt.column_names, "by")
    n = ctx.n_shards
    impl = functools.partial(_topk_impl, keys=keys, ascending=asc,
                             k=min(k, dt.capacity), n_shards=n)
    cols, counts = _run_sharded(
        ctx, impl, (dt.columns, dt.counts),
        out_specs=(P(ctx.data_axis), P(ctx.data_axis)))
    return DistTable(cols, counts, range_partitioning(keys, asc, n))


def _quantile_impl(cols: Cols, counts: jnp.ndarray, *, column, qs, method,
                   n_shards, bucket, capacity, n_samples, need_sort, axis):
    local_cols, count = _local_parts(cols, counts)
    qarr = jnp.asarray(qs, jnp.float32)
    if capacity == 0:  # gathers on size-0 columns are ill-formed
        return jnp.full((len(qs),), jnp.nan, jnp.float32)

    if method == "approx":
        # splitter-style sketch: pooled regular sample, no exchange
        col = local_cols[column].astype(jnp.float32)
        cap = col.shape[0]
        mask = _mask_for(count, cap) & ~jnp.isnan(col)
        svals, scnt = compact_rows({"v": col}, mask, cap)[:2]
        stride = jnp.maximum(scnt // n_samples, 1)
        sidx = jnp.minimum(jnp.arange(n_samples, dtype=jnp.int32) * stride,
                           jnp.maximum(scnt - 1, 0))
        ok = sidx < scnt
        sample = jnp.where(ok, svals["v"][sidx], jnp.inf)
        nval = jnp.sum(ok, dtype=jnp.int32)
        if axis is not None:
            sample = spmd_allgather(sample, axis)
            nval = spmd_allreduce(nval, axis)
        sample = jnp.sort(sample)  # invalid (+inf) entries sort last
        t = qarr * jnp.maximum(nval - 1, 0).astype(jnp.float32)
        lo = jnp.floor(t).astype(jnp.int32)
        hi = jnp.ceil(t).astype(jnp.int32)
        vlo = sample[jnp.clip(lo, 0, sample.shape[0] - 1)]
        vhi = sample[jnp.clip(hi, 0, sample.shape[0] - 1)]
        out = vlo + (t - lo.astype(jnp.float32)) * (vhi - vlo)
        return jnp.where(nval > 0, out, jnp.nan)

    # exact: rows globally sorted by the column (sorted here if needed);
    # NaNs order last, so the non-NaN prefix is globally contiguous
    sort_ov = jnp.zeros((), jnp.int32)
    if need_sort:
        local_cols, count, sort_ov = range_shuffle(
            local_cols, count, (column,), (True,), n_shards, bucket,
            capacity, axis, n_samples=n_samples)
    col = local_cols[column].astype(jnp.float32)
    cap = col.shape[0]
    mask = _mask_for(count, cap)
    nn = jnp.sum(mask & ~jnp.isnan(col), dtype=jnp.int32)
    if axis is not None:
        nn_all = spmd_allgather(nn[None], axis)
        me = jax.lax.axis_index(axis)
        offset = jnp.sum(jnp.where(jnp.arange(n_shards) < me, nn_all, 0))
    else:
        nn_all = nn[None]
        offset = jnp.zeros((), jnp.int32)
    total = jnp.sum(nn_all)
    t = qarr * jnp.maximum(total - 1, 0).astype(jnp.float32)
    lo = jnp.floor(t).astype(jnp.int32)
    hi = jnp.ceil(t).astype(jnp.int32)

    def fetch(g):  # global rank → value, via one masked psum
        local = g - offset
        have = (local >= 0) & (local < nn)
        v = jnp.where(have, col[jnp.clip(local, 0, cap - 1)], 0.0)
        return spmd_allreduce(v, axis) if axis is not None else v

    vlo, vhi = fetch(lo), fetch(hi)
    out = vlo + (t - lo.astype(jnp.float32)) * (vhi - vlo)
    if axis is not None:
        sort_ov = spmd_allreduce(sort_ov, axis)
    # a skew-overflowed internal sort dropped rows: poison, never mislead
    return jnp.where((total > 0) & (sort_ov == 0), out, jnp.nan)


@operator("table.quantile", Abstraction.TABLE)
def quantile(dt: DistTable, column: str, qs, *, ctx: HPTMTContext,
             method: str = "auto", bucket_factor: float = 2.0,
             n_samples: int = 64) -> jnp.ndarray:
    """Quantiles of one column, numpy ``nanquantile`` semantics (linear
    interpolation, NaNs excluded).  Returns a ``(len(qs),)`` float32
    array (replicated).

    ``method="exact"`` reads the true order statistics off the range
    layout: already-sorted inputs (orderby/topk metadata on ``(column,)``
    ascending) cost ZERO AllToAll and ZERO sorts — rank→shard arithmetic
    plus one masked AllReduce per boundary; otherwise one sample-sort
    exchange runs first.  ``method="approx"`` is the splitter-style
    fallback: quantiles of a pooled per-shard regular sample (error
    bounded by the §9 sampling skew bound), never any exchange.
    ``"auto"`` picks exact when the layout is already there, else approx.
    """
    if column not in dt.column_names:
        raise ValueError(f"column= names unknown column {column!r}; "
                         f"table has {sorted(dt.column_names)}")
    if method not in ("auto", "exact", "approx"):
        raise ValueError(f"unknown quantile method={method!r}; expected "
                         f"'auto', 'exact' or 'approx'")
    if np.isscalar(qs) and not isinstance(qs, (str, bytes)):
        qs = (float(qs),)
    else:
        try:
            qs = tuple(float(q) for q in qs)
        except TypeError:
            raise ValueError(f"qs={qs!r} must be a probability or a "
                             f"sequence of probabilities") from None
    bad = [q for q in qs if not 0.0 <= q <= 1.0]
    if bad:
        raise ValueError(f"qs= values {bad} outside [0, 1]")
    n = ctx.n_shards
    # a range layout whose FIRST key is this column ascending proves the
    # global order the exact path reads ranks from
    asc = partitioning_ascending(dt.partitioning)
    sorted_on_col = (partitioning_kind(dt.partitioning) == "range"
                     and partitioning_keys(dt.partitioning)[:1] == (column,)
                     and bool(asc and asc[0]))
    if method == "auto":
        method = "exact" if (sorted_on_col or n == 1) else "approx"
    impl = functools.partial(
        _quantile_impl, column=column, qs=qs, method=method, n_shards=n,
        bucket=_bucket_capacity(dt.capacity, n, bucket_factor),
        capacity=dt.capacity, n_samples=min(n_samples, dt.capacity),
        need_sort=method == "exact" and not sorted_on_col)
    return _run_sharded(ctx, impl, (dt.columns, dt.counts), out_specs=P())


# ===========================================================================
# Join (Table III) — shuffle + local hash build/probe (or sort-merge oracle)
# ===========================================================================
_JOIN_HOWS = ("inner", "left", "right", "outer")


def _hash_slots(n_rows: int) -> int:
    """Power-of-two slot count with 4x head-room — the one sizing rule for
    every build table (join, set ops, groupby hash; DESIGN.md §8.1)."""
    return 1 << max(int(4 * n_rows - 1).bit_length(), 6)


def _bcast(mask: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Broadcast a row mask over ``v``'s trailing dims; zero masked rows."""
    return jnp.where(mask.reshape((-1,) + (1,) * (v.ndim - 1)), v,
                     jnp.zeros_like(v))


def _emit_join_columns(lcols: Cols, rcols: Cols, keys, li, ri) -> Cols:
    """Late-materialized join output from ``(left_row, right_row)`` pairs.

    The probe/merge loops emit only the two int32 index lanes — ``li``
    and ``ri`` in each side's original row space, ``-1`` for an absent
    side — and every payload column is gathered here ONCE per side
    (DESIGN.md §8).  Key columns come from whichever side the pair has
    (left wins when both); absent sides zero-fill, so pure-padding pairs
    are zero rows.
    """
    has_l, has_r = li >= 0, ri >= 0
    li_s = jnp.where(has_l, li, 0)
    ri_s = jnp.where(has_r, ri, 0)
    out: Cols = {}
    for k in keys:
        out[k] = jnp.where(
            has_l.reshape((-1,) + (1,) * (lcols[k].ndim - 1)),
            lcols[k][li_s], _bcast(has_r, rcols[k][ri_s]))
    for k, v in lcols.items():
        if k in keys:
            continue
        out[k] = _bcast(has_l, v[li_s])
    for k, v in rcols.items():
        if k in keys:
            continue
        name = k if k not in lcols else f"{k}_r"
        out[name] = _bcast(has_r, v[ri_s])
    out["_matched"] = has_l & has_r
    return out


def _local_sorted_join(lcols: Cols, ln, rcols: Cols, rn, *, keys, how,
                       max_matches, window, out_capacity):
    # hashes carried through the shuffle (or computed here on the
    # single-shard path — same values either way)
    lcols, lh1, lh2 = take_hashes(lcols, keys)
    rcols, rh1, rh2 = take_hashes(rcols, keys)
    lcap = next(iter(lcols.values())).shape[0]
    rcap = next(iter(rcols.values())).shape[0]
    lmask, rmask = _mask_for(ln, lcap), _mask_for(rn, rcap)

    # invalid rows get MAX hash so the sorted array is truly sorted
    # (binary search requires global sortedness, including the tail).
    # Single-key stable sort: equal-h1 candidates are probed through the
    # bounded window below, so no secondary sort key is needed, and only the
    # probe-side arrays ride the sort gather — non-key output columns are
    # gathered once through ``rorder`` at emit time.
    rh1 = jnp.where(rmask, rh1, jnp.uint32(0xFFFFFFFF))
    rorder = jnp.argsort(rh1, stable=True)
    rh1s, rh2s = rh1[rorder], rh2[rorder]
    rvalid_s = rmask[rorder]
    rkey_s = {k: rcols[k][rorder] for k in keys}

    lo = jnp.searchsorted(rh1s, lh1, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(rh1s, lh1, side="right").astype(jnp.int32)
    cnt = hi - lo

    def keys_equal(cand):
        # bitwise identity, matching the hash (NaN keys with equal bits
        # are equal, ±0.0 are not) — value ``==`` would contradict the
        # hash adjacency this path probes by (same fix as groupby PR 2)
        eq = lh2 == rh2s[cand]
        for k in keys:
            eq &= _key_bits_eq(lcols[k], rkey_s[k][cand])
        return eq

    rows = jnp.arange(lcap, dtype=jnp.int32)
    cnt_win = jnp.zeros((lcap,), jnp.int32)  # verified matches in window
    # right rows some left row verified against, even past the fan-out cap
    # (a capped pair must not resurface in the right/outer tail — same
    # rule as the hash path); only those modes pay the scatter
    track_touch = how in ("right", "outer")
    rtouched = jnp.zeros((rcap,), bool)

    def touch(rtouched, ok, cand):
        if not track_touch:
            return rtouched
        return rtouched.at[jnp.where(ok, cand, rcap)].set(True, mode="drop")

    if max_matches == 1:
        # scatter-free fast path: first match wins
        ridx = jnp.full((lcap,), -1, jnp.int32)
        found = jnp.zeros((lcap,), bool)
        for j in range(window):
            cand = jnp.clip(lo + j, 0, rcap - 1)
            ok = (j < cnt) & lmask & rvalid_s[cand] & keys_equal(cand)
            cnt_win += ok.astype(jnp.int32)
            rtouched = touch(rtouched, ok, cand)
            ok &= ~found
            ridx = jnp.where(ok, cand, ridx)
            found |= ok
        right_idx = ridx[:, None]
        matched = found.astype(jnp.int32)
    else:
        matched = jnp.zeros((lcap,), jnp.int32)
        right_idx = jnp.full((lcap, max_matches), -1, jnp.int32)
        for j in range(window):
            cand = jnp.clip(lo + j, 0, rcap - 1)
            ok = (j < cnt) & lmask & rvalid_s[cand] & keys_equal(cand)
            cnt_win += ok.astype(jnp.int32)
            rtouched = touch(rtouched, ok, cand)
            ok &= matched < max_matches
            slot = jnp.clip(matched, 0, max_matches - 1)
            cur = right_idx[rows, slot]
            right_idx = right_idx.at[rows, slot].set(jnp.where(ok, cand, cur))
            matched = matched + ok.astype(jnp.int32)

    # fan-out overflow (§2): matches verified but dropped by max_matches,
    # plus equal-h1 candidates beyond the probe window that could not even
    # be verified — never silently lost
    fanout_ov = jnp.sum(
        jnp.maximum(cnt_win - max_matches, 0)
        + jnp.where(lmask, jnp.maximum(cnt - window, 0), 0), dtype=jnp.int32)

    # expand to (lcap * max_matches) candidate output rows
    li = jnp.repeat(rows, max_matches)
    ri = right_idx.reshape(-1)
    has_match = ri >= 0
    first = (jnp.arange(lcap * max_matches) % max_matches) == 0
    keep_unmatched_l = first & lmask[li] & (matched[li] == 0)
    if how in ("inner", "right"):
        keep = has_match
    else:  # left / outer
        keep = has_match | keep_unmatched_l
    if how in ("right", "outer"):
        # tail block: right rows (in h1-sorted space) no left row verified
        tail_keep = rvalid_s & ~rtouched
        li = jnp.concatenate([li, jnp.full((rcap,), -1, jnp.int32)])
        ri = jnp.concatenate([ri, jnp.arange(rcap, dtype=jnp.int32)])
        keep = jnp.concatenate([keep, tail_keep])

    # ri indexes h1-sorted right space: compose sort + probe gathers so
    # every right column rides one gather through ``rorder``
    rsrc = jnp.where(ri >= 0, rorder[jnp.where(ri >= 0, ri, 0)], -1)
    out = _emit_join_columns(lcols, rcols, keys, li, rsrc)
    cols, n_out, trunc = _compact_cols(out, keep, out_capacity)
    return cols, n_out, trunc + fanout_ov


def _local_hash_join(lcols: Cols, ln, rcols: Cols, rn, *, keys, how,
                     max_matches, max_probes, out_capacity):
    """Sort-free local join: hash build over the right side, counted
    two-pass probe by the left, late-materialized payload gather.

    The build table is seeded by the ``(h1, h2)`` carried through the
    exchange (zero rehash); the probe hot loop touches only the two hash
    lanes plus the bitwise key lanes, and emits bare ``(left_row,
    right_row)`` index pairs at exclusive-scan offsets — output rows are
    born compacted, so the path contains zero ``sort`` primitives
    (DESIGN.md §8).  Overflow counts, per the §2 contract: verified
    matches dropped by ``max_matches``, probe/build rows that exhausted
    ``max_probes`` (their matches are unprovable), and rows past
    ``out_capacity``.
    """
    from repro.kernels.hash_join import ops as hjops

    lcols, lh1, lh2 = take_hashes(lcols, keys)
    rcols, rh1, rh2 = take_hashes(rcols, keys)
    lcap = next(iter(lcols.values())).shape[0]
    rcap = next(iter(rcols.values())).shape[0]
    lmask, rmask = _mask_for(ln, lcap), _mask_for(rn, rcap)
    lkeys = key_compare_u32(lcols, keys)
    rkeys = key_compare_u32(rcols, keys)

    slots = _hash_slots(rcap)
    table, n_unplaced = hjops.build_table(rh1, rh2, rmask, slots, max_probes)
    slot_h2, slot_keys = hjops.slot_payload(table, rh2, rkeys)
    cnt, rimat, exhausted = hjops.probe(table, slot_h2, slot_keys, lh1, lh2,
                                        lkeys, lmask, max_matches,
                                        max_probes)

    keep_all_left = how in ("left", "outer")
    emit_n = jnp.minimum(cnt, max_matches)
    if keep_all_left:
        emit_n = jnp.maximum(emit_n, 1)
    emit_n = jnp.where(lmask, emit_n, 0)
    base = jnp.cumsum(emit_n) - emit_n  # exclusive scan → packed offsets
    total = jnp.sum(emit_n, dtype=jnp.int32)
    li, ri = hjops.emit_lookup(rimat, base, emit_n, total, out_capacity)
    overflow = (jnp.sum(jnp.where(lmask, jnp.maximum(cnt - max_matches, 0),
                                  0), dtype=jnp.int32)
                + jnp.sum(exhausted, dtype=jnp.int32) + n_unplaced)
    if how in ("right", "outer"):
        # tail: right rows no left row's key matches, found by the reverse
        # membership probe (a unique-key table over the LEFT side) — a
        # right row whose pairs were all dropped by the fan-out cap stays
        # matched, so capped pairs never resurface as unmatched rows
        lslots = _hash_slots(lcap)
        lowner, _, l_unres = hjops.build_table_unique(
            lh1, lh2, lkeys, lmask, lslots, max_probes)
        lsh2, lskeys = hjops.slot_payload(lowner, lh2, lkeys)
        rcnt, _, rexh = hjops.probe(lowner, lsh2, lskeys, rh1, rh2,
                                    rkeys, rmask, 1, max_probes)
        tail = rmask & (rcnt == 0) & ~rexh
        tcum = jnp.cumsum(tail.astype(jnp.int32))
        tpos = jnp.where(tail, total + tcum - 1, out_capacity)
        ri = ri.at[tpos].set(jnp.arange(rcap, dtype=jnp.int32), mode="drop")
        total = total + jnp.sum(tail, dtype=jnp.int32)
        overflow = (overflow + jnp.sum(l_unres, dtype=jnp.int32)
                    + jnp.sum(rexh, dtype=jnp.int32))

    out = _emit_join_columns(lcols, rcols, keys, li, ri)
    overflow = overflow + jnp.maximum(total - out_capacity, 0)
    return out, jnp.minimum(total, out_capacity), overflow


def _join_impl(lc, lcnt, rc, rcnt, *, keys, how, method, max_matches,
               window, max_probes, n_shards, lbucket, rbucket, mid_cap_l,
               mid_cap_r, out_capacity, axis, shuffle_left, shuffle_right):
    lcols, ln = _local_parts(lc, lcnt)
    rcols, rn = _local_parts(rc, rcnt)
    ov = jnp.zeros((), jnp.int32)
    if n_shards > 1:
        # co-locate equal keys; carry (h1, h2) so the local join never
        # rehashes the shuffled rows — the hash path seeds its build table
        # straight from the carried hashes (DESIGN.md §3.3/§8).  A side
        # whose partitioning metadata already proves co-location skips its
        # exchange (DESIGN.md §4); its hashes are recomputed locally by
        # take_hashes.
        if shuffle_left:
            lcols, ln, o = hash_shuffle(lcols, ln, keys, n_shards, lbucket,
                                        mid_cap_l, axis, carry_hashes=True)
            ov = ov + o
        if shuffle_right:
            rcols, rn, o = hash_shuffle(rcols, rn, keys, n_shards, rbucket,
                                        mid_cap_r, axis, carry_hashes=True)
            ov = ov + o
    if method == "hash":
        out, cnt, ov_o = _local_hash_join(
            lcols, ln, rcols, rn, keys=keys, how=how,
            max_matches=max_matches, max_probes=max_probes,
            out_capacity=out_capacity)
    else:
        out, cnt, ov_o = _local_sorted_join(
            lcols, ln, rcols, rn, keys=keys, how=how,
            max_matches=max_matches, window=window,
            out_capacity=out_capacity)
    overflow = ov + ov_o
    if axis is not None:
        overflow = spmd_allreduce(overflow, axis)
    return out, cnt[None], overflow


@operator("table.join", Abstraction.TABLE)
def join(left: DistTable, right: DistTable, keys: Sequence[str], *,
         ctx: HPTMTContext, how: str = "inner", max_matches: int = 1,
         window: int = 4, out_capacity: Optional[int] = None,
         bucket_factor: float = 2.0, method: str = "auto",
         max_probes: Optional[int] = None
         ) -> Tuple[DistTable, jnp.ndarray]:
    """Distributed equi-join: shuffle-by-key + local hash build/probe
    (Table III); ``how`` is inner/left/right/outer.

    ``method`` selects the local kernel (DESIGN.md §8): ``"hash"`` — a
    sort-free open-addressing build over the right side plus a counted
    two-pass probe with late-materialized payload gathers; ``"sort"`` —
    the sort-merge oracle (argsort by carried hash + bounded probe
    window).  ``"auto"`` picks hash: it is sort-free, faster at every
    measured size, and reports rather than misses fan-out beyond its
    probe bound.  Put the smaller table on the right — it is the build
    side (conventional for both kernels: sort-merge orders the right side
    too, and swapping sides internally would silently change which side
    ``max_matches`` caps).

    ``max_matches`` bounds the join fan-out per left row (static shapes);
    matches beyond it — and, on the hash path, rows whose probe chain
    exceeds ``max_probes`` — are counted in the returned overflow, never
    silently lost.  A side already hash-partitioned on exactly ``keys``
    skips its shuffle; the output is itself partitioned on ``keys``
    (matched rows stay on the shard their key hashed to), so a following
    groupby/join on the same keys moves no data (DESIGN.md §4).
    """
    if how not in _JOIN_HOWS:
        raise ValueError(f"unknown join type how={how!r}; "
                         f"expected one of {_JOIN_HOWS}")
    if method not in ("auto", "hash", "sort"):
        raise ValueError(f"unknown join method={method!r}; "
                         f"expected 'auto', 'hash' or 'sort'")
    if max_matches < 1:
        raise ValueError(f"max_matches={max_matches} must be >= 1")
    if method == "auto":
        method = "hash"
    check_no_reserved(left.column_names)
    check_no_reserved(right.column_names)
    n = ctx.n_shards
    mid_l = max(left.capacity, 1)
    mid_r = max(right.capacity, 1)
    default_out = mid_l * max_matches + (
        mid_r if how in ("right", "outer") else 0)
    impl = functools.partial(
        _join_impl, keys=tuple(keys), how=how, method=method,
        max_matches=max_matches, window=window,
        max_probes=max_probes or max(64, 2 * max_matches), n_shards=n,
        lbucket=_bucket_capacity(left.capacity, n, bucket_factor),
        rbucket=_bucket_capacity(right.capacity, n, bucket_factor),
        mid_cap_l=mid_l, mid_cap_r=mid_r,
        out_capacity=out_capacity or default_out,
        shuffle_left=not _partitioned_on(left, keys, ctx),
        shuffle_right=not _partitioned_on(right, keys, ctx))
    cols, counts, overflow = _run_sharded(
        ctx, impl, (left.columns, left.counts, right.columns, right.counts),
        out_specs=(P(ctx.data_axis), P(ctx.data_axis), P()))
    return DistTable(cols, counts, (tuple(keys), n)), overflow


# ===========================================================================
# GroupBy + Aggregate (Table III)
# ===========================================================================
_SEGMENT_OPS = ("sum", "mean", "min", "max", "count")


def split_aggs(aggs):
    """Decompose aggregates into (map-side partial, merge) aggregates.

    sum/count/min/max combine associatively; mean decomposes into a sum and
    a count that are summed at the merge and divided at finalize (the mean
    decomposition rule, DESIGN.md §4).  Shared by the eager map-side combine
    and the dataflow combiner barrier.
    """
    partial, merge = [], []
    for col, op in aggs:
        if op in ("sum", "count"):
            partial.append((col, op))
            merge.append((f"{col}_{op}", "sum"))
        elif op in ("min", "max"):
            partial.append((col, op))
            merge.append((f"{col}_{op}", op))
        elif op == "mean":
            partial.append((col, "sum"))
            partial.append((col, "count"))
            merge.append((f"{col}_sum", "sum"))
            merge.append((f"{col}_count", "sum"))
        else:
            raise ValueError(op)
    return tuple(dict.fromkeys(partial)), tuple(dict.fromkeys(merge))


def finalize_agg_cols(cols: Cols, aggs, merge_aggs) -> Cols:
    """Rename merged partial-aggregate columns to the user's labels.

    ``cols`` holds key columns plus ``{col}_{partial}_{mergeop}`` outputs of
    the merge groupby; means are finalized as sum/count here (and only
    here — partials never divide).
    """
    merge_labels = {f"{c}_{o}" for c, o in merge_aggs}
    out = {k: v for k, v in cols.items() if k not in merge_labels}
    for col, op in aggs:
        if op == "mean":
            s, c = cols[f"{col}_sum_sum"], cols[f"{col}_count_sum"]
            out[f"{col}_mean"] = s / jnp.maximum(c, 1.0)
        elif op in ("sum", "count"):
            out[f"{col}_{op}"] = cols[f"{col}_{op}_sum"]
        else:
            out[f"{col}_{op}"] = cols[f"{col}_{op}_{op}"]
    return out


def _agg_outputs(aggs, seg_count, sums, minmax, out_capacity):
    """Assemble labeled aggregate columns from the shared reductions."""
    out: Cols = {}
    for col, agg in aggs:
        label = f"{col}_{agg}"
        if agg == "count":
            out[label] = seg_count[:out_capacity]
        elif agg == "sum":
            out[label] = sums[col][:out_capacity]
        elif agg == "mean":
            s = sums[col]
            cnt = seg_count.reshape((-1,) + (1,) * (s.ndim - 1))
            out[label] = (s / jnp.maximum(cnt, 1.0))[:out_capacity]
        else:
            out[label] = minmax[(col, agg)][:out_capacity]
    return out


def _segment_aggregates(cols: Cols, aggs, seg_id, n_segments: int):
    """All reductions for ``aggs`` over ``seg_id`` with minimal scatters.

    Every sum-combining lane (counts + sums, incl. both halves of mean)
    rides ONE fused segment reduction — trailing dims flatten to extra
    lanes and are reshaped back after; min/max reduce per column.
    Repeated (column, op) pairs are computed once.
    """
    from repro.kernels.segment_reduce import ops as segops

    cap = seg_id.shape[0]
    need_count = any(a in ("count", "mean") for _, a in aggs)
    sum_cols = list(dict.fromkeys(
        c for c, a in aggs if a in ("sum", "mean")))
    parts, spans = [], []  # spans: (col name | None=count, trailing, lanes)
    if need_count:
        parts.append(jnp.ones((cap, 1), jnp.float32))
        spans.append((None, (), 1))
    for c in sum_cols:
        v = cols[c].astype(jnp.float32).reshape(cap, -1)
        parts.append(v)
        spans.append((c, tuple(cols[c].shape[1:]), v.shape[1]))
    seg_count, sums = None, {}
    if parts:
        fused = segops.segment_reduce_fused(
            jnp.concatenate(parts, axis=1), seg_id, n_segments)
        off = 0
        for name, trailing, lanes in spans:
            block = fused[:, off:off + lanes]
            off += lanes
            if name is None:
                seg_count = block[:, 0]
            else:
                sums[name] = block.reshape((fused.shape[0],) + trailing)
    minmax = {}
    for col, agg in aggs:
        if agg in ("min", "max") and (col, agg) not in minmax:
            minmax[(col, agg)] = segops.segment_reduce(
                cols[col].astype(jnp.float32), seg_id, n_segments, op=agg)
    return seg_count, sums, minmax


def _local_groupby_sort(cols: Cols, count, *, keys, aggs, out_capacity):
    """Sort-based grouping: lexsort keys, segment-reduce runs."""
    cap = next(iter(cols.values())).shape[0]
    mask = _mask_for(count, cap)
    key_arrays = [cols[k] for k in keys]
    sorted_cols, order = _sort_cols(cols, key_arrays, mask)
    smask = mask[order]

    # a row opens a new segment when ANY key differs from its predecessor
    # (row 0 always does)
    new_seg = jnp.concatenate(
        [jnp.ones((1,), bool), jnp.zeros((cap - 1,), bool)])
    for k in keys:
        col = sorted_cols[k]
        new_seg = new_seg | jnp.concatenate(
            [jnp.ones((1,), bool), col[1:] != col[:-1]])
    new_seg = new_seg & smask
    seg_id = jnp.cumsum(new_seg.astype(jnp.int32)) - 1
    n_seg = jnp.maximum(jnp.max(jnp.where(smask, seg_id, -1)) + 1, 0)
    seg_id = jnp.where(smask, seg_id, cap)  # sentinel bucket for invalid

    out: Cols = {}
    # first row of each segment via counting scatter (segment ids of the
    # boundary rows are unique), no argsort
    first_idx = jnp.zeros((cap,), jnp.int32).at[
        jnp.where(new_seg, seg_id, cap)].set(
        jnp.arange(cap, dtype=jnp.int32), mode="drop")
    for k in keys:
        out[k] = sorted_cols[k][first_idx][:out_capacity]
    seg_count, sums, minmax = _segment_aggregates(
        sorted_cols, aggs, seg_id, cap + 1)
    out.update(_agg_outputs(aggs, seg_count, sums, minmax, out_capacity))
    # zero-fill rows beyond n_seg; pad when out_capacity exceeds the input
    # capacity (there can be at most ``cap`` groups, the rest is padding)
    m = _mask_for(jnp.minimum(n_seg, out_capacity), out_capacity)
    out = {k: jnp.where(m.reshape((-1,) + (1,) * (v.ndim - 1)),
                        _pad_axis0(v, out_capacity),
                        jnp.zeros(((out_capacity,) + v.shape[1:]), v.dtype))
           for k, v in out.items()}
    overflow = jnp.maximum(n_seg - out_capacity, 0)
    return out, jnp.minimum(n_seg, out_capacity).astype(jnp.int32), overflow


def _key_bits_eq(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Key equality by the same identity the hash uses.

    Float keys compare by f32 bit pattern, exactly matching
    ``table.hash_columns`` — so a row's key-compare verdict is always
    consistent with its probe sequence.  Value-compare (``==``) would
    deadlock NaN keys (NaN != NaN even against the row's own claimed slot,
    so each NaN row would claim a fresh slot every round) and would call
    ``-0.0 == +0.0`` equal while their hashes differ.  Consequence: the
    hash kernel groups float keys bitwise (equal-bit NaNs form one group,
    ±0.0 form two), where the sort kernel groups by value.
    """
    if jnp.issubdtype(a.dtype, jnp.floating):
        a = jax.lax.bitcast_convert_type(a.astype(jnp.float32), jnp.uint32)
        b = jax.lax.bitcast_convert_type(b.astype(jnp.float32), jnp.uint32)
    return a == b


def _local_groupby_hash(cols: Cols, count, *, keys, aggs, out_capacity,
                        max_probes: int = 64):
    """Sort-free grouping: claim hash-table slots, segment-reduce by slot.

    Each valid row double-hash-probes a power-of-two slot table via the
    shared ``build_table_unique`` primitive (``kernels/hash_join``, also
    under the join and set-op paths — DESIGN.md §8): the lowest row index
    probing a free slot claims it for its key (scatter-min), and rows
    match a slot only after comparing their ACTUAL bitwise key lanes
    against the claimant (hash equality is never trusted, DESIGN.md §4).
    The probe loop exits as soon as every valid row is resolved —
    typically 2-3 rounds at the ≤25% load factor implied by the 4x slot
    head-room.  Rows unresolved after ``max_probes`` (cardinality far
    beyond ``out_capacity``) are counted as overflow, per the §2
    contract.  O(n) per round, zero sorts.
    """
    from repro.kernels.hash_join import ops as hjops

    from .table import hash_columns

    cap = next(iter(cols.values())).shape[0]
    mask = _mask_for(count, cap)
    slots = _hash_slots(out_capacity)
    h1, h2 = hash_columns([cols[k] for k in keys])
    owner, seg, unresolved = hjops.build_table_unique(
        h1, h2, key_compare_u32(cols, keys), mask, slots, max_probes)

    occupied = owner >= 0
    claimant = jnp.where(occupied, owner, 0)
    slot_cols: Cols = {k: jnp.where(
        occupied.reshape((-1,) + (1,) * (cols[k].ndim - 1)),
        cols[k][claimant], jnp.zeros_like(cols[k][claimant])) for k in keys}
    seg_count, sums, minmax = _segment_aggregates(cols, aggs, seg, slots + 1)
    slot_cols.update(_agg_outputs(aggs, seg_count, sums, minmax, slots))
    out, n_seg, trunc = compact_rows(slot_cols, occupied, out_capacity)
    overflow = jnp.sum(unresolved, dtype=jnp.int32) + trunc
    return out, n_seg, overflow


def _local_groupby(cols: Cols, count, *, keys, aggs, out_capacity,
                   method: str = "auto"):
    """Local grouping, dispatching sort vs hash (DESIGN.md §4).

    ``auto`` picks the sort-free hash table when the caller declared low
    cardinality (``out_capacity`` at most a quarter of the row capacity —
    the slot table then still fits the 4x head-room), else the lexsort
    path.  Returns ``(columns, n_groups, overflow)``.  Overflow is a
    data-loss indicator (zero iff nothing was dropped); its unit is groups
    for capacity truncation and rows for hash-probe exhaustion, and which
    groups survive truncation is deterministic per kernel but differs
    between them (sorted-key order vs hash-slot order) — callers retrying
    per the §2 contract should grow capacity, not interpret the count.
    """
    cap = next(iter(cols.values())).shape[0]
    if method == "auto":
        method = "hash" if out_capacity * 4 <= cap else "sort"
    if method == "hash":
        return _local_groupby_hash(cols, count, keys=keys, aggs=aggs,
                                   out_capacity=out_capacity)
    return _local_groupby_sort(cols, count, keys=keys, aggs=aggs,
                               out_capacity=out_capacity)


def _groupby_impl(cols, counts, *, keys, aggs, n_shards, bucket,
                  mid_capacity, out_capacity, axis, elide, combine,
                  partial_cap, combine_bucket, method):
    local_cols, count = _local_parts(cols, counts)
    ov = jnp.zeros((), jnp.int32)
    if n_shards > 1 and not elide:
        if combine:
            # map-side combine: pre-aggregate locally so only distinct
            # (key, partial) rows enter the packed AllToAll
            partial_aggs, merge_aggs = split_aggs(aggs)
            pcols, pcount, o = _local_groupby(
                local_cols, count, keys=keys, aggs=partial_aggs,
                out_capacity=partial_cap, method=method)
            ov += o
            mid = n_shards * combine_bucket
            pcols, pcount, o = hash_shuffle(
                pcols, pcount, keys, n_shards, combine_bucket, mid, axis)
            ov += o
            out, n_seg, o = _local_groupby(
                pcols, pcount, keys=keys, aggs=merge_aggs,
                out_capacity=out_capacity, method=method)
            ov += o
            out = finalize_agg_cols(out, aggs, merge_aggs)
        else:
            local_cols, count, o = hash_shuffle(
                local_cols, count, keys, n_shards, bucket, mid_capacity,
                axis)
            out, n_seg, o2 = _local_groupby(
                local_cols, count, keys=keys, aggs=aggs,
                out_capacity=out_capacity, method=method)
            ov += o + o2
    else:
        # single shard, or rows already co-located on the keys: no exchange
        out, n_seg, o = _local_groupby(local_cols, count, keys=keys,
                                       aggs=aggs, out_capacity=out_capacity,
                                       method=method)
        ov += o
    if axis is not None:
        ov = spmd_allreduce(ov, axis)
    return out, n_seg[None], ov


@operator("table.groupby", Abstraction.TABLE)
def groupby_aggregate(dt: DistTable, keys: Sequence[str],
                      aggs: Sequence[Tuple[str, str]], *, ctx: HPTMTContext,
                      out_capacity: Optional[int] = None,
                      bucket_factor: float = 2.0,
                      combine: "bool | str" = "auto",
                      method: str = "auto",
                      ) -> Tuple[DistTable, jnp.ndarray]:
    """GroupBy + aggregate (Table III): shuffle-by-key + segment reduce.

    ``aggs`` is a list of ``(column, op)`` with op in sum/mean/min/max/count.

    Two data-movement optimisations (DESIGN.md §4):

      * **Shuffle elision** — when ``dt.partitioning`` records that rows are
        already hash-co-located on exactly these ``keys`` (e.g. the output
        of a join or shuffle on the same keys), the exchange is skipped
        entirely and grouping is purely local.
      * **Map-side combine** (``combine``) — pre-aggregate locally before
        the exchange so only distinct ``(key, partial)`` rows cross the
        network; mean decomposes into sum+count and is finalized after the
        merge.  ``"auto"`` enables it when ``out_capacity`` declares
        cardinality below the row capacity (which also shrinks the
        AllToAll frame itself).

    ``method`` selects the local grouping kernel: ``"sort"`` (lexsort +
    segment runs), ``"hash"`` (sort-free slot table), or ``"auto"``.
    """
    for _, a in aggs:
        if a not in _SEGMENT_OPS:
            raise ValueError(f"unknown aggregate {a!r}")
    if method not in ("auto", "sort", "hash"):
        raise ValueError(f"unknown groupby method {method!r}")
    if not isinstance(combine, bool) and combine != "auto":
        raise ValueError(f"combine must be a bool or 'auto', got {combine!r}")
    check_no_reserved(dt.column_names)
    n = ctx.n_shards
    out_cap = out_capacity or dt.capacity
    elide = _partitioned_on(dt, keys, ctx)
    do_combine = combine if isinstance(combine, bool) else (
        out_cap < dt.capacity)
    partial_cap = (dt.capacity if out_cap >= dt.capacity
                   else min(dt.capacity, out_cap * n))
    impl = functools.partial(
        _groupby_impl, keys=tuple(keys), aggs=tuple(aggs), n_shards=n,
        bucket=_bucket_capacity(dt.capacity, n, bucket_factor),
        mid_capacity=dt.capacity, out_capacity=out_cap, elide=elide,
        combine=do_combine, partial_cap=partial_cap,
        combine_bucket=_bucket_capacity(partial_cap, n, bucket_factor),
        method=method)
    cols, counts, overflow = _run_sharded(
        ctx, impl, (dt.columns, dt.counts),
        out_specs=(P(ctx.data_axis), P(ctx.data_axis), P()))
    return DistTable(cols, counts, (tuple(keys), n)), overflow


@operator("table.aggregate", Abstraction.TABLE)
def aggregate(dt: DistTable, column: str, op: str, *, ctx: HPTMTContext):
    """Global scalar aggregate of one column (Table III Aggregate)."""

    def impl(cols, counts, *, axis):
        local_cols, count = _local_parts(cols, counts)
        cap = next(iter(local_cols.values())).shape[0]
        mask = _mask_for(count, cap)
        col = local_cols[column].astype(jnp.float32)
        if op == "sum":
            v = jnp.sum(jnp.where(mask, col, 0.0))
        elif op == "count":
            v = jnp.sum(mask.astype(jnp.float32))
        elif op == "mean":
            v = jnp.sum(jnp.where(mask, col, 0.0))
        elif op == "min":
            v = jnp.min(jnp.where(mask, col, jnp.inf))
        elif op == "max":
            v = jnp.max(jnp.where(mask, col, -jnp.inf))
        else:
            raise ValueError(f"unknown aggregate {op!r}")
        if axis is not None:
            red = {"sum": "sum", "count": "sum", "mean": "sum",
                   "min": "min", "max": "max"}[op]
            v = spmd_allreduce(v, axis, op=red)
            if op == "mean":
                n = spmd_allreduce(jnp.sum(mask.astype(jnp.float32)), axis)
                v = v / jnp.maximum(n, 1.0)
        elif op == "mean":
            v = v / jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)
        return v

    return _run_sharded(ctx, impl, (dt.columns, dt.counts), out_specs=P())


# ===========================================================================
# set operators: Union / Difference / Intersect / Cartesian (Table II/III)
# ===========================================================================
def _dedup_hash(cols: Cols, h1, h2, mask, max_probes: int = 64):
    """Keep the lowest-index row of every bitwise-equal duplicate group.

    Sort-free: rows claim unique-key slots (``build_table_unique`` over
    the carried full-row hashes) and only slot claimants survive.  Rows
    whose probe chain exhausts are *kept* and counted — dropping them
    could lose a distinct row, keeping one can at worst leave a duplicate
    that the overflow count tells the caller to retry away (§2).
    Returns ``(keep, n_unresolved)``; row identity is bitwise (equal-bit
    NaNs deduplicate, ±0.0 stay distinct), consistent with the hashes.
    """
    from repro.kernels.hash_join import ops as hjops

    cap = h1.shape[0]
    keys_u32 = key_compare_u32(cols, tuple(sorted(cols)))
    owner, seg, unresolved = hjops.build_table_unique(
        h1, h2, keys_u32, mask, _hash_slots(cap), max_probes)
    rows = jnp.arange(cap, dtype=jnp.int32)
    claimant = owner[jnp.where(unresolved, 0, seg)] == rows
    keep = mask & (unresolved | claimant)
    return keep, jnp.sum(unresolved, dtype=jnp.int32)


def _membership_hash(a_cols: Cols, amask, ah1, ah2, b_cols: Cols, bmask,
                     bh1, bh2, names, max_probes: int = 64):
    """For each row of A: does a bitwise-equal row exist in B?

    Hash + verify over a unique-key slot table of B — the same build/probe
    primitives as the join, seeded by the carried hashes (zero rehash,
    zero sorts).  Returns ``(found, n_overflow)`` where the count covers B
    rows missing from the table and A probes that exhausted — for both,
    membership is unprovable, so the caller surfaces the count (§2).
    """
    from repro.kernels.hash_join import ops as hjops

    bkeys = key_compare_u32(b_cols, names)
    akeys = key_compare_u32(a_cols, names)
    owner, _, b_unres = hjops.build_table_unique(
        bh1, bh2, bkeys, bmask, _hash_slots(bh1.shape[0]), max_probes)
    slot_h2, slot_keys = hjops.slot_payload(owner, bh2, bkeys)
    cnt, _, exhausted = hjops.probe(owner, slot_h2, slot_keys, ah1, ah2,
                                    akeys, amask, 1, max_probes)
    found = amask & (cnt > 0)
    overflow = (jnp.sum(b_unres, dtype=jnp.int32)
                + jnp.sum(exhausted, dtype=jnp.int32))
    return found, overflow


def _setop_impl(ac, acnt, bc, bcnt, *, kind, names, n_shards, abucket,
                bbucket, mid_a, mid_b, out_capacity, axis, shuffle_a,
                shuffle_b):
    acols, an = _local_parts(ac, acnt)
    bcols, bn = _local_parts(bc, bcnt)
    ov = jnp.zeros((), jnp.int32)

    if n_shards > 1:
        # sides whose metadata proves co-location on the full schema skip
        # their exchange (DESIGN.md §4)
        if shuffle_a:
            acols, an, o = hash_shuffle(acols, an, names, n_shards, abucket,
                                        mid_a, axis, carry_hashes=True)
            ov += o
        if shuffle_b:
            bcols, bn, o = hash_shuffle(bcols, bn, names, n_shards, bbucket,
                                        mid_b, axis, carry_hashes=True)
            ov += o
    # hashes: popped from the shuffle carry, or computed once here — they
    # seed the set-op slot tables directly (build-side reuse, DESIGN.md §8)
    acols, ah1, ah2 = take_hashes(acols, names)
    bcols, bh1, bh2 = take_hashes(bcols, names)

    acap = next(iter(acols.values())).shape[0]
    bcap = next(iter(bcols.values())).shape[0]
    amask, bmask = _mask_for(an, acap), _mask_for(bn, bcap)

    if kind == "union":
        # concat then hash-dedup (hashes concatenate alongside the rows)
        cat = {k: jnp.concatenate([acols[k], bcols[k]]) for k in acols}
        cmask = jnp.concatenate([amask, bmask])
        h1 = jnp.concatenate([ah1, bh1])
        h2 = jnp.concatenate([ah2, bh2])
        keep, o_dedup = _dedup_hash(cat, h1, h2, cmask)
        out, cnt, o = _compact_cols(cat, keep, out_capacity)
    elif kind == "difference":
        found, o_dedup = _membership_hash(acols, amask, ah1, ah2, bcols,
                                          bmask, bh1, bh2, names)
        out, cnt, o = _compact_cols(acols, amask & ~found, out_capacity)
    elif kind == "intersect":
        found, o_mem = _membership_hash(acols, amask, ah1, ah2, bcols,
                                        bmask, bh1, bh2, names)
        keep, o_d = _dedup_hash(acols, ah1, ah2, found)
        o_dedup = o_mem + o_d
        out, cnt, o = _compact_cols(acols, keep, out_capacity)
    else:
        raise ValueError(kind)
    ov = ov + o + o_dedup
    if axis is not None:
        ov = spmd_allreduce(ov, axis)
    return out, cnt[None], ov


def _make_setop(kind: str, opname: str, doc: str):
    @operator(opname, Abstraction.TABLE)
    def op(a: DistTable, b: DistTable, *, ctx: HPTMTContext,
           out_capacity: Optional[int] = None, bucket_factor: float = 2.0,
           ) -> Tuple[DistTable, jnp.ndarray]:
        names = tuple(sorted(set(a.column_names) & set(b.column_names)))
        if names != a.column_names or names != b.column_names:
            raise ValueError("set operators require identical schemas")
        check_no_reserved(names)
        n = ctx.n_shards
        default_out = (a.capacity + b.capacity if kind == "union"
                       else a.capacity)
        impl = functools.partial(
            _setop_impl, kind=kind, names=names, n_shards=n,
            abucket=_bucket_capacity(a.capacity, n, bucket_factor),
            bbucket=_bucket_capacity(b.capacity, n, bucket_factor),
            mid_a=a.capacity, mid_b=b.capacity,
            out_capacity=out_capacity or default_out,
            shuffle_a=not _partitioned_on(a, names, ctx),
            shuffle_b=not _partitioned_on(b, names, ctx))
        cols, counts, overflow = _run_sharded(
            ctx, impl, (a.columns, a.counts, b.columns, b.counts),
            out_specs=(P(ctx.data_axis), P(ctx.data_axis), P()))
        # output rows keep the shard their full-row hash assigned
        return DistTable(cols, counts, (names, n)), overflow

    op.__doc__ = doc
    op.__name__ = kind
    return op


union = _make_setop("union", "table.union",
                    "Distributed Union with duplicate removal (Table II).")
difference = _make_setop(
    "difference", "table.difference",
    "Rows of A with no equal row in B (Table II Difference).")
intersect = _make_setop(
    "intersect", "table.intersect",
    "Deduplicated rows of A that also appear in B (Table III Intersect).")


@operator("table.cartesian", Abstraction.TABLE)
def cartesian(a: DistTable, b: DistTable, *, ctx: HPTMTContext,
              out_capacity: Optional[int] = None) -> DistTable:
    """Cartesian product (Table II): AllGather right, local cross join."""
    n = ctx.n_shards

    def impl(ac, acnt, bc, bcnt, *, axis):
        acols, an = _local_parts(ac, acnt)
        bcols, bn = _local_parts(bc, bcnt)
        acap = next(iter(acols.values())).shape[0]
        bcap = next(iter(bcols.values())).shape[0]
        if axis is not None:
            bcols = {k: spmd_allgather(v, axis) for k, v in bcols.items()}
            bns = spmd_allgather(bn[None], axis)
        else:
            bns = bn[None]
        bg = bcols[next(iter(bcols))].shape[0]
        # validity of gathered right rows
        pos = jnp.arange(bg, dtype=jnp.int32)
        bvalid = (pos % bcap) < bns[pos // bcap]
        li = jnp.repeat(jnp.arange(acap, dtype=jnp.int32), bg)
        ri = jnp.tile(jnp.arange(bg, dtype=jnp.int32), acap)
        keep = _mask_for(an, acap)[li] & bvalid[ri]
        out = {f"a_{k}": v[li] for k, v in acols.items()}
        out.update({f"b_{k}": v[ri] for k, v in bcols.items()})
        cols, cnt, _ = _compact_cols(out, keep, out_capacity or acap * bg)
        return cols, cnt[None]

    cols, counts = _run_sharded(
        ctx, impl, (a.columns, a.counts, b.columns, b.counts),
        out_specs=(P(ctx.data_axis), P(ctx.data_axis)))
    return DistTable(cols, counts)
