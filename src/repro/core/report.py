"""Unified overflow accounting — one exactness certificate per result.

Every operator in this repo runs at a static capacity and *counts* rows it
cannot hold instead of corrupting state (DESIGN.md §2).  Before this
module the counts were scattered per-operator conventions: ``join``
returned a traced scalar, the ``TSet`` barriers discarded theirs, the
scan kept ``rows_overflowed`` on :class:`ScanStats`.  An
:class:`OverflowReport` folds them all into one host-side structure that
rides along with ``DataFrame``/``TSet``/spill results, so a caller has a
single place to ask "is this result exact?" — and the spill engine has a
single place to record that an overflow was *recovered* (re-run
out-of-core) rather than lost.

Counts live under dotted source labels, e.g. ``"join.fanout"``,
``"groupby.slots"``, ``"scan.capacity"``, ``"window.truncated"``.
Recovered counts are kept separately: they describe work the spill path
re-did exactly, so they do not affect :meth:`is_exact`.
"""
from __future__ import annotations

import builtins
import dataclasses
from typing import Dict, Iterator, Tuple


class OverflowError(RuntimeError, builtins.OverflowError):
    """Raised when a result with a nonzero residual overflow is asserted
    exact (:meth:`OverflowReport.assert_exact`) or when an operator is
    configured to fail rather than drop (``DataFrame`` default).

    Subclasses BOTH ``RuntimeError`` (the repo's operator-failure family)
    and the builtin ``OverflowError``, so callers who never import this
    module still catch it with a plain ``except OverflowError:``."""


@dataclasses.dataclass
class OverflowReport:
    """Mutable accumulator of per-source overflow counts.

    ``entries`` maps a dotted source label to the number of rows that
    overflowed and were dropped there.  ``recovered`` maps labels to rows
    that *would* have overflowed in-memory but were recomputed exactly by
    the spill engine — evidence of recovery, not of loss.
    """

    entries: Dict[str, int] = dataclasses.field(default_factory=dict)
    recovered: Dict[str, int] = dataclasses.field(default_factory=dict)

    def add(self, source: str, count) -> "OverflowReport":
        """Record ``count`` dropped rows under ``source`` (0 is a no-op)."""
        n = int(count)
        if n:
            self.entries[source] = self.entries.get(source, 0) + n
        return self

    def add_recovered(self, source: str, count) -> "OverflowReport":
        """Record ``count`` rows recovered via spill under ``source``."""
        n = int(count)
        if n:
            self.recovered[source] = self.recovered.get(source, 0) + n
        return self

    def merge(self, other: "OverflowReport") -> "OverflowReport":
        for k, v in other.entries.items():
            self.add(k, v)
        for k, v in other.recovered.items():
            self.add_recovered(k, v)
        return self

    @property
    def total(self) -> int:
        """Residual (lost) rows across all sources."""
        return sum(self.entries.values())

    @property
    def total_recovered(self) -> int:
        return sum(self.recovered.values())

    def is_exact(self) -> bool:
        """True iff no row was lost anywhere in the lineage."""
        return self.total == 0

    def assert_exact(self) -> "OverflowReport":
        if not self.is_exact():
            detail = ", ".join(f"{k}={v}" for k, v in sorted(
                self.entries.items()))
            raise OverflowError(
                f"result is inexact: {self.total} rows overflowed static "
                f"capacity ({detail}) — raise the capacity/bucket_factor "
                f"or enable spill (spill='auto')")
        return self

    def to_metrics(self, prefix: str = "overflow") -> Dict[str, int]:
        """This report as flat dotted metrics for the telemetry layer.

        Lost rows keep their source labels under ``<prefix>.``
        (``overflow.join.fanout``); spill-recovered rows land under
        ``<prefix>.recovered.``, so one metrics dump carries the same
        exactness story the report itself tells (DESIGN.md §12).
        """
        out = {f"{prefix}.{k}": v for k, v in sorted(self.entries.items())}
        out.update({f"{prefix}.recovered.{k}": v
                    for k, v in sorted(self.recovered.items())})
        return out

    def __iter__(self) -> Iterator[Tuple[str, int]]:
        return iter(sorted(self.entries.items()))

    def __bool__(self) -> bool:  # truthy iff something was lost
        return self.total > 0

    def __repr__(self) -> str:
        lost = ", ".join(f"{k}={v}" for k, v in sorted(self.entries.items()))
        rec = ", ".join(f"{k}={v}" for k, v in sorted(self.recovered.items()))
        return (f"OverflowReport(lost={{{lost}}}, recovered={{{rec}}})")
