"""HPTMT core: the paper's operator architecture as a composable JAX module."""
from . import array_ops, dataflow, table_ops
from .context import HPTMTContext, host_test_context, local_context, make_mesh
from .operator import Abstraction, Execution, Style, get_operator, list_operators
from .table import (DistTable, Table, hash_columns, partitioning_keys,
                    partitioning_kind, range_partitioning)
from .dataflow import TSet
from .report import OverflowError, OverflowReport
