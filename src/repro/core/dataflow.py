"""Dataflow operators — the Twister2/TSet side of HPTMT (paper §V-B-2, §VII-A).

Eager operators (``table_ops``) take whole tables in memory.  Dataflow
operators process data **piece by piece**: the dataset is a stream of
bounded-size chunks (the external-memory model — "datasets that do not fit
into the available random access memory", Fig 5), and each operator consumes
and produces chunks.  Distributed barriers (GroupBy/Join/OrderBy/Union) use
the *combiner* pattern: per-chunk shuffle + partial result, merged at the
barrier — so peak memory stays bounded by the chunk size, not the dataset.

The same local/distributed kernels power both styles; only the driver
differs.  That is the paper's Fig 9: dataflow operators and eager operators
working together in a single parallel program.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from . import table_ops
from .context import HPTMTContext
from .operator import Abstraction, Execution, Style, operator
from .report import OverflowReport
from .table import DistTable, Table, partitioning_keys, partitioning_kind


# ---------------------------------------------------------------------------
# plan nodes
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class _Node:
    kind: str
    inputs: Tuple["_Node", ...] = ()
    payload: dict = dataclasses.field(default_factory=dict)


class TSet:
    """A lazy, chunked, distributed dataset (Twister2 TSet analogue)."""

    def __init__(self, node: _Node, ctx: HPTMTContext):
        self._node = node
        self._ctx = ctx
        self._last_report: Optional[OverflowReport] = None

    @property
    def overflow_report(self) -> Optional[OverflowReport]:
        """Overflow accounting from the most recent materialization
        (``collect``/``reduce``/``quantile``/``to_numpy``), or ``None``
        before the first one.  Barrier overflows that previously vanished
        — join fan-out, orderby/union capacity, per-chunk groupby partials
        — all land here, plus any spill-recovery evidence a
        :meth:`from_spill` source carries (DESIGN.md §10)."""
        return self._last_report

    # -- sources -----------------------------------------------------------
    @classmethod
    def from_chunks(cls, chunks: Sequence[DistTable], ctx: HPTMTContext) -> "TSet":
        return cls(_Node("source", payload={"chunks": list(chunks)}), ctx)

    @classmethod
    def from_spill(cls, result, ctx: Optional[HPTMTContext] = None) -> "TSet":
        """Source a TSet from a completed spill result (DESIGN.md §10).

        The spilled chunk stream becomes the source chunks — partitioning
        metadata intact, so downstream barriers keep eliding — and the
        spill report (recovered rows, residual losses) is folded into
        every materialization's :attr:`overflow_report`.  Duck-typed on
        ``.chunks()`` / ``.report`` so core never imports the spill
        layer."""
        node = _Node("source", payload={"chunks": list(result.chunks()),
                                        "report": result.report})
        return cls(node, ctx or result._ctx)

    @classmethod
    def from_table(cls, dt: DistTable, ctx: HPTMTContext,
                   chunk_rows: Optional[int] = None) -> "TSet":
        """Split a table into row-chunks of at most ``chunk_rows`` each."""
        if chunk_rows is None or chunk_rows >= dt.capacity:
            return cls.from_chunks([dt], ctx)
        chunks = []
        cap, p = dt.capacity, dt.n_shards
        for start in range(0, cap, chunk_rows):
            stop = min(start + chunk_rows, cap)
            cols = {}
            for k, v in dt.columns.items():
                blocks = v.reshape((p, cap) + v.shape[1:])
                cols[k] = blocks[:, start:stop].reshape(
                    (p * (stop - start),) + v.shape[1:])
            counts = jnp.clip(dt.counts - start, 0, stop - start)
            # row-slicing never moves rows across shards: layout survives
            chunks.append(DistTable(cols, counts, dt.partitioning))
        return cls.from_chunks(chunks, ctx)

    @classmethod
    def from_scan(cls, scan, ctx: Optional[HPTMTContext] = None) -> "TSet":
        """Source a TSet from a storage ``ScanSource`` (repro.io.scan).

        The scan's fragment rounds become the chunk stream — the chunked
        ingest path (paper Fig 5): each operator stage works on one
        bounded-size chunk at a time (the source list itself is
        materialized, as with every TSet source).  Chunks inherit the
        scan's partitioned-re-entry metadata, so a groupby/join on the
        partition keys elides its merge shuffle (DESIGN.md §4/§5).
        Duck-typed (anything with ``.chunks()`` and ``.ctx``) so core
        never imports the io layer.
        """
        return cls.from_chunks(scan.chunks(), ctx or scan.ctx)

    # -- piecewise (streaming) operators ------------------------------------
    def select(self, predicate: Callable) -> "TSet":
        return TSet(_Node("select", (self._node,), {"pred": predicate}),
                    self._ctx)

    def project(self, columns: Sequence[str]) -> "TSet":
        return TSet(_Node("project", (self._node,), {"cols": tuple(columns)}),
                    self._ctx)

    def map_columns(self, fn: Callable[[Dict[str, jnp.ndarray]], Dict]) -> "TSet":
        """Apply a per-chunk columnar transform (adds/replaces columns)."""
        return TSet(_Node("map", (self._node,), {"fn": fn}), self._ctx)

    # -- barrier (shuffling) operators ---------------------------------------
    def join(self, other: "TSet", keys: Sequence[str], **kw) -> "TSet":
        return TSet(_Node("join", (self._node, other._node),
                          {"keys": tuple(keys), "kw": kw}), self._ctx)

    def groupby(self, keys: Sequence[str], aggs: Sequence[Tuple[str, str]],
                **kw) -> "TSet":
        return TSet(_Node("groupby", (self._node,),
                          {"keys": tuple(keys), "aggs": tuple(aggs), "kw": kw}),
                    self._ctx)

    def orderby(self, by, **kw) -> "TSet":
        """Global multi-key sort at the barrier (materializing)."""
        return TSet(_Node("orderby", (self._node,), {"by": by, "kw": kw}),
                    self._ctx)

    def union(self, other: "TSet", **kw) -> "TSet":
        return TSet(_Node("union", (self._node, other._node), {"kw": kw}),
                    self._ctx)

    def window(self, partition_by, order_by, aggs, rows=None,
               **kw) -> "TSet":
        """Windowed aggregation barrier (DESIGN.md §9): chunks merge, one
        sample-sort exchange orders them (elided if the layout holds),
        the window lanes evaluate in place."""
        return TSet(_Node("window", (self._node,),
                          {"partition_by": partition_by,
                           "order_by": order_by, "aggs": tuple(aggs),
                           "rows": rows, "kw": kw}), self._ctx)

    def topk(self, by, k: int, **kw) -> "TSet":
        """Streaming top-k via the combiner pattern: each chunk reduces to
        its own k candidates (bounded memory), and the barrier merges the
        per-chunk winners — no chunk ever rematerializes."""
        return TSet(_Node("topk", (self._node,),
                          {"by": by, "k": k, "kw": kw}), self._ctx)

    # -- sinks ----------------------------------------------------------------
    def collect(self) -> DistTable:
        """Execute the dataflow graph and materialize the result."""
        self._last_report = report = OverflowReport()
        chunks = _execute(self._node, self._ctx, report)
        self._publish_report()
        return _concat_chunks(chunks, self._ctx)

    def _publish_report(self) -> None:
        """Mirror the materialization's overflow into the active telemetry
        collector under the same dotted labels (no-op when off)."""
        from repro import telemetry

        rec = telemetry.current()
        if rec is not None and self._last_report is not None:
            rec.record_overflow(self._last_report)

    def lazy(self, name: str = "tset"):
        """Bridge into the query planner (repro.plan, DESIGN.md §11).

        Materializes this TSet's streaming graph (a barrier, exactly like
        :meth:`collect` — chunk layouts survive concatenation) and roots
        a :class:`~repro.plan.LazyFrame` at the result, so downstream
        relational chains get whole-pipeline exchange optimization the
        chunk-wise executor cannot see.  The materialization's overflow
        report is carried into the lazy lineage.
        """
        from repro.plan import LazyFrame
        from repro.plan.logical import source

        dt = self.collect()
        return LazyFrame(source(dt, name), self._ctx,
                         OverflowReport().merge(self._last_report))

    def reduce(self, column: str, op: str):
        """Streaming scalar aggregate (per-chunk partials, merged)."""
        self._last_report = report = OverflowReport()
        chunks = _execute(self._node, self._ctx, report)
        self._publish_report()
        parts = [table_ops.aggregate(c, column, op, ctx=self._ctx)
                 for c in chunks]
        stack = jnp.stack(parts)
        merge = {"sum": jnp.sum, "count": jnp.sum, "min": jnp.min,
                 "max": jnp.max, "mean": jnp.mean}[op]
        return merge(stack)

    def quantile(self, column: str, qs, **kw):
        """Column quantiles at the barrier (materializing; exact by
        default via the range layout — table_ops.quantile)."""
        self._last_report = report = OverflowReport()
        dt = _concat_chunks(_execute(self._node, self._ctx, report),
                            self._ctx)
        self._publish_report()
        return table_ops.quantile(dt, column, qs, ctx=self._ctx, **kw)

    def to_numpy(self) -> Dict[str, np.ndarray]:
        """Bridge to NumPy (paper Fig 13 line 28 / Fig 17 line 18)."""
        return self.collect().to_numpy()


# ---------------------------------------------------------------------------
# executor
# ---------------------------------------------------------------------------
def _concat_chunks(chunks: List[DistTable], ctx: HPTMTContext) -> DistTable:
    if len(chunks) == 1:
        return chunks[0]
    p = chunks[0].n_shards
    names = chunks[0].column_names
    out_cols = {}
    cap = sum(c.capacity for c in chunks)
    for name in names:
        blocks = []
        for shard in range(p):
            for c in chunks:
                v = c.columns[name]
                blocks.append(v.reshape((p, c.capacity) + v.shape[1:])[shard])
        out_cols[name] = jnp.concatenate(blocks, axis=0)
    # rows are valid-prefix within each chunk block, not globally: re-compact
    valid_parts = []
    for c in chunks:
        valid_parts.append(
            jnp.arange(c.capacity, dtype=jnp.int32)[None, :] < c.counts[:, None])
    valid = jnp.concatenate(valid_parts, axis=1).reshape(-1)  # (p*cap,)

    def impl(cols, cnts, valid_flags, *, axis):
        out, n, _ = table_ops._compact_cols(cols, valid_flags, cap)
        return out, n[None]

    from jax.sharding import PartitionSpec as P
    cols2, counts2 = table_ops._run_sharded(
        ctx, impl, (out_cols, jnp.zeros((p,), jnp.int32), valid),
        out_specs=(P(ctx.data_axis), P(ctx.data_axis)))
    # shard-wise concatenation keeps every row on its shard: when all
    # chunks agree on a hash layout, the merged table still has it — this
    # is what lets the combiner barrier's merge groupby elide its shuffle
    # (DESIGN.md §4).  A RANGE layout does NOT survive: concatenating two
    # sorted chunks interleaves their orders, so only the single-chunk
    # early-return above can keep it (DESIGN.md §9).
    parts = {c.partitioning for c in chunks}
    part = parts.pop() if len(parts) == 1 else None
    if partitioning_kind(part) == "range":
        part = None
    return DistTable(cols2, counts2, part)


def _execute(node: _Node, ctx: HPTMTContext,
             report: Optional[OverflowReport] = None) -> List[DistTable]:
    if report is None:
        report = OverflowReport()
    if node.kind == "source":
        src_report = node.payload.get("report")
        if src_report is not None:
            report.merge(src_report)
        return list(node.payload["chunks"])

    if node.kind in ("select", "project", "map"):
        chunks = _execute(node.inputs[0], ctx, report)
        out = []
        for c in chunks:
            if node.kind == "select":
                out.append(table_ops.select(c, node.payload["pred"], ctx=ctx))
            elif node.kind == "project":
                out.append(table_ops.project(c, node.payload["cols"], ctx=ctx))
            else:
                updates = node.payload["fn"](c.columns)
                new_cols = dict(c.columns)
                new_cols.update(updates)
                # a transform that rewrites a key column — hash or range —
                # invalidates the layout evidence; untouched keys keep it
                part = c.partitioning
                if part is not None and \
                        set(partitioning_keys(part)) & set(updates):
                    part = None
                out.append(DistTable(new_cols, c.counts, part))
        return out

    if node.kind == "groupby":
        # combiner pattern: partial aggregate per chunk, then merge the
        # partials.  Each per-chunk groupby leaves its output partitioned
        # on the keys; _concat_chunks preserves the common layout, so the
        # merge groupby below elides its shuffle — one exchange per chunk,
        # zero at the barrier (DESIGN.md §4).
        chunks = _execute(node.inputs[0], ctx, report)
        keys, aggs = node.payload["keys"], node.payload["aggs"]
        partial_aggs, merge_aggs = table_ops.split_aggs(aggs)
        # map-side combine is essential here, not just an optimisation: a
        # chunk's per-shard capacity is small by design, so shuffling raw
        # rows of a low-cardinality key would overflow it — pre-aggregated
        # partials always fit
        kw = dict(node.payload["kw"])
        kw.setdefault("combine", True)
        partials = []
        for c in chunks:
            part, ov = table_ops.groupby_aggregate(
                c, keys, partial_aggs, ctx=ctx, **kw)
            report.add("groupby.slots", ov)
            partials.append(part)
        merged = _concat_chunks(partials, ctx)
        final, ov = table_ops.groupby_aggregate(
            merged, keys, merge_aggs, ctx=ctx, **kw)
        report.add("groupby.slots", ov)
        final = DistTable(
            table_ops.finalize_agg_cols(final.columns, aggs, merge_aggs),
            final.counts, final.partitioning)
        return [final]

    # materializing barriers
    if node.kind == "join":
        left = _concat_chunks(_execute(node.inputs[0], ctx, report), ctx)
        right = _concat_chunks(_execute(node.inputs[1], ctx, report), ctx)
        out, ov = table_ops.join(left, right, node.payload["keys"], ctx=ctx,
                                 **node.payload["kw"])
        report.add("join.fanout", ov)
        return [out]
    if node.kind == "orderby":
        t = _concat_chunks(_execute(node.inputs[0], ctx, report), ctx)
        out, ov = table_ops.orderby(t, node.payload["by"], ctx=ctx,
                                    **node.payload["kw"])
        report.add("orderby.capacity", ov)
        return [out]
    if node.kind == "window":
        t = _concat_chunks(_execute(node.inputs[0], ctx, report), ctx)
        out, ov = table_ops.window_aggregate(
            t, node.payload["partition_by"], node.payload["order_by"],
            node.payload["aggs"], rows=node.payload["rows"], ctx=ctx,
            **node.payload["kw"])
        # window overflow means truncated (wrong-VALUED) windows, not
        # dropped rows — unlike the other barriers it must never pass
        # silently (§2: zero overflow is the exactness certificate)
        report.add("window.truncated", ov)
        if int(ov) != 0:
            raise RuntimeError(
                f"window: {int(ov)} windows were truncated by the "
                f"cross-shard halo — raise the capacity or repartition")
        return [out]
    if node.kind == "topk":
        # combiner pattern: per-chunk top-k candidates (bounded memory),
        # merged by one final top-k over the k-per-chunk survivors
        chunks = _execute(node.inputs[0], ctx, report)
        by, k, kw = (node.payload[f] for f in ("by", "k", "kw"))
        cands = [table_ops.topk(c, by, k, ctx=ctx, **kw) for c in chunks]
        merged = _concat_chunks(cands, ctx)
        return [table_ops.topk(merged, by, k, ctx=ctx, **kw)]
    if node.kind == "union":
        a = _concat_chunks(_execute(node.inputs[0], ctx, report), ctx)
        b = _concat_chunks(_execute(node.inputs[1], ctx, report), ctx)
        out, ov = table_ops.union(a, b, ctx=ctx, **node.payload["kw"])
        report.add("union.capacity", ov)
        return [out]
    raise ValueError(f"unknown node {node.kind}")


# agg decomposition/finalization shared with the eager map-side combine:
# table_ops.split_aggs / table_ops.finalize_agg_cols (DESIGN.md §4)
