"""Multidimensional scaling — the paper's flagship composition (Figs 14/15).

Reproduces the HPTMT pattern end to end:

  1. *table operators* (dataflow style) curate the input point set —
     select by quality, dedup, order;
  2. the ``to_jax`` bridge hands the curated table to array land (Fig 13
     line 28 / Fig 17 line 18);
  3. *array operators* compute the row-partitioned distance matrix
     (AllGather of the point block — Table I) and run SMACOF iterations,
     with AllReduce for the global stress — the MPI side of Fig 14.

Same code runs single-device (tests) or on a row-sharded mesh.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DistTable, HPTMTContext, Table, table_ops
from repro.core.array_ops import spmd_allgather, spmd_allreduce
from repro.dataframe.frame import DataFrame


def _pairwise_dist(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    d2 = (jnp.sum(x * x, 1)[:, None] + jnp.sum(y * y, 1)[None]
          - 2 * x @ y.T)
    return jnp.sqrt(jnp.maximum(d2, 1e-12))


def smacof(delta: jnp.ndarray, dim: int, iters: int, seed: int
           ) -> Tuple[List[float], jnp.ndarray]:
    """Classic SMACOF on a full dissimilarity matrix (array operators).

    The Guttman transform requires a strictly off-diagonal B matrix — the
    sqrt-clamp in the distance kernel leaves ~1e-6 on the diagonal, which
    (δ_ii/d_ii = 1) silently breaks the majorization, so both δ and the
    ratio matrix are explicitly diagonal-masked.
    """
    n = delta.shape[0]
    eye = jnp.eye(n, dtype=bool)
    delta = jnp.where(eye, 0.0, delta)
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, dim)) * 0.1

    @jax.jit
    def step(x):
        d = _pairwise_dist(x, x)
        ratio = jnp.where(~eye & (d > 1e-9),
                          delta / jnp.maximum(d, 1e-9), 0.0)
        b = -ratio
        b = b.at[jnp.arange(n), jnp.arange(n)].set(ratio.sum(1))
        x_new = (b @ x) / n
        stress = jnp.sum(jnp.where(eye, 0.0, (delta - d) ** 2)) / 2
        return x_new, stress

    path = []
    for _ in range(iters):
        x, stress = step(x)
        path.append(float(stress))
    return path, x


def mds_pipeline(n_points: int, dim: int, iters: int, ctx: HPTMTContext,
                 seed: int = 0) -> Tuple[List[float], jnp.ndarray]:
    """Fig 14 end-to-end: table preprocessing → distance matrix → MDS."""
    rng = np.random.default_rng(seed)
    # raw point table with a quality column and some junk rows
    n_raw = n_points + n_points // 3 + 1
    feats = rng.normal(size=(n_raw, 4)).astype(np.float32)
    quality = rng.uniform(size=n_raw).astype(np.float32)
    # ensure exactly n_points survive the filter
    order = np.argsort(-quality)
    quality[order[:n_points]] = np.clip(quality[order[:n_points]], 0.5, None)
    quality[order[n_points:]] = np.clip(quality[order[n_points:]], None,
                                        0.49)
    df = DataFrame.from_dict(
        {"id": np.arange(n_raw, dtype=np.int32),
         "quality": quality,
         **{f"f{i}": feats[:, i] for i in range(4)}}, ctx)

    # 1) table operators: select + order (deterministic row order)
    curated = df.select(lambda c: c["quality"] >= 0.5).sort_values("id")

    # 2) bridge to arrays
    points = curated.to_jax([f"f{i}" for i in range(4)])  # (n_points, 4)
    assert points.shape[0] == n_points

    # 3) array operators: row-partitioned distance matrix
    if ctx.is_distributed:
        p = ctx.n_shards
        pad = (-n_points) % p
        pts = jnp.pad(points, ((0, pad), (0, 0)))

        def block(local_pts):
            all_pts = spmd_allgather(local_pts, ctx.data_axis)
            return _pairwise_dist(local_pts, all_pts)

        from jax.sharding import PartitionSpec as P
        delta = ctx.shard_map(block, in_specs=P(ctx.data_axis),
                              out_specs=P(ctx.data_axis))(pts)
        delta = delta[:n_points, :n_points]
    else:
        delta = _pairwise_dist(points, points)

    return smacof(delta, dim, iters, seed)
