"""Pallas TPU kernels for HPTMT hot spots.

Each kernel package has: ``kernel.py`` (pl.pallas_call + BlockSpec tiling),
``ops.py`` (dispatching jit'd wrapper), ``ref.py`` (pure-jnp oracle used for
interpret-mode validation).
"""
