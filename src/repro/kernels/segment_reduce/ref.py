"""Pure-jnp oracle for segment reduction (GroupBy-aggregate hot loop)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_INITS = {"sum": 0.0, "min": jnp.inf, "max": -jnp.inf}


def segment_reduce(values: jnp.ndarray, segment_ids: jnp.ndarray,
                   num_segments: int, op: str = "sum") -> jnp.ndarray:
    """Reduce ``values`` by ``segment_ids`` into ``num_segments`` buckets.

    ids outside ``[0, num_segments)`` are dropped. Empty segments hold the
    reduction identity (0 / +inf / -inf), matching ``jax.ops.segment_*``.
    """
    if op == "sum":
        return jax.ops.segment_sum(values, segment_ids, num_segments)
    if op == "min":
        return jax.ops.segment_min(values, segment_ids, num_segments)
    if op == "max":
        return jax.ops.segment_max(values, segment_ids, num_segments)
    raise ValueError(f"unknown op {op!r}")
