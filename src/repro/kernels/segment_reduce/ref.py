"""Pure-jnp oracle for segment reduction (GroupBy-aggregate hot loop)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

_INITS = {"sum": 0.0, "min": jnp.inf, "max": -jnp.inf}


def segment_reduce(values: jnp.ndarray, segment_ids: jnp.ndarray,
                   num_segments: int, op: str = "sum") -> jnp.ndarray:
    """Reduce ``values`` by ``segment_ids`` into ``num_segments`` buckets.

    ids outside ``[0, num_segments)`` are dropped. Empty segments hold the
    reduction identity (0 / +inf / -inf), matching ``jax.ops.segment_*``.
    """
    if op == "sum":
        return jax.ops.segment_sum(values, segment_ids, num_segments)
    if op == "min":
        return jax.ops.segment_min(values, segment_ids, num_segments)
    if op == "max":
        return jax.ops.segment_max(values, segment_ids, num_segments)
    raise ValueError(f"unknown op {op!r}")


def segment_reduce_fused(values: jnp.ndarray, segment_ids: jnp.ndarray,
                         num_segments: int) -> jnp.ndarray:
    """Sum-reduce ``(N, L)`` values by segment in ONE scatter.

    XLA lowers a leading-axis ``segment_sum`` over a 2-D operand to a single
    scatter-add whose cost is dominated by the row count, not the lane
    count — measurably cheaper than one scatter per aggregate column
    (the GroupBy map-side-combine hot loop, DESIGN.md §4).
    """
    return jax.ops.segment_sum(values, segment_ids, num_segments)
