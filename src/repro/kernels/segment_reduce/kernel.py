"""Pallas TPU segment-reduce kernel.

TPU adaptation of the GroupBy-aggregate hot loop (paper Table III): rather
than scatter-adds (slow on TPU — no efficient random-access writes), each
(segment-block × value-block) grid cell builds a one-hot matrix
``onehot[s, n] = (segment_ids[n] == s)`` and reduces it against the value
block.  For ``sum`` this is a matmul that runs on the **MXU**; min/max use
masked VPU reductions.  Output blocks are revisited across the value-block
grid dimension (accumulation), so the value dimension must be the innermost
(fastest-varying) grid axis.

Block sizes default to 512×512: one onehot tile is 512*512*4B = 1 MiB of
VMEM, well inside the ~16 MiB v5e VMEM budget together with the value and
output tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_INITS = {"sum": 0.0, "min": float("inf"), "max": float("-inf")}


def _kernel(seg_ref, val_ref, out_ref, *, op: str, block_s: int):
    s = pl.program_id(0)
    n = pl.program_id(1)

    @pl.when(n == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, _INITS[op])

    seg = seg_ref[...]            # (block_n,) int32
    val = val_ref[...]            # (block_n,) float32
    local = seg - s * block_s
    block_n = seg.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (block_s, block_n), 0)
    onehot = rows == local[None, :]

    if op == "sum":
        # MXU path: one-hot matmul
        contrib = jnp.dot(onehot.astype(jnp.float32), val.astype(jnp.float32),
                          preferred_element_type=jnp.float32)
        out_ref[...] += contrib.astype(out_ref.dtype)
    elif op == "min":
        cur = jnp.min(jnp.where(onehot, val[None, :], jnp.inf), axis=1)
        out_ref[...] = jnp.minimum(out_ref[...], cur.astype(out_ref.dtype))
    else:  # max
        cur = jnp.max(jnp.where(onehot, val[None, :], -jnp.inf), axis=1)
        out_ref[...] = jnp.maximum(out_ref[...], cur.astype(out_ref.dtype))


def _kernel_fused(seg_ref, val_ref, out_ref, *, block_s: int):
    """Multi-lane sum: one one-hot matmul reduces all value lanes at once.

    ``val_ref`` is ``(block_n, lanes)``; the same ``(block_s, block_n)``
    one-hot contracts every lane in a single MXU pass, so the per-element
    cost of extra aggregate columns is amortised against the one-hot build.
    """
    s = pl.program_id(0)
    n = pl.program_id(1)

    @pl.when(n == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    seg = seg_ref[...]                      # (block_n,) int32
    val = val_ref[...]                      # (block_n, lanes) float32
    local = seg - s * block_s
    block_n = seg.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (block_s, block_n), 0)
    onehot = (rows == local[None, :]).astype(jnp.float32)
    out_ref[...] += jnp.dot(onehot, val.astype(jnp.float32),
                            preferred_element_type=jnp.float32)


def segment_reduce_pallas(values: jnp.ndarray, segment_ids: jnp.ndarray,
                          num_segments: int, op: str = "sum", *,
                          block_n: int = 512, block_s: int = 512,
                          interpret: bool = False) -> jnp.ndarray:
    """values (N,) f32, segment_ids (N,) i32 → (num_segments,) f32.

    N and num_segments are padded to block multiples internally; ids outside
    ``[0, num_segments)`` are dropped (they never match a one-hot row).
    """
    n = values.shape[0]
    n_pad = -(-n // block_n) * block_n
    s_pad = -(-num_segments // block_s) * block_s
    vals = jnp.pad(values.astype(jnp.float32), (0, n_pad - n))
    segs = jnp.pad(segment_ids.astype(jnp.int32), (0, n_pad - n),
                   constant_values=s_pad)  # padding never matches a block row
    segs = jnp.where(segs < 0, s_pad, segs)

    out = pl.pallas_call(
        functools.partial(_kernel, op=op, block_s=block_s),
        grid=(s_pad // block_s, n_pad // block_n),
        in_specs=[
            pl.BlockSpec((block_n,), lambda s, i: (i,)),
            pl.BlockSpec((block_n,), lambda s, i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_s,), lambda s, i: (s,)),
        out_shape=jax.ShapeDtypeStruct((s_pad,), jnp.float32),
        interpret=interpret,
    )(segs, vals)
    return out[:num_segments]


def segment_reduce_fused_pallas(values: jnp.ndarray,
                                segment_ids: jnp.ndarray,
                                num_segments: int, *, block_n: int = 512,
                                block_s: int = 512,
                                interpret: bool = False) -> jnp.ndarray:
    """values (N, L) f32, segment_ids (N,) i32 → (num_segments, L) f32 sums.

    All lanes reduce through one one-hot matmul per grid cell (MXU), so a
    GroupBy with several sum/count/mean aggregates costs one kernel pass.
    """
    n, lanes = values.shape
    n_pad = -(-n // block_n) * block_n
    s_pad = -(-num_segments // block_s) * block_s
    vals = jnp.pad(values.astype(jnp.float32), ((0, n_pad - n), (0, 0)))
    segs = jnp.pad(segment_ids.astype(jnp.int32), (0, n_pad - n),
                   constant_values=s_pad)
    segs = jnp.where(segs < 0, s_pad, segs)

    out = pl.pallas_call(
        functools.partial(_kernel_fused, block_s=block_s),
        grid=(s_pad // block_s, n_pad // block_n),
        in_specs=[
            pl.BlockSpec((block_n,), lambda s, i: (i,)),
            pl.BlockSpec((block_n, lanes), lambda s, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_s, lanes), lambda s, i: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((s_pad, lanes), jnp.float32),
        interpret=interpret,
    )(segs, vals)
    return out[:num_segments]
