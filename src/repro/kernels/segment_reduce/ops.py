"""Public entry point for segment reduction.

Dispatch: compiled Pallas kernel on TPU, pure-jnp reference elsewhere
(the reference is itself fast XLA code on CPU).  ``force`` overrides for
testing ("pallas" uses interpret mode off-TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnums=(2,), static_argnames=("op", "force"))
def segment_reduce(values: jnp.ndarray, segment_ids: jnp.ndarray,
                   num_segments: int, op: str = "sum",
                   force: str | None = None) -> jnp.ndarray:
    if force == "pallas" or (force is None and _on_tpu()):
        return _kernel.segment_reduce_pallas(
            values, segment_ids, num_segments, op, interpret=not _on_tpu())
    return _ref.segment_reduce(values, segment_ids, num_segments, op)


@functools.partial(jax.jit, static_argnums=(2,), static_argnames=("force",))
def segment_reduce_fused(values: jnp.ndarray, segment_ids: jnp.ndarray,
                         num_segments: int,
                         force: str | None = None) -> jnp.ndarray:
    """Sum-reduce ``(N, L)`` value lanes by segment in one pass.

    The GroupBy fast path: every sum-combining aggregate (sum, count, the
    sum/count halves of mean) rides one scatter (CPU/GPU) or one one-hot
    matmul sweep (TPU Pallas) instead of one reduction per column.
    """
    if force == "pallas" or (force is None and _on_tpu()):
        return _kernel.segment_reduce_fused_pallas(
            values, segment_ids, num_segments, interpret=not _on_tpu())
    return _ref.segment_reduce_fused(values, segment_ids, num_segments)
