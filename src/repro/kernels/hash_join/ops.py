"""Public entry points for the hash-join build/probe engine.

Dispatch mirrors ``hash_partition/ops.py``: the probe-count hot loop runs
the compiled Pallas kernel on TPU (within its VMEM table budget) and the
pure-jnp reference elsewhere; ``force`` overrides for testing ("pallas"
uses interpret mode off-TPU).  Build (contended scatter-min) and emit
(binary search + gather walk) lower well through XLA everywhere — they
have no Pallas variant and always take the reference path.

These primitives serve three operators (DESIGN.md §8): join
(``build_table`` + two-pass probe), set-op membership/dedup and the
groupby hash kernel (``build_table_unique``).
"""
from __future__ import annotations

import jax

from . import kernel as _kernel
from . import ref as _ref

build_table = _ref.build_table
build_table_unique = _ref.build_table_unique
slot_payload = _ref.slot_payload
emit_lookup = _ref.emit_lookup

#: Largest slot-table footprint (uint32 lanes) the Pallas probe kernel may
#: keep VMEM-resident; bigger tables fall back to the jnp reference.
_PALLAS_MAX_TABLE_LANES = 1 << 21


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def probe(table_row, slot_h2, slot_keys, ph1, ph2, pkeys_u32, pvalid,
          max_matches: int = 1, max_probes: int = 64,
          force: str | None = None):
    """Fused probe: match counts, first-match registers, exhausted flags.

    Pallas on TPU when the slot table fits VMEM, jnp oracle elsewhere.
    Returns ``(cnt (N,) int32, rimat (N, max_matches) int32,
    exhausted (N,) bool)``.
    """
    table_lanes = table_row.shape[0] * (2 + slot_keys.shape[1])
    if force == "pallas" or (force is None and _on_tpu()
                             and table_lanes <= _PALLAS_MAX_TABLE_LANES):
        return _kernel.probe_pallas(
            table_row, slot_h2, slot_keys, ph1, ph2, pkeys_u32, pvalid,
            max_matches, max_probes, interpret=not _on_tpu())
    return _ref.probe(table_row, slot_h2, slot_keys, ph1, ph2, pkeys_u32,
                      pvalid, max_matches, max_probes)
