"""Pallas TPU probe kernel for the hash-join engine (DESIGN.md §8).

The fused probe (the one walk of the counted two-pass scheme) is the
join's hot loop: per probe row it walks the double-hash sequence,
gathering only the slot-indexed ``table_row`` / ``h2`` / key lanes —
two-ish uint32 lanes per candidate instead of the packed payload row —
while counting matches and filling the first-``max_matches`` registers.
The kernel blocks the probe rows across the grid and keeps the slot table
resident (block index 0 on every grid step), so one HBM read of the probe
block serves the whole walk; the walk itself is an early-exit
``while_loop`` over VMEM gathers.

Sizing caveat: the whole slot table (``table_row`` + ``h2`` + key lanes,
4 bytes per lane per slot) must fit VMEM alongside one probe block —
about 1M slots at one key lane on a ~16 MiB v5e core.  ``ops.py`` only
dispatches here within that budget; larger tables take the jnp reference,
which is the same algorithm as XLA gathers.

The walk must match ``ref.probe`` bit-for-bit — the jnp oracle IS the
semantics (tests compare in interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(trow_ref, th2_ref, tkeys_ref, ph1_ref, ph2_ref, pkeys_ref,
            pvalid_ref, cnt_ref, rimat_ref, exh_ref, *, slots: int,
            max_probes: int, max_matches: int, n_lanes: int):
    trow = trow_ref[...]
    th2 = th2_ref[...]
    tkeys = tkeys_ref[...]
    ph1 = ph1_ref[...]
    ph2 = ph2_ref[...]
    pkeys = pkeys_ref[...]
    active0 = pvalid_ref[...] != 0
    step = ph2 | jnp.uint32(1)
    block_n = ph1.shape[0]
    ords = jnp.arange(max_matches, dtype=jnp.int32)

    def cond(state):
        j, _cnt, _rimat, active = state
        return (j < max_probes) & jnp.any(active)

    def body(state):
        j, cnt, rimat, active = state
        slot = ((ph1 + j.astype(jnp.uint32) * step)
                & jnp.uint32(slots - 1)).astype(jnp.int32)
        brow = jnp.take(trow, slot, axis=0)
        occ = brow >= 0
        match = active & occ & (ph2 == jnp.take(th2, slot, axis=0))
        for lane in range(n_lanes):
            match &= pkeys[:, lane] == jnp.take(tkeys[:, lane], slot, axis=0)
        rimat = jnp.where(match[:, None] & (cnt[:, None] == ords[None, :]),
                          brow[:, None], rimat)
        return j + 1, cnt + match.astype(jnp.int32), rimat, active & occ

    state = (jnp.int32(0), jnp.zeros((block_n,), jnp.int32),
             jnp.full((block_n, max_matches), -1, jnp.int32), active0)
    _, cnt, rimat, active = jax.lax.while_loop(cond, body, state)
    cnt_ref[...] = cnt
    rimat_ref[...] = rimat
    exh_ref[...] = active.astype(jnp.int32)


def probe_pallas(table_row: jnp.ndarray, slot_h2: jnp.ndarray,
                 slot_keys: jnp.ndarray, ph1: jnp.ndarray,
                 ph2: jnp.ndarray, pkeys_u32: jnp.ndarray,
                 pvalid: jnp.ndarray, max_matches: int = 1,
                 max_probes: int = 64, *, block_n: int = 1024,
                 interpret: bool = False):
    """table_row (S,) i32, slot_h2 (S,) u32, slot_keys (S, L) u32,
    ph1/ph2 (N,) u32, pkeys_u32 (N, L) u32, pvalid (N,) bool →
    ``(cnt (N,) int32, rimat (N, max_matches) int32, exhausted (N,)
    bool)``."""
    n = ph1.shape[0]
    slots = table_row.shape[0]
    n_lanes = slot_keys.shape[1]
    n_pad = -(-n // block_n) * block_n
    s_pad = max(128, slots)  # lane-width floor; padded slots are never probed
    trow = jnp.pad(table_row, (0, s_pad - slots), constant_values=-1)
    th2 = jnp.pad(slot_h2, (0, s_pad - slots))
    tkeys = jnp.pad(slot_keys, ((0, s_pad - slots), (0, 0)))
    h1 = jnp.pad(ph1, (0, n_pad - n))
    h2 = jnp.pad(ph2, (0, n_pad - n))
    pk = jnp.pad(pkeys_u32, ((0, n_pad - n), (0, 0)))
    val = jnp.pad(pvalid.astype(jnp.int32), (0, n_pad - n))

    row_spec = pl.BlockSpec((block_n,), lambda i: (i,))
    cnt, rimat, exh = pl.pallas_call(
        functools.partial(_kernel, slots=slots, max_probes=max_probes,
                          max_matches=max_matches, n_lanes=n_lanes),
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((s_pad,), lambda i: (0,)),
            pl.BlockSpec((s_pad,), lambda i: (0,)),
            pl.BlockSpec((s_pad, n_lanes), lambda i: (0, 0)),
            row_spec,
            row_spec,
            pl.BlockSpec((block_n, n_lanes), lambda i: (i, 0)),
            row_spec,
        ],
        out_specs=[row_spec,
                   pl.BlockSpec((block_n, max_matches), lambda i: (i, 0)),
                   row_spec],
        out_shape=[jax.ShapeDtypeStruct((n_pad,), jnp.int32),
                   jax.ShapeDtypeStruct((n_pad, max_matches), jnp.int32),
                   jax.ShapeDtypeStruct((n_pad,), jnp.int32)],
        interpret=interpret,
    )(trow, th2, tkeys, h1, h2, pk, val)
    return cnt[:n], rimat[:n], exh[:n].astype(bool)
