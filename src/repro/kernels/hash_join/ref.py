"""Pure-jnp oracle for the sort-free hash-join engine (DESIGN.md §8).

Build/probe over a double-hash open-addressing slot table, seeded by the
``(h1, h2)`` row hashes the exchange already carries (§3.3) — zero rehash.
The probe sequence of a row is ``slot_j = (h1 + j * (h2 | 1)) & (slots-1)``
(odd step over a power-of-two table → full cycle), identical for
bitwise-equal keys since their hashes are equal.

Two build flavours share that sequence:

  * :func:`build_table` — the JOIN table: every valid build row claims its
    OWN slot, so duplicate keys occupy successive reachable slots of the
    shared sequence.  The open-addressing invariant (a row placed at probe
    index ``j`` saw positions ``0..j-1`` occupied, and slots are never
    vacated) means a probe walk that stops at the first EMPTY slot has
    visited every equal-key build row.
  * :func:`build_table_unique` — the GROUPBY/SET-OP table: bitwise-equal
    keys SHARE one slot, claimed by the lowest row index (scatter-min),
    and every row learns its slot.  Dedup keeps claimants; membership
    probes for the representative.

:func:`probe` / :func:`emit_lookup` are the counted two-pass scheme with
a single fused walk: the probe pass counts matches per probe row AND
records the first ``max_matches`` build rows in registers, the caller
exclusive-scans the emit widths into packed output offsets, and the emit
lookup maps every packed output slot back to its ``(probe row, match
ordinal)`` by binary search over the scan and one register gather — the
output is born compacted (no post-hoc compaction, no sort).  Matching
never trusts hash equality: candidates compare their actual key lanes
(``core.exchange.key_compare_u32`` — the same bitwise identity the hash
uses).  All loops are early-exit ``while_loop``s; rows that exhaust
``max_probes`` are surfaced to the caller, which counts them under the §2
overflow contract.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

_BIG = 2**31 - 1  # empty-slot sentinel during construction (scatter-min)


def _probe_slots(h1: jnp.ndarray, step: jnp.ndarray, j: jnp.ndarray,
                 slots: int) -> jnp.ndarray:
    """j-th probe slot of each row; ``j`` is scalar or per-row int32."""
    return ((h1 + j.astype(jnp.uint32) * step)
            & jnp.uint32(slots - 1)).astype(jnp.int32)


def _take_first(eligible: jnp.ndarray, m: int) -> Tuple[jnp.ndarray,
                                                        jnp.ndarray]:
    """Row indices of the first ``m`` eligible rows (scatter-free).

    XLA CPU scatters cost per UPDATE (tens of ns each), gathers are
    vectorized — so the retry rounds below never scatter full-width
    arrays.  This selection is a cumsum plus a binary search over it
    (searchsorted: gathers only); returns ``(indices (m,) int32 clipped
    in-range, ok (m,) bool)``.
    """
    n = eligible.shape[0]
    cs = jnp.cumsum(eligible.astype(jnp.int32))
    k = jnp.arange(1, m + 1, dtype=jnp.int32)
    ok = k <= cs[n - 1]
    pos = jnp.searchsorted(cs, k, side="left").astype(jnp.int32)
    return jnp.clip(pos, 0, n - 1), ok


def build_table(h1: jnp.ndarray, h2: jnp.ndarray, valid: jnp.ndarray,
                slots: int, max_probes: int = 64
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Insert every valid row into its own slot (the join build table).

    Round 0 scatter-mins every valid row at its first probe slot — the
    one unavoidable full-width scatter.  The (few) rows that lost a
    contended slot then retry in compacted batches of ``~n/8``: each
    retry round selects the lowest-index still-unplaced rows
    (:func:`_take_first`), attempts their next FREE slot, and advances the
    losers — so retry scatters are an order of magnitude narrower than
    the table.  A row only moves past a slot it saw occupied, which is
    what makes the first-empty-slot probe termination sound.  Rows still
    unplaced after ``max_probes`` probes (or when the retry budget is
    exhausted — adversarial duplicate floods) are missing from the table;
    the caller must count them as overflow.

    Returns ``(table_row (slots,) int32 with -1 = empty, n_unplaced)``.
    """
    n = h1.shape[0]
    step = h2 | jnp.uint32(1)
    rows = jnp.arange(n, dtype=jnp.int32)
    big = jnp.int32(_BIG)
    m = min(n, max(256, n // 8))
    outer_cap = n // m + 2  # each batch retires all its rows

    table = jnp.full((slots,), big, jnp.int32)
    slot0 = _probe_slots(h1, step, jnp.int32(0), slots)
    table = table.at[jnp.where(valid, slot0, slots)].min(rows, mode="drop")
    pending = valid & (table[slot0] != rows)

    def outer_cond(state):
        it, _table, pending, _failed = state
        return (it < outer_cap) & jnp.any(pending)

    def outer_body(state):
        it, table, pending, failed = state
        si, ok = _take_first(pending, m)
        sh1, sstep = h1[si], step[si]

        def inner_cond(s):
            _jm, _table, alive, _placed = s
            return jnp.any(alive)

        def inner_body(s):
            jm, table, alive, placed = s
            slot = _probe_slots(sh1, sstep, jm, slots)
            att = alive & (table[slot] == big)
            table = table.at[jnp.where(att, slot, slots)].min(
                si, mode="drop")
            won = att & (table[slot] == si)
            placed |= won
            jm = jm + (alive & ~won).astype(jnp.int32)
            return jm, table, alive & ~won & (jm < max_probes), placed

        inner = (jnp.ones((m,), jnp.int32), table, ok,
                 jnp.zeros((m,), bool))
        _, table, _, placed = jax.lax.while_loop(inner_cond, inner_body,
                                                 inner)
        failed = failed + jnp.sum(ok & ~placed, dtype=jnp.int32)
        pending = pending.at[jnp.where(ok, si, n)].set(False, mode="drop")
        return it + 1, table, pending, failed

    state = (jnp.int32(0), table, pending, jnp.int32(0))
    _, table, pending, failed = jax.lax.while_loop(outer_cond, outer_body,
                                                   state)
    # rows still pending here only if the outer budget ran out
    failed = failed + jnp.sum(pending, dtype=jnp.int32)
    return jnp.where(table == big, -1, table), failed


def build_table_unique(h1: jnp.ndarray, h2: jnp.ndarray,
                       keys_u32: jnp.ndarray, valid: jnp.ndarray,
                       slots: int, max_probes: int = 64
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One slot per distinct key, claimed by the lowest row index.

    Round 0 scatter-mins every valid row at its first probe slot, then
    resolves in bulk: a row joins a slot only after comparing its ACTUAL
    key lanes against the claimant — hash equality is never trusted.
    Bitwise-equal keys share the probe sequence, so the overwhelming
    majority resolve against their representative immediately; the
    leftovers (slot collisions between distinct keys) retry in compacted
    ``~n/8`` batches exactly like :func:`build_table`, keeping every
    retry scatter narrow.  Rows unresolved after ``max_probes`` probes or
    the retry budget (key cardinality far beyond the slot head-room) are
    the caller's overflow count.

    Returns ``(owner (slots,) int32 claimant row or -1 = empty,
    seg (n,) int32 slot of each resolved row with ``slots`` as the
    unresolved sentinel, unresolved (n,) bool)``.
    """
    n = h1.shape[0]
    step = h2 | jnp.uint32(1)
    rows = jnp.arange(n, dtype=jnp.int32)
    big = jnp.int32(_BIG)
    m = min(n, max(256, n // 8))
    outer_cap = n // m + 2  # each batch retires all its rows

    owner = jnp.full((slots,), big, jnp.int32)
    slot0 = _probe_slots(h1, step, jnp.int32(0), slots)
    owner = owner.at[jnp.where(valid, slot0, slots)].min(rows, mode="drop")
    own0 = owner[slot0]
    same0 = valid & (own0 < big)
    safe0 = jnp.where(same0, own0, 0)
    same0 &= jnp.all(keys_u32 == keys_u32[safe0], axis=1)
    seg = jnp.where(same0, slot0, slots)
    pending = valid & ~same0
    unresolved = pending

    def outer_cond(state):
        it, _owner, _seg, pending, _unresolved = state
        return (it < outer_cap) & jnp.any(pending)

    def outer_body(state):
        it, owner, seg, pending, unresolved = state
        si, ok = _take_first(pending, m)
        sh1, sstep, skeys = h1[si], step[si], keys_u32[si]

        def inner_cond(s):
            _jm, _owner, alive, _segm, _res = s
            return jnp.any(alive)

        def inner_body(s):
            jm, owner, alive, segm, resolved = s
            slot = _probe_slots(sh1, sstep, jm, slots)
            free = owner[slot] == big
            owner = owner.at[jnp.where(alive & free, slot, slots)].min(
                si, mode="drop")
            own = owner[slot]
            same = alive & (own < big)
            safe = jnp.where(same, own, 0)
            same &= jnp.all(skeys == keys_u32[safe], axis=1)
            segm = jnp.where(same, slot, segm)
            resolved |= same
            jm = jm + (alive & ~same).astype(jnp.int32)
            return jm, owner, alive & ~same & (jm < max_probes), segm, \
                resolved

        inner = (jnp.ones((m,), jnp.int32), owner, ok,
                 jnp.full((m,), slots, jnp.int32), jnp.zeros((m,), bool))
        _, owner, _, segm, resolved = jax.lax.while_loop(
            inner_cond, inner_body, inner)
        seg = seg.at[jnp.where(ok & resolved, si, n)].set(segm, mode="drop")
        unresolved = unresolved.at[jnp.where(ok & resolved, si, n)].set(
            False, mode="drop")
        pending = pending.at[jnp.where(ok, si, n)].set(False, mode="drop")
        return it + 1, owner, seg, pending, unresolved

    state = (jnp.int32(0), owner, seg, pending, unresolved)
    _, owner, seg, _, unresolved = jax.lax.while_loop(outer_cond, outer_body,
                                                      state)
    return jnp.where(owner == big, -1, owner), seg, unresolved


def slot_payload(table_row: jnp.ndarray, bh2: jnp.ndarray,
                 bkeys_u32: jnp.ndarray
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Slot-indexed verification payload: ``(h2, key lanes)`` per slot.

    One batched gather per array at table-construction time, so the probe
    loops touch only slot-indexed lanes (never the build table's packed
    payload columns — those are late-materialized by the caller).
    """
    occ = table_row >= 0
    safe = jnp.where(occ, table_row, 0)
    slot_h2 = jnp.where(occ, bh2[safe], 0)
    slot_keys = jnp.where(occ[:, None], bkeys_u32[safe],
                          jnp.zeros_like(bkeys_u32[safe]))
    return slot_h2, slot_keys


def probe(table_row: jnp.ndarray, slot_h2: jnp.ndarray,
          slot_keys: jnp.ndarray, ph1: jnp.ndarray, ph2: jnp.ndarray,
          pkeys_u32: jnp.ndarray, pvalid: jnp.ndarray,
          max_matches: int = 1, max_probes: int = 64
          ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """The fused probe pass: match counts + the first-match registers.

    Each probe row walks its sequence once, until the first empty slot
    (which, by the build invariant, proves no further equal-key build
    rows exist); candidates verify by ``h2`` plus the actual key lanes.
    The walk simultaneously counts every match and records the first
    ``max_matches`` build rows in an ``(n, max_matches)`` register matrix
    — matches order by build-row index, since insertion order is row
    order.  One walk serves both halves of the counted two-pass scheme;
    :func:`emit_lookup` turns the registers into packed output pairs.

    Returns ``(cnt (n,) int32, rimat (n, max_matches) int32 with -1 =
    empty register, exhausted (n,) bool)`` — exhausted rows hit
    ``max_probes`` while still on an occupied chain, so their count is a
    lower bound and the caller surfaces them as overflow.
    """
    slots = table_row.shape[0]
    n = ph1.shape[0]
    step = ph2 | jnp.uint32(1)
    ords = jnp.arange(max_matches, dtype=jnp.int32)

    def cond(state):
        j, _cnt, _rimat, active = state
        return (j < max_probes) & jnp.any(active)

    def body(state):
        j, cnt, rimat, active = state
        slot = _probe_slots(ph1, step, j, slots)
        brow = table_row[slot]
        occ = brow >= 0
        match = active & occ & (ph2 == slot_h2[slot])
        match &= jnp.all(pkeys_u32 == slot_keys[slot], axis=1)
        rimat = jnp.where(match[:, None] & (cnt[:, None] == ords[None, :]),
                          brow[:, None], rimat)
        return j + 1, cnt + match.astype(jnp.int32), rimat, active & occ

    state = (jnp.int32(0), jnp.zeros((n,), jnp.int32),
             jnp.full((n, max_matches), -1, jnp.int32), pvalid)
    _, cnt, rimat, active = jax.lax.while_loop(cond, body, state)
    return cnt, rimat, active


def emit_lookup(rimat: jnp.ndarray, base: jnp.ndarray, emit_n: jnp.ndarray,
                total: jnp.ndarray, out_capacity: int
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Turn probe registers into packed ``(probe_row, build_row)`` pairs.

    Output slot ``p`` belongs to probe row ``i`` with ``base[i] <= p <
    base[i] + emit_n[i]`` (``base``/``emit_n`` are the exclusive scan and
    widths of the per-row emit counts), recovered by a binary search over
    the scan — searchsorted, not a sort — and its pair is one register
    gather: the output is born compacted, scatter-free.  An output slot
    owed to an unmatched keep-all row (``emit_n = 1`` with zero matches)
    reads an empty register and keeps ``ri = -1`` — exactly the
    left/outer unmatched row.

    Returns ``(li, ri)`` int32 index pairs, ``-1`` for an absent side;
    slots at or past ``total`` are ``(-1, -1)`` padding.
    """
    n, max_matches = rimat.shape
    p = jnp.arange(out_capacity, dtype=jnp.int32)
    ends = (base + emit_n).astype(jnp.int32)
    i = jnp.clip(jnp.searchsorted(ends, p, side="right").astype(jnp.int32),
                 0, n - 1)
    valid_p = p < total
    k_target = jnp.clip(p - base[i], 0, max_matches - 1)
    ri = jnp.where(valid_p, rimat[i, k_target], -1)
    return jnp.where(valid_p, i, -1), ri
