"""Pure-jnp oracle for the blocked segmented windowed scan (DESIGN.md §9).

The windowed-aggregation hot loop: for every row ``i`` of a table sorted by
``(partition, order)`` keys, reduce the rows of the same partition inside a
trailing row-count window,

    out[i] = op( values[a .. i] ),   a = max(i - window + 1, seg_start[i]),

for ``op`` in sum/min/max — all sum-combining lanes ride ONE call with the
values stacked as ``(n, L)`` lanes, exactly like ``segment_reduce_fused``.
``seg_start[i]`` is the row index where ``i``'s segment (partition) begins;
segments are contiguous because the table is sorted, so no per-row hash or
grouping structure is needed.

The algorithm is the classic two-scan sliding-window decomposition, made
segment-aware:

  1. rows are split into chunks of exactly ``window`` rows;
  2. a *segmented* inclusive prefix scan runs forward within each chunk and
     a segmented suffix scan runs backward (both reset at segment starts —
     :func:`_chunk_scan`, a Hillis–Steele ladder of ``log2(window)``
     shift-combine steps);
  3. a window ending at ``i`` either lies entirely inside ``i``'s chunk
     (then the prefix at ``i`` IS the answer: the window start can never
     precede the chunk start without leaving the chunk, because chunks are
     window-sized) or it straddles one chunk boundary (then it is the
     disjoint union of a suffix in the previous chunk and the prefix at
     ``i`` — one gather + one combine).

Total work is O(n log window) fully-vectorized ops, zero sorts, zero
scatters.  The Pallas kernel (``kernel.py``) runs the SAME ``_chunk_scan``
helper on its VMEM blocks, so interpret-mode kernel output is bit-identical
to this reference — float summation order and all (tested in
``tests/test_window.py``).

:func:`segmented_cumulative` reuses the scan ladder at chunk size = n for
expanding (cumulative) aggregates; lag/lead/row_number/rank need no kernel
at all (they are gathers off the same segment machinery) and live in
``repro.window``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_IDENTITY = {"sum": 0.0, "min": float("inf"), "max": float("-inf")}


def _combine(op: str, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    if op == "sum":
        return a + b
    if op == "min":
        return jnp.minimum(a, b)
    return jnp.maximum(a, b)


def _chunk_scan(v: jnp.ndarray, f: jnp.ndarray, op: str) -> jnp.ndarray:
    """Segmented inclusive scan along axis 1 of ``v (m, c, L)``.

    ``f (m, c)`` flags rows that START a segment; the scan value at a row
    covers back to the nearest flagged row (or the chunk start).  A
    Hillis–Steele ladder: at offset ``d`` a row whose accumulated span is
    still open combines with the row ``d`` to its left and inherits its
    completion flag.  The combine ORDER is fixed (left operand is always
    the earlier span), so float results are deterministic and shared
    bit-for-bit with the Pallas kernel, which calls this same helper.
    """
    c = v.shape[1]
    ident = jnp.asarray(_IDENTITY[op], v.dtype)
    d = 1
    while d < c:
        sv = jnp.concatenate(
            [jnp.full_like(v[:, :d], ident), v[:, :-d]], axis=1)
        sf = jnp.concatenate(
            [jnp.ones_like(f[:, :d]), f[:, :-d]], axis=1)
        v = jnp.where(f[..., None], v, _combine(op, sv, v))
        f = f | sf
        d *= 2
    return v


def _chunk_suffix(v: jnp.ndarray, new_seg: jnp.ndarray,
                  op: str) -> jnp.ndarray:
    """Segmented suffix scan along axis 1: ``out[j] = op(v[j .. e])`` where
    ``e`` is the last row of ``j``'s segment within the chunk.

    Runs :func:`_chunk_scan` on the reversed chunk; the reversed flags mark
    rows whose successor starts a new segment (= segment ENDS), which are
    exactly the reversed scan's segment starts.
    """
    rf = jnp.concatenate(
        [new_seg[:, 1:], jnp.zeros_like(new_seg[:, :1])], axis=1)
    out = _chunk_scan(v[:, ::-1], rf[:, ::-1], op)
    return out[:, ::-1]


def windowed_scan(values: jnp.ndarray, seg_start: jnp.ndarray, window: int,
                  op: str = "sum") -> jnp.ndarray:
    """values (n, L) f32, seg_start (n,) i32 → (n, L) rolling reductions.

    ``out[i] = op(values[max(i - window + 1, seg_start[i]) .. i])`` — the
    trailing row-count window clipped at the segment start (so a window
    larger than its partition degrades to an expanding aggregate over the
    partition, the SQL ROWS BETWEEN semantics).  ``seg_start[i]`` must
    satisfy ``seg_start[i] <= i`` and be constant within each segment.
    """
    n, lanes = values.shape
    w = int(window)
    n_pad = -(-n // w) * w
    ident = jnp.asarray(_IDENTITY[op], values.dtype)
    vals = jnp.pad(values, ((0, n_pad - n), (0, 0)), constant_values=ident)
    idx = jnp.arange(n_pad, dtype=jnp.int32)
    # padding rows are their own segments: they never contaminate a window
    segs = jnp.concatenate([seg_start.astype(jnp.int32),
                            idx[n:]]) if n_pad > n else seg_start
    new_seg = segs == idx

    m = n_pad // w
    v3 = vals.reshape(m, w, lanes)
    f3 = new_seg.reshape(m, w)
    prefix = _chunk_scan(v3, f3, op).reshape(n_pad, lanes)
    suffix = _chunk_suffix(v3, f3, op).reshape(n_pad, lanes)

    a = jnp.maximum(idx - (w - 1), segs)
    chunk_start = (idx // w) * w
    use_prev = a < chunk_start  # window straddles one chunk boundary
    sval = suffix[jnp.clip(a, 0, n_pad - 1)]
    out = jnp.where(use_prev[:, None], _combine(op, sval, prefix), prefix)
    return out[:n]


def segmented_cumulative(values: jnp.ndarray, seg_start: jnp.ndarray,
                         op: str = "sum") -> jnp.ndarray:
    """values (n, L), seg_start (n,) → expanding (cumulative) reductions.

    ``out[i] = op(values[seg_start[i] .. i])`` — the unbounded-window
    special case, computed as one chunk-sized segmented scan (the same
    ladder the windowed scan uses, at chunk size n).  No Pallas variant:
    the ladder is plain shift-combine XLA code with nothing for a kernel
    to fuse beyond what the compiler already does.
    """
    n = values.shape[0]
    f = (seg_start.astype(jnp.int32) == jnp.arange(n, dtype=jnp.int32))
    return _chunk_scan(values[None], f[None], op)[0]
