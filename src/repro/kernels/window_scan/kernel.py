"""Pallas TPU kernel for the blocked segmented windowed scan (DESIGN.md §9).

Grid layout: the rows are tiled into blocks of ``bc`` window-sized chunks
(``block_n = bc * window`` rows).  Each grid step loads its own block plus
the PREVIOUS block (two BlockSpecs over the same operand, the second with a
clamped ``i-1`` index map) — the only cross-block dependence of the
two-scan window decomposition is the suffix of the chunk immediately before
a row's chunk, and with window-aligned blocks that chunk is either inside
the current block or the last chunk of the previous one.  So one grid step
computes:

  * the segmented prefix scan of its ``bc`` chunks,
  * the segmented suffix scan of the ``bc`` chunks shifted one to the left
    (previous block's last chunk + own chunks 0..bc-2),
  * the per-row combine ``prefix ⊕ suffix[window start]`` via one VMEM
    gather —

all with the SAME ``_chunk_scan`` helper as the jnp reference, so
interpret-mode output is bit-identical to ``ref.windowed_scan`` (the oracle
IS the semantics, as with every kernel in this tree).  There is no
revisiting of output blocks and no scratch: the kernel is one read of two
input blocks and one write.

VMEM: 2 value blocks + suffix source + prefix ≈ ``4 * block_n * lanes *
4B`` — at the default ~512-row blocks this is KBs, far under budget; wide
windows raise ``block_n`` to one chunk (``bc = 1``), which ``ops.py`` caps
before dispatching here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import _IDENTITY, _chunk_scan, _chunk_suffix, _combine


def _kernel(vals_ref, pvals_ref, seg_ref, pseg_ref, out_ref, *, w: int,
            bc: int, op: str, block_n: int):
    pid = pl.program_id(0)
    base = pid * block_n
    cur = vals_ref[...]                      # (block_n, L)
    prev = pvals_ref[...]                    # previous block (clamped at 0)
    segs = seg_ref[...]                      # (block_n,) i32
    psegs = pseg_ref[...]
    lanes = cur.shape[1]

    idx = base + jax.lax.broadcasted_iota(jnp.int32, (block_n, 1), 0)[:, 0]
    f_cur = segs == idx
    f_prev = psegs == (idx - block_n)        # garbage at pid=0: never used

    v3 = cur.reshape(bc, w, lanes)
    f3 = f_cur.reshape(bc, w)
    prefix = _chunk_scan(v3, f3, op).reshape(block_n, lanes)

    # suffix of each row's PREVIOUS chunk: previous block's last chunk
    # followed by this block's chunks 0..bc-2
    sv = jnp.concatenate([prev[block_n - w:], cur[:block_n - w]], axis=0)
    sf = jnp.concatenate([f_prev[block_n - w:], f_cur[:block_n - w]], axis=0)
    suffix = _chunk_suffix(sv.reshape(bc, w, lanes),
                           sf.reshape(bc, w), op).reshape(block_n, lanes)

    a = jnp.maximum(idx - (w - 1), segs)
    chunk_start = (idx // w) * w
    use_prev = a < chunk_start
    local_chunk = (idx - base) // w
    spos = local_chunk * w + (a % w)         # a lives in chunk-1 ⇒ its
    sval = jnp.take(suffix, spos, axis=0)    # offset there is a mod w
    out_ref[...] = jnp.where(use_prev[:, None],
                             _combine(op, sval, prefix), prefix)


def windowed_scan_pallas(values: jnp.ndarray, seg_start: jnp.ndarray,
                         window: int, op: str = "sum", *,
                         target_block: int = 512,
                         interpret: bool = False) -> jnp.ndarray:
    """values (n, L) f32, seg_start (n,) i32 → (n, L); see ref.windowed_scan."""
    n, lanes = values.shape
    w = int(window)
    bc = max(1, target_block // w)
    block_n = bc * w
    n_pad = -(-n // block_n) * block_n
    ident = _IDENTITY[op]
    vals = jnp.pad(values.astype(jnp.float32), ((0, n_pad - n), (0, 0)),
                   constant_values=ident)
    idx = jnp.arange(n_pad, dtype=jnp.int32)
    segs = jnp.concatenate([seg_start.astype(jnp.int32), idx[n:]]) \
        if n_pad > n else seg_start.astype(jnp.int32)

    row_spec = pl.BlockSpec((block_n, lanes), lambda i: (i, 0))
    prev_spec = pl.BlockSpec((block_n, lanes),
                             lambda i: (jnp.maximum(i - 1, 0), 0))
    seg_spec = pl.BlockSpec((block_n,), lambda i: (i,))
    pseg_spec = pl.BlockSpec((block_n,), lambda i: (jnp.maximum(i - 1, 0),))
    out = pl.pallas_call(
        functools.partial(_kernel, w=w, bc=bc, op=op, block_n=block_n),
        grid=(n_pad // block_n,),
        in_specs=[row_spec, prev_spec, seg_spec, pseg_spec],
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((n_pad, lanes), jnp.float32),
        interpret=interpret,
    )(vals, vals, segs, segs)
    return out[:n]
