"""Public entry points for the windowed-scan engine (DESIGN.md §9).

Dispatch mirrors ``segment_reduce/ops.py``: the compiled Pallas kernel on
TPU, the pure-jnp reference elsewhere (itself fast XLA code);
``force="pallas"`` runs the kernel in interpret mode for testing and must
match the reference bit-for-bit (shared chunk-scan helper).  The expanding
(cumulative) scan has no Pallas variant — it is one chunk-sized ladder of
shift-combines with nothing extra for a kernel to fuse — and always takes
the reference path.

``windowed_scan`` accepts ``(n,)`` or ``(n, L)`` values; all sum-combining
window lanes of one operator call ride a single ``(n, L)`` invocation
(count and both halves of mean derive from it), min/max reduce per column —
the same lane-fusion contract as the groupby segment reduction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref as _ref

segmented_cumulative = _ref.segmented_cumulative

#: Windows wider than this skip the Pallas kernel: a block is at least one
#: window-sized chunk, and a multi-thousand-row chunk ladder stops fitting
#: comfortably in VMEM next to its halo block.
_PALLAS_MAX_WINDOW = 4096

_OPS = ("sum", "min", "max")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnums=(2, 3, 4),
                   static_argnames=("op", "force"))
def windowed_scan(values: jnp.ndarray, seg_start: jnp.ndarray, window: int,
                  op: str = "sum", force: str | None = None) -> jnp.ndarray:
    """Rolling segment-clipped reduction; see ``ref.windowed_scan``.

    ``out[i] = op(values[max(i - window + 1, seg_start[i]) .. i])``.
    """
    if op not in _OPS:
        raise ValueError(f"unknown windowed_scan op {op!r}; expected "
                         f"one of {_OPS}")
    squeeze = values.ndim == 1
    v = values[:, None] if squeeze else values
    v = v.astype(jnp.float32)
    if force == "pallas" or (force is None and _on_tpu()
                             and window <= _PALLAS_MAX_WINDOW):
        out = _kernel.windowed_scan_pallas(v, seg_start, window, op,
                                           interpret=not _on_tpu())
    else:
        out = _ref.windowed_scan(v, seg_start, window, op)
    return out[:, 0] if squeeze else out
