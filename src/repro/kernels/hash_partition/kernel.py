"""Pallas TPU hash-partition kernel (shuffle hot loop, paper Fig 2).

Fuses, per row-block: (a) the multi-column murmur-style hash chain,
(b) destination-shard assignment ``h % P``, and (c) the per-destination
histogram — one HBM read of the key block instead of three.  The histogram
uses a one-hot VPU reduction with the histogram block revisited across the
row grid (accumulation), so the row dimension is the innermost grid axis.

With ``return_hashes`` the kernel also emits the full ``(h1, h2)`` row
hashes so the shuffle engine can carry them through the exchange
(DESIGN.md §3.3) — join and set-op kernels then never rehash post-shuffle.

The hash chain must match ``repro.core.table.hash_columns`` bit-for-bit —
the pure-jnp oracle in ``ref.py`` *is* that function.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

_H1_INIT = np.uint32(0x9E3779B9)
_H2_INIT = np.uint32(0x85EBCA6B)
_MUL1 = np.uint32(0xCC9E2D51)
_MUL2 = np.uint32(0x1B873593)
_K2_XOR = np.uint32(0xDEADBEEF)


def _mix(h, k, mul):
    k = k * mul
    k = (k << 15) | (k >> 17)
    h = h ^ k
    h = (h << 13) | (h >> 19)
    return h * np.uint32(5) + np.uint32(0xE6546B64)


def _kernel(keys_ref, valid_ref, *out_refs, n_parts: int, sentinel: int,
            n_cols: int, with_hashes: bool):
    if with_hashes:
        dest_ref, h1_ref, h2_ref, hist_ref = out_refs
    else:
        dest_ref, hist_ref = out_refs
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    block_n = dest_ref.shape[0]
    h1 = jnp.full((block_n,), _H1_INIT, jnp.uint32)
    h2 = jnp.full((block_n,), _H2_INIT, jnp.uint32)
    for c in range(n_cols):
        k = keys_ref[:, c]
        h1 = _mix(h1, k, _MUL1)
        if with_hashes:
            h2 = _mix(h2, k ^ _K2_XOR, _MUL2)
    h1 = h1 ^ (h1 >> 16)

    dest = (h1 % np.uint32(n_parts)).astype(jnp.int32)
    dest = jnp.where(valid_ref[...] != 0, dest, sentinel)
    dest_ref[...] = dest
    if with_hashes:
        h1_ref[...] = h1
        h2_ref[...] = h2 ^ (h2 >> 16)

    p_pad = hist_ref.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (p_pad, block_n), 0)
    onehot = rows == dest[None, :]
    hist_ref[...] += jnp.sum(onehot.astype(jnp.int32), axis=1)


def hash_partition_pallas(keys_u32: jnp.ndarray, valid: jnp.ndarray,
                          n_parts: int, *, block_n: int = 1024,
                          interpret: bool = False,
                          return_hashes: bool = False):
    """keys_u32 (N, K) uint32, valid (N,) int32 → (dest (N,), hist (P,))
    plus ``(h1 (N,), h2 (N,))`` uint32 when ``return_hashes``."""
    n, k = keys_u32.shape
    n_pad = -(-n // block_n) * block_n
    p_pad = max(8, -(-n_parts // 128) * 128)
    keys = jnp.pad(keys_u32, ((0, n_pad - n), (0, 0)))
    val = jnp.pad(valid.astype(jnp.int32), (0, n_pad - n))

    row_spec = pl.BlockSpec((block_n,), lambda i: (i,))
    row_shape = jax.ShapeDtypeStruct((n_pad,), jnp.int32)
    out_specs = [row_spec]
    out_shape = [row_shape]
    if return_hashes:
        out_specs += [row_spec, row_spec]
        out_shape += [jax.ShapeDtypeStruct((n_pad,), jnp.uint32)] * 2
    out_specs.append(pl.BlockSpec((p_pad,), lambda i: (0,)))
    out_shape.append(jax.ShapeDtypeStruct((p_pad,), jnp.int32))

    outs = pl.pallas_call(
        functools.partial(_kernel, n_parts=n_parts, sentinel=p_pad,
                          n_cols=k, with_hashes=return_hashes),
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(keys, val)
    dest, hist = outs[0], outs[-1]
    # sentinel rows → n_parts (match ref convention)
    d = jnp.where(dest[:n] == p_pad, n_parts, dest[:n])
    if return_hashes:
        return d, hist[:n_parts], outs[1][:n], outs[2][:n]
    return d, hist[:n_parts]
