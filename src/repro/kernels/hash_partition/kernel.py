"""Pallas TPU hash-partition kernel (shuffle hot loop, paper Fig 2).

Fuses, per row-block: (a) the multi-column murmur-style hash chain,
(b) destination-shard assignment ``h % P``, and (c) the per-destination
histogram — one HBM read of the key block instead of three.  The histogram
uses a one-hot VPU reduction with the histogram block revisited across the
row grid (accumulation), so the row dimension is the innermost grid axis.

The hash chain must match ``repro.core.table.hash_columns`` bit-for-bit —
the pure-jnp oracle in ``ref.py`` *is* that function.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

_H1_INIT = np.uint32(0x9E3779B9)
_MUL1 = np.uint32(0xCC9E2D51)


def _mix(h, k, mul):
    k = k * mul
    k = (k << 15) | (k >> 17)
    h = h ^ k
    h = (h << 13) | (h >> 19)
    return h * np.uint32(5) + np.uint32(0xE6546B64)


def _kernel(keys_ref, valid_ref, dest_ref, hist_ref, *, n_parts: int,
            sentinel: int, n_cols: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    block_n = dest_ref.shape[0]
    h1 = jnp.full((block_n,), _H1_INIT, jnp.uint32)
    for c in range(n_cols):
        h1 = _mix(h1, keys_ref[:, c], _MUL1)
    h1 = h1 ^ (h1 >> 16)

    dest = (h1 % np.uint32(n_parts)).astype(jnp.int32)
    dest = jnp.where(valid_ref[...] != 0, dest, sentinel)
    dest_ref[...] = dest

    p_pad = hist_ref.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (p_pad, block_n), 0)
    onehot = rows == dest[None, :]
    hist_ref[...] += jnp.sum(onehot.astype(jnp.int32), axis=1)


def hash_partition_pallas(keys_u32: jnp.ndarray, valid: jnp.ndarray,
                          n_parts: int, *, block_n: int = 1024,
                          interpret: bool = False):
    """keys_u32 (N, K) uint32, valid (N,) int32 → (dest (N,), hist (P,))."""
    n, k = keys_u32.shape
    n_pad = -(-n // block_n) * block_n
    p_pad = max(8, -(-n_parts // 128) * 128)
    keys = jnp.pad(keys_u32, ((0, n_pad - n), (0, 0)))
    val = jnp.pad(valid.astype(jnp.int32), (0, n_pad - n))

    dest, hist = pl.pallas_call(
        functools.partial(_kernel, n_parts=n_parts, sentinel=p_pad,
                          n_cols=k),
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((p_pad,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad,), jnp.int32),
            jax.ShapeDtypeStruct((p_pad,), jnp.int32),
        ],
        interpret=interpret,
    )(keys, val)
    # sentinel rows → n_parts (match ref convention)
    d = jnp.where(dest[:n] == p_pad, n_parts, dest[:n])
    return d, hist[:n_parts]
