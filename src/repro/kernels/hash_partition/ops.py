"""Public entry point for hash-partitioning (shuffle destination compute).

Dispatch mirrors ``segment_reduce/ops.py``: compiled Pallas kernel on TPU,
pure-jnp reference elsewhere.  ``force`` overrides for testing ("pallas"
uses interpret mode off-TPU).  This is the single hash site of the shuffle
engine (``core/exchange.py``): with ``return_hashes`` the fused kernel also
hands back ``(h1, h2)`` so the exchange can carry them and downstream
operators never rehash.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.table import _as_u32

from . import kernel as _kernel
from . import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def hash_partition(key_cols: Sequence[jnp.ndarray], n_parts: int,
                   valid: jnp.ndarray, force: str | None = None,
                   return_hashes: bool = False):
    """Row destinations + histogram (+ row hashes when ``return_hashes``).

    Pallas on TPU, jnp oracle elsewhere.  Returns ``(dest, hist)`` or
    ``(dest, hist, h1, h2)``.
    """
    if force == "pallas" or (force is None and _on_tpu()):
        keys = jnp.stack([_as_u32(c) for c in key_cols], axis=1)
        return _kernel.hash_partition_pallas(
            keys, valid, n_parts, interpret=not _on_tpu(),
            return_hashes=return_hashes)
    if return_hashes:
        return _ref.hash_partition_full(key_cols, n_parts, valid)
    return _ref.hash_partition(key_cols, n_parts, valid)
