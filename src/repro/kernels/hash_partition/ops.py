"""Public entry point for hash-partitioning (shuffle destination compute)."""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.table import _as_u32

from . import kernel as _kernel
from . import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def hash_partition(key_cols: Sequence[jnp.ndarray], n_parts: int,
                   valid: jnp.ndarray, force: str | None = None,
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Row destinations + histogram; Pallas on TPU, jnp oracle elsewhere."""
    if force == "pallas" or (force is None and _on_tpu()):
        keys = jnp.stack([_as_u32(c) for c in key_cols], axis=1)
        return _kernel.hash_partition_pallas(
            keys, valid, n_parts, interpret=not _on_tpu())
    return _ref.hash_partition(key_cols, n_parts, valid)
