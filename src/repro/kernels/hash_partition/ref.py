"""Pure-jnp oracle for the shuffle hash-partition (paper Fig 2 hot loop)."""
from __future__ import annotations

from typing import Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.table import hash_columns


def hash_partition_full(key_cols: Sequence[jnp.ndarray], n_parts: int,
                        valid: jnp.ndarray
                        ) -> Tuple[jnp.ndarray, jnp.ndarray,
                                   jnp.ndarray, jnp.ndarray]:
    """Row → destination partition, histogram, and the row hashes.

    Returns (dest (N,) int32 with invalid rows = n_parts,
             hist (n_parts,) int32 over valid rows,
             h1 (N,) uint32, h2 (N,) uint32).
    """
    h1, h2 = hash_columns(list(key_cols))
    dest = (h1 % np.uint32(n_parts)).astype(jnp.int32)
    dest = jnp.where(valid, dest, n_parts)
    hist = jnp.zeros(n_parts + 1, jnp.int32).at[
        jnp.clip(dest, 0, n_parts)].add(1)[:n_parts]
    return dest, hist, h1, h2


def hash_partition(key_cols: Sequence[jnp.ndarray], n_parts: int,
                   valid: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Row → destination partition + per-partition histogram.

    Returns (dest (N,) int32 with invalid rows = n_parts,
             hist (n_parts,) int32 over valid rows).
    """
    dest, hist, _, _ = hash_partition_full(key_cols, n_parts, valid)
    return dest, hist
