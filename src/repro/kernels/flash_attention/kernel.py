"""Pallas TPU flash-attention kernel (forward).

Online-softmax tiling (Flash-Attention 2 schedule) adapted to the TPU memory
hierarchy: q/k/v tiles stream HBM→VMEM under BlockSpec control; the two
matmuls per tile run on the MXU with fp32 accumulation; running max / sum /
accumulator live in VMEM scratch that persists across the (innermost)
key-block grid dimension.

Layout: heads are folded into the leading grid axis.  GQA never
materializes repeated KV heads — the kv BlockSpec index-maps query head
``h`` onto kv head ``h // group``, so each kv tile is fetched once per
query-head group.

Block sizes: (block_q=128, block_k=128) aligns both matmul contractions to
the 128×128 MXU; with D=128 the VMEM working set is
q(64KB) + k(64KB) + v(64KB) + acc(64KB) + O(1) vectors ≈ 0.3 MB.

Masking is done on absolute positions: ``q_offset`` places the query block
inside a longer KV context (decode), ``kv_len`` masks right-padding,
``window`` gives Mistral-style sliding-window attention.  Causal masking
also *skips* key blocks strictly above the diagonal (they are revisits of
the output block, so skipping is just an early-exit ``pl.when``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            sm_scale: float, block_q: int, block_k: int, causal: bool,
            window: Optional[int], kv_len: int, q_offset: int,
            n_kblocks: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # absolute positions of this tile
    q_pos0 = q_offset + qi * block_q
    k_pos0 = kj * block_k

    # causal early-exit: whole key block above the diagonal
    block_needed = True
    if causal:
        block_needed = k_pos0 <= q_pos0 + block_q - 1
    if window is not None:
        # skip only if the newest key is outside the *oldest* query's window
        block_needed = jnp.logical_and(
            block_needed,
            q_pos0 - (k_pos0 + block_k - 1) < window)

    @pl.when(block_needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale   # (bq, bk)

        rows = q_pos0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = k_pos0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        allow = cols < kv_len
        if causal:
            allow &= cols <= rows
        if window is not None:
            allow &= (rows - cols) < window
        s = jnp.where(allow, s, _NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(allow, p, 0.0)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv

    @pl.when(kj == n_kblocks - 1)
    def _finish():
        l = l_ref[...]
        out = acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
        out = jnp.where((l > 0)[:, None], out, 0.0)
        o_ref[0] = out.astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, window: Optional[int] = None,
                           kv_len: Optional[int] = None, q_offset: int = 0,
                           sm_scale: Optional[float] = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jnp.ndarray:
    """q (B, Hq, Sq, D); k, v (B, Hkv, Sk, D) → (B, Hq, Sq, D)."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    if sm_scale is None:
        sm_scale = d ** -0.5
    if kv_len is None:
        kv_len = sk

    block_q = min(block_q, max(sq, 8))
    block_k = min(block_k, max(sk, 8))
    sq_pad = -(-sq // block_q) * block_q
    sk_pad = -(-sk // block_k) * block_k

    qr = jnp.pad(q.reshape(b * hq, sq, d), ((0, 0), (0, sq_pad - sq), (0, 0)))
    kr = jnp.pad(k.reshape(b * hkv, sk, d), ((0, 0), (0, sk_pad - sk), (0, 0)))
    vr = jnp.pad(v.reshape(b * hkv, sk, d), ((0, 0), (0, sk_pad - sk), (0, 0)))

    n_kblocks = sk_pad // block_k
    grid = (b * hq, sq_pad // block_q, n_kblocks)

    def kv_index(bh, qi, kj):
        return ((bh // hq) * hkv + (bh % hq) // group, kj, 0)

    out = pl.pallas_call(
        functools.partial(
            _kernel, sm_scale=sm_scale, block_q=block_q, block_k=block_k,
            causal=causal, window=window, kv_len=kv_len, q_offset=q_offset,
            n_kblocks=n_kblocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, d), kv_index),
            pl.BlockSpec((1, block_k, d), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out[:, :sq].reshape(b, hq, sq, d)
