"""Public flash-attention entry point.

Dispatch policy (see DESIGN.md): the Pallas kernel is the **TPU target**;
on CPU (this container) the pure-jnp reference executes — it is the same
math and is what the dry-run lowers for roofline analysis.  Tests force the
Pallas path in interpret mode and assert allclose against the reference.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    kv_len: Optional[int] = None, q_offset: int = 0,
                    sm_scale: Optional[float] = None,
                    force: str | None = None) -> jnp.ndarray:
    if force == "pallas" or (force is None and _on_tpu()):
        return _kernel.flash_attention_pallas(
            q, k, v, causal=causal, window=window, kv_len=kv_len,
            q_offset=q_offset, sm_scale=sm_scale, interpret=not _on_tpu())
    return _ref.flash_attention(
        q, k, v, causal=causal, window=window, kv_len=kv_len,
        q_offset=q_offset, sm_scale=sm_scale)
