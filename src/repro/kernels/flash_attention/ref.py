"""Pure-jnp oracle for flash attention (masked softmax attention).

Semantics (must match kernel exactly):
  * GQA: ``Hq = G * Hkv``; query head ``h`` attends kv head ``h // G``.
  * ``kv_len``: keys at positions >= kv_len are padding (masked out).
  * ``causal``: query at absolute position ``q_offset + i`` sees keys
    ``<= q_offset + i`` (``q_offset`` supports decode, where a single query
    sits at the end of a long cache).
  * ``window``: sliding-window attention — key j visible iff
    ``q_pos - j < window`` (Mistral-style).
Fully-masked rows return zeros.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    kv_len: Optional[int] = None, q_offset: int = 0,
                    sm_scale: Optional[float] = None) -> jnp.ndarray:
    """q (B, Hq, Sq, D); k, v (B, Hkv, Sk, D) → (B, Hq, Sq, D)."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    if sm_scale is None:
        sm_scale = d ** -0.5
    qf = q.astype(jnp.float32).reshape(b, hkv, g, sq, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf) * sm_scale

    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    allow = jnp.ones((sq, sk), bool)
    if kv_len is not None:
        allow &= k_pos < kv_len
    if causal:
        allow &= k_pos <= q_pos
    if window is not None:
        allow &= (q_pos - k_pos) < window
    s = jnp.where(allow[None, None, None], s, -jnp.inf)

    m = jnp.max(s, axis=-1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)  # fully-masked rows
    p = jnp.exp(s - m)
    p = jnp.where(allow[None, None, None], p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf) / jnp.maximum(l, 1e-30)
    o = jnp.where(l > 0, o, 0.0)
    return o.reshape(b, hq, sq, d).astype(q.dtype)
