"""Checkpoint/restart with atomic commits and elastic re-sharding.

Implements the paper's fault-tolerance prescription (§VII-F): recovery
happens *outside* operator code — the trainer periodically snapshots, and on
restart (possibly with a different mesh: elastic scale-up/down or a failed
pod removed) the checkpoint is re-laid-out onto the new sharding at load
time via ``device_put`` with the target ``NamedSharding``.

Layout: ``<dir>/step_<n>/`` with one ``.npy`` per leaf + ``manifest.json``;
a ``LATEST`` file is written last (atomic rename) so a crash mid-save never
corrupts the recovery point.  Saves can run on a background thread.

Integrity (DESIGN.md §13.5): each manifest leaf records a CRC32 of the
host bytes at save time; ``restore`` re-hashes what it read and raises
:class:`CheckpointIntegrityError` on bit-rot, dtype drift (manifest vs
template — no more silent casting), or shape mismatch.
"""
from __future__ import annotations

import concurrent.futures
import json
import os
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np


class CheckpointIntegrityError(ValueError):
    """A checkpoint leaf failed validation against its manifest (bad CRC,
    dtype drift, or shape mismatch).  Subclasses ``ValueError`` so callers
    written against the old shape-check contract keep working."""


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for path, _ in flat:
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.GetAttrKey):
                parts.append(p.name)
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        names.append("__".join(parts) or "leaf")
    return names, [v for _, v in flat], treedef


class CheckpointManager:
    def __init__(self, directory: str, async_save: bool = False):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        self._pool = (concurrent.futures.ThreadPoolExecutor(max_workers=1)
                      if async_save else None)
        self._pending: Optional[concurrent.futures.Future] = None
        self._lock = threading.Lock()

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any) -> None:
        names, leaves, _ = _leaf_paths(tree)
        host_leaves = [np.asarray(x) for x in leaves]  # pull off device now
        if self._pool is not None:
            self.wait()
            self._pending = self._pool.submit(
                self._write, step, names, host_leaves)
        else:
            self._write(step, names, host_leaves)

    def _write(self, step: int, names, host_leaves) -> None:
        with self._lock:
            final = os.path.join(self.directory, f"step_{step}")
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "leaves": []}
            for name, arr in zip(names, host_leaves):
                fname = f"{name}.npy"
                np.save(os.path.join(tmp, fname), arr)
                manifest["leaves"].append(
                    {"name": name, "file": fname,
                     "shape": list(arr.shape), "dtype": str(arr.dtype),
                     "crc32": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)                    # atomic commit
            with open(os.path.join(self.directory, "LATEST.tmp"), "w") as f:
                f.write(str(step))
            os.replace(os.path.join(self.directory, "LATEST.tmp"),
                       os.path.join(self.directory, "LATEST"))

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    # -- restore ---------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.directory, "LATEST")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            return int(f.read().strip())

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Any:
        """Load a checkpoint into ``template``'s structure.

        ``shardings`` (a matching pytree of NamedShardings, or None) lets
        the same checkpoint restore onto a *different* mesh — the elastic
        path: save on 256 chips, resume on 512 or 128.
        """
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.directory}")
        d = os.path.join(self.directory, f"step_{step}")
        names, leaves, treedef = _leaf_paths(template)
        shard_leaves = (treedef.flatten_up_to(shardings)
                        if shardings is not None else [None] * len(leaves))
        meta = {}
        mpath = os.path.join(d, "manifest.json")
        if os.path.exists(mpath):  # pre-§13.5 checkpoints lack one
            with open(mpath) as f:
                meta = {e["name"]: e for e in json.load(f)["leaves"]}
        out = []
        for name, tmpl, shd in zip(names, leaves, shard_leaves):
            arr = np.load(os.path.join(d, f"{name}.npy"))
            if tuple(arr.shape) != tuple(tmpl.shape):
                raise CheckpointIntegrityError(
                    f"checkpoint leaf {name}: shape {arr.shape} != "
                    f"template {tmpl.shape}")
            entry = meta.get(name)
            if entry is not None:
                want = np.dtype(tmpl.dtype)
                if np.dtype(entry["dtype"]) != want:
                    raise CheckpointIntegrityError(
                        f"checkpoint leaf {name}: saved dtype "
                        f"{entry['dtype']} != template {want}; refusing to "
                        f"silently cast — resave or fix the template")
                crc = entry.get("crc32")
                if crc is not None:
                    got = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
                    if got != crc:
                        raise CheckpointIntegrityError(
                            f"checkpoint leaf {name}: CRC mismatch "
                            f"(manifest {crc:#010x}, file {got:#010x}) — "
                            f"{os.path.join(d, name + '.npy')} is corrupt")
            if shd is not None:
                out.append(jax.device_put(arr, shd))
            else:
                out.append(jax.numpy.asarray(arr, dtype=tmpl.dtype))
        return treedef.unflatten(out)
