"""Out-of-core spillable operators: bounded-memory join / groupby / window.

This is the recovery path DESIGN.md §2's overflow contract points at: when
an operator's planned static capacity cannot hold its input, the engine
hash-partitions the rows into on-disk ``.hpt`` runs (``store.py``), then
streams **partition-pairs** through the exact same in-memory kernels —
each pair sized to a caller-committed ``budget_rows`` per shard — and
leaves the outputs on disk as a chunk stream.  Nothing is approximated:
every partition is processed by the identical ``table_ops`` code the
all-in-memory path runs, so spilled results are bit-exact against the
in-memory oracle (property-tested in ``tests/test_spill.py``).

Partition truthfulness is the load-bearing invariant.  The host-side
partitioner (``hashing.py``) computes bit-identical hashes to the device
``hash_columns``, assigns ``shard = h1 % n_shards`` (exactly the shuffle
destination rule) and ``partition = (h1 // n_shards) % n_parts``, and the
run files carry ``(_h1, _h2)`` across the disk boundary.  A re-ingested
partition therefore re-enters with ``(keys, n_shards)`` hash metadata —
or, for windows, a host-sorted block layout with range metadata — that is
*true*, so the PR 2 / PR 5 elision paths fire and the per-pair operator
adds **zero** AllToAll (and zero sorts, for windows) to the trace;
jaxpr-asserted in the tests.

Skew handling: a partition whose per-shard row count exceeds the budget
is refined once by re-splitting on the independent ``h2`` (no rehash —
the runs carry it).  A partition that still exceeds the budget after
refinement is dominated by duplicates of a single key, which no
partitioner can split; it is processed in one piece at an enlarged
capacity (still exact) and counted in ``SpillStats.oversized``.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import telemetry
from repro.core import table_ops
from repro.core.context import HPTMTContext
from repro.core.exchange import H1_NAME, H2_NAME, LANES_NAME
from repro.core.report import OverflowReport
from repro.core.table import DistTable, Table, range_partitioning

from .hashing import np_hash_columns, np_lex_order, np_order_lanes
from .store import SpillStore

HostChunk = Tuple[Dict[str, np.ndarray], int]

#: head-room multiplier on the minimum partition count, absorbing hash skew
_PART_HEADROOM = 2
#: partition count when the source size is unknown (generator sources);
#: the h2 refinement pass repairs any underestimate, so this is only a
#: granularity default, never a correctness knob
_DEFAULT_PARTS = 32


def plan_partitions(total_rows: Optional[int], n_shards: int,
                    budget_rows: int) -> int:
    """Number of spill partitions so a partition-pair fits the budget."""
    if budget_rows < 1:
        raise ValueError(f"budget_rows={budget_rows} must be >= 1")
    if total_rows is None:
        return _DEFAULT_PARTS
    return max(1, math.ceil(total_rows / (n_shards * budget_rows))
               * _PART_HEADROOM)


def should_spill(total_rows: int, n_shards: int,
                 budget_rows: Optional[int]) -> bool:
    """The trigger decision: does the input exceed the planned capacity?"""
    return budget_rows is not None and total_rows > n_shards * budget_rows


# ===========================================================================
# host-side chunk ingestion
# ===========================================================================
def iter_host_chunks(src) -> Iterator[HostChunk]:
    """Normalize a spill source into host ``(columns, num_rows)`` chunks.

    Accepts a :class:`DistTable` (one chunk per shard), an iterable of
    DistTables (e.g. ``ScanSource.chunks()``), or an iterable of already-
    host ``(dict, n)`` tuples.  Only valid rows are yielded; padding never
    touches disk.
    """
    if isinstance(src, DistTable):
        src = [src]
    for item in src:
        if isinstance(item, DistTable):
            for i in range(item.n_shards):
                t = item.shard_table(i)
                n = int(t.num_rows)
                yield ({k: np.asarray(v[:n]) for k, v in t.columns.items()},
                       n)
        else:
            cols, n = item
            yield ({k: np.asarray(v)[:n] for k, v in cols.items()}, int(n))


def _total_rows_or_none(*srcs) -> Optional[int]:
    """Source size without consuming it, or None for generator sources."""
    total = 0
    for s in srcs:
        if isinstance(s, DistTable):
            total += int(s.num_rows())
        elif isinstance(s, (list, tuple)):
            for item in s:
                if isinstance(item, DistTable):
                    total += int(item.num_rows())
                else:
                    total += int(item[1])
        else:
            return None
    return total


def _schema_of(cols: Dict[str, np.ndarray]) -> Dict[str, Tuple]:
    return {k: (np.dtype(v.dtype), tuple(v.shape[1:]))
            for k, v in cols.items()}


# ===========================================================================
# partition pass
# ===========================================================================
def _write_buckets(store: SpillStore, tag: str, cols: Dict[str, np.ndarray],
                   q: np.ndarray, s: np.ndarray, order: np.ndarray) -> None:
    """Write contiguous ``(q, s)`` groups of the permuted chunk as runs."""
    if len(order) == 0:
        return
    with telemetry.span("spill.write", tag=tag, rows=len(order),
                        bytes=sum(int(v.nbytes) for v in cols.values())):
        qs = q[order]
        ss = s[order]
        boundary = np.nonzero((qs[1:] != qs[:-1]) | (ss[1:] != ss[:-1]))[0] + 1
        starts = np.concatenate([[0], boundary])
        stops = np.concatenate([boundary, [len(order)]])
        for a, b in zip(starts, stops):
            rows = order[a:b]
            store.write_run(tag, int(qs[a]), int(ss[a]),
                            {k: v[rows] for k, v in cols.items()}, int(b - a))


def _partition_hash(store: SpillStore, tag: str, src, keys: Sequence[str],
                    n_shards: int, n_parts: int
                    ) -> Tuple[int, Dict[str, Tuple]]:
    """Hash-partition a source into ``(q, s)`` runs carrying ``(h1, h2)``.

    ``s = h1 % n_shards`` is the shuffle destination rule; ``q`` consumes
    the next hash bits, so re-ingesting partition ``q`` shard-by-shard
    reproduces exactly the layout a real shuffle would have produced.
    """
    total, schema = 0, None
    for cols, n in iter_host_chunks(src):
        if schema is None:
            schema = _schema_of(cols)
        if n == 0:
            continue
        h1, h2 = np_hash_columns([cols[k] for k in keys])
        s = (h1 % np.uint32(n_shards)).astype(np.int64)
        q = ((h1 // np.uint32(n_shards)) % np.uint32(n_parts)).astype(np.int64)
        cols = dict(cols)
        cols[H1_NAME], cols[H2_NAME] = h1, h2
        _write_buckets(store, tag, cols, q, s, np.lexsort((s, q)))
        total += n
    if schema is None:
        raise ValueError(f"spill source {tag!r} yielded no chunks")
    return total, schema


def _canonical_nan(col: np.ndarray) -> np.ndarray:
    """Collapse every NaN payload to one bit pattern before hashing.

    Window partition identity is the *ordering* identity (all NaNs form
    one partition, DESIGN.md §9); the hash is bitwise, so differing NaN
    payloads must not scatter one window partition across spill
    partitions.
    """
    if np.issubdtype(col.dtype, np.floating):
        nan = np.isnan(col)
        if nan.any():
            col = np.where(nan, np.asarray(np.nan, col.dtype), col)
    return col


def _partition_window(store: SpillStore, tag: str, src,
                      pkeys: Sequence[str], keys: Sequence[str],
                      ascending: Sequence[bool], n_parts: int
                      ) -> Tuple[int, Dict[str, Tuple]]:
    """Partition by window-partition keys, carrying the order lanes.

    Rows of one window partition must never straddle spill partitions, so
    ``q`` hashes the PARTITION BY keys only; the full directional lanes
    (``pkeys + okeys``) ride along in the run files so re-ingestion sorts
    on the host with one ``lexsort`` and no recomputation.
    """
    total, schema = 0, None
    for cols, n in iter_host_chunks(src):
        if schema is None:
            schema = _schema_of(cols)
        if n == 0:
            continue
        h1, h2 = np_hash_columns([_canonical_nan(cols[k]) for k in pkeys])
        q = (h1 % np.uint32(n_parts)).astype(np.int64)
        cols = dict(cols)
        cols[H1_NAME], cols[H2_NAME] = h1, h2
        cols[LANES_NAME] = np_order_lanes(cols, keys, ascending)
        s = np.zeros(n, np.int64)
        _write_buckets(store, tag, cols, q, s, np.argsort(q, kind="stable"))
        total += n
    if schema is None:
        raise ValueError(f"spill source {tag!r} yielded no chunks")
    return total, schema


# ===========================================================================
# skew refinement
# ===========================================================================
def _refine_oversized(store: SpillStore, tags: Sequence[str],
                      n_shards: int, budget_rows: int, n_parts: int,
                      per_shard: bool) -> Tuple[List[int], int, int]:
    """Split partitions whose load exceeds the budget.

    One refinement level re-buckets on the carried ``h2`` (independent of
    the ``h1`` bits already consumed) — the same child mapping on every
    operand, so join pairs stay aligned.  Returns the final partition
    ids, the count refined, and the count left oversized (single-key
    skew: unsplittable, processed whole at an enlarged capacity).
    """
    def load(q: int) -> int:
        if per_shard:
            return max((store.rows(t, q, s)
                        for t in tags for s in range(n_shards)), default=0)
        return max((store.rows(t, q) for t in tags), default=0)

    pending = sorted({q for t in tags for q in store.partitions(t)})
    pending = [(q, 0) for q in pending]
    final: List[int] = []
    next_q = n_parts
    refined = oversized = 0
    while pending:
        q, level = pending.pop()
        size = load(q)
        if size <= budget_rows:
            final.append(q)
            continue
        if level >= 1:
            final.append(q)
            oversized += 1
            continue
        fanout = max(2, math.ceil(size / budget_rows) * _PART_HEADROOM)
        base = next_q
        next_q += fanout
        refined += 1
        for t in tags:
            for s in store.shards(t, q):
                for cols, n in store.iter_runs(t, q, s):
                    child = base + (cols[H2_NAME] % np.uint32(fanout)
                                    ).astype(np.int64)
                    sq = np.full(n, s, np.int64)
                    _write_buckets(store, t, cols, child, sq,
                                   np.argsort(child, kind="stable"))
            store.drop_partition(t, q)
        pending.extend((base + j, 1) for j in range(fanout))
    return sorted(set(final)), refined, oversized


# ===========================================================================
# partition loading / output writing
# ===========================================================================
def _empty_cols(schema: Dict[str, Tuple]) -> Dict[str, np.ndarray]:
    return {k: np.zeros((0,) + tuple(tr), dt)
            for k, (dt, tr) in schema.items()}


def _round_capacity(rows: int, budget_rows: int) -> int:
    """Pad capacities to budget multiples so jit traces are reused."""
    return budget_rows * max(1, math.ceil(rows / budget_rows))


def _load_hash_partition(store: SpillStore, tag: str, q: int,
                         schema: Dict[str, Tuple], keys: Sequence[str],
                         ctx: HPTMTContext, capacity: int) -> DistTable:
    """Re-ingest one partition with TRUE hash-partitioning metadata."""
    with telemetry.span("spill.read", tag=tag, partition=q) as sp:
        tables = []
        total = 0
        for s in range(ctx.n_shards):
            cols, n = store.read_partition(tag, q, s)
            total += n
            if n == 0:
                cols = _empty_cols(schema)
            cols.pop(H1_NAME, None)
            cols.pop(H2_NAME, None)
            tables.append(Table.from_arrays(
                {k: jnp.asarray(v) for k, v in cols.items()},
                num_rows=n, capacity=capacity))
        sp.attrs["rows"] = total
        return DistTable.from_shard_tables(
            tables, ctx, partitioning=(tuple(keys), ctx.n_shards))


def _load_range_partition(store: SpillStore, tag: str, q: int,
                          schema: Dict[str, Tuple], keys: Sequence[str],
                          ascending: Sequence[bool], ctx: HPTMTContext,
                          capacity: int) -> DistTable:
    """Re-ingest one window partition with TRUE range metadata.

    The whole partition is lex-sorted by its carried lanes on the host
    and block-sliced into contiguous per-shard chunks — exactly the
    layout the sample-sort exchange would have produced, so the per-pair
    window runs its zero-AllToAll / zero-sort elided path.
    """
    with telemetry.span("spill.read", tag=tag, partition=q) as sp:
        cols, n = store.read_partition(tag, q)
        sp.attrs["rows"] = n
        if n == 0:
            cols = dict(_empty_cols(schema))
            cols[LANES_NAME] = np.zeros((0, len(keys)), np.uint32)
        order = np_lex_order(cols[LANES_NAME])
        cols = {k: v[order] for k, v in cols.items()
                if k not in (H1_NAME, H2_NAME, LANES_NAME)}
        per = max(1, math.ceil(n / ctx.n_shards))
        tables = []
        for s in range(ctx.n_shards):
            a, b = min(s * per, n), min((s + 1) * per, n)
            tables.append(Table.from_arrays(
                {k: jnp.asarray(v[a:b]) for k, v in cols.items()},
                num_rows=b - a, capacity=capacity))
        return DistTable.from_shard_tables(
            tables, ctx,
            partitioning=range_partitioning(keys, ascending, ctx.n_shards))


def _write_output(store: SpillStore, q: int, dt: DistTable) -> int:
    """Persist a pair result shard-by-shard; returns rows written."""
    total = 0
    for s in range(dt.n_shards):
        t = dt.shard_table(s)
        n = int(t.num_rows)
        if n == 0:
            continue
        store.write_run("out", q, s,
                        {k: np.asarray(v[:n]) for k, v in t.columns.items()},
                        n)
        total += n
    return total


def _out_schema_of(dt: DistTable) -> Dict[str, Tuple]:
    return {k: (np.dtype(v.dtype), tuple(v.shape[1:]))
            for k, v in dt.shard_table(0).columns.items()}


# ===========================================================================
# results
# ===========================================================================
@dataclasses.dataclass
class SpillStats:
    """What the engine did — partitions, refinement, disk traffic."""
    n_parts: int = 0
    pairs: int = 0
    refined: int = 0
    oversized: int = 0
    rows_in: int = 0
    rows_out: int = 0
    bytes_spilled: int = 0


class SpillResult:
    """A completed spilled operator: an on-disk chunk stream + report.

    The output lives in the spill store until consumed; :meth:`chunks`
    streams it partition-by-partition as DistTables with partitioning
    metadata attached (so downstream operators keep eliding), deleting
    each partition's runs after they are yielded.  :meth:`collect`
    materializes everything (tests / small outputs); :meth:`to_tset`
    hands the stream to the dataflow layer for chunk-wise merging.
    """

    def __init__(self, store: SpillStore, ctx: HPTMTContext,
                 partitioning, report: OverflowReport, stats: SpillStats,
                 out_schema: Dict[str, Tuple]):
        self._store = store
        self._ctx = ctx
        self._partitioning = partitioning
        self.report = report
        self.stats = stats
        self._out_schema = out_schema

    @property
    def store(self) -> SpillStore:
        return self._store

    @property
    def partitioning(self):
        return self._partitioning

    def chunks(self, *, drop: bool = True) -> Iterator[DistTable]:
        """Stream output partitions as metadata-carrying DistTables."""
        for q in self._store.partitions("out"):
            cap = max(max((self._store.rows("out", q, s)
                           for s in range(self._ctx.n_shards)), default=0), 1)
            tables = []
            for s in range(self._ctx.n_shards):
                cols, n = self._store.read_partition("out", q, s)
                if n == 0:
                    cols = _empty_cols(self._out_schema)
                tables.append(Table.from_arrays(
                    {k: jnp.asarray(v) for k, v in cols.items()},
                    num_rows=n, capacity=cap))
            yield DistTable.from_shard_tables(
                tables, self._ctx, partitioning=self._partitioning)
            if drop:
                self._store.drop_partition("out", q)

    def empty_chunk(self) -> DistTable:
        """A zero-row DistTable with the output schema and partitioning —
        the stand-in result when no partition produced rows (e.g. an
        inner join with no matches)."""
        cols = _empty_cols(self._out_schema)
        tables = [Table.from_arrays(
            {k: jnp.asarray(v) for k, v in cols.items()},
            num_rows=0, capacity=1) for _ in range(self._ctx.n_shards)]
        return DistTable.from_shard_tables(
            tables, self._ctx, partitioning=self._partitioning)

    def collect(self) -> Dict[str, np.ndarray]:
        """Materialize the whole output on the host (closes the store)."""
        pieces = [c.to_numpy() for c in self.chunks()]
        self.close()
        if not pieces:
            return _empty_cols(self._out_schema)
        return {k: np.concatenate([p[k] for p in pieces], axis=0)
                for k in pieces[0]}

    def to_tset(self):
        """Materialize the chunk stream into a TSet source whose
        materializations carry this spill's report (closes the store)."""
        from repro.core.dataflow import TSet

        ts = TSet.from_spill(self)
        self.close()
        return ts

    def close(self) -> None:
        self._store.close()

    def __enter__(self) -> "SpillResult":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ===========================================================================
# spilled operators
# ===========================================================================
def spill_join(left, right, keys: Sequence[str], *, ctx: HPTMTContext,
               budget_rows: int, how: str = "inner", method: str = "auto",
               max_matches: int = 1, max_probes: Optional[int] = None,
               workdir: Optional[str] = None,
               report: Optional[OverflowReport] = None,
               policy=None) -> SpillResult:
    """Out-of-core equi-join under a per-shard ``budget_rows`` memory cap.

    Both operands are hash-partitioned to disk on ``keys``; each
    partition-pair re-enters with true ``(keys, n_shards)`` metadata and
    joins with BOTH shuffles elided.  Fan-out beyond ``max_matches`` is
    still counted (it is a semantic cap, not a memory one) under
    ``"join.fanout"`` in the report.
    """
    report = report if report is not None else OverflowReport()
    keys = tuple(keys)
    store = SpillStore(workdir, policy=policy)
    try:
        n_parts = plan_partitions(_total_rows_or_none(left, right),
                                  ctx.n_shards, budget_rows)
        ln, lschema = _partition_hash(store, "left", left, keys,
                                      ctx.n_shards, n_parts)
        rn, rschema = _partition_hash(store, "right", right, keys,
                                      ctx.n_shards, n_parts)
        parts, refined, oversized = _refine_oversized(
            store, ("left", "right"), ctx.n_shards, budget_rows, n_parts,
            per_shard=True)
        stats = SpillStats(n_parts=n_parts, refined=refined,
                           oversized=oversized, rows_in=ln + rn)

        def run(ldt, rdt):
            return table_ops.join(ldt, rdt, keys, ctx=ctx, how=how,
                                  method=method, max_matches=max_matches,
                                  max_probes=max_probes)

        pair_fn = jax.jit(run)
        out_schema = None
        for q in parts:
            lrows = max((store.rows("left", q, s)
                         for s in range(ctx.n_shards)), default=0)
            rrows = max((store.rows("right", q, s)
                         for s in range(ctx.n_shards)), default=0)
            skip = ((lrows == 0 and how not in ("right", "outer"))
                    or (rrows == 0 and how == "inner")
                    or (rrows == 0 and lrows == 0))
            if skip:
                store.drop_partition("left", q)
                store.drop_partition("right", q)
                continue
            lcap = _round_capacity(max(lrows, 1), budget_rows)
            rcap = _round_capacity(max(rrows, 1), budget_rows)
            ldt = _load_hash_partition(store, "left", q, lschema, keys,
                                       ctx, lcap)
            rdt = _load_hash_partition(store, "right", q, rschema, keys,
                                       ctx, rcap)
            with telemetry.span("spill.reentry", op="table.join",
                                partition=q) as sp:
                out, ov = pair_fn(ldt, rdt)
                sp.block(out)
            report.add("join.fanout", ov)
            if out_schema is None:
                out_schema = _out_schema_of(out)
            stats.rows_out += _write_output(store, q, out)
            stats.pairs += 1
            store.drop_partition("left", q)
            store.drop_partition("right", q)
        report.add_recovered("spill.join", ln + rn)
        if out_schema is None:
            out_schema = _join_schema(lschema, rschema, keys)
        return _finish(store, ctx, (keys, ctx.n_shards), report, stats,
                       out_schema)
    except BaseException:
        store.close()
        raise


def spill_groupby(src, keys: Sequence[str],
                  aggs: Sequence[Tuple[str, str]], *, ctx: HPTMTContext,
                  budget_rows: int, workdir: Optional[str] = None,
                  report: Optional[OverflowReport] = None,
                  policy=None) -> SpillResult:
    """Out-of-core groupby-aggregate under a per-shard memory budget.

    Each key lives in exactly one spill partition, so per-partition
    grouping is exact with no cross-partition merge step.
    """
    report = report if report is not None else OverflowReport()
    keys = tuple(keys)
    store = SpillStore(workdir, policy=policy)
    try:
        n_parts = plan_partitions(_total_rows_or_none(src), ctx.n_shards,
                                  budget_rows)
        n, schema = _partition_hash(store, "in", src, keys, ctx.n_shards,
                                    n_parts)
        parts, refined, oversized = _refine_oversized(
            store, ("in",), ctx.n_shards, budget_rows, n_parts,
            per_shard=True)
        stats = SpillStats(n_parts=n_parts, refined=refined,
                           oversized=oversized, rows_in=n)

        def run(dt):
            return table_ops.groupby_aggregate(dt, keys, tuple(aggs),
                                               ctx=ctx)

        pair_fn = jax.jit(run)
        out_schema = None
        for q in parts:
            rows = max((store.rows("in", q, s)
                        for s in range(ctx.n_shards)), default=0)
            if rows == 0:
                store.drop_partition("in", q)
                continue
            cap = _round_capacity(rows, budget_rows)
            dt = _load_hash_partition(store, "in", q, schema, keys, ctx, cap)
            with telemetry.span("spill.reentry", op="table.groupby",
                                partition=q) as sp:
                out, ov = pair_fn(dt)
                sp.block(out)
            report.add("groupby.slots", ov)
            if out_schema is None:
                out_schema = _out_schema_of(out)
            stats.rows_out += _write_output(store, q, out)
            stats.pairs += 1
            store.drop_partition("in", q)
        report.add_recovered("spill.groupby", n)
        if out_schema is None:
            out_schema = _groupby_schema(schema, keys, aggs)
        return _finish(store, ctx, (keys, ctx.n_shards), report, stats,
                       out_schema)
    except BaseException:
        store.close()
        raise


def spill_window(src, partition_by, order_by, aggs, *, ctx: HPTMTContext,
                 budget_rows: int, rows: Optional[int] = None,
                 ascending=True, workdir: Optional[str] = None,
                 report: Optional[OverflowReport] = None,
                 policy=None) -> SpillResult:
    """Out-of-core windowed aggregation under a per-shard memory budget.

    Partitions hash the PARTITION BY keys only (one window partition
    never straddles spill partitions); each re-ingested partition is
    host-sorted by its carried lanes, block-sliced, and evaluated on the
    range-elided window path — zero AllToAll, zero sort primitives.
    """
    report = report if report is not None else OverflowReport()
    pkeys = (partition_by,) if isinstance(partition_by, str) \
        else tuple(partition_by)
    store = SpillStore(workdir, policy=policy)
    try:
        it = iter_host_chunks(src)
        try:
            first = next(it)
        except StopIteration:
            raise ValueError("spill source yielded no chunks") from None
        colnames = tuple(sorted(first[0]))
        okeys, asc_o = table_ops._normalize_order(order_by, ascending,
                                                  colnames, "order_by")
        keys = pkeys + okeys
        asc = (True,) * len(pkeys) + asc_o
        n_parts = plan_partitions(_total_rows_or_none(src), ctx.n_shards,
                                  budget_rows)
        n, schema = _partition_window(store, "in",
                                      itertools.chain([first], it),
                                      pkeys, keys, asc, n_parts)
        parts, refined, oversized = _refine_oversized(
            store, ("in",), ctx.n_shards, budget_rows * ctx.n_shards,
            n_parts, per_shard=False)
        stats = SpillStats(n_parts=n_parts, refined=refined,
                           oversized=oversized, rows_in=n)

        def run(dt):
            return table_ops.window_aggregate(dt, pkeys, okeys, aggs,
                                              ctx=ctx, rows=rows,
                                              ascending=asc_o)

        pair_fn = jax.jit(run)
        out_schema = None
        for q in parts:
            qrows = store.rows("in", q)
            if qrows == 0:
                store.drop_partition("in", q)
                continue
            per = max(1, math.ceil(qrows / ctx.n_shards))
            cap = _round_capacity(per, budget_rows)
            dt = _load_range_partition(store, "in", q, schema, keys, asc,
                                       ctx, cap)
            with telemetry.span("spill.reentry", op="table.window",
                                partition=q) as sp:
                out, ov = pair_fn(dt)
                sp.block(out)
            report.add("window.truncated", ov)
            if out_schema is None:
                out_schema = _out_schema_of(out)
            stats.rows_out += _write_output(store, q, out)
            stats.pairs += 1
            store.drop_partition("in", q)
        report.add_recovered("spill.window", n)
        part = range_partitioning(keys, asc, ctx.n_shards)
        if out_schema is None:
            out_schema = dict(schema)
        return _finish(store, ctx, part, report, stats, out_schema)
    except BaseException:
        store.close()
        raise


def _finish(store: SpillStore, ctx, partitioning, report, stats,
            out_schema) -> SpillResult:
    stats.bytes_spilled = store.bytes_written
    rec = telemetry.current()
    if rec is not None:
        rec.metrics.gauge("spill.bytes_spilled", stats.bytes_spilled)
        rec.metrics.gauge("spill.pairs", stats.pairs)
        rec.metrics.gauge("spill.rows_in", stats.rows_in)
        rec.metrics.gauge("spill.rows_out", stats.rows_out)
        telemetry.publish_pressure(rec, "spill")
        rec.record_overflow(report)
    return SpillResult(store, ctx, partitioning, report, stats, out_schema)


# ===========================================================================
# predicted output schemas (fallback when no partition produced rows)
# ===========================================================================
def _join_schema(lschema, rschema, keys) -> Dict[str, Tuple]:
    out = dict(lschema)
    for k, v in rschema.items():
        if k not in keys:
            out[k] = v
    return out


def _groupby_schema(schema, keys, aggs) -> Dict[str, Tuple]:
    out = {k: schema[k] for k in keys}
    for col, op in aggs:
        if op == "count":
            out[f"{col}_count"] = (np.dtype(np.int32), ())
        elif op == "mean":
            out[f"{col}_mean"] = (np.dtype(np.float32), ())
        else:
            out[f"{col}_{op}"] = schema[col]
    return out
