"""On-disk run store for the spill engine (DESIGN.md §10).

A :class:`SpillStore` owns one scratch directory of ``.hpt`` run files.
Runs are keyed by ``(tag, partition, shard)`` — ``tag`` names the operand
("left", "right", "in", "out"), ``partition`` is the spill partition a
row's key hashed to, ``shard`` the mesh shard it will re-enter on — and a
key may accumulate several sequence-numbered files (one per ingested
chunk), since the ``.hpt`` container is write-once.

Durability contract: every run goes through ``io.native.write_hpt``'s
atomic tmp-write + rename, and carries the container's per-column CRC32,
so a reader can never decode a torn run — interrupted writes either leave
a ``*.tmp`` that :meth:`SpillStore.close` / the engine's error path
removes, or raise :class:`~repro.io.native.HptIntegrityError` at read.

Fault injection: every run write passes through the unified chaos
registry (:mod:`repro.resilience.faults`) at site ``"spill.write"``.
The legacy ``HPTMT_SPILL_FAULT`` env knob (``"<point>:<n>"``) keeps its
exact semantics as a back-compat alias: the ``n``-th run write fails —
``disk_full`` raises ``ENOSPC`` before any byte lands; ``partial_write``
tears the tmp file mid-write and then fails, simulating a crash.  Both
surface as the named :class:`SpillWriteError` with the tmp file cleaned
up, and the injector disarms after firing so a retry under the same
environment succeeds — exactly the story the fault tests assert.  A
:class:`~repro.resilience.FaultPolicy` passed to the store retries the
write in place (the run's columns are still in memory) with backoff.
"""
from __future__ import annotations

import os
import shutil
import tempfile
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.io.native import read_hpt, write_hpt
from repro.resilience import faults as _faults
from repro.resilience.policy import RetryBudgetExceeded

FAULT_ENV = _faults.SPILL_FAULT_ENV
FAULT_POINTS = _faults.SPILL_FAULT_POINTS


class SpillError(RuntimeError):
    """Base class for spill-engine failures."""


class SpillWriteError(SpillError):
    """A spill run could not be written (disk full / interrupted write).

    The failed run's temp file has already been cleaned up; retrying the
    operation recomputes the run from its in-memory source.
    """


def reset_fault_injection() -> None:
    """Re-arm the fault injector from the current environment (tests).

    Delegates to the unified registry's :func:`repro.resilience.faults.
    reset` — one-shot "fired" memory is per armed spec there, so a retry
    under an unchanged environment succeeds.
    """
    _faults.reset()


def _check_fault(path: str) -> None:
    """Fire any armed ``spill.write`` fault (once) at this write site."""
    _faults.fire("spill.write", path=path)


class SpillStore:
    """A directory of spill runs with an in-memory index.

    Usable as a context manager; ``close()`` removes the whole scratch
    tree (runs, temp files and all), so no spill artifact outlives the
    operation that created it unless the caller opts into ``keep=True``.
    """

    def __init__(self, workdir: Optional[str] = None, *, keep: bool = False,
                 policy=None):
        if workdir is None:
            self.root = tempfile.mkdtemp(prefix="hptmt-spill-")
            self._owns_root = True
        else:
            os.makedirs(workdir, exist_ok=True)
            self.root = workdir
            self._owns_root = False
        self.keep = keep
        self.policy = policy  # optional FaultPolicy: retry run writes
        # (tag, q, s) -> list of (path, rows)
        self._runs: Dict[Tuple[str, int, int], List[Tuple[str, int]]] = {}
        self._seq = 0
        self.bytes_written = 0
        self.closed = False

    # -- writing -----------------------------------------------------------
    def write_run(self, tag: str, q: int, s: int,
                  cols: Dict[str, np.ndarray], num_rows: int) -> str:
        """Write one run file atomically; returns its path.

        Injected or real OS-level write failures are converted to the
        named :class:`SpillWriteError` after removing the temp file, so a
        failed spill never leaves a half-written run behind.
        """
        path = os.path.join(
            self.root, f"{tag}-q{q:05d}-s{s:03d}-{self._seq:05d}.hpt")
        self._seq += 1

        def attempt():
            _check_fault(path)
            return write_hpt(path, cols, num_rows)

        try:
            if self.policy is not None:
                header = self.policy.run(attempt, site="spill.write")
            else:
                header = attempt()
        except (OSError, RetryBudgetExceeded) as e:
            for leftover in (path + ".tmp", path):
                try:
                    os.remove(leftover)
                except OSError:
                    pass
            raise SpillWriteError(
                f"spill run {os.path.basename(path)} failed to write "
                f"({getattr(e, 'strerror', None) or e}); "
                f"scratch dir {self.root} — free disk "
                f"space or point the spill workdir elsewhere and retry"
            ) from e
        nbytes = sum(n for _, n in header["offsets"].values())
        self.bytes_written += nbytes
        self._runs.setdefault((tag, q, s), []).append((path, int(num_rows)))
        return path

    # -- reading -----------------------------------------------------------
    def partitions(self, tag: str) -> List[int]:
        return sorted({q for (t, q, _s) in self._runs if t == tag})

    def shards(self, tag: str, q: int) -> List[int]:
        return sorted({s for (t, qq, s) in self._runs if t == tag and qq == q})

    def rows(self, tag: str, q: int, s: Optional[int] = None) -> int:
        return sum(n for (t, qq, ss), runs in self._runs.items()
                   if t == tag and qq == q and (s is None or ss == s)
                   for _, n in runs)

    def read_partition(self, tag: str, q: int, s: Optional[int] = None
                       ) -> Tuple[Dict[str, np.ndarray], int]:
        """Concatenate the runs of one partition (optionally one shard)."""
        keys = sorted(k for k in self._runs
                      if k[0] == tag and k[1] == q and (s is None or k[2] == s))
        pieces: List[Dict[str, np.ndarray]] = []
        total = 0
        for key in keys:
            for path, n in self._runs[key]:
                cols, nn = read_hpt(path)
                pieces.append(cols)
                total += nn
        if not pieces:
            return {}, 0
        names = list(pieces[0])
        return {k: np.concatenate([p[k] for p in pieces], axis=0)
                for k in names}, total

    def iter_runs(self, tag: str, q: int, s: Optional[int] = None
                  ) -> Iterator[Tuple[Dict[str, np.ndarray], int]]:
        """Stream one partition's runs file-by-file (bounded memory)."""
        keys = sorted(k for k in self._runs
                      if k[0] == tag and k[1] == q and (s is None or k[2] == s))
        for key in keys:
            for path, _ in self._runs[key]:
                yield read_hpt(path)

    def drop_partition(self, tag: str, q: int) -> None:
        """Delete a partition's runs once consumed (keeps disk bounded)."""
        for key in [k for k in self._runs if k[0] == tag and k[1] == q]:
            for path, _ in self._runs.pop(key):
                try:
                    os.remove(path)
                except OSError:
                    pass

    # -- lifecycle ---------------------------------------------------------
    def leftover_temp_files(self) -> List[str]:
        """Any ``*.tmp`` files in the scratch tree (should always be [])."""
        if not os.path.isdir(self.root):
            return []
        return sorted(p for p in os.listdir(self.root) if p.endswith(".tmp"))

    def close(self) -> None:
        if self.closed:
            return
        self.closed = True
        self._runs.clear()
        if not self.keep and (self._owns_root or os.path.isdir(self.root)):
            shutil.rmtree(self.root, ignore_errors=True)

    def __enter__(self) -> "SpillStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
