"""Out-of-core spill subsystem (DESIGN.md §10).

Turns the §2 overflow contract's *counted loss* into *recovery*: inputs
bigger than the mesh's planned capacity are hash-partitioned into on-disk
``.hpt`` runs and streamed partition-by-partition through the unchanged
in-memory operators under a bounded per-step memory budget — bit-exact
against the all-in-memory oracle, with the run format carrying the row
hashes and order lanes so re-ingested partitions trigger the shuffle- and
sort-elision paths (zero redundant AllToAll on re-entry).

  hashing.py   bit-identical numpy mirrors of the device hash / lanes
  store.py     run-file store, atomic writes, fault injection
  engine.py    spill_join / spill_groupby / spill_window + SpillResult
"""
from .engine import (SpillResult, SpillStats, iter_host_chunks,
                     plan_partitions, should_spill, spill_groupby,
                     spill_join, spill_window)
from .store import (FAULT_ENV, SpillError, SpillStore, SpillWriteError,
                    reset_fault_injection)

__all__ = [
    "SpillResult", "SpillStats", "iter_host_chunks", "plan_partitions",
    "should_spill", "spill_groupby", "spill_join", "spill_window",
    "FAULT_ENV", "SpillError", "SpillStore", "SpillWriteError",
    "reset_fault_injection",
]
