"""Host-side (numpy) mirrors of the device hash / order-lane transforms.

The spill engine partitions rows **on the host**: run files are cut from
numpy buffers without round-tripping through the accelerator.  For the
re-ingested partitions to re-enter the partitioned world truthfully —
``shard = h1 % n_shards`` must hold for every row the engine places on
shard ``s`` — the host partitioner has to compute *bit-identical* hashes
to ``core.table.hash_columns`` and *bit-identical* directional lanes to
``core.exchange.sort_key_lanes``.  These mirrors are property-tested for
exact equality against the jax originals in ``tests/test_spill.py``;
any drift there silently breaks the shuffle-elision contract, so the
constants are imported from the originals rather than re-declared.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.table import _H1_INIT, _H2_INIT, _MUL1, _MUL2


def np_as_u32(col: np.ndarray) -> np.ndarray:
    """Numpy twin of ``core.table._as_u32`` (bit-stable 32-bit view)."""
    col = np.asarray(col)
    if col.dtype == np.bool_:
        return col.astype(np.uint32)
    if np.issubdtype(col.dtype, np.floating):
        return col.astype(np.float32).view(np.uint32)
    return col.astype(np.uint32)


def _np_mix(h: np.ndarray, k: np.ndarray, mul: np.uint32) -> np.ndarray:
    k = k * mul
    k = (k << np.uint32(15)) | (k >> np.uint32(17))
    h = h ^ k
    h = (h << np.uint32(13)) | (h >> np.uint32(19))
    return h * np.uint32(5) + np.uint32(0xE6546B64)


def np_hash_columns(cols: Sequence[np.ndarray]
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Numpy twin of ``core.table.hash_columns`` — bit-identical output."""
    n = np.asarray(cols[0]).shape[0]
    h1 = np.full((n,), _H1_INIT, dtype=np.uint32)
    h2 = np.full((n,), _H2_INIT, dtype=np.uint32)
    with np.errstate(over="ignore"):
        for c in cols:
            k = np_as_u32(c)
            h1 = _np_mix(h1, k, _MUL1)
            h2 = _np_mix(h2, k ^ np.uint32(0xDEADBEEF), _MUL2)
    h1 = h1 ^ (h1 >> np.uint32(16))
    h2 = h2 ^ (h2 >> np.uint32(16))
    return h1, h2


def np_sort_key_lanes(col: np.ndarray, ascending: bool = True) -> np.ndarray:
    """Numpy twin of ``core.exchange.sort_key_lanes`` (NaN-last contract)."""
    col = np.asarray(col)
    if col.dtype.itemsize == 8:
        raise TypeError(
            f"orderby/range-partition key dtype {col.dtype} is 64-bit; "
            f"narrow the column to a 32-bit type first")
    if col.ndim > 1:
        raise TypeError("orderby/range-partition keys must be 1-D columns")
    if np.issubdtype(col.dtype, np.floating):
        f = col.astype(np.float32)
        b = f.view(np.uint32)
        m = np.where(b >> np.uint32(31) != 0, ~b, b | np.uint32(0x80000000))
        nan = np.isnan(f)
    elif col.dtype == np.bool_:
        m = col.astype(np.uint32)
        nan = None
    elif np.issubdtype(col.dtype, np.unsignedinteger):
        m = col.astype(np.uint32)
        nan = None
    else:  # signed integers
        m = col.astype(np.int32).view(np.uint32) ^ np.uint32(0x80000000)
        nan = None
    if not ascending:
        m = ~m
    if nan is not None:
        m = np.where(nan, np.uint32(0xFFFFFFFF), m)
    return m[:, None]


def np_order_lanes(cols: Dict[str, np.ndarray], key_names: Sequence[str],
                   ascending: Sequence[bool]) -> np.ndarray:
    """Numpy twin of ``core.exchange.order_lanes`` (lane 0 most significant)."""
    return np.concatenate(
        [np_sort_key_lanes(cols[k], asc)
         for k, asc in zip(key_names, ascending)], axis=1)


def np_lex_order(lanes: np.ndarray) -> np.ndarray:
    """Stable sort permutation for directional lanes (all rows valid)."""
    keys: List[np.ndarray] = [lanes[:, lane]
                              for lane in range(lanes.shape[1] - 1, -1, -1)]
    return np.lexsort(tuple(keys))
