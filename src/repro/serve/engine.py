"""Serving engine: batched prefill + lockstep decode with typed caches.

Cache kinds per architecture family (DESIGN.md §4): full KV, sliding-window
ring (SWA), MLA latent, Mamba conv+SSM state, xLSTM matrix/scalar state —
all built by ``models.transformer.init_cache`` / prefill and stepped by the
same ``apply_lm``.  The engine decodes all sequences in lockstep (equal
lengths), the standard batched-serving regime the decode shape cells model.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_len: int = 512
    temperature: float = 0.0          # 0 → greedy
    eos_id: int = -1                  # -1 → never stop early


def make_prefill_step(cfg: ModelConfig, cache_len: int):
    def prefill(params, tokens, frontend_embeds=None):
        logits, cache, _ = T.apply_lm(
            params, cfg, tokens, mode="prefill",
            frontend_embeds=frontend_embeds, cache_len=cache_len,
            last_logit_only=True)
        return logits[:, -1], cache

    return prefill


def make_decode_step(cfg: ModelConfig):
    def decode(params, cache, token, pos, rng):
        logits, new_cache, _ = T.apply_lm(
            params, cfg, token, mode="decode", cache=cache,
            positions=jnp.asarray([pos], jnp.int32).reshape(1,))
        nxt = sample(logits[:, -1], rng)
        return nxt, new_cache

    return decode


def sample(logits: jnp.ndarray, rng, temperature: float = 0.0) -> jnp.ndarray:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    return jax.random.categorical(
        rng, logits / temperature, axis=-1).astype(jnp.int32)[:, None]


class Engine:
    """Simple batched generation driver over jitted prefill/decode steps."""

    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig):
        import dataclasses
        self.cfg = dataclasses.replace(cfg, remat=False)  # no grads at serve
        self.params = params
        self.scfg = serve_cfg
        self._prefill = jax.jit(make_prefill_step(self.cfg,
                                                  serve_cfg.max_len))
        self._decode = jax.jit(self._decode_fn)

    def _decode_fn(self, params, cache, token, pos, rng):
        logits, new_cache, _ = T.apply_lm(
            params, self.cfg, token, mode="decode", cache=cache,
            positions=pos.reshape(1,))
        nxt = sample(logits[:, -1], rng, self.scfg.temperature)
        return nxt, new_cache

    def generate(self, prompts: jnp.ndarray, n_tokens: int,
                 frontend_embeds=None, rng=None) -> np.ndarray:
        """prompts (B, S) int32 → generated (B, n_tokens) int32."""
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        b, s = prompts.shape
        prefix = (self.cfg.frontend_seq
                  if self.cfg.frontend == "vision" else 0)
        last_logits, cache = self._prefill(self.params, prompts,
                                           frontend_embeds)
        token = sample(last_logits, rng, self.scfg.temperature)
        out = [np.asarray(token)]
        pos = s + prefix
        for i in range(n_tokens - 1):
            rng, sub = jax.random.split(rng)
            token, cache = self._decode(
                self.params, cache, token, jnp.asarray(pos, jnp.int32), sub)
            out.append(np.asarray(token))
            pos += 1
            if self.scfg.eos_id >= 0 and np.all(out[-1] == self.scfg.eos_id):
                break
        return np.concatenate(out, axis=1)
