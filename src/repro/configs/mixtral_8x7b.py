"""Mixtral 8x7B — MoE 8 experts top-2, sliding-window attention.

[arXiv:2401.04088; hf] 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, window 4096, rope theta 1e6.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000,
    n_experts=8, experts_per_token=2, window=4096, rope_theta=1e6,
)
