"""Whisper medium — encoder-decoder; conv frontend is a STUB.

[arXiv:2212.04356] 24+24L d_model=1024 16H (kv=16) d_ff=4096 vocab=51865.
``input_specs`` supplies precomputed mel-frame embeddings (B, 1500, d).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab_size=51865,
    is_encoder_decoder=True, n_encoder_layers=24,
    frontend="audio", frontend_seq=1500,
)
