"""Model/architecture configuration schema.

One dataclass covers the ten assigned architecture families (dense / MoE /
hybrid SSM / xLSTM / enc-dec audio / VLM).  Every field is static so configs
hash cleanly into jit caches.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                 # 0 → d_model // n_heads

    # --- attention ---------------------------------------------------------
    attention: str = "gqa"          # gqa | mla
    window: Optional[int] = None    # sliding-window size (SWA)
    rope_theta: float = 10_000.0
    # MLA (DeepSeek/MiniCPM3 style multi-head latent attention)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ----------------------------------------------------------------
    n_experts: int = 0              # routed experts (0 → dense FFN)
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0               # per-expert hidden dim (0 → d_ff)
    moe_every: int = 1              # MoE FFN every k-th layer (Jamba: 2)
    capacity_factor: float = 1.25

    # --- hybrid / SSM --------------------------------------------------------
    # mixer pattern within a layer group; scanned over n_layers/len(pattern)
    # entries: "attn" | "mamba" | "mlstm" | "slstm"
    block_pattern: Tuple[str, ...] = ("attn",)
    ssm_state_dim: int = 16
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    dt_rank: int = 0                # 0 → ceil(d_model / 16)

    # --- encoder-decoder / multimodal frontends ------------------------------
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    frontend: Optional[str] = None  # "audio" | "vision" (stub embeddings)
    frontend_seq: int = 0           # frames / image patches fed to backbone

    # --- misc -----------------------------------------------------------------
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"         # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True
    scan_chunk: int = 1024          # SSM sequential-scan chunk length
    mlstm_chunk: int = 128          # mLSTM chunkwise-parallel chunk length
    attn_q_chunk: int = 256         # XLA-attention query streaming chunk
    scan_unroll: bool = False       # unroll layer-group scan (roofline runs)
    use_flash: Optional[bool] = None  # None → Pallas on TPU, XLA elsewhere
    mla_absorb: bool = False        # absorbed MLA decode (beyond-paper opt)
    kv_quant: bool = False          # int8 KV cache w/ per-vector scales

    # -------------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def group_size(self) -> int:
        return len(self.block_pattern)

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.group_size == 0, (
            f"{self.name}: n_layers {self.n_layers} not divisible by "
            f"pattern length {self.group_size}")
        return self.n_layers // self.group_size

    @property
    def sub_quadratic(self) -> bool:
        """Bounded per-token decode state (SSM/hybrid/windowed attention)."""
        kinds = set(self.block_pattern)
        if kinds <= {"mamba", "mlstm", "slstm"}:
            return True
        if "attn" in kinds and self.window is not None:
            return True  # SWA bounds the KV window
        return kinds.isdisjoint({"attn"})

    def decode_cache_len(self, seq_len: int) -> int:
        """Per-layer attention cache length for a decode cell."""
        if self.window is not None:
            return min(self.window, seq_len)
        return seq_len

    def param_count(self) -> int:
        """Analytic parameter count (for 6·N·D roofline math)."""
        d, v = self.d_model, self.vocab_size
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        h, hk, dh = self.n_heads, self.n_kv_heads, self.head_dim
        for li in range(self.n_layers):
            kind = self.block_pattern[li % self.group_size]
            if kind == "attn":
                if self.attention == "mla":
                    qd = self.qk_nope_dim + self.qk_rope_dim
                    total += d * self.q_lora_rank
                    total += self.q_lora_rank * h * qd
                    total += d * (self.kv_lora_rank + self.qk_rope_dim)
                    total += self.kv_lora_rank * h * (self.qk_nope_dim
                                                      + self.v_head_dim)
                    total += h * self.v_head_dim * d
                else:
                    total += d * h * dh + 2 * d * hk * dh + h * dh * d
            elif kind == "mamba":
                din = self.ssm_expand * d
                total += d * 2 * din + din * self.ssm_conv_width
                dtr = self.dt_rank or -(-d // 16)
                total += din * (dtr + 2 * self.ssm_state_dim)
                total += dtr * din + din * self.ssm_state_dim + din
                total += din * d
            elif kind in ("mlstm", "slstm"):
                din = self.ssm_expand * d
                total += d * din * 4 + din * d  # q/k/v/gates + out proj
            # FFN
            if kind in ("mlstm", "slstm") or self.d_ff == 0:
                continue
            if self.is_moe and (li % self.moe_every == self.moe_every - 1):
                f = self.expert_d_ff
                total += d * self.n_experts  # router
                total += self.n_experts * 3 * d * f
                total += self.n_shared_experts * 3 * d * f
            else:
                total += 3 * d * self.d_ff
        if self.is_encoder_decoder:
            # encoder self-attn + FFN, decoder cross-attn
            enc = self.n_encoder_layers * (
                d * h * dh + 2 * d * hk * dh + h * dh * d + 3 * d * self.d_ff)
            cross = self.n_layers * (d * h * dh + 2 * d * hk * dh + h * dh * d)
            total += enc + cross
        return total

    def active_param_count(self) -> int:
        """Active (per-token) parameters — MoE counts top-k experts only."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        f = self.expert_d_ff
        n_moe_layers = sum(
            1 for li in range(self.n_layers)
            if self.block_pattern[li % self.group_size] not in
            ("mlstm", "slstm")
            and li % self.moe_every == self.moe_every - 1)
        inactive = (self.n_experts - self.experts_per_token)
        return self.param_count() - n_moe_layers * inactive * 3 * d * f
