"""xLSTM 125M — sLSTM + mLSTM blocks (attention-free).

[arXiv:2405.04517] 12L d_model=768 4H vocab=50304, d_ff=0 (blocks carry
their own up/down projections).  3:1 mLSTM:sLSTM interleave.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304, tie_embeddings=True,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    ssm_expand=2,
)
