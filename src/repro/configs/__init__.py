"""Architecture registry: the ten assigned configs + shape cells.

``get_config(arch_id)`` returns the full published config;
``reduced_config(cfg)`` shrinks it family-preservingly for CPU smoke tests
(same block pattern / attention type / MoE topology, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

from .base import ModelConfig

from .jamba_v01_52b import CONFIG as _jamba
from .mixtral_8x7b import CONFIG as _mixtral
from .qwen2_moe_a27b import CONFIG as _qwen2moe
from .deepseek_67b import CONFIG as _deepseek
from .minicpm3_4b import CONFIG as _minicpm3
from .phi3_mini_38b import CONFIG as _phi3
from .smollm_360m import CONFIG as _smollm
from .xlstm_125m import CONFIG as _xlstm
from .whisper_medium import CONFIG as _whisper
from .internvl2_76b import CONFIG as _internvl2

ARCHS: Dict[str, ModelConfig] = {
    c.name: c for c in (
        _jamba, _mixtral, _qwen2moe, _deepseek, _minicpm3, _phi3, _smollm,
        _xlstm, _whisper, _internvl2)
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(ARCHS)}")
    return ARCHS[arch_id]


def list_archs():
    return sorted(ARCHS)


# ---------------------------------------------------------------------------
# shape cells (assigned input shapes)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def cell_is_runnable(cfg: ModelConfig, shape: ShapeCell) -> Tuple[bool, str]:
    """Whether an (arch × shape) cell runs, per the assignment's skip rules."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("long_500k needs sub-quadratic attention; "
                       f"{cfg.name} is pure full-attention (see DESIGN.md)")
    return True, ""


# ---------------------------------------------------------------------------
# reduced configs for smoke tests
# ---------------------------------------------------------------------------
def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Family-preserving tiny config: one pattern group, small dims."""
    n_heads = min(cfg.n_heads, 4)
    # preserve the GQA grouping ratio where possible
    ratio = max(1, cfg.n_heads // cfg.n_kv_heads)
    n_kv = max(1, n_heads // ratio)
    n_heads = n_kv * ratio
    d_head = 16
    d_model = max(n_heads * d_head, 32)
    updates = dict(
        n_layers=cfg.group_size * (2 if cfg.n_layers >= 2 * cfg.group_size
                                   else 1),
        d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv, d_head=d_head,
        d_ff=0 if cfg.d_ff == 0 else 4 * d_model,
        vocab_size=128,
        scan_chunk=32,
    )
    if cfg.is_moe:
        updates.update(n_experts=min(cfg.n_experts, 4),
                       experts_per_token=min(cfg.experts_per_token, 2),
                       moe_d_ff=2 * d_model if cfg.moe_d_ff else 0,
                       n_shared_experts=min(cfg.n_shared_experts, 1))
    if cfg.attention == "mla":
        updates.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                       qk_rope_dim=8, v_head_dim=16)
    if cfg.window is not None:
        updates.update(window=32)
    if cfg.is_encoder_decoder:
        updates.update(n_encoder_layers=2)
    if cfg.frontend is not None:
        updates.update(frontend_seq=8)
    return dataclasses.replace(cfg, **updates)
