"""MiniCPM3-4B — dense with MLA (multi-head latent attention).

[hf:openbmb/MiniCPM3-4B] 62L d_model=2560 40H d_ff=6400 vocab=73448,
MLA: q_lora=768, kv_lora=256, qk_nope=64, qk_rope=32, v_head=64.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=6400,
    vocab_size=73448,
    attention="mla", q_lora_rank=768, kv_lora_rank=256,
    qk_nope_dim=64, qk_rope_dim=32, v_head_dim=64,
    block_pattern=("attn",) * 2,   # 62 = 31 groups x 2
)
