"""Jamba v0.1 52B — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887; hf] 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16 experts top-2 on every other layer; one attention layer
per 8-layer block (position 4), Mamba elsewhere.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=65536,
    n_experts=16, experts_per_token=2, moe_every=2,
    block_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    ssm_state_dim=16, ssm_conv_width=4, ssm_expand=2,
)
