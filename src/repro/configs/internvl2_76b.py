"""InternVL2 76B — VLM; InternViT frontend is a STUB (patch embeddings).

[arXiv:2404.16821] 80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256
(LLaMA-3-70B backbone). ``input_specs`` supplies patch embeddings
(B, 256, d) prefixing the token stream.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab_size=128256, rope_theta=5e5,
    frontend="vision", frontend_seq=256,
)
