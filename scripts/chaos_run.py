"""Chaos harness: seeded fault schedules against the resilient runtime.

Drives the three recovery contracts of DESIGN.md §13 end to end, each
under a seeded deterministic fault schedule, and verifies that the
observable result is **bit-exact** against an uninterrupted oracle and
that the retry budget actually bounded the damage:

  1. ``scan``  — a transient read fault (``scan.read``) injected into a
     planned scan→filter→groupby→sort pipeline running under a
     :class:`~repro.resilience.FaultPolicy`; the retry must absorb it.
  2. ``spill`` — a write fault (``spill.write``: disk-full or partial
     write) injected into an out-of-core groupby with a policy-carrying
     :class:`~repro.spill.SpillStore`; the retry must leave no torn
     run files and a bit-exact aggregate.
  3. ``commit`` — a ``SIGKILL`` injected mid stage-checkpoint commit
     (``checkpoint.commit:crash``) in a child process; a second child
     must resume from the committed prefix and reproduce the oracle
     bit-for-bit (the kill-and-resume contract).

Run:  PYTHONPATH=src python scripts/chaos_run.py --seeds 11,23,37
Exits non-zero on the first violated contract; prints one summary line
per (scenario, seed) so CI logs show exactly what was injected.
"""
from __future__ import annotations

import argparse
import os
import shutil
import subprocess
import sys
import tempfile
import zlib

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import telemetry as T  # noqa: E402
from repro.core import local_context  # noqa: E402
from repro.dataframe.frame import DataFrame  # noqa: E402
from repro.io.dataset import write_dataset  # noqa: E402
from repro.io.scan import pred  # noqa: E402
from repro.plan.frame import LazyFrame  # noqa: E402
from repro.resilience import FaultPolicy, arm_schedule, faults  # noqa: E402


def _crc_rows(df) -> str:
    d = df.to_numpy()
    crc = 0
    for k in sorted(d):
        crc = zlib.crc32(np.ascontiguousarray(d[k]).tobytes(), crc)
    return f"{crc:08x}"


def _events(root: str, n: int = 96) -> str:
    rng = np.random.default_rng(5)
    cols = {"k": (np.arange(n) % 12).astype(np.float32),
            "u": np.arange(n, dtype=np.float32),
            "v": rng.normal(size=n).astype(np.float32)}
    write_dataset(root, [(cols, n)], format="hpt", rows_per_group=12)
    return root


def _pipeline(ds: str, ctx):
    return (LazyFrame.read_parquet(ds, ctx)
            .filter([pred("u", "<", 72.0)])
            .groupby(["k"], [("v", "sum"), ("v", "count")])
            .sort_values("v_sum"))


def scenario_scan(seed: int, work: str) -> str:
    """Transient scan faults under a seeded schedule; retry absorbs."""
    ctx = local_context()
    ds = _events(os.path.join(work, "ds"))
    oracle = _crc_rows(_pipeline(ds, ctx).collect(strict=False))
    faults.reset()
    sched = arm_schedule(seed, ["scan.read"], kinds=("io_error",
                                                     "disk_full"),
                         n_faults=1, max_nth=3)
    rec = T.Collector("chaos-scan")
    pol = FaultPolicy(max_retries=3, backoff_base=0.0, backoff_max=0.0)
    got = _crc_rows(_pipeline(ds, ctx).collect(strict=False, policy=pol,
                                               telemetry=rec))
    assert got == oracle, f"scan: {got} != oracle {oracle}"
    retries = rec.metrics.counters.get("retry.scan.read", 0)
    injected = faults.fires("scan.read")
    assert retries <= pol.max_retries, f"retry budget blown: {retries}"
    assert injected >= 1 or all(nth > 8 for _, _, nth in sched), sched
    faults.reset()
    return f"injected={sched} fired={injected} retries={retries}"


def scenario_spill(seed: int, work: str) -> str:
    """Spill write faults; policy retry leaves no torn runs, bit-exact."""
    from repro.spill import spill_groupby

    ctx = local_context()
    rng = np.random.default_rng(seed)
    n = 4096
    cols = {"k": rng.integers(0, 64, n).astype(np.int32),
            "v": rng.standard_normal(n).astype(np.float32)}
    df = DataFrame.from_dict(cols, ctx, bucket_factor=2.0)
    aggs = (("v", "sum"), ("v", "count"))
    want = df.groupby(["k"], list(aggs)).to_numpy()
    faults.reset()
    sched = arm_schedule(seed, ["spill.write"],
                         kinds=("disk_full", "partial_write"),
                         n_faults=1, max_nth=2)
    rec = T.Collector("chaos-spill")
    pol = FaultPolicy(max_retries=2, backoff_base=0.0, backoff_max=0.0)
    spill_dir = os.path.join(work, "spill")
    with T.using(rec):
        with spill_groupby(df.table, ("k",), aggs, ctx=ctx,
                           budget_rows=256, workdir=spill_dir,
                           policy=pol) as res:
            got = res.collect()
    order_w, order_g = np.argsort(want["k"]), np.argsort(got["k"])
    for c in want:
        a, b = want[c][order_w], got[c][order_g]
        assert np.array_equal(a, b), f"spill: column {c} diverged"
    leftovers = []
    if os.path.isdir(spill_dir):
        leftovers = [f for f in os.listdir(spill_dir)
                     if f.endswith(".tmp")]
    assert not leftovers, f"torn run files left behind: {leftovers}"
    retries = rec.metrics.counters.get("retry.spill.write", 0)
    assert retries <= pol.max_retries, f"retry budget blown: {retries}"
    fired = faults.fires("spill.write")
    faults.reset()
    return f"injected={sched} fired={fired} retries={retries}"


_CHILD = """
import os, sys, zlib
import numpy as np
sys.path.insert(0, {src!r})
from repro import telemetry as T
from repro.core import local_context
from repro.io.scan import pred
from repro.plan.frame import LazyFrame
from repro.resilience import FaultPolicy

ds, ckdir = sys.argv[1], sys.argv[2]
ctx = local_context()
lf = (LazyFrame.read_parquet(ds, ctx)
      .filter([pred("u", "<", 72.0)])
      .groupby(["k"], [("v", "sum"), ("v", "count")])
      .sort_values("v_sum"))
rec = T.Collector("chaos-child")
pol = FaultPolicy(max_retries=1, checkpoint_dir=ckdir,
                  keep_checkpoints=True)
out = lf.collect(strict=False, policy=pol, telemetry=rec)
d = out.to_numpy()
crc = 0
for k in sorted(d):
    crc = zlib.crc32(np.ascontiguousarray(d[k]).tobytes(), crc)
print("RESTORED", rec.metrics.counters.get("recovery.stages_restored", 0))
print("CRC", f"{{crc:08x}}")
"""


def scenario_commit_crash(seed: int, work: str) -> str:
    """SIGKILL mid stage-commit in a child; resume is bit-exact."""
    ctx = local_context()
    ds = _events(os.path.join(work, "ds"))
    oracle = _crc_rows(_pipeline(ds, ctx).collect(strict=False))
    ckdir = os.path.join(work, "stages")
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "..", "src")
    child = _CHILD.format(src=os.path.abspath(src))
    env = dict(os.environ)
    env.pop("HPTMT_FAULTS", None)
    # the pipeline commits two stages; the seed picks which commit dies
    nth = 1 + (seed >> 1) % 2
    env1 = dict(env, HPTMT_FAULTS=f"checkpoint.commit:crash:{nth}")
    r1 = subprocess.run([sys.executable, "-c", child, ds, ckdir],
                        capture_output=True, text=True, timeout=560,
                        env=env1)
    assert r1.returncode == -9, (
        f"expected SIGKILL, got rc={r1.returncode}\n{r1.stderr[-2000:]}")
    r2 = subprocess.run([sys.executable, "-c", child, ds, ckdir],
                        capture_output=True, text=True, timeout=560,
                        env=env)
    assert r2.returncode == 0, r2.stderr[-2000:]
    lines = dict(l.split() for l in r2.stdout.splitlines())
    assert lines["CRC"] == oracle, (
        f"resumed run diverged: {lines['CRC']} != oracle {oracle}")
    restored = int(lines["RESTORED"])
    if nth == 2:
        assert restored >= 1, "crash after commit 1 but nothing restored"
    return f"killed_at_commit={nth} restored={restored} crc=ok"


SCENARIOS = [("scan", scenario_scan), ("spill", scenario_spill),
             ("commit-crash", scenario_commit_crash)]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seeds", default="11,23,37",
                    help="comma-separated chaos schedule seeds")
    ap.add_argument("--only", default=None,
                    help="run one scenario: scan | spill | commit-crash")
    args = ap.parse_args(argv)
    seeds = [int(s) for s in args.seeds.split(",") if s]
    failures = 0
    for seed in seeds:
        for name, fn in SCENARIOS:
            if args.only and name != args.only:
                continue
            work = tempfile.mkdtemp(prefix=f"chaos-{name}-{seed}-")
            try:
                detail = fn(seed, work)
                print(f"PASS {name:>12} seed={seed:<3} {detail}")
            except AssertionError as e:
                failures += 1
                print(f"FAIL {name:>12} seed={seed:<3} {e}")
            finally:
                shutil.rmtree(work, ignore_errors=True)
    if failures:
        print(f"{failures} chaos contract violation(s)")
        return 1
    print("all chaos contracts held")
    return 0


if __name__ == "__main__":
    sys.exit(main())
