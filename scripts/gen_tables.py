"""Generate EXPERIMENTS.md tables from dry-run JSON results."""
import json
import sys


def fmt_s(x):
    return f"{x:8.2f}" if x >= 0.01 else f"{x*1e3:6.1f}m"


def _tpu_adjusted(r):
    """Post-hoc TPU-adjusted terms from a JSON record (see roofline.py)."""
    import os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.configs import SHAPES, get_config
    from repro.launch import roofline as rl
    roof = r["roofline"]
    if "tpu_adjusted" in roof:
        return roof["tpu_adjusted"]
    cfg = get_config(r["arch"])
    cell = SHAPES[r["shape"]]
    meas = rl.Roofline(
        flops=roof["compute_s"] * rl.PEAK_FLOPS,
        hbm_bytes=roof["memory_s"] * rl.HBM_BW,
        collectives=rl.CollectiveStats({}, {}, roof["collective_s"]),
        n_chips=r["n_chips"], model_flops=roof["model_flops"])
    return rl.tpu_adjusted_terms(cfg, cell, r["n_chips"], meas)


def tpu_table(path):
    data = json.load(open(path))
    rows = []
    for r in data:
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        roof = r["roofline"]
        adj = _tpu_adjusted(r)
        rows.append(
            "| {arch} | {shape} | {c:.2f} | {m:.2f} | {k:.2f} "
            "| {step:.2f} | {mfu:.1f}% |".format(
                arch=r["arch"], shape=r["shape"], c=roof["compute_s"],
                m=adj["memory_s_tpu"], k=adj["collective_s_tpu"],
                step=adj["step_s_tpu"], mfu=adj["mfu_tpu"] * 100))
    return "\n".join(rows)


def roofline_table(path):
    data = json.load(open(path))
    rows = []
    for r in data:
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — "
                        f"| — | skip (full attention) |")
            continue
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        roof = r["roofline"]
        peak = r["memory"]["peak_bytes_per_device"] / 2**30
        rows.append(
            "| {arch} | {shape} | {c:.2f} | {m:.2f} | {k:.2f} | {b} "
            "| {uf:.2f} | {mfu:.1f}% | {peak:.1f} |".format(
                arch=r["arch"], shape=r["shape"],
                c=roof["compute_s"], m=roof["memory_s"],
                k=roof["collective_s"], b=roof["bottleneck"],
                uf=roof["useful_flops_frac"],
                mfu=roof["mfu_at_roofline"] * 100, peak=peak))
    return "\n".join(rows)


def memory_table(path):
    data = json.load(open(path))
    rows = []
    for r in data:
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | skip |")
            continue
        if r.get("status") != "ok":
            continue
        m = r["memory"]
        rows.append(
            "| {arch} | {shape} | {peak:.2f} | {arg:.2f} | ok ({t:.0f}s) |"
            .format(arch=r["arch"], shape=r["shape"],
                    peak=m["peak_bytes_per_device"] / 2**30,
                    arg=m["argument_bytes_per_device"] / 2**30,
                    t=r.get("compile_s", 0)))
    return "\n".join(rows)


if __name__ == "__main__":
    kind, path = sys.argv[1], sys.argv[2]
    print({"roofline": roofline_table, "memory": memory_table,
           "tpu": tpu_table}[kind](path))
