"""Generate on-disk datasets for the quickstart example and benchmarks.

Two generators, both writing through the storage subsystem
(``repro.io``, DESIGN.md §5):

  * :func:`make_events_dataset` — an "events" fact table (6 columns, with
    ``day`` sorted so date-range predicates prune whole fragments) plus a
    "users" dimension table, the classic scan→join→groupby shape.  Used
    by ``examples/quickstart.py`` and ``benchmarks/run.py``'s
    ``ingest_scan_*`` cases.
  * :func:`make_corpus_dataset` — the synthetic training corpus
    (docs + tokens) as datasets, feeding ``repro.data.pipeline.disk_corpus``.

Run:  PYTHONPATH=src python scripts/make_dataset.py events /tmp/events_ds
"""
from __future__ import annotations

import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))


def make_events_dataset(root: str, n_rows: int = 100_000,
                        n_users: int = 1_000, n_days: int = 30,
                        fmt=None, rows_per_group: int = None,
                        seed: int = 0) -> str:
    """Events fact table + users dimension table under ``root``.

    Events are sorted by ``day`` so per-fragment min/max statistics make
    day-range predicates prunable — the pushdown demo/benchmark shape.
    """
    from repro.io import write_dataset

    rng = np.random.default_rng(seed)
    per = rows_per_group or max(n_rows // 16, 1)
    events = {
        "user_id": rng.integers(0, n_users, n_rows).astype(np.int32),
        "day": np.sort(rng.integers(0, n_days, n_rows)).astype(np.int32),
        "value": rng.normal(size=n_rows).astype(np.float32),
        "score": rng.uniform(0, 1, n_rows).astype(np.float32),
        "clicks": rng.integers(0, 20, n_rows).astype(np.int32),
        "flag": (rng.uniform(size=n_rows) < 0.3),
    }
    write_dataset(os.path.join(root, "events"), [(events, n_rows)],
                  format=fmt, rows_per_group=per)
    users = {
        "user_id": np.arange(n_users, dtype=np.int32),
        "segment": rng.integers(0, 8, n_users).astype(np.int32),
        "weight": rng.uniform(0.5, 2.0, n_users).astype(np.float32),
    }
    write_dataset(os.path.join(root, "users"), [(users, n_users)],
                  format=fmt)
    return root


def make_corpus_dataset(root: str, n_docs: int = 64, mean_doc_len: int = 96,
                        vocab_size: int = 128, fmt=None,
                        seed: int = 0) -> str:
    """The training corpus (docs + tokens) as on-disk datasets."""
    from repro.data.pipeline import CorpusConfig, synthetic_corpus_arrays
    from repro.io import write_dataset

    arrays = synthetic_corpus_arrays(CorpusConfig(
        n_docs=n_docs, mean_doc_len=mean_doc_len, vocab_size=vocab_size,
        seed=seed))
    for name, cols in arrays.items():
        n = next(iter(cols.values())).shape[0]
        write_dataset(os.path.join(root, name), [(cols, n)], format=fmt,
                      rows_per_group=max(n // 8, 1))
    return root


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("kind", choices=("events", "corpus"))
    p.add_argument("root")
    p.add_argument("--rows", type=int, default=100_000)
    p.add_argument("--format", default=None,
                   help="hpt | parquet | auto (default: parquet when "
                        "pyarrow is available, else hpt)")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)
    if args.kind == "events":
        make_events_dataset(args.root, n_rows=args.rows, fmt=args.format,
                            seed=args.seed)
    else:
        make_corpus_dataset(args.root, fmt=args.format, seed=args.seed)
    print(f"wrote {args.kind} dataset(s) under {args.root}")


if __name__ == "__main__":
    main()
