"""Cross-run performance report over a telemetry ledger (DESIGN.md §14.3).

Reads the JSONL ledger that ``LazyFrame.collect(ledger=...)`` and
``benchmarks/run.py --ledger`` append to, groups records by plan
fingerprint, and renders a markdown report comparing each fingerprint's
LATEST run against its PREVIOUS one:

  * wall-time delta — flagged as a regression past ``--time-threshold``
    (default +30%, the same bar as the bench gate);
  * q-error drift — flagged when the max q-error grew by more than
    ``--qerr-threshold``x (default 2x: the planner's estimates are
    drifting out of contract even if the run is not yet slower).

``--gate`` exits non-zero when anything is flagged, so CI can ride the
report as a cheap cross-run screen; fingerprints with a single run
render as "baseline" rows and never flag.

Usage::

    python scripts/perf_report.py LEDGER.jsonl [--out report.md] [--gate]
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import Any, Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.telemetry import ledger  # noqa: E402

TIME_THRESHOLD = 0.30   # latest wall_s may exceed previous by ≤30%
QERR_THRESHOLD = 2.0    # latest max q-error may exceed previous by ≤2x


def fingerprint_deltas(records: List[Dict[str, Any]], *,
                       time_threshold: float = TIME_THRESHOLD,
                       qerr_threshold: float = QERR_THRESHOLD
                       ) -> List[Dict[str, Any]]:
    """Per-fingerprint latest-vs-previous comparison rows, file order
    (== time order for an append-only ledger) within each fingerprint."""
    by_fp: Dict[str, List[Dict[str, Any]]] = {}
    for r in records:
        fp = r.get("fingerprint")
        if fp:
            by_fp.setdefault(fp, []).append(r)
    rows = []
    for fp in sorted(by_fp):
        runs = by_fp[fp]
        latest = runs[-1]
        prev = runs[-2] if len(runs) > 1 else None
        row: Dict[str, Any] = {
            "fingerprint": fp, "kind": latest.get("kind", "?"),
            "runs": len(runs), "wall_s": latest.get("wall_s"),
            "prev_wall_s": prev.get("wall_s") if prev else None,
            "max_qerror": latest.get("max_qerror"),
            "prev_max_qerror": prev.get("max_qerror") if prev else None,
            "time_delta": None, "qerr_drift": None, "flags": [],
        }
        if prev and prev.get("wall_s") and latest.get("wall_s") is not None:
            delta = latest["wall_s"] / prev["wall_s"] - 1.0
            row["time_delta"] = delta
            if delta > time_threshold:
                row["flags"].append("TIME")
        if prev and prev.get("max_qerror") and latest.get("max_qerror"):
            drift = latest["max_qerror"] / prev["max_qerror"]
            row["qerr_drift"] = drift
            if drift > qerr_threshold:
                row["flags"].append("QERR")
        rows.append(row)
    return rows


def _fmt_s(v: Optional[float]) -> str:
    if v is None:
        return "—"
    return f"{v * 1e3:.1f}ms" if v < 1.0 else f"{v:.2f}s"


def _fmt_q(v: Optional[float]) -> str:
    return "—" if v is None else f"{v:.2f}"


def render_markdown(rows: List[Dict[str, Any]], *, path: str = "") -> str:
    lines = ["# Performance report", ""]
    if path:
        lines += [f"Ledger: `{path}` — "
                  f"{sum(r['runs'] for r in rows)} run(s), "
                  f"{len(rows)} fingerprint(s).", ""]
    lines += ["| fingerprint | kind | runs | prev wall | last wall | Δtime |"
              " prev qerr | last qerr | drift | flags |",
              "|---|---|---:|---:|---:|---:|---:|---:|---:|---|"]
    for r in rows:
        delta = ("baseline" if r["time_delta"] is None
                 else f"{r['time_delta']:+.1%}")
        drift = ("—" if r["qerr_drift"] is None
                 else f"{r['qerr_drift']:.2f}x")
        flags = " ".join(f"**{f}**" for f in r["flags"]) or "ok"
        lines.append(
            f"| `{r['fingerprint'][:20]}` | {r['kind']} | {r['runs']} "
            f"| {_fmt_s(r['prev_wall_s'])} | {_fmt_s(r['wall_s'])} "
            f"| {delta} | {_fmt_q(r['prev_max_qerror'])} "
            f"| {_fmt_q(r['max_qerror'])} | {drift} | {flags} |")
    flagged = [r for r in rows if r["flags"]]
    lines.append("")
    if flagged:
        lines.append(f"**{len(flagged)} regression(s) flagged:** "
                     + ", ".join(f"`{r['fingerprint'][:20]}` "
                                 f"({'/'.join(r['flags'])})"
                                 for r in flagged))
    else:
        lines.append("No regressions flagged.")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("ledger", help="JSONL ledger path")
    p.add_argument("--out", help="write the markdown report here "
                                 "(default: stdout only)")
    p.add_argument("--time-threshold", type=float, default=TIME_THRESHOLD,
                   help="relative wall-time slowdown flagged as regression")
    p.add_argument("--qerr-threshold", type=float, default=QERR_THRESHOLD,
                   help="max-q-error growth factor flagged as drift")
    p.add_argument("--gate", action="store_true",
                   help="exit non-zero when any fingerprint is flagged")
    args = p.parse_args(argv)

    records = ledger.read(args.ledger)
    rows = fingerprint_deltas(records,
                              time_threshold=args.time_threshold,
                              qerr_threshold=args.qerr_threshold)
    text = render_markdown(rows, path=args.ledger)
    print(text)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    flagged = sum(1 for r in rows if r["flags"])
    if args.gate and flagged:
        print(f"# GATE FAILED: {flagged} fingerprint(s) regressed",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
