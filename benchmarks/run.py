"""Benchmark harness — one function per paper table/figure.

  bench_array_ops     → paper Table I   (array collectives)
  bench_table_ops     → paper Tables II/III (relational operators)
  bench_shuffle       → paper Fig 2     (shuffle primitive)
  bench_join_scaling  → paper Fig 16    (Cylon join scaling study)
  bench_mds           → paper Figs 14/15 (MDS composition pipeline)
  bench_lm_step       → framework: LM train/decode step (tokens/s)
  bench_kernels       → Pallas kernel interpret-mode vs ref overhead

Methodology: every operator case is jitted ONCE and the compiled function is
timed with a ``block_until_ready`` per iteration — numbers are steady-state
execution, not retrace time.  Prints ``name,us_per_call,derived`` CSV
(derived = rows/s, tokens/s, …) and writes ``BENCH_shuffle.json`` next to
this file so the perf trajectory is tracked across PRs.

Wall times are single-host CPU numbers — scaling behaviour at pod size is
covered by the dry-run collective analysis (EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DistTable, Table, local_context, table_ops
from repro.core import array_ops

CTX = local_context()
ROWS = []
DEFAULT_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "BENCH_shuffle.json")


def _timeit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """µs per call of an already-jitted ``fn``, blocking every iteration."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # µs


def _emit(name: str, us: float, derived: str):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


def _table(n: int, n_keys: int = None, seed: int = 0) -> DistTable:
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, n_keys or max(n // 4, 2), n).astype(np.int32)
    vals = rng.normal(size=n).astype(np.float32)
    return DistTable.from_local(
        Table.from_arrays({"k": jnp.asarray(keys), "v": jnp.asarray(vals)}),
        CTX)


# ---------------------------------------------------------------------------
def bench_array_ops(n: int = 1 << 20):
    """Paper Table I: array collective operators."""
    x = jnp.ones((8, n // 8), jnp.float32)
    flat = jnp.ones((n,), jnp.float32)
    for name, fn, arg in [
        ("allreduce", lambda v: array_ops.allreduce(v, ctx=CTX), x),
        ("allgather", lambda v: array_ops.allgather(v, ctx=CTX), flat),
        ("broadcast", lambda v: array_ops.broadcast(v, ctx=CTX), x),
        ("alltoall", lambda v: array_ops.alltoall(v, ctx=CTX), flat),
        ("reduce_scatter",
         lambda v: array_ops.reduce_scatter(v, ctx=CTX), flat),
    ]:
        jfn = jax.jit(fn)
        us = _timeit(jfn, arg)
        gbps = n * 4 / (us * 1e-6) / 1e9
        _emit(f"tab1_array_{name}", us, f"{gbps:.2f}GB/s")


def bench_table_ops(n: int = 200_000):
    """Paper Tables II/III: relational operators at n rows (pre-jitted)."""
    dt = _table(n)
    dt2 = _table(n, seed=1)

    unary = [
        ("select", jax.jit(lambda t: table_ops.select(
            t, lambda c: c["v"] > 0, ctx=CTX))),
        ("project", jax.jit(lambda t: table_ops.project(t, ["v"], ctx=CTX))),
        ("orderby", jax.jit(lambda t: table_ops.orderby(t, "v", ctx=CTX))),
        ("groupby", jax.jit(lambda t: table_ops.groupby_aggregate(
            t, ["k"], [("v", "sum"), ("v", "mean")], ctx=CTX))),
        ("aggregate", jax.jit(lambda t: table_ops.aggregate(
            t, "v", "sum", ctx=CTX))),
    ]
    binary = [
        ("union", jax.jit(lambda a, b: table_ops.union(a, b, ctx=CTX))),
        ("difference", jax.jit(lambda a, b: table_ops.difference(
            a, b, ctx=CTX))),
        ("intersect", jax.jit(lambda a, b: table_ops.intersect(
            a, b, ctx=CTX))),
    ]
    for name, jfn in unary:
        us = _timeit(jfn, dt)
        _emit(f"tab23_table_{name}", us, f"{n / (us * 1e-6) / 1e6:.1f}Mrow/s")
    for name, jfn in binary:
        us = _timeit(jfn, dt, dt2)
        _emit(f"tab23_table_{name}", us, f"{n / (us * 1e-6) / 1e6:.1f}Mrow/s")


def bench_shuffle(n: int = 500_000):
    """Paper Fig 2: hash shuffle (one packed AllToAll per exchange)."""
    dt = _table(n)
    jfn = jax.jit(lambda t: table_ops.shuffle(t, ["k"], ctx=CTX))
    us = _timeit(jfn, dt)
    _emit("fig2_shuffle", us, f"{n / (us * 1e-6) / 1e6:.1f}Mrow/s")


def bench_join_scaling(sizes=(50_000, 100_000, 200_000, 400_000)):
    """Paper Fig 16: join wall time while load grows (weak scaling proxy:
    rows double, per-row time should stay ~flat)."""
    for n in sizes:
        rng = np.random.default_rng(0)
        lk = rng.permutation(n).astype(np.int32)
        rk = rng.permutation(n).astype(np.int32)
        l = DistTable.from_local(Table.from_arrays(
            {"k": jnp.asarray(lk), "a": jnp.asarray(lk, jnp.float32)}), CTX)
        r = DistTable.from_local(Table.from_arrays(
            {"k": jnp.asarray(rk), "b": jnp.asarray(rk, jnp.float32)}), CTX)
        jfn = jax.jit(lambda a, b, n=n: table_ops.join(
            a, b, ["k"], out_capacity=n, ctx=CTX))
        us = _timeit(jfn, l, r, iters=3)
        _emit(f"fig16_join_{n}", us, f"{n / (us * 1e-6) / 1e6:.2f}Mrow/s")


def bench_mds():
    """Paper Figs 14/15: table-prep + SMACOF MDS composition."""
    from repro.apps.mds import mds_pipeline
    for n in (64, 128, 256):
        t0 = time.perf_counter()
        path, emb = mds_pipeline(n_points=n, dim=2, iters=20, ctx=CTX)
        dt = (time.perf_counter() - t0) * 1e6
        _emit(f"fig15_mds_{n}pts", dt, f"stress={path[-1]:.3f}")


def bench_lm_step():
    """Framework: LM train + decode step at reduced config (CPU)."""
    from repro.configs import get_config, reduced_config
    from repro.train.optimizer import OptimizerConfig
    from repro.train.train_step import (TrainConfig, init_train_state,
                                        make_train_step)

    for arch in ("smollm-360m", "mixtral-8x7b", "xlstm-125m"):
        cfg = reduced_config(get_config(arch))
        state = init_train_state(jax.random.PRNGKey(0), cfg)
        step = jax.jit(make_train_step(
            cfg, TrainConfig(optimizer=OptimizerConfig())))
        b, s = 4, 128
        rng = jax.random.PRNGKey(1)
        batch = {"tokens": jax.random.randint(rng, (b, s), 0,
                                              cfg.vocab_size)}
        batch["labels"] = batch["tokens"]
        us = _timeit(lambda: step(state, batch)[1]["loss"], iters=3)
        _emit(f"lm_train_step_{arch}", us,
              f"{b * s / (us * 1e-6):.0f}tok/s")


def bench_kernels():
    """Pallas kernels (interpret) vs jnp reference wall time."""
    from repro.kernels.flash_attention import ops as fops
    from repro.kernels.segment_reduce import ops as sops

    q = jnp.ones((1, 4, 256, 64), jnp.float32)
    k = v = jnp.ones((1, 2, 256, 64), jnp.float32)
    us_ref = _timeit(jax.jit(
        lambda a, b, c: fops.flash_attention(a, b, c, force="ref")), q, k, v)
    _emit("kernel_flash_ref_xla", us_ref, "256x256")

    vals = jnp.ones((1 << 16,), jnp.float32)
    segs = jnp.zeros((1 << 16,), jnp.int32)
    us = _timeit(jax.jit(lambda a, b: sops.segment_reduce(a, b, 512,
                                                          force="ref")),
                 vals, segs)
    _emit("kernel_segreduce_ref_xla", us, "65k_rows")


def write_json(path: str) -> None:
    """Machine-readable perf record (name → µs + derived metric)."""
    data = {name: {"us_per_call": round(us, 1), "derived": derived}
            for name, us, derived in ROWS}
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}", flush=True)


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true",
                   help="small sizes, shuffle-relevant benches only (CI)")
    p.add_argument("--out", default=DEFAULT_JSON,
                   help="path for the JSON perf record")
    args = p.parse_args(argv)

    print("name,us_per_call,derived")
    if args.quick:
        bench_table_ops(n=20_000)
        bench_shuffle(n=50_000)
        bench_join_scaling(sizes=(20_000, 40_000))
    else:
        bench_array_ops()
        bench_table_ops()
        bench_shuffle()
        bench_join_scaling()
        bench_mds()
        bench_lm_step()
        bench_kernels()
    write_json(args.out)
    print(f"# {len(ROWS)} benchmarks complete")


if __name__ == "__main__":
    main()
