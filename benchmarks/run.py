"""Benchmark harness — one function per paper table/figure.

  bench_array_ops     → paper Table I   (array collectives)
  bench_table_ops     → paper Tables II/III (relational operators)
  bench_shuffle       → paper Fig 2     (shuffle primitive)
  bench_join_scaling  → paper Fig 16    (Cylon join scaling study)
  bench_join_highdup  → high-duplication join: hash vs sort-merge
                        (fan-out ≈ 8, DESIGN.md §8)
  bench_orderby       → multi-key sample sort (DESIGN.md §9)
  bench_window_rolling→ rolling windows off the range layout vs a
                        gather-then-numpy-sort oracle (DESIGN.md §9)
  bench_topk          → tree-reduced top-k, no global sort
  bench_setop_union   → set-op union on the hash dedup path
  bench_mds           → paper Figs 14/15 (MDS composition pipeline)
  bench_lm_step       → framework: LM train/decode step (tokens/s)
  bench_kernels       → Pallas kernel interpret-mode vs ref overhead
  bench_scan_ingest   → storage scan (DESIGN.md §5): full vs pushdown,
                        native .hpt always, Parquet when pyarrow present
  bench_planned_pipeline → lazy planner (DESIGN.md §11): whole-pipeline
                        scan→filter→groupby, planned vs eager wall time
  bench_spill_join    → out-of-core join beyond budget_rows (DESIGN.md
                        §10): chunk-streamed, exactness- and RSS-gated
  bench_telemetry_overhead → collector on vs off around the 500k
                        shuffle, gated < 2% (DESIGN.md §12)

Methodology: every operator case is jitted ONCE and the compiled function is
timed with a ``block_until_ready`` per iteration — numbers are steady-state
execution, not retrace time.  Prints ``name,us_per_call,derived,peak_rss_mb``
CSV (derived = rows/s, tokens/s, …) and writes ``BENCH_shuffle.json`` next to
this file so the perf trajectory is tracked across PRs.

Wall times are single-host CPU numbers — scaling behaviour at pod size is
covered by the dry-run collective analysis (EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DistTable, Table, local_context, table_ops
from repro.core import array_ops

CTX = local_context()
ROWS = []
DEFAULT_JSON = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "BENCH_shuffle.json")

#: committed peak-RSS cap for the out-of-core spill case (DESIGN.md §10):
#: the bounded-memory promise as a number.  The spill bench joins an input
#: far larger than its budget_rows working set; if its peak RSS climbs past
#: this, the engine stopped being out-of-core and main() exits non-zero.
SPILL_RSS_BUDGET_MB = 4096.0
RSS_VIOLATIONS = []

#: committed ceiling on what the telemetry machinery may add around a
#: jitted operator call (DESIGN.md §12): collector-on vs collector-off
#: on the 500k shuffle, best-of interleaved legs.  Violations fail
#: main() exactly like the RSS budget.
TELEMETRY_OVERHEAD_BUDGET_PCT = 2.0
TELEMETRY_VIOLATIONS = []

#: per-case static collective audits (compiled-HLO counts/bytes +
#: achieved fraction of the ICI roofline), keyed by bench name; rides
#: into the JSON record and the --telemetry-out artifact
TELEMETRY = {}


def _attach_telemetry(name: str, jfn, *args, us: float = None) -> None:
    """Audit one jitted bench case: compiled-HLO collective counts and
    payload bytes, plus — when the wall time is known — the achieved
    exchange bandwidth against the ``roofline.ICI_BW`` bound."""
    from repro.launch.roofline import ICI_BW
    from repro.telemetry import compiled_collectives

    rec = compiled_collectives(jfn, *args)
    entry = {"collectives": rec["counts"],
             "bytes_by_kind": rec["bytes_by_kind"],
             "total_bytes": rec["total_bytes"],
             "ring_cost_s": rec["ring_cost_s"]}
    if us and rec["total_bytes"]:
        achieved = rec["total_bytes"] / (us * 1e-6)
        entry["achieved_bytes_per_s"] = round(achieved)
        entry["ici_roofline_frac"] = round(achieved / ICI_BW, 4)
    TELEMETRY[name] = entry


def _peak_rss_mb() -> float:
    """Process peak RSS in MB — VmHWM (resettable) with a rusage fallback."""
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmHWM:"):
                    return int(line.split()[1]) / 1024.0
    except OSError:
        pass
    try:
        import resource

        return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    except Exception:
        return float("nan")


def _reset_peak_rss() -> None:
    """Reset the kernel's VmHWM watermark so per-case peaks are isolated
    (Linux /proc/self/clear_refs; silently a no-op elsewhere — then VmHWM
    is a process-lifetime high-water mark and per-case numbers only ever
    over-report, never under-report)."""
    try:
        with open("/proc/self/clear_refs", "w") as f:
            f.write("5")
    except OSError:
        pass


def _timeit(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """µs per call of an already-jitted ``fn``, blocking every iteration."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # µs


def _emit(name: str, us: float, derived: str):
    rss = _peak_rss_mb()
    ROWS.append((name, us, derived, rss))
    print(f"{name},{us:.1f},{derived},{rss:.0f}", flush=True)


def _table(n: int, n_keys: int = None, seed: int = 0) -> DistTable:
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, n_keys or max(n // 4, 2), n).astype(np.int32)
    vals = rng.normal(size=n).astype(np.float32)
    return DistTable.from_local(
        Table.from_arrays({"k": jnp.asarray(keys), "v": jnp.asarray(vals)}),
        CTX)


# ---------------------------------------------------------------------------
def bench_array_ops(n: int = 1 << 20):
    """Paper Table I: array collective operators."""
    x = jnp.ones((8, n // 8), jnp.float32)
    flat = jnp.ones((n,), jnp.float32)
    for name, fn, arg in [
        ("allreduce", lambda v: array_ops.allreduce(v, ctx=CTX), x),
        ("allgather", lambda v: array_ops.allgather(v, ctx=CTX), flat),
        ("broadcast", lambda v: array_ops.broadcast(v, ctx=CTX), x),
        ("alltoall", lambda v: array_ops.alltoall(v, ctx=CTX), flat),
        ("reduce_scatter",
         lambda v: array_ops.reduce_scatter(v, ctx=CTX), flat),
    ]:
        jfn = jax.jit(fn)
        us = _timeit(jfn, arg)
        gbps = n * 4 / (us * 1e-6) / 1e9
        _emit(f"tab1_array_{name}", us, f"{gbps:.2f}GB/s")


def bench_table_ops(n: int = 200_000):
    """Paper Tables II/III: relational operators at n rows (pre-jitted)."""
    dt = _table(n)
    dt2 = _table(n, seed=1)

    unary = [
        ("select", jax.jit(lambda t: table_ops.select(
            t, lambda c: c["v"] > 0, ctx=CTX))),
        ("project", jax.jit(lambda t: table_ops.project(t, ["v"], ctx=CTX))),
        ("orderby", jax.jit(lambda t: table_ops.orderby(t, "v", ctx=CTX))),
        ("groupby", jax.jit(lambda t: table_ops.groupby_aggregate(
            t, ["k"], [("v", "sum"), ("v", "mean")], ctx=CTX))),
        ("aggregate", jax.jit(lambda t: table_ops.aggregate(
            t, "v", "sum", ctx=CTX))),
    ]
    binary = [
        ("union", jax.jit(lambda a, b: table_ops.union(a, b, ctx=CTX))),
        ("difference", jax.jit(lambda a, b: table_ops.difference(
            a, b, ctx=CTX))),
        ("intersect", jax.jit(lambda a, b: table_ops.intersect(
            a, b, ctx=CTX))),
    ]
    for name, jfn in unary:
        us = _timeit(jfn, dt)
        _emit(f"tab23_table_{name}", us, f"{n / (us * 1e-6) / 1e6:.1f}Mrow/s")
    for name, jfn in binary:
        us = _timeit(jfn, dt, dt2)
        _emit(f"tab23_table_{name}", us, f"{n / (us * 1e-6) / 1e6:.1f}Mrow/s")


def bench_shuffle(n: int = 500_000):
    """Paper Fig 2: hash shuffle (one packed AllToAll per exchange)."""
    dt = _table(n)
    jfn = jax.jit(lambda t: table_ops.shuffle(t, ["k"], ctx=CTX))
    us = _timeit(jfn, dt)
    _emit("fig2_shuffle", us, f"{n / (us * 1e-6) / 1e6:.1f}Mrow/s")
    _attach_telemetry("fig2_shuffle", jfn, dt, us=us)


def bench_groupby_lowcard(n: int = 200_000, n_keys: int = 1_000):
    """Low-cardinality GroupBy: the map-side-combine / hash-slot regime.

    ``out_capacity`` declares the bounded key cardinality, which selects
    the sort-free hash grouping kernel (and, on multi-shard meshes, the
    shrunken combine exchange) — DESIGN.md §4.
    """
    dt = _table(n, n_keys=n_keys)
    out_cap = 1 << (2 * n_keys - 1).bit_length()
    jfn = jax.jit(lambda t: table_ops.groupby_aggregate(
        t, ["k"], [("v", "sum"), ("v", "mean")], out_capacity=out_cap,
        ctx=CTX))
    us = _timeit(jfn, dt)
    _emit("groupby_lowcard", us, f"{n / (us * 1e-6) / 1e6:.1f}Mrow/s")


def bench_join_then_groupby(n: int = 200_000):
    """Operator chain: join + groupby on the join keys.

    The groupby consumes the join's partitioning metadata, so on meshes the
    chain issues shuffles only for the join inputs (zero for pre-partitioned
    ones) and none for the groupby — jaxpr-asserted in
    tests/test_partitioning.py; here the steady-state wall time is tracked.
    """
    rng = np.random.default_rng(0)
    lk = rng.permutation(n).astype(np.int32)
    rk = rng.permutation(n).astype(np.int32)
    l = DistTable.from_local(Table.from_arrays(
        {"k": jnp.asarray(lk), "a": jnp.asarray(lk, jnp.float32)}), CTX)
    r = DistTable.from_local(Table.from_arrays(
        {"k": jnp.asarray(rk), "b": jnp.asarray(rk, jnp.float32)}), CTX)

    def chain(a, b):
        j, ov1 = table_ops.join(a, b, ["k"], out_capacity=n, ctx=CTX)
        g, ov2 = table_ops.groupby_aggregate(
            j, ["k"], [("a", "sum"), ("b", "mean")], ctx=CTX)
        return g, ov1 + ov2

    jfn = jax.jit(chain)
    us = _timeit(jfn, l, r, iters=3)
    _emit("join_then_groupby", us, f"{n / (us * 1e-6) / 1e6:.2f}Mrow/s")


def bench_join_scaling(sizes=(50_000, 100_000, 200_000, 400_000)):
    """Paper Fig 16: join wall time while load grows (weak scaling proxy:
    rows double, per-row time should stay ~flat).  Runs the default path
    (``method="auto"`` → the sort-free hash build/probe, DESIGN.md §8)."""
    for n in sizes:
        rng = np.random.default_rng(0)
        lk = rng.permutation(n).astype(np.int32)
        rk = rng.permutation(n).astype(np.int32)
        l = DistTable.from_local(Table.from_arrays(
            {"k": jnp.asarray(lk), "a": jnp.asarray(lk, jnp.float32)}), CTX)
        r = DistTable.from_local(Table.from_arrays(
            {"k": jnp.asarray(rk), "b": jnp.asarray(rk, jnp.float32)}), CTX)
        jfn = jax.jit(lambda a, b, n=n: table_ops.join(
            a, b, ["k"], out_capacity=n, ctx=CTX))
        us = _timeit(jfn, l, r, iters=3)
        _emit(f"fig16_join_{n}", us, f"{n / (us * 1e-6) / 1e6:.2f}Mrow/s")


def bench_join_highdup(n: int = 200_000, n_keys: int = 1_000,
                       fanout: int = 8):
    """High-duplication join (fan-out ≈ ``fanout``): sort-merge's worst
    regime, and the case the hash engine's counted two-pass scheme is
    built for (DESIGN.md §8).

    Left: ``n`` rows with keys uniform over ``n_keys``; right: every key
    exactly ``fanout`` times — each left row emits ``fanout`` pairs.  Both
    kernels run on identical inputs; the sort path's probe window is set
    to the duplicate depth it needs to find every match.
    """
    rng = np.random.default_rng(0)
    lk = rng.integers(0, n_keys, n).astype(np.int32)
    rk = np.repeat(np.arange(n_keys, dtype=np.int32), fanout)
    l = DistTable.from_local(Table.from_arrays(
        {"k": jnp.asarray(lk), "a": jnp.asarray(lk, jnp.float32)}), CTX)
    r = DistTable.from_local(Table.from_arrays(
        {"k": jnp.asarray(rk),
         "b": jnp.arange(len(rk), dtype=jnp.float32)}), CTX)
    out_cap = n * fanout
    jhash = jax.jit(lambda a, b: table_ops.join(
        a, b, ["k"], max_matches=fanout, out_capacity=out_cap, ctx=CTX))
    jsort = jax.jit(lambda a, b: table_ops.join(
        a, b, ["k"], max_matches=fanout, window=fanout,
        out_capacity=out_cap, method="sort", ctx=CTX))
    us = _timeit(jhash, l, r, iters=3)
    _emit("join_highdup", us, f"{n / (us * 1e-6) / 1e6:.2f}Mrow/s")
    us_sort = _timeit(jsort, l, r, iters=3)
    _emit("join_highdup_sort", us_sort,
          f"hash_{us_sort / us:.2f}x_faster")


def bench_orderby(n: int = 500_000):
    """Multi-key sample sort (DESIGN.md §9): monotone-lane directional
    keys, splitter AllGather, one packed AllToAll, local lexsort."""
    rng = np.random.default_rng(0)
    dt = DistTable.from_local(Table.from_arrays({
        "g": jnp.asarray(rng.integers(0, 1_000, n).astype(np.int32)),
        "t": jnp.asarray(rng.integers(0, 1 << 20, n).astype(np.int32)),
        "v": jnp.asarray(rng.normal(size=n).astype(np.float32))}), CTX)
    jfn = jax.jit(lambda t: table_ops.orderby(t, ["g", "t"], ctx=CTX))
    us = _timeit(jfn, dt, iters=3)
    _emit("orderby_500k", us, f"{n / (us * 1e-6) / 1e6:.1f}Mrow/s")
    _attach_telemetry("orderby_500k", jfn, dt, us=us)


def bench_window_rolling(n: int = 200_000, n_part: int = 1_000,
                         w: int = 32):
    """Rolling windows off the range layout (DESIGN.md §9) vs the
    numpy-style recompute an un-layouted system pays.

    The subsystem path: the table already carries orderby's range
    metadata (the steady state of an ordered pipeline), so `window`
    evaluates sum+mean+count via the fused blocked scan with zero
    exchanges and zero sorts.  The oracle: gather to host (`to_numpy`),
    np.lexsort by (partition, order), vectorized cumsum-diff rolling —
    the honest fast-numpy recompute.  Acceptance: ≥ 1.5x."""
    rng = np.random.default_rng(0)
    g = rng.integers(0, n_part, n).astype(np.int32)
    t = rng.integers(0, 1 << 20, n).astype(np.int32)
    v = rng.normal(size=n).astype(np.float32)
    dt = DistTable.from_local(Table.from_arrays(
        {"g": jnp.asarray(g), "t": jnp.asarray(t),
         "v": jnp.asarray(v)}), CTX)
    srt, _ = table_ops.orderby(dt, ["g", "t"], ctx=CTX)
    aggs = [("v", "sum"), ("v", "mean"), (None, "count")]
    jfn = jax.jit(lambda d: table_ops.window_aggregate(
        d, ["g"], ["t"], aggs, rows=w, ctx=CTX))
    us = _timeit(jfn, srt, iters=3)
    _emit("window_rolling_200k", us, f"{n / (us * 1e-6) / 1e6:.1f}Mrow/s")

    def oracle():
        cols = srt.to_numpy()  # the gather an un-layouted system pays
        og, ot, ov = cols["g"], cols["t"], cols["v"]
        order = np.lexsort((ot, og))
        sg, sv = og[order], ov[order]
        m = len(sv)
        new_seg = np.r_[True, sg[1:] != sg[:-1]]
        seg_start = np.maximum.accumulate(
            np.where(new_seg, np.arange(m), 0))
        c = np.cumsum(sv)
        a = np.maximum(np.arange(m) - w + 1, seg_start)
        s = c - np.where(a > 0, c[a - 1], 0.0)
        cnt = np.arange(m) - a + 1
        return s, s / cnt, cnt

    us_o = _timeit(oracle, iters=3)
    _emit("window_rolling_200k_oracle", us_o,
          f"window_{us_o / us:.2f}x_faster")


def bench_topk(n: int = 500_000, k: int = 64):
    """Top-k via per-shard candidates + tree-reduce merge — no global
    sort of the 500k rows ever happens off a single shard's lexsort."""
    dt = _table(n)
    jfn = jax.jit(lambda t: table_ops.topk(t, "v", k, ctx=CTX))
    us = _timeit(jfn, dt, iters=3)
    _emit("topk_500k", us, f"{n / (us * 1e-6) / 1e6:.1f}Mrow/s")
    _attach_telemetry("topk_500k", jfn, dt, us=us)


def bench_setop_union(n: int = 200_000):
    """Set-op union at ``n`` rows per side: concat + sort-free hash dedup
    over the carried full-row hashes (DESIGN.md §8)."""
    dt = _table(n)
    dt2 = _table(n, seed=1)
    jfn = jax.jit(lambda a, b: table_ops.union(a, b, ctx=CTX))
    us = _timeit(jfn, dt, dt2, iters=3)
    _emit("setop_union_200k", us, f"{2 * n / (us * 1e-6) / 1e6:.1f}Mrow/s")


def bench_mds():
    """Paper Figs 14/15: table-prep + SMACOF MDS composition."""
    from repro.apps.mds import mds_pipeline
    for n in (64, 128, 256):
        t0 = time.perf_counter()
        path, emb = mds_pipeline(n_points=n, dim=2, iters=20, ctx=CTX)
        dt = (time.perf_counter() - t0) * 1e6
        _emit(f"fig15_mds_{n}pts", dt, f"stress={path[-1]:.3f}")


def bench_lm_step():
    """Framework: LM train + decode step at reduced config (CPU)."""
    from repro.configs import get_config, reduced_config
    from repro.train.optimizer import OptimizerConfig
    from repro.train.train_step import (TrainConfig, init_train_state,
                                        make_train_step)

    for arch in ("smollm-360m", "mixtral-8x7b", "xlstm-125m"):
        cfg = reduced_config(get_config(arch))
        state = init_train_state(jax.random.PRNGKey(0), cfg)
        step = jax.jit(make_train_step(
            cfg, TrainConfig(optimizer=OptimizerConfig())))
        b, s = 4, 128
        rng = jax.random.PRNGKey(1)
        batch = {"tokens": jax.random.randint(rng, (b, s), 0,
                                              cfg.vocab_size)}
        batch["labels"] = batch["tokens"]
        us = _timeit(lambda: step(state, batch)[1]["loss"], iters=3)
        _emit(f"lm_train_step_{arch}", us,
              f"{b * s / (us * 1e-6):.0f}tok/s")


def bench_kernels():
    """Pallas kernels (interpret) vs jnp reference wall time."""
    from repro.kernels.flash_attention import ops as fops
    from repro.kernels.segment_reduce import ops as sops

    q = jnp.ones((1, 4, 256, 64), jnp.float32)
    k = v = jnp.ones((1, 2, 256, 64), jnp.float32)
    us_ref = _timeit(jax.jit(
        lambda a, b, c: fops.flash_attention(a, b, c, force="ref")), q, k, v)
    _emit("kernel_flash_ref_xla", us_ref, "256x256")

    vals = jnp.ones((1 << 16,), jnp.float32)
    segs = jnp.zeros((1 << 16,), jnp.int32)
    us = _timeit(jax.jit(lambda a, b: sops.segment_reduce(a, b, 512,
                                                          force="ref")),
                 vals, segs)
    _emit("kernel_segreduce_ref_xla", us, "65k_rows")


def bench_scan_ingest(n: int = 500_000):
    """Storage-layer ingest (DESIGN.md §5): cold scan of an on-disk
    dataset, full vs projection+predicate pushdown.

    Host I/O + table assembly is the measured path (no jit): this is the
    realistic "data lands on disk, enters the operator world" cost the
    paper's §VI interop argument is about.  The pushdown case projects 2
    of 6 columns and prunes ~2/3 of the fragments via min/max stats.
    """
    import shutil
    import sys
    import tempfile

    sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                    "..", "scripts"))
    from make_dataset import make_events_dataset

    from repro.io import ScanSource, has_pyarrow, pred

    fmts = ["hpt"] + (["parquet"] if has_pyarrow() else [])
    for fmt in fmts:
        root = tempfile.mkdtemp(prefix=f"hptmt_bench_{fmt}_")
        try:
            make_events_dataset(root, n_rows=n, fmt=fmt,
                                rows_per_group=max(n // 16, 1))
            events = os.path.join(root, "events")

            def full_scan():
                src = ScanSource(events, ctx=CTX)
                return src.to_dist_table()[0].counts

            def pushdown_scan():
                src = ScanSource(events, ctx=CTX,
                                 columns=["user_id", "value"],
                                 predicate=pred("day", "<", 10))
                return src.to_dist_table()[0].counts

            us = _timeit(full_scan, iters=3)
            _emit(f"ingest_scan_{fmt}", us,
                  f"{n / (us * 1e-6) / 1e6:.1f}Mrow/s")
            us = _timeit(pushdown_scan, iters=3)
            _emit(f"ingest_scan_{fmt}_pushdown", us,
                  f"{n / (us * 1e-6) / 1e6:.1f}Mrow/s")
        finally:
            shutil.rmtree(root, ignore_errors=True)



def bench_planned_pipeline(n: int = 500_000):
    """Planned vs eager pipeline (DESIGN.md §11): scan → filter → groupby.

    Both cases run the same user chain over the same on-disk events
    dataset.  The eager API executes each call as issued — a full-width
    scan of every fragment, then the filter, then the groupby exchange.
    The lazy API plans the whole pipeline first: the day-range predicate
    lands in the scan (fragment pruning via manifest min/max + residual
    mask), the scan reads only the 3 of 6 columns the pipeline touches,
    and the groupby runs on what is left.  End-to-end host wall time
    (I/O included, no jit of the I/O path) — the planner's win is the
    work it never does.  Acceptance: planned ≥ 1.3x, recorded in the
    derived field; wall time rides the regression gate like every case.
    """
    import shutil
    import sys as _sys
    import tempfile

    _sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "scripts"))
    from make_dataset import make_events_dataset

    from repro.dataframe.frame import DataFrame
    from repro.io import pred
    from repro.plan import LazyFrame

    root = tempfile.mkdtemp(prefix="hptmt_bench_plan_")
    try:
        make_events_dataset(root, n_rows=n, fmt="hpt",
                            rows_per_group=max(n // 16, 1))
        events = os.path.join(root, "events")
        aggs = [("value", "sum"), ("value", "count")]

        def eager():
            df = DataFrame.read_parquet(events, CTX)
            return (df.select(lambda c: c["day"] < 10)
                    .groupby(["user_id"], aggs).table.counts)

        def planned():
            return (LazyFrame.read_parquet(events, CTX)
                    .filter([pred("day", "<", 10)])
                    .groupby(["user_id"], aggs)
                    .collect(jit=False).table.counts)

        us_p = _timeit(planned, iters=3)
        _emit("planned_pipeline", us_p,
              f"{n / (us_p * 1e-6) / 1e6:.1f}Mrow/s")
        us_e = _timeit(eager, iters=3)
        _emit("planned_pipeline_eager", us_e,
              f"planned_{us_e / us_p:.2f}x_faster")
    finally:
        shutil.rmtree(root, ignore_errors=True)


def bench_spill_join(n: int = 2_000_000, budget_rows: int = 262_144):
    """Out-of-core join: input far beyond the committed per-step budget.

    The acceptance case for DESIGN.md §10: an ``n``-row probe side joined
    at a ``budget_rows`` working-set cap — the spill engine must complete
    it exactly (row count cross-checked against a numpy membership oracle,
    zero residual overflow) while peak RSS stays under the committed
    ``SPILL_RSS_BUDGET_MB``.  The result is consumed chunk-wise, never
    materialized whole.  Wall time rides the regression gate like every
    other case; the RSS cap failure is collected in ``RSS_VIOLATIONS``
    and fails the run at the end of main().
    """
    from repro.spill import spill_join

    rng = np.random.default_rng(3)
    n_keys = n // 4
    lk = rng.integers(0, n_keys, n).astype(np.int32)
    rk = rng.permutation(n_keys)[: n_keys // 2].astype(np.int32)  # unique
    left = DistTable.from_local(Table.from_arrays(
        {"k": jnp.asarray(lk), "v": jnp.asarray(lk, jnp.float32)}), CTX)
    right = DistTable.from_local(Table.from_arrays(
        {"k": jnp.asarray(rk), "w": jnp.asarray(rk, jnp.float32)}), CTX)
    expected = int(np.isin(lk, rk).sum())  # right keys unique: 1 match/row

    _reset_peak_rss()
    t0 = time.perf_counter()
    res = spill_join(left, right, ("k",), ctx=CTX, budget_rows=budget_rows)
    rows_out = 0
    for chunk in res.chunks():  # chunk-wise consumption, bounded memory
        rows_out += int(chunk.num_rows())
    report, stats = res.report, res.stats
    res.close()
    us = (time.perf_counter() - t0) * 1e6
    peak = _peak_rss_mb()

    name = f"spill_join_{n // 1000}k_budget{budget_rows // 1024}k"
    assert report.is_exact(), f"residual overflow: {report}"
    assert rows_out == expected, (rows_out, expected)
    _emit(name, us, f"{n / (us * 1e-6) / 1e6:.1f}Mrow/s "
                    f"parts={stats.n_parts} "
                    f"spilled={stats.bytes_spilled >> 20}MB")
    if peak > SPILL_RSS_BUDGET_MB:
        RSS_VIOLATIONS.append((name, peak))
        print(f"# RSS VIOLATION: {name} peaked at {peak:.0f}MB "
              f"> committed {SPILL_RSS_BUDGET_MB:.0f}MB budget", flush=True)


def bench_telemetry_overhead(n: int = 500_000, rounds: int = 15):
    """Telemetry overhead contract (DESIGN.md §12): collector on vs off.

    Both legs run the identical pre-jitted 500k shuffle, blocking every
    call; the ON leg additionally activates a collector and wraps each
    call in a span (open, ``block_until_ready``, close — everything the
    instrumentation adds around a jit boundary).  Legs are interleaved
    and compared best-of-``rounds`` so runner noise cancels instead of
    deciding the gate; a trip re-measures once at double rounds before
    counting (a genuine per-span cost reproduces; a one-off scheduler /
    page-cache spike right after the spill bench does not).  The ratio
    must stay under ``TELEMETRY_OVERHEAD_BUDGET_PCT`` or main() exits
    non-zero.
    """
    from repro import telemetry

    dt = _table(n)
    jfn = jax.jit(lambda t: table_ops.shuffle(t, ["k"], ctx=CTX))
    for _ in range(3):
        jax.block_until_ready(jfn(dt))

    def leg_off() -> float:
        t0 = time.perf_counter()
        jax.block_until_ready(jfn(dt))
        return time.perf_counter() - t0

    def leg_on() -> float:
        with telemetry.trace("bench-overhead") as rec:
            t0 = time.perf_counter()
            with rec.span("bench.shuffle") as sp:
                sp.block(jfn(dt))
            return time.perf_counter() - t0

    def measure(k: int):
        offs, ons = [], []
        for _ in range(k):
            offs.append(leg_off())
            ons.append(leg_on())
        return min(offs), min(ons)

    best_off, best_on = measure(rounds)
    overhead = best_on / best_off - 1.0
    if overhead * 100 > TELEMETRY_OVERHEAD_BUDGET_PCT:
        off2, on2 = measure(rounds * 2)
        best_off, best_on = min(best_off, off2), min(best_on, on2)
        overhead = best_on / best_off - 1.0
    name = "telemetry_overhead_500k"
    _emit(name, best_off * 1e6, f"overhead_{overhead * 100:.2f}pct")
    if overhead * 100 > TELEMETRY_OVERHEAD_BUDGET_PCT:
        TELEMETRY_VIOLATIONS.append((name, overhead * 100))
        print(f"# TELEMETRY OVERHEAD VIOLATION: {name} on/off = "
              f"{overhead:+.2%} > {TELEMETRY_OVERHEAD_BUDGET_PCT:.0f}% "
              f"budget", flush=True)


def write_ledger(path: str) -> None:
    """Append each case's record to the cross-run JSONL ledger
    (``scripts/perf_report.py`` renders the per-fingerprint deltas)."""
    from repro.telemetry import ledger

    for name, us, derived, rss in ROWS:
        rec = ledger.bench_record(
            name, us, derived=derived,
            peak_rss_mb=None if rss != rss else round(rss, 1),
            telemetry=TELEMETRY.get(name))
        ledger.append(path, rec)
    print(f"# appended {len(ROWS)} record(s) to {path}", flush=True)


def write_telemetry(path: str) -> None:
    """The per-bench collective audits as one JSON artifact (CI uploads
    this next to the perf record)."""
    with open(path, "w") as f:
        json.dump(TELEMETRY, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}", flush=True)


def write_json(path: str, merge: bool = False) -> None:
    """Machine-readable perf record (name → µs + derived metric).

    ``merge=True`` updates only the cases that ran into an existing file
    (the ``--spill-only`` job must not clobber the committed baseline's
    other entries)."""
    data = {}
    if merge and os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    for name, us, derived, rss in ROWS:
        rec = {"us_per_call": round(us, 1), "derived": derived,
               "peak_rss_mb": round(rss, 1)}
        if name in TELEMETRY:
            rec["telemetry"] = TELEMETRY[name]
        data[name] = rec
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}", flush=True)


def compare_json(base: dict, baseline_name: str, threshold: float,
                 min_delta_us: float = 1000.0) -> int:
    """Regression gate: fail when any case slows >threshold vs baseline.

    ``base`` is the PRELOADED baseline record — callers read it before any
    ``write_json`` so that ``--compare X --out X`` (or the default ``--out``
    pointing at the committed baseline) can never compare a run against
    its own freshly-written copy.

    Only cases present in both the fresh run and the committed baseline are
    compared (quick-mode runs a subset at smaller sizes, so a quick number
    beating a full-size baseline is expected; what the gate catches is the
    catastrophic class — retrace-per-call, lost fusion, accidental
    quadratic paths — which blow far past the margin in either mode).
    A slowdown must exceed the relative threshold AND ``min_delta_us`` of
    absolute regression: overhead-dominated microsecond cases (project,
    scalar aggregate) jitter past 30% from dispatch noise alone on slower
    runners, while every real regression class costs milliseconds.
    Returns the number of regressions; prints a per-case delta table.
    """
    regressions = []
    print(f"# compare vs {baseline_name} "
          f"(fail > {threshold:+.0%} and > {min_delta_us:.0f}us)")
    for name, us, *_ in ROWS:
        if name not in base:
            print(f"# {name}: no baseline, skipped")
            continue
        ref = base[name]["us_per_call"]
        delta = us / ref - 1.0
        regressed = delta > threshold and us - ref > min_delta_us
        flag = " REGRESSION" if regressed else ""
        print(f"# {name}: {us:.1f}us vs {ref:.1f}us ({delta:+.1%}){flag}")
        if regressed:
            regressions.append(name)
    if regressions:
        print(f"# FAILED: {len(regressions)} case(s) regressed "
              f">{threshold:.0%}: {', '.join(regressions)}")
    else:
        print("# regression gate passed")
    return len(regressions)


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true",
                   help="small sizes, shuffle-relevant benches only (CI)")
    p.add_argument("--out", default=DEFAULT_JSON,
                   help="path for the JSON perf record")
    p.add_argument("--compare", metavar="BASELINE.json",
                   help="fail when any case regresses vs this record")
    p.add_argument("--threshold", type=float, default=0.30,
                   help="relative slowdown tolerated by --compare")
    p.add_argument("--min-delta-us", type=float, default=1000.0,
                   help="absolute slowdown (us) below which --compare "
                        "treats a relative regression as noise")
    p.add_argument("--spill-only", action="store_true",
                   help="run only the memory-capped out-of-core spill "
                        "case at full size (the CI spill job)")
    p.add_argument("--telemetry-out", metavar="TELEMETRY.json",
                   help="also write the per-bench collective audits "
                        "(compiled-HLO counts/bytes, roofline fraction) "
                        "as a standalone JSON artifact")
    p.add_argument("--ledger", metavar="LEDGER.jsonl",
                   help="append one run-history record per case to this "
                        "JSONL ledger (keyed bench:<case>) for "
                        "scripts/perf_report.py cross-run deltas")
    p.add_argument("--compare-files", nargs=2, metavar=("FRESH", "BASELINE"),
                   help="compare two existing records (no benches run): "
                        "the like-for-like gate — both sides same sizes, "
                        "same machine (CI runs the PR base for BASELINE)")
    args = p.parse_args(argv)

    if args.compare_files:
        fresh_path, baseline_path = args.compare_files
        with open(fresh_path) as f:
            for name, rec in json.load(f).items():
                ROWS.append((name, rec["us_per_call"], rec["derived"],
                             rec.get("peak_rss_mb", float("nan"))))
        with open(baseline_path) as f:
            base = json.load(f)
        if compare_json(base, baseline_path, args.threshold,
                        args.min_delta_us):
            raise SystemExit(1)
        return

    # read the baseline BEFORE running/writing anything: with the default
    # --out both paths may name the committed baseline, and comparing a
    # run against its own fresh copy would make the gate vacuous
    base = None
    if args.compare:
        with open(args.compare) as f:
            base = json.load(f)

    print("name,us_per_call,derived,peak_rss_mb")
    if args.spill_only:
        bench_spill_join()
        write_json(args.out, merge=True)
        if args.ledger:
            write_ledger(args.ledger)
        if RSS_VIOLATIONS:
            print(f"# FAILED: peak RSS over the {SPILL_RSS_BUDGET_MB:.0f}MB "
                  "budget: " + ", ".join(f"{n}={p:.0f}MB"
                                         for n, p in RSS_VIOLATIONS))
            raise SystemExit(1)
        return
    if args.quick:
        bench_table_ops(n=20_000)
        bench_shuffle(n=50_000)
        bench_groupby_lowcard(n=20_000, n_keys=200)
        bench_join_then_groupby(n=20_000)
        bench_join_scaling(sizes=(20_000, 40_000))
        bench_join_highdup(n=20_000, n_keys=200)
        bench_orderby(n=50_000)
        bench_window_rolling(n=20_000, n_part=200)
        bench_topk(n=50_000)
        bench_setop_union(n=20_000)
        bench_scan_ingest(n=50_000)
        bench_planned_pipeline(n=50_000)
        bench_spill_join(n=400_000, budget_rows=65_536)
        bench_telemetry_overhead()  # full 500k: the committed contract
    else:
        bench_array_ops()
        bench_table_ops()
        bench_shuffle()
        bench_groupby_lowcard()
        bench_join_then_groupby()
        bench_join_scaling()
        bench_join_highdup()
        bench_orderby()
        bench_window_rolling()
        bench_topk()
        bench_setop_union()
        bench_mds()
        bench_lm_step()
        bench_kernels()
        bench_scan_ingest()
        bench_planned_pipeline()
        bench_spill_join()
        bench_telemetry_overhead()
    write_json(args.out)
    if args.telemetry_out:
        write_telemetry(args.telemetry_out)
    if args.ledger:
        write_ledger(args.ledger)
    print(f"# {len(ROWS)} benchmarks complete")
    failures = 0
    if base is not None:
        failures += compare_json(base, args.compare, args.threshold,
                                 args.min_delta_us)
    if RSS_VIOLATIONS:
        print(f"# FAILED: {len(RSS_VIOLATIONS)} case(s) over the "
              f"{SPILL_RSS_BUDGET_MB:.0f}MB RSS budget: "
              + ", ".join(f"{n}={p:.0f}MB" for n, p in RSS_VIOLATIONS))
        failures += len(RSS_VIOLATIONS)
    if TELEMETRY_VIOLATIONS:
        print(f"# FAILED: {len(TELEMETRY_VIOLATIONS)} case(s) over the "
              f"{TELEMETRY_OVERHEAD_BUDGET_PCT:.0f}% telemetry overhead "
              "budget: " + ", ".join(f"{n}={p:+.2f}%"
                                     for n, p in TELEMETRY_VIOLATIONS))
        failures += len(TELEMETRY_VIOLATIONS)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
