"""Batched serving example: prefill + lockstep decode with typed caches.

Demonstrates all four cache families the decode shape-cells exercise:
full KV (phi3), sliding-window ring (mixtral), MLA latent (minicpm3), and
SSM/xLSTM state (xlstm) — at reduced configs so it runs on CPU in seconds.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import transformer as T
from repro.serve.engine import Engine, ServeConfig


def main():
    rng = np.random.default_rng(0)
    for arch in ("phi3-mini-3.8b", "mixtral-8x7b", "minicpm3-4b",
                 "xlstm-125m"):
        cfg = reduced_config(get_config(arch))
        params = T.init_lm(jax.random.PRNGKey(7), cfg)
        engine = Engine(cfg, params, ServeConfig(max_len=64,
                                                 temperature=0.0))
        prompts = jnp.asarray(rng.integers(1, cfg.vocab_size, (4, 12)),
                              jnp.int32)
        t0 = time.perf_counter()
        out = engine.generate(prompts, n_tokens=16)
        dt = time.perf_counter() - t0
        tps = out.size / dt
        kinds = "/".join(sorted(set(cfg.block_pattern)))
        print(f"{arch:18s} cache={kinds:12s} generated {out.shape} "
              f"in {dt:.2f}s ({tps:.0f} tok/s)  sample={out[0, :8].tolist()}")
        assert out.shape == (4, 16)
        assert np.all((out >= 0) & (out < cfg.vocab_size))
    print("serve_lm OK")


if __name__ == "__main__":
    main()
