"""Paper Figs 14/15: dataflow table operators feeding an array-operator MDS.

The exact composition the paper demonstrates with Twister2 + MPI:
table preprocessing produces the (row-partitioned) distance matrix, SMACOF
MDS iterates with array operators.  ``repro.apps.mds`` holds the logic; this
driver reports the stress trajectory (the paper's correctness signal) and
timing (its Fig 15 measurement, single-host here).

Run:  PYTHONPATH=src python examples/mds_pipeline.py [n_points]
"""
import sys
import time

import numpy as np

from repro.apps.mds import mds_pipeline
from repro.core import local_context


def main():
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 128
    ctx = local_context()
    t0 = time.perf_counter()
    stress_path, embedding = mds_pipeline(n_points=n, dim=2, iters=50,
                                          ctx=ctx, seed=0)
    dt = time.perf_counter() - t0
    print(f"n_points={n}  iters=50  wall={dt:.2f}s")
    print(f"stress: {stress_path[0]:.4f} → {stress_path[-1]:.4f} "
          f"({stress_path[-1] / stress_path[0]:.1%} of initial)")
    print(f"embedding shape: {embedding.shape}, "
          f"finite: {bool(np.all(np.isfinite(np.asarray(embedding))))}")
    assert stress_path[-1] < stress_path[0]
    print("mds_pipeline OK")


if __name__ == "__main__":
    main()
