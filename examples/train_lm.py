"""End-to-end training driver: HPTMT table pipeline → LM training with
checkpoint/restart, straggler monitoring, and workflow orchestration.

Presets:
  cpu-tiny (default)  — family-preserving reduced smollm, ~300 steps on CPU
  100m                — real smollm-360m-class config (~100M active params
                        at seq 512); sized for accelerators, runnable here
                        with --steps 3 as a smoke

Run:  PYTHONPATH=src python examples/train_lm.py [--preset cpu-tiny]
          [--steps N] [--ckpt DIR]
Re-running with the same --ckpt resumes from the last checkpoint
(workflow-level fault tolerance, paper §VII-F — try Ctrl-C mid-run).
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config, reduced_config
from repro.core import local_context
from repro.data.pipeline import CorpusConfig, make_training_data
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import TrainConfig
from repro.train.trainer import LoopConfig, train_loop


def build_config(preset: str):
    base = get_config("smollm-360m")
    if preset == "cpu-tiny":
        cfg = reduced_config(base)
        cfg = dataclasses.replace(cfg, n_layers=4, d_model=128, n_heads=4,
                                  n_kv_heads=2, d_head=32, d_ff=512,
                                  vocab_size=512)
        return cfg, 8, 64          # batch, seq
    if preset == "100m":
        # ~100M params: 12L × 768 (GPT-2-small class, llama-style blocks)
        cfg = dataclasses.replace(
            base, n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
            d_head=64, d_ff=2048, vocab_size=32000, tie_embeddings=True)
        return cfg, 16, 512
    raise SystemExit(f"unknown preset {preset}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="cpu-tiny",
                    choices=["cpu-tiny", "100m"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg, batch, seq = build_config(args.preset)
    n_params = cfg.param_count()
    print(f"preset={args.preset}  params≈{n_params/1e6:.1f}M  "
          f"batch={batch} seq={seq}")

    ctx = local_context()
    data = make_training_data(
        cfg, ctx, batch=batch, seq_len=seq,
        ccfg=CorpusConfig(n_docs=256, mean_doc_len=192,
                          vocab_size=cfg.vocab_size))

    tcfg = TrainConfig(optimizer=OptimizerConfig(
        learning_rate=1e-3, warmup_steps=max(args.steps // 20, 2),
        total_steps=args.steps))
    loop = LoopConfig(total_steps=args.steps, log_every=10,
                      checkpoint_every=max(args.steps // 4, 10),
                      checkpoint_dir=args.ckpt)
    state = train_loop(cfg, tcfg, loop, data)
    hist = train_loop.last_history
    print(f"loss {hist[0]:.3f} → {hist[-1]:.3f} over {len(hist)} steps")
    print("train_lm OK")


if __name__ == "__main__":
    main()
