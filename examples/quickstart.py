"""Quickstart: the HPTMT operator architecture in one file.

Mirrors the paper's Fig 17: table operators (Cylon-style DataFrame) curate
data, the ``to_numpy``/``to_jax`` bridge hands it to array land, a gradient
loop runs on array operators, and the model "synchronizes" with AllReduce —
all the same code single-device or on a mesh.

Part 2 adds the storage layer (DESIGN.md §5): a generated on-disk dataset
is scanned back with projection + predicate pushdown, joined, aggregated,
and bridged to arrays — write → scan → join → groupby → ``to_jax()``.
Part 6 runs a join whose working set exceeds its memory budget through
the out-of-core spill path (DESIGN.md §10) — same API, ``spill="auto"``.
Part 7 plans the same kind of pipeline lazily (DESIGN.md §11): the
rewriter pushes the filter and projection into the scan and ``explain()``
shows the plan before and after optimization.
Part 8 re-runs the planned pipeline under a telemetry collector
(DESIGN.md §12): the plan-vs-observed collective audit, per-node
measured times via ``explain(analyze=True)``, and a Chrome-trace export.
Part 9 makes the same pipeline fault-tolerant (DESIGN.md §13): a chaos
fault absorbed by ``FaultPolicy`` retries, stage checkpoints that let a
killed run resume bit-exactly, and fragment quarantine for corrupt data.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import local_context, array_ops
from repro.dataframe.frame import DataFrame

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "scripts"))
from make_dataset import make_events_dataset  # noqa: E402


def main():
    ctx = local_context()
    rng = np.random.default_rng(0)

    # --- 1. table operators (paper Fig 17 lines 6-17) ----------------------
    n = 2000
    people = DataFrame.from_dict({
        "id": np.arange(n, dtype=np.int32),
        "severity": rng.uniform(0, 4, n).astype(np.float32),
    }, ctx)
    vitals = DataFrame.from_dict({
        "id": rng.permutation(n).astype(np.int32),
        "temperature": (37.0 + rng.normal(0, 0.8, n)).astype(np.float32),
    }, ctx)

    joined = people.join(vitals, on=["id"])
    feverish = joined.select(lambda c: c["temperature"] > 37.5)
    print(f"rows after join: {len(joined)}, feverish: {len(feverish)}")
    stats = feverish.groupby([], [("severity", "mean")]) \
        if False else None
    print(f"mean severity (feverish): "
          f"{feverish.agg('severity', 'mean'):.3f}")

    # --- 2. bridge to arrays (Fig 17 line 18) ------------------------------
    mat = joined.to_jax(["temperature", "severity"])
    x, y = mat[:, 0:1], mat[:, 1]
    x = (x - 37.0)

    # --- 3. array operators: polynomial regression (Fig 17 lines 19-39) ----
    feats = jnp.concatenate([jnp.ones_like(x), x, x**2, x**3], axis=1)
    w = jnp.zeros((4,))

    @jax.jit
    def step(w):
        pred = feats @ w
        grad = feats.T @ (pred - y) / len(y)
        return w - 0.1 * grad

    for i in range(200):
        w = step(w)

    # model sync via the AllReduce array operator (identity on 1 shard,
    # mean across data-parallel shards on a mesh — same code either way)
    w_synced = array_ops.allreduce(w[None], ctx=ctx, op="mean")
    loss = float(jnp.mean((feats @ w_synced - y) ** 2))
    print(f"fitted w={np.asarray(w_synced).round(3)}  mse={loss:.4f}")
    assert np.isfinite(loss)

    # --- 4. storage layer: write → scan → join → groupby → to_jax ----------
    # (DESIGN.md §5; paper §VI names Arrow/Parquet as the interop keystone)
    from repro.io import pred

    with tempfile.TemporaryDirectory() as root:
        make_events_dataset(root, n_rows=20_000, n_users=200, seed=1)
        # pushdown scan: only 3 of 6 event columns materialize, and whole
        # fragments outside the day range are skipped via min/max stats
        events = DataFrame.read_parquet(
            os.path.join(root, "events"), ctx,
            columns=["user_id", "day", "value"],
            predicate=pred("day", "<", 7))
        users = DataFrame.read_parquet(os.path.join(root, "users"), ctx)
        print(f"scanned events: {len(events)} rows (day<7), "
              f"users: {len(users)}")

        per_user = (events.join(users, on=["user_id"])
                    .groupby(["segment"], [("value", "mean"),
                                           ("value", "count")]))
        mat = per_user.to_jax(["value_mean", "value_count"])
        weighted = float(jnp.sum(mat[:, 0] * mat[:, 1]) / jnp.sum(mat[:, 1]))
        print(f"segments: {len(per_user)}, "
              f"count-weighted mean value: {weighted:.4f}")
        assert np.isfinite(weighted)

    # --- 5. ordered analytics: orderby → rolling window (DESIGN.md §9) ----
    # One sample sort establishes the range layout; the window functions
    # then run with zero further exchanges and zero sorts — the ordered
    # twin of the join→groupby elision above.
    m = 5000
    ticks = DataFrame.from_dict({
        "symbol": rng.integers(0, 8, m).astype(np.int32),
        "ts": rng.permutation(m).astype(np.int32),
        "price": (100 + np.cumsum(rng.normal(0, 0.5, m))).astype(np.float32),
    }, ctx)
    ordered = ticks.sort_values(["symbol", "ts"])     # ONE exchange
    assert ordered.partitioning_kind == "range"
    feats = ordered.window(["symbol"], ["ts"]).agg(
        [("price", "mean"), ("price", "min"), ("price", "max"),
         ("price", "lag"), (None, "row_number")], rows=20)  # ZERO more
    spread = feats.to_jax(["price_max", "price_min"])
    print(f"rolling 20-tick max spread: "
          f"{float(jnp.max(spread[:, 0] - spread[:, 1])):.3f}")
    p75 = ordered.quantile("price", 0.75, method="exact")
    movers = feats.select(lambda c: c["price_mean"] > p75)
    print(f"p75 price {p75:.2f}; ticks with rolling mean above: "
          f"{len(movers)}")
    top = ticks.topk("price", 5)
    print(f"top-5 prices: {np.asarray(top.to_numpy()['price']).round(2)}")

    # --- 6. out-of-core: a join bigger than its memory budget (§10) --------
    # The same join API, but the working set is capped at budget_rows: the
    # inputs hash-partition to disk, each partition-pair streams through
    # the in-memory engine with its shuffle elided, and the chunks merge
    # back — bit-exact, with the OverflowReport as the certificate.
    big = 50_000
    k = rng.integers(0, big // 4, big).astype(np.int32)
    orders = DataFrame.from_dict(
        {"k": k, "amount": rng.uniform(0, 9, big).astype(np.float32)}, ctx)
    dims = DataFrame.from_dict(
        {"k": np.arange(big // 8, dtype=np.int32),
         "rate": rng.uniform(0, 1, big // 8).astype(np.float32)}, ctx)
    enriched = orders.join(dims, on=["k"], spill="auto", budget_rows=4096)
    rep = enriched.overflow_report
    rep.assert_exact()        # zero rows lost — spill recovered every one
    print(f"out-of-core join: {len(enriched)} rows at a 4096-row budget "
          f"({rep.total_recovered} rows spill-recovered); "
          f"exact={rep.is_exact()}")

    # --- 7. the lazy planner: whole-pipeline optimization (§11) ------------
    # The same scan→filter→groupby→orderby chain as the eager parts, but
    # nothing runs until collect(): the rewriter pushes the predicate and
    # the projection into the scan (fragment pruning + narrowed reads) and
    # picks a range layout for the groupby so the final sort is local.
    from repro.plan import LazyFrame

    with tempfile.TemporaryDirectory() as root:
        make_events_dataset(root, n_rows=20_000, n_users=200, seed=2)
        lazy = (LazyFrame.read_parquet(os.path.join(root, "events"), ctx)
                .filter([pred("day", "<", 7)])
                .groupby(["user_id"], [("value", "sum")])
                .sort_values("user_id"))
        print("-- plan before optimization --")
        print("\n".join(lazy.explain(optimized=False)
                        .splitlines()[:6]))      # the naive logical tree
        print("-- plan after optimization --")
        print(lazy.explain())                    # rewrites + strategies
        daily = lazy.collect()                   # ONE traced program
        print(f"planned pipeline: {len(daily)} rows, "
              f"exact={daily.overflow_report.is_exact()}")

        # --- 8. telemetry: spans, metrics, plan-vs-observed (§12) ----------
        # Off by default (zero overhead); under an active collector every
        # operator/plan-node/scan becomes a span, overflow and scan facts
        # land as metrics, and collect() audits the planner's predicted
        # exchange count against the traced jaxpr AND the compiled HLO.
        from repro import telemetry

        with telemetry.trace("quickstart") as rec:
            lazy.collect(telemetry=rec, jit=False)
        audit = rec.audits[-1]
        print(f"collective audit: predicted={audit['predicted_a2a']} "
              f"traced={audit['traced_a2a']} "
              f"observed={audit['observed_a2a']} "
              f"(consistent={audit['consistent']})")
        assert audit["consistent"]
        print("-- explain(analyze=True): measured times/rows per node --")
        print(lazy.explain(analyze=True).split("== physical plan ==")[1])
        trace_path = os.path.join(root, "trace.json")
        telemetry.export_chrome_trace(rec, trace_path)  # Perfetto-loadable
        snap = telemetry.metrics_snapshot(rec)
        print(f"chrome trace: {snap['n_spans']} spans; metrics: "
              f"{len(snap['metrics']['counters'])} counters, "
              f"{len(snap['metrics']['gauges'])} gauges")

        # --- 9. fault tolerance: chaos, retry, kill-and-resume (§13) -------
        # A FaultPolicy turns the same collect() fault-tolerant: transient
        # IO faults retry with deterministic backoff, and every exchange
        # boundary commits a fingerprinted stage snapshot, so a killed
        # process resumes from the last committed stage — bit-exact.
        from repro.resilience import FaultPolicy, arm, faults

        arm("scan.read", "io_error")          # chaos: next scan read fails
        ckdir = os.path.join(root, "stages")
        pol = FaultPolicy(max_retries=2, checkpoint_dir=ckdir,
                          keep_checkpoints=True)
        with telemetry.trace("resilient") as rec2:
            safe = lazy.collect(policy=pol, telemetry=rec2)
        assert (safe.to_numpy()["value_sum"]
                == daily.to_numpy()["value_sum"]).all()
        print(f"resilient collect: retried "
              f"{rec2.metrics.counters.get('retry.scan.read', 0)} scan "
              f"read(s), committed "
              f"{rec2.metrics.counters.get('recovery.stages_committed', 0)}"
              f" stage checkpoint(s)")
        # a re-run (as after a crash) restores the stage instead of
        # recomputing the scan/filter/groupby prefix
        with telemetry.trace("resumed") as rec3:
            again = lazy.collect(policy=pol, telemetry=rec3)
        assert (again.to_numpy()["value_sum"]
                == daily.to_numpy()["value_sum"]).all()
        print(f"resumed collect: restored "
              f"{rec3.metrics.counters.get('recovery.stages_restored', 0)} "
              f"stage(s) from {ckdir}")
        faults.reset()

        # corrupt fragments quarantine instead of raising when opted in:
        # the scan skips the bad run, counts what it dropped, and writes
        # a sidecar manifest next to the dataset
        from repro.io.dataset import write_dataset

        small = os.path.join(root, "small_hpt")
        write_dataset(small, [({"g": (np.arange(64) % 4).astype(np.float32),
                                "x": np.arange(64, dtype=np.float32)}, 64)],
                      format="hpt", rows_per_group=8)
        frag = sorted(f for f in os.listdir(small)
                      if f.endswith(".hpt"))[0]
        with open(os.path.join(small, frag), "r+b") as f:
            f.truncate(f.seek(0, 2) - 16)            # tear the last pages
        with telemetry.trace("quarantine") as rec4:
            partial = (LazyFrame.read_parquet(small, ctx,
                                              on_error="quarantine")
                       .groupby(["g"], [("x", "sum")])
                       .collect(strict=False, telemetry=rec4))
        print(f"quarantined scan: {len(partial)} rows kept, "
              f"{int(rec4.metrics.counters['scan.rows_quarantined'])} "
              f"rows quarantined (see _hptmt_quarantine.json)")

        # --- 10. the query observatory: q-errors, memory, ledger (§14) -----
        # Every plan step carries predicted est_rows/est_bytes next to its
        # observed rows/RSS delta; collect(ledger=...) appends one record
        # per run keyed by plan fingerprint, and scripts/perf_report.py
        # flags cross-run regressions. Slow the second run with a
        # chaos-armed retry (~1.2s backoff) so the report flags it.
        ledger_path = os.path.join(root, "runs.jsonl")
        with telemetry.trace("observatory") as rec5:
            lazy.collect(telemetry=rec5, jit=False, ledger=ledger_path,
                         qerror_threshold=4.0)   # strict cardinality audit
        print(f"cardinality audit: "
              f"{int(rec5.metrics.gauges['cardinality.steps_audited'])} "
              f"steps audited, max q-error "
              f"{rec5.metrics.gauges['cardinality.max_qerror']:.2f}")
        print(lazy.explain(analyze=True)
              .split("predicted collectives")[0]
              .split("== physical plan ==")[1])  # est_rows/qerr/rss= lines

        arm("plan.step.0", "io_error")           # chaos: first step fails
        lazy.collect(ledger=ledger_path, policy=FaultPolicy(
            max_retries=2, backoff_base=1.2, backoff_factor=1.0,
            backoff_max=1.2, jitter=0.0))        # retried run is slower
        faults.reset()

        import subprocess
        import sys as _sys
        report = subprocess.run(
            [_sys.executable,
             os.path.join(os.path.dirname(__file__), "..", "scripts",
                          "perf_report.py"),
             ledger_path, "--gate"],
            capture_output=True, text=True)
        assert report.returncode == 1, "the slowed run must be flagged"
        flagged = [ln for ln in report.stdout.splitlines()
                   if "**TIME**" in ln]
        print("perf report flagged the chaos-slowed run:")
        print("\n".join(flagged))
    print("quickstart OK")


if __name__ == "__main__":
    main()
