"""Plan-contract tests: the lazy planner (repro.plan) vs the eager oracle.

Three layers (DESIGN.md §11):

  * rule units     — each rewrite rule fires exactly when its guard says
                     it may, and ``.explain()`` renders stably
  * parity         — ``lazy().collect()`` is bit-exact against the same
                     eager chain (including a hypothesis property suite
                     with NaN keys, ±0.0 and float32-saturating values)
  * the contract   — on a 4-shard mesh the planned pipeline's traced
                     jaxpr contains exactly ``predicted_collectives``
                     AllToAll ops, never more than the eager chain, and
                     strictly fewer on the representative
                     scan→filter→join→groupby→window shape

tier-1 runs this module on one device (every strategy path still
executes; collective counts clamp to zero); the ``plan-contract`` CI job
re-runs it under ``--xla_force_host_platform_device_count=4`` and the
subprocess test below always self-sets four devices.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 env may lack hypothesis: skip only @given tests
    from conftest import given, settings, st

from repro.core import local_context
from repro.dataframe.frame import DataFrame
from repro.io.scan import pred
from repro.plan import LazyFrame, RULES, estimated_rows, logical, optimize

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _canon(d):
    """Rows as a canonically-ordered uint32 view: bit-exact multiset
    comparison that distinguishes -0.0 from +0.0 and NaN bit patterns."""
    cols = sorted(d)
    views = [np.ascontiguousarray(np.asarray(d[c], np.float32)).view(np.uint32)
             for c in cols]
    order = np.lexsort(tuple(reversed(views))) if views else ()
    return cols, [v[order] for v in views]


def _assert_same_rows(got, exp):
    gc, gv = _canon(got)
    ec, ev = _canon(exp)
    assert gc == ec, f"column sets differ: {gc} vs {ec}"
    for c, a, b in zip(gc, gv, ev):
        np.testing.assert_array_equal(a, b, err_msg=f"column {c}")


def _frames(ctx, seed=0, n=48):
    rng = np.random.default_rng(seed)
    big = {"k1": rng.integers(0, 6, n).astype(np.float32),
           "k2": rng.integers(0, 3, n).astype(np.float32),
           "v": rng.normal(size=n).astype(np.float32)}
    small = {"k1": np.repeat(np.arange(6), 3).astype(np.float32),
             "k2": np.tile(np.arange(3), 6).astype(np.float32),
             "w": rng.normal(size=18).astype(np.float32)}
    return (DataFrame.from_dict(big, ctx, bucket_factor=4.0),
            DataFrame.from_dict(small, ctx, bucket_factor=4.0))


def _hpt_dataset(tmp_path, ctx):
    """8-fragment native dataset; column `a` is globally increasing, so
    range predicates on it prune fragments via manifest min/max."""
    n = 64
    rng = np.random.default_rng(1)
    data = {"a": np.arange(n, dtype=np.float32),
            "b": (np.arange(n) % 8).astype(np.float32),
            "c": rng.normal(size=n).astype(np.float32),
            "d": rng.normal(size=n).astype(np.float32)}
    path = str(tmp_path / "plan_ds")
    DataFrame.from_dict(data, ctx).to_hpt(path, rows_per_group=8)
    return path


# ---------------------------------------------------------------------------
# rewrite-rule units
# ---------------------------------------------------------------------------
def test_rules_registry_matches_docs():
    assert RULES == ("push-filter-through-project",
                     "push-filter-through-join",
                     "push-filter-into-scan",
                     "push-projection-into-scan",
                     "drop-redundant-exchange",
                     "reorder-join-inputs",
                     "choose-range-layout")


def test_push_filter_and_projection_into_scan(tmp_path):
    ctx = local_context()
    path = _hpt_dataset(tmp_path, ctx)
    lf = (LazyFrame.read_parquet(path, ctx)
          .filter([pred("a", "<", 16.0)]).project(["a", "c"]))
    root, fired = optimize(lf.logical_plan)
    assert "push-filter-into-scan" in fired
    assert "push-projection-into-scan" in fired
    assert root.kind == "project" and root.inputs[0].kind == "scan"
    scan = root.inputs[0]
    assert scan.payload["predicate"], "predicate did not reach the scan"
    assert set(scan.payload["columns"]) == {"a", "c"}
    # fragment pruning is visible in the physical plan before any I/O
    txt = lf.explain()
    assert "fragments 2/8" in txt and "push-filter-into-scan" in txt


def test_push_filter_through_project_and_fuse():
    ctx = local_context()
    bf, _ = _frames(ctx)
    lf = (bf.lazy().project(["k1", "v"])
          .filter([pred("v", ">", 0.0)]).filter([pred("k1", "<", 4.0)]))
    root, fired = optimize(lf.logical_plan)
    assert "push-filter-through-project" in fired
    # both predicates fused below the projection, onto the source
    assert root.kind == "project"
    assert root.inputs[0].kind == "filter"
    assert len(root.inputs[0].payload["predicate"]) == 2


def test_push_filter_through_join_inner_only():
    ctx = local_context()
    bf, sf = _frames(ctx)
    inner = (bf.lazy().join(sf.lazy(), ["k1", "k2"], max_matches=4)
             .filter([pred("v", ">", 0.0), pred("w", "<", 1.0)]))
    root, fired = optimize(inner.logical_plan)
    assert "push-filter-through-join" in fired
    assert root.kind == "join"  # filter fully absorbed below the join
    # the same filter above a LEFT join would drop zero-filled unmatched
    # rows if pushed — the rule must not fire
    left = (bf.lazy().join(sf.lazy(), ["k1", "k2"], how="left",
                           max_matches=4).filter([pred("v", ">", 0.0)]))
    _, fired_l = optimize(left.logical_plan)
    assert "push-filter-through-join" not in fired_l


def test_generated_join_columns_never_pushed():
    ctx = local_context()
    bf, sf = _frames(ctx)
    lf = (bf.lazy().join(sf.lazy(), ["k1", "k2"], max_matches=4)
          .filter([pred("_matched", "==", 1.0)]))
    root, fired = optimize(lf.logical_plan)
    assert "push-filter-through-join" not in fired
    assert root.kind == "filter"  # stays above the join as a residual


def test_drop_redundant_exchange():
    ctx = local_context()
    bf, _ = _frames(ctx)
    lf = bf.lazy().repartition(["v"]).groupby(["k1"], [("v", "sum")])
    root, fired = optimize(lf.logical_plan)
    assert "drop-redundant-exchange" in fired
    assert all(n.kind != "repartition" for n in logical.walk(root))
    # a repartition that DOES serve its consumer is kept
    keep = bf.lazy().repartition(["k1"]).groupby(["k1"], [("v", "sum")])
    root_k, fired_k = optimize(keep.logical_plan)
    assert "drop-redundant-exchange" not in fired_k
    assert any(n.kind == "repartition" for n in logical.walk(root_k))
    # repartition feeding topk is NOT dead: topk's tie selection and its
    # k <= per-shard-capacity validation are placement-sensitive, so the
    # user's exchange stays
    kt = bf.lazy().repartition(["k1"]).topk(["v"], 7)
    root_t, fired_t = optimize(kt.logical_plan)
    assert "drop-redundant-exchange" not in fired_t
    assert any(n.kind == "repartition" for n in logical.walk(root_t))


def test_reorder_join_inputs_and_collision_guard():
    ctx = local_context()
    tiny = DataFrame.from_dict(
        {"k": np.arange(4, dtype=np.float32),
         "x": np.arange(4, dtype=np.float32)}, ctx, bucket_factor=4.0)
    wide = DataFrame.from_dict(
        {"k": (np.arange(40) % 4).astype(np.float32),
         "x": np.arange(40, dtype=np.float32)}, ctx, bucket_factor=4.0)
    lf = tiny.lazy().join(wide.lazy(), ["k"], max_matches=16,
                          reorder=True)
    root, fired = optimize(lf.logical_plan)
    assert "reorder-join-inputs" in fired and root.payload["swap"]
    assert "swapped" in lf.explain()
    # without the opt-in the rule never fires, even for this shape:
    # swapping moves the per-left-row max_matches cap to the other side
    lf0 = tiny.lazy().join(wide.lazy(), ["k"], max_matches=16)
    root0, fired0 = optimize(lf0.logical_plan)
    assert "reorder-join-inputs" not in fired0 and not root0.payload["swap"]
    # a literal `x_r` column would collide with the swap's rename
    tiny_r = DataFrame.from_dict(
        {"k": np.arange(4, dtype=np.float32),
         "x": np.arange(4, dtype=np.float32),
         "x_r": np.arange(4, dtype=np.float32)}, ctx, bucket_factor=4.0)
    lf2 = tiny_r.lazy().join(wide.lazy(), ["k"], max_matches=16,
                             reorder=True)
    root2, fired2 = optimize(lf2.logical_plan)
    assert "reorder-join-inputs" not in fired2 and not root2.payload["swap"]


def test_reorder_opt_in_guards_max_matches_cap():
    """The REVIEW regression: table_ops.join caps fan-out per LEFT row,
    so a swap silently caps the OTHER side.  Here the eager orientation
    is exact at max_matches=1 (each left row matches one right row) but
    the swapped orientation overflows (8 left rows share key 0) — the
    rule must stay off by default, and opting in surfaces the overflow
    instead of silently dropping matches."""
    ctx = local_context()
    left = DataFrame.from_dict(
        {"k": np.zeros(8, np.float32),
         "v": np.arange(8, dtype=np.float32)}, ctx, bucket_factor=4.0)
    right = DataFrame.from_dict(
        {"k": np.arange(20, dtype=np.float32),
         "w": 50.0 + np.arange(20, dtype=np.float32)}, ctx,
        bucket_factor=4.0)
    lf = left.lazy().join(right.lazy(), ["k"], max_matches=1)
    root, fired = optimize(lf.logical_plan)
    # estimates favor swapping (8 < 20 rows) yet the rule must not fire
    assert "reorder-join-inputs" not in fired and not root.payload["swap"]
    _assert_same_rows(lf.collect().to_numpy(),
                      left.join(right, ["k"], max_matches=1).to_numpy())
    # with the opt-in the cap binds on the swapped side: strict collect
    # reports it as overflow rather than dropping matches silently
    opt = left.lazy().join(right.lazy(), ["k"], max_matches=1,
                           reorder=True)
    _, fired_o = optimize(opt.logical_plan)
    assert "reorder-join-inputs" in fired_o
    with pytest.raises(OverflowError):
        opt.collect()


def test_choose_range_layout():
    ctx = local_context()
    bf, _ = _frames(ctx)
    lf = bf.lazy().groupby(["k1"], [("v", "sum")]).sort_values("k1")
    root, fired = optimize(lf.logical_plan)
    assert "choose-range-layout" in fired
    assert root.inputs[0].payload["layout"] == "range"
    plan = lf.physical_plan()
    assert [s.strategy for s in plan.steps if s.op == "groupby"] \
        == ["range-exchange"]
    assert [s.strategy for s in plan.steps if s.op == "orderby"] \
        == ["local-sort"]
    # different orderby keys: the groupby stays hash, orderby re-exchanges
    other = bf.lazy().groupby(["k1"], [("v", "sum")]).sort_values("v_sum")
    _, fired_o = optimize(other.logical_plan)
    assert "choose-range-layout" not in fired_o


def test_estimated_rows(tmp_path):
    ctx = local_context()
    path = _hpt_dataset(tmp_path, ctx)
    full = LazyFrame.read_parquet(path, ctx).logical_plan
    assert estimated_rows(full) == 64.0
    pruned = LazyFrame.read_parquet(
        path, ctx, predicate=[pred("a", "<", 16.0)]).logical_plan
    assert 0.0 < estimated_rows(pruned) <= 16.0
    bf, _ = _frames(ctx)
    assert estimated_rows(bf.lazy().topk(["v"], 5).logical_plan) == 5.0


# ---------------------------------------------------------------------------
# physical strategies (layout tracking across operator chains)
# ---------------------------------------------------------------------------
def test_join_groupby_elision_strategies():
    ctx = local_context()
    bf, sf = _frames(ctx)
    lf = (bf.lazy().repartition(["k1", "k2"])
          .join(sf.lazy().repartition(["k1", "k2"]), ["k1", "k2"],
                max_matches=4)
          .groupby(["k2", "k1"], [("v", "sum")]))
    plan = lf.physical_plan()
    by_op = {s.op: s.strategy for s in plan.steps}
    assert by_op["join"] == "elide-left+right"
    # key-SET co-location (k1,k2 vs k2,k1) — the eager per-call stamp
    # cannot express this, the planner's true-layout tracking can
    assert by_op["groupby"] == "elide(co-located)"


def test_window_coloc_and_lead_guard():
    ctx = local_context()
    bf, _ = _frames(ctx)
    base = bf.lazy().repartition(["k1"])
    ok = base.window(["k1"], ["v"]).agg([("v", "sum")])
    assert [s.strategy for s in ok.physical_plan().steps
            if s.op == "window"] == ["local-sort(co-located)"]
    # lead's truncation accounting reads downstream shards: full exchange
    lead = base.window(["k1"], ["v"]).agg([("v", "lead")])
    assert [s.strategy for s in lead.physical_plan().steps
            if s.op == "window"] == ["range-exchange"]


def test_orderby_elision_after_sort():
    ctx = local_context()
    bf, _ = _frames(ctx)
    lf = bf.lazy().sort_values(["k1", "v"]).sort_values(["k1", "v"])
    strategies = [s.strategy for s in lf.physical_plan().steps
                  if s.op == "orderby"]
    assert strategies == ["range-exchange", "elide(sorted)"]


# ---------------------------------------------------------------------------
# explain stability
# ---------------------------------------------------------------------------
def test_explain_is_stable_and_golden(tmp_path):
    ctx = local_context()
    path = _hpt_dataset(tmp_path, ctx)
    bf, _ = _frames(ctx)
    lf = (LazyFrame.read_parquet(path, ctx)
          .filter([pred("a", "<", 32.0)]).project(["a", "c"])
          .sort_values("a"))
    first, second = lf.explain(), lf.explain()
    assert first == second, "explain() must be deterministic"
    for needle in ("== logical plan ==", "== rewrites ==",
                   "== optimized plan ==", "== physical plan ==",
                   "push-filter-into-scan", "push-projection-into-scan",
                   "predicted collectives:", "scan[8 fragments",
                   "orderby[a]"):
        assert needle in first, f"missing {needle!r} in:\n{first}"
    # callable predicates render opaquely (no memory addresses)
    cf = bf.lazy().filter(lambda cols: cols["v"] > 0)
    assert "filter[<fn>]" in cf.explain()
    assert cf.explain() == cf.explain()


def test_explain_reads_no_data(tmp_path, monkeypatch):
    ctx = local_context()
    path = _hpt_dataset(tmp_path, ctx)
    lf = LazyFrame.read_parquet(path, ctx).filter([pred("a", "<", 8.0)])
    from repro.io import scan as scan_mod

    def boom(self):
        raise AssertionError("explain() must not materialize the scan")
    monkeypatch.setattr(scan_mod.ScanSource, "to_dist_table", boom)
    assert "predicted collectives" in lf.explain()


# ---------------------------------------------------------------------------
# parity vs the eager oracle (single device; every strategy still runs)
# ---------------------------------------------------------------------------
def test_parity_join_groupby_orderby():
    ctx = local_context()
    bf, sf = _frames(ctx)
    exp = (bf.join(sf, ["k1", "k2"], max_matches=4)
           .groupby(["k2", "k1"], [("v", "sum"), ("w", "max")])
           .sort_values(["k2", "k1"]))
    got = (bf.lazy().join(sf.lazy(), ["k1", "k2"], max_matches=4)
           .groupby(["k2", "k1"], [("v", "sum"), ("w", "max")])
           .sort_values(["k2", "k1"]).collect())
    ge, gg = exp.to_numpy(), got.to_numpy()
    assert sorted(ge) == sorted(gg)
    for c in ge:  # unique sorted keys ⇒ full order is deterministic
        np.testing.assert_array_equal(gg[c], ge[c], err_msg=c)


def test_parity_window_chain():
    ctx = local_context()
    bf, sf = _frames(ctx)

    def chain(a, b):
        return (a.join(b, ["k1", "k2"], max_matches=4)
                .groupby(["k2", "k1"], [("v", "sum"), ("w", "max")])
                .window(["k2", "k1"], ["v_sum"]).agg([("v_sum", "sum")]))

    _assert_same_rows(chain(bf.lazy(), sf.lazy()).collect().to_numpy(),
                      chain(bf, sf).to_numpy())


def test_parity_scan_pushdown(tmp_path):
    ctx = local_context()
    path = _hpt_dataset(tmp_path, ctx)
    exp = DataFrame.read_parquet(path, ctx, columns=["a", "c"],
                                 predicate=[pred("a", "<", 16.0)])
    got = (LazyFrame.read_parquet(path, ctx)
           .filter([pred("a", "<", 16.0)]).project(["a", "c"]).collect())
    ge, gg = exp.to_numpy(), got.to_numpy()
    assert sorted(ge) == sorted(gg) == ["a", "c"]
    for c in ge:
        np.testing.assert_array_equal(gg[c], ge[c], err_msg=c)


def test_parity_swapped_join_with_duplicate_columns():
    ctx = local_context()
    tiny = DataFrame.from_dict(
        {"k": np.arange(4, dtype=np.float32),
         "x": 100.0 + np.arange(4, dtype=np.float32)}, ctx,
        bucket_factor=4.0)
    wide = DataFrame.from_dict(
        {"k": (np.arange(40) % 4).astype(np.float32),
         "x": np.arange(40, dtype=np.float32)}, ctx, bucket_factor=4.0)
    lf = tiny.lazy().join(wide.lazy(), ["k"], max_matches=16,
                          reorder=True)
    _, fired = optimize(lf.logical_plan)
    assert "reorder-join-inputs" in fired  # the swap path really runs
    _assert_same_rows(lf.collect().to_numpy(),
                      tiny.join(wide, ["k"], max_matches=16).to_numpy())


def test_literal_key_suffix_column_survives_projection(tmp_path):
    """REVIEW regression: a dataset column literally named `k_r` where
    `k` is a join key is NOT a join-generated duplicate (join_schema
    never suffixes keys) — required-column analysis must keep it on the
    right-side scan instead of pruning it."""
    ctx = local_context()
    n = 8
    data = {"k": np.arange(n, dtype=np.float32),
            "k_r": 10.0 + np.arange(n, dtype=np.float32),
            "w": np.ones(n, np.float32)}
    path = str(tmp_path / "kr_ds")
    DataFrame.from_dict(data, ctx).to_hpt(path, rows_per_group=4)
    left = DataFrame.from_dict(
        {"k": np.arange(n, dtype=np.float32),
         "v": np.arange(n, dtype=np.float32)}, ctx, bucket_factor=4.0)
    lf = (left.lazy()
          .join(LazyFrame.read_parquet(path, ctx), ["k"], max_matches=1)
          .project(["k", "k_r"]))
    root, fired = optimize(lf.logical_plan)
    scans = [nd for nd in logical.walk(root) if nd.kind == "scan"]
    assert len(scans) == 1
    assert "k_r" in scans[0].payload["columns"]  # literal col kept
    assert "w" not in scans[0].payload["columns"]  # rule still narrows
    assert "push-projection-into-scan" in fired
    _assert_same_rows(lf.collect().to_numpy(),
                      {"k": data["k"], "k_r": data["k_r"]})


def test_parity_topk_and_repartition():
    ctx = local_context()
    bf, _ = _frames(ctx)
    exp = bf.repartition(["k1"]).topk(["v"], 7, largest=True)
    got = bf.lazy().repartition(["k1"]).topk(["v"], 7, largest=True)
    _assert_same_rows(got.collect().to_numpy(), exp.to_numpy())


def test_overflow_parity_and_strict_escape():
    ctx = local_context()
    dup = {"k": np.zeros(8, np.float32),
           "v": np.arange(8, dtype=np.float32)}
    a = DataFrame.from_dict(dup, ctx, bucket_factor=4.0)
    b = DataFrame.from_dict(dup, ctx, bucket_factor=4.0)
    with pytest.raises(OverflowError):
        a.join(b, ["k"], max_matches=1)  # 8 matches per row
    lazy = a.lazy().join(b.lazy(), ["k"], max_matches=1)
    with pytest.raises(OverflowError):
        lazy.collect()
    out = lazy.collect(strict=False)  # caller owns the exactness decision
    assert not out.overflow_report.is_exact()
    assert any(k.startswith("plan.") and v > 0
               for k, v in out.overflow_report)


def test_build_time_validation():
    ctx = local_context()
    bf, sf = _frames(ctx)
    with pytest.raises(ValueError, match="unknown column"):
        bf.lazy().filter([pred("nope", "<", 1.0)])
    with pytest.raises(ValueError, match="unknown aggregate"):
        bf.lazy().groupby(["k1"], [("v", "median")])
    with pytest.raises(TypeError, match="call .lazy"):
        bf.lazy().join(sf, ["k1"])
    with pytest.raises(ValueError, match="positive int"):
        bf.lazy().topk(["v"], 0)


# ---------------------------------------------------------------------------
# property suite: random pipelines, NaN keys, ±0.0, saturating values
# ---------------------------------------------------------------------------
_KEY_POOL = (0.0, -0.0, 1.0, 2.5, float("nan"))
_VAL_POOL = (0.0, -0.0, 1.5, -3.25, 6.5e7, float(2 ** 31), 3.4e38)


@settings(max_examples=12, deadline=None)
@given(data=st.data())
def test_property_random_pipeline_matches_eager(data):
    ctx = local_context()
    n = data.draw(st.integers(min_value=6, max_value=28), label="rows")
    k = np.asarray(data.draw(st.lists(st.sampled_from(_KEY_POOL),
                                      min_size=n, max_size=n)), np.float32)
    v = np.asarray(data.draw(st.lists(st.sampled_from(_VAL_POOL),
                                      min_size=n, max_size=n)), np.float32)
    base = {"k": k, "v": v, "u": np.arange(n, dtype=np.float32)}
    df = DataFrame.from_dict(base, ctx, bucket_factor=4.0)
    lf = df.lazy()
    for op in data.draw(st.lists(
            st.sampled_from(["filter", "sort", "repart"]), max_size=2),
            label="mid"):
        if op == "filter":
            t = data.draw(st.sampled_from([0.0, 1.5, -3.25]))
            df = df.select(lambda cols, _t=t: cols["v"] >= _t)
            lf = lf.filter([pred("v", ">=", t)])
        elif op == "sort":
            df, lf = df.sort_values(["k", "u"]), lf.sort_values(["k", "u"])
        else:
            df, lf = df.repartition(["k"]), lf.repartition(["k"])
    tail = data.draw(st.sampled_from(["groupby", "window", "topk", "none"]),
                     label="tail")
    if tail == "groupby":
        aggs = [("v", "sum"), ("v", "count"), ("v", "min")]
        df, lf = df.groupby(["k"], aggs), lf.groupby(["k"], aggs)
    elif tail == "window":
        # order key `u` is unique ⇒ in-partition order (and thus every
        # running aggregate) is deterministic under any row placement
        df = df.window(["k"], ["u"]).agg([("v", "sum")])
        lf = lf.window(["k"], ["u"]).agg([("v", "sum")])
    elif tail == "topk":
        df, lf = df.topk(["v", "u"], 5), lf.topk(["v", "u"], 5)
    out = lf.collect(strict=False, jit=False)
    assert out.overflow_report.is_exact()
    _assert_same_rows(out.to_numpy(), df.to_numpy())


# ---------------------------------------------------------------------------
# the 4-shard contract: predicted == traced, planned < eager
# ---------------------------------------------------------------------------
def _run_devices(script: str, n: int = 4, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-4000:]}"
    return r.stdout


def test_plan_contract_4way():
    out = _run_devices("""
        import jax, numpy as np
        from repro.core import host_test_context, table_ops
        from repro.dataframe.frame import DataFrame

        ctx = host_test_context(n_shards=4)
        rng = np.random.default_rng(0)
        nb = 320
        big = {"k1": rng.integers(0, 10, nb).astype(np.float32),
               "k2": rng.integers(0, 4, nb).astype(np.float32),
               "v": rng.normal(size=nb).astype(np.float32)}
        small = {"k1": np.repeat(np.arange(10), 4).astype(np.float32),
                 "k2": np.tile(np.arange(4), 10).astype(np.float32),
                 "w": rng.normal(size=40).astype(np.float32)}
        bf = DataFrame.from_dict(big, ctx, bucket_factor=4.0)
        sf = DataFrame.from_dict(small, ctx, bucket_factor=4.0)
        KEYS, GKEYS = ["k1", "k2"], ["k2", "k1"]
        AGGS = [("v", "sum"), ("w", "max")]
        WAGGS = [("v_sum", "sum")]

        def count(fn, *args):
            return str(jax.make_jaxpr(fn)(*args)).count("all_to_all")

        # representative chain: join -> groupby -> window
        def eager_fn(lt, rt):
            j, _ = table_ops.join(lt, rt, KEYS, ctx=ctx, how="inner",
                                  max_matches=64)
            g, _ = table_ops.groupby_aggregate(j, GKEYS, AGGS, ctx=ctx)
            w, _ = table_ops.window_aggregate(g, GKEYS, ["v_sum"], WAGGS,
                                              ctx=ctx)
            return w.columns

        ne = count(eager_fn, bf.table, sf.table)
        lf = (bf.lazy().join(sf.lazy(), KEYS, max_matches=64)
              .groupby(GKEYS, AGGS).window(GKEYS, ["v_sum"]).agg(WAGGS))
        plan = lf.physical_plan()
        npl = count(plan.fn, *plan.inputs())
        print("CHAIN eager=%d planned=%d predicted=%d"
              % (ne, npl, plan.predicted_collectives))
        assert npl == plan.predicted_collectives, (npl,
                                                   plan.predicted_collectives)
        assert npl < ne, "representative chain must be strictly cheaper"

        exp = (bf.join(sf, KEYS, max_matches=64).groupby(GKEYS, AGGS)
               .window(GKEYS, ["v_sum"]).agg(WAGGS)).to_numpy()
        got = lf.collect().to_numpy()
        assert sorted(got) == sorted(exp), (sorted(got), sorted(exp))
        def canon(d):
            views = [np.ascontiguousarray(np.asarray(d[c], np.float32))
                     .view(np.uint32) for c in sorted(d)]
            order = np.lexsort(tuple(reversed(views)))
            return [v[order] for v in views]
        for c, a, b in zip(sorted(got), canon(got), canon(exp)):
            np.testing.assert_array_equal(a, b, err_msg=c)

        # choose-range-layout: groupby -> orderby pays ONE exchange
        lf2 = bf.lazy().groupby(["k1"], [("v", "sum")]).sort_values("k1")
        plan2 = lf2.physical_plan()

        def eager2(dt):
            g, _ = table_ops.groupby_aggregate(dt, ["k1"], [("v", "sum")],
                                               ctx=ctx)
            s, _ = table_ops.orderby(g, ["k1"], ctx=ctx)
            return s.columns

        ne2 = count(eager2, bf.table)
        np2 = count(plan2.fn, *plan2.inputs())
        print("GB-OB eager=%d planned=%d predicted=%d"
              % (ne2, np2, plan2.predicted_collectives))
        assert np2 == plan2.predicted_collectives
        assert np2 < ne2
        got2 = lf2.collect().to_numpy()
        exp2 = (bf.groupby(["k1"], [("v", "sum")])
                .sort_values("k1")).to_numpy()
        for c in exp2:
            np.testing.assert_array_equal(got2[c], exp2[c], err_msg=c)
        print("PLAN-CONTRACT-4DEV-OK")
        """)
    assert "PLAN-CONTRACT-4DEV-OK" in out
    assert "CHAIN eager=4 planned=2 predicted=2" in out
