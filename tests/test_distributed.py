"""Multi-device behaviour, run in subprocesses with forced host devices
(the main test process must keep seeing exactly 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_devices(script: str, n: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-4000:]}"
    return r.stdout


def test_distributed_table_ops_8way():
    out = run_devices("""
        import jax, numpy as np, jax.numpy as jnp, collections
        from repro.core import (Table, DistTable, HPTMTContext, make_mesh,
                                table_ops)
        mesh = make_mesh((8,), ("data",))
        ctx = HPTMTContext(mesh=mesh)
        rng = np.random.default_rng(0)
        ids = rng.integers(0, 64, 256).astype(np.int32)
        vals = rng.normal(size=256).astype(np.float32)
        t = Table.from_arrays({"id": jnp.asarray(ids), "v": jnp.asarray(vals)})
        dt = DistTable.from_local(t, ctx, capacity=64)

        sh, ov = table_ops.shuffle(dt, ["id"], ctx=ctx)
        assert int(ov) == 0 and int(sh.num_rows()) == 256
        loc = {}
        for s in range(8):
            st = sh.shard_table(s)
            for i in np.asarray(st.columns["id"][:int(st.num_rows)]):
                loc.setdefault(int(i), set()).add(s)
        assert all(len(v) == 1 for v in loc.values()), "keys not co-located"

        ga, ov = table_ops.groupby_aggregate(dt, ["id"], [("v","sum")], ctx=ctx)
        got = ga.to_numpy()
        exp = collections.defaultdict(float)
        for i, v in zip(ids, vals): exp[int(i)] += float(v)
        order = np.argsort(got["id"])
        np.testing.assert_allclose(
            got["v_sum"][order], [exp[k] for k in sorted(exp)], rtol=1e-4)

        srt, ov = table_ops.orderby(dt, "v", ctx=ctx)
        np.testing.assert_allclose(srt.to_numpy()["v"], np.sort(vals),
                                   rtol=1e-6)
        print("DIST-TABLE-OK")
        """)
    assert "DIST-TABLE-OK" in out


def test_array_collectives_8way():
    out = run_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.core import HPTMTContext, make_mesh, array_ops
        ctx = HPTMTContext(mesh=make_mesh((8,), ("data",)))
        x = jnp.arange(8*4, dtype=jnp.float32).reshape(8, 4)
        np.testing.assert_allclose(array_ops.allreduce(x, ctx=ctx),
                                   np.asarray(x).sum(0))
        np.testing.assert_allclose(array_ops.allreduce(x, ctx=ctx, op="max"),
                                   np.asarray(x).max(0))
        np.testing.assert_allclose(array_ops.broadcast(x, ctx=ctx, root=5),
                                   np.asarray(x)[5])
        g = array_ops.allgather(jnp.arange(16., dtype=jnp.float32), ctx=ctx)
        np.testing.assert_allclose(g, np.arange(16.))
        rs = array_ops.reduce_scatter(jnp.ones((16, 2)), ctx=ctx)
        np.testing.assert_allclose(np.asarray(rs), 8 * np.ones((16, 2)))
        print("COLLECTIVES-OK")
        """)
    assert "COLLECTIVES-OK" in out


def test_sharded_train_step_4x2():
    """FSDP×TP train step on a 4×2 host mesh == single-device step."""
    out = run_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.configs import get_config, reduced_config
        from repro.sharding import axes as am
        from repro.train.train_step import (TrainConfig, init_train_state,
                                            make_sharded_train_step)
        from repro.train.optimizer import OptimizerConfig
        from repro.core.context import make_mesh
        import dataclasses

        cfg = reduced_config(get_config("phi3-mini-3.8b"))
        cfg = dataclasses.replace(cfg, d_model=64, n_heads=4, n_kv_heads=4,
                                  d_ff=128)
        mesh = make_mesh((4, 2), ("data", "model"))
        tcfg = TrainConfig(optimizer=OptimizerConfig(warmup_steps=0))
        state = init_train_state(jax.random.PRNGKey(0), cfg)
        rng = jax.random.PRNGKey(1)
        batch = {"tokens": jax.random.randint(rng, (8, 32), 0,
                                              cfg.vocab_size)}
        batch["labels"] = batch["tokens"]

        with am.logical_binding(mesh):
            step, sspec, bspec = make_sharded_train_step(
                cfg, tcfg, mesh, state)
            s2, m = step(state, batch)
            loss_sharded = float(m["loss"])

        # oracle: plain jit on 1 logical device path
        from repro.train.train_step import make_train_step
        state_o = init_train_state(jax.random.PRNGKey(0), cfg)
        _, m_o = jax.jit(make_train_step(cfg, tcfg))(state_o, batch)
        assert abs(loss_sharded - float(m_o["loss"])) < 5e-2, (
            loss_sharded, float(m_o["loss"]))
        print("SHARDED-TRAIN-OK", loss_sharded)
        """)
    assert "SHARDED-TRAIN-OK" in out


def test_grad_compression_ef_allreduce():
    out = run_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core.context import make_mesh
        from repro.train.grad_compress import ef_allreduce_mean

        mesh = make_mesh((4,), ("pod",))
        rng = np.random.default_rng(0)
        # per-pod distinct gradients (stacked on leading axis)
        gs = rng.normal(size=(4, 33)).astype(np.float32)
        errs = np.zeros_like(gs)

        def f(g, e):
            return ef_allreduce_mean(g[0], e[0], "pod")

        from repro.core.context import compat_shard_map
        fn = compat_shard_map(lambda g, e: tuple(
                 x[None] for x in ef_allreduce_mean(g[0], e[0], "pod")),
                 mesh=mesh, in_specs=(P("pod"), P("pod")),
                 out_specs=(P("pod"), P("pod")))
        avg, new_err = fn(jnp.asarray(gs), jnp.asarray(errs))
        true_mean = gs.mean(0)
        # int8 quantization: within ~2/127 of max-abs scale
        scale = np.abs(gs).max() / 127
        np.testing.assert_allclose(np.asarray(avg)[0], true_mean,
                                   atol=4 * scale)
        # all pods agree on the result
        for i in range(1, 4):
            np.testing.assert_allclose(np.asarray(avg)[i],
                                       np.asarray(avg)[0], atol=1e-6)
        # error feedback: residual = input - quantized(input)
        assert np.abs(np.asarray(new_err)).max() <= scale * 1.01
        print("EF-ALLREDUCE-OK")
        """)
    assert "EF-ALLREDUCE-OK" in out


def test_embed_lookup_sharded():
    out = run_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.core.context import make_mesh
        from repro.sharding import axes as am

        mesh = make_mesh((2, 4), ("data", "model"))
        embed = jnp.asarray(np.random.default_rng(0).normal(
            size=(64, 16)).astype(np.float32))
        tokens = jnp.asarray(np.random.default_rng(1).integers(
            0, 64, (8, 5)).astype(np.int32))
        with am.logical_binding(mesh):
            out = am.embed_lookup(embed, tokens)
        np.testing.assert_allclose(np.asarray(out), np.asarray(embed)[tokens],
                                   rtol=1e-6)
        print("EMBED-OK")
        """)
    assert "EMBED-OK" in out


def test_elastic_checkpoint_reshard():
    """Save under a 4-shard mesh, restore under a 2-shard mesh."""
    out = run_devices("""
        import jax, numpy as np, jax.numpy as jnp, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.manager import CheckpointManager
        from repro.core.context import make_mesh

        tree = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
        m4 = make_mesh((4,), ("data",))
        m2 = make_mesh((2,), ("data",))
        sharded = jax.device_put(tree["w"], NamedSharding(m4, P("data")))
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            mgr.save(1, {"w": sharded})
            restored = mgr.restore(
                {"w": jnp.zeros((8, 4))},
                shardings={"w": NamedSharding(m2, P("data"))})
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))
        assert restored["w"].sharding.mesh.shape["data"] == 2
        print("ELASTIC-OK")
        """)
    assert "ELASTIC-OK" in out


def test_moe_ep_shardmap_matches_einsum():
    """Explicit-EP shuffle MoE == auto-SPMD einsum MoE (§Perf iteration B1)."""
    out = run_devices("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced_config
        from repro.core.context import make_mesh
        from repro.models import moe as M
        from repro.sharding import axes as am

        cfg = reduced_config(get_config("qwen2-moe-a2.7b"))
        cfg = dataclasses.replace(cfg, n_experts=4, experts_per_token=2,
                                  capacity_factor=8.0)
        params = M.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1),
                              (4, 128, cfg.d_model)).astype(jnp.bfloat16)
        y1, m1 = M._moe_ffn_einsum(params, cfg, x)
        mesh = make_mesh((2, 4), ("data", "model"))
        with am.logical_binding(mesh):
            y2, m2 = M.moe_ffn(params, cfg, x)
        a = np.asarray(y1, np.float32); b = np.asarray(y2, np.float32)
        rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-9)
        assert rel < 2e-2, rel
        assert abs(float(m1["router_z_loss"]) - float(m2["router_z_loss"])) < 1e-3
        print("MOE-EP-MATCH-OK", rel)
        """)
    assert "MOE-EP-MATCH-OK" in out
