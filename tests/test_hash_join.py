"""Sort-free hash-join engine vs the sort-merge oracle (DESIGN.md §8).

Four layers of guarantees:

  * parity — ``method="hash"`` output equals ``method="sort"`` bit-exactly
    on valid rows (as multisets) for all four ``how`` modes, duplicate
    keys, NaN/±0.0 float keys, and fan-out overflow at ``max_matches``,
    with equal overflow counts;
  * sort-freedom — the traced jaxpr of the hash join path and of every
    set operator contains zero ``sort`` primitives;
  * kernel — the Pallas fused-probe kernel (interpret mode) is bit-equal
    to the jnp reference;
  * overflow contract — fan-out beyond ``max_matches``/``max_probes`` is
    counted, never silently dropped (§2).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 env may lack hypothesis: skip only @given tests
    from conftest import given, settings, st

from repro.core import DistTable, Table, local_context, table_ops
from repro.core.exchange import key_compare_u32
from repro.core.table import hash_columns
from repro.dataframe.frame import DataFrame
from repro.kernels.hash_join import ops as hjops

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
CTX = local_context()
RNG = np.random.default_rng(7)

#: float key pool exercising the bitwise identity: NaN (equal bits match),
#: -0.0 vs +0.0 (distinct), and plain values
KEY_POOL = np.array([0.0, -0.0, 1.0, 2.0, 3.5, np.nan, np.nan, 7.25],
                    np.float32)


def make_dt(cols, capacity=None):
    t = Table.from_arrays({k: jnp.asarray(v) for k, v in cols.items()},
                          capacity=capacity)
    return DistTable.from_local(t, CTX)


def canon_rows(got):
    """Canonical bitwise row multiset: every column viewed as bits, rows
    lexsorted — NaN-safe, ±0.0-distinguishing comparisons."""
    names = sorted(got)
    bits = []
    for k in names:
        a = np.asarray(got[k])
        bits.append(a.view(np.uint32) if a.dtype == np.float32
                    else a.astype(np.int64))
    order = np.lexsort(tuple(reversed(bits)))
    return {k: b[order] for k, b in zip(names, bits)}


def assert_rows_equal(a, b, msg=""):
    ca, cb = canon_rows(a), canon_rows(b)
    assert set(ca) == set(cb), (msg, sorted(ca), sorted(cb))
    for k in ca:
        np.testing.assert_array_equal(ca[k], cb[k], err_msg=f"{msg}:{k}")


def _join_both(l, r, how, mm, out_capacity, window=40):
    h, ovh = table_ops.join(l, r, ["k"], how=how, max_matches=mm,
                            out_capacity=out_capacity, method="hash",
                            ctx=CTX)
    s, ovs = table_ops.join(l, r, ["k"], how=how, max_matches=mm,
                            out_capacity=out_capacity, method="sort",
                            window=window, ctx=CTX)
    return h, int(ovh), s, int(ovs)


# ---------------------------------------------------------------------------
# hash-vs-sort parity
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("how", ["inner", "left", "right", "outer"])
def test_hash_join_matches_sort_dup_keys(how):
    lk = np.array([1, 2, 2, 3, 5, 2, 7, 1], np.int32)
    rk = np.array([2, 2, 1, 9, 2, 2], np.int32)
    l = make_dt({"k": lk, "a": np.arange(8, dtype=np.float32)})
    r = make_dt({"k": rk, "b": 10 * np.arange(6, dtype=np.float32)})
    for mm in (1, 2, 4):
        h, ovh, s, ovs = _join_both(l, r, how, mm, 8 * mm + 8)
        assert ovh == ovs, (how, mm)
        assert_rows_equal(h.to_numpy(), s.to_numpy(), f"{how}/mm={mm}")


def test_hash_join_right_outer_semantics():
    l = make_dt({"k": np.array([1, 2, 3], np.int32),
                 "a": np.array([10., 20., 30.], np.float32)})
    r = make_dt({"k": np.array([2, 4], np.int32),
                 "b": np.array([200., 400.], np.float32)})
    right, ov = table_ops.join(l, r, ["k"], how="right", ctx=CTX)
    assert int(ov) == 0
    got = right.to_numpy()
    order = np.argsort(got["k"])
    np.testing.assert_array_equal(got["k"][order], [2, 4])
    np.testing.assert_array_equal(got["b"][order], [200., 400.])
    np.testing.assert_array_equal(got["a"][order], [20., 0.])  # unmatched→0
    np.testing.assert_array_equal(got["_matched"][order], [True, False])

    outer, ov = table_ops.join(l, r, ["k"], how="outer", ctx=CTX)
    assert int(ov) == 0
    got = outer.to_numpy()
    order = np.argsort(got["k"])
    np.testing.assert_array_equal(got["k"][order], [1, 2, 3, 4])
    np.testing.assert_array_equal(got["_matched"][order],
                                  [False, True, False, False])


def test_nan_and_signed_zero_keys_regression():
    """NaN join keys match bitwise; -0.0 and +0.0 never match — on BOTH
    kernels, consistent with the hash identity (the PR 2 groupby fix class:
    value ``==`` would drop NaN matches and cross-match ±0.0)."""
    l = make_dt({"k": np.array([np.nan, -0.0, 1.0], np.float32),
                 "a": np.array([1., 2., 3.], np.float32)})
    r = make_dt({"k": np.array([np.nan, 0.0, 1.0], np.float32),
                 "b": np.array([10., 20., 30.], np.float32)})
    for method in ("hash", "sort"):
        out, ov = table_ops.join(l, r, ["k"], method=method, ctx=CTX)
        assert int(ov) == 0
        got = out.to_numpy()
        # NaN row matched NaN row; 1.0 matched 1.0; -0.0 did NOT match +0.0
        assert len(got["k"]) == 2, method
        assert np.isnan(got["k"]).sum() == 1, method
        np.testing.assert_array_equal(np.sort(got["b"]), [10., 30.])


def test_fanout_beyond_max_matches_is_counted():
    """Matches dropped by the fan-out cap are overflow, never silent (§2)."""
    l = make_dt({"k": np.array([1, 2], np.int32),
                 "a": np.array([1., 2.], np.float32)})
    r = make_dt({"k": np.array([2, 2, 2], np.int32),
                 "b": np.array([5., 6., 7.], np.float32)})
    for method in ("hash", "sort"):
        out, ov = table_ops.join(l, r, ["k"], max_matches=1, out_capacity=8,
                                 method=method, ctx=CTX)
        assert int(ov) == 2, method  # 3 matches, 1 kept
        got = out.to_numpy()
        # deterministic survivor: the FIRST duplicate in right-row order
        np.testing.assert_array_equal(got["b"], [5.])


def test_hash_join_max_probes_exhaustion_counted():
    """Probe chains longer than max_probes surface as overflow."""
    l = make_dt({"k": np.zeros(4, np.int32),
                 "a": np.arange(4, dtype=np.float32)})
    r = make_dt({"k": np.zeros(16, np.int32),
                 "b": np.arange(16, dtype=np.float32)})
    out, ov = table_ops.join(l, r, ["k"], max_matches=16, out_capacity=64,
                             method="hash", max_probes=4, ctx=CTX)
    assert int(ov) > 0  # 16-deep duplicate chain cannot build/probe in 4


@settings(max_examples=40, deadline=None)
@given(lidx=st.lists(st.integers(0, len(KEY_POOL) - 1), min_size=1,
                     max_size=24),
       ridx=st.lists(st.integers(0, len(KEY_POOL) - 1), min_size=1,
                     max_size=24),
       how=st.sampled_from(["inner", "left", "right", "outer"]),
       mm=st.integers(1, 4))
def test_hash_join_parity_property(lidx, ridx, how, mm):
    """Bit-exact hash-vs-sort parity: duplicate keys, NaN/±0.0 keys, all
    four how modes, fan-out overflow at max_matches — equal row multisets
    (bitwise) and equal overflow counts.  Payloads are key-derived so the
    surviving rows under fan-out truncation are comparable as multisets
    regardless of which equal-key duplicate was kept."""
    lk, rk = KEY_POOL[lidx], KEY_POOL[ridx]
    l = make_dt({"k": lk, "a": np.arange(len(lk), dtype=np.float32)})
    r = make_dt({"k": rk,
                 "b": rk.view(np.uint32).astype(np.float32)})
    out_cap = len(lk) * mm + len(rk) + 4
    h, ovh, s, ovs = _join_both(l, r, how, mm, out_cap)
    assert ovh == ovs
    assert_rows_equal(h.to_numpy(), s.to_numpy(), f"{how}/mm={mm}")


# ---------------------------------------------------------------------------
# sort-freedom (jaxpr-asserted)
# ---------------------------------------------------------------------------
def _sort_count(fn, *args) -> int:
    return str(jax.make_jaxpr(fn)(*args)).count("sort[")


def test_hash_join_jaxpr_has_zero_sorts():
    l = make_dt({"k": np.arange(64, dtype=np.int32),
                 "a": np.ones(64, np.float32)})
    r = make_dt({"k": np.arange(64, dtype=np.int32),
                 "b": np.ones(64, np.float32)})
    for how in ("inner", "left", "right", "outer"):
        assert _sort_count(
            lambda a, b, how=how: table_ops.join(
                a, b, ["k"], how=how, method="hash", ctx=CTX), l, r) == 0
    # the oracle really does sort — the assertion above is not vacuous
    assert _sort_count(
        lambda a, b: table_ops.join(a, b, ["k"], method="sort", ctx=CTX),
        l, r) > 0


def test_setops_jaxpr_have_zero_sorts():
    a = make_dt({"x": np.arange(32, dtype=np.int32)})
    b = make_dt({"x": np.arange(16, 48, dtype=np.int32)})
    for op in (table_ops.union, table_ops.difference, table_ops.intersect):
        assert _sort_count(lambda u, v, op=op: op(u, v, ctx=CTX), a, b) == 0


def test_groupby_hash_jaxpr_has_zero_sorts():
    dt = make_dt({"k": np.arange(64, dtype=np.int32),
                  "v": np.ones(64, np.float32)})
    assert _sort_count(
        lambda t: table_ops.groupby_aggregate(
            t, ["k"], [("v", "sum")], method="hash", ctx=CTX), dt) == 0


# ---------------------------------------------------------------------------
# set ops on the hash primitives
# ---------------------------------------------------------------------------
def test_setops_nan_rows_bitwise():
    """Set-op row identity is bitwise (consistent with the hashes):
    equal-bit NaN rows deduplicate and subtract; ±0.0 stay distinct."""
    a = make_dt({"x": np.array([np.nan, np.nan, 1.0, -0.0], np.float32)})
    b = make_dt({"x": np.array([np.nan, 0.0], np.float32)})
    u, ov = table_ops.union(a, b, ctx=CTX)
    assert int(ov) == 0
    bits = np.sort(u.to_numpy()["x"].view(np.uint32))
    # {nan, 1.0, -0.0, +0.0} — one NaN (deduped), both zero signs
    assert len(bits) == 4
    d, _ = table_ops.difference(a, b, ctx=CTX)
    got = d.to_numpy()["x"]
    # NaN rows removed (present in b bitwise); -0.0 kept (+0.0 != -0.0)
    assert len(got) == 2
    assert np.sort(got.view(np.uint32)).tolist() == np.sort(
        np.array([1.0, -0.0], np.float32).view(np.uint32)).tolist()
    i, _ = table_ops.intersect(a, b, ctx=CTX)
    got = i.to_numpy()["x"]
    assert len(got) == 1 and np.isnan(got[0])


# ---------------------------------------------------------------------------
# kernel: Pallas (interpret) vs jnp reference, bit-exact
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mm", [1, 4])
def test_probe_kernel_interpret_matches_ref(mm):
    n_build, n_probe = 700, 900
    bcols = {"k": jnp.asarray(RNG.integers(0, 60, n_build).astype(np.int32)),
             "f": jnp.asarray(KEY_POOL[RNG.integers(0, len(KEY_POOL),
                                                    n_build)])}
    pcols = {"k": jnp.asarray(RNG.integers(0, 70, n_probe).astype(np.int32)),
             "f": jnp.asarray(KEY_POOL[RNG.integers(0, len(KEY_POOL),
                                                    n_probe)])}
    keys = ("k", "f")
    bh1, bh2 = hash_columns([bcols[k] for k in keys])
    ph1, ph2 = hash_columns([pcols[k] for k in keys])
    bkeys = key_compare_u32(bcols, keys)
    pkeys = key_compare_u32(pcols, keys)
    bmask = jnp.arange(n_build) < 640
    pmask = jnp.arange(n_probe) < 850
    table, unplaced = hjops.build_table(bh1, bh2, bmask, 4096, 64)
    assert int(unplaced) == 0
    slot_h2, slot_keys = hjops.slot_payload(table, bh2, bkeys)
    ref = hjops.probe(table, slot_h2, slot_keys, ph1, ph2, pkeys, pmask,
                      mm, 64)
    pal = hjops.probe(table, slot_h2, slot_keys, ph1, ph2, pkeys, pmask,
                      mm, 64, force="pallas")
    for x, y, name in zip(ref, pal, ("cnt", "rimat", "exhausted")):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=name)


def test_build_table_every_valid_row_has_a_slot():
    n = 500
    cols = {"k": jnp.asarray(RNG.integers(0, 40, n).astype(np.int32))}
    h1, h2 = hash_columns([cols["k"]])
    valid = jnp.arange(n) < 450
    table, unplaced = hjops.build_table(h1, h2, valid, 4096, 64)
    t = np.asarray(table)
    assert int(unplaced) == 0
    placed = np.sort(t[t >= 0])
    np.testing.assert_array_equal(placed, np.arange(450))  # own slot each


# ---------------------------------------------------------------------------
# DataFrame surface
# ---------------------------------------------------------------------------
def test_dataframe_join_kwargs():
    df = DataFrame.from_dict({"k": np.array([1, 2, 3], np.int32),
                              "a": np.ones(3, np.float32)}, CTX)
    other = DataFrame.from_dict({"k": np.array([2, 3, 4], np.int32),
                                 "b": np.ones(3, np.float32)}, CTX)
    with pytest.raises(ValueError, match="method='bogus'"):
        df.join(other, on=["k"], method="bogus")
    with pytest.raises(ValueError, match="how='sideways'"):
        df.join(other, on=["k"], how="sideways")
    with pytest.raises(ValueError, match="max_matches"):
        df.join(other, on=["k"], max_matches=0)
    got = df.join(other, on=["k"], how="outer", method="hash",
                  max_matches=2).to_numpy()
    assert sorted(got["k"].tolist()) == [1, 2, 3, 4]
    # the sort oracle stays reachable through the same surface
    got = df.join(other, on=["k"], method="sort", window=8).to_numpy()
    assert sorted(got["k"].tolist()) == [2, 3]


# ---------------------------------------------------------------------------
# 4-device mesh: parity vs single-shard oracle + collective/sort counts
# ---------------------------------------------------------------------------
def _run_devices(script: str, n: int = 4, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-4000:]}"
    return r.stdout


def test_hash_join_and_setops_4way():
    _run_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.core import (Table, DistTable, HPTMTContext, make_mesh,
                                local_context, table_ops)
        mesh = make_mesh((4,), ("data",))
        ctx = HPTMTContext(mesh=mesh)
        one = local_context()
        rng = np.random.default_rng(9)
        n = 256
        lk = rng.integers(0, 64, n).astype(np.int32)
        rk = rng.integers(0, 64, n).astype(np.int32)
        lt = Table.from_arrays({"k": jnp.asarray(lk),
                                "a": jnp.asarray(lk * 2, jnp.float32)})
        rt = Table.from_arrays({"k": jnp.asarray(rk),
                                "b": jnp.asarray(rk * 3, jnp.float32)})

        def rows(dt, cols):
            g = dt.to_numpy()
            return sorted(zip(*(g[c].tolist() for c in cols)))

        for how in ("inner", "left", "right", "outer"):
            got, ovd = table_ops.join(
                DistTable.from_local(lt, ctx, capacity=128),
                DistTable.from_local(rt, ctx, capacity=128),
                ["k"], how=how, max_matches=8, out_capacity=2048,
                method="hash", ctx=ctx)
            ref, ovo = table_ops.join(
                DistTable.from_local(lt, one), DistTable.from_local(rt, one),
                ["k"], how=how, max_matches=8, out_capacity=8192,
                method="hash", ctx=one)
            assert int(ovd) == 0 and int(ovo) == 0, (how, int(ovd), int(ovo))
            cols = ("k", "a", "b", "_matched")
            assert rows(got, cols) == rows(ref, cols), how

        # one packed AllToAll per join side, zero sorts, on the mesh too
        jaxpr = str(jax.make_jaxpr(lambda a, b: table_ops.join(
            a, b, ["k"], method="hash", ctx=ctx))(
            DistTable.from_local(lt, ctx, capacity=128),
            DistTable.from_local(rt, ctx, capacity=128)))
        assert jaxpr.count("all_to_all") == 2, jaxpr.count("all_to_all")
        assert jaxpr.count("sort[") == 0

        # set ops: 4-shard == 1-shard, sort-free on the mesh
        at = Table.from_arrays({"x": jnp.asarray(
            rng.integers(0, 40, n).astype(np.int32))})
        bt = Table.from_arrays({"x": jnp.asarray(
            rng.integers(20, 60, n).astype(np.int32))})
        for op in (table_ops.union, table_ops.difference,
                   table_ops.intersect):
            got, _ = op(DistTable.from_local(at, ctx, capacity=128),
                        DistTable.from_local(bt, ctx, capacity=128),
                        ctx=ctx, out_capacity=1024)
            ref, _ = op(DistTable.from_local(at, one),
                        DistTable.from_local(bt, one), ctx=one)
            assert (sorted(got.to_numpy()["x"].tolist())
                    == sorted(ref.to_numpy()["x"].tolist())), op.__name__
        print("4way hash join + set ops OK")
    """)
