"""Out-of-core spill subsystem tests (DESIGN.md §10).

Four layers, mirroring the spill contract:

  * host/device hash parity — the numpy partitioner must be bit-identical
    to the device hash + order lanes, or partition truthfulness breaks;
  * engine exactness — spilled join/groupby/window results are bit-exact
    against the all-in-memory oracle (the oracle gets capacity head-room
    so IT never overflows);
  * trigger semantics — ``spill="auto"`` stays in memory when the input
    fits the budget and spills when it does not, with identical row
    multisets and zero residual overflow either way;
  * durability — CRC-checked run files, fault injection (disk-full /
    partial write) surfacing named errors with no half-written runs left
    behind, and a clean retry.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from conftest import SRC

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from conftest import given, settings, st

import jax.numpy as jnp

from repro.core import local_context
from repro.core.report import OverflowError, OverflowReport
from repro.core.table import hash_columns
from repro.core.exchange import order_lanes
from repro.dataframe.frame import DataFrame
from repro.io.native import HptIntegrityError, read_hpt, write_hpt
from repro.spill import (FAULT_ENV, SpillStore, SpillWriteError,
                         reset_fault_injection, should_spill, spill_groupby,
                         spill_join, spill_window)
from repro.spill.hashing import (np_hash_columns, np_lex_order,
                                 np_order_lanes)


# ---------------------------------------------------------------------------
# helpers: dtype-robust row-multiset canonicalization + bit-equality
# ---------------------------------------------------------------------------
def _canon(d):
    """Sort rows into a canonical order by raw bytes (dtype-robust)."""
    names = sorted(d)
    n = len(np.asarray(d[names[0]])) if names else 0
    if n == 0:
        return {k: np.asarray(v) for k, v in d.items()}
    lanes = []
    for k in reversed(names):
        b = np.ascontiguousarray(d[k]).view(np.uint8).reshape(n, -1)
        lanes.extend(b[:, j] for j in range(b.shape[1] - 1, -1, -1))
    idx = np.lexsort(tuple(lanes))
    return {k: np.asarray(d[k])[idx] for k in names}


def assert_bitexact(got, want):
    """Equal row multisets with bit-identical values, any row order."""
    assert set(got) == set(want), (sorted(got), sorted(want))
    cg, cw = _canon(got), _canon(want)
    for k in cw:
        g, w = np.ascontiguousarray(cg[k]), np.ascontiguousarray(cw[k])
        assert g.shape == w.shape, (k, g.shape, w.shape)
        np.testing.assert_array_equal(g.view(np.uint8), w.view(np.uint8),
                                      err_msg=k)


def _frame(data, ctx, headroom=1):
    """DataFrame whose oracle path has capacity head-room: the in-memory
    reference must never itself overflow under shuffle skew."""
    n = len(next(iter(data.values())))
    cap = max(1, -(-n // ctx.n_shards)) * max(1, headroom)
    return DataFrame.from_dict(data, ctx, capacity=cap)


# ---------------------------------------------------------------------------
# host/device hash + lane parity
# ---------------------------------------------------------------------------
def _assert_hash_parity(cols):
    h1d, h2d = hash_columns([jnp.asarray(c) for c in cols])
    h1h, h2h = np_hash_columns(cols)
    np.testing.assert_array_equal(np.asarray(h1d), h1h)
    np.testing.assert_array_equal(np.asarray(h2d), h2h)


def test_np_hash_matches_device_mixed_dtypes():
    rng = np.random.default_rng(0)
    n = 512
    f = rng.standard_normal(n).astype(np.float32)
    f[::17] = np.nan
    f[::29] = -0.0
    cols = [rng.integers(-2**31, 2**31 - 1, n).astype(np.int32),
            f, rng.integers(0, 2, n).astype(bool),
            rng.integers(0, 2**32, n).astype(np.uint32)]
    _assert_hash_parity(cols)
    for c in cols:
        _assert_hash_parity([c])


def test_np_lanes_match_device_directions():
    rng = np.random.default_rng(1)
    n = 256
    f = rng.standard_normal(n).astype(np.float32)
    f[::11] = np.nan
    cols = {"i": rng.integers(-1000, 1000, n).astype(np.int32), "f": f,
            "b": rng.integers(0, 2, n).astype(bool)}
    for asc in ((True, True, True), (False, True, False)):
        dev = order_lanes({k: jnp.asarray(v) for k, v in cols.items()},
                          ("i", "f", "b"), asc)
        host = np_order_lanes(cols, ("i", "f", "b"), asc)
        np.testing.assert_array_equal(np.asarray(dev), host)
    # host lexsort over lanes == numpy argsort semantics (NaN last)
    lanes = np_order_lanes(cols, ("f",), (True,))
    order = np_lex_order(lanes)
    sorted_f = cols["f"][order]
    valid = sorted_f[~np.isnan(sorted_f)]
    assert (np.diff(valid) >= 0).all()
    assert np.isnan(sorted_f[-np.isnan(cols["f"]).sum():]).all()


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(-2**31, 2**31 - 1), min_size=1, max_size=64),
       st.lists(st.floats(width=32, allow_nan=True, allow_infinity=True),
                min_size=1, max_size=64))
def test_np_hash_matches_device_property(ints, floats):
    m = min(len(ints), len(floats))
    _assert_hash_parity([np.asarray(ints[:m], np.int32),
                         np.asarray(floats[:m], np.float32)])


# ---------------------------------------------------------------------------
# .hpt integrity: CRC + truncation + magic (satellite 1)
# ---------------------------------------------------------------------------
def test_hpt_crc_roundtrip_and_corruption(tmp_path):
    path = str(tmp_path / "run.hpt")
    cols = {"a": np.arange(100, dtype=np.int32),
            "b": np.linspace(0, 1, 100, dtype=np.float32)}
    header = write_hpt(path, cols, 100)
    assert set(header["crc32"]) == {"a", "b"}
    back, n = read_hpt(path)
    assert n == 100
    np.testing.assert_array_equal(back["a"], cols["a"])

    # flip one payload byte -> CRC mismatch names file and column
    raw = bytearray(open(path, "rb").read())
    raw[-3] ^= 0xFF
    open(path, "wb").write(bytes(raw))
    with pytest.raises(HptIntegrityError, match="run.hpt"):
        read_hpt(path)

    # truncate the payload -> named truncation error
    write_hpt(path, cols, 100)
    whole = open(path, "rb").read()
    open(path, "wb").write(whole[:-10])
    with pytest.raises(HptIntegrityError):
        read_hpt(path)

    # torn header / bad magic
    open(path, "wb").write(b"HPT1\x00")
    with pytest.raises(HptIntegrityError):
        read_hpt(path)
    open(path, "wb").write(b"JUNKJUNKJUNK")
    with pytest.raises(HptIntegrityError):
        read_hpt(path)


# ---------------------------------------------------------------------------
# engine exactness vs the in-memory oracle (local context)
# ---------------------------------------------------------------------------
def test_spill_join_bit_exact_all_hows():
    ctx = local_context()
    rng = np.random.default_rng(2)
    n = 1500
    left = {"k": rng.integers(0, 200, n).astype(np.int32),
            "v": rng.standard_normal(n).astype(np.float32)}
    # right keys only partially overlap so left/outer rows matter
    right = {"k": (np.arange(300, dtype=np.int32) - 50),
             "w": rng.standard_normal(300).astype(np.float32)}
    dl, dr = _frame(left, ctx), _frame(right, ctx)
    for how in ("inner", "left", "outer"):
        want = dl.join(dr, ["k"], how=how, max_matches=16).to_numpy()
        with spill_join(dl.table, dr.table, ("k",), ctx=ctx, budget_rows=128,
                        how=how, max_matches=16) as res:
            got = res.collect()
        assert_bitexact(got, want)


def test_spill_groupby_bit_exact():
    ctx = local_context()
    rng = np.random.default_rng(3)
    n = 4000
    data = {"k": rng.integers(0, 300, n).astype(np.int32),
            "v": rng.standard_normal(n).astype(np.float32)}
    df = _frame(data, ctx)
    aggs = [("v", "sum"), ("v", "min"), ("v", "count")]
    want = df.groupby(["k"], aggs).to_numpy()
    with spill_groupby(df.table, ("k",), aggs, ctx=ctx,
                       budget_rows=256) as res:
        got = res.collect()
        assert res.stats.rows_in == n
    assert_bitexact(got, want)


def test_spill_window_bit_exact_integer_valued():
    # rolling float sums are bit-exact only when addition is associative
    # on the data; integer-valued float32 makes it so (the continuous-
    # float caveat is documented in DESIGN.md §10)
    ctx = local_context()
    rng = np.random.default_rng(4)
    n = 2500
    data = {"g": rng.integers(0, 60, n).astype(np.int32),
            "t": rng.permutation(n).astype(np.int32),
            "x": rng.integers(-100, 100, n).astype(np.float32)}
    df = _frame(data, ctx)
    aggs = [("x", "sum"), ("x", "min"), (None, "row_number"),
            ("x", "lag", 1)]
    want = df.window(["g"], ["t"]).agg(aggs, rows=8).to_numpy()
    with spill_window(df.table, ("g",), ("t",), aggs, ctx=ctx,
                      budget_rows=300, rows=8) as res:
        got = res.collect()
    assert_bitexact(got, want)


def test_spill_join_empty_result_keeps_schema():
    ctx = local_context()
    dl = _frame({"k": np.arange(100, dtype=np.int32),
                 "v": np.ones(100, np.float32)}, ctx)
    dr = _frame({"k": np.arange(1000, 1010, dtype=np.int32),
                 "w": np.ones(10, np.float32)}, ctx)
    out = dl.join(dr, ["k"], spill=True, budget_rows=32)
    assert len(out) == 0
    assert {"k", "v", "w"} <= set(out.columns)


def test_skew_refinement_and_oversized_counted():
    # one dominant key cannot be split by any partitioner: the engine
    # must refine once, give up, count it oversized — and stay exact
    ctx = local_context()
    rng = np.random.default_rng(5)
    n = 2000
    k = np.where(rng.random(n) < 0.7, 7, rng.integers(0, 50, n)) \
        .astype(np.int32)
    data = {"k": k, "v": rng.standard_normal(n).astype(np.float32)}
    df = _frame(data, ctx)
    want = df.groupby(["k"], [("v", "sum"), ("v", "count")]).to_numpy()
    with spill_groupby(df.table, ("k",), (("v", "sum"), ("v", "count")),
                       ctx=ctx, budget_rows=100) as res:
        got = res.collect()
        assert res.stats.oversized >= 1
        assert res.stats.refined >= 1
    assert_bitexact(got, want)


# ---------------------------------------------------------------------------
# trigger semantics: the overflow -> spill boundary (satellite 4)
# ---------------------------------------------------------------------------
N_TRIG = 1000


@pytest.mark.parametrize("budget,expect_spill", [
    (N_TRIG, False),        # fits exactly: stay in memory
    (N_TRIG - 1, True),     # one row over the committed budget: spill
    (N_TRIG // 4, True),    # far over: spill
    (None, False),          # no budget committed: stay in memory
])
def test_auto_trigger_straddles_capacity_boundary(budget, expect_spill):
    ctx = local_context()
    rng = np.random.default_rng(6)
    data = {"k": rng.integers(0, 100, N_TRIG).astype(np.int32),
            "v": rng.standard_normal(N_TRIG).astype(np.float32)}
    df = _frame(data, ctx)
    assert should_spill(N_TRIG, ctx.n_shards, budget) == expect_spill
    aggs = [("v", "sum"), ("v", "count")]
    want = df.groupby(["k"], aggs).to_numpy()
    out = df.groupby(["k"], aggs, spill="auto", budget_rows=budget)
    assert_bitexact(out.to_numpy(), want)
    # the report tells which path ran, and certifies zero residual loss
    assert bool(out.overflow_report.recovered) == expect_spill
    assert out.overflow_report.is_exact()


def test_auto_retries_in_memory_overflow_via_spill():
    # an undersized out_capacity makes the in-memory groupby drop groups;
    # spill="auto" must catch the counted overflow and recover exactly
    ctx = local_context()
    rng = np.random.default_rng(7)
    n = 1200
    data = {"k": rng.integers(0, 400, n).astype(np.int32),
            "v": rng.standard_normal(n).astype(np.float32)}
    df = _frame(data, ctx)
    aggs = [("v", "sum")]
    want = df.groupby(["k"], aggs).to_numpy()
    with pytest.raises(OverflowError, match="overflowed static capacity"):
        df.groupby(["k"], aggs, out_capacity=64)
    out = df.groupby(["k"], aggs, out_capacity=64, spill="auto")
    assert_bitexact(out.to_numpy(), want)
    assert out.overflow_report.total_recovered >= n
    assert out.overflow_report.is_exact()


def test_join_auto_retry_and_forced_spill_agree():
    ctx = local_context()
    rng = np.random.default_rng(8)
    n = 900
    dl = _frame({"k": rng.integers(0, 80, n).astype(np.int32),
                 "v": rng.standard_normal(n).astype(np.float32)}, ctx)
    dr = _frame({"k": np.arange(80, dtype=np.int32),
                 "w": rng.standard_normal(80).astype(np.float32)}, ctx)
    want = dl.join(dr, ["k"], max_matches=16).to_numpy()
    with pytest.raises(OverflowError):
        dl.join(dr, ["k"], max_matches=16, out_capacity=64)
    auto = dl.join(dr, ["k"], max_matches=16, out_capacity=64, spill="auto")
    forced = dl.join(dr, ["k"], max_matches=16, spill=True, budget_rows=128)
    assert_bitexact(auto.to_numpy(), want)
    assert_bitexact(forced.to_numpy(), want)
    assert auto.overflow_report.is_exact()


def test_window_spill_and_residual_semantics():
    ctx = local_context()
    rng = np.random.default_rng(9)
    n = 800
    data = {"g": rng.integers(0, 20, n).astype(np.int32),
            "t": rng.permutation(n).astype(np.int32),
            "x": rng.integers(0, 50, n).astype(np.float32)}
    df = _frame(data, ctx)
    want = df.window(["g"], ["t"]).agg([("x", "sum")], rows=4).to_numpy()
    out = df.window(["g"], ["t"]).agg([("x", "sum")], rows=4,
                                      spill="auto", budget_rows=100)
    assert_bitexact(out.to_numpy(), want)
    assert out.overflow_report.is_exact()
    # residual semantic overflow (join fan-out cap) still raises via spill
    dl = _frame({"k": np.zeros(64, np.int32),
                 "v": np.arange(64, dtype=np.float32)}, ctx)
    dr = _frame({"k": np.zeros(8, np.int32),
                 "w": np.arange(8, dtype=np.float32)}, ctx)
    with pytest.raises(OverflowError):
        dl.join(dr, ["k"], max_matches=1, spill=True, budget_rows=16)


def test_spill_mode_validated_eagerly():
    ctx = local_context()
    df = _frame({"k": np.arange(8, dtype=np.int32),
                 "v": np.ones(8, np.float32)}, ctx)
    with pytest.raises(ValueError, match="spill="):
        df.groupby(["k"], [("v", "sum")], spill="yes")
    with pytest.raises(ValueError, match="spill="):
        df.join(df, ["k"], spill=1.5)


# ---------------------------------------------------------------------------
# unified report (satellite 2)
# ---------------------------------------------------------------------------
def test_overflow_report_api():
    r = OverflowReport()
    assert r.is_exact() and not r
    r.add("join.fanout", 0)
    assert r.entries == {}
    r.add("join.fanout", 3).add("scan.capacity", 2).add("join.fanout", 1)
    assert r.total == 6 and bool(r)
    r2 = OverflowReport().add_recovered("spill.join", 100)
    r.merge(r2)
    assert r.total_recovered == 100
    assert dict(r) == {"join.fanout": 4, "scan.capacity": 2}
    with pytest.raises(OverflowError, match="join.fanout=4"):
        r.assert_exact()
    OverflowReport().add_recovered("x", 5).assert_exact()


def test_report_threads_through_lineage_and_tset():
    ctx = local_context()
    rng = np.random.default_rng(10)
    n = 600
    df = _frame({"k": rng.integers(0, 50, n).astype(np.int32),
                 "v": rng.standard_normal(n).astype(np.float32)}, ctx)
    g = df.groupby(["k"], [("v", "sum")], spill=True, budget_rows=64)
    assert g.overflow_report.total_recovered == n
    # derived frames inherit the lineage report
    assert g.select(lambda c: c["k"] >= 0).overflow_report.total_recovered \
        == n
    # TSet: spill source report + barrier accounting reach the sink
    with spill_groupby(df.table, ("k",), (("v", "sum"),), ctx=ctx,
                       budget_rows=64) as res:
        ts = res.to_tset()
    out = ts.groupby(["k"], [("v_sum", "sum")])
    assert out.overflow_report is None  # not yet materialized
    out.collect()
    assert out.overflow_report.total_recovered == n
    assert out.overflow_report.is_exact()


def test_scan_stats_as_report():
    from repro.io.scan import ScanStats

    stats = ScanStats(rows_overflowed=7)
    rep = stats.as_report()
    assert dict(rep) == {"scan.capacity": 7}
    assert ScanStats().as_report().is_exact()


# ---------------------------------------------------------------------------
# fault injection (satellite 3)
# ---------------------------------------------------------------------------
def _spill_inputs(ctx):
    rng = np.random.default_rng(11)
    n = 400
    return _frame({"k": rng.integers(0, 40, n).astype(np.int32),
                   "v": rng.standard_normal(n).astype(np.float32)}, ctx), n


@pytest.mark.parametrize("point", ["disk_full", "partial_write"])
def test_fault_injection_named_error_no_leaks_then_retry(
        point, tmp_path, monkeypatch):
    ctx = local_context()
    df, n = _spill_inputs(ctx)
    workdir = str(tmp_path / "scratch")
    monkeypatch.setenv(FAULT_ENV, f"{point}:3")
    reset_fault_injection()
    try:
        with pytest.raises(SpillWriteError, match="free disk space"):
            spill_groupby(df.table, ("k",), (("v", "sum"),), ctx=ctx,
                          budget_rows=64, workdir=workdir)
        # error path closed the store: no runs, no half-written temp files
        assert not os.path.isdir(workdir) or not os.listdir(workdir)
        # the injector disarmed after firing: the retry succeeds
        want = df.groupby(["k"], [("v", "sum")]).to_numpy()
        with spill_groupby(df.table, ("k",), (("v", "sum"),), ctx=ctx,
                           budget_rows=64, workdir=workdir) as res:
            assert res.store.leftover_temp_files() == []
            got = res.collect()
        assert_bitexact(got, want)
    finally:
        reset_fault_injection()


def test_fault_injection_rejects_unknown_point(monkeypatch, tmp_path):
    ctx = local_context()
    df, _ = _spill_inputs(ctx)
    monkeypatch.setenv(FAULT_ENV, "meteor_strike:1")
    reset_fault_injection()
    try:
        with pytest.raises(ValueError, match="meteor_strike"):
            spill_groupby(df.table, ("k",), (("v", "sum"),), ctx=ctx,
                          budget_rows=64, workdir=str(tmp_path / "s"))
    finally:
        reset_fault_injection()


def test_store_write_failure_cleans_tmp(monkeypatch, tmp_path):
    monkeypatch.setenv(FAULT_ENV, "partial_write:1")
    reset_fault_injection()
    try:
        store = SpillStore(str(tmp_path / "s"))
        with pytest.raises(SpillWriteError):
            store.write_run("in", 0, 0, {"a": np.arange(4)}, 4)
        assert store.leftover_temp_files() == []
        # next write (same env, already fired) succeeds atomically
        store.write_run("in", 0, 0, {"a": np.arange(4)}, 4)
        cols, nn = store.read_partition("in", 0, 0)
        assert nn == 4
        store.close()
        assert not os.path.isdir(store.root)
    finally:
        reset_fault_injection()


# ---------------------------------------------------------------------------
# 4-shard: spilled partitions re-enter on the elided paths (jaxpr-proofed)
# ---------------------------------------------------------------------------
def _run_devices(script: str, n: int = 4, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-4000:]}"
    return r.stdout


def test_spill_elision_4way():
    out = _run_devices("""
        import re
        import jax, numpy as np, jax.numpy as jnp
        from repro.core import (Table, DistTable, HPTMTContext, make_mesh,
                                table_ops)
        from repro.dataframe.frame import DataFrame
        from repro.spill import spill_join, spill_window
        from repro.spill.engine import (_load_hash_partition,
                                        _load_range_partition,
                                        _partition_hash, _partition_window)
        from repro.spill.store import SpillStore

        ctx = HPTMTContext(mesh=make_mesh((4,), ("data",)))
        rng = np.random.default_rng(12)
        n = 6000
        left = {"k": rng.integers(0, 700, n).astype(np.int32),
                "v": rng.standard_normal(n).astype(np.float32)}
        right = {"k": np.arange(700, dtype=np.int32),
                 "w": rng.standard_normal(700).astype(np.float32)}
        def frame(d):
            rows = len(next(iter(d.values())))
            return DataFrame.from_dict(
                d, ctx, capacity=2 * -(-rows // ctx.n_shards))
        dl, dr = frame(left), frame(right)

        # end-to-end parity at 4 shards through the DataFrame trigger
        want = dl.join(dr, ["k"], max_matches=4).to_numpy()
        got = dl.join(dr, ["k"], max_matches=4, spill=True,
                      budget_rows=400).to_numpy()
        names = sorted(want)
        def canon(d):
            m = len(next(iter(d.values())))
            lanes = []
            for k in reversed(names):
                b = np.ascontiguousarray(d[k]).view(np.uint8).reshape(m, -1)
                lanes.extend(b[:, j] for j in range(b.shape[1] - 1, -1, -1))
            idx = np.lexsort(tuple(lanes))
            return {k: np.asarray(d[k])[idx] for k in names}
        cw, cg = canon(want), canon(got)
        for k in names:
            a = np.ascontiguousarray(cw[k]).view(np.uint8)
            b = np.ascontiguousarray(cg[k]).view(np.uint8)
            assert a.shape == b.shape and (a == b).all(), k

        # a re-ingested partition-pair joins with ZERO AllToAll
        store = SpillStore()
        _, ls = _partition_hash(store, "left", dl.table, ("k",), 4, 8)
        _, rs = _partition_hash(store, "right", dr.table, ("k",), 4, 8)
        q = store.partitions("left")[0]
        ldt = _load_hash_partition(store, "left", q, ls, ("k",), ctx, 512)
        rdt = _load_hash_partition(store, "right", q, rs, ("k",), ctx, 512)
        assert ldt.partitioning == (("k",), 4)
        jx = str(jax.make_jaxpr(lambda a, b: table_ops.join(
            a, b, ("k",), ctx=ctx, max_matches=4))(ldt, rdt))
        assert jx.count("all_to_all") == 0, jx.count("all_to_all")
        store.close()

        # a re-ingested window partition: ZERO AllToAll, ZERO sorts
        wd = {"g": rng.integers(0, 50, 4000).astype(np.int32),
              "t": rng.permutation(4000).astype(np.int32),
              "x": rng.integers(0, 9, 4000).astype(np.float32)}
        dw = frame(wd)
        store = SpillStore()
        _, ws = _partition_window(store, "in", dw.table, ("g",),
                                  ("g", "t"), (True, True), 8)
        q = store.partitions("in")[0]
        wdt = _load_range_partition(store, "in", q, ws, ("g", "t"),
                                    (True, True), ctx, 512)
        aggs = [("x", "sum"), (None, "row_number")]
        jx = str(jax.make_jaxpr(lambda d: table_ops.window_aggregate(
            d, ("g",), ("t",), aggs, ctx=ctx, rows=8))(wdt))
        assert jx.count("all_to_all") == 0, jx.count("all_to_all")
        # \bsort\b: the sort PRIMITIVE — 'indices_are_sorted' gather
        # attrs contain the substring but are not sorts
        assert len(re.findall(r"\\bsort\\b", jx)) == 0, jx
        # the unsorted input DOES sort (the assertion has teeth)
        jd = str(jax.make_jaxpr(lambda d: table_ops.window_aggregate(
            d, ("g",), ("t",), aggs, ctx=ctx, rows=8))(dw.table))
        assert len(re.findall(r"\\bsort\\b", jd)) >= 1
        store.close()

        # full spilled window parity at 4 shards (integer-valued floats)
        wwant = dw.window(["g"], ["t"]).agg(aggs, rows=8).to_numpy()
        wgot = dw.window(["g"], ["t"]).agg(aggs, rows=8, spill=True,
                                           budget_rows=300).to_numpy()
        names = sorted(wwant)
        cw, cg = canon(wwant), canon(wgot)
        for k in names:
            a = np.ascontiguousarray(cw[k]).view(np.uint8)
            b = np.ascontiguousarray(cg[k]).view(np.uint8)
            assert a.shape == b.shape and (a == b).all(), k
        print("SPILL-ELISION-4WAY-OK")
        """)
    assert "SPILL-ELISION-4WAY-OK" in out
