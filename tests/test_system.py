"""End-to-end system behaviour: the paper's architecture working as a whole.

The flagship test mirrors Fig 14: dataflow table operators prepare data,
tensor operators train, the workflow engine orchestrates with fault
tolerance — one HPTMT program.
"""
import numpy as np
import pytest

from repro.core import local_context


def test_end_to_end_pipeline_train_serve(tmp_path):
    import jax
    from repro.configs import get_config, reduced_config
    from repro.data.pipeline import CorpusConfig, make_training_data
    from repro.serve.engine import Engine, ServeConfig
    from repro.train.optimizer import OptimizerConfig
    from repro.train.train_step import TrainConfig
    from repro.train.trainer import LoopConfig, train_loop
    from repro.workflow.engine import Task, WorkflowEngine

    ctx = local_context()
    cfg = reduced_config(get_config("smollm-360m"))
    tcfg = TrainConfig(optimizer=OptimizerConfig(
        learning_rate=3e-3, warmup_steps=2, total_steps=30))

    results = {}

    def prepare():
        return make_training_data(
            cfg, ctx, batch=4, seq_len=24,
            ccfg=CorpusConfig(n_docs=32, mean_doc_len=48,
                              vocab_size=cfg.vocab_size, seed=3))

    def train(prepare):
        loop = LoopConfig(total_steps=25, log_every=10,
                          checkpoint_every=10,
                          checkpoint_dir=str(tmp_path / "ckpt"))
        state = train_loop(cfg, tcfg, loop, prepare, log_fn=lambda s: None)
        from repro.train.trainer import train_loop as tl
        results["history"] = tl.last_history
        return state

    def serve(train):
        eng = Engine(cfg, train.params, ServeConfig(max_len=48))
        import jax.numpy as jnp
        prompts = jnp.asarray(
            np.random.default_rng(0).integers(1, cfg.vocab_size, (2, 8)),
            jnp.int32)
        return eng.generate(prompts, n_tokens=5)

    wf = WorkflowEngine(str(tmp_path / "journal.json"))
    wf.add(Task("prepare", prepare))
    wf.add(Task("train", train, deps=("prepare",)))
    wf.add(Task("serve", serve, deps=("train",)))
    out = wf.run()

    hist = results["history"]
    assert hist[-1] < hist[0], f"loss did not decrease: {hist[0]}→{hist[-1]}"
    gen = out["serve"]
    assert gen.shape == (2, 5)
    assert gen.dtype == np.int32
    assert np.all((gen >= 0) & (gen < cfg.vocab_size))


def test_mds_composition():
    """Paper Fig 14: table operators → distance matrix → SMACOF MDS on
    array operators, in one program."""
    from repro.apps.mds import mds_pipeline

    ctx = local_context()
    stress_path, embedding = mds_pipeline(n_points=24, dim=2, iters=30,
                                          ctx=ctx, seed=0)
    assert embedding.shape == (24, 2)
    assert stress_path[-1] < stress_path[0] * 0.8, stress_path[::10]
    assert np.all(np.isfinite(np.asarray(embedding)))
