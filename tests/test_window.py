"""Ordered-analytics subsystem (DESIGN.md §9): multi-key orderby, range
partitioning metadata, windowed aggregation, rank/top-k/quantile.

Four layers of guarantees:

  * parity — every ordered operator against a numpy oracle, including
    duplicate keys, NaN keys, descending directions, and windows larger
    than their partition;
  * the NaN-last contract — NaNs are one deterministic block at the END
    of the sort in BOTH directions (the old ``-x`` negation flipped them
    to the front under descending);
  * kernel fidelity — the Pallas windowed scan in interpret mode is
    bit-identical to the jnp reference;
  * elision — orderby produces range metadata, window/rank/quantile
    consume it, and the traced jaxpr of the chain really contains the
    promised AllToAll/sort counts (4-device subprocess leg).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # tier-1 env may lack hypothesis: skip only @given tests
    from conftest import given, settings, st

from repro.core import (DistTable, Table, local_context, partitioning_kind,
                        range_partitioning, table_ops)
from repro.core.dataflow import TSet
from repro.dataframe.frame import DataFrame

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
RNG = np.random.default_rng(23)
CTX = local_context()


def make_dt(d, capacity=None):
    t = Table.from_arrays({k: jnp.asarray(v) for k, v in d.items()},
                          capacity=capacity)
    return DistTable.from_local(t, CTX)


# ---------------------------------------------------------------------------
# numpy oracle for the ordering contract (monotone lanes, NaN-last)
# ---------------------------------------------------------------------------
def np_lane(col, asc=True):
    """The DESIGN.md §9 monotone-u32 transform, in numpy."""
    a = np.asarray(col)
    if a.dtype.kind == "f":
        b = a.astype(np.float32).view(np.uint32)
        m = np.where(b >> 31 != 0, ~b, b | np.uint32(0x80000000))
        if not asc:
            m = ~m
        return np.where(np.isnan(a), np.uint32(0xFFFFFFFF), m)
    if a.dtype.kind == "b" or a.dtype.kind == "u":
        m = a.astype(np.uint32)
    else:
        m = a.astype(np.int32).view(np.uint32) ^ np.uint32(0x80000000)
    return m if asc else ~m


def np_order(cols, ascending):
    """Oracle sort permutation: stable lexsort of the monotone lanes."""
    lanes = [np_lane(c, a) for c, a in zip(cols, ascending)]
    return np.lexsort(tuple(lanes[::-1][i] for i in range(len(lanes))))


def np_groups(cols):
    """Partition ids under the ordering identity (NaNs one group)."""
    lanes = np.stack([np_lane(c, True) for c in cols], axis=1) \
        if cols else np.zeros((len(cols[0]) if cols else 0, 0), np.uint32)
    _, ids = np.unique(lanes, axis=0, return_inverse=True)
    return ids


# ---------------------------------------------------------------------------
# multi-key orderby
# ---------------------------------------------------------------------------
def test_orderby_multikey_vs_numpy():
    n = 300
    g = RNG.integers(-5, 5, n).astype(np.int32)
    x = RNG.normal(size=n).astype(np.float32)
    dt = make_dt({"g": g, "x": x})
    for asc in ((True, True), (False, True), (True, False), (False, False)):
        out, ov = table_ops.orderby(dt, ["g", "x"], ascending=list(asc),
                                    ctx=CTX)
        assert int(ov) == 0
        got = out.to_numpy()
        order = np_order([g, x], asc)
        np.testing.assert_array_equal(got["g"], g[order], err_msg=str(asc))
        np.testing.assert_array_equal(got["x"], x[order], err_msg=str(asc))
        assert out.partitioning == range_partitioning(("g", "x"), asc, 1)
    # full-row multiset is preserved
    srt, _ = table_ops.orderby(dt, ["g", "x"], ctx=CTX)
    got = srt.to_numpy()
    assert sorted(zip(got["g"].tolist(), got["x"].tolist())) == \
        sorted(zip(g.tolist(), x.tolist()))


def test_orderby_nan_last_both_directions():
    """The satellite fix: descending float sorts keep NaNs LAST (the seed
    ``_negate`` flipped them to the front)."""
    x = np.array([3.0, np.nan, -1.0, np.nan, 2.0, -np.inf, np.inf, -0.0,
                  0.0], np.float32)
    dt = make_dt({"x": x})
    nn = (~np.isnan(x)).sum()
    for asc in (True, False):
        out, ov = table_ops.orderby(dt, "x", ascending=asc, ctx=CTX)
        assert int(ov) == 0
        got = out.to_numpy()["x"]
        assert np.all(np.isnan(got[nn:])), (asc, got)
        assert not np.any(np.isnan(got[:nn])), (asc, got)
        exp = np.sort(x[~np.isnan(x)])
        np.testing.assert_allclose(got[:nn], exp if asc else exp[::-1])
    # the total order separates -0.0 / +0.0 deterministically
    asc_got = table_ops.orderby(dt, "x", ctx=CTX)[0].to_numpy()["x"]
    signs = np.signbit(asc_got[np.where(asc_got[:nn] == 0.0)[0]])
    np.testing.assert_array_equal(signs, [True, False])


@settings(max_examples=30, deadline=None)
@given(vals=st.lists(st.one_of(st.floats(-100, 100, width=32),
                               st.just(float("nan"))),
                     min_size=1, max_size=48),
       keys=st.lists(st.integers(0, 5), min_size=1, max_size=48),
       asc_k=st.booleans(), asc_v=st.booleans())
def test_orderby_property(vals, keys, asc_k, asc_v):
    n = min(len(vals), len(keys))
    k = np.array(keys[:n], np.int32)
    v = np.array(vals[:n], np.float32)
    dt = make_dt({"k": k, "v": v})
    out, ov = table_ops.orderby(dt, ["k", "v"], ascending=[asc_k, asc_v],
                                ctx=CTX)
    assert int(ov) == 0
    got = out.to_numpy()
    order = np_order([k, v], (asc_k, asc_v))
    np.testing.assert_array_equal(got["k"], k[order])
    np.testing.assert_array_equal(
        np.isnan(got["v"]), np.isnan(v[order]))
    np.testing.assert_array_equal(
        np.nan_to_num(got["v"]), np.nan_to_num(v[order]))


# ---------------------------------------------------------------------------
# windowed aggregation vs a brute-force numpy oracle
# ---------------------------------------------------------------------------
def np_window_oracle(g_cols, o_cols, v, rows):
    """Brute-force rolling/cumulative windows, ranks, lag/lead."""
    n = len(v)
    order = np_order(list(g_cols) + list(o_cols),
                     (True,) * (len(g_cols) + len(o_cols)))
    gid = np_groups([c[order] for c in g_cols]) if g_cols else \
        np.zeros(n, np.int64)
    rid = np_groups([c[order] for c in list(g_cols) + list(o_cols)])
    sv = v[order]
    out = {k: np.zeros(n) for k in ("sum", "mean", "count", "min", "max",
                                    "row_number", "rank", "lag", "lead")}
    for i in range(n):
        s0 = i
        while s0 > 0 and gid[s0 - 1] == gid[i]:
            s0 -= 1
        a = s0 if rows is None else max(i - rows + 1, s0)
        win = sv[a:i + 1]
        out["sum"][i] = win.sum()
        out["mean"][i] = win.mean()
        out["count"][i] = i - a + 1
        out["min"][i] = win.min()
        out["max"][i] = win.max()
        out["row_number"][i] = i - s0 + 1
        r0 = i
        while r0 > 0 and rid[r0 - 1] == rid[i]:
            r0 -= 1
        out["rank"][i] = r0 - s0 + 1
        out["lag"][i] = sv[i - 1] if i - 1 >= s0 else 0.0
        seg_end = i
        while seg_end + 1 < n and gid[seg_end + 1] == gid[i]:
            seg_end += 1
        out["lead"][i] = sv[i + 1] if i + 1 <= seg_end else 0.0
    return order, out


AGGS = [("v", "sum"), ("v", "mean"), (None, "count"), ("v", "min"),
        ("v", "max"), (None, "row_number"), (None, "rank"), ("v", "lag"),
        ("v", "lead")]
LABELS = {"v_sum": "sum", "v_mean": "mean", "count": "count",
          "v_min": "min", "v_max": "max", "row_number": "row_number",
          "rank": "rank", "v_lag": "lag", "v_lead": "lead"}


def check_window(g, t, v, rows):
    dt = make_dt({"g": g, "t": t, "v": v})
    out, ov = table_ops.window_aggregate(dt, ["g"], ["t"], AGGS, rows=rows,
                                         ctx=CTX)
    assert int(ov) == 0
    got = out.to_numpy()
    _, exp = np_window_oracle([g], [t], v, rows)
    for lbl, key in LABELS.items():
        np.testing.assert_allclose(got[lbl], exp[key], rtol=1e-4, atol=1e-4,
                                   err_msg=f"rows={rows} {lbl}")


def test_window_rolling_and_cumulative_vs_numpy():
    n = 257
    g = RNG.integers(0, 6, n).astype(np.int32)
    t = RNG.integers(0, 30, n).astype(np.int32)  # duplicate order keys
    v = RNG.normal(size=n).astype(np.float32)
    for rows in (1, 4, 32, None):
        check_window(g, t, v, rows)


def test_window_larger_than_partition_and_nan_keys():
    # windows clip at partition starts; NaN partition keys form ONE
    # partition (the ordering identity, DESIGN.md §9)
    n = 80
    g = RNG.normal(size=n).astype(np.float32)
    g[RNG.random(n) < 0.3] = np.nan
    g[RNG.random(n) < 0.3] = 1.5  # duplicates
    t = RNG.integers(0, 9, n).astype(np.int32)
    v = RNG.normal(size=n).astype(np.float32)
    check_window(g, t, v, rows=50)
    check_window(g, t, v, rows=None)


def test_window_multi_partition_and_order_keys():
    n = 120
    g1 = RNG.integers(0, 3, n).astype(np.int32)
    g2 = RNG.integers(0, 3, n).astype(np.int32)
    t = RNG.integers(0, 8, n).astype(np.int32)
    v = RNG.normal(size=n).astype(np.float32)
    dt = make_dt({"a": g1, "b": g2, "t": t, "v": v})
    out, ov = table_ops.window_aggregate(
        dt, ["a", "b"], ["t"], [("v", "sum"), (None, "rank")], rows=5,
        ctx=CTX)
    assert int(ov) == 0
    got = out.to_numpy()
    order, exp = np_window_oracle([g1, g2], [t], v, 5)
    np.testing.assert_allclose(got["v_sum"], exp["sum"], rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_array_equal(got["rank"], exp["rank"])


def test_window_lag_lead_offsets():
    n = 64
    g = RNG.integers(0, 4, n).astype(np.int32)
    t = np.arange(n, dtype=np.int32)
    v = RNG.normal(size=n).astype(np.float32)
    dt = make_dt({"g": g, "t": t, "v": v})
    out, ov = table_ops.window_aggregate(
        dt, ["g"], ["t"], [("v", "lag", 3), ("v", "lead", 2)], rows=4,
        ctx=CTX)
    assert int(ov) == 0
    got = out.to_numpy()
    order = np_order([g, t], (True, True))
    sg, sv = g[order], v[order]
    for i in range(n):
        s0 = i
        while s0 > 0 and sg[s0 - 1] == sg[i]:
            s0 -= 1
        exp_lag = sv[i - 3] if i - 3 >= s0 else 0.0
        in_seg = i + 2 < n and np.all(sg[i:i + 3] == sg[i])
        exp_lead = sv[i + 2] if in_seg else 0.0
        np.testing.assert_allclose(got["v_lag3"][i], exp_lag, rtol=1e-6)
        np.testing.assert_allclose(got["v_lead2"][i], exp_lead, rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(keys=st.lists(st.integers(0, 4), min_size=1, max_size=40),
       vals=st.lists(st.floats(-50, 50, width=32), min_size=1, max_size=40),
       rows=st.one_of(st.none(), st.integers(1, 8)))
def test_window_property(keys, vals, rows):
    n = min(len(keys), len(vals))
    g = np.array(keys[:n], np.int32)
    t = np.arange(n, dtype=np.int32)
    v = np.array(vals[:n], np.float32)
    dt = make_dt({"g": g, "t": t, "v": v})
    out, ov = table_ops.window_aggregate(
        dt, ["g"], ["t"], [("v", "sum"), (None, "count"), (None, "rank")],
        rows=rows, ctx=CTX)
    assert int(ov) == 0
    got = out.to_numpy()
    _, exp = np_window_oracle([g], [t], v, rows)
    np.testing.assert_allclose(got["v_sum"], exp["sum"], rtol=1e-3,
                               atol=1e-3)
    np.testing.assert_array_equal(got["count"], exp["count"])
    np.testing.assert_array_equal(got["rank"], exp["rank"])


# ---------------------------------------------------------------------------
# Pallas windowed scan: interpret mode is bit-identical to the reference
# ---------------------------------------------------------------------------
def test_windowed_scan_pallas_bit_equality():
    from repro.kernels.window_scan import ops as wops

    n = 1111
    vals = jnp.asarray(RNG.normal(size=(n, 3)).astype(np.float32))
    flags = np.zeros(n, bool)
    flags[0] = True
    flags[np.sort(RNG.choice(np.arange(1, n), 40, replace=False))] = True
    seg = jnp.asarray(np.maximum.accumulate(
        np.where(flags, np.arange(n), 0)).astype(np.int32))
    for w in (1, 7, 64, 512):
        for op in ("sum", "min", "max"):
            ref = wops.windowed_scan(vals, seg, w, op)
            pal = wops.windowed_scan(vals, seg, w, op, force="pallas")
            np.testing.assert_array_equal(np.asarray(ref), np.asarray(pal),
                                          err_msg=f"w={w} op={op}")


def test_windowed_scan_semantics_vs_bruteforce():
    from repro.kernels.window_scan import ops as wops

    n, w = 203, 9
    vals = RNG.normal(size=(n, 1)).astype(np.float32)
    flags = np.zeros(n, bool)
    flags[0] = True
    flags[np.sort(RNG.choice(np.arange(1, n), 11, replace=False))] = True
    seg = np.maximum.accumulate(np.where(flags, np.arange(n), 0))
    got = np.asarray(wops.windowed_scan(
        jnp.asarray(vals), jnp.asarray(seg, np.int32), w, "sum"))[:, 0]
    exp = np.array([vals[max(i - w + 1, seg[i]):i + 1, 0].sum()
                    for i in range(n)])
    np.testing.assert_allclose(got, exp, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# top-k and quantile
# ---------------------------------------------------------------------------
def test_topk_vs_numpy():
    n = 500
    v = RNG.normal(size=n).astype(np.float32)
    g = RNG.integers(0, 50, n).astype(np.int32)  # duplicates
    dt = make_dt({"g": g, "v": v})
    top = table_ops.topk(dt, "v", 12, ctx=CTX)
    np.testing.assert_allclose(top.to_numpy()["v"],
                               np.sort(v)[::-1][:12], rtol=1e-6)
    assert partitioning_kind(top.partitioning) == "range"
    # smallest-k via largest=False; multi-key with duplicate primaries
    bot = table_ops.topk(dt, ["g", "v"], 7, largest=False, ctx=CTX)
    got = bot.to_numpy()
    order = np_order([g, v], (True, True))
    np.testing.assert_array_equal(got["g"], g[order][:7])
    np.testing.assert_allclose(got["v"], v[order][:7], rtol=1e-6)
    # k beyond the row count returns everything
    small = make_dt({"v": np.array([3., 1., 2.], np.float32)})
    allk = table_ops.topk(small, "v", 64, ctx=CTX)
    np.testing.assert_allclose(np.sort(allk.to_numpy()["v"]), [1., 2., 3.])


def test_quantile_exact_and_approx():
    n = 4000
    v = RNG.normal(size=n).astype(np.float32)
    v[RNG.random(n) < 0.05] = np.nan
    dt = make_dt({"v": v})
    qs = (0.0, 0.1, 0.5, 0.9, 1.0)
    exact = np.asarray(table_ops.quantile(dt, "v", qs, method="exact",
                                          ctx=CTX))
    np.testing.assert_allclose(exact, np.nanquantile(v, qs), rtol=1e-5,
                               atol=1e-6)
    # exact off a pre-sorted input elides the internal sort, same numbers
    srt, _ = table_ops.orderby(dt, "v", ctx=CTX)
    exact2 = np.asarray(table_ops.quantile(srt, "v", qs, ctx=CTX))
    np.testing.assert_allclose(exact2, exact, rtol=1e-6)
    # approx: rank error bounded by the sampling density (~sqrt(q(1-q)/m))
    approx = np.asarray(table_ops.quantile(dt, "v", qs, method="approx",
                                           n_samples=512, ctx=CTX))
    valid = np.sort(v[~np.isnan(v)])
    ranks = np.searchsorted(valid, approx) / len(valid)
    assert np.all(np.abs(ranks - np.asarray(qs)) < 0.06), (ranks, qs)


def test_quantile_empty_and_scalar_frame_api():
    df = DataFrame.from_dict({"v": np.arange(10, dtype=np.float32)}, CTX)
    assert df.quantile("v", 0.5) == pytest.approx(4.5)
    arr = df.quantile("v", [0.0, 1.0])
    np.testing.assert_allclose(arr, [0.0, 9.0])
    empty = make_dt({"v": np.zeros(4, np.float32)})
    empty = DistTable(empty.columns, jnp.zeros(1, jnp.int32))
    out = np.asarray(table_ops.quantile(empty, "v", (0.5,), method="exact",
                                        ctx=CTX))
    assert np.isnan(out).all()


# ---------------------------------------------------------------------------
# metadata contract (§4 rules extended to range layouts) + frame/TSet API
# ---------------------------------------------------------------------------
def test_range_metadata_contract():
    n = 64
    dt = make_dt({"k": RNG.integers(0, 9, n).astype(np.int32),
                  "t": RNG.integers(0, 9, n).astype(np.int32),
                  "v": RNG.normal(size=n).astype(np.float32)})
    srt, _ = table_ops.orderby(dt, ["k", "t"], ctx=CTX)
    part = range_partitioning(("k", "t"), (True, True), 1)
    assert srt.partitioning == part
    # select keeps rows in place (stable compaction) -> preserved
    sel = table_ops.select(srt, lambda c: c["v"] > -10, ctx=CTX)
    assert sel.partitioning == part
    # project: keeping every key preserves, dropping one drops
    assert table_ops.project(srt, ["k", "t"], ctx=CTX).partitioning == part
    assert table_ops.project(srt, ["k", "v"], ctx=CTX).partitioning is None
    # window adds columns without moving rows -> output carries the layout
    w, _ = table_ops.window_aggregate(srt, ["k"], ["t"], [("v", "sum")],
                                      rows=4, ctx=CTX)
    assert w.partitioning == part
    # hash operators overwrite with hash evidence
    gb, _ = table_ops.groupby_aggregate(srt, ["k"], [("v", "sum")], ctx=CTX)
    assert gb.partitioning == (("k",), 1)
    # TSet: row-chunking preserves a range layout; multi-chunk concat and
    # key-rewriting maps drop it
    chunks = TSet.from_table(srt, CTX, chunk_rows=16)
    for c in chunks._node.payload["chunks"]:
        assert c.partitioning == part
    assert chunks.collect().partitioning is None  # interleaved concat
    kept = TSet.from_table(srt, CTX).map_columns(
        lambda c: {"v": c["v"] * 2}).collect()
    assert kept.partitioning == part
    dropped = TSet.from_table(srt, CTX).map_columns(
        lambda c: {"t": c["t"] + 1}).collect()
    assert dropped.partitioning is None


def test_frame_api_and_validation():
    df = DataFrame.from_dict({
        "g": RNG.integers(0, 4, 60).astype(np.int32),
        "t": RNG.integers(0, 60, 60).astype(np.int32),
        "v": RNG.normal(size=60).astype(np.float32)}, CTX)
    assert df.partitioning_kind is None
    rp = df.repartition(["g"])
    assert rp.partitioning_kind == "hash"
    rr = df.repartition(["g", "t"], mode="range")
    assert rr.partitioning_kind == "range"
    # the sorted frame windows with no further exchange, columns added
    w = rr.window(["g"], ["t"]).agg([("v", "mean"), (None, "row_number")],
                                    rows=8)
    assert set(w.columns) >= {"g", "t", "v", "v_mean", "row_number"}
    assert len(w) == len(df)
    rk = df.rank(["g"], ["t"])
    assert "rank" in rk.columns and "row_number" in rk.columns
    top = df.topk("v", 5)
    assert len(top) == 5
    # eager validation names the offending kwarg/entry
    with pytest.raises(ValueError, match="mode="):
        df.repartition(["g"], mode="sideways")
    with pytest.raises(ValueError, match="keys="):
        df.repartition(["nope"])
    with pytest.raises(ValueError, match="by="):
        df.sort_values(["g", "nope"])
    with pytest.raises(ValueError, match="ascending="):
        df.sort_values(["g", "t"], ascending=[True])
    with pytest.raises(ValueError, match="unknown window op"):
        df.window(["g"], ["t"]).agg([("v", "median")])
    with pytest.raises(ValueError, match="rows="):
        df.window(["g"], ["t"]).agg([("v", "sum")], rows=0)
    with pytest.raises(ValueError, match="offset"):
        df.window(["g"], ["t"]).agg([("v", "lag", 0)])
    with pytest.raises(ValueError, match="collides"):
        df.window(["g"], ["t"]).agg([("v", "sum"), ("v", "sum")])
    with pytest.raises(ValueError, match="partition_by="):
        df.window(["nope"], ["t"]).agg([("v", "sum")])
    with pytest.raises(ValueError, match="method="):
        df.quantile("v", 0.5, method="guess")
    with pytest.raises(ValueError, match="qs="):
        df.quantile("v", [0.5, 1.5])
    with pytest.raises(ValueError, match="column="):
        table_ops.quantile(df.table, "nope", 0.5, ctx=CTX)
    with pytest.raises(ValueError, match="k="):
        df.topk("v", 0)


def test_tset_window_and_topk_match_eager():
    n = 128
    g = RNG.integers(0, 5, n).astype(np.int32)
    t = RNG.integers(0, 40, n).astype(np.int32)
    v = RNG.normal(size=n).astype(np.float32)
    dt = make_dt({"g": g, "t": t, "v": v})
    ts = TSet.from_table(dt, CTX, chunk_rows=32)
    got = ts.window(["g"], ["t"], [("v", "sum")], rows=6).collect()
    exp, _ = table_ops.window_aggregate(dt, ["g"], ["t"], [("v", "sum")],
                                        rows=6, ctx=CTX)
    np.testing.assert_allclose(got.to_numpy()["v_sum"],
                               exp.to_numpy()["v_sum"], rtol=1e-5)
    topc = ts.topk("v", 9).collect()
    np.testing.assert_allclose(topc.to_numpy()["v"],
                               np.sort(v)[::-1][:9], rtol=1e-6)
    q = np.asarray(ts.quantile("v", (0.5,), method="exact"))
    np.testing.assert_allclose(q, np.quantile(v, 0.5), rtol=1e-5)


# ---------------------------------------------------------------------------
# 4-shard subprocess leg: parity + the AllToAll/sort elision contract
# ---------------------------------------------------------------------------
def _run_devices(script: str, n: int = 4, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-4000:]}"
    return r.stdout


def test_ordered_chain_4way():
    """The acceptance chain: orderby = ONE AllToAll; window/rank/quantile
    on the same keys add ZERO AllToAll and ZERO sorts; values match the
    single-device oracle bit-for-bit where exact."""
    out = _run_devices("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.core import (Table, DistTable, HPTMTContext, make_mesh,
                                local_context, table_ops,
                                range_partitioning)
        mesh = make_mesh((4,), ("data",))
        ctx = HPTMTContext(mesh=mesh)
        one = local_context()
        rng = np.random.default_rng(11)
        n = 512
        g = rng.integers(0, 11, n).astype(np.int32)
        t = rng.integers(0, 60, n).astype(np.int32)
        v = rng.normal(size=n).astype(np.float32)
        mk = lambda c: Table.from_arrays(
            {k: jnp.asarray(x) for k, x in c.items()})
        dt = DistTable.from_local(mk({"g": g, "t": t, "v": v}), ctx,
                                  capacity=256)
        dt1 = DistTable.from_local(mk({"g": g, "t": t, "v": v}), one)

        # orderby: exactly ONE AllToAll, zero for the elided re-sort
        jx = str(jax.make_jaxpr(lambda d: table_ops.orderby(
            d, ["g", "t"], ctx=ctx))(dt))
        assert jx.count("all_to_all") == 1, jx.count("all_to_all")
        srt, ov = table_ops.orderby(dt, ["g", "t"], ctx=ctx)
        assert int(ov) == 0
        assert srt.partitioning == range_partitioning(
            ("g", "t"), (True, True), 4)
        jx0 = str(jax.make_jaxpr(lambda d: table_ops.orderby(
            d, ["g", "t"], ctx=ctx))(srt))
        assert jx0.count("all_to_all") == 0

        # window on the range layout: ZERO AllToAll, ZERO sorts
        aggs = [("v", "sum"), ("v", "mean"), ("v", "min"), ("v", "count"),
                (None, "rank"), (None, "row_number"), ("v", "lag"),
                ("v", "lead")]
        jw = str(jax.make_jaxpr(lambda d: table_ops.window_aggregate(
            d, ["g"], ["t"], aggs, rows=8, ctx=ctx))(srt))
        assert jw.count("all_to_all") == 0, jw.count("all_to_all")
        assert "sort[" not in jw, "window must stay sort-free"

        # the full chain costs exactly the orderby's single AllToAll
        def chain(d):
            s, o1 = table_ops.orderby(d, ["g", "t"], ctx=ctx)
            w, o2 = table_ops.window_aggregate(
                s, ["g"], ["t"], aggs, rows=8, ctx=ctx)
            return w, o1 + o2
        jc = str(jax.make_jaxpr(chain)(dt))
        assert jc.count("all_to_all") == 1, jc.count("all_to_all")

        # parity: rolling AND cumulative vs the 1-shard oracle
        ref, _ = table_ops.orderby(dt1, ["g", "t"], ctx=one)
        for rows in (8, None):
            w4, ov4 = table_ops.window_aggregate(
                srt, ["g"], ["t"], aggs, rows=rows, ctx=ctx)
            assert int(ov4) == 0, (rows, int(ov4))
            r1, _ = table_ops.window_aggregate(
                ref, ["g"], ["t"], aggs, rows=rows, ctx=one)
            a, b = w4.to_numpy(), r1.to_numpy()
            for lbl in ("v_sum", "v_mean", "v_min", "v_count", "rank",
                        "row_number", "v_lag", "v_lead"):
                np.testing.assert_allclose(
                    a[lbl], b[lbl], rtol=1e-4, atol=1e-5,
                    err_msg=f"rows={rows} {lbl}")

        # topk: zero AllToAll, parity
        jt = str(jax.make_jaxpr(lambda d: table_ops.topk(
            d, "v", 16, ctx=ctx))(dt))
        assert jt.count("all_to_all") == 0
        np.testing.assert_allclose(
            table_ops.topk(dt, "v", 16, ctx=ctx).to_numpy()["v"],
            table_ops.topk(dt1, "v", 16, ctx=one).to_numpy()["v"],
            rtol=1e-6)

        # quantile off the range layout: zero AllToAll, zero sorts, and
        # numpy parity; approx stays within the sampling rank bound
        sv, _ = table_ops.orderby(dt, "v", ctx=ctx)
        jq = str(jax.make_jaxpr(lambda d: table_ops.quantile(
            d, "v", (0.5,), ctx=ctx))(sv))
        assert jq.count("all_to_all") == 0 and "sort[" not in jq
        qs = (0.1, 0.5, 0.9)
        np.testing.assert_allclose(
            np.asarray(table_ops.quantile(sv, "v", qs, ctx=ctx)),
            np.quantile(v, qs), rtol=1e-5, atol=1e-6)
        qa = np.asarray(table_ops.quantile(dt, "v", qs, method="approx",
                                           ctx=ctx))
        ranks = np.searchsorted(np.sort(v), qa) / n
        assert np.all(np.abs(ranks - np.asarray(qs)) < 0.05), ranks
        print("ORDERED-4WAY-OK")
        """)
    assert "ORDERED-4WAY-OK" in out


def test_window_truncation_counted_4way():
    """A rolling window deeper than a mid-partition shard's rows cannot be
    proven from the one-shard halo: it must COUNT truncations (§2), never
    return silently wrong windows."""
    out = _run_devices("""
        import numpy as np, jax.numpy as jnp
        from repro.core import (Table, DistTable, HPTMTContext, make_mesh,
                                table_ops)
        mesh = make_mesh((4,), ("data",))
        ctx = HPTMTContext(mesh=mesh)
        n = 64
        # ONE partition spanning every shard, ~16 rows per shard
        t = np.arange(n, dtype=np.int32)
        v = np.ones(n, np.float32)
        dt = DistTable.from_local(Table.from_arrays(
            {"g": jnp.zeros(n, jnp.int32), "t": jnp.asarray(t),
             "v": jnp.asarray(v)}), ctx, capacity=32)
        srt, _ = table_ops.orderby(dt, ["g", "t"], ctx=ctx)
        # window of 28 needs up to 27 rows back: beyond one shard's ~16
        w, ov = table_ops.window_aggregate(
            srt, ["g"], ["t"], [("v", "sum")], rows=28, ctx=ctx)
        assert int(ov) > 0, "deep cross-shard windows must count"
        # a window within the halo is exact and counts zero
        w2, ov2 = table_ops.window_aggregate(
            srt, ["g"], ["t"], [("v", "sum")], rows=8, ctx=ctx)
        assert int(ov2) == 0
        got = w2.to_numpy()["v_sum"]
        exp = np.minimum(np.arange(n) + 1, 8).astype(np.float32)
        np.testing.assert_allclose(got, exp)
        # topk beyond what a shard can surface is rejected, not clamped
        try:
            table_ops.topk(srt, "t", 33, ctx=ctx)
        except ValueError as e:
            assert "per-shard capacity" in str(e)
        else:
            raise AssertionError("k > capacity must raise")
        print("TRUNCATION-4WAY-OK")
        """)
    assert "TRUNCATION-4WAY-OK" in out
