"""Per-architecture smoke tests (deliverable f): every assigned arch, at a
family-preserving reduced config, runs one forward + one train step on CPU
with shape assertions and NaN checks; plus prefill↔decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced_config
from repro.models import transformer as T
from repro.train.optimizer import OptimizerConfig
from repro.train.train_step import TrainConfig, init_train_state, \
    make_train_step

ARCH_IDS = sorted(ARCHS)


def _batch(cfg, b=2, s=32, rng=None):
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    tokens = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend is not None or cfg.is_encoder_decoder:
        batch["frontend"] = 0.02 * jax.random.normal(
            rng, (b, cfg.frontend_seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = reduced_config(get_config(arch))
    params = T.init_lm(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg)
    logits, _, aux = T.apply_lm(params, cfg, batch["tokens"], mode="train",
                                frontend_embeds=batch.get("frontend"))
    b, s = batch["tokens"].shape
    exp_s = s + (cfg.frontend_seq if cfg.frontend == "vision" else 0)
    assert logits.shape == (b, exp_s, cfg.vocab_size)
    assert not np.any(np.isnan(logits)), f"{arch}: NaN logits"
    assert all(np.isfinite(float(v)) for v in aux.values())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = reduced_config(get_config(arch))
    tcfg = TrainConfig(optimizer=OptimizerConfig(warmup_steps=1,
                                                 total_steps=10))
    state = init_train_state(jax.random.PRNGKey(2), cfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = _batch(cfg)
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: non-finite loss"
    assert float(metrics["grad_norm"]) > 0
    # params actually changed
    delta = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         state.params, state2.params)
    assert max(jax.tree.leaves(delta)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    cfg = reduced_config(get_config(arch))
    if cfg.is_moe:  # capacity dropping differs between grouping modes
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    b, s = 2, 48
    params = T.init_lm(jax.random.PRNGKey(3), cfg)
    batch = _batch(cfg, b, s, jax.random.PRNGKey(4))
    pref = cfg.frontend_seq if cfg.frontend == "vision" else 0
    cache_len = s + pref + 4
    full_logits, _, _ = T.apply_lm(
        params, cfg, batch["tokens"], mode="prefill",
        frontend_embeds=batch.get("frontend"), cache_len=cache_len)
    _, cache, _ = T.apply_lm(
        params, cfg, batch["tokens"][:, :s - 1], mode="prefill",
        frontend_embeds=batch.get("frontend"), cache_len=cache_len)
    dec, _, _ = T.apply_lm(
        params, cfg, batch["tokens"][:, s - 1:], mode="decode", cache=cache,
        positions=jnp.array([s - 1 + pref], jnp.int32))
    a = np.asarray(dec[:, 0])
    e = np.asarray(full_logits[:, -1])
    rel = np.max(np.abs(a - e)) / (np.max(np.abs(e)) + 1e-9)
    assert rel < 3e-2, f"{arch}: decode inconsistent with prefill ({rel})"


def test_sliding_window_ring_cache():
    """SWA ring cache gives the same logits as an oversized linear cache."""
    cfg = reduced_config(get_config("mixtral-8x7b"))
    cfg = dataclasses.replace(cfg, capacity_factor=8.0, window=16)
    params = T.init_lm(jax.random.PRNGKey(5), cfg)
    b, s = 1, 40
    tokens = jax.random.randint(jax.random.PRNGKey(6), (b, s), 0,
                                cfg.vocab_size)
    # full forward (train mode applies the window mask over all positions)
    full, _, _ = T.apply_lm(params, cfg, tokens, mode="train")
    # prefill s-1 then decode the last token through the ring
    _, cache, _ = T.apply_lm(params, cfg, tokens[:, :-1], mode="prefill",
                             cache_len=cfg.window)
    dec, _, _ = T.apply_lm(params, cfg, tokens[:, -1:], mode="decode",
                           cache=cache,
                           positions=jnp.array([s - 1], jnp.int32))
    rel = (np.max(np.abs(np.asarray(dec[:, 0]) - np.asarray(full[:, -1])))
           / (np.max(np.abs(np.asarray(full[:, -1]))) + 1e-9))
    assert rel < 3e-2, f"ring cache mismatch {rel}"


def test_multi_step_decode_matches_prefill():
    """Three decode steps == logits of a longer prefill (dense arch)."""
    cfg = reduced_config(get_config("phi3-mini-3.8b"))
    params = T.init_lm(jax.random.PRNGKey(7), cfg)
    b, s, extra = 2, 16, 3
    tokens = jax.random.randint(jax.random.PRNGKey(8), (b, s + extra), 0,
                                cfg.vocab_size)
    full, _, _ = T.apply_lm(params, cfg, tokens, mode="prefill",
                            cache_len=s + extra)
    _, cache, _ = T.apply_lm(params, cfg, tokens[:, :s], mode="prefill",
                             cache_len=s + extra)
    for i in range(extra):
        dec, cache, _ = T.apply_lm(params, cfg, tokens[:, s + i:s + i + 1],
                                   mode="decode", cache=cache,
                                   positions=jnp.array([s + i], jnp.int32))
        a, e = np.asarray(dec[:, 0]), np.asarray(full[:, s + i])
        rel = np.max(np.abs(a - e)) / (np.max(np.abs(e)) + 1e-9)
        assert rel < 2e-2, f"step {i}: {rel}"


def test_param_count_analytic_close_to_actual():
    for arch in ("phi3-mini-3.8b", "smollm-360m", "mixtral-8x7b"):
        cfg = reduced_config(get_config(arch))
        params = T.init_lm(jax.random.PRNGKey(0), cfg)
        actual = sum(int(np.prod(p.shape))
                     for p in jax.tree.leaves(params))
        analytic = cfg.param_count()
        # analytic ignores norm scales / gate biases / expert padding
        assert abs(actual - analytic) / actual < 0.25, (
            f"{arch}: analytic {analytic} vs actual {actual}")


def test_full_configs_match_assignment():
    """Exact published hyperparameters (spot checks per arch)."""
    a = get_config("jamba-v0.1-52b")
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads, a.d_ff,
            a.vocab_size) == (32, 4096, 32, 8, 14336, 65536)
    assert a.n_experts == 16 and a.experts_per_token == 2
    assert a.block_pattern.count("attn") == 1  # 1:7 interleave
    m = get_config("mixtral-8x7b")
    assert m.window == 4096 and m.n_experts == 8
    q = get_config("qwen2-moe-a2.7b")
    assert q.n_experts == 60 and q.experts_per_token == 4
    assert q.n_shared_experts == 4 and q.vocab_size == 151936
    d = get_config("deepseek-67b")
    assert d.n_layers == 95 and d.d_model == 8192 and d.d_ff == 22016
    mc = get_config("minicpm3-4b")
    assert mc.attention == "mla" and mc.n_layers == 62
    x = get_config("xlstm-125m")
    assert x.d_ff == 0 and set(x.block_pattern) == {"mlstm", "slstm"}
    w = get_config("whisper-medium")
    assert w.is_encoder_decoder and w.frontend == "audio"
    i = get_config("internvl2-76b")
    assert i.frontend == "vision" and i.n_layers == 80


def test_mla_absorbed_decode_matches_naive():
    """Beyond-paper opt: absorbed MLA decode == naive latent expansion."""
    cfg = reduced_config(get_config("minicpm3-4b"))
    params = T.init_lm(jax.random.PRNGKey(9), cfg)
    b, s = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(10), (b, s), 0,
                                cfg.vocab_size)
    _, cache, _ = T.apply_lm(params, cfg, tokens[:, :-1], mode="prefill",
                             cache_len=s + 2)
    naive, _, _ = T.apply_lm(params, cfg, tokens[:, -1:], mode="decode",
                             cache=cache,
                             positions=jnp.array([s - 1], jnp.int32))
    cfg_abs = dataclasses.replace(cfg, mla_absorb=True)
    absorbed, _, _ = T.apply_lm(params, cfg_abs, tokens[:, -1:],
                                mode="decode", cache=cache,
                                positions=jnp.array([s - 1], jnp.int32))
    a, e = np.asarray(absorbed), np.asarray(naive)
    rel = np.max(np.abs(a - e)) / (np.max(np.abs(e)) + 1e-9)
    assert rel < 2e-2, f"absorbed MLA deviates: {rel}"


def test_int8_kv_cache_decode_close_to_full_precision():
    """Beyond-paper opt: int8 KV cache ≈ bf16 cache decode logits."""
    cfg = reduced_config(get_config("phi3-mini-3.8b"))
    params = T.init_lm(jax.random.PRNGKey(11), cfg)
    b, s = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(12), (b, s), 0,
                                cfg.vocab_size)
    outs = {}
    for quant in (False, True):
        c = dataclasses.replace(cfg, kv_quant=quant)
        _, cache, _ = T.apply_lm(params, c, tokens[:, :-1], mode="prefill",
                                 cache_len=s + 2)
        if quant:
            assert cache["groups"]["layer_0"]["mixer"]["k"].dtype == jnp.int8
        dec, cache2, _ = T.apply_lm(params, c, tokens[:, -1:], mode="decode",
                                    cache=cache,
                                    positions=jnp.array([s - 1], jnp.int32))
        if quant:
            assert cache2["groups"]["layer_0"]["mixer"]["v"].dtype == jnp.int8
        outs[quant] = np.asarray(dec[:, 0])
    rel = (np.max(np.abs(outs[True] - outs[False]))
           / (np.max(np.abs(outs[False])) + 1e-9))
    assert rel < 0.05, f"int8 KV deviates too much: {rel}"


def test_flash_kernel_path_in_model():
    """Model forward with the Pallas kernel (interpret) == XLA attend path."""
    cfg = reduced_config(get_config("phi3-mini-3.8b"))
    params = T.init_lm(jax.random.PRNGKey(13), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(14), (2, 64), 0,
                                cfg.vocab_size)
    xla, _, _ = T.apply_lm(params, cfg, tokens, mode="train")
    cfg_fl = dataclasses.replace(cfg, use_flash=True)
    flash, _, _ = T.apply_lm(params, cfg_fl, tokens, mode="train")
    a, e = np.asarray(flash), np.asarray(xla)
    rel = np.max(np.abs(a - e)) / (np.max(np.abs(e)) + 1e-9)
    assert rel < 2e-2, f"flash model path deviates: {rel}"
