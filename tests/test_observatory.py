"""Query-observatory tests (DESIGN.md §14).

Three pillars over the off-by-default collector:

  * **cardinality audit** — every physical step carries the planner's
    ``est_rows``; op-by-op collects observe ``rows_out``; the q-error
    closes the loop, ``qerror_threshold`` enforces it, and ``refine()``
    re-takes join-order decisions from observed rows (parity-tested).
  * **memory accounting** — analytic ``est_bytes`` per step from the
    packed-lane model, host RSS watermark deltas per step, pressure
    gauges from scan/spill, and the peak-memory footer in
    ``explain(analyze=True)``.
  * **run-history ledger** — one JSONL record per collect/bench run
    keyed by plan fingerprint; ``scripts/perf_report.py`` renders
    cross-run deltas and flags regressions; crashed runs leave no
    record, resumed runs share the original fingerprint.
"""
import importlib.util
import os

import numpy as np
import pytest

from repro import telemetry
from repro.core import local_context
from repro.dataframe.frame import DataFrame
from repro.io.scan import pred
from repro.plan import LazyFrame
from repro.plan.frame import optimize
from repro.plan import logical as L
from repro.resilience import FatalInjectedFault, FaultPolicy, arm, reset
from repro.telemetry import (CardinalityAuditError, ledger, q_error,
                             step_qerrors)
from repro.telemetry import memory as M
from repro.workflow.engine import Task, WorkflowEngine

SCRIPTS = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "scripts"))


@pytest.fixture(autouse=True)
def _clean_faults():
    reset()
    yield
    reset()


def _perf_report():
    spec = importlib.util.spec_from_file_location(
        "perf_report", os.path.join(SCRIPTS, "perf_report.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _df(ctx, n=64, seed=0, n_keys=8):
    rng = np.random.default_rng(seed)
    return DataFrame.from_dict(
        {"k": rng.integers(0, n_keys, n).astype(np.float32),
         "v": rng.normal(size=n).astype(np.float32)}, ctx,
        bucket_factor=4.0)


# ---------------------------------------------------------------------------
# pillar 1: cardinality audit
# ---------------------------------------------------------------------------
def test_q_error_math():
    assert q_error(10, 10) == 1.0
    assert q_error(100, 10) == 10.0
    assert q_error(10, 100) == 10.0, "symmetric: over == under"
    assert q_error(0, 0) == 1.0, "empty-vs-empty is exact, not 0/0"
    assert q_error(0, 5) == 5.0


def test_plan_steps_carry_estimates():
    ctx = local_context()
    big = _df(ctx, n=96)
    small = DataFrame.from_dict(
        {"k": np.arange(8, dtype=np.float32),
         "w": np.arange(8, dtype=np.float32)}, ctx, bucket_factor=4.0)
    lf = (big.lazy().join(small.lazy(), ["k"], max_matches=4)
          .groupby(["k"], [("v", "sum")]).sort_values("k"))
    plan = lf.physical_plan()
    for s in plan.steps:
        assert s.est_rows is not None and s.est_rows > 0, s
        assert s.est_bytes is not None and s.est_bytes > 0, s
    # estimates are deterministic: two lowerings agree exactly
    again = lf.physical_plan()
    assert [(s.est_rows, s.est_bytes) for s in plan.steps] == \
           [(s.est_rows, s.est_bytes) for s in again.steps]


def test_plain_explain_is_deterministic_with_est_rows():
    ctx = local_context()
    lf = _df(ctx).lazy().groupby(["k"], [("v", "sum")])
    first = lf.explain()
    assert "est_rows=" in first, "plain explain must show the estimate"
    assert first == lf.explain(), "est_rows must not break determinism"


def test_collect_records_qerrors_and_threshold_enforces():
    ctx = local_context()
    n = 64
    # every row matches the == predicate, but the prior says 10% — a
    # deliberate 10x miss the audit must both RECORD and ENFORCE
    df = DataFrame.from_dict(
        {"k": np.full(n, 5.0, np.float32),
         "v": np.arange(n, dtype=np.float32)}, ctx, bucket_factor=4.0)
    lf = df.lazy().filter([pred("k", "==", 5.0)])

    with telemetry.trace("qerr") as rec:
        out = lf.collect(telemetry=rec, jit=False)   # no threshold: records
    assert len(out) == n
    qs = step_qerrors(rec)
    filt = max(qs.values())
    assert abs(filt - 10.0) < 0.01, qs
    facts = rec.plan_steps[max(qs, key=qs.get)]
    assert facts["qerr"] == 10.0
    assert rec.metrics.gauges["cardinality.max_qerror"] == 10.0
    assert rec.metrics.gauges["cardinality.steps_audited"] == len(qs)

    with telemetry.trace("qerr-strict") as rec2:
        with pytest.raises(CardinalityAuditError, match="filter"):
            lf.collect(telemetry=rec2, jit=False, qerror_threshold=4.0)

    # enforcement is a strict-mode contract only
    with telemetry.trace("qerr-lax") as rec3:
        lf.collect(telemetry=rec3, jit=False, strict=False,
                   qerror_threshold=4.0)


def test_refine_repins_join_order_from_observed_rows():
    ctx = local_context()
    n = 64
    rng = np.random.default_rng(1)
    # big's == filter keeps ALL rows but is estimated at 10% → the
    # estimate rule sees 6.4 vs 32 and swaps; observation says 64 vs 32
    big = DataFrame.from_dict(
        {"k": (np.arange(n) % 8).astype(np.float32),
         "c": np.full(n, 5.0, np.float32),
         "v": rng.normal(size=n).astype(np.float32)}, ctx,
        bucket_factor=4.0)
    small = DataFrame.from_dict(
        {"k": (np.arange(32) % 8).astype(np.float32),
         "w": np.arange(32, dtype=np.float32)}, ctx, bucket_factor=4.0)
    lf = (big.lazy().filter([pred("c", "==", 5.0)])
          .join(small.lazy(), ["k"], max_matches=64, reorder=True)
          .groupby(["k"], [("v", "sum"), ("w", "sum")])
          .sort_values("k"))

    root, _ = optimize(lf.logical_plan)
    join = next(nd for nd in L.walk(root) if nd.kind == "join")
    assert join.payload["swap"] is True, "estimate rule must have fired"

    with telemetry.trace("refine") as rec:
        oracle = lf.collect(telemetry=rec, jit=False).to_numpy()

    refined = lf.refine(rec)
    rjoin = next(nd for nd in L.walk(refined.logical_plan)
                 if nd.kind == "join")
    assert rjoin.payload["swap"] is False, "observed 64>32: unswap"
    assert rjoin.payload["reorder"] is False, "decision must be PINNED"
    # the pin survives re-optimization on the next collect
    reroot, _ = optimize(refined.logical_plan)
    assert next(nd for nd in L.walk(reroot)
                if nd.kind == "join").payload["swap"] is False

    got = refined.collect().to_numpy()
    assert sorted(got) == sorted(oracle)
    for col in oracle:
        np.testing.assert_allclose(got[col], oracle[col], rtol=1e-5,
                                   err_msg=col)


# ---------------------------------------------------------------------------
# pillar 2: memory accounting
# ---------------------------------------------------------------------------
def test_rss_probes_and_watermark():
    kb = M.rss_kb()
    peak = M.peak_rss_kb()
    assert kb is not None and kb > 0
    assert peak is not None and peak >= kb * 0.5  # VmHWM never lags far
    with M.RssWatermark() as wm:
        ballast = np.ones(1 << 20, dtype=np.float64)  # 8 MiB
        ballast[0] = 2.0
    assert wm.delta_kb >= 0.0
    rec = telemetry.Collector("mem")
    M.publish_pressure(rec, "x")
    assert rec.metrics.gauges["x.pressure.rss_mb"] > 0
    assert rec.metrics.gauges["x.pressure.peak_rss_mb"] > 0


def test_step_live_bytes_model_shapes():
    base = M.step_live_bytes("filter", rows_in=100, rows_out=50,
                             cols_in=3, cols_out=3, exchanges=0,
                             n_shards=1)
    assert base > 0
    more_rows = M.step_live_bytes("filter", rows_in=1000, rows_out=500,
                                  cols_in=3, cols_out=3, exchanges=0,
                                  n_shards=1)
    assert more_rows > base, "model must scale with rows"
    exch = M.step_live_bytes("groupby", rows_in=100, rows_out=50,
                             cols_in=3, cols_out=3, exchanges=1,
                             n_shards=4)
    no_exch = M.step_live_bytes("groupby", rows_in=100, rows_out=50,
                                cols_in=3, cols_out=3, exchanges=0,
                                n_shards=4)
    assert exch > no_exch, "exchanges stage extra input copies"
    spill = M.step_live_bytes("join", rows_in=100, rows_out=100,
                              cols_in=3, cols_out=4, exchanges=0,
                              n_shards=1, spill_bytes=4096)
    dry = M.step_live_bytes("join", rows_in=100, rows_out=100,
                            cols_in=3, cols_out=4, exchanges=0,
                            n_shards=1)
    assert spill - dry == 4096, "spill run bytes are additive"


def test_collect_observes_memory_and_analyze_footer():
    ctx = local_context()
    lf = (_df(ctx, n=96).lazy()
          .groupby(["k"], [("v", "sum")]).sort_values("k"))
    with telemetry.trace("mem") as rec:
        lf.collect(telemetry=rec, jit=False)
    for idx, facts in rec.plan_steps.items():
        assert facts["est_bytes"] > 0, (idx, facts)
        assert facts["peak_rss_delta_kb"] >= 0, (idx, facts)
    sp = next(s for s in rec.all_spans() if s.name.startswith("plan.")
              and "peak_rss_delta_kb" in s.attrs)
    assert sp.attrs["est_bytes"] > 0
    txt = lf.explain(analyze=True)
    assert "memory: est_live=" in txt, txt
    assert "peak_rss_delta=" in txt, txt


def test_scan_publishes_pressure_gauges(tmp_path):
    ctx = local_context()
    data = {"a": np.arange(64, dtype=np.float32),
            "b": np.arange(64, dtype=np.float32)}
    path = str(tmp_path / "press_ds")
    DataFrame.from_dict(data, ctx).to_hpt(path, rows_per_group=16)
    with telemetry.trace("press") as rec:
        DataFrame.read_parquet(path, ctx)
    assert rec.metrics.gauges["scan.pressure.rss_mb"] > 0
    assert rec.metrics.gauges["scan.pressure.peak_rss_mb"] > 0


# ---------------------------------------------------------------------------
# pillar 3: run-history ledger + perf report
# ---------------------------------------------------------------------------
def test_ledger_roundtrip_skips_torn_line(tmp_path):
    path = str(tmp_path / "led" / "runs.jsonl")
    ledger.append(path, {"fingerprint": "fp0", "wall_s": 1.0})
    ledger.append(path, {"fingerprint": "fp0", "wall_s": 2.0})
    with open(path, "a") as f:
        f.write('{"fingerprint": "fp0", "wall')   # crash mid-append
    recs = ledger.read(path)
    assert [r["wall_s"] for r in recs] == [1.0, 2.0]
    assert ledger.read(str(tmp_path / "missing.jsonl")) == []


def test_collect_appends_fingerprinted_ledger_records(tmp_path):
    ctx = local_context()
    path = str(tmp_path / "runs.jsonl")
    lf = _df(ctx).lazy().groupby(["k"], [("v", "sum")])
    lf.collect(ledger=path)                       # un-instrumented run
    with telemetry.trace("led") as rec:
        lf.collect(telemetry=rec, jit=False, ledger=path)
    recs = ledger.read(path)
    assert len(recs) == 2
    assert recs[0]["fingerprint"] == recs[1]["fingerprint"]
    assert recs[0]["kind"] == "collect"
    assert recs[0]["wall_s"] > 0
    assert recs[0]["max_qerror"] is None, "no collector: identity only"
    assert recs[1]["max_qerror"] >= 1.0
    assert recs[1]["steps"] == len(rec.plan_steps)
    assert recs[1]["qerrors"], "instrumented run files per-step q-errors"
    assert recs[1]["audit_consistent"] is True
    assert recs[1]["peak_rss_mb"] > 0


def test_crash_leaves_no_record_and_resume_shares_fingerprint(tmp_path):
    ctx = local_context()
    path = str(tmp_path / "runs.jsonl")
    ckdir = str(tmp_path / "stages")
    pol = FaultPolicy(max_retries=1, backoff_base=0.001, backoff_max=0.01,
                      checkpoint_dir=ckdir, keep_checkpoints=True)
    big = _df(ctx, n=96)
    small = DataFrame.from_dict(
        {"k": np.arange(8, dtype=np.float32),
         "w": np.arange(8, dtype=np.float32)}, ctx, bucket_factor=4.0)

    def build():
        return (big.lazy().join(small.lazy(), ["k"], max_matches=4)
                .groupby(["k"], [("v", "sum"), ("w", "max")])
                .sort_values("k"))

    plan = build().physical_plan()
    last = plan.steps[-1].index
    assert sum(1 for s in plan.steps if s.stage) >= 2, \
        "need a committed prefix below the injected fault"

    rec1 = telemetry.Collector("run1")
    oracle = build().collect(telemetry=rec1, policy=pol,
                             ledger=path).to_numpy()
    assert rec1.metrics.counters["recovery.stages_committed"] >= 2
    assert len(ledger.read(path)) == 1

    # fatal at the LAST step: everything below it already committed,
    # the process "dies" before the ledger append
    arm(f"plan.step.{last}", "fatal")
    rec2 = telemetry.Collector("run2")
    with pytest.raises(FatalInjectedFault):
        build().collect(telemetry=rec2, policy=pol, ledger=path)
    assert len(ledger.read(path)) == 1, "crashed run must leave no record"

    rec3 = telemetry.Collector("run3")
    got = build().collect(telemetry=rec3, policy=pol,
                          ledger=path).to_numpy()
    for k, v in oracle.items():
        np.testing.assert_array_equal(v, got[k], err_msg=k)
    # the resumed run restored the committed prefix instead of re-running
    assert rec3.metrics.counters["recovery.stages_restored"] >= 1
    recs = ledger.read(path)
    assert len(recs) == 2
    assert recs[0]["fingerprint"] == recs[1]["fingerprint"], \
        "a resumed run is the SAME pipeline: one ledger key"
    assert recs[1]["counters"]["recovery.stages_restored"] >= 1


def test_perf_report_flags_exactly_the_regressed_fingerprints(tmp_path):
    pr = _perf_report()
    ctx = local_context()
    path = str(tmp_path / "runs.jsonl")
    lf = _df(ctx).lazy().groupby(["k"], [("v", "sum")])
    lf.collect()                                   # warm caches off-ledger
    lf.collect(ledger=path)                        # baseline record
    # chaos-armed retry: the whole-plan retry backs off ~0.8s before the
    # (disarmed) rerun succeeds — a deterministic >30% slowdown
    arm("plan.step.0", "io_error")
    lf.collect(ledger=path, policy=FaultPolicy(
        max_retries=2, backoff_base=0.8, backoff_factor=1.0,
        backoff_max=0.8, jitter=0.0))
    [slow_fp] = {r["fingerprint"] for r in ledger.read(path)}

    # a healthy fingerprint (mild jitter) and a q-error-drifting one
    ledger.append(path, {"fingerprint": "stable:demo", "kind": "collect",
                         "wall_s": 1.0, "max_qerror": 1.2})
    ledger.append(path, {"fingerprint": "stable:demo", "kind": "collect",
                         "wall_s": 1.1, "max_qerror": 1.25})
    ledger.append(path, {"fingerprint": "drifty:demo", "kind": "collect",
                         "wall_s": 1.0, "max_qerror": 1.0})
    ledger.append(path, {"fingerprint": "drifty:demo", "kind": "collect",
                         "wall_s": 1.0, "max_qerror": 3.0})

    rows = pr.fingerprint_deltas(ledger.read(path))
    flagged = {r["fingerprint"]: r["flags"] for r in rows if r["flags"]}
    assert set(flagged) == {slow_fp, "drifty:demo"}, flagged
    assert flagged[slow_fp] == ["TIME"]
    assert flagged["drifty:demo"] == ["QERR"]

    out = str(tmp_path / "report.md")
    assert pr.main([path, "--out", out, "--gate"]) == 1
    text = open(out).read()
    assert "**TIME**" in text and "**QERR**" in text
    assert "2 regression(s) flagged" in text

    # a single-run ledger renders as baseline and gates green
    clean = str(tmp_path / "clean.jsonl")
    ledger.append(clean, {"fingerprint": "a", "wall_s": 1.0})
    assert pr.main([clean, "--out", str(tmp_path / "clean.md"),
                    "--gate"]) == 0
    assert "| baseline |" in open(str(tmp_path / "clean.md")).read()


def test_bench_record_shape():
    r = ledger.bench_record("shuffle", 1234.5, derived="p50",
                            peak_rss_mb=99.5,
                            telemetry={"collectives": {"all-to-all": 3}})
    assert r["fingerprint"] == "bench:shuffle"
    assert r["kind"] == "bench"
    assert r["wall_s"] == pytest.approx(1234.5e-6, rel=1e-3)
    assert r["observed_a2a"] == 3
    assert r["peak_rss_mb"] == 99.5


# ---------------------------------------------------------------------------
# satellite: workflow engine observability
# ---------------------------------------------------------------------------
def test_workflow_spans_retries_and_replay_counters(tmp_path):
    class Flaky(RuntimeError):
        pass

    journal = str(tmp_path / "journal.json")
    state = {"fails": 1}

    def make_engine():
        def a():
            return 10

        def b(a):
            if state["fails"] > 0:
                state["fails"] -= 1
                raise Flaky("transient")
            return a + 1

        pol = FaultPolicy(max_retries=2, backoff_base=0.001,
                          backoff_max=0.002)
        return (WorkflowEngine(journal_path=journal, policy=pol)
                .add(Task("a", a)).add(Task("b", b, deps=("a",))))

    with telemetry.trace("wf") as rec:
        results = make_engine().run()
    assert results["b"] == 11
    names = [s.name for s in rec.all_spans()]
    assert "workflow.a" in names and "workflow.b" in names
    sb = next(s for s in rec.all_spans() if s.name == "workflow.b")
    assert sb.attrs["attempts"] == 2
    assert sb.attrs["deps"] == ["a"]
    assert rec.metrics.counters["workflow.tasks_run"] == 2
    assert rec.metrics.counters["workflow.retries"] == 1

    # resume from the journal: both tasks replay, nothing re-runs
    with telemetry.trace("wf2") as rec2:
        make_engine().run()
    assert rec2.metrics.counters["workflow.replayed"] == 2
    assert "workflow.tasks_run" not in rec2.metrics.counters
    assert not any(s.name.startswith("workflow.")
                   for s in rec2.all_spans())
