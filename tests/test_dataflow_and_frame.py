"""Dataflow (TSet) streaming semantics + DataFrame API + data pipeline."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DistTable, Table, TSet, local_context
from repro.dataframe.frame import DataFrame

CTX = local_context()


def _dt(cols, **kw):
    return DistTable.from_local(
        Table.from_arrays({k: jnp.asarray(v) for k, v in cols.items()}),
        CTX, **kw)


def test_chunked_equals_eager_groupby():
    """Dataflow (piecewise + combiner) == eager whole-table result."""
    rng = np.random.default_rng(1)
    keys = rng.integers(0, 8, 64).astype(np.int32)
    vals = rng.normal(size=64).astype(np.float32)
    dt = _dt({"k": keys, "v": vals})
    eager = DataFrame(dt, CTX).groupby(["k"], [("v", "sum"), ("v", "mean")])
    stream = (TSet.from_table(dt, CTX, chunk_rows=16)
              .groupby(["k"], [("v", "sum"), ("v", "mean")]).collect())
    a, b = eager.to_numpy(), stream.to_numpy()
    oa, ob = np.argsort(a["k"]), np.argsort(b["k"])
    np.testing.assert_array_equal(a["k"][oa], b["k"][ob])
    np.testing.assert_allclose(a["v_sum"][oa], b["v_sum"][ob], rtol=1e-4)
    np.testing.assert_allclose(a["v_mean"][oa], b["v_mean"][ob], rtol=1e-4)


def test_streaming_select_is_piecewise():
    dt = _dt({"x": np.arange(100, dtype=np.int32)})
    ts = TSet.from_table(dt, CTX, chunk_rows=10)
    out = ts.select(lambda c: c["x"] % 3 == 0).collect()
    got = np.sort(out.to_numpy()["x"])
    np.testing.assert_array_equal(got, np.arange(0, 100, 3))


def test_streaming_reduce():
    dt = _dt({"x": np.arange(50, dtype=np.float32)})
    total = TSet.from_table(dt, CTX, chunk_rows=7).reduce("x", "sum")
    assert float(total) == pytest.approx(np.arange(50).sum())


def test_dataflow_join_and_numpy_bridge():
    docs = _dt({"doc": np.array([0, 1, 2], np.int32),
                "q": np.array([0.9, 0.1, 0.8], np.float32)})
    toks = _dt({"doc": np.repeat([0, 1, 2], 4).astype(np.int32),
                "tok": np.arange(12, dtype=np.int32)})
    good = TSet.from_table(docs, CTX).select(lambda c: c["q"] > 0.5)
    joined = TSet.from_table(toks, CTX, chunk_rows=6).join(
        good, keys=["doc"], out_capacity=16)
    arrs = joined.to_numpy()      # Fig 13/17 bridge
    assert sorted(set(arrs["doc"].tolist())) == [0, 2]
    assert len(arrs["tok"]) == 8


def test_dataframe_api_roundtrip():
    df = DataFrame.from_dict(
        {"id": np.array([3, 1, 2], np.int32),
         "v": np.array([30., 10., 20.], np.float32)}, CTX)
    assert len(df) == 3
    srt = df.sort_values("id")
    np.testing.assert_array_equal(srt.to_numpy()["id"], [1, 2, 3])
    assert df.agg("v", "sum") == pytest.approx(60.0)
    mat = df.to_jax(["id", "v"])
    assert mat.shape == (3, 2)


def test_dataframe_overflow_raises():
    df = DataFrame.from_dict({"k": np.zeros(8, np.int32),
                              "v": np.arange(8, np.float32)
                              if False else np.arange(8).astype(np.float32)},
                             CTX)
    other = DataFrame.from_dict({"k": np.zeros(8, np.int32),
                                 "w": np.ones(8, np.float32)}, CTX)
    with pytest.raises(RuntimeError, match="overflow"):
        # every row matches every row (8×8=64) but out_capacity=4
        df.join(other, on=["k"], max_matches=8, out_capacity=4)


def test_data_pipeline_end_to_end():
    from repro.data.pipeline import CorpusConfig, make_training_data
    from repro.configs import get_config, reduced_config
    cfg = reduced_config(get_config("smollm-360m"))
    it = make_training_data(cfg, CTX, batch=2, seq_len=16,
                            ccfg=CorpusConfig(n_docs=16, mean_doc_len=24,
                                              vocab_size=cfg.vocab_size))
    batch = next(it)
    assert batch["tokens"].shape == (2, 16)
    assert batch["labels"].shape == (2, 16)
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(batch["tokens"][0, 1:]),
                                  np.asarray(batch["labels"][0, :-1]))
