"""Telemetry-layer tests (DESIGN.md §12).

Four contracts:

  * **off by default** — no collector active means no spans, no metrics,
    and the shared no-op span object at every instrumentation site.
  * **honest spans** — eager registered-operator calls become spans with
    rows in/out; calls inside a jit trace emit NOTHING (host clocks lie
    there), so instrumentation can never perturb a traced program.
  * **one metrics story** — OverflowReport/ScanStats/spill facts all
    surface under their dotted labels through the active collector, from
    DataFrame, TSet and the planner alike.
  * **plan-vs-observed audit** — ``collect(telemetry=...)`` records
    predicted (planner) == traced (jaxpr) == observed (compiled HLO)
    AllToAll counts; the 4-device subprocess leg asserts all three on
    the representative scan→filter→join→groupby→window chain, with
    payload bytes, and ``explain(analyze=True)`` annotates every
    physical node.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np

from repro import telemetry
from repro.core import local_context, table_ops
from repro.core.dataflow import TSet
from repro.core.report import OverflowReport
from repro.dataframe.frame import DataFrame
from repro.plan import LazyFrame

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _df(ctx, n=64, seed=0, n_keys=8):
    rng = np.random.default_rng(seed)
    return DataFrame.from_dict(
        {"k": rng.integers(0, n_keys, n).astype(np.float32),
         "v": rng.normal(size=n).astype(np.float32)}, ctx,
        bucket_factor=4.0)


# ---------------------------------------------------------------------------
# off by default
# ---------------------------------------------------------------------------
def test_off_by_default_is_one_shared_noop():
    assert telemetry.current() is None
    sp = telemetry.span("anything", tagged=1)
    assert telemetry.span("else") is sp, "off path must reuse ONE object"
    with sp as s:
        s.attrs["x"] = 1
        s.block(None)
    ctx = local_context()
    out = _df(ctx).select(lambda c: c["v"] > 0)
    assert len(out) >= 0
    assert telemetry.current() is None


def test_eager_operator_calls_become_spans_with_rows():
    ctx = local_context()
    df = _df(ctx)
    with telemetry.trace("t") as rec:
        df.groupby(["k"], [("v", "sum")])
    names = [s.name for s in rec.all_spans()]
    assert "table.groupby" in names
    g = next(s for s in rec.all_spans() if s.name == "table.groupby")
    assert g.attrs["rows_in"] == 64
    assert g.attrs["rows_out"] == 8
    assert rec.metrics.counters["table.groupby.calls"] == 1
    assert rec.metrics.counters["table.groupby.rows_in"] == 64
    assert telemetry.current() is None, "trace() must deactivate on exit"


def test_jit_internal_operator_calls_emit_nothing():
    ctx = local_context()
    df = _df(ctx)
    jfn = jax.jit(lambda t: table_ops.shuffle(t, ["k"], ctx=ctx))
    with telemetry.trace("t") as rec:
        jax.block_until_ready(jfn(df.table))
        jax.block_until_ready(jfn(df.table))
    assert not any(s.name.startswith("table.") for s in rec.all_spans()), \
        "operator calls inside a jit trace must not materialize spans"
    assert "table.shuffle.calls" not in rec.metrics.counters


def test_nested_traces_stack():
    with telemetry.trace("outer") as outer:
        with outer.span("a"):
            with telemetry.trace("inner") as inner:
                with telemetry.span("b"):
                    pass
        with telemetry.span("c"):
            pass
    assert [s.name for s in outer.all_spans()] == ["a", "c"]
    assert [s.name for s in inner.all_spans()] == ["b"]


# ---------------------------------------------------------------------------
# the one metrics story: OverflowReport / scan / TSet bridges
# ---------------------------------------------------------------------------
def test_overflow_report_to_metrics_and_gauge_idempotence():
    rep = (OverflowReport().add("join.fanout", 3)
           .add_recovered("spill.join", 7))
    assert rep.to_metrics() == {"overflow.join.fanout": 3,
                                "overflow.recovered.spill.join": 7}
    rec = telemetry.Collector()
    rec.record_overflow(rep)
    rec.record_overflow(rep)  # lineage reports are cumulative → gauges
    assert rec.metrics.gauges["overflow.join.fanout"] == 3
    assert rec.metrics.gauges["overflow.recovered.spill.join"] == 7


def test_scan_overflow_and_stats_reach_collector(tmp_path):
    ctx = local_context()
    data = {"a": np.arange(32, dtype=np.float32),
            "b": np.arange(32, dtype=np.float32)}
    path = str(tmp_path / "tele_ds")
    DataFrame.from_dict(data, ctx).to_hpt(path, rows_per_group=8)
    with telemetry.trace("scan") as rec:
        df = DataFrame.read_parquet(path, ctx, capacity=8, strict=False)
    lost = df.overflow_report.entries["scan.capacity"]
    assert lost > 0
    assert rec.metrics.gauges["overflow.scan.capacity"] == lost
    assert rec.metrics.counters["scan.rows_overflowed"] == lost
    assert rec.metrics.counters["scan.rows_scanned"] > 0
    names = [s.name for s in rec.all_spans()]
    assert "io.scan.materialize" in names
    assert "io.scan.read" in names
    read = next(s for s in rec.all_spans() if s.name == "io.scan.read")
    assert read.attrs["rows_scanned"] > 0


def test_tset_publishes_reports_through_collector():
    ctx = local_context()
    dt = _df(ctx).table
    ts = TSet.from_table(dt, ctx).select(lambda c: c["v"] > 0)
    with telemetry.trace("tset") as rec:
        ts.collect()
        assert any(s.name == "table.select" for s in rec.all_spans())
        # fabricate a lossy lineage: the publish path is the same one
        # collect()/reduce()/quantile() call after _execute
        ts._last_report = OverflowReport().add("window.truncated", 5)
        ts._publish_report()
    assert rec.metrics.gauges["overflow.window.truncated"] == 5


def test_spill_spans_and_gauges():
    from repro.spill import spill_join

    ctx = local_context()
    rng = np.random.default_rng(2)
    n = 4096
    lk = rng.integers(0, n // 4, n).astype(np.int32)
    rk = np.arange(n // 4, dtype=np.int32)
    left = DataFrame.from_dict(
        {"k": lk, "v": lk.astype(np.float32)}, ctx).table
    right = DataFrame.from_dict(
        {"k": rk, "w": rk.astype(np.float32)}, ctx).table
    with telemetry.trace("spill") as rec:
        res = spill_join(left, right, ("k",), ctx=ctx, budget_rows=512)
        rows = sum(int(c.num_rows()) for c in res.chunks())
        res.close()
    assert rows == n
    names = [s.name for s in rec.all_spans()]
    assert "spill.write" in names
    assert "spill.read" in names
    assert "spill.reentry" in names
    re_sp = next(s for s in rec.all_spans() if s.name == "spill.reentry")
    assert re_sp.attrs["op"] == "table.join"
    assert rec.metrics.gauges["spill.bytes_spilled"] > 0
    assert rec.metrics.gauges["spill.rows_in"] == n + n // 4
    assert rec.metrics.gauges["overflow.recovered.spill.join"] > 0


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------
def test_chrome_trace_and_metrics_export(tmp_path):
    with telemetry.trace("export") as rec:
        with rec.span("parent", kind="demo"):
            with rec.span("child"):
                pass
        rec.metrics.count("demo.calls", 2)
        rec.metrics.gauge("demo.level", 7)
    tpath = str(tmp_path / "trace.json")
    telemetry.export_chrome_trace(rec, tpath)
    with open(tpath) as f:
        data = json.load(f)
    evs = data["traceEvents"]
    spans = [e for e in evs if e["ph"] == "X"]
    assert {e["name"] for e in spans} == {"parent", "child"}
    parent = next(e for e in spans if e["name"] == "parent")
    child = next(e for e in spans if e["name"] == "child")
    assert parent["ts"] <= child["ts"], "child opens inside parent"
    assert parent["args"]["kind"] == "demo"
    # metadata names the process + every used lane (Perfetto grouping)
    meta = [e for e in evs if e["ph"] == "M"]
    assert any(e["name"] == "process_name"
               and e["args"]["name"] == "export" for e in meta)
    tids = {e["tid"] for e in spans}
    named = {e["tid"] for e in meta if e["name"] == "thread_name"}
    assert tids <= named, "every span lane must carry a thread_name"
    # gauges become counter tracks stamped at trace end
    counters = [e for e in evs if e["ph"] == "C"]
    level = next(e for e in counters if e["name"] == "demo.level")
    assert level["args"]["value"] == 7
    assert level["ts"] >= max(e["ts"] + e["dur"] for e in spans)

    snap = telemetry.metrics_snapshot(rec)
    assert snap["metrics"]["counters"]["demo.calls"] == 2
    assert snap["metrics"]["gauges"]["demo.level"] == 7
    assert snap["n_spans"] == 2
    mpath = str(tmp_path / "metrics.json")
    telemetry.export_metrics(rec, mpath)
    with open(mpath) as f:
        assert json.load(f)["metrics"]["counters"]["demo.calls"] == 2


# ---------------------------------------------------------------------------
# explain: determinism + analyze annotations + the audit
# ---------------------------------------------------------------------------
def _chain(ctx):
    big = _df(ctx, n=96, seed=0)
    small = DataFrame.from_dict(
        {"k": np.arange(8, dtype=np.float32),
         "w": 10.0 + np.arange(8, dtype=np.float32)}, ctx,
        bucket_factor=4.0)
    return (big.lazy()
            .join(small.lazy(), ["k"], max_matches=4)
            .groupby(["k"], [("v", "sum"), ("w", "max")])
            .sort_values("k"))


def test_explain_is_byte_identical_across_runs():
    ctx = local_context()
    first = _chain(ctx).explain()
    second = _chain(ctx).explain()
    assert first == second
    # analyze output is measured (times vary) but must not change the
    # deterministic render
    assert _chain(ctx).explain() == first


def test_explain_analyze_annotates_every_node():
    ctx = local_context()
    lf = _chain(ctx)
    plan = lf.physical_plan()
    txt = lf.explain(analyze=True)
    phys = txt.split("== physical plan ==")[1].splitlines()
    for s in plan.steps:
        line = next(ln for ln in phys
                    if ln.strip().startswith(f"{s.index}. "))
        assert "time=" in line, f"step {s.index} missing measured time"
        assert "rows=" in line, f"step {s.index} missing rows"
    assert "audit: predicted=" in txt
    assert "traced=" in txt and "observed=" in txt


def test_collect_with_telemetry_records_consistent_audit():
    ctx = local_context()
    lf = _chain(ctx)
    with telemetry.trace("audit") as rec:
        out = lf.collect(telemetry=rec, jit=False)
    assert out.overflow_report.is_exact()
    audit = rec.audits[-1]
    assert audit["consistent"] is True
    assert (audit["predicted_a2a"] == audit["traced_a2a"]
            == audit["observed_a2a"])
    assert rec.metrics.gauges["plan.predicted_a2a"] == audit["predicted_a2a"]
    # every physical step carries its predicted facts
    plan = lf.physical_plan()
    for s in plan.steps:
        assert rec.plan_steps[s.index]["strategy"] == s.strategy
        assert rec.plan_steps[s.index]["time_us"] > 0
    # the jitted path records the audit too (no per-node spans)
    with telemetry.trace("audit-jit") as rec2:
        lf.collect(telemetry=rec2, jit=True)
    assert rec2.audits[-1]["consistent"] is True
    assert not any(s.name.startswith("plan.") and s.name != "plan.collect"
                   for s in rec2.all_spans())


# ---------------------------------------------------------------------------
# satellite: importing the perf CLI must not mutate the process
# ---------------------------------------------------------------------------
def test_perf_import_is_side_effect_free():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent("""
        import os
        before = os.environ["XLA_FLAGS"]
        import repro.launch.perf as perf
        assert os.environ["XLA_FLAGS"] == before, os.environ["XLA_FLAGS"]
        from repro.telemetry.audit import top_collectives
        assert perf._top_collectives is top_collectives
        print("PERF-IMPORT-PURE")
        """)], capture_output=True, text=True, timeout=120, env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-2000:]}"
    assert "PERF-IMPORT-PURE" in r.stdout


# ---------------------------------------------------------------------------
# the 4-device contract: predicted == traced == observed, with bytes
# ---------------------------------------------------------------------------
def _run_devices(script: str, n: int = 4, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-4000:]}"
    return r.stdout


def test_telemetry_contract_4way(tmp_path):
    out = _run_devices(f"""
        import numpy as np
        from repro import telemetry
        from repro.core import host_test_context
        from repro.dataframe.frame import DataFrame
        from repro.io.scan import pred
        from repro.plan import LazyFrame

        ctx = host_test_context(n_shards=4)
        rng = np.random.default_rng(0)
        nb = 320
        big = {{"k1": rng.integers(0, 10, nb).astype(np.float32),
               "k2": rng.integers(0, 4, nb).astype(np.float32),
               "v": rng.normal(size=nb).astype(np.float32)}}
        small = {{"k1": np.repeat(np.arange(10), 4).astype(np.float32),
                 "k2": np.tile(np.arange(4), 10).astype(np.float32),
                 "w": rng.normal(size=40).astype(np.float32)}}
        path = {str(tmp_path / 'tele4_ds')!r}
        DataFrame.from_dict(big, ctx, bucket_factor=4.0).to_hpt(
            path, rows_per_group=40)
        sf = DataFrame.from_dict(small, ctx, bucket_factor=4.0)

        # the representative chain: scan -> filter -> join -> groupby
        # -> window (acceptance shape, DESIGN.md §12)
        lf = (LazyFrame.read_parquet(path, ctx, bucket_factor=4.0)
              .filter([pred("k1", "<", 8.0)])
              .join(sf.lazy(), ["k1", "k2"], max_matches=64)
              .groupby(["k2", "k1"], [("v", "sum"), ("w", "max")])
              .window(["k2", "k1"], ["v_sum"]).agg([("v_sum", "sum")]))
        plan = lf.physical_plan()
        with telemetry.trace("contract") as rec:
            # strict cardinality audit rides the representative chain:
            # the distinct-combo bound must keep every q-error under the
            # contract threshold (observed max ~1.25; margin to 2.0)
            out = lf.collect(telemetry=rec, jit=False, qerror_threshold=2.0)
        audit = rec.audits[-1]
        print("AUDIT predicted=%d traced=%d observed=%d" % (
            audit["predicted_a2a"], audit["traced_a2a"],
            audit["observed_a2a"]))
        assert audit["consistent"] is True, audit
        assert audit["predicted_a2a"] > 0, "chain must exchange"
        assert audit["observed_bytes_by_kind"]["all-to-all"] > 0
        assert all(e["bytes"] > 0 for e in audit["exchanges"])

        # every exchanging step got its traced payload bytes; every step
        # got measured time and rows, plus the observatory facts:
        # predicted (est_rows/est_bytes) and observed (qerr/rss delta)
        for s in plan.steps:
            facts = rec.plan_steps[s.index]
            assert facts["time_us"] > 0, (s.index, facts)
            assert facts["rows_out"] is not None
            assert facts["est_rows"] is not None, (s.index, facts)
            assert facts["est_bytes"] > 0, (s.index, facts)
            assert 1.0 <= facts["qerr"] <= 2.0, (s.index, facts)
            assert facts["peak_rss_delta_kb"] >= 0, (s.index, facts)
            if s.a2a:
                assert facts["a2a_bytes"] > 0, (s.index, facts)
        assert rec.metrics.gauges["cardinality.steps_audited"] == len(
            plan.steps)
        assert rec.metrics.gauges["cardinality.max_qerror"] <= 2.0

        txt = lf.explain(analyze=True)
        want = ("audit: predicted=%d traced=%d observed=%d"
                % ((audit["predicted_a2a"],) * 3))
        assert want in txt, txt
        assert txt.count("time=") >= len(plan.steps)
        print("TELEMETRY-CONTRACT-4DEV-OK")
        """)
    assert "TELEMETRY-CONTRACT-4DEV-OK" in out
    assert "AUDIT predicted=2 traced=2 observed=2" in out
