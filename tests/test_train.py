"""Training substrate: optimizer, loss, micro-batching, MoE metrics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.train.optimizer import (OptimizerConfig, adamw_update,
                                   global_norm, init_opt_state, lr_schedule)
from repro.train.train_step import (TrainConfig, cross_entropy,
                                    init_train_state, make_train_step)


def test_lr_schedule_shape():
    cfg = OptimizerConfig(learning_rate=1e-3, warmup_steps=10,
                          total_steps=100, min_lr_ratio=0.1)
    lrs = [float(lr_schedule(cfg, jnp.asarray(s))) for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert abs(lrs[2] - 1e-3) < 1e-9          # end of warmup
    assert lrs[-1] == pytest.approx(1e-4, rel=1e-3)  # min lr
    assert all(a >= b - 1e-12 for a, b in zip(lrs[2:], lrs[3:]))  # decay


def test_adamw_moves_against_gradient():
    params = {"w": jnp.ones((4, 4))}
    grads = {"w": jnp.ones((4, 4))}
    state = init_opt_state(params)
    cfg = OptimizerConfig(learning_rate=0.1, warmup_steps=0, total_steps=10,
                          weight_decay=0.0)
    new, state2, m = adamw_update(cfg, params, grads, state)
    assert np.all(np.asarray(new["w"]) < 1.0)
    assert int(state2.count) == 1
    assert float(m["grad_norm"]) == pytest.approx(4.0)


def test_grad_clipping():
    params = {"w": jnp.zeros((10,))}
    grads = {"w": jnp.full((10,), 100.0)}
    cfg = OptimizerConfig(learning_rate=1.0, warmup_steps=0, clip_norm=1.0,
                          weight_decay=0.0)
    new, _, m = adamw_update(cfg, params, grads, init_opt_state(params))
    # after clipping the update magnitude is bounded by lr (adam normalizes)
    assert np.all(np.abs(np.asarray(new["w"])) < 1.5)


def test_cross_entropy_matches_manual():
    rng = np.random.default_rng(0)
    logits = jnp.asarray(rng.normal(size=(2, 5, 7)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, 7, (2, 5)), jnp.int32)
    labels = labels.at[0, 0].set(-1)  # masked position
    loss, acc = cross_entropy(logits, labels)
    l = np.asarray(logits)
    mask = np.asarray(labels) >= 0
    p = np.exp(l - l.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    nll = -np.log(p[np.arange(2)[:, None], np.arange(5)[None],
                    np.maximum(np.asarray(labels), 0)])
    expected = (nll * mask).sum() / mask.sum()
    assert float(loss) == pytest.approx(float(expected), rel=1e-5)
    assert 0.0 <= float(acc) <= 1.0


def test_micro_batching_matches_full_batch():
    cfg = reduced_config(get_config("smollm-360m"))
    tcfg1 = TrainConfig(optimizer=OptimizerConfig(warmup_steps=0),
                        micro_batches=1)
    tcfg4 = TrainConfig(optimizer=OptimizerConfig(warmup_steps=0),
                        micro_batches=4)
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    rng = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(rng, (8, 16), 0, cfg.vocab_size)}
    batch["labels"] = batch["tokens"]
    s1, m1 = jax.jit(make_train_step(cfg, tcfg1))(state, batch)
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    s4, m4 = jax.jit(make_train_step(cfg, tcfg4))(state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=1e-3)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                     s1.params, s4.params)
    assert max(jax.tree.leaves(d)) < 5e-3


def test_loss_decreases_on_tiny_problem():
    cfg = reduced_config(get_config("smollm-360m"))
    tcfg = TrainConfig(optimizer=OptimizerConfig(
        learning_rate=3e-3, warmup_steps=2, total_steps=40))
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    rng = jax.random.PRNGKey(1)
    tokens = jax.random.randint(rng, (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}  # memorize one batch
    losses = []
    for _ in range(30):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.7, losses[::6]


def test_moe_metrics_present_and_dropping_bounded():
    cfg = reduced_config(get_config("mixtral-8x7b"))
    state = init_train_state(jax.random.PRNGKey(0), cfg)
    tcfg = TrainConfig(optimizer=OptimizerConfig())
    step = jax.jit(make_train_step(cfg, tcfg))
    rng = jax.random.PRNGKey(2)
    tokens = jax.random.randint(rng, (4, 64), 0, cfg.vocab_size)
    _, m = step(state, {"tokens": tokens, "labels": tokens})
    assert float(m["moe_aux_loss"]) > 0
    assert 0.0 <= float(m["moe_dropped_frac"]) < 0.5
