"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests see 1 device;
multi-device behaviour is exercised via subprocesses (test_distributed.py)."""
import os
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(SRC))


@pytest.fixture(scope="session")
def rng():
    import jax
    return jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# hypothesis fallback: when the package is missing, property tests skip but
# the rest of the module still collects and runs (tier-1 must never hard-fail
# on an optional dependency).  Test modules do
# ``try: from hypothesis import ... except ImportError: from conftest import ...``.
# ---------------------------------------------------------------------------
class _AbsentStrategies:
    """Stands in for ``hypothesis.strategies``; builds inert placeholders."""

    def __getattr__(self, _name):
        return lambda *a, **k: None


st = _AbsentStrategies()


def given(*_args, **_kwargs):
    return pytest.mark.skip(reason="hypothesis not installed")


def settings(*_args, **_kwargs):
    return lambda fn: fn


# ---------------------------------------------------------------------------
# pyarrow fallback (mirrors the hypothesis shim): pyarrow is the optional
# [io] extra — Arrow/Parquet tests skip when it is missing (or disabled via
# HPTMT_DISABLE_PYARROW=1, the "absent" CI leg), while the native .hpt
# storage tests always run.  Tier-1 collection never hard-fails on it.
# ---------------------------------------------------------------------------
def _pyarrow_available() -> bool:
    try:
        from repro.io.compat import has_pyarrow
    except ImportError:
        return False
    return has_pyarrow()


HAS_PYARROW = _pyarrow_available()

requires_pyarrow = pytest.mark.skipif(
    not HAS_PYARROW,
    reason="pyarrow not installed/disabled (optional [io] extra)")
