"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests see 1 device;
multi-device behaviour is exercised via subprocesses (test_distributed.py)."""
import os
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if SRC not in sys.path:
    sys.path.insert(0, os.path.abspath(SRC))


@pytest.fixture(scope="session")
def rng():
    import jax
    return jax.random.PRNGKey(0)
